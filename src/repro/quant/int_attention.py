"""Integer-arithmetic attention paths (paper's plaintext scaling experiment).

These mirror the paper's low-level Rust int16 implementation: both
mechanisms run on int32 lanes with integer-only ops so the comparison is
not biased by float-pipeline optimizations (paper §Scaling experiments).

  * inhibitor: |q − k| sums (int add/abs), shift/ReLU (int max), value
    inhibition (int sub/max) — *no variable×variable products at all*.
  * dot-product: int MACs for QKᵀ and S·V plus an integer-friendly
    Softmax surrogate (shift-normalized exp LUT as used by quantized
    transformer deployments); products force int32 accumulators from int8/16
    inputs — the "expansion to double precision" the paper refers to.

Used by benchmarks/table3_plaintext.py for the timing-vs-T scaling law and
by tests for exactness against the float reference at quantized inputs.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.quant.fake_quant import QuantConfig, compute_scale, quantize


def quantize_qkv(q, k, v, bits: int = 8) -> Tuple:
    """Shared-scale symmetric quantization of q, k, v (paper setup)."""
    cfg = QuantConfig(bits=bits)
    s = jnp.maximum(compute_scale(q, cfg),
                    jnp.maximum(compute_scale(k, cfg),
                                compute_scale(v, cfg)))
    return (quantize(q, s, cfg), quantize(k, s, cfg), quantize(v, s, cfg),
            s)


def int_inhibitor_attention(
    qi: jax.Array,        # (..., n_q, d) int32
    ki: jax.Array,        # (..., n_k, d) int32
    vi: jax.Array,        # (..., n_k, d) int32
    *,
    gamma_shift: int = 0,     # score scale as a right-shift (γ = 2^shift·d?)
    alpha_q: int = 0,         # quantized score shift α
    mask: Optional[jax.Array] = None,
) -> jax.Array:
    """Integer inhibitor attention (eq. 5/6 on int lanes).

    Z = (Σ|q−k|) >> gamma_shift; H = Σ_j max(V − Z, 0) with masked pairs
    excluded. Integer ops only: sub, abs, add, shift, max.
    """
    z = jnp.sum(jnp.abs(qi[..., :, None, :] - ki[..., None, :, :]),
                axis=-1)                                   # (..., n_q, n_k)
    z = jax.lax.shift_right_arithmetic(z, gamma_shift)
    if alpha_q:
        z = jnp.maximum(z - alpha_q, 0)
    if mask is not None:
        inhibited = jnp.maximum(vi[..., None, :, :] - z[..., :, :, None], 0)
        inhibited = inhibited * mask[..., None].astype(inhibited.dtype)
        return jnp.sum(inhibited, axis=-2)
    return jnp.sum(
        jnp.maximum(vi[..., None, :, :] - z[..., :, :, None], 0), axis=-2)


def _int_softmax_surrogate(scores: jax.Array, frac_bits: int = 8):
    """Integer Softmax surrogate: shift-normalized exp2 LUT.

    scores: int32. Returns fixed-point probabilities with ``frac_bits``
    fractional bits (int32). This is the standard integer-only softmax
    used in quantized deployments (max-subtract, exp2 via LUT on the
    clamped difference, fixed-point normalize).
    """
    m = jnp.max(scores, axis=-1, keepdims=True)
    d = jnp.clip(scores - m, -31, 0)
    # exp2 LUT: 2^d in fixed point (d in [-31, 0])
    lut = (2.0 ** jnp.arange(-31, 1, dtype=jnp.float32)
           * (1 << frac_bits)).astype(jnp.int32)
    p = lut[(d + 31).astype(jnp.int32)]
    denom = jnp.sum(p, axis=-1, keepdims=True)
    # fixed-point division
    return ((p.astype(jnp.int64) << frac_bits)
            // jnp.maximum(denom, 1).astype(jnp.int64)).astype(jnp.int32)


def int_dot_product_attention(
    qi: jax.Array,
    ki: jax.Array,
    vi: jax.Array,
    *,
    scale_shift: int = 0,
    mask: Optional[jax.Array] = None,
    frac_bits: int = 8,
) -> jax.Array:
    """Integer dot-product attention baseline (paper's comparison arm).

    QKᵀ int MACs -> shift scale -> integer softmax surrogate -> fixed-point
    S·V. Output carries ``frac_bits`` fractional bits divided out at the
    end (still integer ops).
    """
    s = jnp.einsum("...qd,...kd->...qk", qi, ki)           # int32 MACs
    s = jax.lax.shift_right_arithmetic(s, scale_shift)
    if mask is not None:
        s = jnp.where(mask, s, jnp.int32(-(1 << 30)))
    p = _int_softmax_surrogate(s, frac_bits)               # (..., q, k) fp
    out = jnp.einsum("...qk,...kd->...qd", p.astype(jnp.int64),
                     vi.astype(jnp.int64))
    return jax.lax.shift_right_arithmetic(out, frac_bits).astype(jnp.int32)
