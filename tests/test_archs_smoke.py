"""Per-architecture smoke tests: REDUCED same-family configs, one forward
(+ train gradient) step on CPU; output shapes + finiteness. The FULL
configs are exercised only via the dry-run (ShapeDtypeStruct, no alloc)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.registry import get_model
from repro.nn.module import unbox
from repro.optim import AdamWConfig
from repro.train.step import init_train_state, make_train_step


def _batch_for(api, cfg, rng, b=2, s=16):
    shape = type("S", (), {"global_batch": b, "seq_len": s,
                           "kind": "train"})()
    out = {}
    for name, spec in api.input_specs(shape).items():
        if spec.dtype == jnp.int32:
            out[name] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, spec.shape).astype(np.int32))
        else:
            out[name] = jnp.asarray(
                rng.normal(size=spec.shape).astype(np.float32))
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_smoke(rng, arch):
    cfg = get_config(arch).reduced()
    api = get_model(cfg)
    params = unbox(api.init(jax.random.PRNGKey(0)))
    batch = _batch_for(api, cfg, rng)
    logits, aux = api.forward(params, batch)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab_size
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ["smollm-135m", "qwen2-moe-a2.7b",
                                  "hymba-1.5b", "rwkv6-7b",
                                  "seamless-m4t-large-v2"])
def test_train_step_smoke(rng, arch):
    """One real optimizer step per family: loss finite, params move."""
    cfg = get_config(arch).reduced()
    api = get_model(cfg)
    opt_cfg = AdamWConfig(lr=1e-3)
    params, opt_state, _ = init_train_state(api, opt_cfg,
                                            jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(api, opt_cfg))
    batch = _batch_for(api, cfg, rng)
    p2, o2, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    moved = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), params, p2))
    assert max(moved) > 0


@pytest.mark.parametrize("arch", ["smollm-135m", "qwen2-0.5b",
                                  "hymba-1.5b"])
def test_inhibitor_variant_smoke(rng, arch):
    """The paper's mechanism drops into every attention-bearing arch."""
    cfg = get_config(f"{arch}@inhibitor").reduced()
    assert cfg.attention.mechanism == "inhibitor"
    api = get_model(cfg)
    params = unbox(api.init(jax.random.PRNGKey(0)))
    batch = _batch_for(api, cfg, rng)
    logits, _ = api.forward(params, batch)
    assert bool(jnp.isfinite(logits).all())


def test_rwkv_rejects_inhibitor():
    with pytest.raises(ValueError):
        get_config("rwkv6-7b@inhibitor")


@pytest.mark.parametrize("arch", ["smollm-135m", "hymba-1.5b", "rwkv6-7b"])
def test_decode_matches_forward(rng, arch):
    cfg = get_config(arch).reduced()
    api = get_model(cfg)
    params = unbox(api.init(jax.random.PRNGKey(0)))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)
                                    ).astype(np.int32))
    full, _ = api.forward(params, {"tokens": toks})
    states = api.init_states(2, 16)
    lg1, states = api.step(params, toks[:, :5], states)
    lg2, states = api.step(params, toks[:, 5:6], states)
    np.testing.assert_allclose(lg1, full[:, :5], rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(lg2, full[:, 5:6], rtol=2e-3, atol=2e-3)
