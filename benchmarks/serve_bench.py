"""Serving benchmark: paged vs contiguous KV-cache allocators, the
shared-prefix radix-cache arm, plus the decode-tick kernel-vs-gather arm.

Drives the continuous-batching engine over the same synthetic ragged
workload under both allocators and reports, per arm:

  * decode-tick throughput (tokens/s over the serving loop)
  * prefill compile count (bucketed single-row prefill: bounded by the
    number of buckets, not the number of distinct prompt lengths)
  * cache-memory high-water mark in bytes (pages actually held for the
    paged arm; the full up-front reservation for the contiguous arm)

and asserts greedy-output parity between the arms.  The **shared-prefix
arm** re-runs a workload where most prompt tokens are a common prefix
(system-prompt traffic) under prefix-cache on / off / contiguous
(which can never hit) and gates on: identical outputs across all three,
``prefix_hit_tokens > 0``, strictly fewer prefill tokens computed with
the cache on, a prefill compile count no higher than cache-off, and
leak-free page accounting (``pages_in_use`` returns to exactly the
resident cached pages, and to zero after ``PrefixIndex.clear``) —
written to ``BENCH_serve_prefix.json``.  A second,
attention-level microbench times one paged decode tick under the
``paged`` backend (contiguous block-table gather) against the
``paged_pallas`` backend (block-table-native kernel, DESIGN.md §10) over
the same ragged pool, asserts numerical parity, and reports wall time
plus the analytic per-tick KV HBM traffic of each arm
(``BENCH_serve_decode.json``).  On hosts where the paged kernel family
has no native lowering the kernel arm runs in Pallas interpret mode —
its wall time is not meaningful, and the JSON says so **per arm** via
``kernel.interpret`` (the gather arm is plain XLA and always records
``interpret: false``), so the trend table can refuse to compare an
interpreted timing against a real one; the HBM-traffic model is
platform-independent.

``--sustained`` runs the sustained-load decode arm instead
(``BENCH_serve_sustained.json``): long decode streams at batch 1 vs the
full batch per allocator, gated on tok/s·batch *scaling* and on the
hard paged >= contiguous throughput requirement (DESIGN.md §14).

``--latency`` runs the Poisson open-loop latency arm instead
(``BENCH_serve_latency.json``, DESIGN.md §15): mixed long/short traffic
arrives on a pre-sampled Poisson schedule (tick-indexed, so both arms
see the bit-identical workload) and the same stream is served under
whole-prompt admission (``tick_budget=None``) vs chunked interleaved
admission (``tick_budget`` set).  Reports p50/p99 time-to-first-token
and inter-token latency per arm and hard-gates on (a) greedy output
parity across the two modes and (b) interleaved admission cutting the
in-flight p99 inter-token latency to <= half of whole-prompt admission
— the "one long prompt stalls every stream" failure mode.

Results are printed as CSV rows (same shape as benchmarks.run) and
written to ``BENCH_serve_*.json`` so CI records the serving perf
trajectory.

  PYTHONPATH=src python benchmarks/serve_bench.py --smoke
  PYTHONPATH=src python benchmarks/serve_bench.py --smoke --sustained
  PYTHONPATH=src python benchmarks/serve_bench.py --smoke --latency
"""

from __future__ import annotations

import argparse
import json
import sys
import time


def run_arm(api, params, cfg, *, allocator, prompts, new_tokens,
            engine_kw, prefix_cache=False):
    from repro.serve.engine import Engine, EngineConfig, Request

    eng = Engine(api, params, EngineConfig(allocator=allocator,
                                           prefix_cache=prefix_cache,
                                           **engine_kw))
    t0 = time.perf_counter()
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new_tokens=new_tokens))
    ticks = 0
    done = []
    while eng.active or eng.queue:
        done.extend(eng.step())
        ticks += 1
        if ticks > 100_000:
            raise RuntimeError("engine did not drain")
    wall = time.perf_counter() - t0

    import numpy as np

    mcfg = api.cfg
    a = mcfg.attention
    itemsize = np.dtype(mcfg.cdtype).itemsize
    row_bytes = 2 * a.num_kv_heads * a.head_dim * itemsize  # k + v
    if allocator == "paged":
        hw_rows = eng.alloc.high_water_pages * eng.cfg.page_size
    else:
        hw_rows = engine_kw["max_batch"] * engine_kw["max_len"]
    from repro.analysis.serve_static import engine_desc

    tokens = sum(len(r.output) for r in done)
    stats = eng.stats()
    decode_ticks = max(stats["decode_ticks"], 1)
    telemetry = {}
    if eng.tel is not None and eng.tel.events is not None:
        # validate the recorded span tree in-process: the tracing arm's
        # gate is not just "it didn't crash" but "the trace is
        # well-formed Chrome trace-event JSON with balanced spans"
        from repro.serve.telemetry import (to_chrome_trace,
                                           validate_chrome_trace)
        v = validate_chrome_trace(to_chrome_trace(eng.tel))
        telemetry = {"telemetry_events": len(eng.tel.events),
                     "trace_valid": v["ok"],
                     "trace_errors": v["errors"][:5]}
    return {
        **telemetry,
        "allocator": allocator,
        "requests": len(done),
        "tokens": tokens,
        "decode_ticks": ticks,
        "wall_s": round(wall, 4),
        "tok_per_s": round(tokens / wall, 2),
        "prefill_compiles": eng.prefill_compiles,
        "decode_compiles": eng.decode_compiles,
        # the effective (post-clamp) engine config: the analyzer's
        # --check-bench re-derives the proven compile budget from this
        # record alone (repro.analysis.serve_static.cross_check_bench)
        "engine": engine_desc(eng),
        "retrace_budget": stats["retrace_budget"],
        # S1 gate material: batched block-table flushes, at most one per
        # decode tick no matter how many slots grew — and at most one per
        # prefill (not per chunk): the mirror is pushed once before the
        # chunk loop, so the prefill-side ratio is bounded by 1 even for
        # single-chunk prompts
        "table_uploads": stats["table_uploads"],
        "table_uploads_decode": stats["table_uploads_decode"],
        "table_uploads_prefill": stats["table_uploads_prefill"],
        "prefill_chunks": stats["prefill_chunks"],
        "table_uploads_per_tick": round(
            stats["table_uploads_decode"] / decode_ticks, 4),
        "table_uploads_per_prefill_chunk": round(
            stats["table_uploads_prefill"]
            / max(stats["prefill_chunks"], 1), 4),
        "cache_high_water_bytes": mcfg.num_layers * hw_rows * row_bytes,
        "prefill_tokens": stats["prefill_tokens"],
        "prefix_hit_tokens": stats["prefix_hit_tokens"],
        "forked_pages": stats["forked_pages"],
        "evictions": stats["evictions"],
        "cached_pages": stats["cached_pages"],
        "pages_in_use_after_drain": stats.get("pages_in_use", 0),
    }, {r.request_id: r.output for r in done}


def prefix_workload(cfg, rng, *, n_req, shared_len, max_suffix):
    """Prompts dominated by one shared prefix: every request is
    ``prefix ++ private_suffix`` with ``len(suffix) <= max_suffix <=
    shared_len`` — at least half of all prompt tokens are shared."""
    import numpy as np

    prefix = rng.integers(0, cfg.vocab_size, (shared_len,)).astype(np.int32)
    prompts = []
    for _ in range(n_req):
        sl = int(rng.integers(1, max_suffix + 1))
        prompts.append(np.concatenate(
            [prefix, rng.integers(0, cfg.vocab_size, (sl,)).astype(np.int32)]))
    return prompts


def run_prefix_bench(api, params, cfg, *, rng, n_req, shared_len,
                     max_suffix, new_tokens, engine_kw):
    """Shared-prefix workload under cache-on / cache-off / contiguous.

    Returns the (gated) result dict for ``BENCH_serve_prefix.json``.
    The contiguous arm simply never hits — it is the no-paging baseline
    the parity assert extends over.
    """
    prompts = prefix_workload(cfg, rng, n_req=n_req, shared_len=shared_len,
                              max_suffix=max_suffix)
    shared_tokens = n_req * shared_len
    total_tokens = sum(len(p) for p in prompts)

    arms, outputs = {}, {}
    for name, allocator, cache in (("cache_on", "paged", True),
                                   ("cache_off", "paged", False),
                                   ("contiguous", "contiguous", False)):
        res, outs = run_arm(api, params, cfg, allocator=allocator,
                            prompts=prompts, new_tokens=new_tokens,
                            engine_kw=engine_kw, prefix_cache=cache)
        arms[name] = res
        outputs[name] = outs

    on, off = arms["cache_on"], arms["cache_off"]
    gates = {
        # exactness: cached-prefix reuse must not change a single token
        "parity": (outputs["cache_on"] == outputs["cache_off"]
                   == outputs["contiguous"]),
        # the cache actually fired and saved prefill compute
        "hit_tokens_positive": on["prefix_hit_tokens"] > 0,
        "fewer_prefill_tokens": on["prefill_tokens"] < off["prefill_tokens"],
        # suffix buckets are a subset of the cold buckets (chunk | page)
        "compiles_no_higher": (on["prefill_compiles"]
                               <= off["prefill_compiles"]),
        # refcounted release: everything not cached went back to the free
        # list (cache-off must drain to zero)
        "no_leak_on": (on["pages_in_use_after_drain"] == on["cached_pages"]),
        "no_leak_off": off["pages_in_use_after_drain"] == 0,
    }
    return {
        "requests": n_req,
        "shared_prefix_len": shared_len,
        "shared_token_fraction": round(shared_tokens / total_tokens, 3),
        "prompt_tokens_total": total_tokens,
        "arms": arms,
        "gates": gates,
        "ok": all(gates.values()),
    }


def decode_kernel_bench(*, batch, page_size, pages_per_slot, num_heads,
                        num_kv_heads, head_dim, iters, seed=0):
    """One paged decode tick: block-table gather vs block-table-native
    kernel over the same ragged page pool.  Returns the result dict
    (parity-gated) for ``BENCH_serve_decode.json``."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.mechanism import (AttnShapes, MechanismParams,
                                      PagedLayout, Structural, execute_plan,
                                      plan_attention)
    from repro.kernels.ops import registry

    rng = np.random.default_rng(seed)
    num_pages = batch * pages_per_slot + 1
    pool_shape = (num_pages, page_size, num_kv_heads, head_dim)
    k_pool = jnp.asarray(rng.normal(size=pool_shape).astype(np.float32))
    v_pool = jnp.asarray(rng.normal(size=pool_shape).astype(np.float32))
    q = jnp.asarray(rng.normal(
        size=(batch, 1, num_heads, head_dim)).astype(np.float32))
    # ragged cursors over a shared pool: distinct physical pages per row,
    # unmapped tail entries on the trash page 0 (exactly the engine layout)
    max_len = pages_per_slot * page_size
    lengths = rng.integers(1, max_len + 1, (batch,)).astype(np.int32)
    perm = rng.permutation(np.arange(1, num_pages))
    tables = np.zeros((batch, pages_per_slot), np.int32)
    nxt = 0
    for b in range(batch):
        used = -(-int(lengths[b]) // page_size)
        tables[b, :used] = perm[nxt:nxt + used]
        nxt += used
    tables = jnp.asarray(tables)
    lengths = jnp.asarray(lengths)

    class _Cfg:
        mechanism = "inhibitor"
        causal = True
        sliding_window = None

    shapes = AttnShapes(
        batch=batch, n_q=1, n_k=pages_per_slot * page_size,
        num_heads=num_heads, num_kv_heads=num_kv_heads, head_dim=head_dim,
        has_cache=True, scalar_cursor=False, paged=True)
    params = MechanismParams(signed=True)
    layout = PagedLayout(tables, page_size)

    def arm(backend):
        cfg = _Cfg()
        cfg.backend = backend
        plan = plan_attention(cfg, shapes)
        structural = Structural(causal=True, window=None,
                                q_offset=lengths - 1, kv_valid_len=lengths)
        if backend == "paged_pallas":
            def tick(q_, kp, vp):
                return execute_plan(plan, q_, kp, vp, params=params,
                                    structural=structural, paged=layout)
        else:
            kj = jnp.arange(pages_per_slot * page_size)[None, :]
            mask = (kj < lengths[:, None])[:, None, None, :]

            def tick(q_, kp, vp):
                return execute_plan(plan, q_, kp, vp, params=params,
                                    mask=mask, paged=layout)
        # eager (un-jitted) warmup with concrete operands: on TPU this is
        # what triggers the kernel registry's per-shape autotune pass
        jax.block_until_ready(tick(q, k_pool, v_pool))
        fn = jax.jit(tick)
        out = jax.block_until_ready(fn(q, k_pool, v_pool))   # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(fn(q, k_pool, v_pool))
        wall = (time.perf_counter() - t0) / iters
        # analytic FLOPs/bytes from walking the tick's jaxpr with the
        # shared platform cost table — replaces hand-computed traffic
        from repro.analysis import costmodel
        static = costmodel.roofline(
            costmodel.jaxpr_costs(jax.make_jaxpr(tick)(q, k_pool, v_pool)))
        return plan, out, wall, static

    plan_g, out_g, wall_g, static_g = arm("paged")
    plan_k, out_k, wall_k, static_k = arm("paged_pallas")
    parity = bool(np.allclose(np.asarray(out_g), np.asarray(out_k),
                              rtol=1e-4, atol=1e-5))

    # analytic per-tick KV-read HBM traffic (k + v, all kv heads):
    # the gather touches every block-table entry incl. the trash-page
    # tail; the kernel walks only pages below each row's cursor
    row_bytes = 2 * num_kv_heads * head_dim * 4      # f32 k + v per KV row
    gather_rows = batch * pages_per_slot * page_size
    kernel_rows = int(sum(-(-int(l) // page_size) * page_size
                          for l in np.asarray(lengths)))
    return {
        "batch": batch,
        "page_size": page_size,
        "pages_per_slot": pages_per_slot,
        "platform": registry.platform,
        "parity": parity,
        "gather": {
            "plan": plan_g.backend, "reason": plan_g.reason,
            # the gather arm is plain XLA — it never interprets anything
            "interpret": False,
            "tick_us": round(1e6 * wall_g, 1),
            "tok_per_s": round(batch / wall_g, 1),
            "kv_hbm_bytes_per_tick": gather_rows * row_bytes,
            "static": static_g,
        },
        "kernel": {
            "plan": plan_k.backend, "reason": plan_k.reason,
            # per-arm, per-family: True anywhere the paged kernel family
            # has no native lowering — the trend table refuses to compare
            # an interpret-mode timing against a real one
            "interpret": bool(registry.interpret_for("paged")),
            "tick_us": round(1e6 * wall_k, 1),
            "tok_per_s": round(batch / wall_k, 1),
            "kv_hbm_bytes_per_tick": kernel_rows * row_bytes,
            "static": static_k,
        },
    }


def sustained_bench(api, params, cfg, *, engine_kw, seed=0):
    """Sustained-load decode: long decode streams (tiny prompts, deep
    generations) per allocator at batch 1 vs the full batch, with enough
    queued requests that slots stay continuously occupied.

    Two gate families (DESIGN.md §14):

      * **scaling** — per allocator, full-batch tok/s must reach at least
        ``SCALING_MIN``x the batch-1 tok/s.  Batched decode amortizes the
        per-tick fixed costs (dispatch, the one table upload, the one d2h
        readback) across rows; an engine whose throughput does NOT scale
        with batch has reintroduced per-slot work into the tick.
      * **paged >= contiguous** — at full batch, the paged allocator must
        meet or beat contiguous tok/s.  This is the hard form of the
        ROADMAP "close the gather gap" claim: with the all-layer fused
        gather + clamped table buckets, paged attention reads the
        bucketed high-water window while contiguous always walks the full
        ``max_len`` buffer — on the provisioned-for-the-tail serving
        regime this bench models, paging must win outright, on the CPU
        fused-gather path, not just trail within tolerance.

    Outputs are parity-gated between allocators at each batch size.
    """
    import numpy as np

    SCALING_MIN = 1.5
    full_batch = engine_kw["max_batch"]
    rng = np.random.default_rng(seed)
    prompt_len = 4
    new_tokens = max(8, min(48, engine_kw["max_len"] - prompt_len - 2))

    arms: dict = {}
    outputs: dict = {}
    for allocator in ("contiguous", "paged"):
        arms[allocator] = {}
        outputs[allocator] = {}
        for name, batch in (("single", 1), ("full", full_batch)):
            kw = {**engine_kw, "max_batch": batch}
            n_req = 2 * batch
            prompts = [rng.integers(0, cfg.vocab_size,
                                    (prompt_len,)).astype(np.int32)
                       for _ in range(n_req)]
            res, outs = run_arm(api, params, cfg, allocator=allocator,
                                prompts=prompts, new_tokens=new_tokens,
                                engine_kw=kw)
            res["batch"] = batch
            arms[allocator][name] = res
            outputs[allocator][name] = outs
        # reseed so both allocators see identical prompt streams
        rng = np.random.default_rng(seed)

    # ---- tracing-overhead arm (DESIGN.md §16) ----
    # the identical paged full-batch workload served twice: telemetry
    # absent (eng.tel is None — every hook is a single None check) vs
    # full span tracing on.  Both must produce bit-identical outputs,
    # the enabled trace must validate as well-formed Chrome trace-event
    # JSON, and the enabled arm must keep TRACING_BUDGET of the disabled
    # throughput — the declared instrumentation budget; the measured
    # overhead % is also trend-tracked warn-only so drift is visible
    # long before the hard gate trips.
    TRACING_BUDGET = 0.60
    rng = np.random.default_rng(seed + 1)
    tkw = {**engine_kw, "max_batch": full_batch}
    tprompts = [rng.integers(0, cfg.vocab_size,
                             (prompt_len,)).astype(np.int32)
                for _ in range(2 * full_batch)]
    tracing_arms: dict = {}
    tracing_outs: dict = {}
    for name, extra in (("off", {}), ("on", {"telemetry": True})):
        tracing_arms[name], tracing_outs[name] = run_arm(
            api, params, cfg, allocator="paged", prompts=tprompts,
            new_tokens=new_tokens, engine_kw={**tkw, **extra})
    t_off = tracing_arms["off"]["tok_per_s"]
    t_on = tracing_arms["on"]["tok_per_s"]

    gates = {
        # exactness first: scaling numbers mean nothing off a wrong model
        "parity_single": (outputs["paged"]["single"]
                          == outputs["contiguous"]["single"]),
        "parity_full": (outputs["paged"]["full"]
                        == outputs["contiguous"]["full"]),
        # tok/s·batch scaling per allocator
        "scaling_contiguous": (
            arms["contiguous"]["full"]["tok_per_s"]
            >= SCALING_MIN * arms["contiguous"]["single"]["tok_per_s"]),
        "scaling_paged": (
            arms["paged"]["full"]["tok_per_s"]
            >= SCALING_MIN * arms["paged"]["single"]["tok_per_s"]),
        # the hard throughput gate: paged meets/beats contiguous
        "paged_beats_contiguous": (
            arms["paged"]["full"]["tok_per_s"]
            >= arms["contiguous"]["full"]["tok_per_s"]),
        # observability contract: tracing changes nothing but wall time,
        # the recorded timeline is well-formed, and the cost of tracing
        # stays inside the declared budget
        "tracing_parity": tracing_outs["off"] == tracing_outs["on"],
        "tracing_trace_valid": bool(tracing_arms["on"].get("trace_valid")),
        "tracing_enabled_budget": t_on >= TRACING_BUDGET * t_off,
        "tracing_disabled_noise": (
            t_off >= TRACING_BUDGET * arms["paged"]["full"]["tok_per_s"]),
    }
    return {
        "prompt_len": prompt_len,
        "new_tokens": new_tokens,
        "full_batch": full_batch,
        "scaling_min": SCALING_MIN,
        "arms": arms,
        "scaling": {
            alloc: round(arms[alloc]["full"]["tok_per_s"]
                         / max(arms[alloc]["single"]["tok_per_s"], 1e-9), 3)
            for alloc in ("contiguous", "paged")},
        "tracing": {
            "budget_ratio": TRACING_BUDGET,
            "off": {"tok_per_s": t_off},
            "on": {"tok_per_s": t_on,
                   "events": tracing_arms["on"].get("telemetry_events"),
                   "trace_valid": tracing_arms["on"].get("trace_valid")},
            "overhead_pct": round(100.0 * (1.0 - t_on / max(t_off, 1e-9)),
                                  2),
        },
        "gates": gates,
        "ok": all(gates.values()),
    }


def _latency_arm(api, params, cfg, *, tick_budget, prompts, new_tokens,
                 arrivals, engine_kw):
    """Serve one pre-sampled open-loop arrival schedule to completion.

    Arrivals are indexed by engine tick, not wall clock: request ``i``
    is submitted just before the first ``step()`` whose tick index is
    ``>= arrivals[i]``, whether or not the engine has caught up.  That
    keeps the offered workload bit-identical across arms (same prompts,
    same admission order, same queue pressure) so the output-parity
    gate is meaningful, while TTFT/ITL are still measured in wall-clock
    ms by the engine's per-tick timestamps.
    """
    from repro.serve.engine import Engine, EngineConfig, Request

    eng = Engine(api, params, EngineConfig(tick_budget=tick_budget,
                                           allocator="paged", **engine_kw))
    done = []
    tick = 0
    nxt = 0
    n = len(prompts)
    while nxt < n or eng.active or eng.admitting or len(eng.scheduler):
        while nxt < n and arrivals[nxt] <= tick:
            eng.submit(Request(nxt, prompts[nxt],
                               max_new_tokens=new_tokens[nxt]))
            nxt += 1
        done.extend(eng.step())
        tick += 1
        if tick > 200_000:
            raise RuntimeError("latency arm did not drain")

    from repro.analysis.serve_static import engine_desc

    s = eng.stats()
    lat = {
        k: {"p50": round(s[f"{k}_p50"], 3),
            "p99": round(s[f"{k}_p99"], 3),
            "max": round(eng._lat[k].max, 3),
            "samples": eng._lat[k].count}
        for k in ("ttft_ms", "itl_ms", "queued_ticks")
    }
    return {
        "tick_budget": tick_budget,
        "ticks": tick,
        "requests": len(done),
        "tokens": sum(len(r.output) for r in done),
        "inflight_peak": engine_kw["max_batch"],
        "paused_prefills": s["paused_prefills"],
        "prefill_chunks": s["prefill_chunks"],
        "engine": engine_desc(eng),
        "retrace_budget": s["retrace_budget"],
        **lat,
    }, {r.request_id: r.output for r in done}


def latency_bench(api, params, cfg, *, engine_kw, smoke, seed=0):
    """Poisson open-loop latency arm (DESIGN.md §15).

    Mixed traffic — a stream of short chat-sized prompts with long
    decodes, plus a few long prompts dropped into the middle of the
    stream — arrives on one pre-sampled Poisson (exponential
    inter-arrival) schedule.  The identical schedule is served twice:

      * **whole** — ``tick_budget=None``: an admission runs the full
        prefill schedule inside one tick, so every in-flight decode
        stream stalls for the entire long prompt.
      * **interleaved** — ``tick_budget`` set: prefill advances at most
        a budget's worth of (padded) chunk tokens per tick, between
        decode ticks, so victims keep streaming while the long prompt
        admits.

    Hard gates: greedy outputs bit-identical across the two modes
    (chunked admission may not change the model), and the interleaved
    arm's in-flight p99 inter-token latency must be <= ``ITL_P99_MAX``
    of the whole-prompt arm's — the headline continuous-batching claim.
    """
    import numpy as np

    ITL_P99_MAX = 0.5  # interleaved p99 ITL must be <= half of whole's

    if smoke:
        n_short, long_plen, budget = 8, 160, 16
        short_new, long_new, mean_gap = 24, 4, 3.0
    else:
        n_short, long_plen, budget = 24, 640, 2 * engine_kw["prefill_chunk"]
        short_new, long_new, mean_gap = 32, 8, 3.0

    rng = np.random.default_rng(seed)
    prompts, new_tokens = [], []
    # long prompts sit a third and two-thirds of the way into the
    # arrival order so short streams are mid-decode when they land
    long_at = {n_short // 3, (2 * n_short) // 3}
    for i in range(n_short):
        if i in long_at:
            prompts.append(rng.integers(0, cfg.vocab_size,
                                        (long_plen,)).astype(np.int32))
            new_tokens.append(long_new)
        prompts.append(rng.integers(0, cfg.vocab_size,
                                    (int(rng.integers(4, 13)),))
                       .astype(np.int32))
        new_tokens.append(short_new)
    gaps = rng.exponential(mean_gap, len(prompts))
    arrivals = np.floor(np.cumsum(gaps)).astype(int).tolist()

    arms: dict = {}
    outputs: dict = {}
    for name, tb in (("whole", None), ("interleaved", budget)):
        arms[name], outputs[name] = _latency_arm(
            api, params, cfg, tick_budget=tb, prompts=prompts,
            new_tokens=new_tokens, arrivals=arrivals, engine_kw=engine_kw)

    whole_p99 = arms["whole"]["itl_ms"]["p99"]
    inter_p99 = arms["interleaved"]["itl_ms"]["p99"]
    gates = {
        "parity": outputs["whole"] == outputs["interleaved"],
        "itl_p99_cut": inter_p99 <= ITL_P99_MAX * whole_p99,
    }
    return {
        "requests": len(prompts),
        "long_plen": long_plen,
        "tick_budget": budget,
        "mean_gap_ticks": mean_gap,
        "itl_p99_max_ratio": ITL_P99_MAX,
        "itl_p99_ratio": round(inter_p99 / max(whole_p99, 1e-9), 4),
        "arms": arms,
        "gates": gates,
        "ok": all(gates.values()),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model/workload for CI")
    ap.add_argument("--sustained", action="store_true",
                    help="run ONLY the sustained-load decode arm "
                         "(batch-scaling + hard paged>=contiguous gates; "
                         "writes BENCH_serve_sustained.json)")
    ap.add_argument("--latency", action="store_true",
                    help="run ONLY the Poisson open-loop latency arm "
                         "(interleaved-vs-whole admission TTFT/ITL SLOs; "
                         "writes BENCH_serve_latency.json)")
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--json", default=None,
                    help="output path (default BENCH_serve_<mode>.json)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import numpy as np
    import jax

    from repro.configs import get_config
    from repro.models.registry import get_model
    from repro.nn.module import unbox

    # max_len is deliberately ~8x the longest sequence the workload
    # reaches: contiguous decode always attends over (and rewrites) the
    # full max_len buffer, while paged decode clamps its block tables to
    # the bucketed high-water width — the serving regime (capacity
    # provisioned for the tail, typical sequences far shorter) where
    # paging earns its keep.  warmup="serve" pre-traces both arms'
    # ladders at engine construction, before the timed window opens.
    if args.smoke:
        cfg = get_config(args.arch).reduced(num_layers=2, d_model=32,
                                            d_ff=64, vocab_size=128)
        engine_kw = dict(max_batch=4, max_len=512, page_size=8,
                         prefill_chunk=8, warmup="serve")
        n_req, new_tokens, max_plen = args.requests or 10, 24, 40
    else:
        cfg = get_config(args.arch).reduced()
        engine_kw = dict(max_batch=8, max_len=1024, page_size=16,
                         prefill_chunk=32, warmup="serve")
        n_req, new_tokens, max_plen = args.requests or 32, 32, 160

    api = get_model(cfg)
    params = unbox(api.init(jax.random.PRNGKey(args.seed)))

    if args.latency:
        latency = latency_bench(api, params, cfg, engine_kw=engine_kw,
                                smoke=args.smoke, seed=args.seed)
        with open("BENCH_serve_latency.json", "w") as f:
            json.dump(latency, f, indent=2, sort_keys=True)
        for name in ("whole", "interleaved"):
            r = latency["arms"][name]
            print(f"serve_latency_{name},{r['itl_ms']['p99'] * 1e3:.1f},"
                  f"ttft_p50={r['ttft_ms']['p50']}ms;"
                  f"ttft_p99={r['ttft_ms']['p99']}ms;"
                  f"itl_p50={r['itl_ms']['p50']}ms;"
                  f"itl_p99={r['itl_ms']['p99']}ms;"
                  f"paused={r['paused_prefills']}", flush=True)
        print(f"serve_latency_gates,0,"
              f"{'OK' if latency['ok'] else 'FAIL ' + str(latency['gates'])}"
              f";itl_p99_ratio={latency['itl_p99_ratio']}"
              f" -> BENCH_serve_latency.json", flush=True)
        return 0 if latency["ok"] else 1

    if args.sustained:
        sustained = sustained_bench(api, params, cfg, engine_kw=engine_kw,
                                    seed=args.seed)
        with open("BENCH_serve_sustained.json", "w") as f:
            json.dump(sustained, f, indent=2, sort_keys=True)
        for alloc in ("contiguous", "paged"):
            for armname in ("single", "full"):
                r = sustained["arms"][alloc][armname]
                print(f"serve_sustained_{alloc}_{armname},"
                      f"{1e6 * r['wall_s'] / max(r['tokens'], 1):.1f},"
                      f"tok_per_s={r['tok_per_s']};batch={r['batch']}",
                      flush=True)
        tr = sustained["tracing"]
        print(f"serve_tracing,{tr['overhead_pct']:.2f},"
              f"off={tr['off']['tok_per_s']}tok/s;"
              f"on={tr['on']['tok_per_s']}tok/s;"
              f"events={tr['on']['events']};"
              f"trace_valid={tr['on']['trace_valid']}", flush=True)
        print(f"serve_sustained_gates,0,"
              f"{'OK' if sustained['ok'] else 'FAIL ' + str(sustained['gates'])}"
              f" -> BENCH_serve_sustained.json", flush=True)
        return 0 if sustained["ok"] else 1

    rng = np.random.default_rng(args.seed)
    lens = rng.integers(1, max_plen, (n_req,))
    prompts = [rng.integers(0, cfg.vocab_size, (int(l),)).astype(np.int32)
               for l in lens]

    results = {}
    outputs = {}
    print("name,us_per_call,derived")
    for allocator in ("contiguous", "paged"):
        res, outs = run_arm(api, params, cfg, allocator=allocator,
                            prompts=prompts, new_tokens=new_tokens,
                            engine_kw=engine_kw)
        results[allocator] = res
        outputs[allocator] = outs
        us_per_tok = 1e6 * res["wall_s"] / max(res["tokens"], 1)
        print(f"serve_{allocator},{us_per_tok:.1f},"
              f"tok_per_s={res['tok_per_s']};"
              f"compiles={res['prefill_compiles']};"
              f"hwm_bytes={res['cache_high_water_bytes']}", flush=True)

    parity = outputs["paged"] == outputs["contiguous"]
    results["parity"] = bool(parity)
    results["distinct_prompt_lens"] = int(len(set(map(int, lens))))
    # S1 gate (parity-checked above): the batched table flush means at
    # most ONE block-table upload per decode tick — regression here is
    # the per-slot upload loop coming back.  Same discipline on the
    # prefill side: one upload per admission, bounded by one per chunk
    upload_gate = (results["paged"]["table_uploads_per_tick"] <= 1.0
                   and results["paged"]["table_uploads_per_prefill_chunk"]
                   <= 1.0)
    results["table_upload_gate"] = bool(upload_gate)
    # the hard throughput gate (parity-checked above): with the
    # all-layer fused gather + clamped table buckets + warmed ladder,
    # paged serving must meet/beat the contiguous baseline on this
    # host's fused-gather path — warn-only trend tracking is over
    throughput_gate = (results["paged"]["tok_per_s"]
                       >= results["contiguous"]["tok_per_s"])
    results["throughput_gate"] = bool(throughput_gate)
    # measured-vs-proven compile soundness, computed from the recorded
    # configs the same way CI's --check-bench pass does
    compile_gate = all(
        arm["prefill_compiles"] <= arm["retrace_budget"]["prefill_proven"]
        and arm["decode_compiles"] <= arm["retrace_budget"]["decode_proven"]
        for arm in (results["paged"], results["contiguous"]))
    results["compile_gate"] = bool(compile_gate)
    path = args.json or f"BENCH_serve_{'smoke' if args.smoke else 'full'}.json"
    with open(path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    print(f"serve_parity,0,{'OK' if parity else 'MISMATCH'} -> {path}",
          flush=True)
    print(f"serve_table_uploads,0,"
          f"per_tick={results['paged']['table_uploads_per_tick']};"
          f"per_prefill_chunk="
          f"{results['paged']['table_uploads_per_prefill_chunk']};"
          f"{'OK' if upload_gate else 'FAIL'}", flush=True)
    print(f"serve_throughput,0,"
          f"paged={results['paged']['tok_per_s']}tok/s vs "
          f"contiguous={results['contiguous']['tok_per_s']}tok/s;"
          f"{'OK' if throughput_gate else 'FAIL'}", flush=True)
    print(f"serve_compile_budget,0,"
          f"paged={results['paged']['decode_compiles']}/"
          f"{results['paged']['retrace_budget']['decode_proven']};"
          f"{'OK' if compile_gate else 'SOUNDNESS-FAIL'}", flush=True)

    # ---- shared-prefix radix-cache arm (DESIGN.md §11) ----
    if args.smoke:
        prefix_kw = dict(n_req=8, shared_len=24, max_suffix=12, new_tokens=6,
                         engine_kw=engine_kw)
    else:
        # page_size == prefill_chunk so suffix buckets are a subset of the
        # cold buckets (the compile-count gate)
        prefix_kw = dict(n_req=24, shared_len=96, max_suffix=48,
                         new_tokens=16,
                         engine_kw={**engine_kw, "page_size": 32})
    prefix_res = run_prefix_bench(api, params, cfg,
                                  rng=np.random.default_rng(args.seed + 1),
                                  **prefix_kw)
    with open("BENCH_serve_prefix.json", "w") as f:
        json.dump(prefix_res, f, indent=2, sort_keys=True)
    for name in ("cache_on", "cache_off", "contiguous"):
        r = prefix_res["arms"][name]
        us_per_tok = 1e6 * r["wall_s"] / max(r["tokens"], 1)
        print(f"serve_prefix_{name},{us_per_tok:.1f},"
              f"tok_per_s={r['tok_per_s']};"
              f"prefill_tokens={r['prefill_tokens']};"
              f"hit_tokens={r['prefix_hit_tokens']};"
              f"compiles={r['prefill_compiles']}", flush=True)
    print(f"serve_prefix_gates,0,"
          f"{'OK' if prefix_res['ok'] else 'FAIL ' + str(prefix_res['gates'])}"
          f" -> BENCH_serve_prefix.json", flush=True)

    # ---- decode-tick kernel-vs-gather arm (attention-level microbench) ----
    a = cfg.attention
    if args.smoke:
        decode_kw = dict(batch=4, page_size=8, pages_per_slot=8, iters=3)
    else:
        decode_kw = dict(batch=8, page_size=16, pages_per_slot=16, iters=20)
    decode = decode_kernel_bench(
        num_heads=a.num_heads, num_kv_heads=a.num_kv_heads,
        head_dim=a.head_dim, seed=args.seed, **decode_kw)
    with open("BENCH_serve_decode.json", "w") as f:
        json.dump(decode, f, indent=2, sort_keys=True)
    for armname in ("gather", "kernel"):
        r = decode[armname]
        print(f"serve_decode_{armname},{r['tick_us']:.1f},"
              f"tok_per_s={r['tok_per_s']};"
              f"kv_hbm_bytes={r['kv_hbm_bytes_per_tick']}", flush=True)
    print(f"serve_decode_parity,0,"
          f"{'OK' if decode['parity'] else 'MISMATCH'} -> "
          f"BENCH_serve_decode.json", flush=True)
    return 0 if (parity and decode["parity"] and prefix_res["ok"]
                 and upload_gate and compile_gate
                 and throughput_gate) else 1


if __name__ == "__main__":
    sys.exit(main())
