"""Feed-forward blocks: classic ReLU/GELU MLP (paper eq. 4) and gated
(SwiGLU) variants used by the llama/qwen/mistral-family architectures."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.linear import apply_dense, init_dense
from repro.nn.module import KeyGen


def _act(name: str):
    return {
        "relu": jax.nn.relu,
        "gelu": jax.nn.gelu,
        "silu": jax.nn.silu,
        "tanh": jnp.tanh,
    }[name]


def init_mlp(key, embed_dim: int, hidden_dim: int, *,
             use_bias: bool = False, dtype=jnp.float32) -> dict:
    """Two-layer MLP (paper eq. 4): x -> act(x W1 + b1) W2 + b2."""
    kg = KeyGen(key)
    return {
        "wi": init_dense(kg("wi"), (embed_dim,), (hidden_dim,),
                         ("embed",), ("mlp",), use_bias=use_bias, dtype=dtype),
        "wo": init_dense(kg("wo"), (hidden_dim,), (embed_dim,),
                         ("mlp",), ("embed",), use_bias=use_bias, dtype=dtype),
    }


def apply_mlp(params: dict, x: jax.Array, *, activation: str = "relu",
              compute_dtype=None) -> jax.Array:
    h = apply_dense(params["wi"], x, 1, compute_dtype)
    h = _act(activation)(h)
    return apply_dense(params["wo"], h, 1, compute_dtype)


def init_gated_mlp(key, embed_dim: int, hidden_dim: int, *,
                   use_bias: bool = False, dtype=jnp.float32) -> dict:
    """SwiGLU-style gated MLP: x -> (act(x Wg) * (x Wu)) Wd."""
    kg = KeyGen(key)
    return {
        "wg": init_dense(kg("wg"), (embed_dim,), (hidden_dim,),
                         ("embed",), ("mlp",), use_bias=use_bias, dtype=dtype),
        "wu": init_dense(kg("wu"), (embed_dim,), (hidden_dim,),
                         ("embed",), ("mlp",), use_bias=use_bias, dtype=dtype),
        "wd": init_dense(kg("wd"), (hidden_dim,), (embed_dim,),
                         ("mlp",), ("embed",), use_bias=use_bias, dtype=dtype),
    }


def apply_gated_mlp(params: dict, x: jax.Array, *, activation: str = "silu",
                    compute_dtype=None) -> jax.Array:
    g = _act(activation)(apply_dense(params["wg"], x, 1, compute_dtype))
    u = apply_dense(params["wu"], x, 1, compute_dtype)
    return apply_dense(params["wd"], g * u, 1, compute_dtype)
