"""Serving benchmark: paged vs contiguous KV-cache allocators.

Drives the continuous-batching engine over the same synthetic ragged
workload under both allocators and reports, per arm:

  * decode-tick throughput (tokens/s over the serving loop)
  * prefill compile count (bucketed single-row prefill: bounded by the
    number of buckets, not the number of distinct prompt lengths)
  * cache-memory high-water mark in bytes (pages actually held for the
    paged arm; the full up-front reservation for the contiguous arm)

and asserts greedy-output parity between the arms.  Results are printed
as CSV rows (same shape as benchmarks.run) and written to a
``BENCH_serve_*.json`` so CI records the serving perf trajectory.

  PYTHONPATH=src python benchmarks/serve_bench.py --smoke
"""

from __future__ import annotations

import argparse
import json
import sys
import time


def run_arm(api, params, cfg, *, allocator, prompts, new_tokens,
            engine_kw):
    from repro.serve.engine import Engine, EngineConfig, Request

    eng = Engine(api, params, EngineConfig(allocator=allocator, **engine_kw))
    t0 = time.perf_counter()
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new_tokens=new_tokens))
    ticks = 0
    done = []
    while eng.active or eng.queue:
        done.extend(eng.step())
        ticks += 1
        if ticks > 100_000:
            raise RuntimeError("engine did not drain")
    wall = time.perf_counter() - t0

    import numpy as np

    mcfg = api.cfg
    a = mcfg.attention
    itemsize = np.dtype(mcfg.cdtype).itemsize
    row_bytes = 2 * a.num_kv_heads * a.head_dim * itemsize  # k + v
    if allocator == "paged":
        hw_rows = eng.alloc.high_water_pages * eng.cfg.page_size
    else:
        hw_rows = engine_kw["max_batch"] * engine_kw["max_len"]
    tokens = sum(len(r.output) for r in done)
    return {
        "allocator": allocator,
        "requests": len(done),
        "tokens": tokens,
        "decode_ticks": ticks,
        "wall_s": round(wall, 4),
        "tok_per_s": round(tokens / wall, 2),
        "prefill_compiles": eng.prefill_compiles,
        "cache_high_water_bytes": mcfg.num_layers * hw_rows * row_bytes,
    }, {r.request_id: r.output for r in done}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model/workload for CI")
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--json", default=None,
                    help="output path (default BENCH_serve_<mode>.json)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import numpy as np
    import jax

    from repro.configs import get_config
    from repro.models.registry import get_model
    from repro.nn.module import unbox

    if args.smoke:
        cfg = get_config(args.arch).reduced(num_layers=2, d_model=32,
                                            d_ff=64, vocab_size=128)
        engine_kw = dict(max_batch=4, max_len=64, page_size=8,
                         prefill_chunk=8)
        n_req, new_tokens, max_plen = args.requests or 10, 8, 40
    else:
        cfg = get_config(args.arch).reduced()
        engine_kw = dict(max_batch=8, max_len=256, page_size=16,
                         prefill_chunk=32)
        n_req, new_tokens, max_plen = args.requests or 32, 32, 160

    api = get_model(cfg)
    params = unbox(api.init(jax.random.PRNGKey(args.seed)))
    rng = np.random.default_rng(args.seed)
    lens = rng.integers(1, max_plen, (n_req,))
    prompts = [rng.integers(0, cfg.vocab_size, (int(l),)).astype(np.int32)
               for l in lens]

    results = {}
    outputs = {}
    print("name,us_per_call,derived")
    for allocator in ("contiguous", "paged"):
        res, outs = run_arm(api, params, cfg, allocator=allocator,
                            prompts=prompts, new_tokens=new_tokens,
                            engine_kw=engine_kw)
        results[allocator] = res
        outputs[allocator] = outs
        us_per_tok = 1e6 * res["wall_s"] / max(res["tokens"], 1)
        print(f"serve_{allocator},{us_per_tok:.1f},"
              f"tok_per_s={res['tok_per_s']};"
              f"compiles={res['prefill_compiles']};"
              f"hwm_bytes={res['cache_high_water_bytes']}", flush=True)

    parity = outputs["paged"] == outputs["contiguous"]
    results["parity"] = bool(parity)
    results["distinct_prompt_lens"] = int(len(set(map(int, lens))))
    path = args.json or f"BENCH_serve_{'smoke' if args.smoke else 'full'}.json"
    with open(path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    print(f"serve_parity,0,{'OK' if parity else 'MISMATCH'} -> {path}",
          flush=True)
    return 0 if parity else 1


if __name__ == "__main__":
    sys.exit(main())
