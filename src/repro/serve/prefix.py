"""Shared-prefix radix KV cache: a page-granular trie over finished token
sequences (DESIGN.md §11).

Real serving traffic is dominated by requests that share long prompt
prefixes (system prompts, few-shot templates, multi-turn history), and
every prefill token re-computed under encryption is PBS-priced — so the
engine keeps the KV pages of finished requests alive in a radix index and
lets later admissions *mount* the longest matching page run instead of
re-prefilling it.

Granularity is the page: a KV page holds exactly ``page_size`` token
rows, so only **page-aligned** prefixes are shareable, and the trie's
alphabet is the page — each edge is labelled with a run of page-sized
token tuples and carries the physical pages backing them.  Two sequences
that diverge *inside* a page share nothing (their page tuples differ),
which is exactly the safe choice: a partially-matching page would hold
rows the new request must overwrite.

Ownership: the index holds **one allocator reference per cached page**
(`PagedAllocator.addref`).  ``insert`` takes references only on the pages
of newly created edges (re-walked prefixes keep their original pages);
``evict`` drops references LRU-leaf-first until enough pages actually
return to the free list — a leaf whose pages are still mounted by an
active slot is detached from the trie but its pages survive on the
slot's references.  Matching never blocks eviction, so the cache can
never cause an admission failure that an empty cache would not
(``PagedAllocator.attach_reclaimer`` wires ``evict`` in as the
free-list-dry fallback).
"""

from __future__ import annotations

import heapq
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.kvcache import PagedAllocator

PageKey = Tuple[int, ...]


class _Node:
    """One radix edge: ``keys[i]`` (a page-sized token tuple) is backed by
    physical page ``phys[i]``.  Children are keyed by the first page tuple
    of their edge."""

    __slots__ = ("keys", "phys", "children", "parent", "stamp")

    def __init__(self, keys: List[PageKey], phys: List[int],
                 parent: Optional["_Node"], stamp: int = 0):
        self.keys = keys
        self.phys = phys
        self.children: Dict[PageKey, "_Node"] = {}
        self.parent = parent
        self.stamp = stamp


class PrefixIndex:
    """Radix index mapping token-sequence prefixes to physical page runs.

    All operations are host-side and O(sequence length); device pool
    contents are never touched here (pages are immutable while cached —
    the engine forks before any write, DESIGN.md §11).
    """

    def __init__(self, alloc: PagedAllocator):
        self.alloc = alloc
        self.page_size = alloc.page_size
        self.root = _Node([], [], None)
        self._clock = 0
        # counters surfaced through Engine.stats()
        self.evictions = 0          # pages dropped from the index
        self.hits = 0               # match() calls returning > 0 tokens
        self.misses = 0

    # ---- helpers ----
    def _page_keys(self, tokens: Sequence[int]) -> List[PageKey]:
        """Full page-sized token tuples covering the aligned prefix."""
        ps = self.page_size
        toks = np.asarray(tokens)
        n = (len(toks) // ps) * ps
        return [tuple(int(t) for t in toks[i:i + ps])
                for i in range(0, n, ps)]

    @property
    def cached_pages(self) -> int:
        total, stack = 0, [self.root]
        while stack:
            node = stack.pop()
            total += len(node.phys)
            stack.extend(node.children.values())
        return total

    # ---- lookup ----
    def match(self, tokens: Sequence[int], *,
              touch: bool = True) -> Tuple[int, List[int]]:
        """Longest page-aligned cached prefix of ``tokens``.

        Returns ``(n_tokens, pages)`` with ``n_tokens`` a multiple of
        ``page_size`` and ``pages`` the physical pages holding those KV
        rows *in logical order*.  ``touch`` refreshes the LRU stamp of
        every node on the path (scheduler affinity probes pass
        ``touch=False`` so peeking does not distort eviction order).
        """
        keys = self._page_keys(tokens)
        if touch:
            self._clock += 1
        node, i, pages = self.root, 0, []
        while i < len(keys):
            child = node.children.get(keys[i])
            if child is None:
                break
            m = 0
            while (m < len(child.keys) and i + m < len(keys)
                   and child.keys[m] == keys[i + m]):
                m += 1
            pages.extend(child.phys[:m])
            if touch:
                child.stamp = self._clock
            i += m
            if m < len(child.keys):
                break               # diverged inside the edge
            node = child
        if touch:
            if pages:
                self.hits += 1
            else:
                self.misses += 1
        return len(pages) * self.page_size, pages

    # ---- insertion ----
    def insert(self, tokens: Sequence[int], pages: Sequence[int]) -> int:
        """Cache the page-aligned prefix of ``tokens`` backed by
        ``pages`` (physical, logical order — a finished slot's block
        run).  Only the suffix past the already-cached prefix creates
        edges, and only those pages gain an index reference; re-walked
        prefixes keep their original physical pages (the duplicates the
        finished slot held are freed with the slot).  Returns the number
        of pages newly referenced."""
        keys = self._page_keys(tokens)
        if len(pages) < len(keys):
            raise ValueError(
                f"{len(keys)} page keys but only {len(pages)} pages")
        self._clock += 1
        node, i = self.root, 0
        while i < len(keys):
            child = node.children.get(keys[i])
            if child is None:
                # new edge: take a reference on each backing page
                new_phys = [int(p) for p in pages[i:len(keys)]]
                for p in new_phys:
                    self.alloc.addref(p)
                node.children[keys[i]] = _Node(keys[i:], new_phys, node,
                                               self._clock)
                return len(new_phys)
            m = 0
            while (m < len(child.keys) and i + m < len(keys)
                   and child.keys[m] == keys[i + m]):
                m += 1
            child.stamp = self._clock
            if m < len(child.keys):
                # diverged mid-edge: split at the page-aligned boundary m
                # (m >= 1: the child was found by its first page tuple)
                mid = _Node(child.keys[:m], child.phys[:m], node,
                            self._clock)
                tail_key = child.keys[m]
                child.keys = child.keys[m:]
                child.phys = child.phys[m:]
                child.parent = mid
                mid.children[tail_key] = child
                node.children[keys[i]] = mid
                node, i = mid, i + m
            else:
                node, i = child, i + m
        return 0

    # ---- eviction ----
    def evict(self, need_pages: int) -> int:
        """Drop least-recently-used leaves until ``need_pages`` pages have
        actually returned to the free list (or nothing evictable
        remains).  Eviction is edge-at-a-time (a leaf's whole page run),
        leaf-first so interior prefixes shared by surviving entries stay
        cached; detaching a leaf can expose its parent as the next LRU
        candidate (pushed onto the same stamp-ordered heap — one trie
        walk per call, not per victim).  Returns the number of pages
        freed."""
        freed = 0
        tie = itertools.count()            # heap tiebreak (nodes unordered)
        heap = []
        stack = [self.root]
        while stack:
            node = stack.pop()
            for child in node.children.values():
                if child.children:
                    stack.append(child)
                else:
                    heapq.heappush(heap, (child.stamp, next(tie), child))
        while freed < need_pages and heap:
            _, _, victim = heapq.heappop(heap)
            for p in victim.phys:
                freed += self.alloc.decref(p)
            self.evictions += len(victim.phys)
            parent = victim.parent
            parent.children.pop(victim.keys[0])
            victim.parent = None
            if parent is not self.root and not parent.children:
                heapq.heappush(heap, (parent.stamp, next(tie), parent))
        return freed

    def clear(self) -> int:
        """Drop every cached entry (all index references)."""
        return self.evict(self.cached_pages + 1)
