"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first
device query, and tests must keep seeing 1 device.

Axis roles (DESIGN.md §6):
  pod   — data parallelism across pods (slow inter-pod links)
  data  — FSDP + batch sharding within a pod
  model — tensor/expert/sequence parallelism within a pod
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(data: int, model: int, pods: int = 1):
    """Arbitrary mesh for tests/examples (e.g. (2, 2) on 4 CPU devices)."""
    if pods > 1:
        return jax.make_mesh((pods, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
