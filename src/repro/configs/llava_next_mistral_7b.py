"""llava-next-mistral-7b — VLM: Mistral-7B backbone + anyres patch stub.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, head_dim=128.

The vision tower is a STUB per the assignment: ``input_specs`` provides
precomputed patch embeddings for up to 5 anyres tiles × 576 patches
(b, 2880, 1024), projected into the LM embedding space by a trained
2-layer-equivalent projection.  The language backbone is fully implemented.
"""

from repro.configs.base import FrontendConfig, ModelConfig
from repro.core.attention import AttentionConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=32000,
    attention=AttentionConfig(
        mechanism="dotprod", num_heads=32, num_kv_heads=8, head_dim=128,
        qkv_bias=False, use_rope=True, rope_base=1000000.0, causal=True),
    norm="rmsnorm",
    norm_eps=1e-5,
    mlp="gated_silu",
    frontend=FrontendConfig(kind="vision", embed_dim=1024,
                            tokens_per_item=576, max_tiles=5),
    tie_embeddings=False,
    max_seq_len=32768,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
