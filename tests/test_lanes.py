"""Lane abstraction: int/fhe_sim bit-exactness, float-lane closeness,
per-layer cost accounting, block-level parameter selection, and the
integer-lane bugfix regressions (masked rows, GQA, overflow headroom)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.lanes import FheSimLane, get_lane
from repro.fhe import select_params_for_report
from repro.models import transformer as tfm
from repro.models.registry import get_model
from repro.nn.lane_layers import lane_linear, lane_mlp, lane_norm
from repro.nn.module import unbox
from repro.quant.int_attention import (int_dot_product_attention,
                                       int_inhibitor_attention,
                                       lane_attention_heads,
                                       lane_dot_product_attention,
                                       lane_inhibitor_attention)
from repro.quant.ptq import PtqConfig, ptq_lm


@pytest.fixture(scope="module")
def tiny_qlm():
    """PTQ'd reduced paper-tiny (shared across lane tests)."""
    cfg = get_config("paper-tiny").reduced(
        num_layers=2, d_model=32, d_ff=64, num_heads=2, num_kv_heads=2,
        head_dim=16)
    params = unbox(get_model(cfg).init(jax.random.PRNGKey(0)))
    return cfg, params


def _tokens(cfg, n=6, b=1, seed=0):
    return np.random.default_rng(seed).integers(0, cfg.vocab_size, (b, n))


# ---------------------------------------------------------------------------
# Whole-model: fhe_sim ≡ int (bit-exact), cmul structure, params selection
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mech", ["inhibitor", "inhibitor_unsigned",
                                  "dotprod"])
def test_model_fhe_bit_exact_with_int(tiny_qlm, mech):
    cfg, params = tiny_qlm
    qlm = ptq_lm(params, cfg.with_attention_kind(mech))
    toks = _tokens(cfg)
    ref = get_lane("int")
    fhe = get_lane("fhe_sim")
    out_int = ref.to_numpy(tfm.lm_forward_lane(qlm, ref, toks))
    out_fhe = fhe.to_numpy(tfm.lm_forward_lane(qlm, fhe, toks))
    np.testing.assert_array_equal(out_int, out_fhe)
    tot = fhe.ctx.summary()
    if mech.startswith("inhibitor"):
        # the paper's core property, now at block scale
        assert tot["cmuls"] == 0
    else:
        assert tot["cmuls"] > 0
    assert tot["pbs"] > 0


def test_model_float_lane_tracks_int(tiny_qlm):
    """Float lane on the same quantized weights ≈ int lane (rounding +
    surrogate error only)."""
    cfg, params = tiny_qlm
    qlm = ptq_lm(params, cfg)
    toks = _tokens(cfg)
    li, lf = get_lane("int"), get_lane("float")
    out_i = li.to_numpy(tfm.lm_forward_lane(qlm, li, toks)).astype(float)
    out_f = lf.to_numpy(tfm.lm_forward_lane(qlm, lf, toks))
    corr = np.corrcoef(out_i.ravel(), out_f.ravel())[0, 1]
    # d_model=32 makes the dyadic-rms estimate coarse and two layers
    # compound it; paper-tiny at full width sits near 0.94
    assert corr > 0.75, corr


def test_model_int_tracks_float_reference(tiny_qlm):
    """PTQ + int lane ≈ the native float model (the end-to-end
    quantization claim; inhibitor arm)."""
    cfg, params = tiny_qlm
    qlm = ptq_lm(params, cfg)
    toks = _tokens(cfg)
    li = get_lane("int")
    out_i = li.to_numpy(tfm.lm_forward_lane(qlm, li, toks)).astype(float)
    ref, _ = get_model(cfg).forward(params, {"tokens": jnp.asarray(toks)})
    corr = np.corrcoef(np.asarray(ref).ravel(), out_i.ravel())[0, 1]
    assert corr > 0.8, corr


def test_scope_report_sums_to_totals_and_selects_params(tiny_qlm):
    cfg, params = tiny_qlm
    qlm = ptq_lm(params, cfg)
    fhe = get_lane("fhe_sim")
    tfm.lm_forward_lane(qlm, fhe, _tokens(cfg))
    report = fhe.ctx.scope_report()
    tot = fhe.ctx.summary()
    for counter in ("pbs", "cmuls", "adds", "lit_muls"):
        assert sum(s[counter] for s in report.values()) == tot[counter]
    assert max(s["max_bits_at_pbs"] for s in report.values()) \
        == tot["max_bits_at_pbs"]
    sel = select_params_for_report(report)
    assert sel.msg_bits >= tot["max_bits_at_pbs"]
    # per-sublayer scopes exist for every block layer
    assert {"L0.ln1", "L0.attn", "L0.mlp", "L1.attn", "head"} <= set(report)


def test_select_params_for_report_names_offending_layer():
    report = {"L0.attn": {"max_bits_at_pbs": 8},
              "L3.mlp": {"max_bits_at_pbs": 17}}
    with pytest.raises(ValueError, match="L3.mlp"):
        select_params_for_report(report)
    with pytest.raises(ValueError, match="empty"):
        select_params_for_report({})


# ---------------------------------------------------------------------------
# Per-layer int ≡ fhe bit-exactness and float closeness
# ---------------------------------------------------------------------------

def _rand_acts(rng, shape, ptq):
    return rng.integers(-ptq.act_clip, ptq.act_clip + 1, shape)


@pytest.mark.parametrize("subtract_mean", [False, True])
def test_lane_norm_int_fhe_exact_and_float_close(rng, subtract_mean):
    ptq = PtqConfig()
    x = _rand_acts(rng, (2, 5, 16), ptq)
    p = {"scale": np.round(rng.normal(1.0, 0.1, 16)
                           * (1 << ptq.weight_frac)).astype(np.int64),
         "bias": rng.integers(-8, 8, 16)}
    li, lf, lh = get_lane("int"), get_lane("float"), get_lane("fhe_sim")
    yi = li.to_numpy(lane_norm(li, li.array(x), p, ptq=ptq,
                               subtract_mean=subtract_mean))
    yh = lh.to_numpy(lane_norm(lh, lh.array(x), p, ptq=ptq,
                               subtract_mean=subtract_mean))
    np.testing.assert_array_equal(yi, yh)
    assert lh.ctx.summary()["cmuls"] == 0          # shift-normalized: no c×c
    yf = lf.to_numpy(lane_norm(lf, lf.array(x), p, ptq=ptq,
                               subtract_mean=subtract_mean))
    # half-step dyadic rms → normalizer within 2^(1/4); plus rounding
    err = np.abs(yf - yi) / (np.abs(yf) + 8)
    assert float(np.median(err)) < 0.25, float(np.median(err))


def test_lane_mlp_int_fhe_exact(rng):
    ptq = PtqConfig()
    x = _rand_acts(rng, (1, 4, 8), ptq)
    wi = {"kernel": rng.integers(-40, 40, (8, 16)),
          "bias": rng.integers(-500, 500, 16)}
    wo = {"kernel": rng.integers(-40, 40, (16, 8))}
    li, lh = get_lane("int"), get_lane("fhe_sim")
    yi = li.to_numpy(lane_mlp(li, li.array(x), wi, wo, ptq=ptq))
    yh = lh.to_numpy(lane_mlp(lh, lh.array(x), wi, wo, ptq=ptq))
    np.testing.assert_array_equal(yi, yh)
    s = lh.ctx.summary()
    assert s["cmuls"] == 0 and s["pbs"] == 16 * 4   # one ReLU per hidden unit
    # plaintext-weight matmuls are levelled: counted as lit-muls/adds
    assert s["lit_muls"] >= 4 * (8 * 16 + 16 * 8)


def test_lane_linear_matches_float_matmul(rng):
    ptq = PtqConfig()
    x = _rand_acts(rng, (3, 8), ptq)
    p = {"kernel": rng.integers(-64, 64, (8, 5)),
         "bias": rng.integers(-100, 100, 5)}
    li, lf = get_lane("int"), get_lane("float")
    yi = li.to_numpy(lane_linear(li, li.array(x), p, ptq=ptq))
    yf = lf.to_numpy(lane_linear(lf, lf.array(x), p, ptq=ptq))
    # float divides exactly where int floors: error < 1 integer step
    assert np.max(np.abs(yi - yf)) <= 1.0


# ---------------------------------------------------------------------------
# Integer-lane bugfix regressions (satellite sweep)
# ---------------------------------------------------------------------------

def test_int_dotprod_fully_masked_row_returns_zero(rng):
    """A fully masked query row must attend to nothing — the old -2^30
    sentinel softmax degraded to a uniform average over masked keys."""
    q = jnp.asarray(rng.integers(-7, 8, (1, 4, 3)), jnp.int32)
    k = jnp.asarray(rng.integers(-7, 8, (1, 5, 3)), jnp.int32)
    v = jnp.asarray(rng.integers(-7, 8, (1, 5, 3)), jnp.int32)
    mask = np.ones((1, 4, 5), bool)
    mask[0, 2, :] = False                    # row 2 sees nothing
    out = np.asarray(int_dot_product_attention(
        q, k, v, mask=jnp.asarray(mask)))
    assert np.all(out[0, 2] == 0)
    assert np.any(out[0, 0] != 0)
    # inhibitor arm: same exclusion semantics
    out_i = np.asarray(int_inhibitor_attention(
        q, k, v, mask=jnp.asarray(mask)))
    assert np.all(out_i[0, 2] == 0)


def test_int_attention_gqa_head_broadcast(rng):
    """GQA through lane_attention_heads ≡ manual kv-head repetition."""
    b, n, h, hk, d = 2, 6, 4, 2, 8
    q = jnp.asarray(rng.integers(-15, 16, (b, n, h, d)), jnp.int32)
    k = jnp.asarray(rng.integers(-15, 16, (b, n, hk, d)), jnp.int32)
    v = jnp.asarray(rng.integers(-15, 16, (b, n, hk, d)), jnp.int32)
    lane = get_lane("int")
    out = lane.to_numpy(lane_attention_heads(
        lane, lane_inhibitor_attention, q, k, v, gamma_shift=2, alpha_q=1,
        signed=True))
    k_rep = jnp.repeat(k, h // hk, axis=2).transpose(0, 2, 1, 3)
    v_rep = jnp.repeat(v, h // hk, axis=2).transpose(0, 2, 1, 3)
    ref = int_inhibitor_attention(q.transpose(0, 2, 1, 3), k_rep, v_rep,
                                  gamma_shift=2, alpha_q=1, signed=True)
    np.testing.assert_array_equal(out,
                                  np.asarray(ref.transpose(0, 2, 1, 3)))


def test_int_dotprod_no_overflow_at_high_frac_bits(rng):
    """frac_bits=12 over a long row stays within int32: the old
    ``(p << frac) // denom`` + int64-cast einsum silently wrapped (jax
    downcasts int64 to int32 without x64).  Exactness vs the numpy-int64
    FHE lane is the overflow oracle."""
    n_k = 600
    q = jnp.asarray(rng.integers(-127, 128, (1, 2, 8)), jnp.int32)
    k = jnp.asarray(rng.integers(-127, 128, (1, n_k, 8)), jnp.int32)
    v = jnp.asarray(rng.integers(-127, 128, (1, n_k, 8)), jnp.int32)
    out32 = np.asarray(int_dot_product_attention(
        q, k, v, scale_shift=8, frac_bits=12))
    lane = FheSimLane()
    out64 = lane.to_numpy(lane_dot_product_attention(
        lane, lane.array(np.asarray(q)), lane.array(np.asarray(k)),
        lane.array(np.asarray(v)), scale_shift=8, frac_bits=12))
    np.testing.assert_array_equal(out32, out64)


def test_int_dotprod_masked_max_ignores_dominant_masked_score(rng):
    """Fixed-point softmax is not shift-invariant: a masked (e.g. future)
    key with a dominant raw score must not drive the attendable
    probabilities to zero — the row max runs over attendable wires only."""
    q = jnp.asarray([[[8, 8]]], jnp.int32)                  # (1, 1, 2)
    k = jnp.asarray([[[1, 1], [120, 120]]], jnp.int32)      # k1 dominates
    v = jnp.asarray([[[5, 5], [99, 99]]], jnp.int32)
    mask = jnp.asarray([[[True, False]]])                   # k1 masked out
    out = np.asarray(int_dot_product_attention(
        q, k, v, frac_bits=6, mask=mask))
    np.testing.assert_array_equal(out[0, 0], [5, 5])        # attends k0 fully
    # float lane agrees (the reviewer repro: int used to return zeros)
    lf = get_lane("float")
    out_f = lf.to_numpy(lane_dot_product_attention(
        lf, lf.array(np.asarray(q)), lf.array(np.asarray(k)),
        lf.array(np.asarray(v)), frac_bits=6, mask=np.asarray(mask)))
    np.testing.assert_allclose(out_f[0, 0], [5.0, 5.0], atol=0.2)
    # and the fhe lane stays bit-exact with int under masked max
    lh = FheSimLane()
    out_h = lh.to_numpy(lane_dot_product_attention(
        lh, lh.array(np.asarray(q)), lh.array(np.asarray(k)),
        lh.array(np.asarray(v)), frac_bits=6, mask=np.asarray(mask)))
    np.testing.assert_array_equal(out, out_h)


def test_masked_row_sentinel_below_all_representable_scores(rng):
    """The masked-position fill must sit below any score the int32
    regime can represent: at head_dim=128 with 8-bit inputs an
    *attendable* score reaches −127²·128 ≈ −2^21, and a −2^20 fill would
    out-max it, collapsing the whole attendable row to zero (reviewer
    repro)."""
    d = 128
    q = jnp.asarray(np.full((1, 1, d), 127), jnp.int32)
    k = jnp.asarray(np.stack([np.full((d,), -127),      # attendable, −2.06M
                              np.full((d,), 1)])[None], jnp.int32)
    v = jnp.asarray(np.stack([np.full((d,), 50), np.full((d,), 99)])[None],
                    jnp.int32)
    mask = jnp.asarray([[[True, False]]])
    out = np.asarray(int_dot_product_attention(q, k, v, mask=mask,
                                               frac_bits=6))
    np.testing.assert_array_equal(out[0, 0], np.full(d, 50))


def test_int_backend_masked_runs_under_jit(rng):
    """The registry 'int' backend must stay jit-traceable with a mask
    (causal configs; the lane refactor briefly forced host conversion)."""
    from repro.core.attention import (AttentionConfig, apply_attention,
                                      init_attention)
    from repro.nn.module import unbox

    cfg = AttentionConfig(mechanism="inhibitor", num_heads=2,
                          num_kv_heads=2, head_dim=8, causal=True,
                          use_rope=False)
    params = unbox(init_attention(jax.random.PRNGKey(0), cfg, 16))
    qparams = jax.tree.map(
        lambda a: np.round(np.asarray(a) * 16).astype(np.int32), params)
    x = jnp.asarray(rng.integers(-7, 8, (1, 5, 16)), jnp.int32)
    y, _ = jax.jit(lambda p, t: apply_attention(p, cfg, t))(qparams, x)
    assert y.shape == (1, 5, 16)
    # dotprod arm too (masked softmax surrogate path)
    cfg_d = AttentionConfig(mechanism="dotprod", num_heads=2,
                            num_kv_heads=2, head_dim=8, causal=True,
                            use_rope=False)
    y2, _ = jax.jit(lambda p, t: apply_attention(p, cfg_d, t))(qparams, x)
    assert y2.shape == (1, 5, 16)


def test_normalized_inhibitor_survives_large_key_counts(rng):
    """The key-count reciprocal literal must keep precision for any n_k —
    a fixed 2^8 numerator truncated to zero past 256 attendable keys,
    silently zeroing every normalized output."""
    n_k = 300
    q = jnp.asarray(rng.integers(-31, 32, (1, 2, 8)), jnp.int32)
    k = jnp.asarray(rng.integers(-31, 32, (1, n_k, 8)), jnp.int32)
    v = jnp.asarray(rng.integers(-31, 32, (1, n_k, 8)), jnp.int32)
    li, lf = get_lane("int"), get_lane("float")
    oi = li.to_numpy(lane_inhibitor_attention(
        li, q, k, v, gamma_shift=2, signed=True, normalize=True))
    assert np.any(oi != 0)
    of = lf.to_numpy(lane_inhibitor_attention(
        lf, lf.array(np.asarray(q)), lf.array(np.asarray(k)),
        lf.array(np.asarray(v)), gamma_shift=2, signed=True,
        normalize=True))
    # the literal keeps ~8 significant bits at any count
    assert float(np.abs(oi - of).max()) <= 0.05 * float(
        np.abs(of).max()) + 2.0


def test_lane_norm_mean_literal_precise_at_large_d(rng):
    """1/d literals must not collapse for d > 256: mean subtraction has
    to actually remove a constant offset at d=512."""
    from repro.nn.lane_layers import _mean_literal

    c, f = _mean_literal(512)
    assert abs(c / (1 << f) - 1 / 512) < 1e-4
    ptq = PtqConfig()
    d = 512
    base = rng.integers(-20, 21, (1, 2, d))
    p = {"scale": np.full(d, 1 << ptq.weight_frac, np.int64)}
    li = get_lane("int")
    y0 = li.to_numpy(lane_norm(li, li.array(base), p, ptq=ptq,
                               subtract_mean=True))
    y_off = li.to_numpy(lane_norm(li, li.array(base + 30), p, ptq=ptq,
                                  subtract_mean=True))
    # LayerNorm surrogate is offset-invariant once the mean is removed
    assert float(np.abs(y0 - y_off).mean()) < 2.0


def test_int_dotprod_rejects_unsafe_frac_bits(rng):
    q = jnp.asarray(rng.integers(-7, 8, (1, 2, 4)), jnp.int32)
    with pytest.raises(ValueError, match="frac_bits"):
        int_dot_product_attention(q, q, q, frac_bits=13)


def test_probabilities_sum_to_one_in_fixed_point(rng):
    """The softmax surrogate's renormalized probabilities sum to ~2^fb
    per row (the property that bounds S·V accumulation regardless of
    n_k)."""
    fb = 8
    lane = FheSimLane()
    q = lane.array(rng.integers(-7, 8, (1, 5, 4)))
    k = lane.array(rng.integers(-7, 8, (1, 9, 4)))
    v_unit = lane.array(np.ones((1, 9, 1), np.int64) << fb)
    out = lane.to_numpy(lane_dot_product_attention(
        lane, q, k, v_unit, scale_shift=2, frac_bits=fb))
    # mixing a constant-2^fb value stream returns ≈ 2^fb everywhere
    assert np.all(np.abs(out - (1 << fb)) <= (1 << fb) * 0.1)


# ---------------------------------------------------------------------------
# PTQ guards
# ---------------------------------------------------------------------------

def test_ptq_rejects_unsupported_families():
    cfg = get_config("smollm-135m").reduced()      # gated_silu + rope
    params = unbox(get_model(cfg).init(jax.random.PRNGKey(0)))
    with pytest.raises(ValueError, match="gated|RoPE"):
        ptq_lm(params, cfg)


def test_lane_registry_unknown_lane():
    with pytest.raises(ValueError, match="unknown lane"):
        get_lane("concrete")
