"""Serve-path telemetry: span tracer, flight recorder, metrics registry
(DESIGN.md §16).

Three cooperating pieces, all host-only and allocation-bounded:

  * **Tracer** — a lightweight event bus the engine threads through its
    tick loop.  Every request gets a lifecycle span tree on its own
    Perfetto track (submit → queued → admitted[prefix credit] → prefill
    chunk batches [paused/resumed] → decode → finish/cancel/truncated);
    every engine tick gets phase attribution on track 0 (prefill pass,
    scheduler, decode step) plus instants for table uploads, CoW forks,
    evictions, and first-seen decode buckets (with kernel/plan
    provenance attached as args).  Events are 6-tuples appended to a
    plain list — no objects, no I/O, no device interaction — and the
    same append feeds a bounded ``deque`` ring (the flight recorder).
  * **Flight recorder** — the last ``ring`` events, dumped to JSON by
    the engine's error paths (:func:`dump_flight`): a crash leaves the
    final K scheduling decisions on disk even when no trace was
    requested.
  * **MetricsRegistry** — unifies the engine's counters with bounded
    reservoir :class:`Histogram` s (Vitter's algorithm R), replacing the
    unbounded per-request latency lists: O(capacity) memory and
    O(capacity log capacity) percentile cost no matter how long the
    engine runs.

**Zero-overhead-off contract:** the engine holds ``self.tel = None``
when telemetry is disabled and guards every hook with one attribute
load + ``is not None`` — no event tuples, no ring, no timestamps.  The
host-sync audit (``repro.analysis.serve_static.audit_telemetry_file``)
closes the call graph over the emit-path functions below and proves
they perform **zero** host<->device transfers, so instrumentation can
never add h2d/d2h traffic to the tick path (the engine's own 2 h2d +
1 d2h contract is audited separately and unchanged).

Exporter writes Chrome trace-event JSON loadable in Perfetto
(https://ui.perfetto.dev).  CLI validates + summarizes a trace::

    python -m repro.serve.telemetry TRACE_serve.json

Exit status is non-zero when the trace is malformed: unbalanced or
misnested B/E spans, non-monotonic per-track timestamps, or a request
span that never reaches a terminal event.
"""

from __future__ import annotations

import dataclasses
import json
import random
import time
from collections import deque
from pathlib import Path
from typing import Any, Dict, List, Optional

import numpy as np

SCHEMA = 1

#: single-process trace: every event shares one pid
PID = 1
#: track 0 is the engine tick timeline; request lifecycles get their own
#: track at REQ_TID_BASE + request_id so Perfetto renders one swimlane
#: per request under the tick timeline
TID_ENGINE = 0
REQ_TID_BASE = 1000

#: terminal request states (exactly one instant per request track)
TERMINALS = ("finish", "cancel", "truncated")

__all__ = [
    "SCHEMA", "PID", "TID_ENGINE", "REQ_TID_BASE", "TERMINALS",
    "Histogram", "MetricsRegistry", "TelemetryConfig", "Tracer",
    "make_tracer", "to_chrome_trace", "write_trace", "dump_flight",
    "validate_chrome_trace", "summarize_chrome_trace", "main",
]


# --------------------------------------------------------------------------
# metrics registry: counters + bounded reservoir histograms
# --------------------------------------------------------------------------

class Histogram:
    """Fixed-capacity reservoir sample (Vitter's algorithm R) with exact
    count/min/max/sum.  Replaces the engine's unbounded latency lists:
    ``record`` is O(1), percentiles are computed over at most
    ``capacity`` values, and memory never grows with serve time.  The
    reservoir RNG is private and deterministically seeded — recording
    never perturbs ``random``'s global state or jax keys."""

    __slots__ = ("capacity", "count", "total", "vmin", "vmax",
                 "_vals", "_rng")

    def __init__(self, capacity: int = 512, seed: int = 0x5EED):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.count = 0
        self.total = 0.0
        self.vmin = 0.0
        self.vmax = 0.0
        self._vals: List[float] = []
        self._rng = random.Random(seed)

    def record(self, v) -> None:
        v = float(v)  # sync: host — latency samples arrive as host scalars (the engine reads device values upstream, under its own audited tag)
        self.count += 1
        self.total += v
        if self.count == 1 or v < self.vmin:
            self.vmin = v
        if self.count == 1 or v > self.vmax:
            self.vmax = v
        if len(self._vals) < self.capacity:
            self._vals.append(v)
        else:
            j = self._rng.randrange(self.count)
            if j < self.capacity:
                self._vals[j] = v

    @property
    def max(self) -> float:
        return self.vmax

    @property
    def min(self) -> float:
        return self.vmin

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def __len__(self) -> int:
        return self.count

    def percentile(self, p: float) -> float:
        """Percentile over the reservoir (the exact percentile while
        ``count <= capacity``; an unbiased estimate after)."""
        if not self._vals:
            return 0.0
        return float(np.percentile(self._vals, p))  # sync: host — the reservoir is host-resident python floats

    def snapshot(self) -> Dict[str, Any]:
        return {
            "count": self.count,
            "mean": round(self.mean, 4),
            "min": round(self.vmin, 4),
            "max": round(self.vmax, 4),
            "p50": round(self.percentile(50), 4),
            "p99": round(self.percentile(99), 4),
            "reservoir": len(self._vals),
            "capacity": self.capacity,
        }


class MetricsRegistry:
    """One home for the engine's scalar counters and bounded histograms.
    ``Engine.counters`` aliases ``self.counters`` so every existing
    counter key keeps working; histograms back ``Engine.stats()``'s
    ``*_p50`` / ``*_p99`` / ``latency_samples`` surface."""

    def __init__(self):
        self.counters: Dict[str, int] = {}
        self.histograms: Dict[str, Histogram] = {}

    def counter(self, name: str, inc: int = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + inc

    def histogram(self, name: str, capacity: int = 512) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(capacity)
        return h

    def snapshot(self) -> Dict[str, Any]:
        return {
            "counters": dict(self.counters),
            "histograms": {k: h.snapshot()
                           for k, h in sorted(self.histograms.items())},
        }


# --------------------------------------------------------------------------
# tracer + flight-recorder ring
# --------------------------------------------------------------------------

@dataclasses.dataclass
class TelemetryConfig:
    """Telemetry spec for ``EngineConfig.telemetry``.  ``trace=False``
    keeps only the flight-recorder ring (crash forensics with O(ring)
    memory); ``ring=0`` disables the ring."""
    trace: bool = True
    ring: int = 256
    flight_path: str = "FLIGHT_serve.json"


class Tracer:
    """Append-only span/event recorder.  An event is the 6-tuple
    ``(ts_us, ph, name, cat, tid, args)`` — ``ph`` is the Chrome
    trace-event phase (B/E/i/X/C).  Emission is two list appends at
    most; export/validation cost is paid only when a trace is written.

    The ``request_*`` helpers encode the lifecycle span grammar in ONE
    place so the engine call sites stay single guarded lines and the
    validator can rely on the nesting:

        B request > B queued .. E queued > i admitted > B prefill
        [X prefill_chunks / i paused / i resumed / i restaged_uncached]*
        .. E prefill > B decode .. E decode > i finish|cancel|truncated
        > E request
    """

    def __init__(self, *, trace: bool = True, ring: int = 256,
                 flight_path: str = "FLIGHT_serve.json"):
        self.events: Optional[List[tuple]] = [] if trace else None
        self.ring: Optional[deque] = (deque(maxlen=ring) if ring > 0
                                      else None)
        self.flight_path = flight_path
        self.dropped = 0           # events evicted from the ring
        self.meta: Dict[str, Any] = {}
        self.thread_names: Dict[int, str] = {TID_ENGINE: "engine ticks"}
        self._t0 = time.perf_counter()

    # ---- core emit path (audited: zero host<->device transfers) ----
    def now(self) -> float:
        """Microseconds since tracer construction (trace timebase)."""
        return (time.perf_counter() - self._t0) * 1e6

    def _emit(self, ts: Optional[float], ph: str, name: str, cat: str,
              tid: int, args: Optional[Dict[str, Any]]) -> None:
        ev = (self.now() if ts is None else ts, ph, name, cat, tid, args)
        ring = self.ring
        if ring is not None:
            if len(ring) == ring.maxlen:
                self.dropped += 1
            ring.append(ev)
        if self.events is not None:
            self.events.append(ev)

    def begin(self, name: str, tid: int = TID_ENGINE, cat: str = "tick",
              **args) -> None:
        self._emit(None, "B", name, cat, tid, args or None)

    def end(self, name: str, tid: int = TID_ENGINE) -> None:
        self._emit(None, "E", name, "", tid, None)

    def instant(self, name: str, tid: int = TID_ENGINE, cat: str = "tick",
                **args) -> None:
        self._emit(None, "i", name, cat, tid, args or None)

    def complete(self, name: str, start: float, tid: int = TID_ENGINE,
                 cat: str = "tick", **args) -> None:
        """X event spanning [start, now) — ``start`` from :meth:`now`."""
        args["_dur"] = self.now() - start
        self._emit(start, "X", name, cat, tid, args)

    def counter(self, name: str, tid: int = TID_ENGINE, **series) -> None:
        self._emit(None, "C", name, "tick", tid, series)

    def set_meta(self, key: str, value: Any) -> None:
        """Trace-level metadata (plan/kernel provenance, engine config);
        exported under ``otherData.meta``, JSON-serializable values."""
        self.meta[key] = value

    def set_thread_name(self, tid: int, label: str) -> None:
        self.thread_names[tid] = label

    # ---- request lifecycle grammar ----
    def request_submit(self, rid: int, prompt_len: int,
                       max_new_tokens: int, priority: int) -> None:
        tid = REQ_TID_BASE + rid
        self.thread_names[tid] = f"req {rid}"
        self.begin("request", tid=tid, cat="request", id=rid,
                   prompt_len=prompt_len, max_new_tokens=max_new_tokens,
                   priority=priority)
        self.begin("queued", tid=tid, cat="request")

    def request_admitted(self, rid: int, slot: int, credit: int,
                         chunks: int) -> None:
        tid = REQ_TID_BASE + rid
        self.end("queued", tid=tid)
        self.instant("admitted", tid=tid, cat="request", slot=slot,
                     prefix_credit=credit, chunks=chunks)
        self.begin("prefill", tid=tid, cat="request", prefix_credit=credit)

    def request_chunks(self, rid: int, start: float, lo: int, hi: int,
                       pos: int, total: int) -> None:
        self.complete("prefill_chunks", start, tid=REQ_TID_BASE + rid,
                      cat="request", lo=lo, hi=hi, pos=pos, total=total)

    def request_paused(self, rid: int, pos: int) -> None:
        self.instant("paused", tid=REQ_TID_BASE + rid, cat="request",
                     pos=pos)

    def request_resumed(self, rid: int, pos: int) -> None:
        self.instant("resumed", tid=REQ_TID_BASE + rid, cat="request",
                     pos=pos)

    def request_restaged(self, rid: int) -> None:
        self.instant("restaged_uncached", tid=REQ_TID_BASE + rid,
                     cat="request")

    def request_decode(self, rid: int, credit: int) -> None:
        tid = REQ_TID_BASE + rid
        self.end("prefill", tid=tid)
        self.begin("decode", tid=tid, cat="request", prefix_credit=credit)

    def request_finish(self, rid: int, terminal: str, tokens: int) -> None:
        tid = REQ_TID_BASE + rid
        self.end("decode", tid=tid)
        self.instant(terminal, tid=tid, cat="request", tokens=tokens)
        self.end("request", tid=tid)

    def request_cancel(self, rid: int, where: str) -> None:
        """Cancel before decode: ``where`` is 'queued' or 'prefill' (an
        actively decoding cancel goes through the finish path as
        ``truncated`` instead)."""
        tid = REQ_TID_BASE + rid
        self.end("queued" if where == "queued" else "prefill", tid=tid)
        self.instant("cancel", tid=tid, cat="request", where=where)
        self.end("request", tid=tid)


def make_tracer(spec) -> Optional[Tracer]:
    """``EngineConfig.telemetry`` -> Tracer or None (disabled).

    ``None``/``False`` -> disabled (the zero-overhead default);
    ``True``/``"on"`` -> full tracing; ``"flight"`` -> flight-recorder
    ring only (no event list); a :class:`TelemetryConfig` or an existing
    :class:`Tracer` pass through."""
    if spec is None or spec is False:
        return None
    if isinstance(spec, Tracer):
        return spec
    if spec is True or spec == "on":
        return Tracer()
    if spec == "flight":
        return Tracer(trace=False)
    if isinstance(spec, TelemetryConfig):
        return Tracer(trace=spec.trace, ring=spec.ring,
                      flight_path=spec.flight_path)
    raise ValueError(f"unknown telemetry spec {spec!r} (expected None, "
                     f"bool, 'on', 'flight', TelemetryConfig, or Tracer)")


# --------------------------------------------------------------------------
# Chrome trace-event export (Perfetto-loadable)
# --------------------------------------------------------------------------

def _event_dict(ev: tuple) -> Dict[str, Any]:
    ts, ph, name, cat, tid, args = ev
    d: Dict[str, Any] = {"name": name, "ph": ph, "ts": round(ts, 3),
                         "pid": PID, "tid": tid}
    if cat:
        d["cat"] = cat
    if args:
        args = dict(args)
        dur = args.pop("_dur", None)
        if dur is not None:
            d["dur"] = round(dur, 3)
        if args:
            d["args"] = args
    if ph == "i":
        d["s"] = "t"               # thread-scoped instant
    return d


def to_chrome_trace(tracer: Tracer) -> Dict[str, Any]:
    """Full-trace export: metadata events name the tracks (engine ticks
    on top, one swimlane per request), then the event stream in emission
    order."""
    evs: List[Dict[str, Any]] = [
        {"name": "process_name", "ph": "M", "ts": 0, "pid": PID,
         "tid": TID_ENGINE, "args": {"name": "repro.serve.engine"}},
    ]
    for tid, label in sorted(tracer.thread_names.items()):
        evs.append({"name": "thread_name", "ph": "M", "ts": 0, "pid": PID,
                    "tid": tid, "args": {"name": label}})
        evs.append({"name": "thread_sort_index", "ph": "M", "ts": 0,
                    "pid": PID, "tid": tid, "args": {"sort_index": tid}})
    evs.extend(_event_dict(ev) for ev in (tracer.events or ()))
    return {
        "traceEvents": evs,
        "displayTimeUnit": "ms",
        "otherData": {"schema": SCHEMA, "flight": False,
                      "dropped": tracer.dropped, "meta": tracer.meta},
    }


def write_trace(tracer: Tracer, path) -> str:
    p = Path(path)
    p.write_text(json.dumps(to_chrome_trace(tracer), indent=1,
                            sort_keys=True), encoding="utf-8")
    return str(p)


def dump_flight(tracer: Tracer, reason: str, path=None) -> str:
    """Write the flight-recorder ring (last K events before an engine
    error) as a relaxed Chrome trace: Perfetto still loads it, and the
    validator skips span-balance checks (``otherData.flight``) since the
    ring may open mid-span."""
    p = Path(path if path is not None else tracer.flight_path)
    doc = {
        "traceEvents": [_event_dict(ev) for ev in (tracer.ring or ())],
        "displayTimeUnit": "ms",
        "otherData": {"schema": SCHEMA, "flight": True, "reason": reason,
                      "dropped": tracer.dropped, "meta": tracer.meta},
    }
    p.write_text(json.dumps(doc, indent=1, sort_keys=True),
                 encoding="utf-8")
    return str(p)


# --------------------------------------------------------------------------
# validation + summary (the CLI's hard gate)
# --------------------------------------------------------------------------

_VALID_PH = frozenset({"B", "E", "i", "X", "C", "M"})


def validate_chrome_trace(doc: Dict[str, Any],
                          flight: Optional[bool] = None) -> Dict[str, Any]:
    """Schema + well-formedness check of a Chrome trace-event document.

    Hard requirements (full traces): every event carries name/ph/ts/
    pid/tid with sane types; per-track timestamps are non-decreasing;
    B/E spans balance and nest (E matches the innermost open B on its
    track); every request track reaches exactly one terminal instant
    (finish/cancel/truncated) and closes its root span.  Flight dumps
    (``otherData.flight`` or ``flight=True``) relax the balance and
    terminal requirements — the ring legitimately starts mid-span."""
    errors: List[str] = []
    evs = doc.get("traceEvents")
    if not isinstance(evs, list):
        return {"ok": False, "errors": ["traceEvents is not a list"],
                "summary": {}}
    if flight is None:
        flight = bool(doc.get("otherData", {}).get("flight"))
    stacks: Dict[int, List[str]] = {}
    last_ts: Dict[int, float] = {}
    request_tracks: set = set()
    admitted: set = set()
    terminals: Dict[int, List[str]] = {}
    n_by_ph: Dict[str, int] = {}
    ticks = 0
    for i, e in enumerate(evs):
        if not isinstance(e, dict):
            errors.append(f"event {i}: not an object")
            continue
        ph = e.get("ph")
        name = e.get("name")
        if ph not in _VALID_PH:
            errors.append(f"event {i}: bad ph {ph!r}")
            continue
        n_by_ph[ph] = n_by_ph.get(ph, 0) + 1
        if not isinstance(name, str):
            errors.append(f"event {i}: name is not a string")
            continue
        if not isinstance(e.get("ts"), (int, float)):
            errors.append(f"event {i} ({name}): ts is not a number")
            continue
        if not isinstance(e.get("pid"), int) or not isinstance(
                e.get("tid"), int):
            errors.append(f"event {i} ({name}): pid/tid not ints")
            continue
        if ph == "M":
            continue
        tid, ts = e["tid"], e["ts"]
        if ts < last_ts.get(tid, 0.0) - 1e-6:
            errors.append(f"event {i} ({name}): ts {ts} goes backwards "
                          f"on track {tid}")
        last_ts[tid] = max(last_ts.get(tid, 0.0), ts)
        if ph == "X" and not isinstance(e.get("dur"), (int, float)):
            errors.append(f"event {i} ({name}): X event without dur")
        if ph == "B":
            stacks.setdefault(tid, []).append(name)
            if name == "tick":
                ticks += 1
            if name == "request":
                request_tracks.add(tid)
        elif ph == "E":
            stack = stacks.setdefault(tid, [])
            if not stack:
                if not flight:
                    errors.append(f"event {i}: E {name!r} with no open "
                                  f"span on track {tid}")
            elif stack[-1] != name:
                errors.append(f"event {i}: E {name!r} does not match "
                              f"innermost open span {stack[-1]!r} on "
                              f"track {tid}")
                stack.pop()
            else:
                stack.pop()
        elif ph == "i":
            if name == "admitted":
                admitted.add(tid)
            if name in TERMINALS:
                terminals.setdefault(tid, []).append(name)
    if not flight:
        for tid, stack in sorted(stacks.items()):
            if stack:
                errors.append(f"track {tid}: unclosed span(s) {stack}")
        for tid in sorted(request_tracks):
            t = terminals.get(tid, [])
            if len(t) != 1:
                errors.append(
                    f"request track {tid}: expected exactly one terminal "
                    f"event ({'/'.join(TERMINALS)}), got {t}")
        for tid in sorted(admitted - request_tracks):
            errors.append(f"track {tid}: 'admitted' without a request "
                          f"root span")
    term_counts: Dict[str, int] = {}
    for names in terminals.values():
        for n in names:
            term_counts[n] = term_counts.get(n, 0) + 1
    all_ts = [e["ts"] for e in evs
              if isinstance(e, dict) and e.get("ph") != "M"
              and isinstance(e.get("ts"), (int, float))]
    summary = {
        "events": len(evs),
        "by_ph": n_by_ph,
        "ticks": ticks,
        "requests": len(request_tracks),
        "admitted": len(admitted),
        "terminals": term_counts,
        "wall_ms": round((max(all_ts) - min(all_ts)) / 1e3, 3)
        if all_ts else 0.0,
        "flight": flight,
    }
    return {"ok": not errors, "errors": errors[:50], "summary": summary}


def summarize_chrome_trace(doc: Dict[str, Any]) -> str:
    v = validate_chrome_trace(doc)
    s = v["summary"]
    other = doc.get("otherData", {}) if isinstance(doc, dict) else {}
    lines = [
        f"trace: {s.get('events', 0)} events over "
        f"{s.get('wall_ms', 0.0)} ms"
        + (" [flight-recorder dump]" if s.get("flight") else ""),
        f"  ticks={s.get('ticks', 0)} requests={s.get('requests', 0)} "
        f"admitted={s.get('admitted', 0)} "
        f"terminals={s.get('terminals', {})}",
        f"  phases={s.get('by_ph', {})} "
        f"dropped_from_ring={other.get('dropped', 0)}",
    ]
    if other.get("reason"):
        lines.append(f"  dump reason: {other['reason']}")
    meta = other.get("meta") or {}
    if meta.get("decode_plan"):
        p = meta["decode_plan"]
        lines.append(f"  decode plan: backend={p.get('backend')} "
                     f"({p.get('reason', '')[:80]})")
    lines.append(f"  => {'VALID' if v['ok'] else 'INVALID'}")
    return "\n".join(lines)


def main(argv=None) -> int:
    import argparse
    import sys

    ap = argparse.ArgumentParser(
        prog="python -m repro.serve.telemetry",
        description="Validate + summarize a serve-path Chrome trace "
                    "(or flight-recorder dump); exit 1 when malformed")
    ap.add_argument("trace", help="trace JSON (from --trace-out or a "
                                  "FLIGHT_serve.json dump)")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress the summary, report errors only")
    args = ap.parse_args(argv)

    with open(args.trace, encoding="utf-8") as f:
        doc = json.load(f)
    v = validate_chrome_trace(doc)
    if not args.quiet:
        print(summarize_chrome_trace(doc))
    for err in v["errors"]:
        print(f"TRACE INVALID: {err}", file=sys.stderr)
    return 0 if v["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
