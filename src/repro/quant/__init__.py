"""Quantization: fake-quant (QAT) and integer inference paths."""

from repro.quant.fake_quant import (  # noqa: F401
    QuantConfig,
    dequantize,
    fake_quant,
    quantize,
    quantize_params,
)
from repro.quant.int_attention import (  # noqa: F401
    int_dot_product_attention,
    int_inhibitor_attention,
    lane_attention_heads,
    lane_dot_product_attention,
    lane_inhibitor_attention,
    quantize_qkv,
)
from repro.quant.ptq import PtqConfig, QuantizedLM, ptq_lm  # noqa: F401
