"""Unified multi-head attention layer with swappable score mechanism.

``AttentionConfig.kind`` selects the mechanism:

  * ``"dotprod"``            — conventional Softmax attention (paper eq. 3)
  * ``"inhibitor"``          — signed inhibitor (paper eq. 7 / fused eq. 10)
  * ``"inhibitor_unsigned"`` — unsigned inhibitor (paper eq. 6 / fused eq. 9)

The projection layout (fused QKV per-head, GQA, optional QKV bias, RoPE) is
shared across mechanisms so the paper's technique is a one-line config swap
on every architecture in :mod:`repro.configs`.

Decode support: a :class:`KVCache` carries (k, v, length); ``apply`` with
``cache`` set appends the new keys/values and attends over the valid prefix.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import dotprod as dp
from repro.core import inhibitor as inh
from repro.nn.linear import apply_dense, init_dense
from repro.nn.module import KeyGen


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    kind: str = "dotprod"           # dotprod | inhibitor | inhibitor_unsigned
    num_heads: int = 8
    num_kv_heads: int = 8
    head_dim: int = 64
    qkv_bias: bool = False
    out_bias: bool = False
    use_rope: bool = True
    rope_base: float = 10000.0
    rope_pct: float = 1.0           # fraction of head_dim rotated (stablelm)
    score_shift: float = 0.5        # inhibitor α (paper: 0.5)
    score_scale: Optional[float] = None  # default √head_dim (paper γ)
    normalize: bool = True          # key-count normalization (DESIGN.md §2)
    sliding_window: Optional[int] = None
    causal: bool = True
    use_kernel: bool = False        # dispatch to Pallas flash path
    kv_chunk: int = 256             # chunk size for the streaming form
    chunked_threshold: int = 4096   # n_k above which the streaming form is
                                    # used when the kernel path is off


class KVCache(NamedTuple):
    k: jax.Array        # (b, max_len, h_kv, d)
    v: jax.Array        # (b, max_len, h_kv, d)
    length: jax.Array   # () int32 shared cursor, or (b,) per-slot cursors
                        # (ragged continuous batching — serve.engine)


def init_kv_cache(batch: int, max_len: int, num_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16, *, per_slot: bool = False) -> KVCache:
    shape = (batch, max_len, num_kv_heads, head_dim)
    length = jnp.zeros((batch,) if per_slot else (), jnp.int32)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype), length)


def init_attention(key, cfg: AttentionConfig, embed_dim: int, *,
                   dtype=jnp.float32) -> dict:
    kg = KeyGen(key)
    h, hk, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "wq": init_dense(kg("wq"), (embed_dim,), (h, d), ("embed",),
                         ("heads", "head_dim"), use_bias=cfg.qkv_bias,
                         dtype=dtype),
        "wk": init_dense(kg("wk"), (embed_dim,), (hk, d), ("embed",),
                         ("kv_heads", "head_dim"), use_bias=cfg.qkv_bias,
                         dtype=dtype),
        "wv": init_dense(kg("wv"), (embed_dim,), (hk, d), ("embed",),
                         ("kv_heads", "head_dim"), use_bias=cfg.qkv_bias,
                         dtype=dtype),
        "wo": init_dense(kg("wo"), (h, d), (embed_dim,),
                         ("heads", "head_dim"), ("embed",),
                         use_bias=cfg.out_bias, dtype=dtype),
    }


def _mechanism(cfg: AttentionConfig, q, k, v, mask):
    if cfg.kind == "dotprod":
        return dp.dot_product_attention(q, k, v, mask=mask,
                                        score_scale=cfg.score_scale)
    signed = cfg.kind == "inhibitor"
    if cfg.kind not in ("inhibitor", "inhibitor_unsigned"):
        raise ValueError(f"unknown attention kind {cfg.kind!r}")
    if cfg.use_kernel:
        from repro.kernels import ops as kops
        return kops.flash_inhibitor(
            q, k, v, mask=mask, score_scale=cfg.score_scale,
            score_shift=cfg.score_shift, signed=signed,
            normalize=cfg.normalize)
    if k.shape[1] > cfg.chunked_threshold:
        return inh.inhibitor_attention_chunked(
            q, k, v, mask=mask, score_scale=cfg.score_scale,
            score_shift=cfg.score_shift, signed=signed,
            normalize=cfg.normalize, kv_chunk=cfg.kv_chunk)
    return inh.inhibitor_attention(
        q, k, v, mask=mask, score_scale=cfg.score_scale,
        score_shift=cfg.score_shift, signed=signed, normalize=cfg.normalize)


def _build_mask(cfg: AttentionConfig, n_q: int, n_k: int, q_offset,
                kv_valid_len=None) -> Optional[jax.Array]:
    """Boolean (b|1, 1, n_q, n_k) mask combining causality, sliding window
    and KV-cache validity. ``q_offset`` / ``kv_valid_len`` may be scalars
    (shared cursor) or (b,) vectors (ragged continuous batching)."""
    masks = []
    qoff = jnp.asarray(q_offset)
    if qoff.ndim == 0:
        qoff = qoff[None]
    qi = qoff[:, None, None] + jnp.arange(n_q)[None, :, None]  # (b|1, nq, 1)
    kj = jnp.arange(n_k)[None, None, :]                        # (1, 1, nk)
    if cfg.causal:
        masks.append(kj <= qi)
    if cfg.sliding_window is not None:
        masks.append(kj > qi - cfg.sliding_window)
    if kv_valid_len is not None:
        kv = jnp.asarray(kv_valid_len)
        if kv.ndim == 0:
            kv = kv[None]
        masks.append(jnp.broadcast_to(kj < kv[:, None, None],
                                      (kv.shape[0], n_q, n_k)))
    if not masks:
        return None
    m = masks[0]
    for extra in masks[1:]:
        m = m & extra
    return m[:, None]


def apply_attention(
    params: dict,
    cfg: AttentionConfig,
    x: jax.Array,
    *,
    x_kv: Optional[jax.Array] = None,
    positions: Optional[jax.Array] = None,
    cache: Optional[KVCache] = None,
    attn_mask: Optional[jax.Array] = None,
    compute_dtype=None,
):
    """Attention over ``x`` (self) or ``x_kv`` (cross). Returns (y, cache').

    x: (b, n_q, embed). positions: (b, n_q) absolute positions for RoPE
    (defaults to arange, or cache.length + arange when decoding).
    """
    from repro.nn.rotary import apply_rope

    cdt = compute_dtype or x.dtype
    b, n_q, _ = x.shape
    src = x if x_kv is None else x_kv

    q = apply_dense(params["wq"], x, 1, cdt)          # (b, n_q, h, d)
    k = apply_dense(params["wk"], src, 1, cdt)        # (b, n_kv, hk, d)
    v = apply_dense(params["wv"], src, 1, cdt)

    if positions is None:
        offset = cache.length if cache is not None else 0
        off = jnp.asarray(offset)
        if off.ndim == 1:                       # per-slot cursors (b,)
            positions = off[:, None] + jnp.arange(n_q)[None, :]
        else:
            positions = jnp.arange(n_q)[None, :] + off
        positions = jnp.broadcast_to(positions, (b, n_q))

    if cfg.use_rope and x_kv is None:
        if cfg.rope_pct >= 1.0:
            q = apply_rope(q, positions, base=cfg.rope_base)
            k = apply_rope(k, positions, base=cfg.rope_base)
        else:
            rd = int(cfg.head_dim * cfg.rope_pct)
            rd -= rd % 2
            q = jnp.concatenate(
                [apply_rope(q[..., :rd], positions, base=cfg.rope_base),
                 q[..., rd:]], axis=-1)
            k = jnp.concatenate(
                [apply_rope(k[..., :rd], positions, base=cfg.rope_base),
                 k[..., rd:]], axis=-1)

    new_cache = None
    kv_valid_len = None
    if cache is not None:
        # append new k/v at the cache cursor(s), attend over the buffer
        if cache.length.ndim == 1:              # ragged: per-slot cursors
            upd = jax.vmap(
                lambda buf, new, off: jax.lax.dynamic_update_slice(
                    buf, new, (off, 0, 0)))
            k_buf = upd(cache.k, k.astype(cache.k.dtype), cache.length)
            v_buf = upd(cache.v, v.astype(cache.v.dtype), cache.length)
        else:
            k_buf = jax.lax.dynamic_update_slice(
                cache.k, k.astype(cache.k.dtype), (0, cache.length, 0, 0))
            v_buf = jax.lax.dynamic_update_slice(
                cache.v, v.astype(cache.v.dtype), (0, cache.length, 0, 0))
        new_cache = KVCache(k_buf, v_buf, cache.length + n_q)
        k, v = k_buf.astype(cdt), v_buf.astype(cdt)
        kv_valid_len = cache.length + n_q

    n_k = k.shape[1]
    q_offset = cache.length if cache is not None else 0
    scalar_cursor = jnp.asarray(q_offset).ndim == 0

    # Large structural-mask inhibitor attention takes the flash-structured
    # blocked path: exact, chunk-bounded memory, analytic backward, no
    # (n_q, n_k) mask arrays (core.blocked).
    if (cfg.kind in ("inhibitor", "inhibitor_unsigned") and not cfg.use_kernel
            and attn_mask is None and x_kv is None and scalar_cursor
            and n_q * n_k >= (1 << 20)):
        from repro.core.blocked import blocked_inhibitor_attention

        out = blocked_inhibitor_attention(
            q, k, v, score_scale=cfg.score_scale,
            score_shift=cfg.score_shift, signed=cfg.kind == "inhibitor",
            normalize=cfg.normalize, causal=cfg.causal,
            window=cfg.sliding_window, q_offset=q_offset,
            kv_valid_len=kv_valid_len, chunk_k=cfg.kv_chunk,
            chunk_q=min(cfg.kv_chunk, 512))
        y = apply_dense(params["wo"], out, 2, cdt)
        return y, new_cache

    mask = attn_mask
    if mask is None and x_kv is None:
        mask = _build_mask(cfg, n_q, n_k, q_offset, kv_valid_len)
    elif mask is None and x_kv is not None and kv_valid_len is not None:
        kvl = jnp.asarray(kv_valid_len)
        if kvl.ndim == 1:
            mask = (jnp.arange(n_k)[None, :] < kvl[:, None])[:, None, None]
        else:
            mask = (jnp.arange(n_k)[None, :] < kvl)[None, None, None]

    out = _mechanism(cfg, q, k, v, mask)              # (b, n_q, h, d)
    y = apply_dense(params["wo"], out, 2, cdt)
    return y, new_cache
