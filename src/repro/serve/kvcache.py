"""Serving-side KV cache management: slot + paged block-table allocators.

The engine keeps a fixed pool of ``max_batch`` slots it schedules against.
Two allocators back those slots:

``SlotAllocator`` (contiguous)
    Each slot owns a full ``max_len`` stride of the stacked
    (layers, batch, max_len, kv_heads, head_dim) cache buffers — memory for
    the worst case is reserved up front whether or not a request uses it.
    Kept as the baseline arm of ``benchmarks/serve_bench.py``.

``PagedAllocator`` (block tables)
    KV rows live in a shared pool of fixed-size pages
    (layers, num_pages, page_size, kv_heads, head_dim).  Each slot holds a
    block table mapping logical page index -> physical page; pages are
    handed out from a free list on demand as a request's cursor grows and
    reclaimed in O(pages-held) when the slot is released (free-list push,
    no compaction, no copying).  ``high_water_pages`` records the peak
    pool occupancy — the number the serving bench reports against the
    contiguous baseline's always-fully-reserved buffer.

    Physical page 0 is reserved as the *trash page*: inactive batch rows
    still flow through the jitted decode step (static shapes), and their
    garbage KV writes must land somewhere that no live slot owns.  Block
    tables are zeroed on release, so stale rows scatter into page 0, which
    is never allocated and never read (validity is cursor-defined).

Both allocators expose the same scheduling surface (``claim`` /
``release`` / ``active`` / ``lengths`` / ``slots``); the paged one adds
``ensure(slot, length)`` for on-demand page growth and a ``block_tables``
array the engine mirrors into device state.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class SlotState:
    request_id: Optional[int] = None
    length: int = 0
    done: bool = True


class SlotAllocator:
    """Contiguous allocator: slot i owns rows [i] of the cache buffers."""

    def __init__(self, max_batch: int):
        self.slots: List[SlotState] = [SlotState() for _ in range(max_batch)]

    def claim(self, request_id: int) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s.done:
                self.slots[i] = SlotState(request_id, 0, False)
                return i
        return None

    def release(self, slot: int):
        self.slots[slot] = SlotState()

    def active(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if not s.done]

    def lengths(self) -> np.ndarray:
        return np.array([s.length for s in self.slots], np.int32)


class PagedAllocator:
    """Block-table allocator over a shared page pool (vLLM-style).

    ``num_pages`` counts *physical* pages including the reserved trash
    page 0; usable capacity is ``num_pages - 1``.  The default sizing
    (``max_batch * pages_per_slot + 1``) can always hold every slot at
    ``max_len`` — undersize it to serve more slots than worst-case memory,
    at the cost of admission backpressure when the free list runs dry.
    """

    def __init__(self, max_batch: int, max_len: int, page_size: int = 16,
                 num_pages: Optional[int] = None):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.page_size = page_size
        self.max_len = max_len
        self.pages_per_slot = -(-max_len // page_size)
        if num_pages is None:
            num_pages = max_batch * self.pages_per_slot + 1
        if num_pages < 2:
            raise ValueError("num_pages must be >= 2 (page 0 is reserved)")
        self.num_pages = num_pages
        self.slots: List[SlotState] = [SlotState() for _ in range(max_batch)]
        self.block_tables = np.zeros((max_batch, self.pages_per_slot),
                                     np.int32)
        self._pages: List[List[int]] = [[] for _ in range(max_batch)]
        # LIFO free list (page 0 reserved as the trash page): pop from the
        # end so recently-released pages are reused while still cache-warm
        self.free: List[int] = list(range(num_pages - 1, 0, -1))
        self.high_water_pages = 0

    @property
    def pages_in_use(self) -> int:
        return (self.num_pages - 1) - len(self.free)

    def claim(self, request_id: int) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s.done:
                self.slots[i] = SlotState(request_id, 0, False)
                return i
        return None

    def ensure(self, slot: int, length: int) -> Optional[bool]:
        """Grow ``slot``'s block table to cover ``length`` positions.

        Returns True if new pages were mapped, False if already covered,
        None if the free list ran dry (caller backpressures: requeue the
        request or hard-stop the generation).  Pages grabbed before an
        exhaustion are kept mapped — they are reclaimed with the slot.
        """
        need = -(-length // self.page_size)
        if need > self.pages_per_slot:
            return None
        grew = False
        held = self._pages[slot]
        while len(held) < need:
            if not self.free:
                return None
            page = self.free.pop()
            self.block_tables[slot, len(held)] = page
            held.append(page)
            grew = True
            # inside the loop so a partial growth that then runs dry still
            # counts toward the peak (those pages stay mapped)
            self.high_water_pages = max(self.high_water_pages,
                                        self.pages_in_use)
        return grew

    def release(self, slot: int):
        # O(pages-held) reclaim: push back on the free list, zero the table
        self.free.extend(self._pages[slot])
        self._pages[slot] = []
        self.block_tables[slot] = 0
        self.slots[slot] = SlotState()

    def active(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if not s.done]

    def lengths(self) -> np.ndarray:
        return np.array([s.length for s in self.slots], np.int32)
