"""smollm-135m — llama-arch small dense LM.
[hf:HuggingFaceTB/SmolLM-135M; hf]
30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152, head_dim=64.
"""

from repro.configs.base import ModelConfig
from repro.core.attention import AttentionConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    family="dense",
    num_layers=30,
    d_model=576,
    d_ff=1536,
    vocab_size=49152,
    attention=AttentionConfig(
        mechanism="dotprod", num_heads=9, num_kv_heads=3, head_dim=64,
        qkv_bias=False, use_rope=True, rope_base=10000.0, causal=True),
    norm="rmsnorm",
    norm_eps=1e-5,
    mlp="gated_silu",
    tie_embeddings=True,
    max_seq_len=32768,
    source="hf:HuggingFaceTB/SmolLM-135M",
)
