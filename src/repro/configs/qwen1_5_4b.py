"""qwen1.5-4b — dense MHA LM with QKV bias.
[hf:Qwen/Qwen1.5-4B]
40L d_model=2560 20H (kv=20) d_ff=6912 vocab=151936, head_dim=128.
"""

from repro.configs.base import ModelConfig
from repro.core.attention import AttentionConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    num_layers=40,
    d_model=2560,
    d_ff=6912,
    vocab_size=151936,
    attention=AttentionConfig(
        mechanism="dotprod", num_heads=20, num_kv_heads=20, head_dim=128,
        qkv_bias=True, use_rope=True, rope_base=5000000.0, causal=True),
    norm="rmsnorm",
    norm_eps=1e-6,
    mlp="gated_silu",
    tie_embeddings=False,
    max_seq_len=32768,
    source="hf:Qwen/Qwen1.5-4B",
)
