"""Serving engine: continuous batching == sequential greedy decode."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import transformer as tfm
from repro.models.registry import get_model
from repro.nn.module import unbox
from repro.serve.engine import Engine, EngineConfig, Request


def _make(arch="smollm-135m"):
    cfg = get_config(arch).reduced(num_layers=2, d_model=32, d_ff=64,
                                   vocab_size=128)
    api = get_model(cfg)
    params = unbox(api.init(jax.random.PRNGKey(0)))
    api = api._replace(init_states=lambda b, s, **kw: tfm.init_states(
        cfg, b, s, per_slot=True))
    return cfg, api, params


def _greedy_ref(cfg, api, params, prompt, n_new, max_len=64):
    states = tfm.init_states(cfg, 1, max_len, per_slot=True)
    logits, states = api.step(params, jnp.asarray(prompt)[None], states,
                              None)
    out = [int(jnp.argmax(logits[0, -1]))]
    while len(out) < n_new:
        logits, states = api.step(
            params, jnp.asarray([[out[-1]]], dtype=jnp.int32), states, None)
        out.append(int(jnp.argmax(logits[0, -1])))
    return out


def test_engine_matches_sequential_greedy(rng):
    cfg, api, params = _make()
    eng = Engine(api, params, EngineConfig(max_batch=4, max_len=64))
    lens = (5, 3, 7, 5, 4, 6)   # ragged + recycling (6 reqs, 4 slots)
    prompts = [rng.integers(0, cfg.vocab_size, (l,)).astype(np.int32)
               for l in lens]
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new_tokens=6))
    done = eng.run_to_completion()
    assert len(done) == len(prompts)
    for r in done:
        assert r.output == _greedy_ref(cfg, api, params,
                                       prompts[r.request_id], 6)


def test_engine_eos_early_stop(rng):
    cfg, api, params = _make()
    eng = Engine(api, params, EngineConfig(max_batch=2, max_len=64))
    prompt = rng.integers(0, cfg.vocab_size, (4,)).astype(np.int32)
    ref = _greedy_ref(cfg, api, params, prompt, 8)
    eos = ref[2]
    eng.submit(Request(0, prompt, max_new_tokens=8, eos_id=eos))
    done = eng.run_to_completion()
    assert done[0].output[-1] == eos and len(done[0].output) <= 8
