"""Synthetic task generators (the paper's Table 1 benchmark suite, offline).

The paper evaluates on: the adding problem, MNIST, IMDB and IAM
handwriting.  No datasets ship with this container, so we reproduce each
task's *structure* with deterministic synthetic generators of matched
difficulty class:

  * ``adding``      — the exact Hochreiter & Schmidhuber task (two input
                      channels: uniform values + two-hot marker; target =
                      marked dot product). Identical to the paper's setup.
  * ``digits``      — MNIST surrogate: 10-class classification of 16×16
                      noisy class-template images, flattened to patch
                      sequences for a 1-layer transformer (paper's MNIST
                      protocol at reduced resolution).
  * ``sentiment``   — IMDB surrogate: binary classification of token
                      sequences where class-conditional token distributions
                      overlap (bag-of-words signal + noise), exercising the
                      same attention-pooling pathway.
  * ``copy_words``  — IAMW surrogate: sequence transduction with CTC-style
                      structure replaced by per-position classification of
                      blurred glyph sequences (edit-distance metric).
  * ``lm``          — deterministic token-stream generator for LM smoke
                      training (bigram-skewed sampling so loss decreases
                      measurably within a few hundred steps).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np


def adding_problem(batch: int, length: int, seed: int) -> Tuple[np.ndarray,
                                                                np.ndarray]:
    """Returns x: (b, length, 2), y: (b, 1)."""
    rng = np.random.default_rng(seed)
    vals = rng.uniform(0.0, 1.0, (batch, length)).astype(np.float32)
    marks = np.zeros((batch, length), np.float32)
    for i in range(batch):
        a, b = rng.choice(length, size=2, replace=False)
        marks[i, a] = 1.0
        marks[i, b] = 1.0
    y = np.sum(vals * marks, axis=1, keepdims=True).astype(np.float32)
    x = np.stack([vals, marks], axis=-1)
    return x, y


_DIGIT_CACHE = {}


def _digit_templates(res: int, seed: int = 1234) -> np.ndarray:
    key = (res, seed)
    if key not in _DIGIT_CACHE:
        rng = np.random.default_rng(seed)
        base = rng.normal(size=(10, res, res)).astype(np.float32)
        # smooth the templates so classes are locally structured
        for _ in range(2):
            base = (base + np.roll(base, 1, 1) + np.roll(base, -1, 1)
                    + np.roll(base, 1, 2) + np.roll(base, -1, 2)) / 5.0
        _DIGIT_CACHE[key] = base / np.abs(base).max()
    return _DIGIT_CACHE[key]


def digits(batch: int, seed: int, *, res: int = 16,
           noise: float = 0.7) -> Tuple[np.ndarray, np.ndarray]:
    """MNIST-surrogate: x (b, res, res), y (b,) in [0, 10)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, batch)
    temps = _digit_templates(res)
    x = temps[labels] + rng.normal(size=(batch, res, res)).astype(
        np.float32) * noise
    return x.astype(np.float32), labels.astype(np.int32)


def sentiment(batch: int, seed: int, *, length: int = 64,
              vocab: int = 512, signal: float = 0.25):
    """IMDB-surrogate: token ids (b, length), labels (b,) in {0,1}.

    Class c biases a disjoint 10%% slice of the vocabulary; ``signal`` is
    the fraction of positions drawn from the biased slice.
    """
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 2, batch)
    slice_size = vocab // 10
    toks = rng.integers(0, vocab, (batch, length))
    n_sig = max(1, int(length * signal))
    for i in range(batch):
        pos = rng.choice(length, n_sig, replace=False)
        lo = labels[i] * slice_size
        toks[i, pos] = rng.integers(lo, lo + slice_size, n_sig)
    return toks.astype(np.int32), labels.astype(np.int32)


def copy_words(batch: int, seed: int, *, length: int = 12,
               n_glyphs: int = 26, glyph_dim: int = 16, noise: float = 0.5):
    """IAMW-surrogate: glyph embeddings (b, length, glyph_dim),
    target glyph ids (b, length)."""
    rng = np.random.default_rng(seed)
    protos = np.random.default_rng(999).normal(
        size=(n_glyphs, glyph_dim)).astype(np.float32)
    ids = rng.integers(0, n_glyphs, (batch, length))
    x = protos[ids] + rng.normal(size=(batch, length, glyph_dim)).astype(
        np.float32) * noise
    return x.astype(np.float32), ids.astype(np.int32)


def lm_tokens(batch: int, seq_len: int, vocab: int, seed: int):
    """Bigram-skewed token stream: tokens (b, s+1) -> (inputs, labels)."""
    rng = np.random.default_rng(seed)
    # deterministic bigram preference: next ~ 3*cur + small noise (mod V)
    cur = rng.integers(0, vocab, (batch,))
    out = np.empty((batch, seq_len + 1), np.int64)
    out[:, 0] = cur
    for t in range(1, seq_len + 1):
        jump = rng.integers(0, 7, (batch,))
        stay = rng.random(batch) < 0.8
        nxt = np.where(stay, (3 * out[:, t - 1] + jump) % vocab,
                       rng.integers(0, vocab, (batch,)))
        out[:, t] = nxt
    return out[:, :-1].astype(np.int32), out[:, 1:].astype(np.int32)
