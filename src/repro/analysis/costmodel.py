"""Shared platform cost table + analytic jaxpr cost model.

This module is the single home for the accelerator constants that
``benchmarks/roofline.py`` used to hard-code, plus two static analyses
built on them:

* :func:`jaxpr_costs` — walk a (closed) jaxpr and tally FLOPs and an
  HBM-byte upper bound per equation, recursing through ``pjit``/
  ``scan``/``cond``/``while``/``pallas_call``.  The byte count is the
  *unfused* sum of operand+result bytes — an upper bound XLA's fuser
  only improves on — except for gather/scatter-family primitives, where
  counting the full operand would be wildly wrong (a paged-KV gather
  reads the gathered rows, not the whole pool), so only the moved data
  is charged.
* :func:`kernel_prior` / :func:`rank_kernel_candidates` — a static
  execution-time prior for ``KernelRegistry`` candidate configs (grid
  dispatch overhead + HBM traffic + FLOPs, with a VMEM feasibility
  guard), letting the autotuner rank candidates *before* any timing
  runs and skip statically-infeasible ones.

Import cost: stdlib only at module level (``jax`` is imported lazily
inside :func:`jaxpr_costs`' callers' jaxprs, never here), so the lint
and the analyzer CLI stay fast to start.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Sequence, Tuple

SCHEMA_VERSION = 1


@dataclasses.dataclass(frozen=True)
class Platform:
    """Peak numbers for one accelerator, used by every roofline in the
    repo (benchmarks and static analysis share this table)."""

    name: str
    peak_flops: float        # sustained matmul FLOP/s (bf16)
    hbm_bw: float            # HBM bandwidth, bytes/s
    link_bw: float           # inter-chip interconnect, bytes/s
    h2d_bw: float            # host<->device (PCIe-class), bytes/s
    dispatch_s: float        # fixed overhead per launched grid step
    vmem_bytes: int          # on-chip vector memory per core


#: TPU v5e — the numbers ``benchmarks/roofline.py`` has always used.
TPU_V5E = Platform(
    name="tpu-v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    link_bw=50e9,
    h2d_bw=32e9,
    dispatch_s=1e-6,
    vmem_bytes=128 * 2 ** 20,
)

DEFAULT_PLATFORM = TPU_V5E


# --------------------------------------------------------------------------
# jaxpr walking
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Costs:
    """Accumulated static costs of one jaxpr."""

    flops: float = 0.0
    hbm_bytes: float = 0.0
    host_callbacks: int = 0      # pure/io/debug callbacks — host syncs
    unbounded_loops: int = 0     # while-loops: cost counted for one trip

    def add(self, other: "Costs", scale: float = 1.0) -> None:
        self.flops += other.flops * scale
        self.hbm_bytes += other.hbm_bytes * scale
        self.host_callbacks += other.host_callbacks
        self.unbounded_loops += other.unbounded_loops

    def as_dict(self) -> Dict[str, Any]:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "host_callbacks": self.host_callbacks,
            "unbounded_loops": self.unbounded_loops,
        }


#: primitives that move/relayout data without arithmetic — charged
#: bytes for the *moved* data only (out read+write), zero FLOPs
_DATA_MOVEMENT = frozenset({
    "broadcast_in_dim", "reshape", "transpose", "rev", "slice",
    "concatenate", "squeeze", "expand_dims", "convert_element_type",
    "iota", "copy", "pad", "select_n", "split",
})

#: gather/scatter family: charge moved slices + index bytes, never the
#: full operand (a paged-KV gather does not read the whole pool).  This
#: is what makes the whole-model fused page gather (DESIGN.md §14) win
#: *statically*: one all-layer gather charges the table's index bytes
#: once where the per-layer path charged them num_layers times — the
#: drop ANALYSIS_serve.json's decode roofline gates on.
_GATHER_LIKE = frozenset({"gather", "dynamic_slice"})
_SCATTER_LIKE = frozenset({
    "scatter", "scatter-add", "scatter_add", "scatter-mul",
    "scatter-min", "scatter-max", "dynamic_update_slice",
})

#: reductions: one FLOP per input element
_REDUCTIONS = frozenset({
    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
    "reduce_and", "reduce_or", "argmax", "argmin", "cumsum",
    "cumlogsumexp", "cummax", "cummin", "cumprod",
})

#: host-callback primitives — each is a device<->host synchronisation
#: point inside a jitted computation
CALLBACK_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback",
    "host_callback_call", "infeed", "outfeed",
})


def _aval_bytes(aval) -> int:
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    try:
        return int(math.prod(shape)) * int(dtype.itemsize)
    except (TypeError, ValueError):      # polymorphic dims etc.
        return 0


def _aval_size(aval) -> int:
    shape = getattr(aval, "shape", None)
    if shape is None:
        return 0
    try:
        return int(math.prod(shape))
    except (TypeError, ValueError):
        return 0


def _in_avals(eqn) -> List[Any]:
    import jax.core as jcore
    return [v.aval for v in eqn.invars
            if not isinstance(v, jcore.Literal)]


def _dot_general_flops(eqn) -> float:
    ((lhs_c, _rhs_c), (lhs_b, _rhs_b)) = eqn.params["dimension_numbers"]
    lhs = eqn.invars[0].aval
    contract = math.prod(lhs.shape[d] for d in lhs_c) or 1
    batch = math.prod(lhs.shape[d] for d in lhs_b) or 1
    out_elems = sum(_aval_size(v.aval) for v in eqn.outvars)
    # out already includes the batch dims; 2 FLOPs (mul+add) per MAC
    del batch
    return 2.0 * out_elems * contract


def _conv_flops(eqn) -> float:
    rhs = eqn.invars[1].aval           # kernel: spatial... x in_ch x out_ch
    out_elems = sum(_aval_size(v.aval) for v in eqn.outvars)
    kernel_macs = _aval_size(rhs) // max(rhs.shape[-1], 1)
    return 2.0 * out_elems * max(kernel_macs, 1)


def jaxpr_costs(jaxpr) -> Costs:
    """Tally static costs of a jaxpr (``jax.make_jaxpr(f)(*avals)``).

    Accepts a ``ClosedJaxpr`` or a raw ``Jaxpr``.  ``scan`` bodies are
    multiplied by their trip count; ``cond`` takes the most expensive
    branch; ``while`` bodies are counted once and flagged via
    ``unbounded_loops``; ``pallas_call`` kernels are multiplied by their
    grid size when the grid is statically known.
    """
    jx = getattr(jaxpr, "jaxpr", jaxpr)
    total = Costs()
    for eqn in jx.eqns:
        name = eqn.primitive.name
        params = eqn.params
        if name in CALLBACK_PRIMS:
            total.host_callbacks += 1
            continue
        if name == "scan":
            total.add(jaxpr_costs(params["jaxpr"]),
                      scale=float(params.get("length", 1)))
            continue
        if name == "while":
            total.add(jaxpr_costs(params["body_jaxpr"]))
            total.unbounded_loops += 1
            continue
        if name == "cond":
            branches = [jaxpr_costs(b) for b in params["branches"]]
            worst = max(branches,
                        key=lambda c: c.flops + c.hbm_bytes,
                        default=Costs())
            total.add(worst)
            # callbacks on *any* branch are reachable syncs
            worst_cb = worst.host_callbacks
            total.host_callbacks += (
                sum(b.host_callbacks for b in branches) - worst_cb)
            continue
        if name == "pallas_call":
            try:
                grid = math.prod(params["grid_mapping"].grid) or 1
                total.add(jaxpr_costs(params["jaxpr"]), scale=float(grid))
                continue
            except Exception:      # opaque pallas params: fall through
                pass
        inner = params.get("jaxpr") or params.get("call_jaxpr")
        if inner is not None:      # pjit / custom_vjp / remat / checkpoint
            total.add(jaxpr_costs(inner))
            continue

        out_bytes = sum(_aval_bytes(v.aval) for v in eqn.outvars)
        out_elems = sum(_aval_size(v.aval) for v in eqn.outvars)
        in_avals = _in_avals(eqn)
        in_bytes = sum(_aval_bytes(a) for a in in_avals)

        if name == "dot_general":
            total.flops += _dot_general_flops(eqn)
            total.hbm_bytes += in_bytes + out_bytes
        elif name == "conv_general_dilated":
            total.flops += _conv_flops(eqn)
            total.hbm_bytes += in_bytes + out_bytes
        elif name in _GATHER_LIKE:
            idx_bytes = sum(_aval_bytes(a) for a in in_avals[1:])
            total.hbm_bytes += 2 * out_bytes + idx_bytes
        elif name in _SCATTER_LIKE:
            upd_bytes = (_aval_bytes(in_avals[-1])
                         if in_avals else out_bytes)
            idx_bytes = sum(_aval_bytes(a) for a in in_avals[1:-1])
            total.hbm_bytes += 2 * upd_bytes + idx_bytes
        elif name in _DATA_MOVEMENT:
            total.hbm_bytes += 2 * out_bytes
        elif name in _REDUCTIONS:
            total.flops += sum(_aval_size(a) for a in in_avals)
            total.hbm_bytes += in_bytes + out_bytes
        else:                      # default: elementwise
            total.flops += out_elems
            total.hbm_bytes += in_bytes + out_bytes
    return total


def roofline(costs: Costs, platform: Platform = DEFAULT_PLATFORM, *,
             transfer_bytes: float = 0.0) -> Dict[str, Any]:
    """Roofline estimate for one jaxpr's costs on one platform."""
    compute_s = costs.flops / platform.peak_flops
    memory_s = costs.hbm_bytes / platform.hbm_bw
    transfer_s = transfer_bytes / platform.h2d_bw
    bound = max(
        (("compute", compute_s), ("memory", memory_s),
         ("transfer", transfer_s)),
        key=lambda kv: kv[1])[0]
    return {
        "flops": costs.flops,
        "hbm_bytes": costs.hbm_bytes,
        "transfer_bytes": transfer_bytes,
        "host_callbacks": costs.host_callbacks,
        "unbounded_loops": costs.unbounded_loops,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "transfer_s": transfer_s,
        "est_s": max(compute_s, memory_s) + transfer_s,
        "bound": bound,
    }


# --------------------------------------------------------------------------
# kernel-candidate static priors (KernelRegistry autotuner)
# --------------------------------------------------------------------------

_ITEMSIZE = 4          # kernels stage fp32 tiles in VMEM
_VMEM_BUDGET_FRAC = 4  # stage at most 1/4 of VMEM (double-buffering etc.)


def kernel_prior(family: str, shape_key: Sequence, choice,
                 platform: Platform = DEFAULT_PLATFORM) -> float:
    """Static execution-time prior (seconds) for one KernelChoice.

    ``choice`` is duck-typed (``block_q``/``block_k``/``sub_k``/
    ``pages_per_step`` attributes, any may be ``None``) so this module
    never imports ``repro.kernels``.  Returns ``inf`` for candidates
    whose staged tiles exceed the VMEM budget — statically infeasible,
    the autotuner need not time them.
    """
    vmem_cap = platform.vmem_bytes // _VMEM_BUDGET_FRAC
    if family == "paged":
        _fam, pages, page_size, h, h_kv, d = shape_key
        pps = getattr(choice, "pages_per_step", None) or 1
        steps = math.ceil(pages / pps)
        staged = 2 * pps * page_size * h_kv * d * _ITEMSIZE
        if staged > vmem_cap:
            return float("inf")
        kv_bytes = 2 * pages * page_size * h_kv * d * _ITEMSIZE
        flops = 4.0 * pages * page_size * h * d
        return (steps * platform.dispatch_s
                + kv_bytes / platform.hbm_bw
                + flops / platform.peak_flops)

    # prefill families (inhibitor / flash): blocked attention over a
    # (n_q, n_k) score grid
    n_q, n_k, h, h_kv, d = shape_key[:5]
    causal = bool(shape_key[5]) if len(shape_key) > 5 else False
    bq = getattr(choice, "block_q", None) or 64
    bk = getattr(choice, "block_k", None) or 128
    staged = (2 * bq * d + 2 * bk * d + bq * bk) * _ITEMSIZE
    if staged > vmem_cap:
        return float("inf")
    frac = 0.5 if (causal and n_q == n_k) else 1.0
    q_steps = math.ceil(n_q / bq)
    k_steps = math.ceil(n_k / bk)
    sub = getattr(choice, "sub_k", None)
    sub_steps = (bk / sub) if (family == "inhibitor" and sub) else 1.0
    steps = q_steps * k_steps * frac * sub_steps
    # every q-row pass re-reads the full K/V stream
    kv_bytes = frac * q_steps * 2.0 * n_k * h_kv * d * _ITEMSIZE
    flops = frac * 4.0 * n_q * n_k * h * d
    return (steps * platform.dispatch_s
            + kv_bytes / platform.hbm_bw
            + flops / platform.peak_flops)


def rank_kernel_candidates(family: str, shape_key: Sequence,
                           candidates: Sequence,
                           platform: Platform = DEFAULT_PLATFORM,
                           ) -> List[Tuple[Any, float]]:
    """Rank autotune candidates by static prior, cheapest first.

    The sort is stable, so candidates with equal priors (including a
    run of ``inf``) keep their declared order — the registry's default
    stays first when the model has no opinion.
    """
    priced = [(c, kernel_prior(family, shape_key, c, platform))
              for c in candidates]
    return sorted(priced, key=lambda cp: cp[1])
