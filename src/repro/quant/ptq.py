"""Post-training quantization of a transformer onto the integer lanes.

The lane-parameterized forward (DESIGN.md §9) runs every layer in fixed-
point integer arithmetic; this module projects a trained float parameter
tree into that regime.  Scale conventions (all powers of two, so every
rescale is a levelled shift under TFHE):

  * activations  x_int = round(x · 2^act_frac), clamped to ``act_bits``
    signed bits at every LUT domain (the standard quantized-deployment
    activation clamp);
  * weights      w_int = round(w · 2^weight_frac), clamped to
    ``weight_bits`` — weights stay **cleartext** in the encrypted
    setting (the server owns the model; only activations are
    ciphertexts), so projections are levelled plaintext-weight matmuls
    followed by a ``weight_frac`` right-shift back to activation scale;
  * biases       b_int = round(b · 2^(act_frac + weight_frac)) for
    linear layers (added before the shift), and activation scale for
    norm biases (added after).

Embedding rows are quantized at activation scale: in the private-
inference deployment the *client* embeds its tokens locally and encrypts
the embedded activations (a cleartext table lookup on an encrypted index
is not in the TFHE op set), so the table is simply the first activation.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class PtqConfig:
    """Fixed-point regime for the integer lanes (powers of two only)."""

    act_bits: int = 8        # signed activation width (LUT-domain clamp)
    act_frac: int = 6        # activations carry 2^act_frac fixed point
    weight_bits: int = 8     # signed weight width
    weight_frac: int = 6     # weights carry 2^weight_frac fixed point
    softmax_frac: int = 6    # softmax-surrogate probability precision
    exp_clip: int = 15       # exp2 LUT window (deeper logits -> p = 0)
    score_frac: int = 1      # integer logits carry 2^score_frac per unit
    ex_bits: int = 4         # half-step RMS exponent width (norm surrogate)
    sq_shift: int = 4        # squares are tabulated as x² >> sq_shift

    @property
    def act_clip(self) -> int:
        return (1 << (self.act_bits - 1)) - 1

    @property
    def weight_clip(self) -> int:
        return (1 << (self.weight_bits - 1)) - 1


def _q(x, frac: int, clip: Optional[int] = None) -> np.ndarray:
    out = np.round(np.asarray(x, np.float64) * (1 << frac)).astype(np.int64)
    if clip is not None:
        out = np.clip(out, -clip, clip)
    return out


def quantize_linear(p: dict, ptq: PtqConfig, *, fold_in=None,
                    fold_out=None) -> dict:
    """Quantize one dense layer {kernel, bias?}.  ``fold_in``/``fold_out``
    flatten multi-axis kernels (e.g. (embed, h, d)) to 2-D matmul form."""
    kern = np.asarray(p["kernel"], np.float64)
    if fold_in:
        kern = kern.reshape(-1, *kern.shape[fold_in:])
    if fold_out:
        kern = kern.reshape(*kern.shape[:fold_out], -1)
    out = {"kernel": _q(kern, ptq.weight_frac, ptq.weight_clip)}
    if "bias" in p:
        out["bias"] = _q(np.asarray(p["bias"], np.float64).reshape(-1),
                         ptq.act_frac + ptq.weight_frac)
    return out


def quantize_norm(p: dict, ptq: PtqConfig) -> dict:
    out = {"scale": _q(p["scale"], ptq.weight_frac)}
    if "bias" in p:
        out["bias"] = _q(p["bias"], ptq.act_frac)
    return out


@dataclasses.dataclass
class QuantizedLM:
    """A PTQ'd decoder-only transformer, ready for any lane.

    ``blocks`` is a python list (one dict per layer — the lane forward
    loops layers in python; TFHE circuits are unrolled anyway).
    """
    cfg: Any                      # the ModelConfig it was quantized from
    ptq: PtqConfig
    embed: np.ndarray             # (vocab, d_model) int, activation scale
    blocks: List[Dict[str, Any]]
    final_norm: dict
    lm_head: dict

    @property
    def gamma_shift(self) -> int:
        a = self.cfg.attention
        gamma = (a.score_scale if a.score_scale is not None
                 else float(a.head_dim) ** 0.5)
        return max(0, int(round(math.log2(gamma)))) if gamma > 1 else 0

    @property
    def alpha_q(self) -> int:
        # the score shift α lives in activation units on integer lanes
        return max(0, int(round(self.cfg.attention.score_shift
                                * (1 << self.ptq.act_frac))))

    @property
    def scale_shift(self) -> int:
        # QKᵀ carries 2^(2·act_frac); bring logits to 2^score_frac units
        return max(0, 2 * self.ptq.act_frac + self.gamma_shift
                   - self.ptq.score_frac)


def ptq_lm(params: dict, cfg, ptq: Optional[PtqConfig] = None) -> QuantizedLM:
    """Project an unboxed float LM parameter tree onto the integer regime.

    Supports the dense family with classic (non-gated) MLPs and no RoPE —
    the FHE-friendly configuration (``paper_tiny``).  Gated MLPs need a
    ciphertext×ciphertext product per hidden unit and RoPE needs
    per-position literal rotations; both are rejected loudly rather than
    silently approximated.
    """
    ptq = ptq or PtqConfig()
    if cfg.family != "dense" or cfg.moe is not None:
        raise ValueError(f"lane PTQ supports the dense family; got "
                         f"{cfg.family!r} (moe={cfg.moe is not None})")
    if cfg.mlp == "gated_silu":
        raise ValueError(
            "gated MLPs multiply two ciphertext activations per hidden "
            "unit (cipher×cipher); use mlp_relu/mlp_gelu for integer lanes")
    if cfg.attention.use_rope:
        raise ValueError("RoPE is not supported on integer lanes; "
                         "use_rope=False (paper_tiny) is the FHE setting")
    if cfg.tie_embeddings:
        raise ValueError("tied embeddings would reuse the activation-scale "
                         "table as logit weights; untie for lane PTQ")

    import jax

    host = jax.tree.map(lambda a: np.asarray(a), params)
    n_layers = cfg.num_layers
    blocks = []
    for i in range(n_layers):
        bp = jax.tree.map(lambda a: a[i], host["blocks"])
        blocks.append({
            "ln1": quantize_norm(bp["ln1"], ptq),
            "wq": quantize_linear(bp["attn"]["wq"], ptq, fold_out=1),
            "wk": quantize_linear(bp["attn"]["wk"], ptq, fold_out=1),
            "wv": quantize_linear(bp["attn"]["wv"], ptq, fold_out=1),
            "wo": quantize_linear(bp["attn"]["wo"], ptq, fold_in=2),
            "ln2": quantize_norm(bp["ln2"], ptq),
            "wi": quantize_linear(bp["ffn"]["wi"], ptq),
            "wo_mlp": quantize_linear(bp["ffn"]["wo"], ptq),
        })
    return QuantizedLM(
        cfg=cfg, ptq=ptq,
        embed=_q(host["embed"]["table"], ptq.act_frac, ptq.act_clip),
        blocks=blocks,
        final_norm=quantize_norm(host["final_norm"], ptq),
        lm_head=quantize_linear(host["lm_head"], ptq),
    )
