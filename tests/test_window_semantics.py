"""Sliding-window mask semantics: window ⇒ causal, identically on every
execution path.

Regression: the three structural-mask implementations used to disagree on
non-causal configs with a sliding window — ``_build_mask`` applied only
the lower bound, ``blocked._chunk_mask`` added ``kj <= qi``, and the
Pallas kernels added neither.  The chosen semantics is *window implies
causality* (matching ``core.inhibitor.sliding_window_mask``); this module
locks it in across the fused/mask path, the blocked path, both Pallas
kernels, and the decode-cache path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attention import (AttentionConfig, apply_attention,
                                  init_attention, init_kv_cache)
from repro.core.mechanism import backend_eligible, get_mechanism, AttnShapes
from repro.nn.module import unbox

TOL = dict(rtol=1e-3, atol=1e-4)
WINDOW = 8


def _cfg(mech, backend=None, causal=True):
    return AttentionConfig(kind=mech, backend=backend, num_heads=4,
                           num_kv_heads=2, head_dim=8, causal=causal,
                           sliding_window=WINDOW)


def _layer(mech):
    return unbox(init_attention(jax.random.PRNGKey(0), _cfg(mech), 32))


@pytest.mark.parametrize("mech", ["inhibitor", "inhibitor_unsigned",
                                  "dotprod"])
@pytest.mark.parametrize("backend", ["fused", "chunked", "blocked",
                                     "pallas"])
@pytest.mark.parametrize("causal", [True, False])
def test_window_implies_causal_cross_backend(rng, mech, backend, causal):
    """Every (backend, causal flag) combination under a sliding window
    must equal the causal naive oracle — the window itself implies
    causality."""
    cfg = _cfg(mech, backend=backend, causal=causal)
    shapes = AttnShapes(batch=2, n_q=32, n_k=32, num_heads=4,
                        num_kv_heads=2, head_dim=8)
    ok, why = backend_eligible(backend, cfg, shapes, get_mechanism(mech))
    if not ok:
        pytest.skip(f"{backend}: {why}")
    params = _layer(mech)
    x = jnp.asarray(rng.normal(size=(2, 32, 32)).astype(np.float32))
    y_ref, _ = apply_attention(params, _cfg(mech, backend="naive",
                                            causal=True), x)
    y, _ = apply_attention(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), **TOL)


@pytest.mark.parametrize("mech", ["inhibitor", "dotprod"])
def test_window_semantics_survive_decode_cache(rng, mech):
    """Prefill + decode against a KV cache with a window agrees with the
    causal full-sequence oracle at the decoded position."""
    params = _layer(mech)
    x = jnp.asarray(rng.normal(size=(1, 12, 32)).astype(np.float32))
    # full-sequence causal oracle, last position
    y_full, _ = apply_attention(params, _cfg(mech, backend="naive",
                                             causal=True), x)
    # prefill 11, decode token 12 through the cache path (non-causal cfg:
    # the window must still impose causality)
    cfg = _cfg(mech, causal=False)
    cache = init_kv_cache(1, 16, 2, 8, jnp.float32)
    _, cache = apply_attention(params, cfg, x[:, :11], cache=cache)
    y_dec, _ = apply_attention(params, cfg, x[:, 11:12], cache=cache)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]),
                               np.asarray(y_full[:, 11]), **TOL)
