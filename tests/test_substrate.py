"""Substrate: optimizer, schedules, compression, checkpoint, data."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointConfig, CheckpointManager,
                              committed_steps, restore, save)
from repro.data import lm_tokens
from repro.data.pipeline import PipelineConfig, lm_batch_at
from repro.optim import (AdamWConfig, adamw_update, clip_by_global_norm,
                         init_adamw, warmup_cosine)


def test_adamw_converges_quadratic():
    target = jnp.asarray([1.5, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    state = init_adamw(params, cfg)
    for _ in range(300):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, state, _ = adamw_update(g, state, params, cfg)
    np.testing.assert_allclose(params["w"], target, atol=1e-2)


def test_grad_clip():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0,
                                                                 rel=1e-5)


def test_schedule_warmup_cosine():
    lr = warmup_cosine(1.0, 10, 100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert float(lr(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(lr(jnp.asarray(100))) == pytest.approx(0.1, rel=1e-3)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": np.arange(10, dtype=np.float32),
            "b": {"c": np.ones((3, 4), np.int32)}}
    save(str(tmp_path), 5, tree)
    restored, step = restore(str(tmp_path), tree)
    assert step == 5
    np.testing.assert_array_equal(restored["a"], tree["a"])
    np.testing.assert_array_equal(restored["b"]["c"], tree["b"]["c"])


def test_checkpoint_crash_consistency(tmp_path):
    """Uncommitted checkpoint dirs are invisible to restore."""
    tree = {"a": np.arange(4, dtype=np.float32)}
    save(str(tmp_path), 1, tree)
    # fake a crashed save: directory without the COMMITTED marker
    os.makedirs(tmp_path / "step_00000002")
    with open(tmp_path / "step_00000002" / "meta.json", "w") as f:
        f.write("{}")
    assert committed_steps(str(tmp_path)) == [1]
    _, step = restore(str(tmp_path), tree)
    assert step == 1


def test_checkpoint_manager_async_and_retention(tmp_path):
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path), every_steps=1,
                                             keep=2))
    tree = {"w": np.zeros(3, np.float32)}
    for s in (1, 2, 3, 4):
        mgr.save(s, {"w": np.full(3, s, np.float32)})
    mgr.wait()
    assert committed_steps(str(tmp_path)) == [3, 4]
    restored, step = mgr.restore(tree)
    assert step == 4 and float(restored["w"][0]) == 4.0


def test_data_determinism_and_host_sharding():
    cfg1 = PipelineConfig(global_batch=8, seq_len=16, vocab_size=100,
                          seed=7)
    a = lm_batch_at(cfg1, 3)
    b = lm_batch_at(cfg1, 3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # two hosts produce different, correctly-sized slices
    h0 = lm_batch_at(PipelineConfig(8, 16, 100, 7, num_hosts=2,
                                    host_index=0), 3)
    h1 = lm_batch_at(PipelineConfig(8, 16, 100, 7, num_hosts=2,
                                    host_index=1), 3)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_lm_tokens_learnable_structure():
    toks, labels = lm_tokens(4, 32, 64, 0)
    # labels are next-token shifted inputs
    np.testing.assert_array_equal(toks[:, 1:], labels[:, :-1])


def test_fold_key_is_process_invariant():
    """Param-init sub-keys must not depend on python's per-process hash
    salt (the old ``hash(name)`` derivation made every init different in
    every process — irreproducible restarts and cross-process parity).
    Pins the crc32 derivation itself, not jax's fold_in internals, so a
    JAX upgrade cannot fail this spuriously."""
    import zlib

    import jax

    from repro.nn.module import fold_key

    folded = zlib.crc32(b"wq") % (2 ** 31 - 1)
    assert folded == 111524964               # process/version invariant
    np.testing.assert_array_equal(
        jax.device_get(fold_key(jax.random.PRNGKey(0), "wq")),
        jax.device_get(jax.random.fold_in(jax.random.PRNGKey(0), folded)))
