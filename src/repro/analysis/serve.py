"""CLI: statically analyze the serve engine's hot path and write
``ANALYSIS_serve.json``.

    PYTHONPATH=src python -m repro.analysis.serve --config paper_tiny
    PYTHONPATH=src python -m repro.analysis.serve --config paper_tiny \
        --check-bench BENCH_serve_smoke.json --out ANALYSIS_serve.json

Exit status is non-zero when any proof obligation fails: a compile set
over the declared retrace budget (unbucketed configs fail here by
construction), an unverifiable trace signature, an untagged host<->
device sync site in the tick path, a per-tick transfer count over the
declared contract, a host callback inside a jitted step, or a bench
artifact whose *measured* compile counters exceed the *proven* bound
(a soundness bug in the enumeration — the loudest failure of all).
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.serve_static import (analyze_serve, cross_check_bench,
                                         format_serve_report)

#: CLI default engine geometry: small enough to analyze in seconds,
#: large enough that every bucket family has >= 3 members
_DEFAULT_ENGINE_KW = dict(max_batch=4, max_len=128, page_size=16,
                          prefill_chunk=16)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.serve",
        description="Static serve-path analysis: retrace-budget proof, "
                    "host-sync audit, and per-signature roofline")
    ap.add_argument("--config", default="paper-tiny",
                    help="architecture id (default: paper-tiny; "
                         "underscores are normalized to dashes)")
    ap.add_argument("--reduced", action="store_true",
                    help="shrink the model (cfg.reduced() defaults) so "
                         "params init stays cheap")
    ap.add_argument("--allocators", default="paged,contiguous",
                    help="comma-separated allocator arms to prove")
    ap.add_argument("--max-batch", type=int,
                    default=_DEFAULT_ENGINE_KW["max_batch"])
    ap.add_argument("--max-len", type=int,
                    default=_DEFAULT_ENGINE_KW["max_len"])
    ap.add_argument("--page-size", type=int,
                    default=_DEFAULT_ENGINE_KW["page_size"])
    ap.add_argument("--prefill-chunk", type=int,
                    default=_DEFAULT_ENGINE_KW["prefill_chunk"])
    ap.add_argument("--budget", type=int, default=None,
                    help="declared total compile budget override "
                         "(default: derived from the config)")
    ap.add_argument("--check-bench", default=None,
                    help="serve_bench JSON artifact: cross-check its "
                         "measured compile counters against the proven "
                         "bounds re-derived from its recorded configs")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="ANALYSIS_serve.json",
                    help="output JSON path ('-' for stdout only)")
    args = ap.parse_args(argv)

    allocators = [a.strip() for a in args.allocators.split(",") if a.strip()]
    engine_kw = dict(max_batch=args.max_batch, max_len=args.max_len,
                     page_size=args.page_size,
                     prefill_chunk=args.prefill_chunk)
    doc = analyze_serve(args.config, allocators=allocators,
                        engine_kw=engine_kw,
                        reduced={} if args.reduced else None,
                        declared_budget=args.budget, seed=args.seed)

    failures = []
    for alloc, arm in doc["allocators"].items():
        r = arm["retrace"]
        if not r["within_budget"]:
            failures.append(
                f"[{alloc}] compile set over budget: proven "
                f"{r['proven_total']} > declared {r['declared_total']} "
                f"(prefill {r['prefill']['proven']}/"
                f"{r['prefill']['declared']}, decode "
                f"{r['decode']['proven']}/{r['decode']['declared']})")
        if not arm["signatures"]["verified"]:
            failures.append(f"[{alloc}] signature verification failed: "
                            f"{arm['signatures'].get('error')}")
        if arm["roofline"]["jit_host_callbacks"]:
            failures.append(
                f"[{alloc}] {arm['roofline']['jit_host_callbacks']} host "
                f"callback(s) inside jitted step functions")
    audit = doc["sync_audit"]
    for site in audit["unallowlisted"]:
        failures.append(
            f"untagged sync: {site['path']}:{site['line']} {site['api']} "
            f"({site['kind']}) in {site['func']}()")
    if not audit["ok"] and not audit["unallowlisted"]:
        failures.append(
            f"per-tick sync contract violated: "
            f"h2d={audit['per_tick']['h2d']}/"
            f"{audit['declared_per_tick']['h2d']}, "
            f"d2h={audit['per_tick']['d2h']}/"
            f"{audit['declared_per_tick']['d2h']}")
    tel = doc["sync_audit_telemetry"]
    for site in tel["unallowlisted"]:
        failures.append(
            f"untagged sync in telemetry emit path: "
            f"{site['path']}:{site['line']} {site['api']} "
            f"({site['kind']}) in {site['func']}()")
    if not tel["ok"] and not tel["unallowlisted"]:
        failures.append(
            f"telemetry emit path is not transfer-free: "
            f"h2d={tel['per_tick']['h2d']}, d2h={tel['per_tick']['d2h']} "
            f"(declared 0 + 0 — instrumentation must never add "
            f"host<->device traffic to the tick path)")
    if args.check_bench:
        with open(args.check_bench) as f:
            doc["cross_check"] = cross_check_bench(json.load(f))
        for arm in doc["cross_check"]["arms"].values():
            failures.extend(arm["failures"])
    doc["ok"] = doc["ok"] and not failures

    print(format_serve_report(doc))
    if args.out != "-":
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        print(f"wrote {args.out}")

    for msg in failures:
        print(f"ANALYSIS FAILURE: {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
