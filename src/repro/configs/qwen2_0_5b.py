"""qwen2-0.5b — dense GQA LM with QKV bias.
[arXiv:2407.10671; hf:Qwen/Qwen2-0.5B]
24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936, head_dim=64.
"""

from repro.configs.base import ModelConfig
from repro.core.attention import AttentionConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    num_layers=24,
    d_model=896,
    d_ff=4864,
    vocab_size=151936,
    attention=AttentionConfig(
        mechanism="dotprod", num_heads=14, num_kv_heads=2, head_dim=64,
        qkv_bias=True, use_rope=True, rope_base=1000000.0, causal=True),
    norm="rmsnorm",
    norm_eps=1e-6,
    mlp="gated_silu",
    tie_embeddings=True,
    max_seq_len=131072,
    source="arXiv:2407.10671",
)
