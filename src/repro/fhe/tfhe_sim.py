"""TFHE circuit simulator: exact integer semantics + cost/noise accounting.

We cannot run the Concrete compiler in this environment, so the paper's FHE
axis (Tables 2 & 4) is reproduced with a *faithful cost simulator*: every
homomorphic operation on an :class:`EncTensor` executes the exact integer
arithmetic (so circuit outputs are bit-exact with a cleartext reference)
while a :class:`FheContext` records, per TFHE's actual cost structure:

  * ``pbs``      — programmable bootstraps.  Univariate LUT = 1 PBS per
                   element; ciphertext×ciphertext multiplication = 2 PBS per
                   element via the paper's eq. 1–2 identity
                   ``ab = PBS(x²/4; a+b) − PBS(x²/4; a−b)``.
  * ``cmuls``    — ciphertext×ciphertext multiplications (the op the
                   inhibitor exists to avoid; each one also costs 2 PBS,
                   already included in ``pbs``).
  * ``adds``     — ciphertext additions/subtractions (levelled, cheap).
  * ``lit_muls`` — literal (plaintext-constant) multiplications (cheap).
  * ``max_bits`` — the message-space bit-width high-water mark: every
                   intermediate's dynamic range is tracked, because TFHE
                   circuit parameters (polySize, lweDim) are chosen from the
                   largest value that must survive a PBS (paper Table 2).

The ``x²/4`` trick needs the *sum* a+b inside the table, so a k-bit × k-bit
product costs a (k+1)-bit table — this is exactly why the paper's dot-
product circuits need 1–2 bits more than the inhibitor circuits (their
last-two-column gap in Table 2), and the simulator reproduces it for free
by tracking ranges of PBS *inputs*.

Per-layer attribution: :meth:`FheContext.scope` opens a named accounting
scope; every counter update lands in the active scope as well as the
totals.  ``scope_report()`` returns the per-scope summaries — the data the
full-block parameter selection (:func:`repro.fhe.params.select_params_for_report`)
and the per-layer cost tables are built from.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Callable, Dict, Optional

import numpy as np

_COUNTERS = ("pbs", "cmuls", "adds", "lit_muls")


@dataclasses.dataclass
class FheContext:
    """Operation counters + message-space tracking for one circuit."""

    pbs: int = 0
    cmuls: int = 0
    adds: int = 0
    lit_muls: int = 0
    max_bits: int = 0           # widest signed message seen at a PBS input
    max_bits_any: int = 0       # widest signed message anywhere
    trace: bool = False
    per_scope: Dict[str, dict] = dataclasses.field(default_factory=dict)
    _scope: Optional[str] = None

    # ---- scoping -----------------------------------------------------
    @contextlib.contextmanager
    def scope(self, name: str):
        """Attribute every counter update inside the block to ``name``
        (in addition to the totals).  Scopes do not nest — the innermost
        name wins, which is what per-layer attribution wants."""
        prev = self._scope
        self._scope = name
        self.per_scope.setdefault(name, {
            "pbs": 0, "cmuls": 0, "adds": 0, "lit_muls": 0,
            "max_bits_at_pbs": 0, "max_bits_any": 0})
        try:
            yield self
        finally:
            self._scope = prev

    def _bump(self, counter: str, n: int):
        setattr(self, counter, getattr(self, counter) + n)
        if self._scope is not None:
            self.per_scope[self._scope][counter] += n

    # ---- width tracking ----------------------------------------------
    def _observe(self, arr: np.ndarray, at_pbs: bool):
        amax = int(np.max(np.abs(arr))) if arr.size else 0
        bits = max(1, int(amax).bit_length()) + 1  # signed representation
        self.max_bits_any = max(self.max_bits_any, bits)
        if at_pbs:
            self.max_bits = max(self.max_bits, bits)
        if self._scope is not None:
            s = self.per_scope[self._scope]
            s["max_bits_any"] = max(s["max_bits_any"], bits)
            if at_pbs:
                s["max_bits_at_pbs"] = max(s["max_bits_at_pbs"], bits)

    # ---- counting (the only mutation API — scope attribution lives
    # here, so EncTensor and FheSimLane both route through it) ---------
    def count_pbs(self, arr: np.ndarray, n_per_element: int = 1):
        self._bump("pbs", int(arr.size) * n_per_element)
        self._observe(arr, at_pbs=True)

    def count_cmul(self, s: np.ndarray, d: np.ndarray):
        """One ciphertext multiply per element: 2 PBS over the packed
        sums/differences a±b (eq. 1), plus the surrounding adds."""
        self._bump("cmuls", int(s.size))
        self.count_pbs(s, 1)
        self.count_pbs(d, 1)
        self._bump("adds", 3 * int(s.size))

    def count_add(self, arr: np.ndarray, n: Optional[int] = None):
        self._bump("adds", int(arr.size) if n is None else int(n))
        self._observe(arr, at_pbs=False)

    def count_lit_mul(self, arr: np.ndarray, n: Optional[int] = None):
        self._bump("lit_muls", int(arr.size) if n is None else int(n))
        self._observe(arr, at_pbs=False)

    def summary(self) -> dict:
        return {
            "pbs": self.pbs,
            "cmuls": self.cmuls,
            "adds": self.adds,
            "lit_muls": self.lit_muls,
            "max_bits_at_pbs": self.max_bits,
            "max_bits_any": self.max_bits_any,
        }

    def scope_report(self) -> Dict[str, dict]:
        """Per-scope summaries (insertion order = execution order)."""
        return {k: dict(v) for k, v in self.per_scope.items()}


class EncTensor:
    """An "encrypted" integer tensor: exact values + cost accounting.

    Supports exactly the operations TFHE supports natively or via PBS:
    add/sub (cheap), multiply-by-literal (cheap), univariate LUT (1 PBS per
    element), ciphertext multiply (2 PBS per element), and the derived
    relu/abs/sign/square/max helpers the two attention circuits need.
    """

    def __init__(self, values: np.ndarray, ctx: FheContext):
        self.values = np.asarray(values, dtype=np.int64)
        self.ctx = ctx

    # ---- structure ----
    @property
    def shape(self):
        return self.values.shape

    def reshape(self, *shape):
        return EncTensor(self.values.reshape(*shape), self.ctx)

    def __getitem__(self, idx):
        return EncTensor(self.values[idx], self.ctx)

    # ---- levelled ops (no PBS) ----
    def __add__(self, other):
        if isinstance(other, EncTensor):
            out = self.values + other.values
        else:
            out = self.values + np.asarray(other, dtype=np.int64)
        self.ctx.count_add(out)
        return EncTensor(out, self.ctx)

    def __sub__(self, other):
        if isinstance(other, EncTensor):
            out = self.values - other.values
        else:
            out = self.values - np.asarray(other, dtype=np.int64)
        self.ctx.count_add(out)
        return EncTensor(out, self.ctx)

    def __neg__(self):
        return EncTensor(-self.values, self.ctx)

    def mul_literal(self, c) -> "EncTensor":
        out = self.values * np.asarray(c, dtype=np.int64)
        self.ctx.count_lit_mul(out)
        return EncTensor(out, self.ctx)

    def shift_right(self, k: int) -> "EncTensor":
        """Arithmetic shift (literal division by 2^k) — levelled rescale."""
        out = self.values >> k
        self.ctx.count_lit_mul(out)
        return EncTensor(out, self.ctx)

    def sum(self, axis=None) -> "EncTensor":
        out = self.values.sum(axis=axis)
        # a tree of ciphertext additions
        self.ctx.count_add(out, n=max(int(self.values.size - out.size), 0))
        return EncTensor(out, self.ctx)

    # ---- PBS ops ----
    def lut(self, fn: Callable[[np.ndarray], np.ndarray],
            n_pbs: int = 1) -> "EncTensor":
        """Univariate table lookup: 1 PBS per element.

        The *input* range determines the required table size — that is the
        message-space bit-width recorded for parameter selection.
        """
        self.ctx.count_pbs(self.values, n_pbs)
        return EncTensor(fn(self.values).astype(np.int64), self.ctx)

    def relu(self) -> "EncTensor":
        return self.lut(lambda x: np.maximum(x, 0))

    def abs(self) -> "EncTensor":
        return self.lut(np.abs)

    def sign(self) -> "EncTensor":
        return self.lut(np.sign)

    def mul_cipher(self, other: "EncTensor") -> "EncTensor":
        """Ciphertext × ciphertext via eq. 1: two PBS of x²/4 over a+b, a−b.

        Exact for integers: (a+b)² − (a−b)² = 4ab; the x²/4 table rounds,
        and the two roundings cancel exactly when a+b and a−b share parity
        (always true). PBS inputs a±b are observed for width tracking —
        the +1 bit over the operands is the paper's Table 2 gap.
        """
        s = self.values + other.values
        d = self.values - other.values
        self.ctx.count_cmul(s, d)
        out = (s * s - d * d) // 4
        self.ctx._observe(out, at_pbs=False)
        return EncTensor(out, self.ctx)


def encrypt(values: np.ndarray, ctx: Optional[FheContext] = None):
    ctx = ctx or FheContext()
    return EncTensor(np.asarray(values, dtype=np.int64), ctx), ctx


def decrypt(t: EncTensor) -> np.ndarray:
    return t.values.copy()
