"""Paged decode kernels: block-table-native flash-inhibitor / flash-attention.

Serving decode (DESIGN.md §8) keeps KV rows in a shared page pool behind
per-slot block tables.  The ``paged`` backend used to gather the *whole*
pool back into a contiguous ``(b, P·ps, h_kv, d)`` tensor every tick —
O(pool) HBM traffic regardless of how many tokens a row actually holds.
These kernels walk each row's block table *inside the grid* instead
(DESIGN.md §10): the K/V BlockSpec index maps read the scalar-prefetched
block tables, so exactly one physical page is DMA'd into VMEM per staged
input and the contiguous intermediate never exists.

Grid layout:

  * grid = (batch · kv_heads, ceil(P / pages_per_step)) — dimension 1 is
    the sequential walk over each row's logical pages; scratch
    accumulators live across it.
  * scalar prefetch: ``block_tables`` (b, P) int32 and ``lengths`` (b,)
    int32 (the per-row cursor = number of valid KV rows, including the
    token scattered this tick).  Index maps translate (row, step, i) ->
    physical page ``tables[row, step·pps + i]``; entries beyond a row's
    cursor point at the reserved trash page 0, so consecutive dead steps
    re-reference the same block and cost no further copies.
  * ``pages_per_step`` physical pages are staged per grid step as
    separate BlockSpec'd inputs (pages are not contiguous in the pool, so
    one wider block cannot cover them); the kernel loops over the staged
    refs.
  * GQA: all ``group = heads / kv_heads`` query heads sharing a KV head
    are processed against one staged page (same staging as
    :mod:`repro.kernels.inhibitor`).

Masking is per-row and dynamic: ``k_pos < lengths[row]`` from
``broadcasted_iota`` — the single decode query sits at position
``lengths[row] - 1``, so causality is implied and only the sliding
window adds structure.  Pages at-or-beyond the cursor are skipped
entirely (``lax.cond`` around the compute), so per-row work is
O(valid pages), not O(table width).

Decode is inference-only: no custom VJP (the wrappers in
:mod:`repro.kernels.ops` do not register one).

Validated in ``interpret=True`` mode against the gather references in
:mod:`repro.kernels.ref` (tests/test_paged_kernels.py sweeps GQA,
windows, ragged cursors, page-straddling cursors and ``normalize``).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_PAGES_PER_STEP = 4

#: Platforms this module's Pallas bodies lower *natively* on.  The grid
#: walks block tables through ``pltpu.PrefetchScalarGridSpec`` scalar
#: prefetch (BlockSpec index maps reading prefetched tables), a
#: TPU/Mosaic feature with no Triton equivalent — on any other platform
#: the body only runs in ``interpret=True`` mode, which must never be
#: picked over the XLA gather path.  A Triton rewrite of the table walk
#: (pointer arithmetic instead of prefetch-indexed BlockSpecs) would
#: extend this to ("tpu", "gpu") and the registry/planner pick it up
#: with no further wiring (kernels.ops.NATIVE_PLATFORMS).
LOWERS_ON = ("tpu",)
NEG_INF = -1e30


def _decode_layout(q, k_pool, block_tables, lengths):
    """Shared shape bookkeeping + the group-major query layout."""
    batch, n_q, heads, d = q.shape
    if n_q != 1:
        raise ValueError(f"paged decode kernels are single-query (n_q=1); "
                         f"got n_q={n_q} — prefill goes through the gather "
                         f"path")
    num_pages, page_size, kv_heads, dk = k_pool.shape
    assert d == dk and heads % kv_heads == 0
    group = heads // kv_heads
    if block_tables.shape[0] != batch or lengths.shape != (batch,):
        raise ValueError(
            f"block_tables {block_tables.shape} / lengths {lengths.shape} "
            f"do not match batch={batch}")
    # head = kv_head * group + g (same factoring as the prefill kernels)
    qg = q.reshape(batch, kv_heads, group, d).reshape(
        batch * kv_heads, group, d)
    return qg, batch, heads, kv_heads, group, d, page_size


def _page_specs(pps: int, page_size: int, kv_heads: int, d: int,
                table_width: int):
    """``2·pps`` BlockSpecs staging pages k0,v0,k1,v1,… per grid step.

    The index maps read the scalar-prefetched block tables; logical page
    indices past the table width clamp to the last column (whose compute
    is masked off by the cursor anyway).
    """
    def page_index(bh, j, tables, lengths, i):
        del lengths
        logical = jnp.minimum(j * pps + i, table_width - 1)
        return (tables[bh // kv_heads, logical], 0, bh % kv_heads, 0)

    specs = []
    for i in range(pps):
        idx = functools.partial(page_index, i=i)
        specs.append(pl.BlockSpec((1, page_size, 1, d), idx))  # k page i
        specs.append(pl.BlockSpec((1, page_size, 1, d), idx))  # v page i
    return specs


def _qo_specs(group: int, d: int):
    def qo_index(bh, j, tables, lengths):
        del j, tables, lengths
        return (bh, 0, 0)
    return pl.BlockSpec((1, group, d), qo_index)


# ---------------------------------------------------------------------------
# paged flash-inhibitor (paper eq. 9 / eq. 10 streaming forms)
# ---------------------------------------------------------------------------

def _paged_inhibitor_kernel(
    tbl_ref, len_ref, q_ref, *rest,
    score_scale: float, score_shift: float, signed: bool, normalize: bool,
    window: Optional[int], kv_heads: int, page_size: int, pps: int,
    n_steps: int,
):
    kv_refs, (o_ref,), (acc_ref, cnt_ref) = (
        rest[:2 * pps], rest[2 * pps:2 * pps + 1], rest[2 * pps + 1:])
    bh = pl.program_id(0)
    j = pl.program_id(1)
    row = bh // kv_heads

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    q = q_ref[0].astype(jnp.float32)              # (group, d)
    valid = len_ref[row]
    q_pos = valid - 1

    def process_page(i, acc, cnt):
        ks = kv_refs[2 * i][0, :, 0, :].astype(jnp.float32)   # (ps, d)
        vs = kv_refs[2 * i + 1][0, :, 0, :].astype(jnp.float32)

        # ---- scores: Z = relu(Σ_d |q − k| / γ − α)  (eq. 5 + shift) ----
        diff = jnp.abs(q[:, None, :] - ks[None, :, :])        # (g, ps, d)
        z = jnp.sum(diff, axis=-1) * (1.0 / score_scale)      # (g, ps)
        if score_shift:
            z = jnp.maximum(z - score_shift, 0.0)

        # ---- per-row cursor mask from positions (True = attend) ----
        k_pos = ((j * pps + i) * page_size
                 + jax.lax.broadcasted_iota(jnp.int32, (1, page_size), 1))
        m = k_pos < valid
        if window is not None:
            # the decode query is the newest position, so the window's
            # causal half (k_pos <= q_pos) is already the cursor mask
            m = m & (k_pos > q_pos - window)
        mf = m.astype(jnp.float32)                            # (1, ps)

        # ---- inhibition (masked fused forms, eq. 9 / eq. 10) ----
        col_v = jnp.einsum("os,sd->od", mf, vs)               # (1, d)
        if signed:
            vp = jnp.maximum(vs, 0.0)
            vn = vs - vp
            t_pos = jnp.sum(jnp.abs(vp[None, :, :] - z[..., None])
                            * mf[0][None, :, None], axis=1)   # (g, d)
            t_neg = jnp.sum(jnp.abs(-vn[None, :, :] - z[..., None])
                            * mf[0][None, :, None], axis=1)
            part = 0.5 * (col_v + t_pos - t_neg)              # (g, d)
        else:
            row_z = jnp.sum(z * mf, axis=-1)                  # (g,)
            cross = jnp.sum(jnp.abs(vs[None, :, :] - z[..., None])
                            * mf[0][None, :, None], axis=1)
            part = 0.5 * (col_v - row_z[:, None] + cross)

        return acc + part, cnt + jnp.sum(mf)

    def do_step():
        acc, cnt = acc_ref[...], cnt_ref[0, 0]
        for i in range(pps):
            acc, cnt = process_page(i, acc, cnt)
        return acc, cnt

    # skip steps wholly past the cursor (their table entries are trash)
    acc, cnt = jax.lax.cond(
        j * pps * page_size < valid, do_step,
        lambda: (acc_ref[...], cnt_ref[0, 0]))
    acc_ref[...] = acc
    cnt_ref[0, 0] = cnt

    @pl.when(j == n_steps - 1)
    def _finalize():
        out = acc_ref[...]
        if normalize:
            out = out / jnp.maximum(cnt_ref[0, 0], 1.0)
        o_ref[0] = out.astype(o_ref.dtype)


def paged_flash_inhibitor_fwd(
    q: jax.Array,               # (batch, 1, heads, d)
    k_pool: jax.Array,          # (num_pages, page_size, kv_heads, d)
    v_pool: jax.Array,
    block_tables: jax.Array,    # (batch, P) int32
    lengths: jax.Array,         # (batch,) int32 per-row cursors
    *,
    score_scale: Optional[float] = None,
    score_shift: float = 0.5,
    signed: bool = True,
    normalize: bool = True,
    window: Optional[int] = None,
    pages_per_step: int = DEFAULT_PAGES_PER_STEP,
    interpret: bool = False,
) -> jax.Array:
    """Block-table-native paged inhibitor decode. Returns (batch, 1, heads, d)."""
    qg, batch, heads, kv_heads, group, d, ps = _decode_layout(
        q, k_pool, block_tables, lengths)
    scale = score_scale if score_scale is not None else math.sqrt(d)
    table_width = block_tables.shape[1]
    pps = max(1, min(pages_per_step, table_width))
    n_steps = -(-table_width // pps)

    kernel = functools.partial(
        _paged_inhibitor_kernel,
        score_scale=scale, score_shift=score_shift, signed=signed,
        normalize=normalize, window=window, kv_heads=kv_heads,
        page_size=ps, pps=pps, n_steps=n_steps)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(batch * kv_heads, n_steps),
        in_specs=[_qo_specs(group, d)] + _page_specs(
            pps, ps, kv_heads, d, table_width),
        out_specs=_qo_specs(group, d),
        scratch_shapes=[
            pltpu.VMEM((group, d), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
    )
    pools = [p for _ in range(pps) for p in (k_pool, v_pool)]
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((batch * kv_heads, group, d), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      qg, *pools)
    return out.reshape(batch, 1, heads, d)


# ---------------------------------------------------------------------------
# paged flash attention (Softmax baseline, online recurrence)
# ---------------------------------------------------------------------------

def _paged_attention_kernel(
    tbl_ref, len_ref, q_ref, *rest,
    score_scale: float, window: Optional[int], kv_heads: int,
    page_size: int, pps: int, n_steps: int,
):
    kv_refs, (o_ref,), (acc_ref, m_ref, l_ref) = (
        rest[:2 * pps], rest[2 * pps:2 * pps + 1], rest[2 * pps + 1:])
    bh = pl.program_id(0)
    j = pl.program_id(1)
    row = bh // kv_heads

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)              # (group, d)
    valid = len_ref[row]
    q_pos = valid - 1

    def process_page(i, acc, m_prev, l_prev):
        ks = kv_refs[2 * i][0, :, 0, :].astype(jnp.float32)   # (ps, d)
        vs = kv_refs[2 * i + 1][0, :, 0, :].astype(jnp.float32)
        k_pos = ((j * pps + i) * page_size
                 + jax.lax.broadcasted_iota(jnp.int32, (1, page_size), 1))
        m_blk = k_pos < valid
        if window is not None:
            m_blk = m_blk & (k_pos > q_pos - window)

        s = jnp.einsum("gd,sd->gs", q, ks) * (1.0 / score_scale)
        s = jnp.where(m_blk, s, NEG_INF)                      # (g, ps)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        # fully-masked pages: exp(NEG_INF - NEG_INF) = 1 — zero them out
        p = p * jnp.any(m_blk, axis=-1)
        alpha = jnp.exp(m_prev - m_new)
        alpha = jnp.where(m_prev == NEG_INF, 0.0, alpha)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("gs,sd->gd", p, vs)
        return acc, m_new, l_new

    def do_step():
        acc, m, l = acc_ref[...], m_ref[...], l_ref[...]
        for i in range(pps):
            acc, m, l = process_page(i, acc, m, l)
        return acc, m, l

    acc, m, l = jax.lax.cond(
        j * pps * page_size < valid, do_step,
        lambda: (acc_ref[...], m_ref[...], l_ref[...]))
    acc_ref[...] = acc
    m_ref[...] = m
    l_ref[...] = l

    @pl.when(j == n_steps - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def paged_flash_attention_fwd(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    *,
    score_scale: Optional[float] = None,
    window: Optional[int] = None,
    pages_per_step: int = DEFAULT_PAGES_PER_STEP,
    interpret: bool = False,
) -> jax.Array:
    """Block-table-native paged Softmax decode. Returns (batch, 1, heads, d)."""
    qg, batch, heads, kv_heads, group, d, ps = _decode_layout(
        q, k_pool, block_tables, lengths)
    scale = score_scale if score_scale is not None else math.sqrt(d)
    table_width = block_tables.shape[1]
    pps = max(1, min(pages_per_step, table_width))
    n_steps = -(-table_width // pps)

    kernel = functools.partial(
        _paged_attention_kernel,
        score_scale=scale, window=window, kv_heads=kv_heads,
        page_size=ps, pps=pps, n_steps=n_steps)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(batch * kv_heads, n_steps),
        in_specs=[_qo_specs(group, d)] + _page_specs(
            pps, ps, kv_heads, d, table_width),
        out_specs=_qo_specs(group, d),
        scratch_shapes=[
            pltpu.VMEM((group, d), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
        ],
    )
    pools = [p for _ in range(pps) for p in (k_pool, v_pool)]
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((batch * kv_heads, group, d), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      qg, *pools)
    return out.reshape(batch, 1, heads, d)
