"""General dense (einsum) layers with logical-axis annotations."""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.module import Param


def init_dense(
    key,
    in_shape: Sequence[int],
    out_shape: Sequence[int],
    in_axes: Sequence[Optional[str]],
    out_axes: Sequence[Optional[str]],
    *,
    use_bias: bool = False,
    dtype=jnp.float32,
    kernel_init=None,
    bias_axes: Optional[Sequence[Optional[str]]] = None,
) -> dict:
    """A generalized linear layer contracting ``in_shape`` into ``out_shape``.

    Kernel has shape ``(*in_shape, *out_shape)`` with logical axes
    ``(*in_axes, *out_axes)``.
    """
    in_shape = tuple(in_shape)
    out_shape = tuple(out_shape)
    if kernel_init is None:
        # truncated-normal with stddev = 1/sqrt(prod(in_shape))
        kernel_init = _fan_in_init(in_shape)
    kernel = kernel_init(key, in_shape + out_shape, dtype)
    params = {"kernel": Param(kernel, tuple(in_axes) + tuple(out_axes))}
    if use_bias:
        baxes = tuple(bias_axes) if bias_axes is not None else tuple(out_axes)
        params["bias"] = Param(jnp.zeros(out_shape, dtype), baxes)
    return params


def _fan_in_init(in_shape):
    fan_in = int(np.prod(in_shape))

    def _init(key, shape, dtype=jnp.float32):
        std = fan_in ** -0.5
        x = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
        return (x * std / 0.87962566103423978).astype(dtype)

    return _init


def apply_dense(params: dict, x: jax.Array, n_in_dims: int = 1,
                compute_dtype=None) -> jax.Array:
    """Contract the last ``n_in_dims`` dims of ``x`` with the kernel."""
    kernel = params["kernel"]
    if compute_dtype is not None:
        kernel = kernel.astype(compute_dtype)
        x = x.astype(compute_dtype)
    n_out = kernel.ndim - n_in_dims
    # build einsum: batch dims ... + contraction
    x_dims = x.ndim
    letters = "abcdefghijklmnopqrstuvwxyz"
    batch = letters[: x_dims - n_in_dims]
    contract = letters[x_dims - n_in_dims: x_dims]
    out = letters[x_dims: x_dims + n_out]
    eq = f"{batch}{contract},{contract}{out}->{batch}{out}"
    y = jnp.einsum(eq, x, kernel)
    if "bias" in params:
        b = params["bias"]
        if compute_dtype is not None:
            b = b.astype(compute_dtype)
        y = y + b
    return y
