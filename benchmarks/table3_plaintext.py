"""Paper Table 3: plaintext integer-arithmetic timing vs sequence length.

The paper times low-level int16 implementations (Rust/Criterion) of both
attention mechanisms at T ∈ {32, 64, 128, 256}, single head, fixed dim,
finding 30–50 % savings for the Inhibitor.  We mirror the protocol with
the int32-lane implementations in repro.quant.int_attention (jit-compiled,
CPU, averaged over ≥20 reps after warm-up).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mechanism import get_mechanism

REPS = 20
D = 16


def _time(fn, *args, reps: int = REPS) -> float:
    out = fn(*args)
    jax.block_until_ready(out)          # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # µs


def run(smoke: bool = False) -> list:
    # the integer-lane reference of each arm comes off the registry
    int_inhibitor = get_mechanism("inhibitor").int_reference
    int_dotprod = get_mechanism("dotprod").int_reference
    rows = []
    rng = np.random.default_rng(0)
    inh = jax.jit(lambda q, k, v: int_inhibitor(
        q, k, v, gamma_shift=2, alpha_q=1))
    dot = jax.jit(lambda q, k, v: int_dotprod(q, k, v, scale_shift=4))
    for T in (32, 64) if smoke else (32, 64, 128, 256):
        q = jnp.asarray(rng.integers(-127, 128, (T, D)).astype(np.int32))
        k = jnp.asarray(rng.integers(-127, 128, (T, D)).astype(np.int32))
        v = jnp.asarray(rng.integers(-127, 128, (T, D)).astype(np.int32))
        t_i = _time(inh, q, k, v, reps=3 if smoke else REPS)
        t_d = _time(dot, q, k, v, reps=3 if smoke else REPS)
        saving = 1.0 - t_i / t_d
        rows.append((f"table3/T{T}/inhibitor", round(t_i, 1), "us"))
        rows.append((f"table3/T{T}/dotprod", round(t_d, 1), "us"))
        rows.append((f"table3/T{T}/saving", 0.0, f"{saving:.1%}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
