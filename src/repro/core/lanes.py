"""Numeric execution lanes: one op set, three arithmetic domains.

The paper's claim is that a whole quantized Transformer — not just the
attention op — runs under TFHE because every layer can be expressed in
the small op vocabulary TFHE executes cheaply: ciphertext add/sub,
plaintext-weight matmul (levelled), multiply/shift by literals, and
univariate table lookups (1 PBS each).  This module makes that op set a
first-class abstraction (DESIGN.md §9): a :class:`Lane` exposes exactly
those operations, and the nn layers / attention mechanisms / model
forward are written once against it.

Three lanes implement the protocol:

  * :class:`FloatLane`   — jnp float32.  Literal shifts divide exactly and
    LUT sites apply their *real-valued* counterpart (``float_fn``), so this
    lane is the continuous reference the integer lanes approximate; run on
    PTQ'd integer weights it differs from the int lane only by activation
    rounding.
  * :class:`IntLane`     — jnp int32.  LUTs are materialized tables
    (gathers) built by the same numpy table functions the FHE lane uses,
    so its results are bit-exact with ``fhe_sim``.
  * :class:`FheSimLane`  — numpy int64 over a shared
    :class:`~repro.fhe.tfhe_sim.FheContext`: identical integer arithmetic
    plus per-op cost accounting (PBS / cmul / add / lit-mul and the
    message-width high-water marks parameter selection keys on).
    ``lane.scope(name)`` attributes costs per layer.

Domain convention: every generic LUT declares its input domain
``[lo, hi]`` and *saturates* into it — that is the declared quantized
activation range (the clamp every integer deployment applies), and the
bit-width recorded at the PBS is the width of the saturated input, i.e.
what the table must cover.  Out-of-range pressure is still visible:
the op that *produced* the value observed its raw width in
``max_bits_any``.

Ciphertext×ciphertext multiplication (:meth:`Lane.mul` and the two
contraction helpers) exists on every lane — the dot-product baseline
needs it — but the inhibitor family never calls it, which is exactly the
zero-``cmuls`` line in the full-block cost report.
"""

from __future__ import annotations

import contextlib
from typing import Any, Callable, Optional, Sequence

import numpy as np

Handle = Any   # lane-private tensor handle (jnp array or np.int64 array)


def _np_int(x) -> np.ndarray:
    return np.asarray(x, dtype=np.int64)


class Lane:
    """Protocol + shared derived ops.  Concrete lanes implement the
    primitive set; everything else (``lut2``) is written once here."""

    name: str = "?"
    is_float: bool = False
    #: FHE cost context (None on plaintext lanes)
    ctx = None

    # ---- ingest / export -------------------------------------------------
    def array(self, x) -> Handle:
        raise NotImplementedError

    def embed(self, table: np.ndarray, tokens) -> Handle:
        """Client-side embedding ingest: cleartext table row gather on
        cleartext token ids, then :meth:`array`.  Concrete lanes index the
        table directly; the static-analysis lane overrides this with
        per-channel vocabulary bounds so its verdicts hold for *any* token
        sequence of the given shape (token values are never read there)."""
        rows = np.asarray(table)[np.asarray(tokens)]
        return self.array(rows)

    def to_numpy(self, t: Handle) -> np.ndarray:
        raise NotImplementedError

    def shape(self, t: Handle):
        return t.shape

    # ---- structure (free: wire relabeling, no homomorphic work) ----------
    def reshape(self, t: Handle, shape) -> Handle:
        return t.reshape(shape)

    def transpose(self, t: Handle, axes) -> Handle:
        return t.transpose(axes)

    def expand_dims(self, t: Handle, axis: int) -> Handle:
        raise NotImplementedError

    def repeat(self, t: Handle, rep: int, axis: int) -> Handle:
        raise NotImplementedError

    # ---- levelled ops ----------------------------------------------------
    def add(self, a: Handle, b) -> Handle:
        raise NotImplementedError

    def sub(self, a: Handle, b) -> Handle:
        raise NotImplementedError

    def neg(self, t: Handle) -> Handle:
        raise NotImplementedError

    def mul_literal(self, t: Handle, c) -> Handle:
        """Multiply by a cleartext integer scalar/array (levelled)."""
        raise NotImplementedError

    def shift_right(self, t: Handle, k: int) -> Handle:
        """Arithmetic shift by a static amount (divide by 2^k)."""
        raise NotImplementedError

    def matmul_plain(self, t: Handle, w: np.ndarray) -> Handle:
        """(..., d_in) × cleartext (d_in, d_out) — the levelled
        plaintext-weight matmul every projection/MLP/logit layer uses
        (weights stay cleartext; activations are the ciphertext)."""
        raise NotImplementedError

    def sum(self, t: Handle, axis, keepdims: bool = False) -> Handle:
        raise NotImplementedError

    def select(self, mask: np.ndarray, t: Handle, fill: int) -> Handle:
        """Cleartext-mask select: keep ``t`` where mask, else the literal
        ``fill`` (one literal multiply per element)."""
        raise NotImplementedError

    def clip(self, t: Handle, lo: int, hi: int) -> Handle:
        """Declared-range saturation (the quantized activation clamp);
        free — it is absorbed into the next table's domain."""
        raise NotImplementedError

    # ---- PBS ops ---------------------------------------------------------
    def relu(self, t: Handle) -> Handle:
        raise NotImplementedError

    def abs(self, t: Handle) -> Handle:
        raise NotImplementedError

    def max(self, t: Handle, axis: int, keepdims: bool = False) -> Handle:
        """Row max via the relu-tree (``max(a,b) = b + relu(a−b)``):
        ~1 PBS per element on the FHE lane."""
        raise NotImplementedError

    def masked_max(self, t: Handle, mask, axis: int,
                   keepdims: bool = False) -> Handle:
        """Row max over the *attendable* subset only.  The mask is public
        structure, so the relu-tree simply runs over the attendable wires
        — no −inf sentinel widening the message space, and a dominant
        masked score can never poison the max (fixed-point softmax is not
        shift-invariant past the exp window).  Fully masked rows return
        the ``_MASKED_ROW`` sentinel; their probabilities are zeroed by
        the later mask select regardless."""
        raise NotImplementedError

    def lut(self, t: Handle, fn: Callable[[np.ndarray], np.ndarray],
            lo: int, hi: int, *,
            float_fn: Optional[Callable] = None,
            int_fn: Optional[Callable] = None) -> Handle:
        """Univariate table lookup over the saturated domain [lo, hi].
        ``fn`` maps int64 numpy → int64 numpy and defines the table on
        both integer lanes (bit-exact); ``float_fn`` is the real-valued
        counterpart the float lane applies instead.  ``int_fn``, when
        given, is a jnp-native expression bit-identical to ``fn`` — the
        int lane evaluates it directly instead of materializing the
        table (large domains, e.g. the reciprocal over row sums, would
        otherwise bake multi-MB gather constants into the jaxpr)."""
        raise NotImplementedError

    # ---- ciphertext×ciphertext (dot-product baseline only) ---------------
    def mul(self, a: Handle, b: Handle) -> Handle:
        raise NotImplementedError

    def dot_scores(self, q: Handle, k: Handle) -> Handle:
        """(..., n_q, d) × (..., n_k, d) → (..., n_q, n_k) cipher–cipher
        contraction (QKᵀ)."""
        raise NotImplementedError

    def mix_values(self, p: Handle, v: Handle) -> Handle:
        """(..., n_q, n_k) × (..., n_k, d) → (..., n_q, d) cipher–cipher
        contraction (S·V)."""
        raise NotImplementedError

    # ---- cost attribution ------------------------------------------------
    @contextlib.contextmanager
    def scope(self, name: str):
        """Per-layer cost attribution (no-op on plaintext lanes)."""
        yield self

    # ---- derived ops (lane-generic) --------------------------------------
    def lut2(self, x: Handle, y: Handle,
             fn: Callable[[np.ndarray, np.ndarray], np.ndarray],
             *, x_lo: int, x_hi: int, y_lo: int, y_hi: int,
             float_fn: Optional[Callable] = None) -> Handle:
        """Bivariate LUT via operand packing — the standard TFHE trick for
        small-operand binary functions: pack ``p = (x−x_lo) + (y−y_lo)·W``
        with levelled ops, then one univariate PBS whose message width is
        the *packed* width (this widening is what parameter selection must
        see).  Both operands saturate to their declared domains.  On the
        float lane the real-valued ``float_fn(x, y)`` applies directly
        (to the same saturated operands)."""
        if self.is_float:
            return float_fn(self.clip(x, x_lo, x_hi),
                            self.clip(y, y_lo, y_hi))
        span = x_hi - x_lo + 1
        xc = self.clip(x, x_lo, x_hi)
        yc = self.clip(y, y_lo, y_hi)
        packed = self.add(self.mul_literal(yc, span), xc)
        base = y_lo * span + x_lo

        def packed_fn(p):
            pp = p - base
            xx = pp % span + x_lo
            yy = pp // span + y_lo
            return fn(xx, yy)

        return self.lut(packed, packed_fn,
                        y_lo * span + x_lo, y_hi * span + x_hi)


#: fill for rows with no attendable key: below every score representable
#: in the supported int32 regime (|Σq·k| < 2^30 — wider inputs overflow
#: the lane itself first), while s − fill ≤ 2^30 + 2^30 still fits int32
_MASKED_ROW = -(1 << 30)


def reciprocal_literal(n_max: int, count=None, base_bits: int = 8):
    """``1/count`` as a cleartext fixed-point literal with ~``base_bits``
    significant bits for ANY count up to ``n_max`` (a fixed-width
    numerator truncates to zero past ``2^base_bits``).  Returns
    ``(literal, fraction_bits)``; apply as ``(x · literal) >> fraction``.
    Shared by the key-count normalization and the norm-surrogate means."""
    f = base_bits + max(int(n_max) - 1, 1).bit_length()
    if count is None:
        return (1 << f) // max(int(n_max), 1), f
    return (1 << f) // count, f


# ---------------------------------------------------------------------------
# Plaintext jnp lanes
# ---------------------------------------------------------------------------

class _JnpLane(Lane):
    """Shared jnp structure/levelled ops for the float and int lanes."""

    def to_numpy(self, t):
        import jax

        return np.asarray(jax.device_get(t))

    def expand_dims(self, t, axis):
        import jax.numpy as jnp

        return jnp.expand_dims(t, axis)

    def repeat(self, t, rep, axis):
        import jax.numpy as jnp

        return jnp.repeat(t, rep, axis=axis)

    def transpose(self, t, axes):
        import jax.numpy as jnp

        return jnp.transpose(t, axes)

    def reshape(self, t, shape):
        import jax.numpy as jnp

        return jnp.reshape(t, shape)

    def sum(self, t, axis, keepdims=False):
        import jax.numpy as jnp

        return jnp.sum(t, axis=axis, keepdims=keepdims)

    def max(self, t, axis, keepdims=False):
        import jax.numpy as jnp

        return jnp.max(t, axis=axis, keepdims=keepdims)

    def masked_max(self, t, mask, axis, keepdims=False):
        import jax.numpy as jnp

        fill = _MASKED_ROW if not self.is_float else float(_MASKED_ROW)
        return jnp.max(jnp.where(mask, t, fill), axis=axis,
                       keepdims=keepdims)

    def clip(self, t, lo, hi):
        import jax.numpy as jnp

        return jnp.clip(t, lo, hi)

    def neg(self, t):
        return -t

    def mul(self, a, b):
        return a * b

    def dot_scores(self, q, k):
        import jax.numpy as jnp

        return jnp.einsum("...qd,...kd->...qk", q, k)

    def mix_values(self, p, v):
        import jax.numpy as jnp

        return jnp.einsum("...qk,...kd->...qd", p, v)


class FloatLane(_JnpLane):
    """jnp float32 — the continuous reference the integer lanes chase."""

    name = "float"
    is_float = True

    def array(self, x):
        import jax.numpy as jnp

        return jnp.asarray(x, jnp.float32)

    def add(self, a, b):
        if isinstance(b, (int, float, np.integer, np.ndarray)):
            b = self.array(b)
        return a + b

    def sub(self, a, b):
        if isinstance(b, (int, float, np.integer, np.ndarray)):
            b = self.array(b)
        return a - b

    def mul_literal(self, t, c):
        return t * self.array(c)

    def shift_right(self, t, k):
        return t / float(1 << k)            # exact divide — no rounding

    def matmul_plain(self, t, w):
        import jax.numpy as jnp

        return jnp.einsum("...i,io->...o", t, self.array(w))

    def select(self, mask, t, fill):
        import jax.numpy as jnp

        # mask may be a traced jnp bool (registry backends run under jit)
        return jnp.where(mask, t, float(fill))

    def relu(self, t):
        import jax.numpy as jnp

        return jnp.maximum(t, 0.0)

    def abs(self, t):
        import jax.numpy as jnp

        return jnp.abs(t)

    def lut(self, t, fn, lo, hi, *, float_fn=None, int_fn=None):
        if float_fn is None:
            raise ValueError("float lane needs the real-valued counterpart "
                             "(float_fn) of this table")
        return float_fn(self.clip(t, lo, hi))


class IntLane(_JnpLane):
    """jnp int32 — the paper's plaintext integer scaling arm.

    Every nonlinearity is a materialized table built by the *same* numpy
    table function the FHE lane applies, so int-lane results are bit-exact
    with the TFHE simulator.  Callers own the range discipline: int32
    arithmetic with the documented shift/clip points keeps every
    intermediate far below 2³¹ for the supported (≤16-bit message) regime.
    """

    name = "int"

    def array(self, x):
        import jax.numpy as jnp

        return jnp.asarray(x, jnp.int32)

    def add(self, a, b):
        if isinstance(b, (int, np.integer, np.ndarray)):
            b = self.array(b)
        return a + b

    def sub(self, a, b):
        if isinstance(b, (int, np.integer, np.ndarray)):
            b = self.array(b)
        return a - b

    def mul_literal(self, t, c):
        return t * self.array(c)

    def shift_right(self, t, k):
        import jax

        return jax.lax.shift_right_arithmetic(t, jnp_int32(k))

    def matmul_plain(self, t, w):
        import jax.numpy as jnp

        return jnp.einsum("...i,io->...o", t, self.array(w))

    def select(self, mask, t, fill):
        import jax.numpy as jnp

        # mask may be a traced jnp bool (registry backends run under jit)
        return jnp.where(mask, t, jnp.int32(fill))

    def relu(self, t):
        import jax.numpy as jnp

        return jnp.maximum(t, 0)

    def abs(self, t):
        import jax.numpy as jnp

        return jnp.abs(t)

    def lut(self, t, fn, lo, hi, *, float_fn=None, int_fn=None):
        import jax.numpy as jnp

        if int_fn is not None:
            return int_fn(jnp.clip(t, lo, hi))
        table = jnp.asarray(
            np.asarray(fn(np.arange(lo, hi + 1, dtype=np.int64)),
                       dtype=np.int64).astype(np.int32))
        idx = jnp.clip(t, lo, hi) - lo
        return jnp.take(table, idx, axis=0)


def jnp_int32(k: int):
    import jax.numpy as jnp

    return jnp.int32(k)


# ---------------------------------------------------------------------------
# TFHE-simulated lane
# ---------------------------------------------------------------------------

class FheSimLane(Lane):
    """numpy int64 arithmetic + TFHE cost accounting on a shared context.

    Handles are plain ``np.int64`` arrays ("ciphertexts"); the lane owns
    the :class:`FheContext` so costs from every layer accumulate in one
    place and :meth:`scope` attributes them per layer.
    """

    name = "fhe_sim"

    def __init__(self, ctx=None):
        from repro.fhe.tfhe_sim import FheContext

        self.ctx = ctx if ctx is not None else FheContext()

    # ---- ingest / export ----
    def array(self, x):
        return _np_int(x)                   # encryption itself is free

    def to_numpy(self, t):
        return np.asarray(t).copy()         # decryption

    # ---- structure ----
    def expand_dims(self, t, axis):
        return np.expand_dims(t, axis)

    def repeat(self, t, rep, axis):
        return np.repeat(t, rep, axis=axis)

    def transpose(self, t, axes):
        return np.transpose(t, axes)

    def reshape(self, t, shape):
        return np.reshape(t, shape)

    # ---- levelled ----
    def add(self, a, b):
        out = a + _np_int(b)
        self.ctx.count_add(out)
        return out

    def sub(self, a, b):
        out = a - _np_int(b)
        self.ctx.count_add(out)
        return out

    def neg(self, t):
        return -t

    def mul_literal(self, t, c):
        out = t * _np_int(c)
        self.ctx.count_lit_mul(out)
        return out

    def shift_right(self, t, k):
        out = t >> k
        self.ctx.count_lit_mul(out)
        return out

    def matmul_plain(self, t, w):
        w = _np_int(w)
        out = t @ w
        n_vec = int(np.prod(t.shape[:-1], dtype=np.int64))
        d_in, d_out = w.shape
        self.ctx.count_lit_mul(out, n=n_vec * d_in * d_out)
        self.ctx.count_add(out, n=n_vec * max(d_in - 1, 0) * d_out)
        return out

    def sum(self, t, axis, keepdims=False):
        out = t.sum(axis=axis, keepdims=keepdims)
        self.ctx.count_add(out, n=max(int(t.size - out.size), 0))
        return out

    def select(self, mask, t, fill):
        m = np.asarray(mask, bool)
        out = np.where(m, t, np.int64(fill))
        self.ctx.count_lit_mul(out)
        return out

    def clip(self, t, lo, hi):
        return np.clip(t, lo, hi)

    # ---- PBS ----
    def relu(self, t):
        self.ctx.count_pbs(t)
        return np.maximum(t, 0)

    def abs(self, t):
        self.ctx.count_pbs(t)
        return np.abs(t)

    def max(self, t, axis, keepdims=False):
        # relu-tree: max(a, b) = b + relu(a − b) — ~1 PBS per element
        self.ctx.count_pbs(t)
        return t.max(axis=axis, keepdims=keepdims)

    def masked_max(self, t, mask, axis, keepdims=False):
        m = np.broadcast_to(np.asarray(mask, bool), t.shape)
        # the relu-tree runs over attendable wires only: PBS count and
        # width observation cover just those elements
        self.ctx._bump("pbs", int(m.sum()))
        self.ctx._observe(np.where(m, t, 0), at_pbs=True)
        return np.where(m, t, np.int64(_MASKED_ROW)).max(
            axis=axis, keepdims=keepdims)

    def lut(self, t, fn, lo, hi, *, float_fn=None, int_fn=None):
        vals = np.clip(t, lo, hi)
        self.ctx.count_pbs(vals)
        return _np_int(fn(vals))

    # ---- ciphertext×ciphertext ----
    def mul(self, a, b):
        s = a + b
        d = a - b
        self.ctx.count_cmul(s, d)
        out = (s * s - d * d) // 4
        self.ctx._observe(out, at_pbs=False)
        return out

    def dot_scores(self, q, k):
        qe = q[..., :, None, :]
        ke = k[..., None, :, :]
        prod = self.mul(np.broadcast_to(qe, np.broadcast_shapes(
            qe.shape, ke.shape)).copy(), np.broadcast_to(
                ke, np.broadcast_shapes(qe.shape, ke.shape)).copy())
        return self.sum(prod, axis=-1)

    def mix_values(self, p, v):
        pe = p[..., :, :, None]
        ve = v[..., None, :, :]
        shp = np.broadcast_shapes(pe.shape, ve.shape)
        prod = self.mul(np.broadcast_to(pe, shp).copy(),
                        np.broadcast_to(ve, shp).copy())
        return self.sum(prod, axis=-2)

    # ---- cost attribution ----
    @contextlib.contextmanager
    def scope(self, name: str):
        with self.ctx.scope(name):
            yield self


_LANES = {"float": FloatLane, "int": IntLane, "fhe_sim": FheSimLane}

#: lanes whose constructor accepts a shared FheContext
_CTX_LANES = frozenset({"fhe_sim", "interval"})


def get_lane(name: str, ctx=None) -> Lane:
    """Lane factory: ``float`` | ``int`` | ``fhe_sim`` | ``interval``
    (the context-carrying lanes accept a shared :class:`FheContext` for
    cross-layer cost accumulation)."""
    if name == "interval" and "interval" not in _LANES:
        # lazy: repro.analysis imports this module at package init
        from repro.analysis.interval_lane import IntervalLane
        _LANES["interval"] = IntervalLane
    try:
        cls = _LANES[name]
    except KeyError:
        raise ValueError(f"unknown lane {name!r}; known: "
                         f"{sorted(set(_LANES) | {'interval'})}") from None
    return cls(ctx) if name in _CTX_LANES else cls()


def available_lanes() -> Sequence[str]:
    return tuple(sorted(_LANES))
