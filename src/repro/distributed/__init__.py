"""Distribution layer: sharding rules, collectives, pipeline, fault tolerance."""

from repro.distributed.sharding import (  # noqa: F401
    ACT_RULES,
    PARAM_RULES,
    batch_spec,
    constrain,
    current_mesh,
    param_sharding,
    param_spec,
    use_mesh,
)
