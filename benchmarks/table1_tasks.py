"""Paper Table 1: task-quality parity, Inhibitor vs dot-product attention.

Trains small single-block transformers (the paper's protocol: simple
set-ups, no hyper-parameter tuning) on the paper's task suite — the exact
adding problem plus offline surrogates for MNIST/IMDB (repro.data.synthetic
documents the correspondence) — with the attention mechanism as the only
varied factor.

Paper claim: per-task scores differ insignificantly between mechanisms.
We report both mechanisms' metrics and the gap.
"""

from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.attention import AttentionConfig, apply_attention, init_attention
from repro.data import adding_problem, digits, sentiment
from repro.nn import KeyGen, unbox
from repro.nn.embedding import init_embedding, apply_embedding
from repro.nn.linear import apply_dense, init_dense
from repro.nn.mlp import apply_mlp, init_mlp
from repro.nn.norm import apply_layernorm, init_layernorm
from repro.optim import AdamWConfig, adamw_update, init_adamw

D_MODEL = 64
STEPS = 150
BATCH = 32


def _attn_cfg(kind: str) -> AttentionConfig:
    return AttentionConfig(kind=kind, num_heads=4, num_kv_heads=4,
                           head_dim=D_MODEL // 4, use_rope=False,
                           causal=False, score_shift=0.5)


def _init_block(key, kind):
    kg = KeyGen(key)
    return {
        "ln1": init_layernorm(D_MODEL),
        "attn": init_attention(kg("attn"), _attn_cfg(kind), D_MODEL),
        "ln2": init_layernorm(D_MODEL),
        "ffn": init_mlp(kg("ffn"), D_MODEL, 2 * D_MODEL, use_bias=True),
    }


def _apply_block(p, kind, x):
    h, _ = apply_attention(p["attn"], _attn_cfg(kind),
                           apply_layernorm(p["ln1"], x))
    x = x + h
    x = x + apply_mlp(p["ffn"], apply_layernorm(p["ln2"], x),
                      activation="relu")
    return x


def _train(init_fn, loss_fn, data_fn, steps=None, lr=3e-3, seed=0):
    steps = STEPS if steps is None else steps
    params = unbox(init_fn(jax.random.PRNGKey(seed)))
    opt_cfg = AdamWConfig(lr=lr, weight_decay=0.0)
    opt = init_adamw(params, opt_cfg)

    @jax.jit
    def step_fn(params, opt, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt, _ = adamw_update(grads, opt, params, opt_cfg)
        return params, opt, loss

    for s in range(steps):
        batch = data_fn(seed * 10_000 + s)
        params, opt, loss = step_fn(params, opt, batch)
    return params


# ---- adding problem (regression; paper metric: MSE) ----

def bench_adding(kind: str, length=50, seed=0, steps=None):
    def init_fn(key):
        kg = KeyGen(key)
        return {
            "embed": init_dense(kg("e"), (2,), (D_MODEL,), (None,),
                                ("embed",), use_bias=True),
            "block": _init_block(kg("b"), kind),
            "head": init_dense(kg("h"), (D_MODEL,), (1,), ("embed",),
                               (None,), use_bias=True),
        }

    def forward(p, x):
        h = apply_dense(p["embed"], x, 1)
        h = _apply_block(p["block"], kind, h)
        return apply_dense(p["head"], jnp.mean(h, axis=1), 1)

    def loss_fn(p, batch):
        x, y = batch
        return jnp.mean(jnp.square(forward(p, x) - y))

    def data_fn(s):
        x, y = adding_problem(BATCH, length, s)
        return jnp.asarray(x), jnp.asarray(y)

    params = _train(init_fn, loss_fn, data_fn, steps=steps, seed=seed)
    x, y = adding_problem(512, length, 123_456 + seed)
    pred = forward(params, jnp.asarray(x))
    return float(jnp.mean(jnp.square(pred - jnp.asarray(y))))


# ---- digits (10-class; paper metric: accuracy) ----

def bench_digits(kind: str, res=16, seed=0, steps=None):
    def init_fn(key):
        kg = KeyGen(key)
        return {
            "embed": init_dense(kg("e"), (res,), (D_MODEL,), (None,),
                                ("embed",), use_bias=True),
            "block": _init_block(kg("b"), kind),
            "head": init_dense(kg("h"), (D_MODEL,), (10,), ("embed",),
                               (None,), use_bias=True),
        }

    def forward(p, x):
        h = apply_dense(p["embed"], x, 1)          # rows as tokens
        h = _apply_block(p["block"], kind, h)
        return apply_dense(p["head"], jnp.mean(h, axis=1), 1)

    def loss_fn(p, batch):
        x, y = batch
        logits = forward(p, x)
        return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(y.shape[0]),
                                                    y])

    def data_fn(s):
        x, y = digits(BATCH, s, res=res)
        return jnp.asarray(x), jnp.asarray(y)

    params = _train(init_fn, loss_fn, data_fn, steps=steps, seed=seed)
    x, y = digits(1024, 777_777 + seed, res=res)
    pred = jnp.argmax(forward(params, jnp.asarray(x)), axis=-1)
    return float(jnp.mean((pred == jnp.asarray(y)).astype(jnp.float32)))


# ---- sentiment (binary; paper metric: accuracy) ----

def bench_sentiment(kind: str, length=64, vocab=512, seed=0, steps=None):
    def init_fn(key):
        kg = KeyGen(key)
        return {
            "embed": init_embedding(kg("e"), vocab, D_MODEL),
            "block": _init_block(kg("b"), kind),
            "head": init_dense(kg("h"), (D_MODEL,), (2,), ("embed",),
                               (None,), use_bias=True),
        }

    def forward(p, toks):
        h = apply_embedding(p["embed"], toks)
        h = _apply_block(p["block"], kind, h)
        return apply_dense(p["head"], jnp.mean(h, axis=1), 1)

    def loss_fn(p, batch):
        toks, y = batch
        logits = forward(p, toks)
        return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(y.shape[0]),
                                                    y])

    def data_fn(s):
        t, y = sentiment(BATCH, s, length=length, vocab=vocab)
        return jnp.asarray(t), jnp.asarray(y)

    params = _train(init_fn, loss_fn, data_fn, steps=steps, seed=seed)
    t, y = sentiment(1024, 555_555 + seed, length=length, vocab=vocab)
    pred = jnp.argmax(forward(params, jnp.asarray(t)), axis=-1)
    return float(jnp.mean((pred == jnp.asarray(y)).astype(jnp.float32)))


def run(smoke: bool = False) -> list:
    """Returns CSV rows (name, us_per_call, derived).

    Mechanisms are enumerated from the registry, so a newly registered
    fourth mechanism shows up in the parity table without touching this
    driver.  ``smoke``: one task, two mechanisms, few steps (CI).
    """
    from repro.core.mechanism import available_mechanisms

    tasks = (("adding", bench_adding, "mse"),
             ("digits", bench_digits, "acc"),
             ("sentiment", bench_sentiment, "acc"))
    kinds = available_mechanisms()
    steps = STEPS
    if smoke:
        tasks = tasks[:1]
        kinds = ("dotprod", "inhibitor")
        steps = 5
    rows = []
    for task, fn, metric in tasks:
        scores = {}
        for kind in kinds:
            t0 = time.perf_counter()
            scores[kind] = fn(kind, steps=steps)
            dt = (time.perf_counter() - t0) * 1e6 / steps
            rows.append((f"table1/{task}/{kind}", round(dt, 1),
                         f"{metric}={scores[kind]:.4f}"))
        for kind in kinds:
            if kind == "dotprod":
                continue
            gap = scores[kind] - scores["dotprod"]
            rows.append((f"table1/{task}/gap_{kind}", 0.0,
                         f"{kind}-dotprod={gap:+.4f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
