"""Pure-JAX neural-net substrate (no flax/haiku dependency)."""

from repro.nn.module import (  # noqa: F401
    KeyGen,
    Param,
    axes_of,
    box_like,
    cast_params,
    fold_key,
    is_param,
    param_bytes,
    param_count,
    tree_map_params,
    unbox,
)
