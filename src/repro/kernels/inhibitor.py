"""Flash-Inhibitor: blockwise-streaming Pallas TPU kernel for the paper's
attention mechanism (eq. 5 + eq. 10/9 fused forms).

TPU adaptation (DESIGN.md §2): the paper's eq. 9 decomposition
``H = ½·Σ V − ½·Σ Z + ½·Σ |V − Z|`` accumulates term-by-term over key/value
blocks, so the n×n score matrix never exists in HBM.  Because inhibition is
a plain sum (no Softmax normalizer) the blockwise accumulation is *exact* —
no running max/denominator rescaling passes, which Softmax flash attention
must do on the VPU.

Memory hierarchy:
  * Q block (group, block_q, d), K/V blocks (block_k, d) staged in VMEM by
    BlockSpec; output accumulator + key-count live in VMEM scratch across
    the sequential kv-block grid dimension.
  * The Manhattan/inhibition cross terms need (rows × keys × d) cubes;
    these are tiled over ``sub_k``-sized key slices inside the kernel so the
    live cube is (group, block_q, sub_k, d) — VMEM-bounded regardless of
    block_k.
  * GQA: the grid is over (batch × kv_heads); all ``group = heads/kv_heads``
    query heads sharing one KV head are processed together against a single
    staged K/V block (KV HBM traffic is paid once per group, not per head).

Masking (causal / sliding window / padded tail) is computed from block
indices with ``broadcasted_iota`` — no mask tensors in HBM.  Masked pairs
are excluded from the sums by multiplication (exact-zero contribution; see
core.inhibitor for why additive large-constant masking is unstable in the
fused form).

Validated in ``interpret=True`` mode against :mod:`repro.kernels.ref`
(tests/test_kernel_inhibitor.py sweeps shapes/dtypes/window/shift).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 64
DEFAULT_BLOCK_K = 128
DEFAULT_SUB_K = 16

#: Native-lowering platforms (see kernels.paged.LOWERS_ON for the
#: contract).  ``launch_prefill_kernel`` allocates ``pltpu.VMEM``
#: scratch accumulators and the cursor path uses
#: ``pltpu.PrefetchScalarGridSpec`` — both TPU/Mosaic-only, so GPU runs
#: would be interpret-mode; a Triton launch branch (register
#: accumulators instead of VMEM scratch, cursors as plain operands)
#: would extend this declaration.
LOWERS_ON = ("tpu",)


def pack_cursors(batch: int, q_offset, kv_valid_len, n_k: int) -> jax.Array:
    """Pack per-row decode cursors into the (2, batch) int32 scalar-prefetch
    operand: row 0 = query offsets, row 1 = KV valid lengths.  Scalars (a
    shared cursor) broadcast; ``None`` means offset 0 / whole buffer."""
    off = jnp.asarray(q_offset if q_offset is not None else 0, jnp.int32)
    val = jnp.asarray(kv_valid_len if kv_valid_len is not None else n_k,
                      jnp.int32)
    off = jnp.broadcast_to(jnp.atleast_1d(off), (batch,))
    val = jnp.broadcast_to(jnp.atleast_1d(val), (batch,))
    return jnp.stack([off, val])


def launch_prefill_kernel(kernel, qg, kg, vg, *, grid, group, block_q,
                          block_k, d, out_shape, scratch_shapes, interpret,
                          cursors=None):
    """Shared launcher for the prefill-layout kernels (flash inhibitor and
    flash attention use identical grids/BlockSpecs).  ``cursors`` selects
    the scalar-prefetch (decode-cache) launch; the plain launch keeps the
    static-skip training path untouched."""
    if cursors is not None:
        qmap = lambda b, i, j, cur: (b, 0, i, 0)     # noqa: E731
        kvmap = lambda b, i, j, cur: (b, j, 0)       # noqa: E731
    else:
        qmap = lambda b, i, j: (b, 0, i, 0)          # noqa: E731
        kvmap = lambda b, i, j: (b, j, 0)            # noqa: E731
    q_spec = pl.BlockSpec((1, group, block_q, d), qmap)
    in_specs = [q_spec, pl.BlockSpec((1, block_k, d), kvmap),
                pl.BlockSpec((1, block_k, d), kvmap)]
    if cursors is not None:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=grid, in_specs=in_specs,
            out_specs=q_spec, scratch_shapes=scratch_shapes)
        return pl.pallas_call(kernel, grid_spec=grid_spec,
                              out_shape=out_shape,
                              interpret=interpret)(cursors, qg, kg, vg)
    return pl.pallas_call(kernel, grid=grid, in_specs=in_specs,
                          out_specs=q_spec, out_shape=out_shape,
                          scratch_shapes=scratch_shapes,
                          interpret=interpret)(qg, kg, vg)


def _flash_inhibitor_kernel(
    # refs: [cursors_ref,] q_ref, k_ref, v_ref, o_ref, acc_ref, cnt_ref
    *refs,
    score_scale: float,
    score_shift: float,
    signed: bool,
    normalize: bool,
    causal: bool,
    window: Optional[int],
    kv_len: int,
    kv_heads: int,
    block_q: int,
    block_k: int,
    sub_k: int,
    n_kv_blocks: int,
    cached: bool,
):
    if cached:
        cur_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, cnt_ref = refs
    else:
        cur_ref = None
        q_ref, k_ref, v_ref, o_ref, acc_ref, cnt_ref = refs
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    q = q_ref[0].astype(jnp.float32)          # (group, block_q, d)
    group, bq, d = q.shape

    if cur_ref is not None:
        # per-row decode cursors (scalar-prefetched): queries start at
        # q_offset and only the first kv_valid rows of the buffer are live
        row = pl.program_id(0) // kv_heads
        q_off = cur_ref[0, row]
        kv_valid = jnp.minimum(kv_len, cur_ref[1, row])
    else:
        q_off = 0
        kv_valid = kv_len
    q_pos = (q_off + iq * block_q
             + jax.lax.broadcasted_iota(jnp.int32, (bq, sub_k), 0))

    def process_sub(s, carry):
        acc, cnt = carry
        ks = k_ref[0, pl.ds(s * sub_k, sub_k), :].astype(jnp.float32)
        vs = v_ref[0, pl.ds(s * sub_k, sub_k), :].astype(jnp.float32)

        # ---- scores: Z = relu(Σ_d |q − k| / γ − α)  (eq. 5 + shift) ----
        diff = jnp.abs(q[:, :, None, :] - ks[None, None, :, :])
        z = jnp.sum(diff, axis=-1) * (1.0 / score_scale)   # (g, bq, sub_k)
        if score_shift:
            z = jnp.maximum(z - score_shift, 0.0)

        # ---- block mask from positions (True = attend) ----
        k_pos = (ik * block_k + s * sub_k
                 + jax.lax.broadcasted_iota(jnp.int32, (bq, sub_k), 1))
        m = k_pos < kv_valid
        if causal:
            m = m & (k_pos <= q_pos)
        if window is not None:
            # a sliding window implies causality (matches _build_mask,
            # blocked._chunk_mask and core.inhibitor.sliding_window_mask)
            m = m & (k_pos > q_pos - window) & (k_pos <= q_pos)
        mf = m.astype(jnp.float32)                          # (bq, sub_k)

        # ---- inhibition (masked fused forms, eq. 9 / eq. 10) ----
        col_v = jnp.einsum("qs,sd->qd", mf, vs)             # (bq, d)
        if signed:
            vp = jnp.maximum(vs, 0.0)
            vn = vs - vp
            t_pos = jnp.sum(jnp.abs(vp[None, None, :, :] - z[..., None])
                            * mf[None, :, :, None], axis=2)
            t_neg = jnp.sum(jnp.abs(-vn[None, None, :, :] - z[..., None])
                            * mf[None, :, :, None], axis=2)
            part = 0.5 * (col_v[None] + t_pos - t_neg)      # (g, bq, d)
        else:
            row_z = jnp.sum(z * mf[None], axis=-1)          # (g, bq)
            cross = jnp.sum(jnp.abs(vs[None, None, :, :] - z[..., None])
                            * mf[None, :, :, None], axis=2)
            part = 0.5 * (col_v[None] - row_z[..., None] + cross)

        acc = acc + part
        cnt = cnt + jnp.sum(mf, axis=-1)                    # (bq,)
        return acc, cnt

    acc = acc_ref[...]
    cnt = cnt_ref[..., 0]
    n_sub = block_k // sub_k

    first_k = ik * block_k
    if causal or window is not None:
        # skip fully-masked blocks (whole kv block strictly above diagonal;
        # a window implies causality, so the same skip applies)
        live = first_k <= q_off + iq * block_q + block_q - 1
    else:
        live = True
    if cur_ref is not None:
        # skip blocks wholly past the row's valid-length cursor
        live = jnp.logical_and(live, first_k < kv_valid)

    def do_block():
        return jax.lax.fori_loop(0, n_sub, process_sub, (acc, cnt))

    if isinstance(live, bool):
        acc, cnt = do_block()
    else:
        acc, cnt = jax.lax.cond(live, do_block, lambda: (acc, cnt))

    acc_ref[...] = acc
    cnt_ref[..., 0] = cnt

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        out = acc_ref[...]
        if normalize:
            out = out / jnp.maximum(cnt_ref[..., 0], 1.0)[None, :, None]
        o_ref[0] = out.astype(o_ref.dtype)


def flash_inhibitor_fwd(
    q: jax.Array,            # (batch, n_q, heads, d)
    k: jax.Array,            # (batch, n_k, kv_heads, d)
    v: jax.Array,            # (batch, n_k, kv_heads, d)
    *,
    score_scale: Optional[float] = None,
    score_shift: float = 0.5,
    signed: bool = True,
    normalize: bool = True,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    sub_k: int = DEFAULT_SUB_K,
    q_offset=None,
    kv_valid_len=None,
    interpret: bool = False,
) -> jax.Array:
    """Pallas flash-inhibitor forward pass. Returns (batch, n_q, heads, d).

    Sequences are padded to block multiples internally; the pad tail is
    excluded via the kv_len mask.  ``q_offset`` / ``kv_valid_len`` (int,
    scalar array, or per-row (b,) arrays) express decode-cache structure:
    queries sit at absolute positions ``q_offset + i`` and only the first
    ``kv_valid_len`` buffer rows are attendable — scalar-prefetched, so
    masks stay index-computed (no HBM mask array).
    """
    batch, n_q, heads, d = q.shape
    n_k, kv_heads = k.shape[1], k.shape[2]
    assert heads % kv_heads == 0
    group = heads // kv_heads
    scale = score_scale if score_scale is not None else math.sqrt(d)

    block_q = min(block_q, max(8, 1 << (n_q - 1).bit_length()))
    block_k = min(block_k, max(8, 1 << (n_k - 1).bit_length()))
    sub_k = min(sub_k, block_k)
    if block_k % sub_k:
        sub_k = math.gcd(block_k, sub_k)

    nq_pad = -n_q % block_q
    nk_pad = -n_k % block_k

    # (batch, kv_heads, group, n_q, d) — group-major so one KV stage serves
    # all query heads of its group
    qg = q.reshape(batch, n_q, kv_heads, group, d).transpose(0, 2, 3, 1, 4)
    qg = qg.reshape(batch * kv_heads, group, n_q, d)
    kg = k.transpose(0, 2, 1, 3).reshape(batch * kv_heads, n_k, d)
    vg = v.transpose(0, 2, 1, 3).reshape(batch * kv_heads, n_k, d)
    if nq_pad:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, nq_pad), (0, 0)))
    if nk_pad:
        kg = jnp.pad(kg, ((0, 0), (0, nk_pad), (0, 0)))
        vg = jnp.pad(vg, ((0, 0), (0, nk_pad), (0, 0)))

    n_q_blocks = (n_q + nq_pad) // block_q
    n_kv_blocks = (n_k + nk_pad) // block_k
    grid = (batch * kv_heads, n_q_blocks, n_kv_blocks)
    cached = q_offset is not None or kv_valid_len is not None

    kernel = functools.partial(
        _flash_inhibitor_kernel,
        score_scale=scale, score_shift=score_shift, signed=signed,
        normalize=normalize, causal=causal, window=window, kv_len=n_k,
        kv_heads=kv_heads, block_q=block_q, block_k=block_k, sub_k=sub_k,
        n_kv_blocks=n_kv_blocks, cached=cached,
    )

    out = launch_prefill_kernel(
        kernel, qg, kg, vg, grid=grid, group=group, block_q=block_q,
        block_k=block_k, d=d,
        out_shape=jax.ShapeDtypeStruct(
            (batch * kv_heads, group, n_q + nq_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
        cursors=(pack_cursors(batch, q_offset, kv_valid_len, n_k)
                 if cached else None))

    out = out[:, :, :n_q, :]
    out = out.reshape(batch, kv_heads, group, n_q, d).transpose(0, 3, 1, 2, 4)
    return out.reshape(batch, n_q, heads, d)
