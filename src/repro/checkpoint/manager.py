"""Async checkpoint manager: overlap saves with training, retention,
auto-resume — the fault-tolerance substrate (DESIGN.md §6).

The train loop calls ``maybe_save(step, tree_fn)`` every step; the manager
decides cadence, snapshots device arrays to host (blocking only for the
device->host copy), and runs the file write on a background thread so the
next step launches immediately.  ``wait()`` drains in-flight writes
(called before exit and before restore-after-failure).
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint import store


@dataclasses.dataclass
class CheckpointConfig:
    root: str
    every_steps: int = 100
    keep: int = 3
    async_save: bool = True


class CheckpointManager:
    def __init__(self, cfg: CheckpointConfig):
        self.cfg = cfg
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ---- save path ----
    def _write(self, step: int, host_tree, extra):
        try:
            store.save(self.cfg.root, step, host_tree, extra=extra)
            store.retain(self.cfg.root, self.cfg.keep)
        except BaseException as e:  # surfaced on next wait()
            self._error = e

    def save(self, step: int, tree: Any, *, extra: Optional[dict] = None):
        self.wait()
        # snapshot to host memory first — the device buffers may be donated
        # by the next step
        host_tree = jax.tree.map(np.asarray, tree)
        if self.cfg.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_tree, extra),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, host_tree, extra)

    def maybe_save(self, step: int, tree: Any, *,
                   extra: Optional[dict] = None) -> bool:
        if step % self.cfg.every_steps:
            return False
        self.save(step, tree, extra=extra)
        return True

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ---- restore path ----
    def latest_step(self) -> Optional[int]:
        return store.latest_step(self.cfg.root)

    def restore(self, tree_like: Any, *, shardings: Any = None):
        self.wait()
        return store.restore(self.cfg.root, tree_like, shardings=shardings)

    def restore_or_init(self, init_fn: Callable[[], Any], *,
                        shardings: Any = None):
        """Auto-resume: restore the latest committed checkpoint if one
        exists, else initialize fresh. Returns (tree, start_step)."""
        if self.latest_step() is None:
            return init_fn(), 0
        tree_like = jax.eval_shape(init_fn)
        tree, step = self.restore(tree_like, shardings=shardings)
        return tree, step
