"""Continuous batching: chunked prefill-decode interleaving
(DESIGN.md §15).

With ``EngineConfig.tick_budget`` set, prefill runs in chunk batches
scheduled *between* decode ticks via the scheduler's ``prefill_quota``
token-budget policy; a partially-prefilled admission is first-class
engine state (``Engine.admitting``).  Covers:

* greedy bit-parity: interleaved admission produces exactly the
  whole-prompt outputs (and the sequential oracle's);
* a long prompt no longer stalls in-flight decode — the victim stream
  gains tokens on every tick the long prompt spends admitting;
* lazy CoW: forks happen only for the chunk batch actually executed,
  never at staging;
* page-pool backpressure pauses a half-prefilled request in place (no
  leaked pages / device rows) and resumes it to the exact output;
* mid-prefill cancellation, finish-at-admission across ticks, deferring
  quota policies vs the stuck-engine guard, latency counters, and the
  greedy sampling-key skip.
"""

import numpy as np
import pytest


def _mk_engine(serve_model, **kw):
    from repro.serve.engine import Engine, EngineConfig

    cfg, api, params = serve_model
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_chunk", 8)
    return Engine(api, params, EngineConfig(**kw))


def _prompts(seed, lens):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, 127, n).astype(np.int32) for n in lens]


# ---------------------------------------------------------------------------
# parity: interleaved == whole-prompt == sequential oracle
# ---------------------------------------------------------------------------

def test_greedy_parity_chunked_vs_whole(serve_model, greedy_ref):
    from repro.serve.engine import Request

    prompts = _prompts(10, (3, 17, 40, 9))
    outs = {}
    for mode, budget in (("whole", None), ("interleaved", 12)):
        eng = _mk_engine(serve_model, tick_budget=budget)
        for i, p in enumerate(prompts):
            eng.submit(Request(i, p, max_new_tokens=8))
        outs[mode] = {r.request_id: r.output
                      for r in eng.run_to_completion()}
    assert outs["interleaved"] == outs["whole"]
    for i, p in enumerate(prompts):
        assert outs["whole"][i] == greedy_ref(p, 8), f"request {i}"


def test_interleaved_tick_budget_caps_prefill_per_tick(serve_model):
    """A 40-token prompt under tick_budget=8 takes several ticks to
    admit, reported via Engine.admitting / inflight_prefills."""
    from repro.serve.engine import Request

    eng = _mk_engine(serve_model, tick_budget=8)
    [p] = _prompts(11, (40,))
    eng.submit(Request(0, p, max_new_tokens=4))
    eng.step()
    assert len(eng.admitting) == 1           # staged, partially prefilled
    assert not eng.active
    part = next(iter(eng.admitting.values()))
    assert 0 < part.pos < 40
    ticks = 1
    while eng.admitting:
        eng.step()
        ticks += 1
    assert ticks > 1                          # admission really spanned ticks
    assert eng.stats()["inflight_prefills"] == 0


# ---------------------------------------------------------------------------
# the SLO property: long prompts don't stall in-flight streams
# ---------------------------------------------------------------------------

def test_long_prompt_does_not_stall_victim_decode(serve_model):
    """Deterministic (tick-counted, not timed): while the long prompt is
    mid-admission, the already-decoding victim gains one token per tick."""
    from repro.serve.engine import Request

    long_p, short_p = _prompts(12, (48, 4))
    eng = _mk_engine(serve_model, tick_budget=16)
    eng.submit(Request(0, short_p, max_new_tokens=40))
    eng.step()                                # victim admitted + decoding
    assert 0 in {s for s in eng.active}
    eng.submit(Request(1, long_p, max_new_tokens=4))
    victim = eng.active[list(eng.active)[0]]
    while True:
        before = len(victim.output)
        eng.step()
        if not eng.admitting:
            break
        # the long prompt is mid-prefill and the victim still decoded
        assert len(victim.output) == before + 1
    assert len(victim.output) > before


def test_whole_prompt_admission_stalls_victim_baseline(serve_model):
    """The contrast case the SLO gate measures: with tick_budget=None the
    long prompt admits in ONE tick (all chunks inside it) — the paper's
    'tail TTFT unbounded in prompt length' failure mode collapses into a
    single engine tick here, visible as a multi-chunk admission tick."""
    from repro.serve.engine import Request

    long_p, short_p = _prompts(13, (48, 4))
    eng = _mk_engine(serve_model)             # tick_budget=None
    eng.submit(Request(0, short_p, max_new_tokens=40))
    eng.step()
    chunks_before = eng.stats()["prefill_chunks"]
    eng.submit(Request(1, long_p, max_new_tokens=4))
    eng.step()
    assert not eng.admitting                  # admitted whole, same tick
    assert eng.stats()["prefill_chunks"] - chunks_before >= 6


# ---------------------------------------------------------------------------
# chunked prefill x prefix credit / CoW
# ---------------------------------------------------------------------------

def test_forks_only_below_executed_chunk(serve_model, greedy_ref):
    """Lazy CoW: staging a credit admission forks nothing; the fork
    lands on the tick the below-credit chunk actually executes."""
    from repro.serve.engine import Engine, EngineConfig, Request
    from repro.serve.scheduler import FIFOScheduler

    class Gate(FIFOScheduler):
        quota = None                          # test-controlled

        def prefill_quota(self, engine, decode_slots):
            return self.quota

    # ps=2, max_len=16, chunk=8 (same geometry as the eager-CoW test in
    # test_prefix_cache): A caches 10 tokens; B extends to 15, its final
    # chunk buckets to 8 and left-shifts to position 8 < credit 10 ->
    # the page holding rows 8-9 must fork, but only when it executes
    cfg, api, params = serve_model
    sched = Gate()
    eng = Engine(api, params, EngineConfig(max_batch=2, max_len=16,
                                           page_size=2, prefill_chunk=8,
                                           scheduler=sched, tick_budget=8))
    rng = np.random.default_rng(14)
    pa = rng.integers(1, 127, 10).astype(np.int32)
    pb = np.concatenate([pa, rng.integers(1, 127, 5).astype(np.int32)])
    eng.submit(Request(0, pa, max_new_tokens=1))
    eng.run_to_completion()                   # caches pa's 5 pages
    assert eng.stats()["cached_pages"] > 0

    sched.quota = 0                           # stage B, defer its chunks
    eng.submit(Request(1, pb, max_new_tokens=1))
    eng.step()
    part = next(iter(eng.admitting.values()))
    assert part.credit == 10 and part.executed == 0
    assert eng.stats()["forked_pages"] == 0   # staged, nothing forked yet
    eng.step()                                # idles: still no fork
    assert eng.stats()["forked_pages"] == 0

    sched.quota = None                        # release the chunk
    done = eng.run_to_completion()
    s = eng.stats()
    assert s["prefix_hit_requests"] == 1
    assert s["forked_pages"] == 1             # fork rode the executed chunk
    assert done[0].output == greedy_ref(pb, 1, max_len=16)
    assert eng.prefix.match(pa, touch=False)[0] == 10   # entry intact


def test_chunked_credit_parity_with_cold_outputs(serve_model):
    """Chunked admission over a mounted credit decodes the same tokens
    as the cold (uncached, whole-prompt) engine."""
    from repro.serve.engine import Request

    [warm] = _prompts(15, (60,))
    cold = _mk_engine(serve_model, prefix_cache=False)
    cold.submit(Request(0, warm, max_new_tokens=3))
    ref = cold.run_to_completion()[0].output

    eng = _mk_engine(serve_model, max_batch=2, tick_budget=8)
    eng.submit(Request(0, warm, max_new_tokens=3))
    eng.run_to_completion()
    eng.submit(Request(1, warm, max_new_tokens=3))
    out = eng.run_to_completion()[0]
    assert eng.stats()["prefix_hit_requests"] == 1
    assert out.output == ref


# ---------------------------------------------------------------------------
# backpressure: pausing a half-prefilled request
# ---------------------------------------------------------------------------

def test_pool_backpressure_pauses_half_prefilled_request(serve_model):
    """An undersized pool pauses a mid-prefill request without leaking
    pages or device-table rows; it resumes to the exact output when the
    blocking request finishes."""
    from repro.serve.engine import Request

    # pool: 9 usable pages.  Blocker holds 5 (32 tokens + decode row);
    # the 40-token newcomer needs 6 -> it must pause mid-prefill.
    eng = _mk_engine(serve_model, max_batch=2, num_pages=10,
                     prefix_cache=False, tick_budget=16)
    blocker_p, late_p = _prompts(16, (32, 40))

    ref = _mk_engine(serve_model, prefix_cache=False)
    ref.submit(Request(0, late_p, max_new_tokens=3))
    want = ref.run_to_completion()[0].output

    eng.submit(Request(0, blocker_p, max_new_tokens=12))
    eng.step()
    eng.submit(Request(1, late_p, max_new_tokens=3))
    out = {r.request_id: r for r in eng.run_to_completion()}
    s = eng.stats()
    assert s["paused_prefills"] > 0           # the pause really happened
    assert out[1].output == want              # resumed to the exact output
    assert not out[1].truncated
    # nothing leaked: all slots released, every page back on the free list
    assert eng.alloc.pages_in_use == 0
    assert not eng.active and not eng.admitting


def test_paused_prefill_is_progress_not_stuck(serve_model):
    """A stalled partial with active slots keeps ticking (decode frees
    pages eventually); only a truly dead engine raises."""
    from repro.serve.engine import Request

    # 9 usable pages: the 24+8-token blocker needs 4 (covered by its
    # prefill reserve — it never grows), the 40-token newcomer needs 6,
    # so the newcomer pauses mid-prefill and resumes after the finish
    eng = _mk_engine(serve_model, max_batch=2, num_pages=10,
                     prefix_cache=False, tick_budget=16)
    big1, big2 = _prompts(17, (24, 40))
    eng.submit(Request(0, big1, max_new_tokens=8))
    eng.submit(Request(1, big2, max_new_tokens=3))
    done = eng.run_to_completion()            # must not raise
    assert sorted(r.request_id for r in done) == [0, 1]
    assert all(not r.truncated for r in done)
    assert eng.stats()["paused_prefills"] > 0


# ---------------------------------------------------------------------------
# cancellation + finish-at-admission
# ---------------------------------------------------------------------------

def test_cancel_mid_prefill_releases_everything(serve_model):
    from repro.serve.engine import Request

    eng = _mk_engine(serve_model, tick_budget=8, prefix_cache=False)
    [p] = _prompts(18, (40,))
    req = Request(0, p, max_new_tokens=4)
    eng.submit(req)
    eng.step()
    assert eng.admitting                      # mid-prefill
    held = eng.alloc.pages_in_use
    assert held > 0
    assert eng.cancel(0)
    assert not eng.admitting
    assert eng.alloc.pages_in_use == 0        # pages all released
    assert req.truncated
    # the freed slot admits the next request cleanly (device row scrubbed)
    eng.submit(Request(1, p[:6], max_new_tokens=2))
    done = eng.run_to_completion()
    assert [r.request_id for r in done] == [1]
    assert eng.cancel(0) is False             # unknown/already gone


def test_cancel_queued_and_active(serve_model):
    from repro.serve.engine import Request

    eng = _mk_engine(serve_model, max_batch=1)
    a, b = _prompts(19, (6, 6))
    eng.submit(Request(0, a, max_new_tokens=30))
    eng.step()
    eng.submit(Request(1, b, max_new_tokens=5))   # queued (slot busy)
    assert eng.cancel(1)                      # dequeue before admission
    assert len(eng.scheduler) == 0
    assert eng.cancel(0)                      # active -> truncated finish
    assert not eng.active
    assert eng.run_to_completion() == []


def test_finish_at_admission_spans_ticks(serve_model):
    """max_new_tokens=1 finishes on the prefill-produced token even when
    the chunked admission took several ticks to get there."""
    from repro.serve.engine import Request

    eng = _mk_engine(serve_model, tick_budget=8)
    [p] = _prompts(20, (40,))
    eng.submit(Request(0, p, max_new_tokens=1))
    ticks = 0
    done = []
    while not done and ticks < 50:
        done = eng.step()
        ticks += 1
    assert ticks > 1                          # the admission spanned ticks
    assert [r.request_id for r in done] == [0]
    assert len(done[0].output) == 1
    assert not eng.active and not eng.admitting


# ---------------------------------------------------------------------------
# scheduler policy: deferral + custom quotas
# ---------------------------------------------------------------------------

def test_zero_quota_policy_defers_without_stuck_error(serve_model):
    """prefill_quota -> 0 defers chunk execution but still stages the
    admission; the no-progress guard must treat that as progress."""
    from repro.serve.engine import Request
    from repro.serve.scheduler import FIFOScheduler

    class StingyThenFair(FIFOScheduler):
        name = "stingy"

        def __init__(self):
            super().__init__()
            self.calls = 0

        def prefill_quota(self, engine, decode_slots):
            self.calls += 1
            return 0 if self.calls <= 3 else None

    sched = StingyThenFair()
    eng = _mk_engine(serve_model, scheduler=sched, tick_budget=8)
    [p] = _prompts(21, (12,))
    eng.submit(Request(0, p, max_new_tokens=3))
    done = eng.run_to_completion()            # must not raise
    assert [r.request_id for r in done] == [0]
    assert sched.calls > 3                    # the deferral window was real


def test_default_quota_is_decode_first(serve_model):
    from repro.serve.scheduler import FIFOScheduler

    eng = _mk_engine(serve_model, tick_budget=10)
    sched = FIFOScheduler()
    assert sched.prefill_quota(eng, 0) == 10
    assert sched.prefill_quota(eng, 4) == 6
    assert sched.prefill_quota(eng, 99) == 0
    eng_unbounded = _mk_engine(serve_model)
    assert sched.prefill_quota(eng_unbounded, 2) is None


# ---------------------------------------------------------------------------
# satellites: latency counters + greedy key skip
# ---------------------------------------------------------------------------

def test_latency_stats_populated(serve_model):
    from repro.serve.engine import Request

    eng = _mk_engine(serve_model, tick_budget=16)
    for i, p in enumerate(_prompts(22, (9, 20))):
        eng.submit(Request(i, p, max_new_tokens=5))
    done = eng.run_to_completion()
    s = eng.stats()
    assert s["latency_samples"]["ttft_ms"] == 2
    assert s["latency_samples"]["itl_ms"] == 2 * 4   # 5 tokens -> 4 gaps
    assert s["ttft_ms_p50"] > 0 and s["ttft_ms_p99"] >= s["ttft_ms_p50"]
    assert s["itl_ms_p50"] > 0
    assert s["queued_ticks_p50"] >= 0
    for r in done:
        assert r.ttft_ms > 0
        assert r.queued_ticks >= 0


def test_greedy_skips_sampling_key_splits(serve_model):
    """EngineConfig.greedy=True never touches jax.random.split on the
    tick path: the root key object is reused as-is."""
    from repro.serve.engine import Request

    eng = _mk_engine(serve_model)
    key_before = np.asarray(eng._key).copy()
    [p] = _prompts(23, (12,))
    eng.submit(Request(0, p, max_new_tokens=6))
    eng.run_to_completion()
    assert np.array_equal(np.asarray(eng._key), key_before)

    sampling = _mk_engine(serve_model, greedy=False, temperature=0.8)
    key_before = np.asarray(sampling._key).copy()
    sampling.submit(Request(0, p, max_new_tokens=3))
    sampling.run_to_completion()
    assert not np.array_equal(np.asarray(sampling._key), key_before)


def test_tick_budget_validation(serve_model):
    from repro.serve.engine import Engine, EngineConfig

    cfg, api, params = serve_model
    with pytest.raises(ValueError, match="tick_budget"):
        Engine(api, params, EngineConfig(tick_budget=0))
