"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape) on the single-pod mesh, from experiments/dryrun/*.json:

  compute    = FLOPs_chip / 197e12        (TPU v5e bf16 peak per chip)
  memory     = bytes_chip / 819e9         (HBM bandwidth per chip)
  collective = wire_bytes_chip / 50e9     (per-link ICI)

FLOPs/bytes are the depth-extrapolated per-chip values (the dry-run
lowers unrolled depth-1/2 variants because HLO cost analysis counts a
lax.scan body once — dryrun.build_cell docstring).  MODEL_FLOPS uses
6·N·D (dense) / 6·N_active·D (MoE) per training step, 2·N·D per
prefill token set, 2·N per decoded token.
"""

from __future__ import annotations

import glob
import json
import os

# the platform cost table lives in repro.analysis.costmodel (shared
# with the static serve-path analyzer and the kernel autotuner priors);
# the module-level aliases keep this script's formulas readable
from repro.analysis.costmodel import TPU_V5E as _PLATFORM

PEAK_FLOPS = _PLATFORM.peak_flops
HBM_BW = _PLATFORM.hbm_bw
LINK_BW = _PLATFORM.link_bw

# active params (N or N_active) per arch, from the configs
_ACTIVE_PARAMS = {}


def active_params(arch: str) -> float:
    if arch not in _ACTIVE_PARAMS:
        from repro.configs import get_config
        from repro.models.registry import get_model
        import jax

        cfg = get_config(arch)
        api = get_model(cfg)
        boxed = jax.eval_shape(api.init, jax.random.PRNGKey(0))
        import jax.tree_util as jtu
        from repro.nn.module import is_param, unbox

        total = 0
        active = 0
        flat = jtu.tree_flatten_with_path(unbox(boxed))[0]
        for path, leaf in flat:
            name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                            for p in path)
            n = 1
            for s in leaf.shape:
                n *= s
            total += n
            if cfg.moe is not None and "/moe/w" in name:
                # routed experts: only top_k of E are active per token
                n = n * cfg.moe.top_k // cfg.moe.effective_experts
            active += n
        _ACTIVE_PARAMS[arch] = (total, active)
    return _ACTIVE_PARAMS[arch]


def model_flops(rec: dict) -> float:
    """Global useful FLOPs for the step this record lowered."""
    from repro.configs import SHAPES_BY_NAME

    shape = SHAPES_BY_NAME[rec["shape"]]
    total, active = active_params(rec["arch"])
    tokens = shape.global_batch * shape.seq_len
    if rec["kind"] == "train":
        return 6.0 * active * tokens
    if rec["kind"] == "prefill":
        return 2.0 * active * tokens
    # decode: one token per sequence
    return 2.0 * active * shape.global_batch


def load_records(out_dir="experiments/dryrun", mesh="16x16"):
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("ok") and r.get("mesh") == mesh and not r.get("opts", {}).get("tag"):
            recs.append(r)
    return recs


def roofline_row(rec: dict) -> dict:
    cost = rec.get("cost_per_chip") or {}
    if "error" in cost or not cost:
        cost = rec.get("cost_raw", {})
    chips = rec["chips"]
    t_comp = cost.get("flops", 0.0) / PEAK_FLOPS
    t_mem = cost.get("bytes accessed", 0.0) / HBM_BW
    t_coll = cost.get("collective_bytes", 0.0) / LINK_BW
    dominant = max((("compute", t_comp), ("memory", t_mem),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    mf = model_flops(rec)
    hlo_global = cost.get("flops", 0.0) * chips
    ratio = mf / hlo_global if hlo_global else 0.0
    bound = max(t_comp, t_mem, t_coll)
    mfu = (mf / chips / PEAK_FLOPS) / bound if bound else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "attention": rec.get("attention_kind", "dotprod"),
        "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
        "dominant": dominant, "model_flops": mf,
        "useful_ratio": ratio, "roofline_mfu": mfu,
        "temp_gb": (rec.get("memory", {}).get("temp_bytes") or 0) / 1e9,
    }


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | attn | compute s | memory s | collective s | "
           "dominant | MODEL/HLO | roofline MFU | temp GB |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['attention']} "
            f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} "
            f"| {r['collective_s']:.4f} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_mfu']:.2%} "
            f"| {r['temp_gb']:.1f} |")
    return "\n".join(lines)


def run(smoke: bool = False) -> list:
    del smoke                     # reads dry-run records; no size knob
    recs = load_records()
    rows = [roofline_row(r) for r in recs]
    csv = []
    for r in rows:
        csv.append((f"roofline/{r['arch']}/{r['shape']}", 0.0,
                    f"dom={r['dominant']};mfu={r['roofline_mfu']:.3f};"
                    f"useful={r['useful_ratio']:.2f}"))
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/roofline.md", "w") as f:
        f.write(markdown_table(rows) + "\n")
    return csv


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
