"""Checkpointing: atomic sharded store + async manager."""

from repro.checkpoint.manager import CheckpointConfig, CheckpointManager  # noqa: F401
from repro.checkpoint.store import (  # noqa: F401
    committed_steps,
    latest_step,
    restore,
    retain,
    save,
)
