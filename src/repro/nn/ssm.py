"""State-space / linear-recurrence layers: RWKV-6 (Finch) time-mix and a
selective-SSM (Mamba-style) block used by the Hymba hybrid architecture.

Both are attention-free token mixers.  The Inhibitor technique (this paper)
replaces dot-product *attention*; these layers have none, so they are
implemented faithfully without it — see DESIGN.md §Arch-applicability.

The reference recurrences here use ``jax.lax.scan`` over time (exact,
O(seq) sequential).  The performance path for RWKV-6 training is the
chunked kernel in :mod:`repro.kernels.rwkv6`, which the model layer calls
through :func:`repro.kernels.ops.wkv6`; decode uses the single-step form.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.linear import apply_dense, init_dense
from repro.nn.module import KeyGen, Param
from repro.nn.norm import apply_groupnorm, init_groupnorm


# ---------------------------------------------------------------------------
# RWKV-6 (Finch): data-dependent decay linear recurrence
# ---------------------------------------------------------------------------

def init_rwkv6_timemix(key, embed_dim: int, num_heads: int, *,
                       lora_dim: int = 64, decay_lora_dim: int = 64,
                       dtype=jnp.float32) -> dict:
    """RWKV-6 time-mix: token-shift LoRA mixers + r/k/v/g/w projections."""
    kg = KeyGen(key)
    head_dim = embed_dim // num_heads
    assert head_dim * num_heads == embed_dim

    def lin(name, out_dim, out_axis="heads_mlp"):
        return init_dense(kg(name), (embed_dim,), (out_dim,),
                          ("embed",), ("heads_mlp",), dtype=dtype)

    p = {
        # token-shift base mix coefficients (mu) for x_{t} vs x_{t-1}
        "mu_base": Param(jnp.zeros((5, embed_dim), dtype), (None, "embed")),
        # data-dependent mix: x -> lora_dim -> 5*embed (stacked LoRA, "ddlerp")
        "mix_lora_a": Param(
            jax.random.normal(kg("mla"), (embed_dim, 5 * lora_dim),
                              jnp.float32).astype(dtype) * 0.01,
            ("embed", None)),
        "mix_lora_b": Param(
            jnp.zeros((5, lora_dim, embed_dim), dtype), (None, None, "embed")),
        "receptance": lin("receptance", embed_dim),
        "key": lin("key", embed_dim),
        "value": lin("value", embed_dim),
        "gate": lin("gate", embed_dim),
        # decay: base + LoRA(x) -> per-channel decay logits
        "w_base": Param(jnp.full((embed_dim,), -6.0, dtype), ("embed",)),
        "w_lora_a": Param(
            jax.random.normal(kg("wla"), (embed_dim, decay_lora_dim),
                              jnp.float32).astype(dtype) * 0.01,
            ("embed", None)),
        "w_lora_b": Param(jnp.zeros((decay_lora_dim, embed_dim), dtype),
                          (None, "embed")),
        # per-channel "bonus" for the current token
        "u": Param(jnp.zeros((embed_dim,), dtype), ("embed",)),
        "output": init_dense(kg("output"), (embed_dim,), (embed_dim,),
                             ("heads_mlp",), ("embed",), dtype=dtype),
        "ln_x": init_groupnorm(num_heads, embed_dim, dtype=dtype),
    }
    return p


def _token_shift(x: jax.Array, prev: Optional[jax.Array] = None) -> jax.Array:
    """Shift sequence right by one; ``prev`` is the carry token for decode."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    else:
        prev = prev[:, None] if prev.ndim == 2 else prev
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def rwkv6_projections(params: dict, x: jax.Array, num_heads: int,
                      x_prev: Optional[jax.Array] = None,
                      compute_dtype=None):
    """Compute r, k, v, g, w (decay) tensors with RWKV-6 ddlerp token shift.

    x: (batch, seq, d). Returns tensors shaped (batch, seq, heads, head_dim)
    and gate g: (batch, seq, d).
    """
    cdt = compute_dtype or x.dtype
    b, s, d = x.shape
    hd = d // num_heads
    xs = _token_shift(x, x_prev)                     # (b, s, d) previous token
    dx = xs - x

    mu = params["mu_base"].astype(jnp.float32)       # (5, d)
    # data-dependent part: tanh(x @ A) @ B  per mixed stream
    la = params["mix_lora_a"].astype(jnp.float32)    # (d, 5*r)
    lb = params["mix_lora_b"].astype(jnp.float32)    # (5, r, d)
    r_dim = lb.shape[1]
    base = x.astype(jnp.float32) + dx.astype(jnp.float32) * mu[:, None, None, :]
    # (5, b, s, r) -> (5, b, s, d)
    z = jnp.tanh((x.astype(jnp.float32) @ la).reshape(b, s, 5, r_dim)
                 ).transpose(2, 0, 1, 3)
    dd = jnp.einsum("nbsr,nrd->nbsd", z, lb)
    mixed = base + dx.astype(jnp.float32) * dd       # (5, b, s, d)
    xw, xk, xv, xr, xg = [m.astype(cdt) for m in mixed]

    r = apply_dense(params["receptance"], xr, 1, cdt).reshape(b, s, num_heads, hd)
    k = apply_dense(params["key"], xk, 1, cdt).reshape(b, s, num_heads, hd)
    v = apply_dense(params["value"], xv, 1, cdt).reshape(b, s, num_heads, hd)
    g = jax.nn.silu(apply_dense(params["gate"], xg, 1, cdt))

    wa = params["w_lora_a"].astype(jnp.float32)
    wb = params["w_lora_b"].astype(jnp.float32)
    w_logit = (params["w_base"].astype(jnp.float32)
               + jnp.tanh(xw.astype(jnp.float32) @ wa) @ wb)  # (b, s, d)
    # decay in (0, 1): exp(-exp(w_logit))
    w = jnp.exp(-jnp.exp(w_logit)).reshape(b, s, num_heads, hd)
    return r, k, v, g, w


def wkv6_scan_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                  u: jax.Array,
                  state: Optional[jax.Array] = None):
    """Exact RWKV-6 recurrence via lax.scan (reference; O(T) sequential).

    Shapes: r,k,v,w: (b, t, h, n) with n = head_dim; u: (h, n).
    State S: (b, h, n, n) with update  S <- diag(w_t) S + k_t^T v_t  and
    output  o_t = r_t (S + diag(u) k_t^T v_t).
    Returns (out (b, t, h, n), final_state).
    """
    b, t, h, n = r.shape
    if state is None:
        state = jnp.zeros((b, h, n, n), jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp  # each (b, h, n)
        kv = kt[..., :, None] * vt[..., None, :]          # (b, h, n, n)
        out = jnp.einsum("bhn,bhnm->bhm", rt,
                         S + u[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, out

    xs = (r.transpose(1, 0, 2, 3).astype(jnp.float32),
          k.transpose(1, 0, 2, 3).astype(jnp.float32),
          v.transpose(1, 0, 2, 3).astype(jnp.float32),
          w.transpose(1, 0, 2, 3).astype(jnp.float32))
    final, outs = jax.lax.scan(step, state, xs)
    return outs.transpose(1, 0, 2, 3), final


def apply_rwkv6_timemix(params: dict, x: jax.Array, num_heads: int, *,
                        state: Optional[jax.Array] = None,
                        x_prev: Optional[jax.Array] = None,
                        use_kernel: bool = False,
                        compute_dtype=None):
    """Full RWKV-6 time-mix block. Returns (y, (final_state, last_token))."""
    b, s, d = x.shape
    hd = d // num_heads
    cdt = compute_dtype or x.dtype
    r, k, v, g, w = rwkv6_projections(params, x, num_heads, x_prev, cdt)
    u = params["u"].astype(jnp.float32).reshape(num_heads, hd)
    if use_kernel:
        from repro.kernels import ops as kops
        out, final = kops.wkv6(r, k, v, w, u, state)
    else:
        out, final = wkv6_scan_ref(r, k, v, w, u, state)
    out = out.reshape(b, s, d)
    out = apply_groupnorm(params["ln_x"], out.astype(cdt), num_heads)
    out = out * g.astype(cdt)
    y = apply_dense(params["output"], out, 1, cdt)
    return y, (final, x[:, -1])


def init_rwkv6_channelmix(key, embed_dim: int, hidden_dim: int, *,
                          dtype=jnp.float32) -> dict:
    kg = KeyGen(key)
    return {
        "mu_k": Param(jnp.zeros((embed_dim,), dtype), ("embed",)),
        "mu_r": Param(jnp.zeros((embed_dim,), dtype), ("embed",)),
        "key": init_dense(kg("key"), (embed_dim,), (hidden_dim,),
                          ("embed",), ("mlp",), dtype=dtype),
        "receptance": init_dense(kg("receptance"), (embed_dim,), (embed_dim,),
                                 ("embed",), ("heads_mlp",), dtype=dtype),
        "value": init_dense(kg("value"), (hidden_dim,), (embed_dim,),
                            ("mlp",), ("embed",), dtype=dtype),
    }


def apply_rwkv6_channelmix(params: dict, x: jax.Array, *,
                           x_prev: Optional[jax.Array] = None,
                           compute_dtype=None):
    """RWKV channel-mix (squared-ReLU FFN with token shift + receptance gate)."""
    cdt = compute_dtype or x.dtype
    xs = _token_shift(x, x_prev)
    dx = xs - x
    mk = params["mu_k"].astype(cdt)
    mr = params["mu_r"].astype(cdt)
    xk = x + dx * mk
    xr = x + dx * mr
    kk = apply_dense(params["key"], xk, 1, cdt)
    kk = jnp.square(jax.nn.relu(kk))
    vv = apply_dense(params["value"], kk, 1, cdt)
    rr = jax.nn.sigmoid(apply_dense(params["receptance"], xr, 1, cdt))
    return rr * vv, x[:, -1]


# ---------------------------------------------------------------------------
# Selective SSM (Mamba-style) for Hymba's parallel SSM heads
# ---------------------------------------------------------------------------

def init_mamba(key, embed_dim: int, inner_dim: int, *, state_dim: int = 16,
               conv_dim: int = 4, dt_rank: Optional[int] = None,
               dtype=jnp.float32) -> dict:
    kg = KeyGen(key)
    dt_rank = dt_rank or max(1, embed_dim // 16)
    # S4D-real initialization of A: -[1..state_dim] per channel
    a_init = jnp.tile(jnp.arange(1, state_dim + 1, dtype=jnp.float32)[None, :],
                      (inner_dim, 1))
    p = {
        "in_proj": init_dense(kg("in_proj"), (embed_dim,), (2 * inner_dim,),
                              ("embed",), ("mlp",), dtype=dtype),
        "conv_w": Param(
            (jax.random.normal(kg("conv"), (conv_dim, inner_dim), jnp.float32)
             * (conv_dim ** -0.5)).astype(dtype), (None, "mlp")),
        "conv_b": Param(jnp.zeros((inner_dim,), dtype), ("mlp",)),
        "x_proj": init_dense(kg("x_proj"), (inner_dim,),
                             (dt_rank + 2 * state_dim,),
                             ("mlp",), (None,), dtype=dtype),
        "dt_proj": init_dense(kg("dt_proj"), (dt_rank,), (inner_dim,),
                              (None,), ("mlp",), use_bias=True, dtype=dtype),
        "A_log": Param(jnp.log(a_init).astype(jnp.float32), ("mlp", None)),
        "D": Param(jnp.ones((inner_dim,), jnp.float32), ("mlp",)),
        "out_proj": init_dense(kg("out_proj"), (inner_dim,), (embed_dim,),
                               ("mlp",), ("embed",), dtype=dtype),
    }
    return p


def _causal_depthwise_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                           carry: Optional[jax.Array] = None):
    """x: (b, t, c); w: (k, c) depthwise causal conv. Returns (y, new_carry)."""
    k = w.shape[0]
    if carry is None:
        carry = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)
    # depthwise conv as sum of shifted slices (k is tiny: 4)
    t = x.shape[1]
    y = sum(xp[:, i:i + t] * w[i][None, None, :] for i in range(k))
    new_carry = xp[:, -(k - 1):] if k > 1 else None
    return y + b[None, None, :], new_carry


def selective_scan_ref(u: jax.Array, dt: jax.Array, A: jax.Array,
                       B: jax.Array, C: jax.Array, D: jax.Array,
                       state: Optional[jax.Array] = None):
    """Mamba selective scan (reference, lax.scan over time).

    u, dt: (b, t, c); A: (c, n); B, C: (b, t, n); D: (c,).
    State: (b, c, n). Returns (y (b, t, c), final_state).
    """
    b, t, c = u.shape
    n = A.shape[1]
    if state is None:
        state = jnp.zeros((b, c, n), jnp.float32)
    dA = jnp.exp(dt[..., None] * (-jnp.exp(A))[None, None, :, :])  # (b,t,c,n)
    dBu = dt[..., None] * B[:, :, None, :] * u[..., None]          # (b,t,c,n)

    def step(S, inp):
        dA_t, dBu_t, C_t = inp
        S = dA_t * S + dBu_t
        y = jnp.einsum("bcn,bn->bc", S, C_t)
        return S, y

    xs = (dA.transpose(1, 0, 2, 3), dBu.transpose(1, 0, 2, 3),
          C.transpose(1, 0, 2).astype(jnp.float32))
    final, ys = jax.lax.scan(step, state, xs)
    y = ys.transpose(1, 0, 2) + u * D[None, None, :]
    return y, final


def apply_mamba(params: dict, x: jax.Array, *, state_dim: int = 16,
                ssm_state: Optional[jax.Array] = None,
                conv_state: Optional[jax.Array] = None,
                compute_dtype=None):
    """Mamba block forward. Returns (y, (ssm_state, conv_state))."""
    cdt = compute_dtype or x.dtype
    xz = apply_dense(params["in_proj"], x, 1, cdt)
    xs, z = jnp.split(xz, 2, axis=-1)
    xs, new_conv = _causal_depthwise_conv(
        xs, params["conv_w"].astype(cdt), params["conv_b"].astype(cdt),
        conv_state)
    xs = jax.nn.silu(xs)
    proj = apply_dense(params["x_proj"], xs, 1, cdt)
    dt_rank = proj.shape[-1] - 2 * state_dim
    dt_low, B, C = jnp.split(proj, [dt_rank, dt_rank + state_dim], axis=-1)
    dt = apply_dense(params["dt_proj"], dt_low, 1, cdt)
    dt = jax.nn.softplus(dt.astype(jnp.float32))
    y, new_ssm = selective_scan_ref(
        xs.astype(jnp.float32), dt, params["A_log"].astype(jnp.float32),
        B.astype(jnp.float32), C.astype(jnp.float32),
        params["D"].astype(jnp.float32), ssm_state)
    y = y.astype(cdt) * jax.nn.silu(z)
    out = apply_dense(params["out_proj"], y, 1, cdt)
    return out, (new_ssm, new_conv)
