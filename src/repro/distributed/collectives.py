"""Explicit collective helpers: compressed cross-pod gradient sync and
communication/compute overlap primitives.

Inside a pjit program XLA SPMD chooses collective schedules automatically;
these shard_map helpers exist for the paths where we want *manual* control:

  * :func:`compressed_grad_sync` — hierarchical DP reduction: full-precision
    pmean over the fast intra-pod ``data`` axis, int8-compressed psum across
    the slow ``pod`` axis (4× wire bytes on the slow hop).
  * :func:`allgather_matmul` — ring-overlapped TP matmul: the all-gather of
    the k-sharded activation is decomposed into P ppermute hops, each hop's
    transfer overlapping the previous chunk's MXU work (the classic
    "collective matmul" that hides ICI latency).  Bit-identical to
    ``allgather(x) @ w`` — asserted by tests/test_distributed.py.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def compressed_grad_sync(grads, mesh: Mesh, *, compress_pod: bool = True):
    """Hierarchical mean over (pod, data) with int8 cross-pod payloads.

    grads: tree of per-replica gradients laid out with batch-sharding
    removed (each (pod, data) replica holds its local gradient).  Returns
    the fully averaged tree.  Wire bytes on the pod hop: 1 int8 + shared
    fp32 scale per tensor vs 4 bytes/elem uncompressed.
    """
    has_pod = "pod" in mesh.axis_names

    def sync_one(g):
        def inner(gl):
            gl = jax.lax.pmean(gl, "data")
            if has_pod:
                if compress_pod:
                    scale = jnp.maximum(jnp.max(jnp.abs(gl)), 1e-12) / 127.0
                    scale = jax.lax.pmax(scale, "pod")
                    q = jnp.clip(jnp.round(gl / scale), -127, 127
                                 ).astype(jnp.int8)
                    s = jax.lax.psum(q.astype(jnp.int32), "pod")
                    gl = s.astype(jnp.float32) * (scale / mesh.shape["pod"])
                else:
                    gl = jax.lax.pmean(gl, "pod")
            return gl

        return shard_map(inner, mesh=mesh, in_specs=P(), out_specs=P(),
                         check_rep=False)(g)

    return jax.tree.map(sync_one, grads)


def allgather_matmul(x: jax.Array, w: jax.Array, mesh: Mesh, *,
                     axis: str = "model") -> jax.Array:
    """Ring-overlapped ``allgather_k(x) @ w``.

    Layout (all logical shapes):
      x: (m, k)  sharded on dim 1 over ``axis``  -> local (m, k/P)
      w: (k, n)  sharded on dim 1 over ``axis``  -> local (k, n/P)
      y: (m, n)  sharded on dim 1 over ``axis``  -> local (m, n/P)

    Each of the P steps multiplies the resident x-chunk (originating from
    shard (idx − i) mod P) with the matching k-rows of the local w slice,
    then rotates the chunk one hop around the ring — transfer i+1 overlaps
    matmul i on hardware with async collectives.
    """
    deg = mesh.shape[axis]

    def inner(xl, wl):
        idx = jax.lax.axis_index(axis)
        k_per = xl.shape[1]
        acc0 = jnp.zeros((xl.shape[0], wl.shape[1]),
                         jnp.promote_types(xl.dtype, wl.dtype))
        perm = [(j, (j + 1) % deg) for j in range(deg)]

        def body(i, carry):
            acc, buf = carry
            src = jax.lax.rem(idx - i + deg, deg)     # resident chunk origin
            wrows = jax.lax.dynamic_slice_in_dim(wl, src * k_per, k_per, 0)
            acc = acc + jnp.dot(buf, wrows)
            buf = jax.lax.ppermute(buf, axis, perm)
            return acc, buf

        acc, _ = jax.lax.fori_loop(0, deg, body, (acc0, xl))
        return acc

    return shard_map(
        inner, mesh=mesh,
        in_specs=(P(None, axis), P(None, axis)),
        out_specs=P(None, axis),
        check_rep=False,
    )(x, w)
