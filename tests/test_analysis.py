"""Static circuit analyzer (repro.analysis): per-primitive interval
soundness, cmul/LUT verification, parameter selection, CLI schema.

The hypothesis-based soundness property test follows the optional-
hypothesis pattern: it skips (not the module — the deterministic tests
here must always run) when the package is absent.
"""

import json

import numpy as np
import pytest

from repro.analysis import IntervalLane, IntervalOverflow, IntervalTensor
from repro.analysis.interval import table_range_minmax
from repro.analysis.lint import lint_source
from repro.core.lanes import _MASKED_ROW, FheSimLane, get_lane
from repro.fhe.params import (select_params_for_report,
                              select_params_static)
from repro.quant.int_attention import (lane_dot_product_attention,
                                       lane_inhibitor_attention)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:     # tier-1 runs without the optional test extra
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# Harness: random intervals, samples inside them, paired lane runs
# ---------------------------------------------------------------------------

def _rand_interval(rng, shape, lo=-100, hi=100):
    a = rng.integers(lo, hi, shape)
    b = rng.integers(lo, hi, shape)
    return IntervalTensor(np.minimum(a, b), np.maximum(a, b))


def _sample(rng, t: IntervalTensor) -> np.ndarray:
    return rng.integers(t.lo, t.hi + 1)


def _contains(t: IntervalTensor, arr) -> bool:
    arr = np.asarray(arr, np.int64)
    return bool(np.all(t.lo <= arr) and np.all(arr <= t.hi))


def _counters(ctx) -> dict:
    return {c: getattr(ctx, c) for c in ("pbs", "cmuls", "adds",
                                         "lit_muls")}


def _paired(op, intervals, rng, n_draws=5):
    """Run ``op(lane, *handles)`` on the interval lane and on fhe_sim with
    ``n_draws`` concrete samples inside the intervals.  Asserts equal op
    counts, dominated widths, and abstract containment of every concrete
    result; returns the abstract result."""
    il = IntervalLane()
    abstract = op(il, *intervals)
    for _ in range(n_draws):
        fl = FheSimLane()
        concrete = op(fl, *[_sample(rng, t) for t in intervals])
        assert _counters(fl.ctx) == _counters(il.ctx)
        assert fl.ctx.max_bits <= il.ctx.max_bits
        assert fl.ctx.max_bits_any <= il.ctx.max_bits_any
        assert _contains(abstract, concrete)
    return abstract


# ---------------------------------------------------------------------------
# Per-primitive soundness (counts equal, widths dominated, containment)
# ---------------------------------------------------------------------------

_W = np.array([[2, -3], [1, 4], [-5, 0]])
_PRIMITIVES = {
    "add": lambda ln, a, b: ln.add(a, b),
    "sub": lambda ln, a, b: ln.sub(a, b),
    "neg": lambda ln, a: ln.neg(a),
    "mul_literal": lambda ln, a: ln.mul_literal(a, -7),
    "mul_literal_array": lambda ln, a: ln.mul_literal(
        a, np.array([2, -3, 5])),
    "shift_right": lambda ln, a: ln.shift_right(a, 2),
    "matmul_plain": lambda ln, a: ln.matmul_plain(a, _W),
    "sum": lambda ln, a: ln.sum(a, axis=-1),
    "sum_keepdims": lambda ln, a: ln.sum(a, axis=0, keepdims=True),
    "select": lambda ln, a: ln.select(
        np.array([[True, False, True]] * 4), a, 9),
    "clip": lambda ln, a: ln.clip(a, -10, 10),
    "relu": lambda ln, a: ln.relu(a),
    "abs": lambda ln, a: ln.abs(a),
    "max": lambda ln, a: ln.max(a, axis=-1),
    "lut": lambda ln, a: ln.lut(a, lambda t: (t * t) >> 2, -50, 50),
    "mul": lambda ln, a, b: ln.mul(a, b),
    "dot_scores": lambda ln, a, b: ln.dot_scores(a, b),
}


@pytest.mark.parametrize("name", sorted(_PRIMITIVES))
def test_primitive_sound(name):
    rng = np.random.default_rng(11)
    op = _PRIMITIVES[name]
    n_args = 2 if name in ("add", "sub", "mul", "dot_scores") else 1
    shape = (2, 3) if name == "dot_scores" else (4, 3)
    ivs = [_rand_interval(rng, shape) for _ in range(n_args)]
    _paired(op, ivs, rng)


def test_mix_values_sound():
    rng = np.random.default_rng(3)
    p = _rand_interval(rng, (2, 4), 0, 16)      # probs (n_q, n_k)
    v = _rand_interval(rng, (4, 3))             # values (n_k, d)
    _paired(lambda ln, a, b: ln.mix_values(a, b), [p, v], rng)


def test_structure_ops_and_scalars():
    rng = np.random.default_rng(5)
    t = _rand_interval(rng, (2, 3, 4))
    il = IntervalLane()
    r = il.reshape(t, (6, 4))
    assert r.shape == (6, 4)
    tr = il.transpose(t, (2, 0, 1))
    assert tr.shape == (4, 2, 3)
    e = il.expand_dims(t, -2)
    assert e.shape == (2, 3, 1, 4)
    rp = il.repeat(t, 2, 1)
    assert rp.shape == (2, 6, 4)
    # none of these are homomorphic work
    assert _counters(il.ctx) == {"pbs": 0, "cmuls": 0, "adds": 0,
                                 "lit_muls": 0}
    with pytest.raises(TypeError, match="abstract bounds"):
        il.to_numpy(t)


def test_embed_bounds_are_token_independent():
    rng = np.random.default_rng(7)
    table = rng.integers(-40, 40, (16, 6))
    il = IntervalLane()
    out = il.embed(table, np.zeros((2, 5), np.int64))
    fl = FheSimLane()
    for _ in range(5):
        toks = rng.integers(0, 16, (2, 5))
        assert _contains(out, fl.to_numpy(fl.embed(table, toks)))
    # per-channel (not global) bounds: channel extremes match the table's
    np.testing.assert_array_equal(out.lo[0, 0], table.min(axis=0))
    np.testing.assert_array_equal(out.hi[0, 0], table.max(axis=0))


def test_lut_saturation_and_site_report():
    il = IntervalLane()
    t = IntervalTensor(np.array([-80, 0]), np.array([-20, 90]))
    out = il.lut(t, lambda x: x + 1, -50, 50)
    site = il.lut_sites[0]
    assert not site["fits_domain"]
    assert site["overflow_lo"] == 30 and site["overflow_hi"] == 40
    assert site["saturated"] == [-50, 50]
    # output bounded by the table over the *reachable* range only
    assert (out.lo[0], out.hi[0]) == (-49, -19)
    assert (out.lo[1], out.hi[1]) == (1, 51)
    # PBS width covers the saturated input (what the table must span)
    assert il.ctx.max_bits == max(1, (50).bit_length()) + 1


def test_lut2_packed_width_widening():
    rng = np.random.default_rng(9)
    # intervals spanning the full declared domains so the recorded table
    # width is the deterministic worst case
    x = IntervalTensor(np.full((4,), -3), np.full((4,), 3))
    y = IntervalTensor(np.full((4,), 0), np.full((4,), 7))

    def op(ln, xx, yy):
        return ln.lut2(xx, yy, lambda a, b: a * b,
                       x_lo=-3, x_hi=3, y_lo=0, y_hi=7)

    _paired(op, [x, y], rng)
    il = IntervalLane()
    op(il, x, y)
    # packed p = (x+3) + y*7 spans [-3, 52]: a 7-bit signed message —
    # wider than either operand (x: 3 bits, y: 4 bits).  That widening is
    # exactly what parameter selection must see.
    assert il.lut_sites[0]["domain"] == [-3, 52]
    assert il.lut_sites[0]["table_bits"] == 7
    assert il.ctx.max_bits == 7


def test_masked_max_sentinel_and_pbs_count():
    rng = np.random.default_rng(13)
    t = _rand_interval(rng, (3, 4))
    mask = np.array([[True, True, False, True],
                     [False, False, False, False],     # fully masked row
                     [True, False, True, True]])
    il = IntervalLane()
    out = il.masked_max(t, mask, axis=-1)
    # fully masked row collapses to the exact sentinel interval
    assert out.lo[1] == out.hi[1] == _MASKED_ROW
    assert il.ctx.pbs == int(mask.sum())     # relu-tree: attendable only
    fl = FheSimLane()
    conc = fl.masked_max(_sample(rng, t), mask, axis=-1)
    assert conc[1] == _MASKED_ROW
    assert _counters(fl.ctx) == _counters(il.ctx)
    assert _contains(out, conc)


def test_interval_overflow_guard_raises():
    big = IntervalTensor(np.array([1 << 40]), np.array([1 << 40]))
    il = IntervalLane()
    with pytest.raises(IntervalOverflow):
        il.mul_literal(big, 1 << 40)


def test_table_range_minmax_matches_bruteforce():
    rng = np.random.default_rng(17)
    tbl = rng.integers(-1000, 1000, (257,))
    i0 = rng.integers(0, 257, (64,))
    i1 = np.minimum(i0 + rng.integers(0, 257, (64,)), 256)
    lo, hi = table_range_minmax(tbl, i0, i1)
    for j in range(64):
        seg = tbl[i0[j]:i1[j] + 1]
        assert lo[j] == seg.min() and hi[j] == seg.max()


# ---------------------------------------------------------------------------
# Mechanism level: zero-cmul proof + cmul-site attribution
# ---------------------------------------------------------------------------

def _qkv_intervals(rng, nq=3, nk=4, d=4, clip=31):
    return [IntervalTensor(np.full((nq if i == 0 else nk, d), -clip),
                           np.full((nq if i == 0 else nk, d), clip))
            for i in range(3)]


def test_inhibitor_mechanism_statically_cmul_free():
    rng = np.random.default_rng(19)
    q, k, v = _qkv_intervals(rng)
    il = IntervalLane()
    with il.scope("attn"):
        lane_inhibitor_attention(il, q, k, v, gamma_shift=1, alpha_q=2,
                                 signed=True, normalize=True)
    assert il.cmul_sites == []
    assert il.ctx.cmuls == 0


def test_dotprod_cmul_sites_attributed_by_contraction():
    rng = np.random.default_rng(23)
    q, k, v = _qkv_intervals(rng, clip=15)
    il = IntervalLane()
    with il.scope("L0.attn"):
        lane_dot_product_attention(il, q, k, v, scale_shift=2, frac_bits=4)
    ops = [s["op"] for s in il.cmul_sites]
    assert ops == ["dot_scores", "mul", "mix_values"]
    assert all(s["scope"] == "L0.attn" for s in il.cmul_sites)
    assert all(s["count"] > 0 and s["pbs_bits"] >= 2
               for s in il.cmul_sites)
    assert il.ctx.cmuls == sum(s["count"] for s in il.cmul_sites)


# ---------------------------------------------------------------------------
# End-to-end: static dominates measured on a full paper-tiny forward
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def paper_tiny():
    import jax

    from repro.configs import get_config
    from repro.models.registry import get_model
    from repro.nn.module import unbox

    cfg = get_config("paper-tiny")
    params = unbox(get_model(cfg).init(jax.random.PRNGKey(0)))
    return cfg, params


@pytest.mark.parametrize("mech", ["inhibitor", "dotprod"])
def test_static_dominates_measured_end_to_end(paper_tiny, mech):
    from repro.analysis import analyze_qlm
    from repro.models import transformer as tfm
    from repro.quant.ptq import ptq_lm

    cfg, params = paper_tiny
    qlm = ptq_lm(params, cfg.with_attention_kind(mech))
    static = analyze_qlm(qlm, seq_len=6)

    rng = np.random.default_rng(29)
    toks = rng.integers(0, cfg.vocab_size, (1, 6))
    fhe = get_lane("fhe_sim")
    tfm.lm_forward_lane(qlm, fhe, toks)
    measured = fhe.ctx.scope_report()

    assert set(measured) == set(static["per_scope"])
    for name, s in measured.items():
        st = static["per_scope"][name]
        # counts are shape-determined: static == measured, exactly
        for c in ("pbs", "cmuls", "adds", "lit_muls"):
            assert s[c] == st[c], (name, c)
        # widths are input-dependent: static must dominate
        assert s["max_bits_at_pbs"] <= st["max_bits_at_pbs"], name
        assert s["max_bits_any"] <= st["max_bits_any"], name

    # cross-checked measured selection succeeds; static picks no smaller
    sel_measured = select_params_for_report(
        measured, static_report=static["per_scope"])
    sel_static = select_params_static(static["per_scope"])
    assert sel_static.msg_bits >= sel_measured.msg_bits
    assert sel_static.poly_size >= sel_measured.poly_size

    if mech == "inhibitor":
        assert static["zero_cmul_proven"]
        assert static["totals"]["cmuls"] == 0
    else:
        assert len(static["cmul_sites"]) >= 1
        assert {s["scope"] for s in static["cmul_sites"]} == {"L0.attn"}
    assert static["lut_verification"]["verified"]


def test_cross_check_detects_unsound_static_bound(paper_tiny):
    from repro.analysis import analyze_qlm
    from repro.models import transformer as tfm
    from repro.quant.ptq import ptq_lm

    cfg, params = paper_tiny
    qlm = ptq_lm(params, cfg)
    static = analyze_qlm(qlm, seq_len=4)
    fhe = get_lane("fhe_sim")
    tfm.lm_forward_lane(qlm, fhe, np.zeros((1, 4), np.int64))
    measured = fhe.ctx.scope_report()

    tampered = {k: dict(v) for k, v in static["per_scope"].items()}
    worst = max(measured, key=lambda k: measured[k]["max_bits_at_pbs"])
    tampered[worst]["max_bits_at_pbs"] = \
        measured[worst]["max_bits_at_pbs"] - 1
    with pytest.raises(ValueError, match="SOUNDNESS"):
        select_params_for_report(measured, static_report=tampered)
    missing = {k: v for k, v in tampered.items() if k != worst}
    with pytest.raises(ValueError, match="missing from the static"):
        select_params_for_report(measured, static_report=missing)


def test_report_without_pbs_raises_descriptive_error():
    """Regression: a PBS-free report must not silently select the
    smallest parameter point."""
    no_pbs = {"L0.qkv_proj": {"max_bits_at_pbs": 0, "pbs": 0},
              "L0.out_proj": {"adds": 64}}
    with pytest.raises(ValueError, match="observed a PBS"):
        select_params_for_report(no_pbs)
    with pytest.raises(ValueError, match="observed a PBS"):
        select_params_static(no_pbs)
    with pytest.raises(ValueError, match="empty"):
        select_params_static({})


# ---------------------------------------------------------------------------
# CLI smoke: ANALYSIS_fhe.json schema
# ---------------------------------------------------------------------------

def test_cli_writes_valid_analysis_json(tmp_path, capsys):
    from repro.analysis.__main__ import main

    out = tmp_path / "ANALYSIS_fhe.json"
    rc = main(["--config", "paper-tiny", "--seq-len", "4",
               "--out", str(out)])
    assert rc == 0
    doc = json.loads(out.read_text())
    assert doc["schema"] == 1
    assert doc["config"] == "paper-tiny"
    assert set(doc["mechanisms"]) == {"inhibitor", "dotprod"}
    for mech, rep in doc["mechanisms"].items():
        assert {"totals", "per_scope", "value_ranges", "cmul_sites",
                "zero_cmul_proven", "lut_sites", "lut_verification",
                "params"} <= set(rep)
        assert rep["params"]["msg_bits"] >= \
            rep["totals"]["max_bits_at_pbs"]
        for scope, s in rep["per_scope"].items():
            assert {"pbs", "cmuls", "adds", "lit_muls",
                    "max_bits_at_pbs"} <= set(s)
            lo, hi = rep["value_ranges"][scope]
            assert lo <= hi
    assert doc["mechanisms"]["inhibitor"]["zero_cmul_proven"]
    assert len(doc["mechanisms"]["dotprod"]["cmul_sites"]) >= 1
    assert "ZERO, proven" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# Lint rules
# ---------------------------------------------------------------------------

def test_lint_flags_each_rule_and_passes_clean_code():
    bad_arith = ("def lane_mix(lane, x):\n"
                 "    return jnp.add(x, 1)\n")
    bad_cmul = ("def lane_inhibitor_alt(lane, q, k):\n"
                "    return lane.mul(q, k)\n")
    bad_hash = "seed = hash(('layer', 3))\n"
    clean = ("def lane_fn(lane, x):\n"
             "    t = np.asarray([1, 2])\n"
             "    return lane.lut(x, lambda v: np.exp2(v), -4, 0)\n")
    assert [v.rule for v in lint_source(bad_arith)] == ["LANE001"]
    assert [v.rule for v in lint_source(bad_cmul)] == ["LANE002"]
    assert [v.rule for v in lint_source(bad_hash)] == ["LANE003"]
    assert lint_source(clean) == []


def test_lint_clean_on_repo_sources():
    from pathlib import Path

    from repro.analysis.lint import lint_paths

    root = Path(__file__).resolve().parent.parent / "src" / "repro"
    assert lint_paths([root]) == []


# ---------------------------------------------------------------------------
# Soundness property (optional hypothesis, like test_property_based.py)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(st.integers(2, 5), st.integers(2, 6), st.integers(2, 4),
           st.integers(0, 10**6))
    def test_fhe_sim_always_dominated_by_static_bounds(nq, nk, d, seed):
        """Any concrete run inside the declared ranges observes per-scope
        widths dominated by — and outputs contained in — the static
        bounds, at identical op counts."""
        rng = np.random.default_rng(seed)
        clip = 31
        shapes = [(nq, d), (nk, d), (nk, d)]
        for fn, kw in (
                (lane_inhibitor_attention,
                 dict(gamma_shift=1, alpha_q=2, signed=True,
                      normalize=True)),
                (lane_dot_product_attention,
                 dict(scale_shift=2, frac_bits=4))):
            il = IntervalLane()
            ivs = [IntervalTensor(np.full(s, -clip), np.full(s, clip))
                   for s in shapes]
            with il.scope("attn"):
                bound = fn(il, *ivs, **kw)
            fl = FheSimLane()
            conc = [rng.integers(-clip, clip + 1, s) for s in shapes]
            with fl.scope("attn"):
                out = fn(fl, *conc, **kw)
            ms, ss = fl.ctx.per_scope["attn"], il.ctx.per_scope["attn"]
            for c in ("pbs", "cmuls", "adds", "lit_muls"):
                assert ms[c] == ss[c], c
            assert ms["max_bits_at_pbs"] <= ss["max_bits_at_pbs"]
            assert ms["max_bits_any"] <= ss["max_bits_any"]
            assert _contains(bound, out)

else:

    @pytest.mark.skip(reason="hypothesis not installed (optional test "
                             "extra); deterministic analyzer tests above "
                             "still ran")
    def test_fhe_sim_always_dominated_by_static_bounds():
        pass
