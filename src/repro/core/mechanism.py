"""Pluggable attention-mechanism registry + explicit backend planner.

This module is the dispatch seam of the whole stack (DESIGN.md §7).  A
*mechanism* (how scores are formed and combined with values — Softmax
dot-product, the paper's Inhibitor, …) registers once; every model token
mixer, kernel path, quantized/integer path, FHE circuit and benchmark
driver then picks it up through one inspectable API:

  * :class:`Mechanism`       — name, mask semantics, VJP hints, and one
                                callable per execution *backend*
  * :func:`register_mechanism` / :func:`get_mechanism` — the registry
  * :func:`plan_attention`   — the planner: (config, :class:`AttnShapes`)
                                -> :class:`ExecutionPlan` (backend + reason)
  * :func:`execute_plan`     — run a plan on (q, k, v)

Backends (``BACKENDS``) are execution strategies for one mechanism:

  ``naive``    broadcast oracle; autodiff-friendly; O(n²·d) memory
  ``fused``    cdist-decomposed / custom-VJP dense form (default)
  ``chunked``  streaming accumulation over KV chunks (exact — no Softmax
               normalizer to rescale for the inhibitor family)
  ``blocked``  two-level chunk scan with structural (causal/window/valid-
               length) masks computed from indices — no mask array in HBM
  ``pallas``   the Pallas TPU kernel (interpret mode on CPU hosts); since
               the kernels carry scalar-prefetched ``q_offset`` /
               ``kv_valid_len`` cursor operands it is eligible at
               decode-cache sites, including ragged per-slot cursors
  ``paged``    block-table gather over a paged KV pool (serving decode /
               single-row prefill; k/v arrive as page pools plus a
               :class:`PagedLayout`) — the non-TPU / prefill fallback
  ``paged_pallas``  block-table-native Pallas decode kernel: the grid
               walks each row's block table, staging K/V pages
               VMEM-resident — no contiguous gather (DESIGN.md §10)
  ``int``      integer-lane arithmetic (paper's quantized scaling arm)
  ``fhe_sim``  the TFHE circuit simulator (numpy, per-head; forced only)

``blocked``, ``pallas`` and ``paged_pallas`` never receive a materialized
mask array — they are listed in :data:`MASK_FREE_BACKENDS` and take a
:class:`Structural` description instead.  The planner only selects
backends whose eligibility predicate passes for the given shapes, so
"registered" and "selectable here" stay distinct, inspectable facts.

Config duck-typing: :func:`plan_attention` reads ``mechanism`` (falling
back to the legacy ``kind``), ``backend``, ``use_kernel`` (deprecated
alias for ``backend="pallas"``), ``chunked_threshold``,
``blocked_threshold``, ``causal`` and ``sliding_window`` off the config
object — it does not import :class:`repro.core.attention.AttentionConfig`
to stay cycle-free and to let tests plan with lightweight stand-ins.
"""

from __future__ import annotations

import dataclasses
import logging
import warnings
from typing import Any, Callable, Dict, Mapping, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

log = logging.getLogger("repro.plan")

BACKENDS: Tuple[str, ...] = (
    "naive", "fused", "chunked", "blocked", "pallas", "paged",
    "paged_pallas", "int", "fhe_sim")

#: Backends that consume a :class:`Structural` description and must never
#: be handed a materialized (n_q, n_k) mask array.
MASK_FREE_BACKENDS = frozenset({"blocked", "pallas", "paged_pallas"})

#: Backends that consume a page pool + :class:`PagedLayout` instead of
#: contiguous (b, n_k, h_kv, d) key/value tensors.
PAGED_BACKENDS = frozenset({"paged", "paged_pallas"})

DEFAULT_BLOCKED_THRESHOLD = 1 << 20   # n_q·n_k above which dense masks are
                                      # unreasonable (formerly inline in
                                      # apply_attention)
DEFAULT_CHUNKED_THRESHOLD = 4096


# ---------------------------------------------------------------------------
# Planner inputs / outputs
# ---------------------------------------------------------------------------

class AttnShapes(NamedTuple):
    """Shape/placement facts the planner keys on (all static at trace time).

    ``scalar_cursor`` is False for ragged continuous batching (per-slot
    cache cursors), where structural masks cannot be expressed from a
    single query offset.  ``platform`` defaults to the active JAX backend.
    """
    batch: int
    n_q: int
    n_k: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    dtype: Any = jnp.float32
    has_explicit_mask: bool = False
    is_cross: bool = False
    has_cache: bool = False
    scalar_cursor: bool = True
    platform: Optional[str] = None
    paged: bool = False          # KV lives in a paged pool (block tables)

    @property
    def resolved_platform(self) -> str:
        return self.platform or jax.default_backend()

    @property
    def score_elements(self) -> int:
        return self.n_q * self.n_k


@dataclasses.dataclass(frozen=True)
class Structural:
    """Mask structure for :data:`MASK_FREE_BACKENDS` — computed from
    indices inside the backend, never materialized.  ``q_offset`` /
    ``kv_valid_len`` may be traced int32 scalars (decode cursors)."""
    causal: bool = True
    window: Optional[int] = None
    q_offset: Any = 0
    kv_valid_len: Any = None


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Block-table layout for the ``paged`` backend.  ``k``/``v`` arrive as
    page pools (num_pages, page_size, h_kv, d); ``block_tables``
    (b, pages_per_slot) int32 maps each batch row's logical page index to a
    physical page.  Validity is expressed through the ordinary mask path
    (the gathered view is logically contiguous per row)."""
    block_tables: Any
    page_size: int


@dataclasses.dataclass(frozen=True)
class MechanismParams:
    """Union of per-call mechanism hyper-parameters.  Each backend reads
    the fields it understands (``signed`` is fixed per mechanism via
    :attr:`Mechanism.param_overrides`; dot-product ignores the shift).
    The ``kernel_*`` fields override the kernel registry's tuned block
    sizes (``None`` = registry decides — DESIGN.md §10)."""
    score_scale: Optional[float] = None
    score_shift: float = 0.0
    signed: bool = True
    normalize: bool = True
    kv_chunk: int = 256
    kernel_block_q: Optional[int] = None
    kernel_block_k: Optional[int] = None
    kernel_sub_k: Optional[int] = None
    kernel_pages_per_step: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """An inspectable dispatch decision: which mechanism implementation
    runs, on which backend, and why the planner chose it."""
    mechanism: str
    backend: str
    reason: str

    def trace_line(self) -> str:
        return (f"plan: mechanism={self.mechanism} backend={self.backend} "
                f"reason={self.reason}")


# ---------------------------------------------------------------------------
# Mechanism + registry
# ---------------------------------------------------------------------------

# Uniform backend signature:
#   fn(q, k, v, *, mask=None, params: MechanismParams,
#      structural: Optional[Structural] = None) -> (b, n_q, h, d)
BackendFn = Callable[..., jax.Array]


@dataclasses.dataclass(frozen=True)
class Mechanism:
    """One attention mechanism: semantics + its backend implementations.

    ``mask_semantics``: how disallowed pairs are suppressed —
      * ``"exclude"``  masked pairs are excluded from the combining sums
                       (inhibitor family; additive large constants would
                       be cancellation-prone in the fused decomposition)
      * ``"neg_inf"``  masked logits are driven to −inf before Softmax
    ``vjp``: gradient-path hint — ``"analytic"`` (custom VJP, recompute-
    based residuals) or ``"autodiff"``.
    ``lane_fn``: the lane-generic integer form of the mechanism
    (``fn(lane, q, k, v, *, mask, **mechanism_kwargs)`` at (..., n, d)
    per-head layout) — the single implementation behind the ``int`` and
    ``fhe_sim`` backends *and* the lane-parameterized model forward
    (DESIGN.md §9).
    ``fhe_circuit`` / ``int_reference``: the raw numpy TFHE circuit and
    raw integer-lane reference the benchmark drivers consume directly
    (both are thin lane dispatches of ``lane_fn``).
    """
    name: str
    description: str
    mask_semantics: str
    vjp: str
    backends: Mapping[str, BackendFn]
    param_overrides: Mapping[str, Any] = dataclasses.field(
        default_factory=dict)
    lane_fn: Optional[Callable] = None
    fhe_circuit: Optional[Callable] = None
    int_reference: Optional[Callable] = None

    def make_params(self, **kw) -> MechanismParams:
        kw.update(self.param_overrides)
        return MechanismParams(**kw)


_REGISTRY: Dict[str, Mechanism] = {}


def register_mechanism(mech: Mechanism, *, overwrite: bool = False) -> Mechanism:
    """Register ``mech`` under ``mech.name``.  Re-registration requires
    ``overwrite=True`` so accidental shadowing fails loudly."""
    unknown = set(mech.backends) - set(BACKENDS)
    if unknown:
        raise ValueError(
            f"mechanism {mech.name!r} declares unknown backends {sorted(unknown)}; "
            f"known: {BACKENDS}")
    if mech.name in _REGISTRY and not overwrite:
        raise ValueError(f"mechanism {mech.name!r} already registered "
                         f"(pass overwrite=True to replace)")
    _REGISTRY[mech.name] = mech
    return mech


def get_mechanism(name: str) -> Mechanism:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown attention mechanism {name!r}; registered: "
            f"{available_mechanisms()}") from None


def available_mechanisms() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# Eligibility + planning
# ---------------------------------------------------------------------------

def backend_eligible(backend: str, cfg, shapes: AttnShapes,
                     mech: Mechanism) -> Tuple[bool, str]:
    """Can ``backend`` run this mechanism at these shapes?  Returns
    (ok, why_not) — the reason string feeds plan traces and errors."""
    if backend not in mech.backends:
        return False, f"not registered for mechanism {mech.name!r}"
    paged = getattr(shapes, "paged", False)
    if paged and backend not in PAGED_BACKENDS:
        return False, "KV lives in a paged pool (block-table backends only)"
    if backend in PAGED_BACKENDS:
        if not paged:
            return False, "no paged KV pool at this call site"
        if shapes.has_explicit_mask or shapes.is_cross:
            return False, "paged pools serve cached causal self-attention"
    if backend == "paged_pallas" and shapes.n_q != 1:
        return False, (f"paged decode kernel is single-query (n_q=1); "
                       f"n_q={shapes.n_q} prefill goes through the gather "
                       f"path")
    is_int = jnp.issubdtype(jnp.dtype(shapes.dtype), jnp.integer)
    if backend in ("int", "fhe_sim") and not is_int:
        return False, "requires integer-lane inputs"
    if backend not in ("int", "fhe_sim") and is_int:
        return False, "float backend on integer-lane inputs"
    if backend in MASK_FREE_BACKENDS:
        if shapes.has_explicit_mask:
            return False, "explicit mask array given (structural masks only)"
        if shapes.is_cross:
            return False, "cross-attention has no structural mask"
    if backend == "blocked" and not shapes.scalar_cursor:
        # the flash kernels take per-row cursor operands; blocked does not
        return False, "ragged per-slot cursors (no shared query offset)"
    if backend == "fhe_sim":
        if shapes.has_explicit_mask or shapes.is_cross or shapes.has_cache:
            return False, "circuit is self-attention without masking"
        if getattr(cfg, "causal", False) or getattr(cfg, "sliding_window",
                                                    None) is not None:
            return False, "circuit attends all-to-all (non-causal only)"
    return True, ""


def kernel_family(mechanism: str) -> str:
    """Registry kernel family that implements ``mechanism``'s Pallas
    path (the key into ``kernels.ops.NATIVE_PLATFORMS`` / autotune
    candidates): the inhibitor variants share the "inhibitor" family,
    every dot-product mechanism the "flash" family."""
    return ("inhibitor" if mechanism in ("inhibitor", "inhibitor_unsigned")
            else "flash")


def kernel_native(family: str, platform: str) -> bool:
    """True when ``family``'s Pallas body lowers natively on
    ``platform`` (the kernel module's own ``LOWERS_ON`` declaration, via
    ``kernels.ops.NATIVE_PLATFORMS``).  The planner keys every kernel
    preference on this instead of hard-coding ``== "tpu"``: anywhere a
    family is non-native the kernel would run interpret-mode Pallas —
    orders of magnitude slower than the XLA gather/blocked paths — so it
    must never be *preferred*, only reachable by forcing the backend."""
    from repro.kernels.ops import NATIVE_PLATFORMS
    return platform in NATIVE_PLATFORMS.get(family, ("tpu",))


_traced_plans: set = set()
_use_kernel_warned = False
_kind_warned = False


def _trace(plan: ExecutionPlan, shapes: Optional[AttnShapes] = None) -> None:
    """One-line plan trace, deduplicated per (mechanism, backend) so
    per-layer tracing and varying sequence lengths (whose reasons embed
    concrete shape numbers) do not spam serve/train logs or grow the
    dedup set unboundedly."""
    key = (plan.mechanism, plan.backend)
    if key in _traced_plans:
        return
    _traced_plans.add(key)
    if shapes is not None:
        log.info("%s [n_q=%d n_k=%d heads=%d platform=%s]", plan.trace_line(),
                 shapes.n_q, shapes.n_k, shapes.num_heads,
                 shapes.resolved_platform)
    else:
        log.info("%s", plan.trace_line())


def resolve_mechanism_name(cfg) -> str:
    """``cfg.mechanism`` when set, else the deprecated ``cfg.kind`` (one
    ``DeprecationWarning`` per process), else the ``"dotprod"`` default."""
    global _kind_warned
    name = getattr(cfg, "mechanism", None)
    if name:
        return name
    kind = getattr(cfg, "kind", None)
    if kind:
        if not _kind_warned:
            _kind_warned = True
            warnings.warn(
                "AttentionConfig.kind is deprecated; set mechanism="
                f"{kind!r} (the registry key) instead",
                DeprecationWarning, stacklevel=2)
        return kind
    return "dotprod"


def plan_attention(cfg, shapes: AttnShapes) -> ExecutionPlan:
    """The planner: explicit, inspectable backend selection.

    Selection order (first eligible wins):

      1. ``cfg.backend`` — forced; ineligibility is an error.
      2. ``cfg.use_kernel`` — deprecated shim for ``backend="pallas"``;
         falls back to automatic selection when the kernel cannot run
         (explicit mask), since the legacy bool could not express
         eligibility.
      3. ``paged_pallas`` on TPU when the KV cache lives in a paged pool
         and this is a single-query decode tick — the block-table-native
         kernel (DESIGN.md §10).
      4. ``paged`` for the remaining paged-pool sites (non-TPU hosts,
         chunked prefill) — the clamped block-table gather.
      5. ``int`` when the inputs are integer lanes.
      6. ``pallas`` on TPU at large structural-mask shapes.
      7. ``blocked`` at large structural-mask shapes
         (``n_q·n_k ≥ cfg.blocked_threshold``).
      8. ``chunked`` when ``n_k > cfg.chunked_threshold``.
      9. ``fused`` (dense default), else ``naive``.
    """
    global _use_kernel_warned
    name = resolve_mechanism_name(cfg)
    mech = get_mechanism(name)

    forced = getattr(cfg, "backend", None)
    shim_note = ""
    # deprecation shim: the legacy bool only ever dispatched the inhibitor
    # family to the kernel (it was a no-op for dotprod), so the shim
    # preserves exactly those semantics — new mechanisms/backends must use
    # the explicit ``backend`` field
    legacy_kernel_mechanism = name in ("inhibitor", "inhibitor_unsigned")
    if (forced is None and getattr(cfg, "use_kernel", False)
            and legacy_kernel_mechanism):
        if not _use_kernel_warned:
            _use_kernel_warned = True
            warnings.warn(
                "AttentionConfig.use_kernel is deprecated; set "
                "backend='pallas' (or leave backend=None for the planner)",
                DeprecationWarning, stacklevel=2)
        # the legacy bool meant "use the TPU kernel" — on non-TPU hosts it
        # would run interpret-mode Pallas (orders of magnitude slower than
        # the XLA paths), which no legacy config ever did intentionally;
        # force an explicit backend="pallas" to get interpret mode
        ok, why = backend_eligible("pallas", cfg, shapes, mech)
        if ok and not kernel_native(kernel_family(name),
                                    shapes.resolved_platform):
            ok, why = False, (f"host platform is "
                              f"{shapes.resolved_platform!r}, no native "
                              f"lowering — kernel would run in interpret "
                              f"mode")
        if ok:
            plan = ExecutionPlan(name, "pallas",
                                 "forced by config (use_kernel shim)")
            _trace(plan, shapes)
            return plan
        shim_note = f"use_kernel requested but pallas ineligible ({why}); "
    elif forced is not None:
        ok, why = backend_eligible(forced, cfg, shapes, mech)
        if not ok:
            raise ValueError(
                f"backend {forced!r} forced by config but ineligible for "
                f"mechanism {name!r} at {shapes!r}: {why}")
        plan = ExecutionPlan(name, forced, "forced by config")
        _trace(plan, shapes)
        return plan

    def eligible(b: str) -> bool:
        return backend_eligible(b, cfg, shapes, mech)[0]

    total = shapes.score_elements
    blocked_at = getattr(cfg, "blocked_threshold", DEFAULT_BLOCKED_THRESHOLD)
    chunked_at = getattr(cfg, "chunked_threshold", DEFAULT_CHUNKED_THRESHOLD)

    if (kernel_native("paged", shapes.resolved_platform)
            and eligible("paged_pallas")):
        plan = ExecutionPlan(
            name, "paged_pallas",
            shim_note + f"paged KV pool, single-query decode "
            f"(block-table-native kernel lowers natively on "
            f"{shapes.resolved_platform!r})")
    elif eligible("paged"):
        if getattr(shapes, "paged", False) and shapes.n_q != 1:
            why = f"chunked prefill n_q={shapes.n_q}"
        else:
            why = (f"no native paged-kernel lowering on "
                   f"{shapes.resolved_platform!r}; interpret-mode Pallas "
                   f"never outranks the gather")
        plan = ExecutionPlan(
            name, "paged",
            shim_note + f"paged KV pool (block-table gather: {why})")
    elif eligible("int"):
        plan = ExecutionPlan(name, "int", shim_note + "integer-lane inputs")
    elif (kernel_native(kernel_family(name), shapes.resolved_platform)
            and total >= blocked_at and eligible("pallas")):
        plan = ExecutionPlan(
            name, "pallas",
            shim_note + f"native pallas lowering on "
            f"{shapes.resolved_platform!r}, structural mask, "
            f"n_q*n_k={total} >= blocked_threshold={blocked_at}")
    elif total >= blocked_at and eligible("blocked"):
        plan = ExecutionPlan(
            name, "blocked",
            shim_note + f"structural mask and n_q*n_k={total} >= "
            f"blocked_threshold={blocked_at}")
    elif shapes.n_k > chunked_at and eligible("chunked"):
        plan = ExecutionPlan(
            name, "chunked",
            shim_note + f"n_k={shapes.n_k} > chunked_threshold={chunked_at}")
    elif eligible("fused"):
        plan = ExecutionPlan(name, "fused", shim_note + "dense default")
    elif eligible("naive"):
        plan = ExecutionPlan(name, "naive",
                             shim_note + "only the oracle backend is eligible")
    else:
        raise ValueError(
            f"no eligible backend for mechanism {name!r} at {shapes!r} "
            f"(registered: {sorted(mech.backends)})")
    _trace(plan, shapes)
    return plan


def choose_plan(mechanism: str, candidates) -> ExecutionPlan:
    """Generic first-eligible-wins chooser for non-(q, k, v) token mixers
    (e.g. the RWKV WKV path).  ``candidates`` is an ordered iterable of
    ``(backend, eligible, reason)``; the chosen plan is trace-logged like
    :func:`plan_attention` decisions."""
    for backend, ok, reason in candidates:
        if ok:
            plan = ExecutionPlan(mechanism, backend, reason)
            _trace(plan)
            return plan
    raise ValueError(f"no eligible backend among candidates for "
                     f"{mechanism!r}")


def execute_plan(plan: ExecutionPlan, q, k, v, *,
                 params: MechanismParams,
                 mask=None,
                 structural: Optional[Structural] = None,
                 paged: Optional[PagedLayout] = None) -> jax.Array:
    """Run ``plan`` on (q, k, v): q (b, n_q, h, d); k, v (b, n_k, h_kv, d).

    ``mask`` is only legal for mask-consuming backends; mask-free backends
    take ``structural`` instead.  Mixing the two is a dispatch bug and
    fails loudly.  For the ``paged`` backend, k/v are page pools
    (num_pages, page_size, h_kv, d) and ``paged`` carries the block tables.
    """
    mech = get_mechanism(plan.mechanism)
    fn = mech.backends.get(plan.backend)
    if fn is None:
        raise ValueError(f"plan names backend {plan.backend!r} which is not "
                         f"registered for mechanism {plan.mechanism!r}")
    if plan.backend in MASK_FREE_BACKENDS and mask is not None:
        raise ValueError(f"backend {plan.backend!r} is mask-free; got an "
                         f"explicit mask array")
    if (paged is not None) != (plan.backend in PAGED_BACKENDS):
        raise ValueError(
            f"backend {plan.backend!r} and paged layout "
            f"{'given' if paged is not None else 'missing'} — paged pools "
            f"are only consumable by {sorted(PAGED_BACKENDS)}")
    if plan.backend in PAGED_BACKENDS:
        return fn(q, k, v, mask=mask, params=params, structural=structural,
                  paged=paged)
    return fn(q, k, v, mask=mask, params=params, structural=structural)


# ---------------------------------------------------------------------------
# Shared layout helpers for the builtin backends
# ---------------------------------------------------------------------------

def _to_heads(q, k, v):
    """(b, n, h|h_kv, d) -> GQA-repeated (b, h, n, d) triples (float32 kept
    by the callee; this only handles layout)."""
    from repro.core.inhibitor import _repeat_kv

    h = q.shape[2]
    rep = h // k.shape[2]
    k = _repeat_kv(k, rep)
    v = _repeat_kv(v, rep)
    return (q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3))


def _int_shifts(params: MechanismParams, d: int) -> Tuple[int, int]:
    """Map the float-domain (γ, α) onto the integer lanes' power-of-two
    analogues: γ ≈ 2^shift, α rounded to the nearest integer level."""
    import math

    gamma = (params.score_scale if params.score_scale is not None
             else float(d) ** 0.5)
    shift = max(0, int(round(math.log2(gamma)))) if gamma > 1 else 0
    return shift, max(0, int(round(params.score_shift)))


# ---------------------------------------------------------------------------
# Builtin backends — inhibitor family (signed fixed per mechanism)
# ---------------------------------------------------------------------------

def _inhibitor_naive(q, k, v, *, mask=None, params, structural=None):
    """Broadcast oracle: eq. 5 scores, large-Z masking, eq. 6/7 inhibition."""
    from repro.core import inhibitor as inh

    n_k = k.shape[1]
    qt, kt, vt = _to_heads(q, k, v)
    z = inh.manhattan_scores(qt, kt, score_scale=params.score_scale,
                             score_shift=params.score_shift)
    m = None
    if mask is not None:
        m = jnp.broadcast_to(mask, z.shape)
        z = inh.mask_scores(z, m)
    out = (inh.inhibit_signed_naive(vt, z) if params.signed
           else inh.inhibit_naive(vt, z))
    if params.normalize:
        if m is not None:
            cnt = jnp.sum(m.astype(jnp.float32), axis=-1, keepdims=True)
        else:
            cnt = jnp.asarray(float(n_k), jnp.float32)
        out = out / jnp.maximum(cnt, 1.0)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def _inhibitor_fused(q, k, v, *, mask=None, params, structural=None):
    from repro.core import inhibitor as inh

    return inh.inhibitor_attention(
        q, k, v, mask=mask, score_scale=params.score_scale,
        score_shift=params.score_shift, signed=params.signed,
        normalize=params.normalize)


def _inhibitor_chunked(q, k, v, *, mask=None, params, structural=None):
    from repro.core import inhibitor as inh

    return inh.inhibitor_attention_chunked(
        q, k, v, mask=mask, score_scale=params.score_scale,
        score_shift=params.score_shift, signed=params.signed,
        normalize=params.normalize, kv_chunk=params.kv_chunk)


def _inhibitor_blocked(q, k, v, *, mask=None, params, structural=None):
    from repro.core.blocked import blocked_inhibitor_attention

    s = structural or Structural()
    return blocked_inhibitor_attention(
        q, k, v, score_scale=params.score_scale,
        score_shift=params.score_shift, signed=params.signed,
        normalize=params.normalize, causal=s.causal, window=s.window,
        q_offset=s.q_offset, kv_valid_len=s.kv_valid_len,
        chunk_k=params.kv_chunk, chunk_q=min(params.kv_chunk, 512))


def _kernel_choice(params: MechanismParams):
    """Config block-size overrides -> a :class:`repro.kernels.ops.
    KernelChoice` (or None, letting the kernel registry tune)."""
    if (params.kernel_block_q is None and params.kernel_block_k is None
            and params.kernel_sub_k is None
            and params.kernel_pages_per_step is None):
        return None
    from repro.kernels.ops import KernelChoice

    return KernelChoice(params.kernel_block_q, params.kernel_block_k,
                        params.kernel_sub_k, params.kernel_pages_per_step)


def _structural_is_plain(s: Structural) -> bool:
    """True when the Structural carries no decode-cache cursors — the
    custom-VJP training kernel applies; otherwise the cursor-carrying
    (inference-only) entry point is used."""
    return (s.kv_valid_len is None
            and isinstance(s.q_offset, int) and s.q_offset == 0)


def _inhibitor_pallas(q, k, v, *, mask=None, params, structural=None):
    from repro.kernels import ops as kops

    s = structural or Structural()
    choice = _kernel_choice(params)
    if _structural_is_plain(s):
        return kops.flash_inhibitor(q, k, v, params.score_scale,
                                    params.score_shift, params.signed,
                                    params.normalize, s.causal, s.window,
                                    choice)
    return kops.flash_inhibitor_cached(
        q, k, v, s.q_offset, s.kv_valid_len, score_scale=params.score_scale,
        score_shift=params.score_shift, signed=params.signed,
        normalize=params.normalize, causal=s.causal, window=s.window,
        choice=choice)


def _gather_pages(k_pool, v_pool, paged: PagedLayout):
    """Gather per-row contiguous KV views out of the page pools.

    k_pool/v_pool: (num_pages, page_size, h_kv, d); block tables (b, P).
    Returns (b, P*page_size, h_kv, d) views — one gather per call, fused by
    XLA into the downstream reads.  Unmapped table entries point at the
    reserved trash page 0; those rows sit beyond the valid-length mask.

    This is the non-TPU / prefill fallback: the serve engine clamps the
    table width handed in here to the bucketed batch high-water page
    count, so the gather is O(pages actually held), not O(pool) — and on
    TPU single-query decode the planner selects ``paged_pallas`` instead,
    which never materializes this view at all (DESIGN.md §10).
    """
    kt = k_pool[paged.block_tables]            # (b, P, ps, h_kv, d)
    vt = v_pool[paged.block_tables]
    b, npg, ps, hk, d = kt.shape
    return (kt.reshape(b, npg * ps, hk, d), vt.reshape(b, npg * ps, hk, d))


def _paged_lengths(q, s: Structural):
    """Per-row valid-length cursors for the paged decode kernels."""
    if s.kv_valid_len is None:
        raise ValueError(
            "paged_pallas needs per-row kv_valid_len cursors (the paged "
            "cache always carries them); got Structural(kv_valid_len=None)")
    lengths = jnp.asarray(s.kv_valid_len, jnp.int32)
    return jnp.broadcast_to(jnp.atleast_1d(lengths), (q.shape[0],))


def _inhibitor_paged(q, k, v, *, mask=None, params, structural=None,
                     paged=None):
    kc, vc = _gather_pages(k, v, paged)
    return _inhibitor_fused(q, kc, vc, mask=mask, params=params)


def _inhibitor_paged_pallas(q, k, v, *, mask=None, params, structural=None,
                            paged=None):
    """Block-table-native decode: k/v are page pools; the kernel grid
    walks each row's block table (no contiguous gather)."""
    from repro.kernels import ops as kops

    s = structural or Structural()
    return kops.paged_flash_inhibitor(
        q, k, v, paged.block_tables, _paged_lengths(q, s),
        score_scale=params.score_scale, score_shift=params.score_shift,
        signed=params.signed, normalize=params.normalize, window=s.window,
        choice=_kernel_choice(params))


def _inhibitor_int(q, k, v, *, mask=None, params, structural=None):
    """Lane dispatch: the mechanism's lane_fn on the jnp int32 lane."""
    from repro.core.lanes import IntLane
    from repro.quant.int_attention import (lane_attention_heads,
                                           lane_inhibitor_attention)

    gamma_shift, alpha_q = _int_shifts(params, q.shape[-1])
    return lane_attention_heads(
        IntLane(), lane_inhibitor_attention, q, k, v, mask=mask,
        gamma_shift=gamma_shift, alpha_q=alpha_q, signed=params.signed,
        normalize=params.normalize)


# ---------------------------------------------------------------------------
# Builtin backends — dot-product (Softmax) family
# ---------------------------------------------------------------------------

def _dotprod_naive(q, k, v, *, mask=None, params, structural=None):
    """Plain-jnp Softmax oracle (no custom VJP — autodiff reference)."""
    d = q.shape[-1]
    scale = (params.score_scale if params.score_scale is not None
             else float(d) ** 0.5)
    qt, kt, vt = _to_heads(q, k, v)
    logits = jnp.einsum("bhqd,bhkd->bhqk", qt.astype(jnp.float32),
                        kt.astype(jnp.float32)) / scale
    if mask is not None:
        logits = jnp.where(jnp.broadcast_to(mask, logits.shape), logits,
                           -1e9)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vt.astype(jnp.float32))
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def _dotprod_fused(q, k, v, *, mask=None, params, structural=None):
    from repro.core import dotprod as dp

    return dp.dot_product_attention(q, k, v, mask=mask,
                                    score_scale=params.score_scale)


def _dotprod_pallas(q, k, v, *, mask=None, params, structural=None):
    from repro.kernels import ops as kops

    s = structural or Structural()
    choice = _kernel_choice(params)
    if _structural_is_plain(s):
        return kops.flash_attention(q, k, v, params.score_scale, s.causal,
                                    s.window, choice)
    return kops.flash_attention_cached(
        q, k, v, s.q_offset, s.kv_valid_len, score_scale=params.score_scale,
        causal=s.causal, window=s.window, choice=choice)


def _dotprod_paged(q, k, v, *, mask=None, params, structural=None,
                   paged=None):
    kc, vc = _gather_pages(k, v, paged)
    return _dotprod_fused(q, kc, vc, mask=mask, params=params)


def _dotprod_paged_pallas(q, k, v, *, mask=None, params, structural=None,
                          paged=None):
    from repro.kernels import ops as kops

    s = structural or Structural()
    return kops.paged_flash_attention(
        q, k, v, paged.block_tables, _paged_lengths(q, s),
        score_scale=params.score_scale, window=s.window,
        choice=_kernel_choice(params))


def _dotprod_int(q, k, v, *, mask=None, params, structural=None):
    """Lane dispatch: the mechanism's lane_fn on the jnp int32 lane."""
    from repro.core.lanes import IntLane
    from repro.quant.int_attention import (lane_attention_heads,
                                           lane_dot_product_attention)

    scale_shift, _ = _int_shifts(params, q.shape[-1])
    return lane_attention_heads(
        IntLane(), lane_dot_product_attention, q, k, v, mask=mask,
        scale_shift=scale_shift)


# ---------------------------------------------------------------------------
# fhe_sim adapter (lane dispatch onto the TFHE simulator; forced only)
# ---------------------------------------------------------------------------

def _fhe_backend(lane_fn, *, use_signed=False, **lane_kw):
    """Adapt the mechanism's lane_fn, run on a fresh :class:`FheSimLane`,
    to the uniform (b, n, h, d) layout.  Runs outside jit (concrete
    integer arrays)."""
    import numpy as np

    def fn(q, k, v, *, mask=None, params=None, structural=None):
        from repro.core import lanes
        from repro.quant.int_attention import lane_attention_heads

        if mask is not None:
            raise ValueError("fhe_sim circuits attend all-to-all; explicit "
                             "masks are unsupported")
        lane = lanes.FheSimLane()
        kw = dict(lane_kw)
        if use_signed and params is not None:
            kw["signed"] = params.signed
            kw["normalize"] = params.normalize
        qn, kn, vn = (lane.array(np.asarray(jax.device_get(t),
                                            dtype=np.int64))
                      for t in (q, k, v))
        out = lane_attention_heads(lane, lane_fn, qn, kn, vn, **kw)
        return jnp.asarray(lane.to_numpy(out).astype(np.int32))

    return fn


# ---------------------------------------------------------------------------
# Builtin registrations
# ---------------------------------------------------------------------------

def _register_builtins() -> None:
    from repro.fhe.circuits import (dotprod_attention_circuit,
                                    inhibitor_attention_circuit)
    from repro.quant.int_attention import (int_dot_product_attention,
                                           int_inhibitor_attention,
                                           lane_dot_product_attention,
                                           lane_inhibitor_attention)

    register_mechanism(Mechanism(
        name="dotprod",
        description="Scaled dot-product Softmax attention (paper eq. 3)",
        mask_semantics="neg_inf",
        vjp="analytic",
        backends={
            "naive": _dotprod_naive,
            "fused": _dotprod_fused,
            "pallas": _dotprod_pallas,
            "paged": _dotprod_paged,
            "paged_pallas": _dotprod_paged_pallas,
            "int": _dotprod_int,
            "fhe_sim": _fhe_backend(lane_dot_product_attention,
                                    scale_shift=2, frac_bits=4),
        },
        lane_fn=lane_dot_product_attention,
        fhe_circuit=dotprod_attention_circuit,
        int_reference=int_dot_product_attention,
    ))

    _inhibitor_backends = {
        "naive": _inhibitor_naive,
        "fused": _inhibitor_fused,
        "chunked": _inhibitor_chunked,
        "blocked": _inhibitor_blocked,
        "pallas": _inhibitor_pallas,
        "paged": _inhibitor_paged,
        "paged_pallas": _inhibitor_paged_pallas,
        "int": _inhibitor_int,
        # the encrypted arm runs the same lane_fn on the TFHE simulator;
        # ``signed`` follows the mechanism (eq. 7 doubles the ReLU LUTs)
        "fhe_sim": _fhe_backend(lane_inhibitor_attention, use_signed=True,
                                gamma_shift=1, alpha_q=1),
    }
    register_mechanism(Mechanism(
        name="inhibitor",
        description="Signed inhibitor attention (paper eq. 7 / fused eq. 10)",
        mask_semantics="exclude",
        vjp="analytic",
        backends=dict(_inhibitor_backends),
        param_overrides={"signed": True},
        lane_fn=lane_inhibitor_attention,
        fhe_circuit=inhibitor_attention_circuit,
        int_reference=int_inhibitor_attention,
    ))
    register_mechanism(Mechanism(
        name="inhibitor_unsigned",
        description="Unsigned inhibitor attention (paper eq. 6 / fused eq. 9)",
        mask_semantics="exclude",
        vjp="analytic",
        backends=dict(_inhibitor_backends),
        param_overrides={"signed": False},
        lane_fn=lane_inhibitor_attention,
        fhe_circuit=inhibitor_attention_circuit,
        int_reference=int_inhibitor_attention,
    ))


_register_builtins()
