"""Both attention mechanisms as TFHE circuits — lane dispatches.

These are the encrypted counterparts of the paper's scaling experiment
(single head, embedding dim ≤ 4, integers up to 8-bit).  Since the lane
refactor (DESIGN.md §9) the circuit *is* the lane-generic mechanism from
:mod:`repro.quant.int_attention` executed on a :class:`FheSimLane` — one
algorithm shared with the plaintext int arm, bit-exact by construction —
and these wrappers only keep the historical (T, d)-per-head numpy
signature the Table 2/4 drivers and tests consume.  Each returns the
exact integer result plus the per-circuit cost summary.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.fhe.tfhe_sim import FheContext

# NOTE: the lane machinery is imported inside the wrappers — this module
# is imported by repro.core.mechanism during its builtin registration, so
# a top-level import of repro.core.lanes would be circular.


def inhibitor_attention_circuit(
    q: np.ndarray,     # (T, d) int
    k: np.ndarray,     # (T, d) int
    v: np.ndarray,     # (T, d) int
    *,
    gamma_shift: int = 0,
    alpha_q: int = 0,
    signed: bool = False,
    ctx: Optional[FheContext] = None,
) -> Tuple[np.ndarray, dict]:
    """Encrypted Inhibitor attention (paper eq. 5 + 6/7, integer form).

    PBS inventory per (T, d) single head:
      * scores:     T²·d  abs-LUTs  (+ T² shift-ReLU LUTs when α > 0)
      * inhibition: T²·d  ReLU-LUTs  (doubled when ``signed``)
    No ciphertext multiplications at all — additions are levelled.
    """
    from repro.core.lanes import FheSimLane
    from repro.quant.int_attention import lane_inhibitor_attention

    lane = FheSimLane(ctx)
    h = lane_inhibitor_attention(
        lane, lane.array(q), lane.array(k), lane.array(v),
        gamma_shift=gamma_shift, alpha_q=alpha_q, signed=signed)
    return lane.to_numpy(h), lane.ctx.summary()


def dotprod_attention_circuit(
    q: np.ndarray,
    k: np.ndarray,
    v: np.ndarray,
    *,
    scale_shift: int = 0,
    softmax_frac_bits: int = 4,
    ctx: Optional[FheContext] = None,
) -> Tuple[np.ndarray, dict]:
    """Encrypted dot-product attention (paper's baseline arm).

    PBS inventory per (T, d) single head:
      * QKᵀ:      2·T²·d  (cipher muls, 2 PBS each)
      * softmax:  T²  max-tree + T² exp-LUTs + T² cipher muls with the
                  reciprocal (2 PBS each) + T reciprocal LUTs
      * S·V:      2·T²·d  (cipher muls)
    ≈ 4·T²·d + 5·T² PBS — about twice the inhibitor, with wider messages
    (the products' a±b PBS inputs add ~1 bit; accumulated scores add more).
    The exp window is clipped to [−15, 0]: deeper scores quantize to 0
    probability anyway at paper-scale fractional precision.
    """
    from repro.core.lanes import FheSimLane
    from repro.quant.int_attention import lane_dot_product_attention

    lane = FheSimLane(ctx)
    h = lane_dot_product_attention(
        lane, lane.array(q), lane.array(k), lane.array(v),
        scale_shift=scale_shift, frac_bits=softmax_frac_bits, exp_clip=15)
    return lane.to_numpy(h), lane.ctx.summary()
