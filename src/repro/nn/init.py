"""Parameter initializers (pure functions, no global state)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def zeros(key, shape, dtype=jnp.float32):
    del key
    return jnp.zeros(shape, dtype)


def ones(key, shape, dtype=jnp.float32):
    del key
    return jnp.ones(shape, dtype)


def normal(stddev: float = 1.0):
    def _init(key, shape, dtype=jnp.float32):
        return (jax.random.normal(key, shape, jnp.float32) * stddev).astype(dtype)

    return _init


def truncated_normal(stddev: float = 1.0, lower: float = -2.0, upper: float = 2.0):
    def _init(key, shape, dtype=jnp.float32):
        x = jax.random.truncated_normal(key, lower, upper, shape, jnp.float32)
        # correct variance of the truncated distribution back to stddev
        c = stddev / 0.87962566103423978
        return (x * c).astype(dtype)

    return _init


def _fans(shape, in_axis=-2, out_axis=-1):
    if len(shape) < 1:
        return 1.0, 1.0
    if len(shape) == 1:
        return float(shape[0]), float(shape[0])
    receptive = 1.0
    for i, d in enumerate(shape):
        if i not in (in_axis % len(shape), out_axis % len(shape)):
            receptive *= d
    return shape[in_axis] * receptive, shape[out_axis] * receptive


def variance_scaling(scale: float, mode: str, distribution: str,
                     in_axis=-2, out_axis=-1):
    """flax-compatible variance-scaling initializer family."""

    def _init(key, shape, dtype=jnp.float32):
        fan_in, fan_out = _fans(shape, in_axis, out_axis)
        if mode == "fan_in":
            denom = max(1.0, fan_in)
        elif mode == "fan_out":
            denom = max(1.0, fan_out)
        elif mode == "fan_avg":
            denom = max(1.0, (fan_in + fan_out) / 2.0)
        else:
            raise ValueError(mode)
        var = scale / denom
        if distribution == "truncated_normal":
            return truncated_normal(math.sqrt(var))(key, shape, dtype)
        if distribution == "normal":
            return normal(math.sqrt(var))(key, shape, dtype)
        if distribution == "uniform":
            lim = math.sqrt(3.0 * var)
            return (jax.random.uniform(key, shape, jnp.float32, -lim, lim)
                    ).astype(dtype)
        raise ValueError(distribution)

    return _init


def lecun_normal(in_axis=-2, out_axis=-1):
    return variance_scaling(1.0, "fan_in", "truncated_normal", in_axis, out_axis)


def xavier_uniform(in_axis=-2, out_axis=-1):
    return variance_scaling(1.0, "fan_avg", "uniform", in_axis, out_axis)


def he_normal(in_axis=-2, out_axis=-1):
    return variance_scaling(2.0, "fan_in", "truncated_normal", in_axis, out_axis)


def embedding_init(stddev: float = 0.02):
    return normal(stddev)
