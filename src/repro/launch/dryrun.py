import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any other import (jax locks the
# device count at first backend init) — see the multi-pod dry-run spec.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver:
  1. builds the production mesh (16×16 single-pod, 2×16×16 multi-pod),
  2. eval_shape's the model init + optimizer + decode states (ShapeDtype
     stand-ins only — no device allocation anywhere),
  3. jits the train/prefill/serve step with explicit in/out shardings,
  4. ``.lower().compile()`` — success proves the sharding config is
     coherent (no mismatched collectives, fits memory at compile),
  5. records ``memory_analysis()`` / ``cost_analysis()`` / the collective
     bytes parsed from the partitioned HLO into a JSON artifact under
     ``experiments/dryrun/`` for the roofline table (§Roofline).

Usage:
  python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod-only-smoke]
  python -m repro.launch.dryrun --list
"""

import argparse
import json
import re
import sys
import time
import traceback

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"\b(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred)\[([0-9,]*)\]")

# `%x = f32[32,64]{1,0} all-reduce(%dot), ... replica_groups=[2,4]<=[8]`
_INSTR_RE = re.compile(
    r"=\s+(?P<result>\(?[a-z0-9]+\[[0-9,]*\][^ ]*(?:,\s*[a-z0-9]+\[[0-9,]*\]"
    r"[^ )]*)*\)?)\s+(?P<kind>all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)(?P<start>-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(m) -> int:
    dt, dims = m.group(1), m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def parse_collective_bytes(hlo_text: str) -> dict:
    """Per-chip wire bytes per collective kind, from the partitioned HLO.

    Shapes in the partitioned module are per-device; the RESULT shape is
    used with the ring-algorithm wire factor for a group of size g:
      all-gather         r·(g−1)/g      (receives everyone else's shard)
      all-reduce         2·r·(g−1)/g    (reduce-scatter + all-gather)
      reduce-scatter     r·(g−1)        (result r is the scattered shard)
      all-to-all         r·(g−1)/g
      collective-permute r              (one hop)
    Async -start ops are counted; -done ops carry no new transfer.
    """
    per_kind = {k: 0.0 for k in COLLECTIVE_OPS}
    counts = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        kind = m.group("kind")
        r_bytes = sum(_shape_bytes(sm)
                      for sm in _SHAPE_RE.finditer(m.group("result")))
        gm = _GROUPS_RE.search(line)
        g = int(gm.group(2)) if gm else 2
        if g <= 1:
            continue
        if kind == "all-gather":
            wire = r_bytes * (g - 1) / g
        elif kind == "all-reduce":
            wire = 2.0 * r_bytes * (g - 1) / g
        elif kind == "reduce-scatter":
            wire = float(r_bytes) * (g - 1)
        elif kind == "all-to-all":
            wire = r_bytes * (g - 1) / g
        else:  # collective-permute
            wire = float(r_bytes)
        per_kind[kind] += wire
        counts[kind] += 1
    total = sum(per_kind.values())
    return {"per_kind_bytes": {k: int(v) for k, v in per_kind.items()},
            "counts": counts, "total_bytes_per_chip": int(total)}


def _lower_one(cfg, shape, mesh, opts):
    """Lower + compile one step function. Returns (compiled, timings)."""
    import jax

    from repro.distributed.sharding import use_mesh
    from repro.launch import shardings as shlib
    from repro.models.registry import get_model
    from repro.optim.adamw import AdamWConfig, init_adamw
    from repro.train.step import (make_prefill_step, make_serve_step,
                                  make_train_step)

    api = get_model(cfg)
    t0 = time.time()
    with use_mesh(mesh, act_rules=opts.get("act_rules")):
        key = jax.random.PRNGKey(0)
        boxed_struct = jax.eval_shape(api.init, key)
        params_struct, params_sh = shlib.params_shardings(boxed_struct, mesh)
        specs = api.input_specs(shape)
        batch_sh = shlib.batch_shardings(specs, mesh)

        if shape.kind == "train":
            opt_cfg = AdamWConfig()
            opt_struct = jax.eval_shape(
                lambda p: init_adamw(p, opt_cfg), params_struct)
            opt_sh = shlib.opt_shardings(opt_struct, params_sh, mesh)
            step = make_train_step(api, opt_cfg,
                                   microbatches=opts.get("microbatches", 1))
            jitted = jax.jit(step,
                             in_shardings=(params_sh, opt_sh, batch_sh),
                             out_shardings=(params_sh, opt_sh, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_struct, opt_struct, specs)
        elif shape.kind == "prefill":
            step = make_prefill_step(api)
            jitted = jax.jit(step, in_shardings=(params_sh, batch_sh),
                             out_shardings=None)
            lowered = jitted.lower(params_struct, specs)
        else:  # decode
            states_struct = jax.eval_shape(
                lambda: api.init_states(shape.global_batch, shape.seq_len))
            states_sh = shlib.state_shardings(states_struct, mesh)
            step = make_serve_step(api)
            tokens_spec = specs.pop("tokens")
            tokens_sh = shlib.batch_shardings({"tokens": tokens_spec},
                                              mesh)["tokens"]
            extra_sh = shlib.batch_shardings(specs, mesh) if specs else None
            jitted = jax.jit(
                step,
                in_shardings=(params_sh, tokens_sh, states_sh, extra_sh),
                out_shardings=(None, None, states_sh),
                donate_argnums=(2,))
            lowered = jitted.lower(params_struct, tokens_spec, states_struct,
                                   specs if specs else None)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    return compiled, (t_lower, t_compile)


def _cost_vector(compiled) -> dict:
    cost = compiled.cost_analysis()
    out = {"flops": 0.0, "bytes accessed": 0.0, "transcendentals": 0.0}
    if isinstance(cost, dict):
        for k in out:
            out[k] = float(cost.get(k, 0.0) or 0.0)
    coll = parse_collective_bytes(compiled.as_text())
    out["collective_bytes"] = float(coll["total_bytes_per_chip"])
    out["_collectives"] = coll
    return out


def _mech_name(cfg):
    from repro.core.mechanism import resolve_mechanism_name

    return resolve_mechanism_name(cfg.attention)


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               attention_kind=None, *, opts=None, layer_extrapolate=True):
    """Lower+compile one cell. Returns the result record dict.

    Cost correction: XLA HLO cost analysis counts a While (lax.scan) body
    ONCE, not ×trip_count — verified empirically (ratio exactly equals the
    trip count).  We therefore lower unrolled depth-1 and depth-2 variants
    of the model at the same shape/mesh and extrapolate:
        corrected(L) = cost(d1) + (L − 1)·(cost(d2) − cost(d1))
    which is exact because every per-layer quantity (layer FLOPs, layer
    optimizer update, layer gradient collectives) is linear in depth.
    """
    import dataclasses

    from repro.configs import SHAPES_BY_NAME, get_config
    from repro.launch.mesh import make_production_mesh

    opts = opts or {}
    cfg = get_config(arch if attention_kind is None
                     else f"{arch}@{attention_kind}")
    if opts.get("remat"):
        cfg = dataclasses.replace(cfg, remat=opts["remat"])
    shape = SHAPES_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)

    compiled, (t_lower, t_compile) = _lower_one(cfg, shape, mesh, opts)
    mem = compiled.memory_analysis()
    raw = _cost_vector(compiled)

    corrected = None
    if layer_extrapolate:
        try:
            # aux lowerings use microbatches=1: the gradient-accumulation
            # scan is ALSO a While whose body cost analysis counts once,
            # and total step FLOPs/bytes are mb-invariant (same tokens)
            aux_opts = {k: v for k, v in opts.items()
                        if k != "microbatches"}
            c1, _ = _lower_one(cfg.with_layers(1, unroll=True), shape, mesh,
                               aux_opts)
            c2, _ = _lower_one(cfg.with_layers(2, unroll=True), shape, mesh,
                               aux_opts)
            v1, v2 = _cost_vector(c1), _cost_vector(c2)
            L = cfg.num_layers
            corrected = {
                k: v1[k] + (L - 1) * (v2[k] - v1[k])
                for k in ("flops", "bytes accessed", "transcendentals",
                          "collective_bytes")
            }
        except Exception as e:  # noqa: BLE001
            corrected = {"error": f"{type(e).__name__}: {e}"}

    def _mem_field(name):
        v = getattr(mem, name, None)
        return int(v) if v is not None else None

    record = {
        "arch": arch,
        "attention_kind": attention_kind or _mech_name(cfg),
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
        "kind": shape.kind,
        "ok": True,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": _mem_field("argument_size_in_bytes"),
            "output_bytes": _mem_field("output_size_in_bytes"),
            "temp_bytes": _mem_field("temp_size_in_bytes"),
            "generated_code_bytes": _mem_field(
                "generated_code_size_in_bytes"),
        },
        "cost_raw": {k: raw[k] for k in
                     ("flops", "bytes accessed", "transcendentals",
                      "collective_bytes")},
        "cost_per_chip": corrected,
        "collectives": raw["_collectives"],
        "opts": opts or {},
    }
    return record


def run_cell(arch, shape_name, multi_pod, attention_kind=None, opts=None,
             out_dir="experiments/dryrun"):
    os.makedirs(out_dir, exist_ok=True)
    tag = (f"{arch}_{shape_name}_{'2x16x16' if multi_pod else '16x16'}"
           + (f"_{attention_kind}" if attention_kind else "")
           + (f"_{opts['tag']}" if opts and opts.get("tag") else ""))
    try:
        rec = build_cell(arch, shape_name, multi_pod, attention_kind,
                         opts=opts)
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec = {"arch": arch, "shape": shape_name,
               "mesh": "2x16x16" if multi_pod else "16x16",
               "attention_kind": attention_kind, "ok": False,
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    path = os.path.join(out_dir, tag + ".json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    status = "OK" if rec.get("ok") else "FAIL"
    mem = rec.get("memory", {}).get("temp_bytes")
    print(f"[{status}] {tag}  temp={mem/1e9:.2f}GB" if mem else
          f"[{status}] {tag}", flush=True)
    if not rec.get("ok"):
        print("   ", rec.get("error"), flush=True)
    return rec


def cell_matrix():
    """The assigned 40 cells (+ noted skips) per DESIGN.md §5."""
    from repro.configs import ARCH_IDS, LONG_CONTEXT_ARCHS
    from repro.configs.base import SHAPES

    cells = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            skip = (shape.name == "long_500k"
                    and arch not in LONG_CONTEXT_ARCHS)
            cells.append((arch, shape.name, skip))
    return cells


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--attention", default=None,
                    help="override attention kind (inhibitor|dotprod|...)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run the full single-pod baseline matrix")
    ap.add_argument("--multi-pod-all", action="store_true",
                    help="also run every cell on the 2x16x16 mesh")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--tag", default=None)
    ap.add_argument("--microbatches", type=int, default=4,
                    help="gradient-accumulation microbatches (train shapes)")
    args = ap.parse_args(argv)

    if args.list:
        for arch, shape, skip in cell_matrix():
            print(f"{arch:28s} {shape:12s}"
                  + ("  [skip: full-attention @ 500k]" if skip else ""))
        return 0

    opts = {"microbatches": args.microbatches}
    if args.remat:
        opts["remat"] = args.remat
    if args.tag:
        opts["tag"] = args.tag

    if args.all or args.multi_pod_all:
        import subprocess
        failures = 0
        for arch, shape, skip in cell_matrix():
            if skip:
                print(f"[SKIP] {arch}_{shape} (full-attention @ 500k — "
                      "DESIGN.md §5)", flush=True)
                continue
            meshes = [False] if args.all and not args.multi_pod_all else []
            if args.multi_pod_all:
                meshes = [False, True] if args.all else [True]
            for mp in meshes:
                # one subprocess per cell: isolates compiler memory and any
                # single-cell crash from the rest of the matrix
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape,
                       "--out-dir", args.out_dir,
                       "--microbatches", str(args.microbatches)]
                if mp:
                    cmd.append("--multi-pod")
                if args.attention:
                    cmd += ["--attention", args.attention]
                if args.remat:
                    cmd += ["--remat", args.remat]
                if args.tag:
                    cmd += ["--tag", args.tag]
                r = subprocess.run(cmd, timeout=3600)
                failures += 0 if r.returncode == 0 else 1
        print(f"done; {failures} failures", flush=True)
        return 1 if failures else 0

    assert args.arch and args.shape, "--arch and --shape (or --all)"
    rec = run_cell(args.arch, args.shape, args.multi_pod, args.attention,
                   opts or None, args.out_dir)
    return 0 if rec.get("ok") else 1


if __name__ == "__main__":
    sys.exit(main())
