"""Sharded npz checkpoint store: atomic, crash-consistent, resumable.

Layout (one checkpoint = one directory):

    <root>/step_000100/
        meta.json            # step, tree structure, shard inventory
        shard_00000.npz      # flattened leaves, chunked by byte budget
        ...
        COMMITTED            # written LAST -> presence = checkpoint valid

Crash consistency: writers stage into ``step_N.tmp`` and rename after the
COMMITTED marker is in place; readers ignore directories without the
marker, so a host failure mid-save can never corrupt the restore point
(the previous checkpoint remains the newest committed one).

On multi-host runs each host writes only the leaves (or leaf-shards) it
owns; here the single-process writer stores full arrays. Restore is
sharding-aware: pass ``shardings`` to place leaves directly onto devices.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np

_MARKER = "COMMITTED"
_SHARD_BYTES = 512 * 1024 * 1024


def _leaf_paths(tree) -> list:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((name, leaf))
    return out


def save(root: str, step: int, tree: Any, *, extra: Optional[dict] = None):
    """Write a committed checkpoint for ``tree`` at ``step``."""
    final = os.path.join(root, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves = _leaf_paths(tree)
    manifest = []
    shard, shard_bytes, shard_idx = {}, 0, 0

    def flush():
        nonlocal shard, shard_bytes, shard_idx
        if shard:
            np.savez(os.path.join(tmp, f"shard_{shard_idx:05d}.npz"), **shard)
            shard, shard_bytes = {}, 0
            shard_idx += 1

    for i, (name, leaf) in enumerate(leaves):
        arr = np.asarray(leaf)
        key = f"leaf_{i:06d}"
        manifest.append({"name": name, "key": key,
                         "shard": shard_idx, "dtype": str(arr.dtype),
                         "shape": list(arr.shape)})
        shard[key] = arr
        shard_bytes += arr.nbytes
        if shard_bytes >= _SHARD_BYTES:
            flush()
    flush()

    meta = {"step": step, "leaves": manifest, "extra": extra or {}}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    with open(os.path.join(tmp, _MARKER), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def committed_steps(root: str) -> list:
    if not os.path.isdir(root):
        return []
    steps = []
    for name in os.listdir(root):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(root, name, _MARKER)):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    continue
    return sorted(steps)


def latest_step(root: str) -> Optional[int]:
    steps = committed_steps(root)
    return steps[-1] if steps else None


def restore(root: str, tree_like: Any, *, step: Optional[int] = None,
            shardings: Any = None):
    """Restore into the structure of ``tree_like``. Returns (tree, step).

    ``shardings``: optional tree of jax.sharding.Sharding matching
    ``tree_like`` — leaves are device_put directly onto their shards.
    """
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {root}")
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)

    by_shard = {}
    for entry in meta["leaves"]:
        by_shard.setdefault(entry["shard"], []).append(entry)
    values = {}
    for shard_idx, entries in by_shard.items():
        with np.load(os.path.join(d, f"shard_{shard_idx:05d}.npz")) as z:
            for e in entries:
                values[e["name"]] = z[e["key"]]

    names = [name for name, _ in _leaf_paths(tree_like)]
    missing = [n for n in names if n not in values]
    if missing:
        raise KeyError(f"checkpoint missing leaves: {missing[:5]}...")
    ordered = [values[n] for n in names]

    flat_shardings = (jax.tree.leaves(shardings)
                      if shardings is not None else [None] * len(ordered))
    placed = []
    for arr, sh in zip(ordered, flat_shardings):
        placed.append(jax.device_put(arr, sh) if sh is not None else
                      jax.numpy.asarray(arr))
    treedef = jax.tree.structure(tree_like)
    return jax.tree.unflatten(treedef, placed), step


def retain(root: str, keep: int):
    """Delete all but the newest ``keep`` committed checkpoints."""
    steps = committed_steps(root)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(root, f"step_{s:08d}"), ignore_errors=True)
