"""Unified multi-head attention layer with swappable score mechanism.

The mechanism (``"dotprod"`` | ``"inhibitor"`` | ``"inhibitor_unsigned"``
| anything else registered) and the execution backend are both resolved
through :mod:`repro.core.mechanism`: ``plan_attention(cfg, shapes)``
returns an inspectable :class:`~repro.core.mechanism.ExecutionPlan` and
``apply_attention`` executes it — no string ladders or inline shape
heuristics live here (DESIGN.md §7).

The projection layout (fused QKV per-head, GQA, optional QKV bias, RoPE) is
shared across mechanisms so the paper's technique is a one-line config swap
on every architecture in :mod:`repro.configs`.

Decode support: a :class:`KVCache` carries (k, v, length); ``apply`` with
``cache`` set appends the new keys/values and attends over the valid prefix.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.mechanism import (
    DEFAULT_BLOCKED_THRESHOLD, DEFAULT_CHUNKED_THRESHOLD,
    MASK_FREE_BACKENDS, AttnShapes, PagedLayout, Structural, execute_plan,
    get_mechanism, plan_attention)
from repro.nn.linear import apply_dense, init_dense
from repro.nn.module import KeyGen


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    kind: Optional[str] = None      # DEPRECATED mechanism name (warns
                                    # once); set ``mechanism`` instead
    num_heads: int = 8
    num_kv_heads: int = 8
    head_dim: int = 64
    qkv_bias: bool = False
    out_bias: bool = False
    use_rope: bool = True
    rope_base: float = 10000.0
    rope_pct: float = 1.0           # fraction of head_dim rotated (stablelm)
    score_shift: float = 0.5        # inhibitor α (paper: 0.5)
    score_scale: Optional[float] = None  # default √head_dim (paper γ)
    normalize: bool = True          # key-count normalization (DESIGN.md §2)
    sliding_window: Optional[int] = None
    causal: bool = True
    mechanism: Optional[str] = None  # registry name; None -> ``kind``
    backend: Optional[str] = None   # force a backend; None = planner auto
    use_kernel: bool = False        # DEPRECATED: shim for backend="pallas"
    kv_chunk: int = 256             # chunk size for streaming/blocked forms
    # Pallas kernel block-size overrides (None = the kernel registry's
    # tuned/default selection — repro.kernels.ops, DESIGN.md §10)
    kernel_block_q: Optional[int] = None
    kernel_block_k: Optional[int] = None
    kernel_sub_k: Optional[int] = None
    kernel_pages_per_step: Optional[int] = None
    # planner thresholds (single source of truth: core.mechanism defaults)
    chunked_threshold: int = DEFAULT_CHUNKED_THRESHOLD   # n_k > this ->
                                                         # streaming form
    blocked_threshold: int = DEFAULT_BLOCKED_THRESHOLD   # n_q·n_k ≥ this ->
                                                         # mask-free paths


class KVCache(NamedTuple):
    k: jax.Array        # (b, max_len, h_kv, d)
    v: jax.Array        # (b, max_len, h_kv, d)
    length: jax.Array   # () int32 shared cursor, or (b,) per-slot cursors
                        # (ragged continuous batching — serve.engine)


def init_kv_cache(batch: int, max_len: int, num_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16, *, per_slot: bool = False) -> KVCache:
    shape = (batch, max_len, num_kv_heads, head_dim)
    length = jnp.zeros((batch,) if per_slot else (), jnp.int32)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype), length)


class PagedKVCache(NamedTuple):
    """Paged decode cache: KV rows live in a shared pool of fixed-size
    pages instead of per-row ``max_len`` strides (serve.kvcache owns the
    host-side page accounting; this is the device half).

    New tokens are *scattered* to ``block_tables[row, pos // page_size]``
    at offset ``pos % page_size``; attention *gathers* each row's pages
    back into a logically contiguous view (the ``paged`` backend in
    core.mechanism).  Physical page 0 is the trash page — unmapped table
    entries point there, so inactive batch rows in a static-shape decode
    step scatter harmlessly.

    Layer-stacked decode states broadcast ONE table over the leading
    layer axis (``block_tables[0]`` is authoritative for every layer),
    which is what lets models/transformer.lm_step hoist a single
    whole-model page gather out of the layer scan instead of walking the
    table per layer (DESIGN.md §14).
    """
    k: jax.Array            # (num_pages, page_size, h_kv, d) pool
    v: jax.Array            # (num_pages, page_size, h_kv, d) pool
    block_tables: jax.Array  # (b, pages_per_slot) int32
    length: jax.Array       # (b,) int32 per-slot cursors


def init_paged_kv_cache(batch: int, max_len: int, num_kv_heads: int,
                        head_dim: int, dtype=jnp.bfloat16, *,
                        page_size: int = 16,
                        num_pages: Optional[int] = None) -> PagedKVCache:
    pages_per_slot = -(-max_len // page_size)
    if num_pages is None:
        num_pages = batch * pages_per_slot + 1      # +1: trash page 0
    shape = (num_pages, page_size, num_kv_heads, head_dim)
    return PagedKVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                        jnp.zeros((batch, pages_per_slot), jnp.int32),
                        jnp.zeros((batch,), jnp.int32))


def init_attention(key, cfg: AttentionConfig, embed_dim: int, *,
                   dtype=jnp.float32) -> dict:
    kg = KeyGen(key)
    h, hk, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "wq": init_dense(kg("wq"), (embed_dim,), (h, d), ("embed",),
                         ("heads", "head_dim"), use_bias=cfg.qkv_bias,
                         dtype=dtype),
        "wk": init_dense(kg("wk"), (embed_dim,), (hk, d), ("embed",),
                         ("kv_heads", "head_dim"), use_bias=cfg.qkv_bias,
                         dtype=dtype),
        "wv": init_dense(kg("wv"), (embed_dim,), (hk, d), ("embed",),
                         ("kv_heads", "head_dim"), use_bias=cfg.qkv_bias,
                         dtype=dtype),
        "wo": init_dense(kg("wo"), (h, d), (embed_dim,),
                         ("heads", "head_dim"), ("embed",),
                         use_bias=cfg.out_bias, dtype=dtype),
    }


def structural_mask_predicate(causal: bool, window, qi, kj):
    """Attendability of (query index ``qi``, key index ``kj``) under the
    causal/sliding-window structure — the shared definition of the
    window-implies-causal semantics for every mask-building path
    (``_build_mask``, the blocked backend's chunk masks, the lane
    forward's cleartext masks); the Pallas kernels keep an in-kernel
    copy for lowering locality, locked against this one by
    tests/test_window_semantics.py.  Works on numpy and jnp index arrays
    alike.  Returns None when unstructured (attend all-to-all)."""
    masks = []
    if causal:
        masks.append(kj <= qi)
    if window is not None:
        masks.append((kj > qi - window) & (kj <= qi))
    if not masks:
        return None
    m = masks[0]
    for extra in masks[1:]:
        m = m & extra
    return m


def _build_mask(cfg: AttentionConfig, n_q: int, n_k: int, q_offset,
                kv_valid_len=None) -> Optional[jax.Array]:
    """Boolean (b|1, 1, n_q, n_k) mask combining causality, sliding window
    and KV-cache validity. ``q_offset`` / ``kv_valid_len`` may be scalars
    (shared cursor) or (b,) vectors (ragged continuous batching)."""
    masks = []
    qoff = jnp.asarray(q_offset)
    if qoff.ndim == 0:
        qoff = qoff[None]
    qi = qoff[:, None, None] + jnp.arange(n_q)[None, :, None]  # (b|1, nq, 1)
    kj = jnp.arange(n_k)[None, None, :]                        # (1, 1, nk)
    structural = structural_mask_predicate(cfg.causal, cfg.sliding_window,
                                           qi, kj)
    if structural is not None:
        masks.append(structural)
    if kv_valid_len is not None:
        kv = jnp.asarray(kv_valid_len)
        if kv.ndim == 0:
            kv = kv[None]
        masks.append(jnp.broadcast_to(kj < kv[:, None, None],
                                      (kv.shape[0], n_q, n_k)))
    if not masks:
        return None
    m = masks[0]
    for extra in masks[1:]:
        m = m & extra
    return m[:, None]


def apply_attention(
    params: dict,
    cfg: AttentionConfig,
    x: jax.Array,
    *,
    x_kv: Optional[jax.Array] = None,
    positions: Optional[jax.Array] = None,
    cache: Optional[KVCache] = None,
    attn_mask: Optional[jax.Array] = None,
    compute_dtype=None,
):
    """Attention over ``x`` (self) or ``x_kv`` (cross). Returns (y, cache').

    x: (b, n_q, embed). positions: (b, n_q) absolute positions for RoPE
    (defaults to arange, or cache.length + arange when decoding).
    """
    from repro.nn.rotary import apply_rope

    cdt = compute_dtype or x.dtype
    b, n_q, _ = x.shape
    src = x if x_kv is None else x_kv

    q = apply_dense(params["wq"], x, 1, cdt)          # (b, n_q, h, d)
    k = apply_dense(params["wk"], src, 1, cdt)        # (b, n_kv, hk, d)
    v = apply_dense(params["wv"], src, 1, cdt)

    if positions is None:
        offset = cache.length if cache is not None else 0
        off = jnp.asarray(offset)
        if off.ndim == 1:                       # per-slot cursors (b,)
            positions = off[:, None] + jnp.arange(n_q)[None, :]
        else:
            positions = jnp.arange(n_q)[None, :] + off
        positions = jnp.broadcast_to(positions, (b, n_q))

    if cfg.use_rope and x_kv is None:
        if cfg.rope_pct >= 1.0:
            q = apply_rope(q, positions, base=cfg.rope_base)
            k = apply_rope(k, positions, base=cfg.rope_base)
        else:
            rd = int(cfg.head_dim * cfg.rope_pct)
            rd -= rd % 2
            q = jnp.concatenate(
                [apply_rope(q[..., :rd], positions, base=cfg.rope_base),
                 q[..., rd:]], axis=-1)
            k = jnp.concatenate(
                [apply_rope(k[..., :rd], positions, base=cfg.rope_base),
                 k[..., rd:]], axis=-1)

    new_cache = None
    kv_valid_len = None
    paged_layout = None
    if isinstance(cache, PagedKVCache):
        # scatter new k/v into the block-table pages at the cursor(s);
        # the 'paged' backend gathers the pages back per row
        ps = cache.k.shape[1]
        pos = cache.length[:, None] + jnp.arange(n_q)[None, :]     # (b, n_q)
        rows = jnp.arange(b)[:, None]
        pages = cache.block_tables[rows, pos // ps]                # (b, n_q)
        offs = pos % ps
        k_pool = cache.k.at[pages, offs].set(k.astype(cache.k.dtype))
        v_pool = cache.v.at[pages, offs].set(v.astype(cache.v.dtype))
        new_cache = PagedKVCache(k_pool, v_pool, cache.block_tables,
                                 cache.length + n_q)
        k, v = k_pool.astype(cdt), v_pool.astype(cdt)
        kv_valid_len = cache.length + n_q
        n_k = cache.block_tables.shape[1] * ps      # gathered logical view
        paged_layout = PagedLayout(cache.block_tables, ps)
    elif cache is not None:
        # append new k/v at the cache cursor(s), attend over the buffer
        if cache.length.ndim == 1:              # ragged: per-slot cursors
            upd = jax.vmap(
                lambda buf, new, off: jax.lax.dynamic_update_slice(
                    buf, new, (off, 0, 0)))
            k_buf = upd(cache.k, k.astype(cache.k.dtype), cache.length)
            v_buf = upd(cache.v, v.astype(cache.v.dtype), cache.length)
        else:
            k_buf = jax.lax.dynamic_update_slice(
                cache.k, k.astype(cache.k.dtype), (0, cache.length, 0, 0))
            v_buf = jax.lax.dynamic_update_slice(
                cache.v, v.astype(cache.v.dtype), (0, cache.length, 0, 0))
        new_cache = KVCache(k_buf, v_buf, cache.length + n_q)
        k, v = k_buf.astype(cdt), v_buf.astype(cdt)
        kv_valid_len = cache.length + n_q

    if paged_layout is None:
        n_k = k.shape[1]
    q_offset = cache.length if cache is not None else 0
    scalar_cursor = jnp.asarray(q_offset).ndim == 0

    # Mechanism AND backend come exclusively from the registry/planner —
    # the plan is inspectable up front via plan_attention(cfg, shapes).
    shapes = AttnShapes(
        batch=b, n_q=n_q, n_k=n_k, num_heads=cfg.num_heads,
        num_kv_heads=k.shape[2], head_dim=cfg.head_dim, dtype=q.dtype,
        has_explicit_mask=attn_mask is not None, is_cross=x_kv is not None,
        has_cache=cache is not None, scalar_cursor=bool(scalar_cursor),
        paged=paged_layout is not None)
    plan = plan_attention(cfg, shapes)
    mech = get_mechanism(plan.mechanism)
    mech_params = mech.make_params(
        score_scale=cfg.score_scale, score_shift=cfg.score_shift,
        normalize=cfg.normalize, kv_chunk=cfg.kv_chunk,
        kernel_block_q=cfg.kernel_block_q, kernel_block_k=cfg.kernel_block_k,
        kernel_sub_k=cfg.kernel_sub_k,
        kernel_pages_per_step=cfg.kernel_pages_per_step)

    if plan.backend in MASK_FREE_BACKENDS:
        # blocked/pallas/paged_pallas compute causality/window/valid-length
        # from indices inside their loops — no (n_q, n_k) mask array in HBM
        structural = Structural(causal=cfg.causal, window=cfg.sliding_window,
                                q_offset=q_offset, kv_valid_len=kv_valid_len)
        out = execute_plan(plan, q, k, v, params=mech_params,
                           structural=structural, paged=paged_layout)
    else:
        mask = attn_mask
        if mask is None and x_kv is None:
            mask = _build_mask(cfg, n_q, n_k, q_offset, kv_valid_len)
        elif mask is None and x_kv is not None and kv_valid_len is not None:
            kvl = jnp.asarray(kv_valid_len)
            if kvl.ndim == 1:
                mask = (jnp.arange(n_k)[None, :] < kvl[:, None])[:, None,
                                                                 None]
            else:
                mask = (jnp.arange(n_k)[None, :] < kvl)[None, None, None]
        out = execute_plan(plan, q, k, v, mask=mask, params=mech_params,
                           paged=paged_layout)

    y = apply_dense(params["wo"], out, 2, cdt)        # out: (b, n_q, h, d)
    return y, new_cache
