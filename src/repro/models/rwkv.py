"""RWKV-6 (Finch) language model — attention-free SSM family.

The Inhibitor technique replaces dot-product *attention*; RWKV has none,
so this architecture is implemented faithfully without it (DESIGN.md
§Arch-applicability).  Blocks scan over stacked layer params like the
transformer; training/prefill uses the chunked WKV Pallas kernel path,
decode carries (wkv state, time-mix shift token, channel-mix shift token)
per layer.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.nn import embedding as emb
from repro.nn import norm as normnn
from repro.nn import ssm as ssmnn
from repro.nn.module import KeyGen, Param


class RwkvLayerState(NamedTuple):
    wkv: jax.Array        # (b, h, n, n) wkv state
    tm_x: jax.Array       # (b, d) last token seen by time-mix
    cm_x: jax.Array       # (b, d) last token seen by channel-mix


def _num_heads(cfg: ModelConfig) -> int:
    return cfg.attention.num_heads


def init_block(key, cfg: ModelConfig) -> dict:
    kg = KeyGen(key)
    dtype = cfg.pdtype
    return {
        "ln1": normnn.init_layernorm(cfg.d_model, dtype=dtype),
        "time_mix": ssmnn.init_rwkv6_timemix(
            kg("tm"), cfg.d_model, _num_heads(cfg),
            lora_dim=cfg.ssm.lora_dim, decay_lora_dim=cfg.ssm.decay_lora_dim,
            dtype=dtype),
        "ln2": normnn.init_layernorm(cfg.d_model, dtype=dtype),
        "channel_mix": ssmnn.init_rwkv6_channelmix(
            kg("cm"), cfg.d_model, cfg.d_ff, dtype=dtype),
    }


def apply_block(params, cfg: ModelConfig, x, *,
                state: Optional[RwkvLayerState] = None,
                use_kernel: bool = True):
    from repro.core.mechanism import choose_plan

    cdt = cfg.cdtype
    h = normnn.apply_layernorm(params["ln1"], x, eps=cfg.norm_eps)
    h = constrain(h, "batch", "seq_sp", "embed")
    # the WKV token mixer's kernel-vs-scan choice is an explicit plan,
    # trace-logged alongside the attention planner's decisions
    plan = choose_plan("wkv6", [
        ("pallas", use_kernel and state is None,
         "chunked WKV kernel (train/prefill, zero initial state)"),
        ("naive", True,
         "exact scan (decode state carry or kernel disabled)"),
    ])
    a, (wkv_state, tm_x) = ssmnn.apply_rwkv6_timemix(
        params["time_mix"], h, _num_heads(cfg),
        state=state.wkv if state is not None else None,
        x_prev=state.tm_x if state is not None else None,
        use_kernel=plan.backend == "pallas", compute_dtype=cdt)
    x = x + a
    h2 = normnn.apply_layernorm(params["ln2"], x, eps=cfg.norm_eps)
    f, cm_x = ssmnn.apply_rwkv6_channelmix(
        params["channel_mix"], h2,
        x_prev=state.cm_x if state is not None else None, compute_dtype=cdt)
    x = x + f
    x = constrain(x, "batch", "seq_sp", "embed")
    return x, RwkvLayerState(wkv_state, tm_x, cm_x)


def init_lm(key, cfg: ModelConfig) -> dict:
    kg = KeyGen(key)
    dtype = cfg.pdtype
    layer_keys = jax.random.split(kg("blocks"), cfg.num_layers)
    blocks = jax.vmap(lambda k: init_block(k, cfg))(layer_keys)
    blocks = jax.tree.map(
        lambda p: Param(p.value, ("layers",) + p.axes) if isinstance(p, Param)
        else p, blocks, is_leaf=lambda p: isinstance(p, Param))
    p = {
        "embed": emb.init_embedding(kg("embed"), cfg.vocab_size, cfg.d_model,
                                    dtype=dtype),
        "ln_in": normnn.init_layernorm(cfg.d_model, dtype=dtype),
        "blocks": blocks,
        "final_norm": normnn.init_layernorm(cfg.d_model, dtype=dtype),
    }
    if not cfg.tie_embeddings:
        from repro.nn.linear import init_dense
        p["lm_head"] = init_dense(kg("lm_head"), (cfg.d_model,),
                                  (cfg.vocab_size,), ("embed",), ("vocab",),
                                  dtype=dtype)
    return p


def _scan_blocks(params, cfg, x, states=None, use_kernel=True):
    def body(carry, layer_in):
        h = carry
        if states is None:
            lp, st = layer_in, None
        else:
            lp, st = layer_in
        h, new_state = apply_block(lp, cfg, h, state=st,
                                   use_kernel=use_kernel)
        return h, new_state

    body_fn = jax.checkpoint(body) if cfg.remat == "full" else body
    xs = params["blocks"] if states is None else (params["blocks"], states)
    if cfg.unroll:
        from repro.models.transformer import unrolled_scan
        return unrolled_scan(body_fn, x, xs, cfg.num_layers)
    return jax.lax.scan(body_fn, x, xs)


def lm_forward(params, cfg: ModelConfig, tokens, *, positions=None,
               extra_embeds=None, use_kernel: bool = True):
    del positions, extra_embeds
    cdt = cfg.cdtype
    x = emb.apply_embedding(params["embed"], tokens, compute_dtype=cdt)
    x = normnn.apply_layernorm(params["ln_in"], x, eps=cfg.norm_eps)
    x = constrain(x, "batch", "seq_sp", "embed")
    x, _ = _scan_blocks(params, cfg, x, use_kernel=use_kernel)
    x = normnn.apply_layernorm(params["final_norm"], x, eps=cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = emb.attend_logits(params["embed"], x, compute_dtype=cdt)
    else:
        from repro.nn.linear import apply_dense
        logits = apply_dense(params["lm_head"], x, 1, cdt)
    logits = constrain(logits, "batch", None, "vocab")
    return logits, jnp.zeros((2,), jnp.float32)


def init_states(cfg: ModelConfig, batch: int, max_len: int, *,
                per_slot: bool = False) -> RwkvLayerState:
    """Stacked decode state. RWKV state is O(1) in sequence length — the
    ``max_len``/``per_slot`` args are accepted for API symmetry; the
    recurrent state is inherently per-row."""
    del max_len, per_slot
    h = _num_heads(cfg)
    n = cfg.d_model // h
    L = cfg.num_layers
    return RwkvLayerState(
        wkv=jnp.zeros((L, batch, h, n, n), jnp.float32),
        tm_x=jnp.zeros((L, batch, cfg.d_model), cfg.cdtype),
        cm_x=jnp.zeros((L, batch, cfg.d_model), cfg.cdtype),
    )


def lm_step(params, cfg: ModelConfig, tokens, states: RwkvLayerState):
    """Decode step (t tokens, recurrent state carry)."""
    cdt = cfg.cdtype
    x = emb.apply_embedding(params["embed"], tokens, compute_dtype=cdt)
    x = normnn.apply_layernorm(params["ln_in"], x, eps=cfg.norm_eps)
    x, new_states = _scan_blocks(params, cfg, x, states=states,
                                 use_kernel=False)
    x = normnn.apply_layernorm(params["final_norm"], x, eps=cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = emb.attend_logits(params["embed"], x, compute_dtype=cdt)
    else:
        from repro.nn.linear import apply_dense
        logits = apply_dense(params["lm_head"], x, 1, cdt)
    return logits, new_states
