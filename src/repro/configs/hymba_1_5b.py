"""hymba-1.5b — hybrid-head LM: parallel attention + mamba heads per layer.
[arXiv:2411.13676; hf:nvidia/Hymba-1.5B]
32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16,
head_dim=64.

Per the paper, attention and SSM branches process the same input in
parallel and their (normalized, scaled) outputs are averaged.  Most
attention layers use a sliding window; this config applies window 1024 to
the attention branch of every layer (meta-tokens and the 3 global-attention
layers of the release are simplifications recorded in DESIGN.md) — which,
combined with the O(1) SSM state, keeps the architecture sub-quadratic and
eligible for the long_500k shape.
"""

from repro.configs.base import ModelConfig, SSMConfig
from repro.core.attention import AttentionConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    d_ff=5504,
    vocab_size=32001,
    attention=AttentionConfig(
        mechanism="dotprod", num_heads=25, num_kv_heads=5, head_dim=64,
        qkv_bias=False, use_rope=True, rope_base=10000.0, causal=True,
        sliding_window=1024),
    norm="rmsnorm",
    norm_eps=1e-6,
    mlp="gated_silu",
    ssm=SSMConfig(kind="mamba", state_dim=16, inner_dim=3200, conv_dim=4),
    tie_embeddings=True,
    max_seq_len=8192,
    source="arXiv:2411.13676",
)
