"""Fault tolerance: restart supervisor, straggler watchdog, elastic rescale.

The unit of recovery is the committed checkpoint (checkpoint.store is
atomic), so the supervisor's contract is simple:

  run_supervised(build_fn, run_fn):
      loop:
          state <- restore latest committed checkpoint (or init)
          run_fn(state)            # raises on step failure / preemption
          on success: return
          on StepFailure: log, rebuild (possibly on fewer hosts), retry

Three production concerns covered here:

  * **Node failure / preemption** — any exception inside the step loop
    triggers restore-from-last-commit.  Because the data pipeline is a pure
    function of (seed, step), the replay is exact.
  * **Stragglers** — ``StepWatchdog`` tracks a robust EWMA of step time and
    flags steps slower than ``threshold×`` the trend; the policy hook
    decides (log / mark host suspect / trigger re-mesh).  On TPU pods the
    usual mitigation is preemptive restart of the slow worker; we surface
    the signal rather than hard-kill inside the loop.
  * **Elastic rescale** — ``elastic_remesh_plan`` computes, for a reduced
    healthy-host set, the largest usable (data, model) mesh and whether
    the FSDP-sharded state can be re-sliced without resharding collectives
    (it can whenever new_data_parallelism divides the old).
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Optional

log = logging.getLogger("repro.fault")


class StepFailure(RuntimeError):
    """Raised by the training loop when a step fails in a recoverable way
    (device error, NaN loss with strict mode, preemption notice)."""


@dataclasses.dataclass
class SupervisorConfig:
    max_restarts: int = 3
    restart_backoff_s: float = 1.0


def run_supervised(run_fn: Callable[[int], None],
                   cfg: Optional[SupervisorConfig] = None) -> int:
    """Run ``run_fn(attempt)`` under restart supervision.

    ``run_fn`` must restore its own state from the latest committed
    checkpoint (CheckpointManager.restore_or_init does this).  Returns the
    number of restarts consumed.
    """
    cfg = cfg or SupervisorConfig()
    attempt = 0
    while True:
        try:
            run_fn(attempt)
            return attempt
        except StepFailure as e:
            attempt += 1
            if attempt > cfg.max_restarts:
                log.error("restart budget exhausted after %d attempts",
                          attempt)
                raise
            log.warning("step failure (%s); restart %d/%d after %.1fs",
                        e, attempt, cfg.max_restarts, cfg.restart_backoff_s)
            time.sleep(cfg.restart_backoff_s)


class StepWatchdog:
    """Robust straggler detector over step wall times."""

    def __init__(self, threshold: float = 2.5, ewma: float = 0.9,
                 warmup_steps: int = 5):
        self.threshold = threshold
        self.ewma = ewma
        self.warmup = warmup_steps
        self._mean: Optional[float] = None
        self._seen = 0
        self.flagged: list = []

    def observe(self, step: int, seconds: float) -> bool:
        """Returns True when ``step`` is a straggler."""
        self._seen += 1
        if self._mean is None:
            self._mean = seconds
            return False
        is_slow = (self._seen > self.warmup
                   and seconds > self.threshold * self._mean)
        if is_slow:
            self.flagged.append((step, seconds, self._mean))
        else:
            # only fold non-straggler steps into the trend
            self._mean = self.ewma * self._mean + (1 - self.ewma) * seconds
        return is_slow


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    data: int
    model: int
    pods: int
    resliceable: bool     # FSDP shards re-slice without gather


def elastic_remesh_plan(healthy_chips: int, *, model_parallelism: int,
                        old_data_parallelism: int,
                        chips_per_pod: int = 256) -> RemeshPlan:
    """Largest mesh on the healthy chip set keeping TP degree fixed.

    TP degree is architecture-determined (head/expert divisibility), so
    elasticity trades only the data axis.  The FSDP state re-slices locally
    iff the new data parallelism divides the old (each new shard is a
    concatenation of old ones); otherwise restore goes through the
    checkpoint reshard path.
    """
    if healthy_chips < model_parallelism:
        raise ValueError("not enough chips for one model replica")
    new_data = healthy_chips // model_parallelism
    # prefer power-of-two data axes (collective efficiency)
    while new_data & (new_data - 1):
        new_data -= 1
    pods = max(1, (new_data * model_parallelism) // chips_per_pod)
    return RemeshPlan(
        data=new_data // pods if pods > 1 else new_data,
        model=model_parallelism,
        pods=pods,
        resliceable=(old_data_parallelism % new_data == 0),
    )
