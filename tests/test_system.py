"""End-to-end behaviour: the paper's system as a whole.

1. Train a reduced paper-faithful Inhibitor transformer on (synthetic) LM
   data — loss falls — then serve it with the continuous-batching engine:
   the served continuation matches teacher-forced argmax.
2. The same pipeline with dot-product attention trains comparably
   (paper Table 1 claim at smoke scale).
3. FHE path: quantized attention through the encrypted circuit — exact vs
   the integer reference (the privacy-preserving deployment path).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import PipelineConfig, lm_batch_at
from repro.models.registry import get_model
from repro.optim import AdamWConfig
from repro.serve.engine import Engine, EngineConfig, Request
from repro.train.loop import TrainConfig, train


def _train_lm(kind: str, steps=40, vocab=128):
    cfg = get_config("smollm-135m").reduced(
        num_layers=2, d_model=48, d_ff=96, vocab_size=vocab,
        num_heads=4, num_kv_heads=2, head_dim=12)
    if kind != "dotprod":
        cfg = cfg.with_attention_kind(kind)
    api = get_model(cfg)
    pipe = PipelineConfig(global_batch=8, seq_len=32, vocab_size=vocab,
                          seed=11)
    out = train(api, AdamWConfig(lr=3e-3),
                TrainConfig(total_steps=steps),
                lambda step: lm_batch_at(pipe, step))
    return cfg, api, out


def test_train_then_serve_inhibitor(rng):
    cfg, api, out = _train_lm("inhibitor")
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])

    params = out["params"]
    # the engine owns state layout (per-slot cursors, paged block tables)
    eng = Engine(api, params, EngineConfig(max_batch=2, max_len=64))
    prompt = rng.integers(0, cfg.vocab_size, (8,)).astype(np.int32)
    eng.submit(Request(0, prompt, max_new_tokens=4))
    done = eng.run_to_completion()

    # teacher-forced argmax reference over the same prefix
    seq = list(prompt)
    for _ in range(4):
        logits, _ = api.forward(params, {"tokens": jnp.asarray(seq)[None]})
        seq.append(int(jnp.argmax(logits[0, -1])))
    assert done[0].output == seq[len(prompt):]


def test_mechanism_parity_at_smoke_scale():
    """Paper Table 1 claim, smoke version: both mechanisms reach similar
    loss on the same stream."""
    _, _, out_d = _train_lm("dotprod")
    _, _, out_i = _train_lm("inhibitor")
    ld = out_d["history"][-1]["loss"]
    li = out_i["history"][-1]["loss"]
    assert abs(ld - li) / max(ld, li) < 0.25, (ld, li)


def test_fhe_inference_of_quantized_attention(rng):
    """Quantized q/k/v through the ENCRYPTED inhibitor circuit equals the
    integer reference bit-for-bit."""
    from repro.fhe import inhibitor_attention_circuit
    from repro.quant.int_attention import (int_inhibitor_attention,
                                           quantize_qkv)

    q = jnp.asarray(rng.normal(size=(6, 4)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(6, 4)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(6, 4)).astype(np.float32))
    qi, ki, vi, s = quantize_qkv(q, k, v, bits=5)
    h_enc, summary = inhibitor_attention_circuit(
        np.asarray(qi), np.asarray(ki), np.asarray(vi), gamma_shift=2,
        alpha_q=1)
    h_int = int_inhibitor_attention(qi, ki, vi, gamma_shift=2, alpha_q=1)
    np.testing.assert_array_equal(h_enc, np.asarray(h_int))
    assert summary["max_bits_at_pbs"] <= 16  # TFHE LUT ceiling
