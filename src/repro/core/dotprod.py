"""Conventional scaled-dot-product Softmax attention (paper eq. 3).

The paper's comparison baseline. Multi-head GQA layout identical to
:mod:`repro.core.inhibitor` so the two mechanisms are drop-in swappable.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e9


@functools.lru_cache(maxsize=None)
def _make_dotprod_core(scale: float):
    """custom_vjp'd softmax-attention core with a lean backward.

    Plain autodiff keeps ~6 score-sized fp32 residuals live per layer
    (logits, masked logits, probs, dprobs, dlogits, softmax internals).
    Here the only residual is the *compute-dtype* probability matrix; the
    backward applies the analytic softmax Jacobian
        dS = P ⊙ (dP − Σ_k dP⊙P)
    so the live fp32 set is one score-sized tensor at a time.
    """

    def fwd_math(qt, kt, vt, mask):
        from repro.distributed.sharding import constrain

        logits = jnp.einsum("bqhd,bkhd->bhqk", qt.astype(jnp.float32),
                            kt.astype(jnp.float32)) / scale
        # scores shard heads over TP when divisible, else the query-seq
        # dim — never replicate the O(s²) tensor (DESIGN.md §6)
        logits = constrain(logits, "batch", "heads", "seq_sp")
        if mask is not None:
            logits = jnp.where(mask, logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, vt.astype(jnp.float32))
        return out, probs

    @jax.custom_vjp
    def core(qt, kt, vt, mask):
        return fwd_math(qt, kt, vt, mask)[0]

    def core_fwd(qt, kt, vt, mask):
        out, probs = fwd_math(qt, kt, vt, mask)
        # masked probs are exactly 0, so the backward needs no mask — only
        # its shape (for the float0 cotangent)
        mshape = None if mask is None else tuple(mask.shape)
        return out, (qt, kt, vt, probs.astype(qt.dtype), mshape)

    def core_bwd(res, g):
        from repro.distributed.sharding import constrain

        qt, kt, vt, probs, mshape = res
        gf = g.astype(jnp.float32)
        pf = probs.astype(jnp.float32)
        dv = jnp.einsum("bhqk,bqhd->bkhd", pf, gf)
        dp = jnp.einsum("bqhd,bkhd->bhqk", gf, vt.astype(jnp.float32))
        dp = constrain(dp, "batch", "heads", "seq_sp")
        ds = pf * (dp - jnp.sum(dp * pf, axis=-1, keepdims=True))
        ds = constrain(ds, "batch", "heads", "seq_sp") / scale
        dq = jnp.einsum("bhqk,bkhd->bqhd", ds, kt.astype(jnp.float32))
        dk = jnp.einsum("bhqk,bqhd->bkhd", ds, qt.astype(jnp.float32))
        dmask = (None if mshape is None
                 else jnp.zeros(mshape, jax.dtypes.float0))
        return (dq.astype(qt.dtype), dk.astype(kt.dtype),
                dv.astype(vt.dtype), dmask)

    core.defvjp(core_fwd, core_bwd)
    return core


def dot_product_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    mask: Optional[jax.Array] = None,
    score_scale: Optional[float] = None,
) -> jax.Array:
    """q: (b, n_q, h, d); k, v: (b, n_k, h_kv, d). Returns (b, n_q, h, d)."""
    from repro.core.inhibitor import _repeat_kv

    b, n_q, h, d = q.shape
    h_kv = k.shape[2]
    k = _repeat_kv(k, h // h_kv)
    v = _repeat_kv(v, h // h_kv)
    scale = score_scale if score_scale is not None else float(d) ** 0.5
    if mask is not None:
        mask = jnp.broadcast_to(mask, (b, h, n_q, k.shape[1]))
    core = _make_dotprod_core(float(scale))
    return core(q, k, v, mask).astype(q.dtype)
