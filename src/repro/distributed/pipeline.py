"""Pipeline parallelism: GPipe-style microbatch schedule over a ``pipe``
mesh axis, built on shard_map + collective_permute.

The production mesh in this repo defaults to (pod, data, model) — PP is an
*optional* axis for deployments whose interconnect topology favors it
(e.g. sparse inter-pod links); `make_pp_mesh` builds (pipe, data, model).

Schedule: the classic GPipe loop with M microbatches over S stages runs
S + M − 1 ticks; each tick every stage processes one resident microbatch
and ppermutes its activation to the next stage.  Bubble fraction
(S − 1)/(S + M − 1) — reported by :func:`bubble_fraction` so configs can
size M.

The stage function is arbitrary (typically a slice of the layer stack —
``num_layers/S`` scanned blocks); stage parameters live sharded on the
pipe axis so each device holds only its stage's weights.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def bubble_fraction(num_stages: int, num_microbatches: int) -> float:
    return (num_stages - 1) / (num_stages + num_microbatches - 1)


def make_pp_mesh(num_stages: int, data: int = 1, model: int = 1) -> Mesh:
    return jax.make_mesh((num_stages, data, model), ("pipe", "data", "model"))


def pipeline_apply(
    stage_fn: Callable,          # (stage_params, x) -> y
    stage_params,                # params with leading stage axis, sharded on pipe
    x: jax.Array,                # (num_microbatches, mb, ...) microbatched input
    mesh: Mesh,
    *,
    num_microbatches: int,
) -> jax.Array:
    """Run the GPipe schedule. Returns outputs with microbatch leading dim.

    x is sharded on the pipe axis by microbatch position per the standard
    circular-rotation formulation: each stage s processes microbatch
    (t − s) at tick t; activations rotate s -> s+1 between ticks.
    """
    num_stages = mesh.shape["pipe"]
    ticks = num_stages + num_microbatches - 1

    def per_stage(params, xs):
        # params: (1, ...) this stage's slice; xs: (num_microbatches, mb, ...)
        stage = jax.lax.axis_index("pipe")
        params = jax.tree.map(lambda p: p[0], params)
        mb_shape = xs.shape[1:]

        state = jnp.zeros(mb_shape, xs.dtype)       # resident activation
        outputs = jnp.zeros_like(xs)

        def tick(t, carry):
            state, outputs = carry
            # stage 0 ingests microbatch t (if any remain)
            mb_idx = jnp.clip(t, 0, num_microbatches - 1)
            injected = jax.lax.dynamic_index_in_dim(xs, mb_idx, 0,
                                                    keepdims=False)
            cur = jnp.where(stage == 0,
                            jnp.where(t < num_microbatches, injected, state),
                            state)
            # every stage applies its slice to its resident microbatch
            y = stage_fn(params, cur)
            # the last stage emits: its microbatch index at tick t is
            # t − (S − 1)
            out_idx = jnp.clip(t - (num_stages - 1), 0, num_microbatches - 1)
            emit = (stage == num_stages - 1) & (t >= num_stages - 1)
            outputs = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, out_idx, 0),
                lambda o: o,
                outputs)
            # rotate activations stage s -> s+1
            perm = [(i, (i + 1) % num_stages) for i in range(num_stages)]
            nxt = jax.lax.ppermute(y, "pipe", perm)
            return (nxt, outputs)

        state, outputs = jax.lax.fori_loop(0, ticks, tick, (state, outputs))
        # only the last stage's outputs are real; psum_scatter-free gather:
        # zero other stages then psum over pipe
        outputs = jnp.where(stage == num_stages - 1, outputs, 0)
        outputs = jax.lax.psum(outputs, "pipe")
        return outputs

    return shard_map(
        per_stage, mesh=mesh,
        in_specs=(P("pipe"), P()),
        out_specs=P(),
        check_rep=False,
    )(stage_params, x)
