"""End-to-end training driver: a ~100M-parameter-class Inhibitor LM.

Trains smollm-135m@inhibitor (or --reduced for CPU smoke) for a few
hundred steps on the deterministic synthetic LM stream with checkpointing,
fault supervision and auto-resume — the full production loop at laptop
scale.

  PYTHONPATH=src python examples/train_inhibitor_lm.py --steps 300
  PYTHONPATH=src python examples/train_inhibitor_lm.py --full  # 135M params

Interrupt it and re-run: it resumes from the last committed checkpoint
bit-exactly (tests/test_train_loop.py asserts this).
"""

import argparse

from repro.launch import train as train_cli


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full", action="store_true",
                    help="train the real 135M config (needs ~8GB + hours)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_inhibitor_lm")
    args = ap.parse_args()

    argv = ["--arch", "smollm-135m", "--attention", "inhibitor",
            "--steps", str(args.steps), "--batch", "16", "--seq", "256",
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100"]
    if args.full:
        argv.append("--full")
    return train_cli.main(argv)


if __name__ == "__main__":
    raise SystemExit(main())
