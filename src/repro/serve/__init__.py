"""Serving: slot-pool continuous batching engine + KV cache management,
shared-prefix radix cache, and pluggable admission scheduling."""

from repro.serve.engine import Engine, EngineConfig, Request  # noqa: F401
from repro.serve.kvcache import (PagedAllocator, SlotAllocator,  # noqa: F401
                                 SlotState)
from repro.serve.prefix import PrefixIndex  # noqa: F401
from repro.serve.scheduler import (FIFOScheduler,  # noqa: F401
                                   PrefixAffinityScheduler,
                                   PriorityScheduler, Scheduler,
                                   make_scheduler, register_scheduler)
