"""Whole-model static FHE circuit analysis (abstract interpretation).

:func:`analyze_qlm` runs the ordinary lane-parameterized forward
(:func:`repro.models.transformer.lm_forward_lane`) on the
:class:`~repro.analysis.interval_lane.IntervalLane` — no concrete token
values, no activations — and packages the resulting static trace into a
report with the same per-scope schema the ``fhe_sim`` measured report
uses, plus what only a static analysis can assert:

  * ``cmul_sites``      — every cipher×cipher multiply, attributed to its
                          scope and contraction (``dot_scores`` /
                          ``mix_values`` / the softmax renorm ``mul``);
                          an empty list is a *proof* that the circuit
                          performs zero ciphertext multiplications for
                          any input in the quantized range;
  * ``lut_sites``       — every PBS table: declared domain, worst-case
                          raw input interval, saturation margins, and the
                          table width the PBS must cover;
  * ``lut_verification``— the hard gate: every LUT's (packed) table width
                          must sit within the 16-bit TFHE LUT ceiling;
  * ``value_ranges``    — proven per-scope value intervals;
  * ``params``          — TFHE macro-parameters selected from the proven
                          block-level width
                          (:func:`repro.fhe.params.select_params_static`).

:func:`analyze_config` wraps it for a named architecture (PTQ'ing a
freshly initialized model) across both attention mechanisms and returns
the ``ANALYSIS_fhe.json`` document the CLI writes and CI gates on.
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

#: widest table a single PBS can evaluate (paper §Computational
#: Efficiency; mirrors the fhe.params 16-bit curve ceiling)
LUT_BITS_CEILING = 16

SCHEMA_VERSION = 1

DEFAULT_MECHANISMS = ("inhibitor", "dotprod")


def analyze_qlm(qlm, *, seq_len: int, batch: int = 1) -> dict:
    """Statically analyze one PTQ'd LM end to end; returns the report."""
    from repro.analysis.interval_lane import IntervalLane
    from repro.fhe.params import select_params_static
    from repro.models.transformer import lm_forward_lane

    lane = IntervalLane()
    # token *values* are never read by the interval lane (embed uses
    # per-channel vocabulary bounds); the array only supplies (b, s)
    tokens = np.zeros((batch, seq_len), np.int64)
    logits = lm_forward_lane(qlm, lane, tokens)

    per_scope = lane.ctx.scope_report()
    lut_violations = [s for s in lane.lut_sites
                      if s["table_bits"] > LUT_BITS_CEILING]
    report = {
        "mechanism": qlm.cfg.attention.mechanism,
        "seq_len": int(seq_len),
        "batch": int(batch),
        "totals": lane.ctx.summary(),
        "per_scope": per_scope,
        "value_ranges": {k: list(v) for k, v in lane.value_ranges.items()},
        "logits_range": list(logits.extremes()),
        "cmul_sites": list(lane.cmul_sites),
        "zero_cmul_proven": not lane.cmul_sites,
        "lut_sites": list(lane.lut_sites),
        "lut_verification": {
            "n_sites": len(lane.lut_sites),
            "n_saturating": sum(not s["fits_domain"]
                                for s in lane.lut_sites),
            "bits_ceiling": LUT_BITS_CEILING,
            "verified": not lut_violations,
            "violations": lut_violations,
        },
    }
    try:
        p = select_params_static(per_scope)
        report["params"] = {
            "lwe_dim": p.lwe_dim, "poly_size": p.poly_size,
            "base_log": p.base_log, "level": p.level,
            "msg_bits": p.msg_bits,
        }
    except ValueError as e:
        report["params"] = None
        report["params_error"] = str(e)
    return report


def analyze_config(name: str, *, seq_len: int = 8, batch: int = 1,
                   mechanisms: Sequence[str] = DEFAULT_MECHANISMS,
                   seed: int = 0, reduced: Optional[dict] = None) -> dict:
    """Analyze a named architecture for each mechanism.

    Initializes the model (``seed``), PTQ's it once per mechanism (the
    weights are mechanism-independent; only the attention hyper-parameter
    mapping changes), and assembles the ``ANALYSIS_fhe.json`` document.
    ``reduced`` forwards size overrides to ``cfg.reduced(...)``.
    """
    import jax

    from repro.configs import get_config
    from repro.models.registry import get_model
    from repro.nn.module import unbox
    from repro.quant.ptq import ptq_lm

    cfg = get_config(name.replace("_", "-"))
    if reduced:
        cfg = cfg.reduced(**reduced)
    params = unbox(get_model(cfg).init(jax.random.PRNGKey(seed)))

    doc = {
        "schema": SCHEMA_VERSION,
        "config": cfg.name,
        "seq_len": int(seq_len),
        "batch": int(batch),
        "seed": int(seed),
        "mechanisms": {},
    }
    for mech in mechanisms:
        qlm = ptq_lm(params, cfg.with_attention_kind(mech))
        doc["mechanisms"][mech] = analyze_qlm(qlm, seq_len=seq_len,
                                              batch=batch)
    return doc


def format_report(report: dict) -> str:
    """Human-readable per-scope table for one mechanism's report."""
    lines = [f"== {report['mechanism']} — static worst case over the "
             f"quantized input range (T={report['seq_len']}) ==",
             f"{'scope':14s} {'pbs':>8} {'cmuls':>7} {'adds':>9} "
             f"{'bits@pbs':>8}  {'value range':>24}"]
    for name, s in report["per_scope"].items():
        lo, hi = report["value_ranges"].get(name, (0, 0))
        lines.append(
            f"{name:14s} {s['pbs']:>8} {s['cmuls']:>7} {s['adds']:>9} "
            f"{s['max_bits_at_pbs']:>8}  [{lo}, {hi}]")
    tot = report["totals"]
    lines.append(f"{'total':14s} {tot['pbs']:>8} {tot['cmuls']:>7} "
                 f"{tot['adds']:>9} {tot['max_bits_at_pbs']:>8}")
    if report["zero_cmul_proven"]:
        lines.append("cmuls: ZERO, proven for every input in the "
                     "quantized range")
    else:
        for site in report["cmul_sites"]:
            lines.append(f"cmul site: {site['scope']} [{site['op']}] × "
                         f"{site['count']} ({site['pbs_bits']}-bit PBS)")
    lv = report["lut_verification"]
    lines.append(f"LUT domains: {lv['n_sites']} sites, "
                 f"{lv['n_saturating']} saturating, verified="
                 f"{lv['verified']} (ceiling {lv['bits_ceiling']} bits)")
    if report.get("params"):
        p = report["params"]
        lines.append(f"static params: poly={p['poly_size']} "
                     f"lwe={p['lwe_dim']} level={p['level']} "
                     f"(proven {tot['max_bits_at_pbs']}-bit messages)")
    else:
        lines.append(f"static params: UNSELECTABLE — "
                     f"{report.get('params_error')}")
    return "\n".join(lines)
