"""Quantization + TFHE simulation: exactness, paper-claim regressions."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.fhe import (circuit_seconds, describe, dotprod_attention_circuit,
                       encrypt, inhibitor_attention_circuit, select_params)
from repro.fhe.tfhe_sim import FheContext
from repro.quant.fake_quant import QuantConfig, compute_scale, dequantize, \
    fake_quant, quantize
from repro.quant.int_attention import (int_inhibitor_attention,
                                       quantize_qkv)


# ---- quantization ----
# (the hypothesis round-trip property test lives in test_property_based.py)

def test_fake_quant_straight_through(rng):
    import jax
    x = jnp.asarray(rng.normal(size=(32,)).astype(np.float32))
    g = jax.grad(lambda t: (fake_quant(t, QuantConfig(bits=8)) ** 2).sum())(x)
    # STE: gradient flows as if identity (2x)
    np.testing.assert_allclose(g, 2 * fake_quant(x, QuantConfig(bits=8)),
                               rtol=1e-5, atol=1e-5)


# ---- TFHE simulator ----

def test_cipher_mul_exact(rng):
    """ab = PBS(x²/4; a+b) − PBS(x²/4; a−b) is exact on integers (eq. 1)."""
    a = np.asarray(rng.integers(-100, 100, (50,)))
    b = np.asarray(rng.integers(-100, 100, (50,)))
    ea, ctx = encrypt(a)
    eb, _ = encrypt(b, ctx)
    prod = ea.mul_cipher(eb)
    np.testing.assert_array_equal(prod.values, a * b)
    assert ctx.pbs == 2 * 50  # two PBS per element


def test_inhibitor_circuit_matches_int_reference(rng):
    T, d = 6, 3
    q = rng.integers(-7, 8, (T, d))
    k = rng.integers(-7, 8, (T, d))
    v = rng.integers(-7, 8, (T, d))
    h, _ = inhibitor_attention_circuit(q, k, v, gamma_shift=1, alpha_q=1)
    ref = np.asarray(int_inhibitor_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        gamma_shift=1, alpha_q=1))
    np.testing.assert_array_equal(h, ref)


def test_paper_claims_bits_pbs_speedup(rng):
    """Table 2/4 regression: +1–2 bits, ~2× PBS, 3–6× encrypted speedup."""
    for T in (2, 4, 8, 16):
        q = rng.integers(-7, 8, (T, 2))
        k = rng.integers(-7, 8, (T, 2))
        v = rng.integers(-7, 8, (T, 2))
        _, si = inhibitor_attention_circuit(q, k, v, gamma_shift=1,
                                            alpha_q=1)
        _, sd = dotprod_attention_circuit(q, k, v, scale_shift=2)
        gap = sd["max_bits_at_pbs"] - si["max_bits_at_pbs"]
        assert 1 <= gap <= 2, (T, gap)
        ratio_pbs = sd["pbs"] / si["pbs"]
        assert 1.8 <= ratio_pbs <= 3.0, (T, ratio_pbs)
        speedup = circuit_seconds(sd) / circuit_seconds(si)
        assert 3.0 <= speedup <= 6.0, (T, speedup)


def test_param_curve_monotone():
    prev = None
    for bits in range(4, 17):
        p = select_params(bits)
        if prev is not None:
            assert p.poly_size >= prev.poly_size
            assert p.lwe_dim >= prev.lwe_dim - 60
        prev = p
    with pytest.raises(ValueError):
        select_params(17)   # paper: 16-bit TFHE LUT ceiling


def test_shared_scale_survives_inhibitor(rng):
    """Paper's 'straightforward quantization': with a shared scale s,
    int-inhibitor(q/s, k/s, v/s) ≈ float-inhibitor(q, k, v)/s."""
    q = rng.normal(size=(5, 4)).astype(np.float32)
    k = rng.normal(size=(5, 4)).astype(np.float32)
    v = rng.normal(size=(5, 4)).astype(np.float32)
    qi, ki, vi, s = quantize_qkv(jnp.asarray(q), jnp.asarray(k),
                                 jnp.asarray(v), bits=8)
    hi = int_inhibitor_attention(qi, ki, vi)            # γ=1, α=0
    # float reference at γ=1, α=0 (unsigned eq. 6)
    z = np.abs(q[:, None, :] - k[None, :, :]).sum(-1)
    hf = np.maximum(v[None, :, :] - z[:, :, None], 0).sum(1)
    np.testing.assert_allclose(np.asarray(hi) * float(s), hf,
                               atol=float(s) * 40)
