"""RWKV-6 (Finch) WKV chunked-scan Pallas kernel.

The recurrence per head (state S ∈ R^{n×n}, n = head_dim):

    S_t = diag(w_t) · S_{t-1} + k_tᵀ v_t
    o_t = r_t · (S_{t-1} + diag(u) · k_tᵀ v_t)

The kernel processes the sequence in chunks of length ``chunk``: the state
is carried in VMEM scratch across the sequential chunk grid dimension, and
*within* a chunk all positions are computed at once:

  * carry term   : (r_t ⊙ e^{cw_t}) @ S          — one matmul per chunk
  * intra term   : A[t,s] = Σ_k r_tk k_sk e^{cw_t − cw_s}  for s < t,
                   plus the diag bonus  A[t,t] = Σ_k r_tk k_tk u_k,
                   then  o += A @ v               — cube + matmul
  * state update : S ← diag(e^{cw_L}) S + Σ_s (k_s ⊙ e^{cw_L − cw_s})ᵀ v_s

All exponents are differences of the within-chunk cumulative log-decay
``cw_t = Σ_{s≤t} log w_s`` with the later index subtracted, hence ≤ 0 —
every ``exp`` is in (0, 1] and the computation is overflow-free for any
decay magnitude (no clamping or rescaling needed).  This is the TPU-native
replacement for the CUDA kernel's per-warp sequential loop: sequential
chunk grid + vectorized intra-chunk cube, sized to VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 32

#: Native-lowering platforms (see kernels.paged.LOWERS_ON for the
#: contract): the chunk-carried state lives in ``pltpu.VMEM`` scratch
#: across the sequential grid dimension, so only TPU lowers natively.
LOWERS_ON = ("tpu",)


def _wkv6_kernel(r_ref, k_ref, v_ref, logw_ref, u_ref, o_ref, sfin_ref,
                 s_ref, *, chunk: int, n_chunks: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)       # (chunk, n)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    logw = logw_ref[0].astype(jnp.float32)  # (chunk, n), entries ≤ 0
    u = u_ref[0].astype(jnp.float32)        # (1, n) -> broadcast
    S = s_ref[...]                          # (n, n) carry

    cw = jnp.cumsum(logw, axis=0)           # (chunk, n) cumulative log decay

    # carry term: o_t += (r_t ⊙ e^{cw_{t-1}}) @ S ; cw_{t-1} = cw_t − logw_t
    cw_prev = cw - logw
    o = jnp.einsum("tn,nm->tm", r * jnp.exp(cw_prev), S)

    # intra-chunk: A[t,s] = Σ_n r_tn k_sn e^{cw_{t-1,n} − cw_{s,n}}, s < t
    # exponent = cw_prev[t] − cw[s] ≤ 0 for s ≤ t−1  (decay over (s, t−1])
    expo = cw_prev[:, None, :] - cw[None, :, :]          # (t, s, n)
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) \
        > jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    w_ts = jnp.exp(jnp.minimum(expo, 0.0)) * tri[..., None]
    A = jnp.einsum("tn,sn,tsn->ts", r, k, w_ts)
    # diagonal bonus: o_t += (r_t ⊙ u ⊙ k_t) · v_t
    diag = jnp.sum(r * u * k, axis=-1)                    # (chunk,)
    o = o + jnp.einsum("ts,sm->tm", A, v) + diag[:, None] * v

    # state update: S ← diag(e^{cw_L}) S + Σ_s (k_s e^{cw_L − cw_s})ᵀ v_s
    decay_all = jnp.exp(cw[-1])                           # (n,)
    k_scaled = k * jnp.exp(cw[-1][None, :] - cw)          # (chunk, n)
    S = decay_all[:, None] * S + jnp.einsum("sn,sm->nm", k_scaled, v)

    s_ref[...] = S
    o_ref[0] = o.astype(o_ref.dtype)
    # the (bh, 0, 0) output block is revisited every chunk; the last write
    # (final chunk) is the state that lands in HBM
    sfin_ref[0] = S.astype(sfin_ref.dtype)


def wkv6_chunked(
    r: jax.Array,      # (b, t, h, n)
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,      # (b, t, h, n) decay in (0, 1)
    u: jax.Array,      # (h, n) bonus
    *,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
):
    """Chunked WKV6. Returns (out (b, t, h, n), final_state (b, h, n, n)).

    Initial state is zero (prefill). Decode uses the single-step jnp path
    (one token does not need a kernel).
    """
    b, t, h, n = r.shape
    pad = -t % chunk
    # floor at a *normal* fp32 value: subnormals (≤1.17e-38) can be flushed
    # to zero by the backend, and log(0) = -inf poisons the exponent algebra
    lw = jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-30))

    def prep(x):
        x = x.transpose(0, 2, 1, 3).reshape(b * h, t, n)
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        return x

    rg, kg, vg, lwg = prep(r), prep(k), prep(v), prep(lw)
    if pad:
        # padded tail: zero k/v ⇒ no state contribution; logw 0 ⇒ no decay
        lwg = lwg.at[:, t:, :].set(0.0)
    ug = jnp.broadcast_to(u.astype(jnp.float32)[:, None, :], (h, 1, n))
    ug = jnp.tile(ug, (b, 1, 1)).reshape(b * h, 1, n)

    tp = t + pad
    n_chunks = tp // chunk
    grid = (b * h, n_chunks)

    kernel = functools.partial(_wkv6_kernel, chunk=chunk, n_chunks=n_chunks)
    out, sfin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, n), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, 1, n), lambda bh, c: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, n), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, n, n), lambda bh, c: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, tp, n), jnp.float32),
            jax.ShapeDtypeStruct((b * h, n, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(rg, kg, vg, lwg, ug)

    out = out[:, :t].reshape(b, h, t, n).transpose(0, 2, 1, 3)
    return out, sfin.reshape(b, h, n, n)
