"""Training loop: jit'd step + checkpoint manager + fault supervision.

This is the single-process entry used by examples and tests; the launcher
(:mod:`repro.launch.train`) wraps it with mesh setup and sharded arrays.
The loop is deliberately restart-pure: all state lives in (params,
opt_state, step), the data pipeline is a pure function of step, and the
checkpoint manager commits atomically — so `run()` after a crash resumes
bit-exactly.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.distributed.fault import StepFailure, StepWatchdog
from repro.models.registry import ModelApi
from repro.optim.adamw import AdamWConfig
from repro.train.step import init_train_state, make_train_step

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class TrainConfig:
    total_steps: int = 100
    log_every: int = 10
    seed: int = 0
    checkpoint: Optional[CheckpointConfig] = None
    fail_on_nan: bool = True


def train(api: ModelApi, opt_cfg: AdamWConfig, train_cfg: TrainConfig,
          batch_fn: Callable[[int], Dict[str, np.ndarray]],
          *, hooks: Optional[list] = None) -> dict:
    """Run the loop; returns {final_params, opt_state, history}."""
    key = jax.random.PRNGKey(train_cfg.seed)
    params, opt_state, _axes = init_train_state(api, opt_cfg, key)

    mgr = (CheckpointManager(train_cfg.checkpoint)
           if train_cfg.checkpoint else None)
    start_step = 0
    if mgr is not None and mgr.latest_step() is not None:
        (params, opt_state), start_step = mgr.restore((params, opt_state))
        log.info("resumed from step %d", start_step)

    step_fn = jax.jit(make_train_step(api, opt_cfg), donate_argnums=(0, 1))
    watchdog = StepWatchdog()
    history = []

    for step in range(start_step, train_cfg.total_steps):
        t0 = time.perf_counter()
        batch = {k: jnp.asarray(v) for k, v in batch_fn(step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0

        if train_cfg.fail_on_nan and not np.isfinite(loss):
            raise StepFailure(f"non-finite loss {loss} at step {step}")
        if watchdog.observe(step, dt):
            log.warning("straggler step %d: %.3fs (trend %.3fs)", step, dt,
                        watchdog._mean)

        history.append({"step": step, "loss": loss, "seconds": dt})
        if step % train_cfg.log_every == 0:
            log.info("step %d loss %.4f (%.3fs)", step, loss, dt)
        if hooks:
            for h in hooks:
                h(step, params, metrics)
        if mgr is not None:
            mgr.maybe_save(step + 1, (params, opt_state))

    if mgr is not None:
        mgr.save(train_cfg.total_steps, (params, opt_state))
        mgr.wait()
    return {"params": params, "opt_state": opt_state, "history": history}
