"""Integer interval domain for the static circuit analyzer.

The abstract value is a per-element signed integer interval ``[lo, hi]``:
an :class:`IntervalTensor` carries two ``np.int64`` arrays of the tensor's
shape, and every transfer function here is *sound* — for any concrete
element ``x ∈ [lo_e, hi_e]`` the concrete op result lies inside the
abstract result's interval.  Per-element (rather than per-tensor) bounds
matter because cleartext weights are concrete: a plaintext-weight matmul
bounds each output channel by the channel's own signed weight column, which
is what keeps whole-block widths near the measured high-water instead of a
uniform worst case over the weight clip.

Bounds are exact int64 arithmetic with an explicit headroom guard — a bound
past ``2^62`` raises :class:`IntervalOverflow` instead of silently wrapping
(wrapped bounds would be an unsound analysis, the one failure mode a static
analyzer must never have).
"""

from __future__ import annotations

import numpy as np

#: int64 headroom guard: interval endpoints past this magnitude abort the
#: analysis (products of two guarded endpoints still need checking by the
#: caller *before* they are materialized — see :func:`mul_bounds`).
GUARD = np.int64(1) << 62

#: largest LUT domain the analyzer will materialize (tables are evaluated
#: over the whole declared domain to bound outputs by range min/max)
MAX_LUT_DOMAIN = 1 << 24


class IntervalOverflow(OverflowError):
    """Static bounds left the exact-int64 regime — the analysis cannot
    continue soundly (the circuit would overflow the lanes long before)."""


def _checked(lo: np.ndarray, hi: np.ndarray, what: str = "op"):
    lo = np.asarray(lo, np.int64)
    hi = np.asarray(hi, np.int64)
    if lo.shape != hi.shape:
        lo, hi = np.broadcast_arrays(lo, hi)
        lo, hi = lo.copy(), hi.copy()
    if lo.size and (int(np.max(np.abs(lo))) >= GUARD
                    or int(np.max(np.abs(hi))) >= GUARD):
        raise IntervalOverflow(
            f"static interval bound exceeded 2^62 during {what!r}; the "
            "circuit's worst case overflows exact int64 analysis")
    if lo.size and np.any(lo > hi):
        raise ValueError(f"inverted interval produced by {what!r} "
                         "(analyzer bug: lo > hi)")
    return lo, hi


class IntervalTensor:
    """Abstract lane handle: per-element signed integer bounds."""

    __slots__ = ("lo", "hi")

    def __init__(self, lo, hi, *, what: str = "interval"):
        self.lo, self.hi = _checked(lo, hi, what)

    # ---- ndarray-protocol surface the base Lane structure ops use ----
    @property
    def shape(self):
        return self.lo.shape

    @property
    def ndim(self) -> int:
        return self.lo.ndim

    @property
    def size(self) -> int:
        return int(self.lo.size)

    def reshape(self, shape):
        return IntervalTensor(self.lo.reshape(shape), self.hi.reshape(shape))

    def transpose(self, axes):
        return IntervalTensor(self.lo.transpose(axes),
                              self.hi.transpose(axes))

    def __getitem__(self, idx):
        return IntervalTensor(self.lo[idx], self.hi[idx])

    # ---- summaries ----
    def extremes(self):
        """Global (min lo, max hi) as python ints (0, 0) when empty."""
        if not self.lo.size:
            return 0, 0
        return int(self.lo.min()), int(self.hi.max())

    def max_abs(self) -> int:
        lo, hi = self.extremes()
        return max(abs(lo), abs(hi))

    def __repr__(self):
        lo, hi = self.extremes()
        return f"IntervalTensor(shape={self.shape}, range=[{lo}, {hi}])"


def as_interval(x) -> IntervalTensor:
    """Concrete scalar/array → exact (degenerate) interval."""
    if isinstance(x, IntervalTensor):
        return x
    a = np.asarray(x, np.int64)
    return IntervalTensor(a, a.copy())


def broadcast_interval(t: IntervalTensor, shape) -> IntervalTensor:
    return IntervalTensor(np.broadcast_to(t.lo, shape).copy(),
                          np.broadcast_to(t.hi, shape).copy())


def mul_bounds(a: IntervalTensor, b: IntervalTensor,
               what: str = "mul") -> IntervalTensor:
    """Sound product interval: elementwise min/max over the four endpoint
    products.  Endpoint products are pre-checked in float so an int64 wrap
    can never produce a silently-unsound bound."""
    if float(a.max_abs()) * float(b.max_abs()) >= float(GUARD):
        raise IntervalOverflow(
            f"interval product exceeds 2^62 during {what!r}")
    p1 = a.lo * b.lo
    p2 = a.lo * b.hi
    p3 = a.hi * b.lo
    p4 = a.hi * b.hi
    lo = np.minimum(np.minimum(p1, p2), np.minimum(p3, p4))
    hi = np.maximum(np.maximum(p1, p2), np.maximum(p3, p4))
    return IntervalTensor(lo, hi, what=what)


def literal_mul_bounds(t: IntervalTensor, c) -> IntervalTensor:
    """Interval × concrete cleartext literal (scalar or array)."""
    return mul_bounds(t, as_interval(c), what="mul_literal")


def matmul_plain_bounds(t: IntervalTensor, w: np.ndarray) -> IntervalTensor:
    """(..., d_in) × concrete (d_in, d_out): per-output-channel bounds via
    the signed split w = w⁺ + w⁻ (w⁺ = max(w, 0), w⁻ = min(w, 0))."""
    w = np.asarray(w, np.int64)
    if float(t.max_abs()) * float(np.abs(w).sum(axis=0).max(initial=0)) \
            >= float(GUARD):
        raise IntervalOverflow("matmul_plain bound exceeds 2^62")
    wp = np.maximum(w, 0)
    wn = np.minimum(w, 0)
    lo = t.lo @ wp + t.hi @ wn
    hi = t.hi @ wp + t.lo @ wn
    return IntervalTensor(lo, hi, what="matmul_plain")


# ---------------------------------------------------------------------------
# Range min/max over materialized LUT tables (sparse-table RMQ)
# ---------------------------------------------------------------------------

def table_range_minmax(tbl: np.ndarray, i0: np.ndarray, i1: np.ndarray):
    """Vectorized inclusive range min/max over ``tbl``: for each query
    ``(i0_e, i1_e)`` return ``(min tbl[i0_e:i1_e+1], max ...)``.

    Bounds a LUT output by the table's extremes over the *reachable*
    (saturated) input range of each element.  O(D log D) sparse-table
    build, O(1) per query — domains are bounded by MAX_LUT_DOMAIN.
    """
    tbl = np.asarray(tbl, np.int64)
    i0 = np.asarray(i0, np.intp)
    i1 = np.asarray(i1, np.intp)
    if np.any(i0 > i1):
        raise ValueError("range query with i0 > i1")
    n = tbl.shape[0]
    if n == 0:
        raise ValueError("empty LUT table")
    # sparse tables: level k covers windows of 2^k
    mins, maxs = [tbl], [tbl]
    k = 1
    while (1 << k) <= n:
        half = 1 << (k - 1)
        prev_mn, prev_mx = mins[-1], maxs[-1]
        mins.append(np.minimum(prev_mn[:-half], prev_mn[half:]))
        maxs.append(np.maximum(prev_mx[:-half], prev_mx[half:]))
        k += 1
    length = i1 - i0 + 1
    # floor(log2(length)) per query
    lev = np.frexp(length.astype(np.float64))[1] - 1
    lev = np.clip(lev, 0, len(mins) - 1).astype(np.intp)
    lo_out = np.empty(i0.shape, np.int64)
    hi_out = np.empty(i0.shape, np.int64)
    for level in np.unique(lev):
        sel = lev == level
        span = 1 << int(level)
        a = i0[sel]
        b = i1[sel] - span + 1
        lo_out[sel] = np.minimum(mins[level][a], mins[level][b])
        hi_out[sel] = np.maximum(maxs[level][a], maxs[level][b])
    return lo_out, hi_out
