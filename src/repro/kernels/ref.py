"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel test sweeps shapes/dtypes and asserts allclose against these.
They are thin reorderings of the core/nn reference implementations so that
the kernels and the model code share a single source of truth.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.dotprod import dot_product_attention
from repro.core.inhibitor import (
    causal_mask,
    inhibitor_attention,
    sliding_window_mask,
)
from repro.nn.ssm import wkv6_scan_ref


def _mask_for(n_q: int, n_k: int, causal: bool, window: Optional[int]):
    # Kernel convention: query block positions start at 0 (training/prefill);
    # decode goes through the jnp cache path, not the kernel.
    if causal and window is not None:
        return sliding_window_mask(n_q, n_k, window)[None, None]
    if causal:
        return causal_mask(n_q, n_k)[None, None]
    if window is not None:
        return sliding_window_mask(n_q, n_k, window)[None, None]
    return None


def flash_inhibitor_ref(q, k, v, *, score_scale=None, score_shift=0.5,
                        signed=True, normalize=True, causal=True,
                        window=None):
    """Oracle for kernels.inhibitor.flash_inhibitor_fwd."""
    mask = _mask_for(q.shape[1], k.shape[1], causal, window)
    return inhibitor_attention(
        q, k, v, mask=mask, score_scale=score_scale,
        score_shift=score_shift, signed=signed, normalize=normalize)


def flash_attention_ref(q, k, v, *, score_scale=None, causal=True,
                        window=None):
    """Oracle for kernels.flash.flash_attention_fwd."""
    mask = _mask_for(q.shape[1], k.shape[1], causal, window)
    return dot_product_attention(q, k, v, mask=mask, score_scale=score_scale)


def wkv6_ref(r, k, v, w, u, state=None):
    """Oracle for kernels.rwkv6.wkv6_chunked (exact lax.scan recurrence)."""
    return wkv6_scan_ref(r, k, v, w, u, state)
