"""Paper Table 4: estimated encrypted execution time vs sequence length.

Exact PBS/add/lit-mul inventories from the TFHE circuit simulator ×
the calibrated cost model (fhe.cost).  Paper claim: 3–6× inhibitor
speedup under encryption, growing circuits with T.
"""

from __future__ import annotations

import numpy as np

from repro.core.mechanism import get_mechanism
from repro.fhe import circuit_seconds

PAPER = {  # published Table 4 (seconds)
    2: (0.749, 2.68), 4: (8.56, 22.4), 8: (23.8, 107), 16: (127, 828),
}


def run(smoke: bool = False) -> list:
    inhibitor_circuit = get_mechanism("inhibitor").fhe_circuit
    dotprod_circuit = get_mechanism("dotprod").fhe_circuit
    rows = []
    rng = np.random.default_rng(0)
    for T in (2, 4) if smoke else (2, 4, 8, 16):
        d = 2
        q = rng.integers(-7, 8, (T, d))
        k = rng.integers(-7, 8, (T, d))
        v = rng.integers(-7, 8, (T, d))
        _, s_inh = inhibitor_circuit(q, k, v, gamma_shift=1, alpha_q=1)
        _, s_dot = dotprod_circuit(q, k, v, scale_shift=2)
        t_i, t_d = circuit_seconds(s_inh), circuit_seconds(s_dot)
        pi, pd = PAPER[T]
        rows.append((f"table4/T{T}/inhibitor", round(t_i * 1e6, 0),
                     f"est={t_i:.2f}s;paper={pi}s"))
        rows.append((f"table4/T{T}/dotprod", round(t_d * 1e6, 0),
                     f"est={t_d:.2f}s;paper={pd}s"))
        rows.append((f"table4/T{T}/speedup", 0.0,
                     f"est={t_d / t_i:.2f}x;paper={pd / pi:.2f}x"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
