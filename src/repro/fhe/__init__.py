"""TFHE simulation layer: exact integer circuits + cost/parameter models."""

from repro.fhe.circuits import (  # noqa: F401
    dotprod_attention_circuit,
    inhibitor_attention_circuit,
)
from repro.fhe.cost import circuit_seconds, describe, pbs_seconds  # noqa: F401
from repro.fhe.params import (  # noqa: F401
    TfheParams,
    select_params,
    select_params_for_report,
    select_params_static,
)
from repro.fhe.tfhe_sim import EncTensor, FheContext, decrypt, encrypt  # noqa: F401
