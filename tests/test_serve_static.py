"""Serve-path static analysis (repro.analysis.serve_static, DESIGN.md §13).

Covers the three analyzer passes and the engine changes they audit:

* retrace-budget enumeration soundness — a live serve run (paged AND
  contiguous) never compiles more prefill/decode traces than the
  analyzer proved reachable, and the bucketed enumeration matches the
  closed-form pow2 sets;
* the deliberately-unbucketed regression fixture (rwkv / ssm family)
  is rejected: proven compile set exceeds the declared budget, API and
  CLI both fail;
* host-sync inventory stability — every tick-path sync site is tagged,
  the per-tick transfer contract holds, the batched block-table flush
  is the only table upload, and LANE004 enforces the tags;
* costmodel unit checks against jax's own lowered cost_analysis where
  the backend provides one, plus gather byte accounting and kernel
  candidate priors;
* the S1 batched-upload change: at most one block-table upload per
  decode tick, greedy parity preserved against the sequential oracle.
"""

import json

import numpy as np
import pytest


# ---------------------------------------------------------------------------
# retrace-budget enumeration (pure)
# ---------------------------------------------------------------------------

def test_prefill_bucket_enumeration_closed_form():
    from repro.analysis.serve_static import enumerate_prefill_buckets

    # bucketed: every reachable width is a pow2 <= chunk — exactly the
    # {1, 2, ..., chunk} set the engine's bucket design promises
    widths = enumerate_prefill_buckets(max_len=64, prefill_chunk=8,
                                       bucketed=True, page_size=8,
                                       prefix_cache=True)
    assert widths == [1, 2, 4, 8]


def test_prefill_enumeration_unbucketed_exceeds_declared():
    from repro.analysis.serve_static import (enumerate_prefill_buckets,
                                             retrace_budget)

    widths = enumerate_prefill_buckets(max_len=64, prefill_chunk=8,
                                       bucketed=False)
    assert widths == list(range(1, 9))      # every partial width traces
    b = retrace_budget(bucketed=False, paged=False, max_len=64,
                       prefill_chunk=8, prefix_cache=False)
    assert b["prefill"]["proven"] == 8 > b["prefill"]["declared"] == 4
    assert not b["within_budget"]


def test_decode_bucket_enumeration_closed_form():
    from repro.analysis.serve_static import enumerate_decode_buckets

    assert enumerate_decode_buckets(max_len=64, page_size=8,
                                    pages_per_slot=8) == [1, 2, 4, 8]
    # non-pow2 pages_per_slot: the clamp caps the top bucket
    assert enumerate_decode_buckets(max_len=48, page_size=8,
                                    pages_per_slot=6) == [1, 2, 4, 6]


def test_retrace_budget_within_for_bucketed_paged():
    from repro.analysis.serve_static import retrace_budget

    b = retrace_budget(bucketed=True, paged=True, max_len=64,
                       prefill_chunk=8, page_size=8, pages_per_slot=8,
                       prefix_cache=True)
    assert b["within_budget"]
    assert b["proven_total"] == 4 + 4 + 1   # prefill + decode + pool copy
    assert b["proven_total"] <= b["declared_total"]


def test_chunk_resume_proof_closed_and_in_budget():
    """Continuous batching's proof obligation: resuming a schedule at a
    chunk boundary reproduces its suffix exactly and introduces no chunk
    width outside the whole-prompt enumeration."""
    from repro.analysis.serve_static import (retrace_budget,
                                             verify_chunk_resume)

    r = verify_chunk_resume(max_len=64, prefill_chunk=8, bucketed=True,
                            page_size=8, prefix_cache=True)
    assert r["closed"] and r["suffix_exact"] and r["new_widths"] == []
    assert r["resume_points"] > 0
    b = retrace_budget(bucketed=True, paged=True, max_len=64,
                       prefill_chunk=8, page_size=8, pages_per_slot=8,
                       prefix_cache=True)
    assert b["chunk_resume"]["closed"] and b["within_budget"]


def test_schedule_helpers_match_engine_methods(serve_model):
    """The module-level pure functions ARE what the engine runs — the
    proof enumerates the engine's actual behavior, not a model of it."""
    from repro.serve.engine import (Engine, EngineConfig, decode_table_width,
                                    prefill_schedule)

    cfg, api, params = serve_model
    eng = Engine(api, params, EngineConfig(max_batch=2, max_len=64,
                                           page_size=8, prefill_chunk=8))
    for plen in (1, 7, 8, 9, 30, 63):
        assert eng._prefill_schedule(plen) == prefill_schedule(
            plen, chunk=eng.cfg.prefill_chunk, max_len=eng.cfg.max_len,
            bucketed=eng._bucketed)
    for longest in (1, 8, 9, 17, 64):
        assert decode_table_width(
            longest, page_size=8,
            pages_per_slot=eng.alloc.pages_per_slot) <= eng.alloc.pages_per_slot


# ---------------------------------------------------------------------------
# live soundness: measured compiles <= proven, both allocators
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("allocator", ["paged", "contiguous"])
def test_signature_enumeration_soundness_live(serve_model, allocator):
    from repro.serve.engine import Engine, EngineConfig, Request

    cfg, api, params = serve_model
    eng = Engine(api, params, EngineConfig(allocator=allocator, max_batch=4,
                                           max_len=64, page_size=8,
                                           prefill_chunk=8))
    rng = np.random.default_rng(0)
    # distinct prompt lengths across every bucket, incl. a long one that
    # walks the decode table through several width buckets
    for i, plen in enumerate((1, 3, 8, 17, 40)):
        eng.submit(Request(i, rng.integers(1, 127, plen).astype(np.int32),
                           max_new_tokens=10))
    eng.run_to_completion()
    s = eng.stats()
    budget = s["retrace_budget"]
    assert budget["within_declared"]
    # THE soundness property: live compile counters never exceed proven
    assert s["prefill_compiles"] <= budget["prefill_proven"]
    assert s["decode_compiles"] <= budget["decode_proven"]
    if allocator == "contiguous":
        assert s["decode_compiles"] == 1


def test_decode_compiles_counts_table_buckets(serve_model):
    """A workload crossing table-width buckets retraces decode once per
    bucket — and the counter sees every one."""
    from repro.serve.engine import Engine, EngineConfig, Request

    cfg, api, params = serve_model
    eng = Engine(api, params, EngineConfig(max_batch=2, max_len=64,
                                           page_size=8, prefill_chunk=8))
    rng = np.random.default_rng(1)
    eng.submit(Request(0, rng.integers(1, 127, 3).astype(np.int32),
                       max_new_tokens=30))
    eng.run_to_completion()
    assert eng.decode_compiles == len(eng._decode_table_buckets)
    assert eng.decode_compiles >= 2        # 3+30 tokens cross 8 and 16+


# ---------------------------------------------------------------------------
# analyzer end-to-end + unbucketed rejection + bench cross-check
# ---------------------------------------------------------------------------

def test_analyze_serve_end_to_end(tmp_path):
    from repro.analysis.serve_static import analyze_serve

    doc = analyze_serve(
        "smollm-135m",
        reduced=dict(num_layers=2, d_model=32, d_ff=64, vocab_size=128),
        engine_kw=dict(max_batch=2, max_len=32, page_size=8,
                       prefill_chunk=8))
    assert doc["ok"]
    for alloc in ("paged", "contiguous"):
        arm = doc["allocators"][alloc]
        assert arm["retrace"]["within_budget"]
        assert arm["signatures"]["verified"]
        # no host callback hides inside the jitted steps
        assert arm["roofline"]["jit_host_callbacks"] == 0
        # every signature got a roofline entry
        assert len(arm["roofline"]["decode"]["per_bucket"]) == \
            arm["retrace"]["decode"]["proven"]
    assert doc["sync_audit"]["ok"]
    (tmp_path / "a.json").write_text(json.dumps(doc))   # JSON-serializable


def test_analyzer_rejects_unbucketed_family():
    """rwkv (ssm family) prefills exact-width chunks: its compile set
    grows with prompt-length diversity and MUST fail the budget proof."""
    from repro.analysis.serve_static import analyze_serve

    doc = analyze_serve("rwkv6-7b", reduced={},
                        engine_kw=dict(max_batch=2, max_len=32,
                                       page_size=8, prefill_chunk=8))
    assert not doc["ok"]
    for arm in doc["allocators"].values():
        assert not arm["retrace"]["within_budget"]
        assert (arm["retrace"]["prefill"]["proven"]
                > arm["retrace"]["prefill"]["declared"])


def test_cli_smoke_and_unbucketed_exit_codes(tmp_path):
    from repro.analysis import serve as cli

    out = tmp_path / "ANALYSIS_serve.json"
    rc = cli.main(["--config", "smollm-135m", "--reduced",
                   "--max-batch", "2", "--max-len", "32",
                   "--page-size", "8", "--prefill-chunk", "8",
                   "--out", str(out)])
    assert rc == 0
    doc = json.loads(out.read_text())
    assert doc["ok"] and doc["schema"] == 2

    rc = cli.main(["--config", "rwkv6-7b", "--reduced",
                   "--max-batch", "2", "--max-len", "32",
                   "--page-size", "8", "--prefill-chunk", "8",
                   "--out", str(tmp_path / "rejected.json")])
    assert rc == 1


def test_cross_check_bench_soundness_direction():
    from repro.analysis.serve_static import cross_check_bench

    engine = {"family": "dense", "allocator": "paged", "bucketed": True,
              "max_batch": 4, "max_len": 64, "page_size": 8,
              "prefill_chunk": 8, "pages_per_slot": 8,
              "prefix_cache": True}
    ok_doc = {"paged": {"engine": engine, "prefill_compiles": 3,
                        "decode_compiles": 4}}
    assert cross_check_bench(ok_doc)["ok"]
    # measured above proven is a SOUNDNESS BUG, loudly reported
    bad_doc = {"paged": {"engine": engine, "prefill_compiles": 99,
                         "decode_compiles": 4}}
    res = cross_check_bench(bad_doc)
    assert not res["ok"]
    assert any("SOUNDNESS BUG" in f
               for f in res["arms"]["paged"]["failures"])


# ---------------------------------------------------------------------------
# host-sync audit + LANE004
# ---------------------------------------------------------------------------

def test_sync_inventory_stable():
    """The tick path's sync inventory is pinned: adding a sync (or
    dropping a tag) changes this set and must be a conscious edit."""
    from repro.analysis.serve_static import audit_engine_file

    audit = audit_engine_file()
    assert audit["ok"]
    assert audit["unallowlisted"] == []
    got = {(s["func"], s["api"], s["kind"], s["cls"])
           for s in audit["sites"]}
    assert got == {
        ("_exec_chunks", "np.asarray", "d2h", "host"),
        ("_exec_chunks", "jnp.asarray", "h2d", "required"),
        ("_exec_chunks", "jnp.int32", "h2d", "eliminable"),
        ("_exec_chunks", "int()", "d2h", "required"),
        ("_copy_page", "jnp.int32", "h2d", "required"),
        ("_flush_tables", "jnp.asarray", "h2d", "required"),
        ("_append_token", "int()", "d2h", "host"),
        ("_finish", "np.asarray", "d2h", "host"),
        ("step", "jnp.asarray", "h2d", "required"),
        ("step", "np.asarray", "d2h", "required"),
    }
    # per-tick contract: one batched table flush + one token upload in,
    # one token readback out
    assert audit["per_tick"] == {"h2d": 2, "d2h": 1}
    assert audit["block_table_uploads_per_tick"]["after"] == 1


def test_lane004_flags_untagged_and_accepts_tagged():
    from repro.analysis.lint import lint_source

    untagged = (
        "import numpy as np\n"
        "class Engine:\n"
        "    def step(self):\n"
        "        nxt = np.asarray(self.decode())\n"
        "    def decode(self):\n"
        "        return 0\n")
    vs = lint_source(untagged, path="src/repro/serve/engine.py")
    assert any(v.rule == "LANE004" for v in vs)
    # same source under a different path: rule does not apply
    assert not lint_source(untagged, path="src/repro/serve/other.py")

    tagged = untagged.replace(
        "np.asarray(self.decode())",
        "np.asarray(self.decode())  # sync: required — readback")
    assert not lint_source(tagged, path="src/repro/serve/engine.py")


def test_repo_engine_is_lane004_clean():
    import repro.serve.engine as engine_mod
    from repro.analysis.lint import lint_paths

    assert lint_paths([engine_mod.__file__]) == []


def test_tick_path_closure_contains_hot_functions():
    import ast
    from pathlib import Path

    import repro.serve.engine as engine_mod
    from repro.analysis.serve_static import tick_path_functions

    tree = ast.parse(Path(engine_mod.__file__).read_text())
    funcs = tick_path_functions(tree)
    # _prefill_chunk/_decode_step run under jax.jit — the closure tracks
    # eager Python calls only, so the jitted bodies are rightly absent
    assert {"step", "_run_prefills", "_advance_one", "_exec_chunks",
            "_reserve_chunks", "_complete_admission", "_flush_tables",
            "_finish", "_copy_page", "_ensure_pages",
            "_stage_slot"} <= funcs
    assert "submit" not in funcs           # caller-side, not tick path
    assert "cancel" not in funcs           # caller-side, not tick path


# ---------------------------------------------------------------------------
# costmodel units
# ---------------------------------------------------------------------------

def test_costmodel_matmul_flops_exact():
    import jax
    import jax.numpy as jnp

    from repro.analysis.costmodel import jaxpr_costs

    m, k, n = 8, 16, 4
    f = lambda a, b: a @ b                              # noqa: E731
    args = (jax.ShapeDtypeStruct((m, k), jnp.float32),
            jax.ShapeDtypeStruct((k, n), jnp.float32))
    costs = jaxpr_costs(jax.make_jaxpr(f)(*args))
    assert costs.flops == 2 * m * n * k
    assert costs.host_callbacks == 0


def test_costmodel_matches_jax_cost_analysis():
    """Where the backend exposes a lowered cost_analysis, our dot FLOPs
    must agree exactly (same 2·M·N·K convention)."""
    import jax
    import jax.numpy as jnp

    from repro.analysis.costmodel import jaxpr_costs

    m, k, n = 8, 16, 4
    f = lambda a, b: a @ b                              # noqa: E731
    args = (jax.ShapeDtypeStruct((m, k), jnp.float32),
            jax.ShapeDtypeStruct((k, n), jnp.float32))
    try:
        ca = jax.jit(f).lower(*args).cost_analysis()
    except Exception:
        ca = None
    if isinstance(ca, list):
        ca = ca[0] if ca else None
    if not isinstance(ca, dict) or "flops" not in ca:
        # backend without a cost model: the exact-FLOPs unit test above
        # still pins the convention
        return
    assert jaxpr_costs(jax.make_jaxpr(f)(*args)).flops == ca["flops"]


def test_costmodel_gather_charges_moved_bytes_only():
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.analysis.costmodel import jaxpr_costs

    pool = jax.ShapeDtypeStruct((1024, 64), jnp.float32)   # 256 KiB
    idx = jnp.asarray(np.arange(4, dtype=np.int32))

    def f(p):
        return p[idx]                                      # 4 rows out

    costs = jaxpr_costs(jax.make_jaxpr(f)(pool))
    # moved data (4 rows in+out) + indices — nowhere near the pool size
    assert costs.hbm_bytes < 1024 * 64 * 4 / 8


def test_costmodel_scan_multiplies_by_length():
    import jax
    import jax.numpy as jnp

    from repro.analysis.costmodel import jaxpr_costs

    def f(xs):
        return jax.lax.scan(lambda c, x: (c + x * x, c), 0.0, xs)

    c5 = jaxpr_costs(jax.make_jaxpr(f)(
        jax.ShapeDtypeStruct((5,), jnp.float32)))
    c50 = jaxpr_costs(jax.make_jaxpr(f)(
        jax.ShapeDtypeStruct((50,), jnp.float32)))
    assert c50.flops == pytest.approx(10 * c5.flops)


def test_costmodel_detects_host_callbacks():
    import jax

    from repro.analysis.costmodel import jaxpr_costs

    def f(x):
        jax.debug.print("x={x}", x=x)
        return x + 1

    assert jaxpr_costs(jax.make_jaxpr(f)(1.0)).host_callbacks == 1


def test_kernel_prior_ranks_paged_candidates():
    from repro.analysis.costmodel import kernel_prior, rank_kernel_candidates
    from repro.kernels.ops import CANDIDATES, KernelChoice

    shape_key = ("inhibitor", 16, 16, 8, 8, 64)   # fam,pages,ps,h,hkv,d
    few = KernelChoice(pages_per_step=1)
    many = KernelChoice(pages_per_step=8)
    # same bytes + flops either way; fewer grid dispatches must win
    assert kernel_prior("paged", shape_key, many) < \
        kernel_prior("paged", shape_key, few)
    ranked = rank_kernel_candidates("paged", shape_key,
                                    CANDIDATES["paged"])
    assert [p for _, p in ranked] == sorted(p for _, p in ranked)
    # a candidate staging more than the VMEM budget is statically out
    huge = KernelChoice(pages_per_step=1 << 20)
    assert kernel_prior("paged", shape_key, huge) == float("inf")


def test_registry_times_candidates_in_prior_order(monkeypatch):
    from repro.kernels.ops import CANDIDATES, registry

    registry.reset()
    monkeypatch.setattr(registry, "_interpret", False)
    shape_key = (32, 1024, 8, 8, 64, True, None, False)
    timed = []

    def timer(choice):
        timed.append(choice)
        return 1.0 + len(timed)        # first-timed wins

    try:
        choice = registry.choose("flash", shape_key, None, timer)
        priors = list(registry.priors.get(("flash",) + shape_key, []))
    finally:
        registry.reset()
    assert timed, "timer never consulted"
    # the priors table was recorded and timing followed its order
    assert timed == [c for c, p in priors
                     if p != float("inf")] or timed == CANDIDATES["flash"]
    assert choice == timed[0]


# ---------------------------------------------------------------------------
# S1: batched block-table upload
# ---------------------------------------------------------------------------

def test_batched_table_upload_per_tick_and_parity(serve_model, greedy_ref):
    from repro.serve.engine import Engine, EngineConfig, Request

    cfg, api, params = serve_model
    eng = Engine(api, params, EngineConfig(max_batch=4, max_len=64,
                                           page_size=8, prefill_chunk=8))
    rng = np.random.default_rng(2)
    prompts = [rng.integers(1, 127, plen).astype(np.int32)
               for plen in (3, 11, 26)]
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new_tokens=10))
    done = {r.request_id: r.output for r in eng.run_to_completion()}
    s = eng.stats()
    # the S1 contract: at most ONE batched upload per decode tick (and
    # strictly fewer in steady state — no-growth ticks upload nothing)
    assert s["table_uploads_decode"] <= s["decode_ticks"]
    assert s["table_uploads"] > 0
    for i, p in enumerate(prompts):
        assert done[i] == greedy_ref(p, 10), f"request {i} diverged"


def test_flush_skips_clean_ticks(serve_model):
    """Steady-state decode (no growth, no admission) re-uploads nothing:
    the device tables are resident, not re-mirrored per tick."""
    from repro.serve.engine import Engine, EngineConfig, Request

    cfg, api, params = serve_model
    eng = Engine(api, params, EngineConfig(max_batch=2, max_len=64,
                                           page_size=8, prefill_chunk=8))
    rng = np.random.default_rng(3)
    eng.submit(Request(0, rng.integers(1, 127, 4).astype(np.int32),
                       max_new_tokens=3))
    eng.step()                              # admission tick
    base = eng.stats()["table_uploads"]
    eng.step()                              # pure decode inside page 1
    assert eng.stats()["table_uploads"] == base
