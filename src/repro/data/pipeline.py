"""Sharded, deterministic, resumable data pipeline.

Production constraints this implements (DESIGN.md §3):

  * **Determinism / resumability** — batches are a pure function of
    (seed, step): restoring a checkpoint at step N replays the exact
    stream with no iterator state to persist.
  * **Sharding** — each data-parallel host materializes only its slice of
    the global batch (``host_slice``); the global batch is assembled by
    ``jax.make_array_from_process_local_data`` on real multi-host runs and
    by simple concatenation in tests.
  * **Prefetch** — a background thread keeps ``prefetch`` batches ahead of
    the training loop (CPU generation overlaps the device step).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    global_batch: int
    seq_len: int
    vocab_size: int
    seed: int = 0
    num_hosts: int = 1
    host_index: int = 0
    prefetch: int = 2


def _host_slice(cfg: PipelineConfig):
    per_host = cfg.global_batch // cfg.num_hosts
    lo = cfg.host_index * per_host
    return lo, lo + per_host


def lm_batch_at(cfg: PipelineConfig, step: int) -> Dict[str, np.ndarray]:
    """The (seed, step)-determined LM batch slice for this host."""
    from repro.data.synthetic import lm_tokens

    lo, hi = _host_slice(cfg)
    # derive a per-(step) seed; generate the host's rows only by offsetting
    # the generator seed per host for independence + determinism
    seed = (cfg.seed * 1_000_003 + step) % (2 ** 31 - 1)
    tokens, labels = lm_tokens(hi - lo, cfg.seq_len, cfg.vocab_size,
                               seed * cfg.num_hosts + cfg.host_index)
    return {"tokens": tokens, "labels": labels}


class Prefetcher:
    """Background-thread prefetch over a (step -> batch) function."""

    def __init__(self, batch_fn: Callable[[int], dict], start_step: int = 0,
                 depth: int = 2):
        self._fn = batch_fn
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put((step, self._fn(step)), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        # drain so the worker unblocks
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
