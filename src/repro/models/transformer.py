"""Decoder-only transformer LM covering the dense / MoE / hybrid families.

One homogeneous, `lax.scan`-able block per config: parameters are stacked
with a leading ("layers",) axis and the forward pass scans over them, so
the compiled HLO contains each layer's program once regardless of depth
(30–48 layers compile in seconds, and remat policy applies per layer).

Block (pre-norm):
    a   = token_mixer(norm1(x))        # attention, or attention ∥ mamba
    x   = x + a
    f   = ffn_or_moe(norm2(x))
    x   = x + f

The token mixer's attention mechanism — dot-product, the paper's
Inhibitor, or any other registered mechanism — is resolved through the
:mod:`repro.core.mechanism` registry (``cfg.attention.mechanism``, legacy
``cfg.attention.kind``), and the execution backend is chosen per shape by
its planner; the hybrid family (hymba) averages a parallel mamba branch
with the attention branch.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.attention import (
    AttentionConfig, KVCache, PagedKVCache, apply_attention, init_attention,
    init_kv_cache, init_paged_kv_cache)
from repro.distributed.sharding import constrain
from repro.nn import embedding as emb
from repro.nn import mlp as mlpnn
from repro.nn import moe as moenn
from repro.nn import norm as normnn
from repro.nn import ssm as ssmnn
from repro.nn.module import KeyGen, Param, fold_key


# ---------------------------------------------------------------------------
# Per-layer state (decode caches)
# ---------------------------------------------------------------------------

class LayerState(NamedTuple):
    """Decode-time state for ONE layer (stacked over layers in practice)."""
    kv: Optional[KVCache] = None          # attention cache
    ssm: Optional[jax.Array] = None       # mamba ssm state (b, c, n)
    conv: Optional[jax.Array] = None      # mamba conv carry (b, k-1, c)


# ---------------------------------------------------------------------------
# Norm dispatch
# ---------------------------------------------------------------------------

def _init_norm(cfg: ModelConfig, dtype):
    if cfg.norm == "rmsnorm":
        return normnn.init_rmsnorm(cfg.d_model, dtype=dtype)
    return normnn.init_layernorm(cfg.d_model, dtype=dtype)


def _apply_norm(cfg: ModelConfig, p, x):
    if cfg.norm == "rmsnorm":
        return normnn.apply_rmsnorm(p, x, eps=cfg.norm_eps)
    return normnn.apply_layernorm(p, x, eps=cfg.norm_eps)


def _init_ffn(key, cfg: ModelConfig, dtype):
    if cfg.mlp == "gated_silu":
        return mlpnn.init_gated_mlp(key, cfg.d_model, cfg.d_ff,
                                    use_bias=cfg.mlp_bias, dtype=dtype)
    return mlpnn.init_mlp(key, cfg.d_model, cfg.d_ff,
                          use_bias=cfg.mlp_bias, dtype=dtype)


def _apply_ffn(cfg: ModelConfig, p, x, cdt):
    if cfg.mlp == "gated_silu":
        return mlpnn.apply_gated_mlp(p, x, activation="silu",
                                     compute_dtype=cdt)
    act = "gelu" if cfg.mlp == "mlp_gelu" else "relu"
    return mlpnn.apply_mlp(p, x, activation=act, compute_dtype=cdt)


# ---------------------------------------------------------------------------
# One block
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig) -> dict:
    from repro.core.mechanism import get_mechanism, resolve_mechanism_name

    get_mechanism(resolve_mechanism_name(cfg.attention))  # fail fast
    kg = KeyGen(key)
    dtype = cfg.pdtype
    p = {
        "ln1": _init_norm(cfg, dtype),
        "attn": init_attention(kg("attn"), cfg.attention, cfg.d_model,
                               dtype=dtype),
        "ln2": _init_norm(cfg, dtype),
    }
    if cfg.family == "hybrid":
        assert cfg.ssm is not None and cfg.ssm.kind == "mamba"
        inner = cfg.ssm.inner_dim or 2 * cfg.d_model
        p["mamba"] = ssmnn.init_mamba(
            kg("mamba"), cfg.d_model, inner, state_dim=cfg.ssm.state_dim,
            conv_dim=cfg.ssm.conv_dim, dt_rank=cfg.ssm.dt_rank, dtype=dtype)
        # learned per-branch output scales (hymba fuses mean of normed outs)
        p["branch_scale"] = Param(jnp.ones((2,), dtype), (None,))
    if cfg.moe is not None:
        p["moe"] = moenn.init_moe(
            kg("moe"), cfg.d_model, cfg.moe.expert_hidden_dim,
            cfg.moe.effective_experts,
            shared_hidden_dim=cfg.moe.shared_hidden_dim,
            shared_gate=cfg.moe.shared_gate, dtype=dtype)
    else:
        p["ffn"] = _init_ffn(kg("ffn"), cfg, dtype)
    return p


def apply_block(params: dict, cfg: ModelConfig, x: jax.Array, *,
                positions=None, state: Optional[LayerState] = None,
                attn_mask=None):
    """Returns (x, new_state, aux_losses (2,))."""
    cdt = cfg.cdtype
    h = _apply_norm(cfg, params["ln1"], x)
    h = constrain(h, "batch", "seq_sp", "embed")

    kv = state.kv if state is not None else None
    a, new_kv = apply_attention(params["attn"], cfg.attention, h,
                                positions=positions, cache=kv,
                                attn_mask=attn_mask, compute_dtype=cdt)

    new_ssm = new_conv = None
    if cfg.family == "hybrid":
        m, (new_ssm, new_conv) = ssmnn.apply_mamba(
            params["mamba"], h, state_dim=cfg.ssm.state_dim,
            ssm_state=state.ssm if state is not None else None,
            conv_state=state.conv if state is not None else None,
            compute_dtype=cdt)
        s = params["branch_scale"].astype(cdt)
        a = 0.5 * (s[0] * a + s[1] * m)

    x = x + a
    x = constrain(x, "batch", "seq_sp", "embed")

    h2 = _apply_norm(cfg, params["ln2"], x)
    aux = jnp.zeros((2,), jnp.float32)
    if cfg.moe is not None:
        f, moe_aux = moenn.apply_moe(
            params["moe"], h2, top_k=cfg.moe.top_k,
            capacity_factor=cfg.moe.capacity_factor,
            normalize_topk=cfg.moe.normalize_topk, compute_dtype=cdt)
        aux = jnp.stack([moe_aux.load_balance_loss, moe_aux.router_z_loss])
    else:
        f = _apply_ffn(cfg, params["ffn"], h2, cdt)
    x = x + f
    x = constrain(x, "batch", "seq_sp", "embed")

    new_state = LayerState(kv=new_kv, ssm=new_ssm, conv=new_conv)
    return x, new_state, aux


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

def init_lm(key, cfg: ModelConfig) -> dict:
    kg = KeyGen(key)
    dtype = cfg.pdtype

    # stacked block params: vmap init over per-layer keys -> leading
    # ("layers",) axis on every leaf
    layer_keys = jax.random.split(kg("blocks"), cfg.num_layers)
    blocks = jax.vmap(lambda k: init_block(k, cfg))(layer_keys)
    blocks = jax.tree.map(
        lambda p: Param(p.value, ("layers",) + p.axes) if isinstance(p, Param)
        else p, blocks, is_leaf=lambda p: isinstance(p, Param))

    p = {
        "embed": emb.init_embedding(kg("embed"), cfg.vocab_size, cfg.d_model,
                                    dtype=dtype),
        "blocks": blocks,
        "final_norm": _init_norm(cfg, dtype),
    }
    if not cfg.tie_embeddings:
        from repro.nn.linear import init_dense
        p["lm_head"] = init_dense(kg("lm_head"), (cfg.d_model,),
                                  (cfg.vocab_size,), ("embed",), ("vocab",),
                                  dtype=dtype)
    if cfg.frontend is not None:
        from repro.nn.linear import init_dense
        p["frontend_proj"] = init_dense(
            kg("frontend_proj"), (cfg.frontend.embed_dim,), (cfg.d_model,),
            (None,), ("embed",), use_bias=True, dtype=dtype)
    return p


def _scan_blocks(params, cfg: ModelConfig, x, positions, states=None,
                 attn_mask=None):
    """Scan apply_block over stacked layer params (and optional states)."""

    def body(carry, layer_in):
        h = carry
        if states is None:
            lp = layer_in
            st = None
        else:
            lp, st = layer_in
        h, new_state, aux = apply_block(lp, cfg, h, positions=positions,
                                        state=st, attn_mask=attn_mask)
        return h, (new_state if states is not None else None, aux)

    body_fn = body
    if cfg.remat == "full":
        body_fn = jax.checkpoint(body)
    elif cfg.remat == "dots":
        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    xs = params["blocks"] if states is None else (params["blocks"], states)
    if cfg.unroll:
        x, (new_states, auxs) = unrolled_scan(body_fn, x, xs, cfg.num_layers)
    else:
        x, (new_states, auxs) = jax.lax.scan(body_fn, x, xs)
    return x, new_states, jnp.sum(auxs, axis=0)


def unrolled_scan(body_fn, carry, xs, length: int):
    """Python-loop drop-in for lax.scan (dry-run cost extraction)."""
    ys = []
    for i in range(length):
        layer_in = jax.tree.map(lambda t: t[i], xs)
        carry, y = body_fn(carry, layer_in)
        ys.append(y)
    stacked = jax.tree.map(lambda *ts: jnp.stack(ts), *ys)
    return carry, stacked


def lm_forward(params: dict, cfg: ModelConfig, tokens: jax.Array, *,
               positions: Optional[jax.Array] = None,
               extra_embeds: Optional[jax.Array] = None):
    """Training / prefill forward. tokens: (b, s) int32 -> logits (b, s, V).

    ``extra_embeds``: (b, n_extra, frontend_dim) modality-stub embeddings
    prepended to the token embeddings (VLM/audio families).
    Returns (logits, aux(2,)).
    """
    cdt = cfg.cdtype
    x = emb.apply_embedding(params["embed"], tokens, compute_dtype=cdt)
    if extra_embeds is not None:
        from repro.nn.linear import apply_dense
        fe = apply_dense(params["frontend_proj"], extra_embeds.astype(cdt),
                         1, cdt)
        x = jnp.concatenate([fe, x], axis=1)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x = constrain(x, "batch", "seq_sp", "embed")
    x, _, aux = _scan_blocks(params, cfg, x, positions)
    x = _apply_norm(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = emb.attend_logits(params["embed"], x, compute_dtype=cdt)
    else:
        from repro.nn.linear import apply_dense
        logits = apply_dense(params["lm_head"], x, 1, cdt)
    logits = constrain(logits, "batch", None, "vocab")
    return logits, aux


# ---------------------------------------------------------------------------
# Lane-parameterized forward (float / int / fhe_sim execution of a PTQ'd LM)
# ---------------------------------------------------------------------------

def _lane_causal_mask(cfg: ModelConfig, n: int):
    """Cleartext attention structure for the lane forward (masks are
    public; masked pairs are excluded from the combining sums).  Shares
    the single causal/window predicate with ``_build_mask``."""
    import numpy as np

    from repro.core.attention import structural_mask_predicate

    a = cfg.attention
    m = structural_mask_predicate(a.causal, a.sliding_window,
                                  np.arange(n)[:, None],
                                  np.arange(n)[None, :])
    return None if m is None else m[None, None]


def _lane_attention_kwargs(mech, qlm):
    """Integer-domain hyper-parameters for a mechanism's lane_fn, filtered
    by its signature (mechanisms accept different shift sets)."""
    import inspect

    full = {
        "gamma_shift": qlm.gamma_shift,
        "alpha_q": qlm.alpha_q,
        "signed": bool(mech.param_overrides.get("signed", False)),
        "normalize": qlm.cfg.attention.normalize,
        "scale_shift": qlm.scale_shift,
        "frac_bits": qlm.ptq.softmax_frac,
        "exp_clip": qlm.ptq.exp_clip,
    }
    accepted = inspect.signature(mech.lane_fn).parameters
    return {k: v for k, v in full.items() if k in accepted}


def apply_block_lane(qblock: dict, qlm, lane, x, *, mask=None,
                     layer_tag: str = "L0"):
    """One pre-norm block on a lane: norm → attention (via the mechanism
    registry's lane_fn) → residual → norm → MLP → residual.  Costs land
    in per-sublayer scopes on the ``fhe_sim`` lane."""
    from repro.core.mechanism import get_mechanism, resolve_mechanism_name
    from repro.nn.lane_layers import lane_linear, lane_mlp, lane_norm
    from repro.quant.int_attention import lane_attention_heads

    cfg, ptq = qlm.cfg, qlm.ptq
    a = cfg.attention
    sub_mean = cfg.norm == "layernorm"
    mech = get_mechanism(resolve_mechanism_name(a))
    if mech.lane_fn is None:
        raise ValueError(f"mechanism {mech.name!r} has no lane_fn — "
                         "it cannot run on integer/encrypted lanes")

    with lane.scope(f"{layer_tag}.ln1"):
        h = lane_norm(lane, x, qblock["ln1"], ptq=ptq,
                      subtract_mean=sub_mean)
    b, n = lane.shape(h)[0], lane.shape(h)[1]
    with lane.scope(f"{layer_tag}.qkv_proj"):
        q = lane.reshape(lane_linear(lane, h, qblock["wq"], ptq=ptq),
                         (b, n, a.num_heads, a.head_dim))
        k = lane.reshape(lane_linear(lane, h, qblock["wk"], ptq=ptq),
                         (b, n, a.num_kv_heads, a.head_dim))
        v = lane.reshape(lane_linear(lane, h, qblock["wv"], ptq=ptq),
                         (b, n, a.num_kv_heads, a.head_dim))
    with lane.scope(f"{layer_tag}.attn"):
        o = lane_attention_heads(lane, mech.lane_fn, q, k, v, mask=mask,
                                 **_lane_attention_kwargs(mech, qlm))
    with lane.scope(f"{layer_tag}.out_proj"):
        o = lane_linear(lane, lane.reshape(
            o, (b, n, a.num_heads * a.head_dim)), qblock["wo"], ptq=ptq)
        x = lane.add(x, o)
    with lane.scope(f"{layer_tag}.ln2"):
        h2 = lane_norm(lane, x, qblock["ln2"], ptq=ptq,
                       subtract_mean=sub_mean)
    with lane.scope(f"{layer_tag}.mlp"):
        act = "gelu" if cfg.mlp == "mlp_gelu" else "relu"
        f = lane_mlp(lane, h2, qblock["wi"], qblock["wo_mlp"], ptq=ptq,
                     activation=act)
        x = lane.add(x, f)
    return x


def lm_forward_lane(qlm, lane, tokens):
    """End-to-end lane forward of a PTQ'd LM: tokens (b, s) cleartext →
    logits handle (b, s, V) on ``lane``.

    On ``fhe_sim`` this is the paper's headline scenario — the whole
    block runs under the TFHE cost model, bit-exact with the ``int``
    lane, with per-layer PBS/add/cmul/bit-width scopes accumulated on
    ``lane.ctx`` (see examples/fhe_inference.py).

    On the ``interval`` lane (:func:`repro.analysis.analyze_qlm`) the
    same call is the whole-model *static analysis*: ``tokens`` supplies
    shape only (embedding bounds span the vocabulary), and the trace
    proves worst-case widths and cmul counts for every input.
    """
    from repro.nn.lane_layers import lane_embed, lane_logits

    cfg = qlm.cfg
    with lane.scope("embed"):
        x = lane_embed(lane, qlm.embed, tokens)
    mask = _lane_causal_mask(cfg, lane.shape(x)[1])
    for i, qblock in enumerate(qlm.blocks):
        x = apply_block_lane(qblock, qlm, lane, x, mask=mask,
                             layer_tag=f"L{i}")
    with lane.scope("head"):
        return lane_logits(lane, x, qlm.final_norm, qlm.lm_head,
                           ptq=qlm.ptq,
                           subtract_mean=cfg.norm == "layernorm")


def fused_gather_applies(cfg: ModelConfig, kv, n_q: int) -> bool:
    """Would :func:`lm_step` hoist the all-layer page gather for this
    paged state?  (DESIGN.md §14.)

    True exactly when the per-layer planner would pick the host-gather
    ``paged`` backend with nothing forced: a forced backend
    (``cfg.attention.backend``) or the ``use_kernel`` shim keeps the
    per-layer path (the escape hatch parity tests rely on), and a
    platform whose planner prefers the block-table-native kernel
    (``paged_pallas`` on TPU single-query decode) keeps the kernel.
    """
    from repro.core.mechanism import AttnShapes, plan_attention

    if not isinstance(kv, PagedKVCache):
        return False
    a = cfg.attention
    if a.backend is not None or a.use_kernel:
        return False
    ps = kv.k.shape[2]
    shapes = AttnShapes(
        batch=kv.block_tables.shape[1], n_q=n_q,
        n_k=kv.block_tables.shape[2] * ps,
        num_heads=a.num_heads, num_kv_heads=kv.k.shape[3],
        head_dim=a.head_dim, dtype=cfg.cdtype,
        has_explicit_mask=False, is_cross=False, has_cache=True,
        scalar_cursor=False, paged=True)
    try:
        plan = plan_attention(a, shapes)
    except ValueError:
        return False
    return plan.backend == "paged"


def _gather_paged_view(kv: PagedKVCache) -> KVCache:
    """ONE whole-model page gather: stacked pools (L, pages, ps, hk, d)
    → contiguous logical view (L, b, P·ps, hk, d) for every layer.

    ``init_states`` broadcasts a single cache over layers and the engine
    uploads one host table broadcast the same way (``_flush_tables``), so
    ``block_tables[0]`` is authoritative for all L layers — the gather
    reads the table once instead of re-walking it per layer inside the
    scan.  The (page, offset) index pair addresses the pool directly, so
    no (b, P, ps, …) → (b, P·ps, …) reshape of the gathered data is ever
    materialized."""
    tables = kv.block_tables[0]                       # (b, P), layer-shared
    ps = kv.k.shape[2]
    page_idx = jnp.repeat(tables, ps, axis=1)         # (b, N): tables[b, j//ps]
    off_idx = jnp.tile(jnp.arange(ps, dtype=tables.dtype),
                       tables.shape[1])[None]         # (1, N): j % ps
    kc = kv.k[:, page_idx, off_idx]                   # (L, b, N, hk, d)
    vc = kv.v[:, page_idx, off_idx]
    return KVCache(kc, vc, kv.length)


def _scatter_paged_rows(kv: PagedKVCache, view: KVCache,
                        n_q: int) -> PagedKVCache:
    """Write the ``n_q`` rows each layer appended to the logical view
    back into the page pool (the inverse of the hoisted gather).  Rows of
    inactive slots land on trash page 0 exactly as the per-layer scatter
    did — duplicate trash-page writes are don't-care by design."""
    tables = kv.block_tables[0]
    ps = kv.k.shape[2]
    rows = jnp.arange(tables.shape[0])[:, None]                    # (b, 1)
    pos = kv.length[0][:, None] + jnp.arange(n_q)[None]            # (b, t)
    pages = tables[rows, pos // ps]
    offs = pos % ps
    k_pool = kv.k.at[:, pages, offs].set(view.k[:, rows, pos])
    v_pool = kv.v.at[:, pages, offs].set(view.v[:, rows, pos])
    return PagedKVCache(k_pool, v_pool, kv.block_tables, view.length)


def init_states(cfg: ModelConfig, batch: int, max_len: int, *,
                per_slot: bool = False, paged: bool = False,
                page_size: int = 16,
                num_pages: Optional[int] = None) -> LayerState:
    """Stacked (num_layers-leading) decode state for the LM.

    ``per_slot``: per-batch-row cache cursors (ragged continuous batching).
    ``paged``: back the KV cache with a shared page pool + block tables
    (serve.kvcache.PagedAllocator owns the host-side accounting); cursors
    are always per-slot in that layout.
    """
    a = cfg.attention
    if paged:
        kv = init_paged_kv_cache(batch, max_len, a.num_kv_heads, a.head_dim,
                                 dtype=cfg.cdtype, page_size=page_size,
                                 num_pages=num_pages)
        kv = jax.tree.map(lambda t: jnp.broadcast_to(
            t[None], (cfg.num_layers,) + t.shape), kv)
        kv = PagedKVCache(kv.k, kv.v, kv.block_tables, kv.length)
    else:
        kv = init_kv_cache(batch, max_len, a.num_kv_heads, a.head_dim,
                           dtype=cfg.cdtype, per_slot=per_slot)
        kv = jax.tree.map(lambda t: jnp.broadcast_to(
            t[None], (cfg.num_layers,) + t.shape), kv)
        kv = KVCache(kv.k, kv.v, kv.length)
    ssm = conv = None
    if cfg.family == "hybrid":
        inner = cfg.ssm.inner_dim or 2 * cfg.d_model
        ssm = jnp.zeros((cfg.num_layers, batch, inner, cfg.ssm.state_dim),
                        jnp.float32)
        conv = jnp.zeros((cfg.num_layers, batch, cfg.ssm.conv_dim - 1, inner),
                         cfg.cdtype)
    return LayerState(kv=kv, ssm=ssm, conv=conv)


def lm_step(params: dict, cfg: ModelConfig, tokens: jax.Array,
            states: LayerState):
    """Decode step: tokens (b, t) appended at states.kv.length.

    Returns (logits (b, t, V), new_states)."""
    cdt = cfg.cdtype
    x = emb.apply_embedding(params["embed"], tokens, compute_dtype=cdt)
    b, t, _ = x.shape
    # states are layer-stacked: kv.length is (L,) shared or (L, b) ragged.
    # positions=None lets each layer derive RoPE positions from its cursor.
    st = states
    if st.kv.length.ndim == 0:
        st = st._replace(kv=KVCache(
            st.kv.k, st.kv.v,
            jnp.broadcast_to(st.kv.length, (cfg.num_layers,))))
    if fused_gather_applies(cfg, st.kv, t):
        # whole-model fused gather (DESIGN.md §14): gather the paged
        # pools into one contiguous logical view up front, run every
        # layer's attention on its slice via the plain masked ``fused``
        # backend (bit-exact with the per-layer gather: identical
        # operands, identical mask), then scatter the appended rows back
        # into the pool once.  XLA sees one batched gather + one scatter
        # instead of L table walks per step.
        pool = st.kv
        run_cfg = dataclasses.replace(
            cfg, attention=dataclasses.replace(cfg.attention,
                                               backend="fused"))
        st = st._replace(kv=_gather_paged_view(pool))
        x, new_states, _ = _scan_blocks(params, run_cfg, x, None, states=st)
        new_states = new_states._replace(
            kv=_scatter_paged_rows(pool, new_states.kv, t))
    else:
        x, new_states, _ = _scan_blocks(params, cfg, x, None, states=st)
    x = _apply_norm(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = emb.attend_logits(params["embed"], x, compute_dtype=cdt)
    else:
        from repro.nn.linear import apply_dense
        logits = apply_dense(params["lm_head"], x, 1, cdt)
    return logits, new_states
