"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel test sweeps shapes/dtypes and asserts allclose against these.
They are thin reorderings of the core/nn reference implementations so that
the kernels and the model code share a single source of truth.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.dotprod import dot_product_attention
from repro.core.inhibitor import (
    causal_mask,
    inhibitor_attention,
    sliding_window_mask,
)
from repro.nn.ssm import wkv6_scan_ref


def _mask_for(n_q: int, n_k: int, causal: bool, window: Optional[int]):
    # Kernel convention: query block positions start at 0 (training/prefill);
    # decode goes through the jnp cache path, not the kernel.
    if causal and window is not None:
        return sliding_window_mask(n_q, n_k, window)[None, None]
    if causal:
        return causal_mask(n_q, n_k)[None, None]
    if window is not None:
        return sliding_window_mask(n_q, n_k, window)[None, None]
    return None


def flash_inhibitor_ref(q, k, v, *, score_scale=None, score_shift=0.5,
                        signed=True, normalize=True, causal=True,
                        window=None):
    """Oracle for kernels.inhibitor.flash_inhibitor_fwd."""
    mask = _mask_for(q.shape[1], k.shape[1], causal, window)
    return inhibitor_attention(
        q, k, v, mask=mask, score_scale=score_scale,
        score_shift=score_shift, signed=signed, normalize=normalize)


def flash_attention_ref(q, k, v, *, score_scale=None, causal=True,
                        window=None):
    """Oracle for kernels.flash.flash_attention_fwd."""
    mask = _mask_for(q.shape[1], k.shape[1], causal, window)
    return dot_product_attention(q, k, v, mask=mask, score_scale=score_scale)


def wkv6_ref(r, k, v, w, u, state=None):
    """Oracle for kernels.rwkv6.wkv6_chunked (exact lax.scan recurrence)."""
    return wkv6_scan_ref(r, k, v, w, u, state)


def _gather_paged(k_pool, v_pool, block_tables):
    """(num_pages, ps, h_kv, d) pools + (b, P) tables -> contiguous
    (b, P*ps, h_kv, d) views — the gather the paged kernels replace."""
    kt = k_pool[block_tables]
    vt = v_pool[block_tables]
    b, npg, ps, hk, d = kt.shape
    return (kt.reshape(b, npg * ps, hk, d), vt.reshape(b, npg * ps, hk, d))


def _decode_mask(n_k: int, lengths, window: Optional[int]):
    """(b, 1, 1, n_k) attendability of each gathered position for the
    single decode query at position lengths[row]-1."""
    kj = jnp.arange(n_k)[None, :]
    m = kj < lengths[:, None]
    if window is not None:
        m = m & (kj > (lengths[:, None] - 1) - window)
    return m[:, None, None, :]


def paged_flash_inhibitor_ref(q, k_pool, v_pool, block_tables, lengths, *,
                              score_scale=None, score_shift=0.5, signed=True,
                              normalize=True, window=None):
    """Oracle for kernels.paged.paged_flash_inhibitor_fwd (gather + fused)."""
    kc, vc = _gather_paged(k_pool, v_pool, block_tables)
    mask = _decode_mask(kc.shape[1], lengths, window)
    return inhibitor_attention(
        q, kc.astype(q.dtype), vc.astype(q.dtype), mask=mask,
        score_scale=score_scale, score_shift=score_shift, signed=signed,
        normalize=normalize)


def paged_flash_attention_ref(q, k_pool, v_pool, block_tables, lengths, *,
                              score_scale=None, window=None):
    """Oracle for kernels.paged.paged_flash_attention_fwd (gather + fused)."""
    kc, vc = _gather_paged(k_pool, v_pool, block_tables)
    mask = _decode_mask(kc.shape[1], lengths, window)
    return dot_product_attention(q, kc.astype(q.dtype), vc.astype(q.dtype),
                                 mask=mask, score_scale=score_scale)
