"""The paper's own experimental scale: small single-layer Inhibitor
Transformers (Table 1 tasks / Tables 2–4 scaling circuits).

This config is the *paper-faithful* model: inhibitor attention (signed,
shifted score α=0.5, γ=√d), classic ReLU FFN (eq. 4), LayerNorm — the
architecture used for the adding/MNIST/IMDB/IAMW benchmark comparisons.
"""

from repro.configs.base import ModelConfig
from repro.core.attention import AttentionConfig

CONFIG = ModelConfig(
    name="paper-tiny",
    family="dense",
    num_layers=1,
    d_model=128,
    d_ff=256,
    vocab_size=256,
    attention=AttentionConfig(
        mechanism="inhibitor", num_heads=4, num_kv_heads=4, head_dim=32,
        score_shift=0.5, use_rope=False, causal=True),
    norm="layernorm",
    norm_eps=1e-5,
    mlp="mlp_relu",
    mlp_bias=True,
    tie_embeddings=False,
    max_seq_len=512,
    remat="none",
    compute_dtype="float32",
    source="paper (Brännvall & Stoian 2024)",
)
