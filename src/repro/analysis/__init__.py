"""Static analysis of the FHE circuit (abstract interpretation + lint).

The measured story (``fhe_sim``) observes one sample forward; this
package *proves* the same quantities for every input in the declared
quantized ranges: per-scope op counts (exactly equal to measured — the
circuit's control flow is input-independent), worst-case PBS message
widths (dominating any measured high-water), zero cipher×cipher products
on the inhibitor arm, and LUT-domain/table-width verification.  See
DESIGN.md §12 for the soundness contract.

    python -m repro.analysis --config paper-tiny      # ANALYSIS_fhe.json
    python -m repro.analysis.serve --config paper-tiny  # ANALYSIS_serve.json
    python -m repro.analysis.lint src/repro           # lane discipline

``repro.analysis.serve_static`` applies the same proof discipline to
the *serving* hot path (DESIGN.md §13): retrace-budget proofs over the
engine's jit entry points, a host-sync audit of the tick path, and a
static roofline (``repro.analysis.costmodel``) shared with the
benchmarks and the kernel autotuner's candidate priors.
"""

from repro.analysis.analyzer import (DEFAULT_MECHANISMS,  # noqa: F401
                                     LUT_BITS_CEILING, analyze_config,
                                     analyze_qlm, format_report)
from repro.analysis.costmodel import (DEFAULT_PLATFORM,  # noqa: F401
                                      TPU_V5E, Costs, Platform,
                                      jaxpr_costs, kernel_prior,
                                      rank_kernel_candidates, roofline)
from repro.analysis.interval import (IntervalOverflow,  # noqa: F401
                                     IntervalTensor, as_interval)
from repro.analysis.interval_lane import IntervalLane  # noqa: F401
from repro.analysis.serve_static import (analyze_serve,  # noqa: F401
                                         audit_sync_sites,
                                         cross_check_bench, retrace_budget,
                                         sync_summary)
