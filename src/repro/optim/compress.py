"""Gradient compression for cross-pod data parallelism.

At multi-pod scale the ``pod`` axis all-reduce crosses the slowest links
(inter-pod ICI/DCN), so gradients are compressed to int8 with a per-tensor
scale before the cross-pod reduction and decompressed after.  An error-
feedback accumulator (Seide et al.; 1-bit SGD lineage) carries the
quantization residual into the next step so compression error does not
bias convergence.

Usage inside a shard_map'd gradient sync (see distributed.collectives):
the intra-pod reduction runs at full precision (cheap links), then the
int8 payload crosses pods — an 4× wire-byte reduction on the slow hop.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    error: any            # error-feedback residual, same tree as grads


def init_compression(grads_like) -> CompressionState:
    return CompressionState(error=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def compress(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array,
                                                    jax.Array]:
    """g + err -> (int8 payload, scale, new_err)."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_err = gf - q.astype(jnp.float32) * scale
    return q, scale, new_err


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads, state: CompressionState):
    """Tree version. Returns ((q_tree, scale_tree), new_state)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(state.error)
    qs, scales, errs = [], [], []
    for g, e in zip(flat_g, flat_e):
        q, s, ne = compress(g, e)
        qs.append(q)
        scales.append(s)
        errs.append(ne)
    return ((jax.tree.unflatten(treedef, qs),
             jax.tree.unflatten(treedef, scales)),
            CompressionState(error=jax.tree.unflatten(treedef, errs)))


def decompress_tree(q_tree, scale_tree):
    return jax.tree.map(decompress, q_tree, scale_tree)
