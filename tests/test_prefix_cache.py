"""Shared-prefix radix KV cache (DESIGN.md §11): radix-index matching /
splitting / LRU eviction, allocator refcount + copy-on-write accounting,
and engine-level behavior — prefix hits with exact outputs, CoW forks
under near-max_len bucketed prefill, refcount-driven eviction under pool
pressure, and leak-free release.

Shared fixtures (``serve_model``, ``greedy_ref``) live in conftest.py.
"""

import numpy as np
import pytest

from repro.serve.engine import Engine, EngineConfig, Request
from repro.serve.kvcache import PagedAllocator
from repro.serve.prefix import PrefixIndex


def _take_pages(al, request_id, n_tokens):
    """Claim a slot, grow it over ``n_tokens``, return (slot, pages)."""
    slot = al.claim(request_id)
    assert al.ensure(slot, n_tokens) is True
    return slot, al.held(slot)


def _assert_pool_consistent(al):
    """Free list and refcounts partition the usable pool exactly."""
    free = list(al.free)
    assert len(set(free)) == len(free), "duplicate pages on the free list"
    assert all(al.ref[p] == 0 for p in free)
    assert 0 not in free
    referenced = [p for p in range(1, al.num_pages) if al.ref[p] > 0]
    assert sorted(free + referenced) == list(range(1, al.num_pages))
    assert al.pages_in_use == len(referenced)


# ---------------------------------------------------------------------------
# Radix index (host side, no jax)
# ---------------------------------------------------------------------------

def test_index_match_is_page_aligned_and_exact():
    al = PagedAllocator(max_batch=2, max_len=64, page_size=4)
    idx = PrefixIndex(al)
    assert idx.match([1, 2, 3, 4, 5]) == (0, [])

    slot, pages = _take_pages(al, 0, 12)
    toks = np.arange(100, 112)
    assert idx.insert(toks, pages) == 3
    al.release(slot)
    assert al.pages_in_use == 3            # index keeps its references
    _assert_pool_consistent(al)

    assert idx.match(toks) == (12, pages[:3])
    # divergent tail: only the full-page-aligned shared prefix matches
    assert idx.match(list(toks[:9]) + [7, 7, 7]) == (8, pages[:2])
    # divergence inside the first page shares nothing
    assert idx.match([100, 7, 7, 7, 7]) == (0, [])
    # shorter query than a full page: nothing page-aligned to mount
    assert idx.match(toks[:3]) == (0, [])


def test_index_insert_splits_edges_and_shares_interior_pages():
    al = PagedAllocator(max_batch=2, max_len=64, page_size=4)
    idx = PrefixIndex(al)
    s0, pages_a = _take_pages(al, 0, 12)
    a = np.asarray([9] * 8 + [1, 2, 3, 4])
    idx.insert(a, pages_a)
    al.release(s0)

    # b shares a's first two pages tokenwise, then diverges: the insert
    # must split a's edge and reference only b's divergent suffix pages
    s1, pages_b = _take_pages(al, 1, 12)
    b = np.asarray([9] * 8 + [5, 6, 7, 8])
    assert idx.insert(b, pages_b) == 1
    al.release(s1)
    assert idx.cached_pages == 4           # 2 shared + 1 + 1
    _assert_pool_consistent(al)

    # both sequences resolve fully, through a's physical prefix pages
    assert idx.match(a) == (12, pages_a[:3])
    assert idx.match(b) == (12, pages_a[:2] + [pages_b[2]])
    # re-inserting an already-cached sequence references nothing new
    s2, pages_c = _take_pages(al, 2, 12)
    assert idx.insert(a, pages_c) == 0
    al.release(s2)
    assert idx.cached_pages == 4


def test_index_lru_eviction_frees_cold_leaves_first():
    al = PagedAllocator(max_batch=2, max_len=64, page_size=4)
    idx = PrefixIndex(al)
    s0, pages_a = _take_pages(al, 0, 8)
    a = np.asarray([1] * 8)
    idx.insert(a, pages_a)
    al.release(s0)
    s1, pages_b = _take_pages(al, 1, 8)
    b = np.asarray([2] * 8)
    idx.insert(b, pages_b)
    al.release(s1)

    idx.match(a)                           # a is now hottest
    freed = idx.evict(1)
    assert freed >= 1 and idx.evictions >= 1
    assert idx.match(b, touch=False) == (0, [])    # cold leaf gone
    assert idx.match(a, touch=False)[0] == 8       # hot entry survives
    _assert_pool_consistent(al)
    # scheduler affinity probes (touch=False) must not distort LRU order
    assert idx.clear() == 2
    assert al.pages_in_use == 0


def test_index_eviction_skips_pages_shared_with_active_slots():
    """Evicting an entry whose pages an active slot still references
    drops the index's reference but frees nothing — the slot's mapping
    stays valid, and the pages return to the free list only when the
    slot releases."""
    al = PagedAllocator(max_batch=2, max_len=64, page_size=4)
    idx = PrefixIndex(al)
    s0, pages = _take_pages(al, 0, 8)
    idx.insert(np.arange(8), pages)
    al.release(s0)

    slot = al.claim(1)
    al.map_shared(slot, pages[:2])         # active slot mounts the prefix
    assert idx.evict(1) == 0               # nothing actually freed
    assert idx.cached_pages == 0           # but the entry is detached
    assert al.pages_in_use == 2            # slot's references keep them
    al.release(slot)
    assert al.pages_in_use == 0
    _assert_pool_consistent(al)


# ---------------------------------------------------------------------------
# Allocator refcounts + copy-on-write (host side, no jax)
# ---------------------------------------------------------------------------

def test_allocator_map_shared_fork_and_release_accounting():
    al = PagedAllocator(max_batch=2, max_len=32, page_size=8)
    s0, pages = _take_pages(al, 0, 16)     # 2 pages, ref 1 each
    for p in pages:
        al.addref(p)                       # simulate index ownership
    al.release(s0)
    assert al.pages_in_use == 2

    s1 = al.claim(1)
    al.map_shared(s1, pages)
    assert [int(al.ref[p]) for p in pages] == [2, 2]
    assert not al.writable(s1, 0) and not al.writable(s1, 1)

    old, new = al.fork(s1, 0)
    assert old == pages[0] and new not in pages
    assert al.writable(s1, 0)              # sole owner of the fork
    assert int(al.ref[old]) == 1           # the "index" keeps the original
    assert al.block_tables[s1, 0] == new
    assert al.held(s1) == [new, pages[1]]

    al.ensure(s1, 24)                      # grow a fresh third page
    al.release(s1)
    assert al.pages_in_use == 2            # only the index refs survive
    for p in pages:
        al.decref(p)
    assert al.pages_in_use == 0
    _assert_pool_consistent(al)
    with pytest.raises(RuntimeError, match="double-freed"):
        al.decref(pages[0])


def test_allocator_reclaimer_is_invoked_when_free_list_dries():
    al = PagedAllocator(max_batch=2, max_len=32, page_size=8, num_pages=3)
    calls = []
    s0, pages = _take_pages(al, 0, 16)     # takes both usable pages
    for p in pages:
        al.addref(p)
    al.release(s0)

    def reclaim(n):
        calls.append(n)
        return sum(al.decref(p) for p in pages)  # index drops everything

    al.attach_reclaimer(reclaim)
    s1 = al.claim(1)
    assert al.ensure(s1, 16) is True       # dry -> reclaim -> succeeds
    assert calls and calls[0] >= 1
    al.release(s1)
    _assert_pool_consistent(al)


def test_allocator_trash_page_never_refcounted():
    al = PagedAllocator(max_batch=1, max_len=32, page_size=8)
    with pytest.raises(ValueError, match="trash page"):
        al.addref(0)
    s = al.claim(0)
    al.ensure(s, 8)
    with pytest.raises(RuntimeError, match="already mapped"):
        al.map_shared(s, [1])              # prefixes mount at logical 0


# ---------------------------------------------------------------------------
# Engine integration
# ---------------------------------------------------------------------------

def test_second_request_mounts_cached_prefix_with_exact_output(
        rng, serve_model, greedy_ref):
    """Acceptance: a repeated prompt prefills only the uncached suffix
    (prefix_hit_tokens > 0, fewer prefill tokens), with bit-identical
    greedy output, and release accounting balances to the cached pages."""
    cfg, api, params = serve_model
    eng = Engine(api, params, EngineConfig(max_batch=1, max_len=64,
                                           page_size=8, prefill_chunk=8))
    p = rng.integers(0, cfg.vocab_size, (20,)).astype(np.int32)
    ref = greedy_ref(p, 4)
    eng.submit(Request(0, p, max_new_tokens=4))
    first = eng.run_to_completion()
    eng.submit(Request(1, p, max_new_tokens=4))
    second = eng.run_to_completion()
    assert first[0].output == ref and second[0].output == ref

    s = eng.stats()
    assert s["prefix_hit_tokens"] == 16    # 2 full pages of the 20-token
    assert s["prefix_hit_requests"] == 1   # prompt (page-aligned, capped)
    assert s["prefill_tokens"] == 20 + 4   # cold full + warm suffix
    assert s["forked_pages"] == 0          # suffix writes land on fresh
    assert s["pages_in_use"] == s["cached_pages"] > 0
    _assert_pool_consistent(eng.alloc)


def test_cache_on_off_and_contiguous_outputs_identical(rng, serve_model):
    """Acceptance: identical greedy outputs across cache-on, cache-off
    and contiguous arms on a shared-prefix workload."""
    cfg, api, params = serve_model
    shared = rng.integers(0, cfg.vocab_size, (16,)).astype(np.int32)
    prompts = [np.concatenate([shared, rng.integers(
        0, cfg.vocab_size, (int(l),)).astype(np.int32)])
        for l in (3, 7, 5, 9, 1)]

    outs = {}
    for name, allocator, cache in (("on", "paged", True),
                                   ("off", "paged", False),
                                   ("contig", "contiguous", False)):
        eng = Engine(api, params, EngineConfig(
            max_batch=2, max_len=64, page_size=8, prefill_chunk=8,
            allocator=allocator, prefix_cache=cache))
        for i, p in enumerate(prompts):
            eng.submit(Request(i, p, max_new_tokens=5))
        outs[name] = {r.request_id: r.output
                      for r in eng.run_to_completion()}
        if name == "on":
            assert eng.stats()["prefix_hit_tokens"] > 0
            assert eng.alloc.pages_in_use == eng.prefix.cached_pages
            _assert_pool_consistent(eng.alloc)
        if name == "off":
            assert eng.stats()["prefix_hit_tokens"] == 0
            assert eng.alloc.pages_in_use == 0
    assert outs["on"] == outs["off"] == outs["contig"]


def test_cow_fork_on_bucketed_left_shift_near_max_len(rng, serve_model,
                                                      greedy_ref):
    """Acceptance (CoW): a near-max_len prompt whose bucketed final chunk
    left-shifts below the mounted prefix forks the touched shared pages
    — the rewrite lands on private copies, the output stays exact, and
    the original cached entry is untouched."""
    cfg, api, params = serve_model
    # ps=2, max_len=16, chunk=8: A caches 10 tokens (5 pages); B extends
    # to 15 tokens, its final chunk buckets to 8 and left-shifts to
    # position 8 < credit 10 -> the page holding rows 8-9 must fork
    eng = Engine(api, params, EngineConfig(max_batch=2, max_len=16,
                                           page_size=2, prefill_chunk=8))
    pa = rng.integers(0, cfg.vocab_size, (10,)).astype(np.int32)
    pb = np.concatenate([pa, rng.integers(0, cfg.vocab_size,
                                          (5,)).astype(np.int32)])
    eng.submit(Request(0, pa, max_new_tokens=1))
    done = eng.run_to_completion()
    eng.submit(Request(1, pb, max_new_tokens=1))
    done += eng.run_to_completion()
    assert done[0].output == greedy_ref(pa, 1, max_len=16)
    assert done[1].output == greedy_ref(pb, 1, max_len=16)

    s = eng.stats()
    assert s["prefix_hit_tokens"] == 10
    assert s["forked_pages"] == 1
    assert eng.prefix.match(pa, touch=False)[0] == 10   # entry intact
    assert eng.prefix.match(pb, touch=False)[0] == 14   # B now cached too
    _assert_pool_consistent(eng.alloc)


def test_two_active_slots_read_the_same_shared_pages(rng, serve_model,
                                                     greedy_ref):
    """Two concurrently decoding requests mount the same cached prefix
    pages (refcount 3: index + both slots) and still produce exact
    outputs — shared pages are read-only below every cursor."""
    cfg, api, params = serve_model
    eng = Engine(api, params, EngineConfig(max_batch=2, max_len=64,
                                           page_size=8, prefill_chunk=8))
    shared = rng.integers(0, cfg.vocab_size, (16,)).astype(np.int32)
    seed_req = Request(0, shared, max_new_tokens=1)
    eng.submit(seed_req)
    done = eng.run_to_completion()          # caches the 16-token prefix

    pa = np.concatenate([shared, rng.integers(0, cfg.vocab_size,
                                              (3,)).astype(np.int32)])
    pb = np.concatenate([shared, rng.integers(0, cfg.vocab_size,
                                              (5,)).astype(np.int32)])
    eng.submit(Request(1, pa, max_new_tokens=6))
    eng.submit(Request(2, pb, max_new_tokens=6))
    eng.step()                              # both admitted, both mounted
    assert len(eng.active) == 2
    shared_pages = eng.prefix.match(shared, touch=False)[1]
    assert shared_pages and all(int(eng.alloc.ref[p]) == 3
                                for p in shared_pages[:1])
    done += eng.run_to_completion()
    outs = {r.request_id: r.output for r in done}
    assert outs[1] == greedy_ref(pa, 6)
    assert outs[2] == greedy_ref(pb, 6)
    assert eng.stats()["prefix_hit_tokens"] == 32
    _assert_pool_consistent(eng.alloc)


def test_eviction_under_pool_pressure_never_blocks_admission(
        rng, serve_model, greedy_ref):
    """Acceptance: with a pool sized so cached prefixes must be evicted
    to admit new work, every request completes exactly — the cache never
    causes an admission failure an empty cache would not."""
    cfg, api, params = serve_model
    # 6 usable pages of 8 = 48 KV rows for prompts needing up to 3 pages
    eng = Engine(api, params, EngineConfig(max_batch=2, max_len=64,
                                           allocator="paged", page_size=8,
                                           num_pages=7, prefill_chunk=8))
    prompts = [rng.integers(0, cfg.vocab_size, (l,)).astype(np.int32)
               for l in (17, 11, 19, 9, 15)]
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new_tokens=4))
    done = eng.run_to_completion()
    assert sorted(r.request_id for r in done) == list(range(len(prompts)))
    for r in done:
        assert not r.truncated
        assert r.output == greedy_ref(prompts[r.request_id], 4)
    assert eng.stats()["evictions"] > 0     # the pool really was tight
    _assert_pool_consistent(eng.alloc)


def test_prefix_cache_gating(serve_model):
    """The index exists only where it is sound: paged pool + cursor-
    guarded KV family.  Recurrent carries (hybrid mamba) cannot skip
    prefix compute, and contiguous slots have no pages to share."""
    from repro.serve.engine import _KV_FAMILIES

    cfg, api, params = serve_model
    eng = Engine(api, params, EngineConfig(max_batch=1, max_len=32))
    assert eng.prefix is not None           # dense family, paged pool
    off = Engine(api, params, EngineConfig(max_batch=1, max_len=32,
                                           prefix_cache=False))
    assert off.prefix is None
    contig = Engine(api, params, EngineConfig(max_batch=1, max_len=32,
                                              allocator="contiguous"))
    assert contig.prefix is None
    assert "hybrid" not in _KV_FAMILIES     # the recurrent-carry gate


def test_engine_stats_shape(serve_model):
    cfg, api, params = serve_model
    eng = Engine(api, params, EngineConfig(max_batch=1, max_len=32))
    s = eng.stats()
    for key in ("prefix_hit_tokens", "forked_pages", "evictions",
                "cached_pages", "prefill_tokens", "generated_tokens",
                "finished_requests", "prefill_compiles", "pages_in_use",
                "high_water_pages", "scheduler"):
        assert key in s
    assert s["scheduler"] == "fifo"


def test_failed_credit_admission_scrubs_device_table_row(rng, serve_model,
                                                         greedy_ref):
    """Regression: an admission that mounts a credit, mirrors its block
    table into device state, and then fails (CoW fork + uncached retry
    both dry) must zero the device row — otherwise the inactive row's
    decode scatter lands on the still-shared cached pages and silently
    corrupts every later hit on that prefix."""
    cfg, api, params = serve_model
    # ps=2, max_len=16, usable pool 10: seed caches 5 pages; C mounts
    # them (+2 fresh) and keeps decoding; B then needs 3 fresh + 1 fork
    # with exactly 3 free -> fork fails (the cached pages are pinned by
    # C and B, so eviction frees nothing), and the uncached retry needs
    # 8 with only 3 free+evictable -> admission backs off entirely
    eng = Engine(api, params, EngineConfig(max_batch=2, max_len=16,
                                           page_size=2, prefill_chunk=8,
                                           num_pages=11))
    p10 = rng.integers(0, cfg.vocab_size, (10,)).astype(np.int32)
    eng.submit(Request(0, p10, max_new_tokens=1))
    done = eng.run_to_completion()          # seed: 5 pages cached

    pc = np.concatenate([p10, rng.integers(0, cfg.vocab_size,
                                           (2,)).astype(np.int32)])
    pb = np.concatenate([p10, rng.integers(0, cfg.vocab_size,
                                           (5,)).astype(np.int32)])
    eng.submit(Request(1, pc, max_new_tokens=4))
    eng.step()                              # C admitted, mounts the prefix
    assert 1 in {r.request_id for r in eng.active.values()}
    eng.submit(Request(2, pb, max_new_tokens=2))
    eng.step()                              # B's admission fails twice
    b_queued = {r.request_id for r in eng.scheduler.pending()}
    assert b_queued == {2}                  # backed off, still queued
    # every inactive slot's device table row must be zeroed (trash page)
    active_slots = set(eng.active)
    tables = np.asarray(eng.states.kv.block_tables[0])
    for slot in range(eng.cfg.max_batch):
        if slot not in active_slots:
            assert not tables[slot].any(), \
                f"stale device block-table row for idle slot {slot}"
    done += eng.run_to_completion()         # C finishes, B then admits
    outs = {r.request_id: r.output for r in done}
    assert outs[1] == greedy_ref(pc, 4, max_len=16)
    assert outs[2] == greedy_ref(pb, 2, max_len=16)
    _assert_pool_consistent(eng.alloc)
