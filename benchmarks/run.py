"""Benchmark harness: one function per paper table (+ roofline reader).

Prints ``name,us_per_call,derived`` CSV; ``python -m benchmarks.run``.
Select subsets with ``--only table1`` etc.  ``--smoke`` runs every suite
at a shrunken size (few steps/reps, smallest T) — the CI job that makes
dispatch/planner regressions visible in timings.
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: table1,table2,table3,table4,roofline")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes/reps for CI dispatch-regression runs")
    args = ap.parse_args(argv)
    wanted = set(args.only.split(",")) if args.only else None

    from benchmarks import (roofline, table1_tasks, table2_fhe_params,
                            table3_plaintext, table4_encrypted)

    suites = [
        ("table1", table1_tasks.run),
        ("table2", table2_fhe_params.run),
        ("table3", table3_plaintext.run),
        ("table4", table4_encrypted.run),
        ("roofline", roofline.run),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        if wanted and name not in wanted:
            continue
        try:
            for row in fn(smoke=args.smoke):
                print(",".join(map(str, row)), flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},0,ERROR", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
