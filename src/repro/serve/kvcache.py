"""Serving-side KV cache management: slot-based continuous batching.

The engine keeps a fixed pool of ``max_batch`` slots, each owning a stride
of the stacked (layers, batch, max_len, kv_heads, head_dim) cache buffers.
Requests claim a free slot, prefill writes their prompt into it, decode
steps advance all active slots together, and finished slots are recycled
without touching the others — per-slot lengths make ragged decode exact.

This is the contiguous (non-paged) variant; page tables only pay off once
prompts share prefixes or lengths vary by orders of magnitude. The slot
abstraction is what the engine schedules against, so a paged allocator can
replace this module without touching engine logic.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SlotState:
    request_id: Optional[int] = None
    length: int = 0
    done: bool = True


class SlotAllocator:
    def __init__(self, max_batch: int):
        self.slots: List[SlotState] = [SlotState() for _ in range(max_batch)]

    def claim(self, request_id: int) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s.done:
                self.slots[i] = SlotState(request_id, 0, False)
                return i
        return None

    def release(self, slot: int):
        self.slots[slot] = SlotState()

    def active(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if not s.done]

    def lengths(self) -> np.ndarray:
        return np.array([s.length for s in self.slots], np.int32)
