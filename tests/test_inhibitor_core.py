"""Paper-equation identities for the inhibitor core (deterministic).

Hypothesis-based property tests live in test_property_based.py, which
skips as a unit when the optional ``hypothesis`` dependency is absent —
tier-1 collection must never die on an optional import.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import inhibitor as I
from repro.core.blocked import blocked_inhibitor_attention


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@pytest.mark.parametrize("signed", [True, False])
@pytest.mark.parametrize("shift", [0.0, 0.5, 2.0])
def test_fused_equals_naive(rng, signed, shift):
    """Eq. 9/10 ≡ eq. 6/7 (the appendix identities)."""
    q = _rand(rng, 2, 3, 6, 8)
    k = _rand(rng, 2, 3, 10, 8)
    v = _rand(rng, 2, 3, 10, 8)
    z = I.manhattan_scores(q, k, score_shift=shift)
    if signed:
        np.testing.assert_allclose(I.inhibit_signed_fused(v, z),
                                   I.inhibit_signed_naive(v, z),
                                   rtol=1e-4, atol=1e-5)
    else:
        np.testing.assert_allclose(I.inhibit_fused(v, z),
                                   I.inhibit_naive(v, z),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("signed", [True, False])
def test_masked_fused_equals_masked_naive(rng, signed):
    """Mask-by-exclusion (fused) ≡ mask-by-large-Z (naive oracle)."""
    q = _rand(rng, 2, 2, 5, 4)
    k = _rand(rng, 2, 2, 7, 4)
    v = _rand(rng, 2, 2, 7, 4)
    mask = jnp.asarray(np.random.default_rng(1).random((2, 2, 5, 7)) > 0.4)
    z = I.manhattan_scores(q, k, score_shift=0.5)
    zm = I.mask_scores(z, mask)
    if signed:
        np.testing.assert_allclose(I.inhibit_signed_fused(v, z, mask),
                                   I.inhibit_signed_naive(v, zm),
                                   rtol=1e-4, atol=1e-5)
    else:
        np.testing.assert_allclose(I.inhibit_fused(v, z, mask),
                                   I.inhibit_naive(v, zm),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("signed", [True, False])
@pytest.mark.parametrize("kv_chunk", [5, 16])
def test_chunked_equals_full(rng, signed, kv_chunk):
    q = _rand(rng, 2, 16, 4, 8)
    k = _rand(rng, 2, 16, 2, 8)
    v = _rand(rng, 2, 16, 2, 8)
    mask = I.causal_mask(16, 16)[None, None]
    o1 = I.inhibitor_attention(q, k, v, mask=mask, signed=signed)
    o2 = I.inhibitor_attention_chunked(q, k, v, mask=mask, signed=signed,
                                       kv_chunk=kv_chunk)
    np.testing.assert_allclose(o1, o2, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("signed", [True, False])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 9)])
def test_blocked_equals_full_with_grads(rng, signed, causal, window):
    b, n, h, hk, d = 2, 37, 4, 2, 16
    q = _rand(rng, b, n, h, d)
    k = _rand(rng, b, n, hk, d)
    v = _rand(rng, b, n, hk, d)
    mask = (I.sliding_window_mask(n, n, window) if window
            else I.causal_mask(n, n))[None, None]

    ref = I.inhibitor_attention(q, k, v, mask=mask, signed=signed)
    out = blocked_inhibitor_attention(q, k, v, signed=signed, causal=causal,
                                      window=window, chunk_q=8, chunk_k=16)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)

    g1 = jax.grad(lambda x: (blocked_inhibitor_attention(
        x, k, v, signed=signed, causal=causal, window=window,
        chunk_q=8, chunk_k=16) ** 2).sum())(q)
    g2 = jax.grad(lambda x: (I.inhibitor_attention(
        x, k, v, mask=mask, signed=signed) ** 2).sum())(q)
    np.testing.assert_allclose(g1, g2, rtol=1e-3, atol=1e-4)


def test_custom_vjp_matches_naive_autodiff(rng):
    """Analytic fused VJP ≡ autodiff of the naive (eq. 6/7) form."""
    q = _rand(rng, 2, 10, 3, 8)
    k = _rand(rng, 2, 10, 3, 8)
    v = _rand(rng, 2, 10, 3, 8)
    mask = I.causal_mask(10, 10)[None, None]

    def naive(q_, k_, v_):
        qt, kt, vt = (x.transpose(0, 2, 1, 3) for x in (q_, k_, v_))
        z = I.manhattan_scores(qt, kt, score_shift=0.5)
        zm = I.mask_scores(z, jnp.broadcast_to(mask, z.shape))
        out = I.inhibit_signed_naive(vt, zm)
        cnt = jnp.broadcast_to(mask, z.shape).sum(-1, keepdims=True)
        return (out / jnp.maximum(cnt, 1)).transpose(0, 2, 1, 3)

    for idx in range(3):
        arrs = [q, k, v]

        def f_new(x, idx=idx):
            a = list(arrs)
            a[idx] = x
            return (I.inhibitor_attention(a[0], a[1], a[2],
                                          mask=mask) ** 2).sum()

        def f_ref(x, idx=idx):
            a = list(arrs)
            a[idx] = x
            return (naive(a[0], a[1], a[2]) ** 2).sum()

        np.testing.assert_allclose(jax.grad(f_new)(arrs[idx]),
                                   jax.grad(f_ref)(arrs[idx]),
                                   rtol=1e-3, atol=1e-4)


def test_masked_positions_contribute_zero(rng):
    """Adding arbitrary masked-out keys never changes the output."""
    q = _rand(rng, 1, 4, 2, 6)
    k = _rand(rng, 1, 5, 2, 6)
    v = _rand(rng, 1, 5, 2, 6)
    out1 = I.inhibitor_attention(q, k, v, mask=jnp.ones((1, 1, 4, 5),
                                                        bool))
    k2 = jnp.concatenate([k, _rand(rng, 1, 3, 2, 6) * 100], axis=1)
    v2 = jnp.concatenate([v, _rand(rng, 1, 3, 2, 6) * 100], axis=1)
    mask = jnp.concatenate([jnp.ones((1, 1, 4, 5), bool),
                            jnp.zeros((1, 1, 4, 3), bool)], axis=-1)
    out2 = I.inhibitor_attention(q, k2, v2, mask=mask)
    np.testing.assert_allclose(out1, out2, rtol=1e-4, atol=1e-4)
