"""Full-block encrypted cost table: inhibitor vs dot-product under TFHE.

Extends the paper's Tables 2/4 (attention-op circuits) to the *model
level* the north-star demands: the whole PTQ'd ``paper_tiny`` block —
norm surrogate, projections, attention, MLP, residuals, logits — runs
under the TFHE simulator on both mechanism arms, bit-exactness against
the plaintext int lane is asserted, and the per-mechanism PBS/cmul
totals, block-level message-width high-water, selected macro-parameters
and estimated single-thread seconds are reported.

Structural claim checked on every run: the inhibitor block performs
**zero** ciphertext×ciphertext multiplications; the dot-product block
pays them in QKᵀ, the softmax renormalization, and S·V.

Each measured forward is paired with the static interval analysis
(``repro.analysis``) of the same circuit: per-scope op counts must match
*exactly* (the circuit's control flow is input-independent), every
measured message width must be dominated by the proven bound, and the
report carries static-vs-measured width/parameter columns.  The zero-
cmul gate is asserted on **both** traces — measured (this input) and
static (every input in the quantized range).

  PYTHONPATH=src python benchmarks/fhe_block.py [--smoke] [--json PATH]

Writes ``BENCH_fhe_block.json`` (CI artifact; serving-style trajectory
tracking for the encrypted-inference axis).
"""

from __future__ import annotations

import argparse
import json

import numpy as np


def run(smoke: bool = False, seq_lens=None) -> dict:
    import jax

    from repro.analysis import analyze_qlm
    from repro.configs import get_config
    from repro.core.lanes import get_lane
    from repro.fhe import (pbs_seconds, select_params_for_report,
                           select_params_static)
    from repro.models import transformer as tfm
    from repro.models.registry import get_model
    from repro.nn.module import unbox
    from repro.quant.ptq import ptq_lm

    seq_lens = seq_lens or ((4,) if smoke else (4, 8, 16))
    cfg = get_config("paper-tiny")
    if smoke:
        cfg = cfg.reduced(num_layers=1, d_model=32, d_ff=64,
                          num_heads=2, num_kv_heads=2, head_dim=16)
    params = unbox(get_model(cfg).init(jax.random.PRNGKey(0)))
    rng = np.random.default_rng(0)

    out = {"config": cfg.name, "d_model": cfg.d_model, "rows": []}
    for T in seq_lens:
        tokens = rng.integers(0, cfg.vocab_size, (1, T))
        per_mech = {}
        for mech in ("inhibitor", "dotprod"):
            qlm = ptq_lm(params, cfg.with_attention_kind(mech))
            int_lane = get_lane("int")
            ref = int_lane.to_numpy(
                tfm.lm_forward_lane(qlm, int_lane, tokens))
            fhe = get_lane("fhe_sim")
            enc = fhe.to_numpy(tfm.lm_forward_lane(qlm, fhe, tokens))
            if not np.array_equal(ref, enc):
                raise AssertionError(
                    f"{mech}@T={T}: encrypted forward diverged from the "
                    "int lane (lane refactor bug)")
            tot = fhe.ctx.summary()
            measured_scopes = fhe.ctx.scope_report()
            static = analyze_qlm(qlm, seq_len=T)
            # measured-vs-static cross-check: a measured width beyond the
            # proven bound fails loudly inside the selection itself
            sel = select_params_for_report(
                measured_scopes, static_report=static["per_scope"])
            sel_static = select_params_static(static["per_scope"])
            for name, s in measured_scopes.items():
                st = static["per_scope"][name]
                for c in ("pbs", "cmuls", "adds", "lit_muls"):
                    if s[c] != st[c]:
                        raise AssertionError(
                            f"{mech}@T={T} scope {name}: static {c}="
                            f"{st[c]} != measured {s[c]} (the abstract "
                            "trace ran a different circuit)")
            per_mech[mech] = {
                "pbs": tot["pbs"],
                "cmuls": tot["cmuls"],
                "adds": tot["adds"],
                "max_bits_at_pbs": tot["max_bits_at_pbs"],
                "static_max_bits_at_pbs":
                    static["totals"]["max_bits_at_pbs"],
                "static_cmuls": static["totals"]["cmuls"],
                "zero_cmul_proven": static["zero_cmul_proven"],
                "lut_verified": static["lut_verification"]["verified"],
                "poly_size": sel.poly_size,
                "lwe_dim": sel.lwe_dim,
                "static_poly_size": sel_static.poly_size,
                "static_msg_bits": sel_static.msg_bits,
                "est_seconds": round(tot["pbs"] * pbs_seconds(sel), 1),
            }
        if per_mech["inhibitor"]["cmuls"] != 0:
            raise AssertionError(
                "inhibitor block performed ciphertext multiplications — "
                "a lane/layer regression broke the paper's core property")
        if not per_mech["inhibitor"]["zero_cmul_proven"]:
            raise AssertionError(
                "static analysis found a reachable cipher×cipher multiply "
                "on the inhibitor arm — the zero-cmul claim no longer "
                "holds for all inputs")
        if per_mech["dotprod"]["cmuls"] <= 0:
            raise AssertionError("dotprod block reported zero cipher muls "
                                 "(cost accounting regression)")
        speedup = (per_mech["dotprod"]["est_seconds"]
                   / max(per_mech["inhibitor"]["est_seconds"], 1e-9))
        out["rows"].append({"T": T, **{
            f"{m}_{k}": v for m, d in per_mech.items()
            for k, v in d.items()}, "speedup": round(speedup, 2)})
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model + single T for CI")
    ap.add_argument("--json", default="BENCH_fhe_block.json")
    args = ap.parse_args(argv)
    res = run(smoke=args.smoke)
    with open(args.json, "w") as f:
        json.dump(res, f, indent=2)
    hdr = (f"{'T':>4} {'mechanism':>10} {'PBS':>8} {'cmuls':>7} "
           f"{'bits':>5} {'bits*':>5} {'poly':>6} {'poly*':>6} "
           f"{'est time':>10}   speedup   (* = static proven)")
    print(hdr)
    for row in res["rows"]:
        for mech in ("inhibitor", "dotprod"):
            sp = f"{row['speedup']:.2f}x" if mech == "dotprod" else ""
            print(f"{row['T']:>4} {mech:>10} {row[f'{mech}_pbs']:>8} "
                  f"{row[f'{mech}_cmuls']:>7} "
                  f"{row[f'{mech}_max_bits_at_pbs']:>5} "
                  f"{row[f'{mech}_static_max_bits_at_pbs']:>5} "
                  f"{row[f'{mech}_poly_size']:>6} "
                  f"{row[f'{mech}_static_poly_size']:>6} "
                  f"{row[f'{mech}_est_seconds']:>9.1f}s   {sp}")
    print(f"\nwrote {args.json}")


if __name__ == "__main__":
    main()
