"""Batched serving engine with continuous batching.

Design (vLLM-style scheduling on a slot pool, TPU-friendly static shapes):

  * A fixed pool of ``max_batch`` slots backs one layer-stacked KV cache
    with **per-slot cursors** (ragged decode is exact — each row attends
    over its own valid prefix only).  The cache is **paged** by default:
    KV rows live in a shared page pool behind per-slot block tables
    (`serve.kvcache.PagedAllocator`), so memory tracks actual tokens held
    instead of ``max_batch * max_len`` worst case.  ``allocator=
    "contiguous"`` keeps the dense per-slot buffers as the baseline arm.
  * Incoming requests queue; whenever a slot frees, the next request is
    admitted and its prompt is prefilled as a **single row** (batch 1 —
    no ``max_batch``× broadcast) in fixed-size chunks.  The final partial
    chunk is padded up to a power-of-two **bucket**, bounding jit
    retraces to the number of buckets instead of the number of distinct
    prompt lengths; near ``max_len`` the bucketed chunk is left-shifted
    over already-written positions (idempotent rewrites of identical KV
    rows) so the write window never overruns the buffer.
  * **Continuous batching** (DESIGN.md §15): with ``EngineConfig.
    tick_budget`` set, prefill chunks are scheduled *between* decode
    ticks — the scheduler's ``prefill_quota`` token-budget policy decides
    how many prompt tokens each tick spends on chunked prefill while
    every active slot keeps decoding, so one long prompt can no longer
    stall in-flight streams.  A partially-prefilled admission is
    first-class engine state (``Engine.admitting``: slot claimed, prefix
    credit mounted, schedule partially executed); page growth and CoW
    forks happen lazily, per chunk batch actually executed.  With
    ``tick_budget=None`` (default) the whole schedule still runs inside
    the admission tick — same code path, same trace signatures.
  * Every engine tick runs one decode step for all active slots together
    (inactive rows compute garbage that is ignored — static shapes, no
    recompilation; under paging their scatter lands on the reserved
    trash page).  Mid-prefill rows ride through decode too: their device
    cursor stays pinned at the resume position, so each tick's garbage
    write lands inside the next chunk's rewrite window (or on the trash
    page at a page boundary) — never on a shared or already-final row.
  * A request finishes on EOS or at max_new_tokens — including an EOS
    produced by prefill itself, which finishes the request at admission,
    same tick.  Slots whose cache hits ``max_len`` are hard-stopped
    (``Request.truncated``) instead of silently clamping writes; prompts
    with ``prompt_len >= max_len`` are rejected at submit.
  * Under paging, finished requests feed a **shared-prefix radix index**
    (`serve.prefix.PrefixIndex`, DESIGN.md §11): admission mounts the
    longest page-aligned cached prefix into the new slot's block table
    (refcount++, no copy) and prefills only the uncached suffix.  Pages
    are copy-on-write — the only engine write that can land below the
    mounted prefix (a near-``max_len`` bucketed chunk left-shifting over
    already-written positions) forks the touched shared pages first.
    Admission order is a pluggable ``Scheduler`` policy (fifo /
    priority / prefix-affinity — serve.scheduler); per-token streaming
    callbacks and prefix/fork/eviction counters surface through
    ``Request.on_token`` and ``Engine.stats()``.

The same engine drives the `serve` launcher and the serving example; on a
mesh the step functions are jit'd with sharded params (TP) and replicated
small decode batches.
"""

from __future__ import annotations

import dataclasses
import functools
import logging
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.attention import KVCache, PagedKVCache
from repro.models.registry import ModelApi
from repro.serve.kvcache import PagedAllocator, SlotAllocator
from repro.serve.prefix import PrefixIndex
from repro.serve.scheduler import make_scheduler
from repro.serve.telemetry import MetricsRegistry, dump_flight, make_tracer

log = logging.getLogger("repro.serve")

# families whose decode state is entirely cursor-guarded: KV rows beyond
# the cursor are invalid by construction, so padded prefill buckets are
# safe.  Recurrent carries (ssm/hybrid/rwkv) would absorb pad tokens, so
# those families prefill in exact-length chunks instead.
_KV_FAMILIES = ("dense", "moe", "vlm")
_PAGEABLE_FAMILIES = ("dense", "moe", "hybrid", "vlm")


# eq=False: requests are identity objects (schedulers remove them from
# queues by identity; a generated __eq__ would tuple-compare the ndarray
# prompt and raise on same-id requests)
@dataclasses.dataclass(eq=False)
class Request:
    request_id: int
    prompt: np.ndarray             # (prompt_len,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    priority: int = 0              # larger admits first (priority policy)
    # streaming: called as on_token(request, token) for every generated
    # token, the prefill-produced first token included, in order
    on_token: Optional[Callable[["Request", int], None]] = None
    # filled by the engine:
    output: Optional[list] = None
    truncated: bool = False        # hard-stopped at max_len / page pool dry
    arrival: int = -1              # submit order (scheduler tiebreak)
    # latency accounting (Engine.stats aggregates p50/p99): stamped from
    # one wall-clock read per tick, so the counters cost no extra syscalls
    queued_ticks: int = -1         # ticks spent waiting for a slot
    ttft_ms: float = -1.0          # submit -> first token
    _t_submit: float = -1.0
    _t_last: float = -1.0          # previous token's tick timestamp
    _tick_submit: int = -1


@dataclasses.dataclass
class _PartialPrefill:
    """A chunked admission in flight: slot claimed, prefix credit
    mounted, schedule partially executed — first-class engine state
    (``Engine.admitting``, DESIGN.md §15).  ``pos`` is the resume
    point: prompt tokens covered so far (device KV rows [0, pos) are
    final); the slot's device cursor is pinned there between ticks."""
    req: Request
    schedule: List[Tuple[int, int]]
    credit: int = 0                # prefix-cache tokens mounted at staging
    next_chunk: int = 0            # index of the first unexecuted chunk
    pos: int = 0                   # tokens covered (== credit at staging)
    executed: int = 0              # chunks run so far (0 => clean unwind)
    last_tok: Optional[int] = None # the prefill-produced first token
    paused: bool = False           # pool-dry pause seen since last chunk
                                   # batch (telemetry emits resumed once)


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    max_len: int = 512
    greedy: bool = True            # False: temperature sampling
    temperature: float = 1.0
    allocator: str = "paged"       # "paged" | "contiguous"
    page_size: int = 16
    num_pages: Optional[int] = None   # paged pool size (None: full capacity)
    prefill_chunk: int = 32        # max tokens per prefill step (pow2)
    prefix_cache: bool = True      # shared-prefix radix index over the
                                   # paged pool (DESIGN.md §11); no-op for
                                   # contiguous slots / recurrent carries
    tick_budget: Optional[int] = None  # continuous batching: max tokens
                                   # (decode + padded prefill-chunk
                                   # widths) one tick may execute.  None:
                                   # whole-prompt admission (legacy).
                                   # The scheduler's prefill_quota policy
                                   # splits it (decode-first by default);
                                   # ignored for recurrent families,
                                   # whose carries would absorb the
                                   # interleaved ticks' pad garbage
    scheduler: Any = "fifo"        # admission policy name or Scheduler
                                   # instance ("fifo"|"priority"|"prefix")
    telemetry: Any = None          # observability (DESIGN.md §16): None/
                                   # False disables every hook (zero
                                   # overhead — no events, no timestamps,
                                   # no allocation); True/"on" records
                                   # the full span trace + flight ring;
                                   # "flight" keeps only the crash ring;
                                   # or a telemetry.TelemetryConfig /
                                   # Tracer instance
    warmup: str = "none"           # "decode": pre-trace the decode step's
                                   # proven signature ladder (and autotune
                                   # native kernels) at construction, so
                                   # no serving tick ever compiles;
                                   # "serve": additionally pre-trace the
                                   # proven prefill chunk buckets — the
                                   # whole serving path compiles up front


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


def clamp_prefill_chunk(chunk: int, max_len: int) -> int:
    """Engine-effective prefill chunk: a power of two no larger than half
    the (pow2-rounded) context.  Pure — the static analyzer re-derives
    the compile budget from recorded configs with this exact function."""
    return min(_next_pow2(chunk), _next_pow2(max_len) >> 1 or 1)


def prefill_schedule(prompt_len: int, *, chunk: int, max_len: int,
                     bucketed: bool, start: int = 0) -> List[Tuple[int, int]]:
    """(start, width) chunks covering [start, prompt_len).  Full chunks
    are exact; for cursor-guarded (bucketed) families the final partial
    chunk is padded to a power-of-two bucket and, near max_len,
    left-shifted over already-written positions (rewrites are
    idempotent).  Pure function of the config — both the engine and
    ``repro.analysis.serve_static``'s retrace-budget proof call it, so
    the proof enumerates exactly what the engine will trace."""
    out: List[Tuple[int, int]] = []
    pos = start
    while pos < prompt_len:
        take = min(chunk, prompt_len - pos)
        if bucketed:
            cb = _next_pow2(take)
            s = max(0, min(pos, max_len - cb))
        else:
            cb, s = take, pos
        out.append((s, cb))
        pos += take
    return out


def decode_table_width(longest: int, *, page_size: int,
                       pages_per_slot: int) -> int:
    """Bucketed block-table width for a decode tick whose longest active
    row holds ``longest`` positions (read + the written KV row), rounded
    up to a power of two.  Pure — shared with the static analyzer's
    decode-bucket enumeration."""
    need = -(-longest // page_size)
    return min(pages_per_slot, _next_pow2(max(need, 1)))


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _jit_pool_page_copy(k_pool, v_pool, old, new):
    """Copy physical page ``old`` -> ``new`` in the stacked
    (L, num_pages, page_size, h_kv, d) K/V pools.  The pools are donated,
    so XLA aliases the buffers and the copy is O(page), not a fresh
    pool-sized allocation (the CoW fork path — Engine._copy_page)."""
    return (k_pool.at[:, new].set(k_pool[:, old]),
            v_pool.at[:, new].set(v_pool[:, old]))


class Engine:
    def __init__(self, api: ModelApi, params, cfg: EngineConfig, *,
                 seed: int = 0):
        if cfg.allocator not in ("paged", "contiguous"):
            raise ValueError(f"unknown allocator {cfg.allocator!r}")
        self.api = api
        self.params = params
        self.cfg = dataclasses.replace(
            cfg, prefill_chunk=clamp_prefill_chunk(cfg.prefill_chunk,
                                                   cfg.max_len))
        fam = api.cfg.family
        self.paged = cfg.allocator == "paged" and fam in _PAGEABLE_FAMILIES
        if cfg.allocator == "paged" and not self.paged:
            log.info("family %r has no pageable KV cache; using contiguous "
                     "slots", fam)
        forced = getattr(api.cfg.attention, "backend", None)
        if forced == "paged_pallas":
            # the paged decode kernel is single-query; prefill chunks are
            # multi-query, so an engine-wide force can never run — fail at
            # construction, not deep inside the first admission
            raise ValueError(
                "backend='paged_pallas' cannot be forced engine-wide: "
                "prefill chunks are multi-query and the paged decode "
                "kernel is single-query (n_q=1).  Leave backend=None — "
                "the planner selects paged_pallas for TPU decode ticks "
                "automatically")
        if self.paged:
            # downgrade (don't crash) when the plan could never select the
            # paged backend: mechanism without a 'paged' entry, a config
            # that forces another backend, integer compute lanes, ...
            ok, why = self._paged_eligible()
            if not ok:
                log.info("paged cache unavailable (%s); using contiguous "
                         "slots", why)
                self.paged = False
        if forced == "paged" and not self.paged:
            raise ValueError(
                f"backend='paged' forced but the engine is backed by "
                f"contiguous slots (allocator={cfg.allocator!r}, family "
                f"{fam!r}) — it needs allocator='paged' and a pageable "
                f"family")
        self._bucketed = fam in _KV_FAMILIES
        if self.paged:
            self.alloc = PagedAllocator(cfg.max_batch, cfg.max_len,
                                        cfg.page_size, cfg.num_pages)
            self.states = api.init_states(
                cfg.max_batch, cfg.max_len, per_slot=True, paged=True,
                page_size=cfg.page_size, num_pages=self.alloc.num_pages)
        else:
            self.alloc = SlotAllocator(cfg.max_batch)
            self.states = api.init_states(cfg.max_batch, cfg.max_len,
                                          per_slot=True)
        # shared-prefix radix cache: page-aligned prefixes of finished
        # requests stay resident and are mounted at admission.  Recurrent
        # carries (hybrid mamba) cannot skip prefix compute — their state
        # at the suffix depends on running the whole prefix — so the
        # index is KV-pure families only.
        self.prefix: Optional[PrefixIndex] = None
        if self.paged and cfg.prefix_cache and fam in _KV_FAMILIES:
            self.prefix = PrefixIndex(self.alloc)
            self.alloc.attach_reclaimer(self._reclaim_pages)
        elif cfg.prefix_cache and self.paged:
            log.info("prefix cache unavailable for family %r (recurrent "
                     "carries cannot skip prefill)", fam)
        self.scheduler = make_scheduler(cfg.scheduler)
        self.active: Dict[int, Request] = {}     # slot -> request
        # slot -> in-flight chunked admission (insertion order == staging
        # order; resumed FIFO each tick before new admissions)
        self.admitting: Dict[int, _PartialPrefill] = {}
        if cfg.tick_budget is not None:
            if cfg.tick_budget < 1:
                raise ValueError(
                    f"tick_budget must be >= 1 (or None), got "
                    f"{cfg.tick_budget}")
            if not self._bucketed:
                log.info("family %r prefills exact-length whole prompts "
                         "(recurrent carries); tick_budget ignored", fam)
        # metrics registry (DESIGN.md §16): the counters dict is owned by
        # the registry and aliased here, so every existing counter key
        # keeps working while --metrics-json gets one unified snapshot
        self.metrics = MetricsRegistry()
        self.metrics.counters.update({
            "prefix_hit_tokens": 0, "prefix_hit_requests": 0,
            "forked_pages": 0, "prefill_tokens": 0,
            "generated_tokens": 0, "finished_requests": 0,
            "table_uploads": 0, "table_uploads_decode": 0,
            "table_uploads_prefill": 0, "decode_ticks": 0,
            "prefill_chunks": 0, "paused_prefills": 0})
        self.counters: Dict[str, int] = self.metrics.counters
        self._arrival = 0
        self._tick = 0
        self._admission_backoff = False
        self._prefill_stalled = False
        self._progressed = False
        # per-request latency samples (finished or streaming): bounded
        # reservoir histograms — stats() reports p50/p99 over the
        # reservoir, O(capacity) memory however long the engine runs
        self._lat = {k: self.metrics.histogram(k)
                     for k in ("ttft_ms", "itl_ms", "queued_ticks")}
        # span tracer + flight recorder, or None (the zero-overhead
        # default): every hook below is one attribute load + is-None
        # guard, and the emit path is statically audited to perform no
        # host<->device transfers (analysis.serve_static
        # .audit_telemetry_file)
        self.tel = make_tracer(cfg.telemetry)
        self._key = jax.random.PRNGKey(seed)
        self.decode_plan = self._plan_decode()
        if self.decode_plan is not None:
            log.info("engine decode %s [max_batch=%d max_len=%d alloc=%s]",
                     self.decode_plan.trace_line(), cfg.max_batch,
                     cfg.max_len, "paged" if self.paged else "contiguous")
        if self.tel is not None:
            self.tel.set_meta("engine", {
                "family": fam, "max_batch": cfg.max_batch,
                "max_len": cfg.max_len,
                "allocator": "paged" if self.paged else "contiguous",
                "page_size": cfg.page_size,
                "prefill_chunk": self.cfg.prefill_chunk,
                "tick_budget": cfg.tick_budget,
                "prefix_cache": self.prefix is not None})
            if self.decode_plan is not None:
                # plan provenance rides the trace: why this backend
                self.tel.set_meta("decode_plan", {
                    "mechanism": self.decode_plan.mechanism,
                    "backend": self.decode_plan.backend,
                    "reason": self.decode_plan.reason})
        # trace-counting wrappers: the wrapped python body runs only while
        # jax traces a NEW input signature, so these counters are live
        # compile counts — checked against the proven retrace budget
        # (repro.analysis.serve_static; measured > proven = soundness bug)
        self._decode_traces = 0
        self._prefill_traces = 0
        self._jit_decode = jax.jit(
            self._trace_counted(self._decode_step, "_decode_traces"))
        self._jit_prefill_chunk = jax.jit(
            self._trace_counted(self._prefill_chunk, "_prefill_traces"))
        self._prefill_buckets: set = set()   # chunk widths handed to jit
        self._decode_table_buckets: set = set()  # high-water table widths
        # host block tables (alloc.block_tables) are authoritative; the
        # device mirror refreshes lazily in ONE batched upload per tick
        self._tables_dirty = False
        self._retrace_budget_cache: Optional[Dict[str, Any]] = None
        if self.cfg.warmup not in ("none", "decode", "serve"):
            raise ValueError(f"unknown warmup policy {self.cfg.warmup!r} "
                             f"(expected 'none', 'decode' or 'serve')")
        if self.cfg.warmup in ("decode", "serve"):
            self._warmup_decode()
        if self.cfg.warmup == "serve":
            self._warmup_prefill()

    # ---- planning / introspection ----
    @property
    def queue(self):
        """The scheduler, exposed under the old attribute name (len() /
        truthiness keep meaning 'requests waiting for admission')."""
        return self.scheduler

    def stats(self) -> Dict[str, int]:
        """Engine-level serving counters: prefix-cache effectiveness
        (``prefix_hit_tokens`` — prompt tokens served from cached pages
        instead of prefill), copy-on-write activity (``forked_pages``),
        cache churn (``evictions``, pages LRU-evicted under pool
        pressure), plus throughput/compile accounting."""
        s = dict(self.counters)
        s["prefill_compiles"] = self.prefill_compiles
        s["decode_compiles"] = self.decode_compiles
        s["retrace_budget"] = self.retrace_budget()
        s["scheduler"] = getattr(self.scheduler, "name",
                                 type(self.scheduler).__name__)
        if self.prefix is not None:
            s["evictions"] = self.prefix.evictions
            s["cached_pages"] = self.prefix.cached_pages
            s["prefix_lookups_hit"] = self.prefix.hits
            s["prefix_lookups_miss"] = self.prefix.misses
        else:
            s["evictions"] = 0
            s["cached_pages"] = 0
        if self.paged:
            s["pages_in_use"] = self.alloc.pages_in_use
            s["high_water_pages"] = self.alloc.high_water_pages
        s["inflight_prefills"] = len(self.admitting)
        # per-request latency percentiles, fed by tick timestamps:
        # ttft_ms (submit -> first token), itl_ms (token -> next token,
        # in-flight streams included), queued_ticks (submit -> slot).
        # Backed by bounded reservoir histograms (telemetry.Histogram);
        # latency_samples reports the true observation counts
        for k, h in self._lat.items():
            s[f"{k}_p50"] = h.percentile(50)
            s[f"{k}_p99"] = h.percentile(99)
        s["latency_samples"] = {k: h.count for k, h in self._lat.items()}
        return s

    def _reclaim_pages(self, need: int) -> int:
        """Allocator reclaim hook: LRU-evict cached prefix pages, and
        surface the eviction on the tick timeline when tracing (the
        allocator calls this only under pool pressure — never on the
        steady-state path, so the hook costs nothing per tick)."""
        freed = self.prefix.evict(need)
        if self.tel is not None and freed:
            self.tel.instant("eviction", need_pages=need, freed_pages=freed)
        return freed

    def _paged_eligible(self):
        """(ok, why_not) for backing this model's decode with the paged
        pool — probed up front so ineligibility degrades to contiguous
        slots instead of raising out of plan_attention."""
        from repro.core.mechanism import (AttnShapes, backend_eligible,
                                          get_mechanism,
                                          resolve_mechanism_name)

        acfg = self.api.cfg.attention
        forced = getattr(acfg, "backend", None)
        if forced not in (None, "paged"):
            return False, f"config forces backend={forced!r}"
        shapes = AttnShapes(
            batch=self.cfg.max_batch, n_q=1, n_k=self.cfg.max_len,
            num_heads=acfg.num_heads, num_kv_heads=acfg.num_kv_heads,
            head_dim=acfg.head_dim, dtype=self.api.cfg.cdtype,
            has_cache=True, scalar_cursor=False, paged=True)
        return backend_eligible("paged", acfg, shapes,
                                get_mechanism(resolve_mechanism_name(acfg)))

    def _plan_decode(self):
        """Inspectable attention plan for the steady-state decode tick
        (per-slot ragged cursors; paged pool or full-slot KV buffer).
        None for attention-free families (rwkv)."""
        from repro.core.mechanism import AttnShapes, plan_attention

        mcfg = self.api.cfg
        if mcfg.family == "ssm":
            return None
        acfg = mcfg.attention
        if self.paged:
            n_k = self.alloc.pages_per_slot * self.cfg.page_size
        else:
            n_k = self.cfg.max_len
        shapes = AttnShapes(
            batch=self.cfg.max_batch, n_q=1, n_k=n_k,
            num_heads=acfg.num_heads, num_kv_heads=acfg.num_kv_heads,
            head_dim=acfg.head_dim, dtype=mcfg.cdtype, has_cache=True,
            scalar_cursor=False, paged=self.paged)
        plan = plan_attention(acfg, shapes)
        if (plan.backend == "paged"
                and getattr(acfg, "backend", None) is None
                and not getattr(acfg, "use_kernel", False)):
            # under these exact conditions models.transformer.lm_step
            # hoists ONE whole-model page gather out of the layer scan
            # (fused_gather_applies) — surface it in the inspectable plan
            plan = dataclasses.replace(
                plan, reason=plan.reason + "; all-layer fused gather "
                "hoisted out of the layer scan (DESIGN.md §14)")
        return plan

    @property
    def prefill_compiles(self) -> int:
        """Number of distinct prefill traces (== compiles).  Bounded by
        the bucket count for cursor-guarded families, not by the number
        of distinct prompt lengths."""
        try:
            n = self._jit_prefill_chunk._cache_size()
            if n:
                return n
        except Exception:  # noqa: BLE001 — private jit API; fall back
            pass
        return max(len(self._prefill_buckets), self._prefill_traces)

    @property
    def decode_compiles(self) -> int:
        """Number of distinct decode traces (== compiles).  Bounded by
        the clamped block-table width buckets (log2(pages_per_slot)+1)
        under paging, 1 for contiguous slots."""
        try:
            n = self._jit_decode._cache_size()
            if n:
                return n
        except Exception:  # noqa: BLE001 — private jit API; fall back
            pass
        return self._decode_traces

    def _trace_counted(self, fn, attr: str):
        """Wrap a step function so jit tracing bumps ``self.<attr>`` —
        the wrapper body only runs on a cache miss, making the counter a
        live compile count."""
        @functools.wraps(fn)
        def counted(*args):
            setattr(self, attr, getattr(self, attr) + 1)
            return fn(*args)
        return counted

    def retrace_budget(self) -> Dict[str, Any]:
        """Proven compile budget for this engine's config, as derived by
        the static analyzer (``repro.analysis.serve_static``).  The live
        ``prefill_compiles`` / ``decode_compiles`` counters must never
        exceed the proven counts."""
        if self._retrace_budget_cache is None:
            from repro.analysis.serve_static import retrace_budget

            b = retrace_budget(
                bucketed=self._bucketed, paged=self.paged,
                max_len=self.cfg.max_len,
                prefill_chunk=self.cfg.prefill_chunk,
                page_size=self.cfg.page_size,
                pages_per_slot=(self.alloc.pages_per_slot
                                if self.paged else None),
                prefix_cache=self.prefix is not None)
            self._retrace_budget_cache = {
                "prefill_proven": b["prefill"]["proven"],
                "decode_proven": b["decode"]["proven"],
                "chunk_resume_closed": b["chunk_resume"]["closed"],
                "within_declared": b["within_budget"]}
        return dict(self._retrace_budget_cache)

    # ---- jitted kernels ----
    def _next_key(self) -> jax.Array:
        """Per-step sampling key.  Greedy decoding takes argmax — the key
        is dead — so the host-side ``jax.random.split`` is skipped
        entirely and every step reuses the root key (bit-identical
        outputs either way; sampling mode still splits per step)."""
        if self.cfg.greedy:
            return self._key
        self._key, sub = jax.random.split(self._key)
        return sub

    def _select(self, logits, key):
        """(n, V) logits -> (n,) int32 next tokens (greedy or sampled)."""
        if self.cfg.greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        t = max(self.cfg.temperature, 1e-6)
        return jax.random.categorical(key, logits / t, axis=-1).astype(
            jnp.int32)

    def _decode_step(self, params, tokens, states, key):
        logits, new_states = self.api.step(params, tokens, states, None)
        nxt = self._select(logits[:, -1], key)
        return nxt, new_states

    def _prefill_chunk(self, params, tokens, states, last_idx, key):
        """One single-row prefill chunk: tokens (1, cb) into batch-1 state
        view.  ``last_idx`` (traced) points at the final *real* token —
        bucket padding sits after it and is causally invisible to it."""
        logits, new_states = self.api.step(params, tokens, states, None)
        lg = jax.lax.dynamic_index_in_dim(logits[0], last_idx, axis=0,
                                          keepdims=False)
        nxt = self._select(lg[None], key)[0]
        return nxt, new_states

    # ---- batch-1 state views (single-row prefill) ----
    def _slot_view(self, slot: int):
        st = self.states
        from repro.models.transformer import LayerState

        if isinstance(st, LayerState):
            kv = st.kv
            if isinstance(kv, PagedKVCache):
                # pools are shared across slots — only table/cursor narrow
                kv_v = PagedKVCache(kv.k, kv.v,
                                    kv.block_tables[:, slot:slot + 1],
                                    kv.length[:, slot:slot + 1])
            else:
                kv_v = KVCache(kv.k[:, slot:slot + 1], kv.v[:, slot:slot + 1],
                               kv.length[:, slot:slot + 1])
            ssm = st.ssm[:, slot:slot + 1] if st.ssm is not None else None
            conv = st.conv[:, slot:slot + 1] if st.conv is not None else None
            return LayerState(kv=kv_v, ssm=ssm, conv=conv)
        return jax.tree.map(lambda x: x[:, slot:slot + 1], st)

    def _merge_view(self, slot: int, view):
        st = self.states
        from repro.models.transformer import LayerState

        if isinstance(st, LayerState):
            kv, kvv = st.kv, view.kv
            if isinstance(kv, PagedKVCache):
                # take the updated pools wholesale (writes landed in this
                # slot's pages only); splice table/cursor rows back
                kv_n = PagedKVCache(
                    kvv.k, kvv.v,
                    kv.block_tables.at[:, slot].set(kvv.block_tables[:, 0]),
                    kv.length.at[:, slot].set(kvv.length[:, 0]))
            else:
                kv_n = KVCache(kv.k.at[:, slot].set(kvv.k[:, 0]),
                               kv.v.at[:, slot].set(kvv.v[:, 0]),
                               kv.length.at[:, slot].set(kvv.length[:, 0]))
            ssm = (st.ssm.at[:, slot].set(view.ssm[:, 0])
                   if st.ssm is not None else None)
            conv = (st.conv.at[:, slot].set(view.conv[:, 0])
                    if st.conv is not None else None)
            self.states = LayerState(kv=kv_n, ssm=ssm, conv=conv)
        else:
            self.states = jax.tree.map(
                lambda x, vv: x.at[:, slot].set(vv[:, 0]), st, view)

    @staticmethod
    def _set_view_cursor(view, value: int):
        """Pin the batch-1 view's KV cursor (bucketed chunks advance it by
        the padded width; the true position is host-known)."""
        kv = view.kv
        return view._replace(kv=kv._replace(
            length=jnp.full_like(kv.length, value)))

    # ---- prefill scheduling ----
    def _prefill_schedule(self, prompt_len: int,
                          start: int = 0) -> List[Tuple[int, int]]:
        """(start, width) chunks covering [start, prompt_len).  Full
        chunks are exact; for cursor-guarded families the final partial
        chunk is padded to a power-of-two bucket and, near max_len,
        left-shifted over already-written positions (rewrites are
        idempotent — and when ``start`` is a prefix-cache credit, a
        left shift below it lands on shared pages, which admission forks
        first: DESIGN.md §11).  ``start > 0`` requires cached KV rows at
        [0, start) — the prefix credit."""
        return prefill_schedule(prompt_len, chunk=self.cfg.prefill_chunk,
                                max_len=self.cfg.max_len,
                                bucketed=self._bucketed, start=start)

    def _prefill_extent(self, prompt_len: int) -> int:
        return max((s + c for s, c in self._prefill_schedule(prompt_len)),
                   default=0)

    def _ensure_pages(self, slot: int, length: int) -> bool:
        """Grow the slot's block table to cover ``length`` positions and
        mark the device mirror stale (the next ``_flush_tables`` pushes
        all dirty rows in one upload).  False: pool exhausted."""
        grew = self.alloc.ensure(slot, length)
        if grew is None:
            return False
        if grew:
            self._mark_tables_dirty()
        return True

    def _exec_chunks(self, slot: int, part: _PartialPrefill, upto: int,
                     now: float) -> Optional[Request]:
        """Run schedule chunks ``[part.next_chunk, upto)`` through the
        jitted single-row prefill.  The caller reserved the pages
        (``_reserve_chunks``) and flushed the table mirror, so the view's
        block-table row is final for every chunk in the batch.  Between
        ticks the merged view's cursor is pinned to the resume point
        ``pos`` — an interleaved decode tick's garbage write lands at
        ``pos``, inside the next chunk's write window (windows always
        cover the resume position), so it is rewritten idempotently.
        Returns the finished request when the batch completed the
        schedule AND its first token was terminal (finish at admission),
        else None."""
        req = part.req
        prompt = np.asarray(req.prompt, np.int32)  # sync: host — the prompt is host-resident numpy, nothing crosses the link
        L = len(prompt)
        tr = self.tel
        lo = part.next_chunk
        if part.paused:
            part.paused = False
            if tr is not None:
                tr.request_resumed(req.request_id, part.pos)
        # start the chunk-batch X span AFTER the resumed instant: the X
        # event is emitted at its start timestamp, so anything recorded
        # between t0 and emission would read as time going backwards
        t0 = tr.now() if tr is not None else 0.0
        view = self._slot_view(slot)
        nxt = None
        last_i = len(part.schedule) - 1
        for i in range(part.next_chunk, upto):
            start, cb = part.schedule[i]
            real = min(start + cb, L) - start
            toks = np.zeros((1, cb), np.int32)
            toks[0, :real] = prompt[start:start + real]
            if self._bucketed:
                view = self._set_view_cursor(view, start)
            last = L - 1 - start if i == last_i else real - 1
            self._prefill_buckets.add(cb)
            self.counters["prefill_chunks"] += 1
            sub = self._next_key()
            nxt, view = self._jit_prefill_chunk(
                self.params,
                jnp.asarray(toks),   # sync: required — prompt-chunk upload (admission-rate, not per-tick)
                view,
                jnp.int32(last),     # sync: eliminable — scalar cursor upload; could ride inside the token buffer
                sub)
            if self.paged:
                # the view's pools are now the freshest — keep the full
                # states' pool in sync so later table growth edits stick
                kv = self.states.kv
                self.states = self.states._replace(
                    kv=kv._replace(k=view.kv.k, v=view.kv.v))
            # each schedule entry covers exactly min(chunk, L - pos) new
            # tokens (left-shifted windows rewrite, they don't advance)
            part.pos = min(part.pos + self.cfg.prefill_chunk, L)
            part.executed += 1
        part.next_chunk = upto
        self._progressed = True
        done = upto == len(part.schedule)
        if self._bucketed:
            view = self._set_view_cursor(view, L if done else part.pos)
        self._merge_view(slot, view)
        # host cursor tracks the resume point so the decode tick's
        # clamped table width covers the mid-prefill row's page
        self.alloc.slots[slot].length = part.pos
        if tr is not None:
            tr.request_chunks(req.request_id, t0, lo, upto, part.pos,
                              len(part.schedule))
        if not done:
            log.debug("request %d prefilled to %d/%d tokens (chunk "
                      "%d/%d)", req.request_id, part.pos, L,
                      part.next_chunk, len(part.schedule))
            return None
        part.last_tok = int(nxt)  # sync: required — prefill's first token feeds host-side finish/stream logic
        return self._complete_admission(slot, now)

    # ---- public API ----
    def submit(self, req: Request):
        # validate + defensively copy: a float array would silently turn
        # into garbage token ids inside the jitted prefill, and a caller
        # mutating its array after submit would corrupt queued prompts
        arr = np.asarray(req.prompt)
        if arr.ndim != 1:
            raise ValueError(
                f"prompt must be 1-D (token ids), got shape {arr.shape}")
        if not np.issubdtype(arr.dtype, np.integer):
            raise ValueError(
                f"prompt must be an integer array, got dtype {arr.dtype}")
        req.prompt = arr.astype(np.int32, copy=True)
        plen = len(req.prompt)
        if plen < 1:
            raise ValueError("empty prompt")
        if plen >= self.cfg.max_len:
            raise ValueError(
                f"prompt_len={plen} >= max_len={self.cfg.max_len}: the KV "
                f"buffer cannot hold the prompt plus one generated token")
        if self.paged:
            # the prefill write extent plus the first decode tick's KV
            # row.  Deliberately credit-free: a slot referencing N pages
            # needs N physical pages whether or not some are shared, and
            # cached credit can shrink (eviction) between submit and
            # admission — this check must reject only prompts the pool
            # could never hold
            need = -(-max(self._prefill_extent(plen), plen + 1)
                     // self.cfg.page_size)
            if need > self.alloc.num_pages - 1:
                raise ValueError(
                    f"prompt needs {need} pages but the pool holds "
                    f"{self.alloc.num_pages - 1}")
        req.output = []
        req.truncated = False
        req.arrival = self._arrival
        req._t_submit = time.perf_counter()
        req._tick_submit = self._tick
        self._arrival += 1
        self.scheduler.add(req)
        if self.tel is not None:
            self.tel.request_submit(req.request_id, plen,
                                    req.max_new_tokens, req.priority)

    def _prefix_credit(self, req: Request) -> Tuple[int, List[int]]:
        """(tokens, pages) of the longest usable cached prefix of the
        request's prompt: page-aligned by construction, and capped so at
        least one prompt token is always prefilled (the engine needs the
        last prompt token's logits to generate)."""
        if self.prefix is None:
            return 0, []
        m, pages = self.prefix.match(req.prompt)
        ps = self.cfg.page_size
        cap = ((len(req.prompt) - 1) // ps) * ps
        m = min(m, cap)
        return m, pages[:m // ps]

    def _copy_page(self, old: int, new: int):
        """Device half of a CoW fork: copy pool page ``old`` -> ``new``
        across all layers (the forked page must carry the shared rows the
        slot is NOT about to rewrite).  Jitted with donated pools so XLA
        updates the buffers in place — O(page) work, not a fresh
        pool-sized array per fork; page ids are traced scalars, so every
        fork reuses one trace."""
        kv = self.states.kv
        k, v = _jit_pool_page_copy(
            kv.k, kv.v,
            jnp.int32(old), jnp.int32(new))  # sync: required — page-id scalars for the donated CoW copy (fork-rate, not per-tick)
        self.states = self.states._replace(kv=kv._replace(k=k, v=v))
        if self.tel is not None:
            self.tel.instant("cow_fork", old_page=old, new_page=new)

    def _mark_tables_dirty(self):
        """Flag the device block-table mirror stale.  The host tables
        (``alloc.block_tables``; zeroed rows included — ``release()``
        clears a slot's row) are authoritative, so any number of host
        edits collapse into ONE batched upload at the next
        ``_flush_tables``, replacing the old per-slot
        ``jnp.asarray(block_tables[slot])`` upload loop."""
        self._tables_dirty = True

    def _flush_tables(self, where: str = "decode"):
        """Mirror the full host block-table array into device state in a
        single batched host->device transfer.  Called once before every
        decode tick and before each prefill reads a slot view — never
        per slot, so a tick's table traffic is at most one upload no
        matter how many slots grew, forked, or were scrubbed."""
        if not (self.paged and self._tables_dirty):
            return
        rows = jnp.asarray(  # sync: required — the tick's one batched h2d block-table upload
            self.alloc.block_tables)
        kv = self.states.kv
        self.states = self.states._replace(kv=kv._replace(
            block_tables=jnp.broadcast_to(rows[None],
                                          kv.block_tables.shape)))
        self._tables_dirty = False
        self.counters["table_uploads"] += 1
        self.counters[f"table_uploads_{where}"] += 1
        if self.tel is not None:
            self.tel.instant("table_upload", where=where)

    def _scrub_slot_device(self, slot: int):
        """Retire an inactive slot's device row: the row keeps flowing
        through the static-shape decode step, and its garbage scatter
        must land on the trash page — never on pages the row's previous
        mapping pointed at (they may be cached/reallocated).  The host
        table row is already zeroed (``alloc.release``), so the table
        half rides the next batched flush; only the cursor is zeroed
        eagerly (a device-side edit, no transfer)."""
        kv = self.states.kv
        self.states = self.states._replace(kv=kv._replace(
            length=kv.length.at[:, slot].set(0)))
        self._mark_tables_dirty()

    def _stage_slot(self, slot: int, req: Request, credit: int,
                    pages: List[int]) -> List[Tuple[int, int]]:
        """Mount the prefix credit and fix the admission's prefill
        schedule.  Staging is allocation-free: page growth and CoW forks
        happen lazily, per chunk batch actually executed
        (``_reserve_chunks``) — a chunk the token budget defers to a
        later tick allocates nothing now."""
        if credit:
            self.alloc.map_shared(slot, pages)
            self._mark_tables_dirty()
        return self._prefill_schedule(len(req.prompt), start=credit)

    def _reserve_chunks(self, slot: int, part: _PartialPrefill,
                        upto: int) -> bool:
        """Grow the block table and CoW-fork shared pages for schedule
        chunks ``[part.next_chunk, upto)`` — exactly the batch the caller
        is about to execute this tick.  Returns False when the page pool
        ran dry even after reclaim (caller unwinds a zero-progress
        admission or pauses a half-prefilled one; pages grabbed before
        the exhaustion stay mapped — they are reclaimed with the slot)."""
        if not self.paged or upto <= part.next_chunk:
            return True
        chunks = part.schedule[part.next_chunk:upto]
        need = max(s + c for s, c in chunks)
        if upto == len(part.schedule):
            # the final batch also covers the first decode tick's KV row
            # (the slot decodes the tick it completes, before the next
            # growth pass runs)
            need = max(need, len(part.req.prompt) + 1)
        if not self._ensure_pages(slot, need):
            return False
        if part.credit:
            # copy-on-write: the only engine writes below the credit are
            # near-max_len bucketed chunks left-shifting over already-
            # written positions.  The rewrite is idempotent (same tokens,
            # same positions) but must not scatter into pages the index /
            # other slots still reference — fork those first, and only
            # for the chunks executing this tick (DESIGN.md §15)
            ps = self.cfg.page_size
            for start, cb in chunks:
                if start >= part.credit:
                    continue
                lo = start // ps
                hi = -(-min(start + cb, part.credit) // ps)
                for lp in range(lo, hi):
                    if self.alloc.writable(slot, lp):
                        continue
                    fork = self.alloc.fork(slot, lp)
                    if fork is None:
                        return False
                    self._copy_page(*fork)
                    self._mark_tables_dirty()
                    self.counters["forked_pages"] += 1
                    log.debug("CoW fork: slot %d logical page %d "
                              "(%d -> %d)", slot, lp, *fork)
        return True

    def _prefill_quota(self) -> Optional[int]:
        """This tick's chunked-prefill token quota (None = unbounded),
        from the scheduler's token-budget policy.  Recurrent families
        always prefill whole prompts — their carries would absorb the
        interleaved ticks' pad garbage — so the budget only paces
        cursor-guarded (bucketed) families."""
        if not self._bucketed:
            return None
        fn = getattr(self.scheduler, "prefill_quota", None)
        if fn is None:     # custom Scheduler predating the budget policy
            budget = self.cfg.tick_budget
            return (None if budget is None
                    else max(0, budget - len(self.active)))
        return fn(self, len(self.active))

    def _plan_chunks(self, part: _PartialPrefill, quota: Optional[int],
                     spent: int) -> int:
        """How far into the partial's schedule this tick may execute:
        returns ``upto`` (chunk index).  The budget charges *padded*
        widths (what jit executes).  The tick's first chunk always fits
        when the quota is positive — overshoot is bounded by one bucket
        — so a small budget slows admission instead of stalling it."""
        upto, cost = part.next_chunk, 0
        for _s, cb in part.schedule[part.next_chunk:]:
            if quota is not None and spent + cost + cb > quota and (
                    spent or cost or quota <= 0):
                break
            upto += 1
            cost += cb
        return upto

    def _batch_cost(self, part: _PartialPrefill, upto: int) -> int:
        return sum(cb for _s, cb in part.schedule[part.next_chunk:upto])

    def _append_token(self, req: Request, tok: int,
                      now: Optional[float] = None):
        """Record a generated token, stamp its latency sample, and fire
        the streaming callback."""
        tok = int(tok)  # sync: host — tok is already a host-side numpy scalar here
        req.output.append(tok)
        self.counters["generated_tokens"] += 1
        if now is not None:
            if len(req.output) == 1:
                req.ttft_ms = (now - req._t_submit) * 1e3
                self._lat["ttft_ms"].record(req.ttft_ms)
            else:
                self._lat["itl_ms"].record((now - req._t_last) * 1e3)
            req._t_last = now
        if req.on_token is not None:
            try:
                req.on_token(req, tok)
            except Exception:   # noqa: BLE001 — user callback must not
                log.exception(  # kill the serving loop
                    "on_token callback failed for request %d",
                    req.request_id)

    def _unwind_slot(self, slot: int):
        """Give a claimed slot (and every page it mapped) back, and scrub
        its device row so the inactive row's decode scatter lands on the
        trash page instead of pages the old mapping pointed at."""
        self.alloc.release(slot)
        if self.paged:
            self._scrub_slot_device(slot)

    def _complete_admission(self, slot: int,
                            now: float) -> Optional[Request]:
        """The partial finished its whole schedule: promote it to an
        active (decoding) slot and account the admission.  Returns the
        request when its first (prefill-produced) token was terminal —
        EOS or max_new_tokens=1 — i.e. finish at admission."""
        part = self.admitting.pop(slot)
        req = part.req
        self.active[slot] = req
        if self.tel is not None:
            self.tel.request_decode(req.request_id, part.credit)
        self.alloc.slots[slot].length = len(req.prompt)
        self.counters["prefill_tokens"] += len(req.prompt) - part.credit
        if part.credit:
            self.counters["prefix_hit_tokens"] += part.credit
            self.counters["prefix_hit_requests"] += 1
        self._append_token(req, part.last_tok, now)
        nxt = req.output[-1]
        done = (len(req.output) >= req.max_new_tokens
                or (req.eos_id is not None and nxt == req.eos_id))
        if done:
            log.debug("request %d finished at admission", req.request_id)
            return self._finish(slot)
        log.debug("admitted request %d into slot %d (prefix credit "
                  "%d tokens)", req.request_id, slot, part.credit)
        return None

    def _advance_one(self, slot: int, quota: Optional[int], spent: int,
                     now: float,
                     reserved_upto: Optional[int] = None
                     ) -> Tuple[int, Optional[Request]]:
        """Advance one in-progress admission by this tick's share of the
        token budget: plan the chunk batch, reserve its pages/forks,
        execute.  Returns (padded tokens spent, finished request or
        None).  Reservation failure on a zero-progress credit admission
        re-stages uncached (the cache must never block an admission an
        empty cache would allow); any other failure pauses the partial in
        place — slot, pages, and executed chunks are all kept, and the
        request resumes when the pool frees up."""
        part = self.admitting[slot]
        upto = reserved_upto
        if upto is not None:
            if upto == part.next_chunk:
                return 0, None          # staged with zero budget left
        else:
            upto = self._plan_chunks(part, quota, spent)
            if upto == part.next_chunk:
                return 0, None          # budget spent: defer to next tick
            if not self._reserve_chunks(slot, part, upto):
                if part.credit and part.executed == 0:
                    # scrub the mounted credit and retry uncached, still
                    # as the same in-progress admission (same slot id)
                    req = part.req
                    del self.admitting[slot]
                    self._unwind_slot(slot)
                    slot2 = self.alloc.claim(req.request_id)
                    fresh = _PartialPrefill(
                        req=req, schedule=self._stage_slot(slot2, req, 0, []))
                    self.admitting[slot2] = fresh
                    self.states = _reset_slot(self.states, slot2)
                    if self.paged:
                        self._mark_tables_dirty()
                    if self.tel is not None:
                        self.tel.request_restaged(req.request_id)
                    return self._advance_one(slot2, quota, spent, now)
                self._prefill_stalled = True
                part.paused = True
                self.counters["paused_prefills"] += 1
                if self.tel is not None:
                    self.tel.request_paused(part.req.request_id, part.pos)
                log.debug("request %d paused mid-prefill at %d/%d tokens "
                          "(page pool dry)", part.req.request_id, part.pos,
                          len(part.req.prompt))
                return 0, None
        cost = self._batch_cost(part, upto)
        # the batch's table edits (growth + forks) ride ONE upload
        self._flush_tables("prefill")
        return cost, self._exec_chunks(slot, part, upto, now)

    def _run_prefills(self, quota: Optional[int],
                      now: float) -> List[Request]:
        """The tick's chunked-prefill pass: resume in-progress admissions
        first (FIFO in staging order), then admit from the scheduler
        while slots and budget allow.  Admission itself (claim + stage)
        is allocation-free, so new requests keep entering ``admitting``
        even after the budget is spent — their chunks run on later
        ticks."""
        finished: List[Request] = []
        # distinguishes "admission failed on an offered request" (a stuck
        # engine if nothing is active) from "the scheduler deferred"
        # (next() -> None — a policy choice, keep ticking)
        self._admission_backoff = False
        self._prefill_stalled = False
        spent = 0
        for slot in list(self.admitting):
            if slot not in self.admitting:
                continue        # re-staged uncached under a new slot id
            cost, fin = self._advance_one(slot, quota, spent, now)
            spent += cost
            if fin is not None:
                finished.append(fin)
        tr = self.tel
        if tr is not None:
            tr.begin("scheduler", queued=len(self.scheduler))
        while len(self.scheduler):
            req = self.scheduler.next(self)
            if req is None:
                break
            slot = self.alloc.claim(req.request_id)
            if slot is None:
                self._admission_backoff = True
                break
            credit, pages = self._prefix_credit(req)
            part = _PartialPrefill(
                req=req, schedule=self._stage_slot(slot, req, credit, pages),
                credit=credit, pos=credit)
            # reserve the first chunk batch BEFORE dequeuing: a pool-dry
            # admission unwinds with the request still queued (retried
            # uncached when a credit was mounted, backed off otherwise)
            upto = self._plan_chunks(part, quota, spent)
            if not self._reserve_chunks(slot, part, upto):
                self._unwind_slot(slot)
                if credit:
                    # the cache must never block an admission an empty
                    # cache would allow — retry uncached (eviction freed
                    # what it could)
                    slot = self.alloc.claim(req.request_id)
                    credit, pages = 0, []
                    part = _PartialPrefill(
                        req=req,
                        schedule=self._stage_slot(slot, req, 0, []))
                    upto = self._plan_chunks(part, quota, spent)
                    if not self._reserve_chunks(slot, part, upto):
                        self._unwind_slot(slot)
                        self._admission_backoff = True
                        break
                else:
                    self._admission_backoff = True
                    break
            self.scheduler.remove(req)
            req.queued_ticks = max(0, self._tick - req._tick_submit - 1)
            self._lat["queued_ticks"].record(req.queued_ticks)
            self.admitting[slot] = part
            if tr is not None:
                tr.request_admitted(req.request_id, slot, part.credit,
                                    len(part.schedule))
            self._progressed = True   # claiming + staging IS progress
            # reset this slot's cursor/recurrent state before any chunk
            # runs (device table row = shared + fresh + forks)
            self.states = _reset_slot(self.states, slot)
            if self._bucketed and part.pos:
                # pin the device cursor at the resume point right away: a
                # credit-mounted partial that executes no chunk this tick
                # still rides the decode step, and an unpinned (zero)
                # cursor would scatter its garbage row into the first
                # SHARED page instead of past the mount (page-aligned
                # credit → the write lands on an unmapped logical page →
                # trash page 0)
                kv = self.states.kv
                self.states = self.states._replace(kv=kv._replace(
                    length=kv.length.at[:, slot].set(part.pos)))
            if self.paged:
                self._mark_tables_dirty()
            cost, fin = self._advance_one(slot, quota, spent, now,
                                          reserved_upto=upto)
            spent += cost
            if fin is not None:
                finished.append(fin)
        if tr is not None:
            tr.end("scheduler")
        return finished

    def cancel(self, request_id: int) -> bool:
        """Abort a request wherever it lives: still queued (dequeue),
        mid-prefill (unwind the slot — nothing is cached; the partial KV
        rows were never validated by a finish), or actively decoding
        (finish now with ``truncated=True``; the generated prefix is
        cached as usual).  Returns False when the id is unknown (already
        finished counts as unknown)."""
        for req in self.scheduler.pending():
            if req.request_id == request_id:
                self.scheduler.remove(req)
                req.truncated = True
                if self.tel is not None:
                    self.tel.request_cancel(request_id, "queued")
                return True
        for slot, part in list(self.admitting.items()):
            if part.req.request_id == request_id:
                del self.admitting[slot]
                self._unwind_slot(slot)
                part.req.truncated = True
                if self.tel is not None:
                    self.tel.request_cancel(request_id, "prefill")
                return True
        for slot, req in list(self.active.items()):
            if req.request_id == request_id:
                req.truncated = True
                self._finish(slot)
                return True
        return False

    def _finish(self, slot: int):
        req = self.active.pop(slot)
        self.counters["finished_requests"] += 1
        if self.tel is not None:
            self.tel.request_finish(
                req.request_id,
                "truncated" if req.truncated else "finish",
                len(req.output))
        if self.prefix is not None:
            # cache the finished sequence: every written KV row is valid
            # (prompt + all-but-the-last generated token have rows), and
            # the index takes references on the page-aligned prefix — the
            # release below then frees only what nothing else holds
            rows = self.alloc.slots[slot].length
            toks = np.concatenate([
                req.prompt,
                np.asarray(  # sync: host — output tokens are host-side python ints
                    req.output[:max(0, rows - len(req.prompt))], np.int32)])
            self.prefix.insert(toks[:rows], self.alloc.held(slot))
        self.alloc.release(slot)
        if self.paged:
            # the freed pages can be reacquired by other slots (or stay
            # cached in the index) any tick — scrub the device row
            self._scrub_slot_device(slot)
        return req

    def step(self) -> List[Request]:
        """One engine tick. Returns requests that finished this tick."""
        # grow in-flight slots' tables for this tick's KV row BEFORE
        # admitting — decoding requests have page priority over new
        # admissions (an admission must never drain the free list out
        # from under a request that only needed one more page).  Slots at
        # max_len hard-stop: decoding past it would clamp the write
        # offset and corrupt the newest rows.  Newly admitted slots are
        # covered through prompt_len + 1 by the admission ensure.
        self._tick += 1
        self._progressed = False
        now = time.perf_counter()
        tr = self.tel
        if tr is not None:
            tr.begin("tick", n=self._tick, active=len(self.active),
                     admitting=len(self.admitting),
                     queued=len(self.scheduler))
        finished: List[Request] = []
        for slot in list(self.active):
            req = self.active[slot]
            if self.alloc.slots[slot].length >= self.cfg.max_len or (
                    self.paged and not self._ensure_pages(
                        slot, self.alloc.slots[slot].length + 1)):
                req.truncated = True
                finished.append(self._finish(slot))
                log.debug("request %d hard-stopped at max_len/page cap",
                          req.request_id)
        if tr is not None:
            tr.begin("prefill_pass")
        finished.extend(self._run_prefills(self._prefill_quota(), now))
        if tr is not None:
            tr.end("prefill_pass")
        if not self.active:
            if tr is not None:
                tr.end("tick")
            return finished
        last = np.zeros((self.cfg.max_batch, 1), np.int32)
        for slot, req in self.active.items():
            last[slot, 0] = req.output[-1]
        sub = self._next_key()
        # the tick's ONE batched block-table upload (replaces the old
        # per-slot jnp.asarray loop over grown slots), then clamp the
        # decode tick's block-table width to the bucketed batch
        # high-water page count: attention (gather or paged kernel) then
        # only walks pages some active row can actually hold, instead of
        # the full pool-capacity table.  Power-of-two buckets bound the
        # decode retraces by log2(pages_per_slot); tables are restored
        # afterwards (the decode step never rewrites them).
        if tr is not None:
            tr.begin("decode_step", batch=len(self.active))
        self._flush_tables("decode")
        last_dev = jnp.asarray(last)  # sync: required — the tick's last-token batch upload
        states_in, full_tables = self.states, None
        if self.paged:
            hw = self._decode_table_width()
            kv = self.states.kv
            full_tables = kv.block_tables
            states_in = self.states._replace(
                kv=kv._replace(block_tables=full_tables[:, :, :hw]))
            if hw not in self._decode_table_buckets:
                self._decode_table_buckets.add(hw)
                self._tune_decode_bucket(last_dev, states_in, sub)
                if tr is not None:
                    # first tick at this table width: attach kernel/plan
                    # provenance (which launch config won the autotune,
                    # and why) to the timeline + trace metadata
                    tr.instant("decode_bucket", cat="plan", table_width=hw,
                               **self._kernel_provenance())
        nxt, new_states = self._jit_decode(self.params, last_dev,
                                           states_in, sub)
        if full_tables is not None:
            kv = new_states.kv
            new_states = new_states._replace(
                kv=kv._replace(block_tables=full_tables))
        self.states = new_states
        self.counters["decode_ticks"] += 1
        if self.admitting and self._bucketed:
            # mid-prefill rows rode this decode tick as inactive batch
            # rows: the step advanced their device cursors past the
            # resume point and scattered one garbage KV row at it.  The
            # garbage is harmless — the next chunk's window rewrites that
            # position (windows always cover the resume point) — but the
            # cursor must be re-pinned to ``pos`` every tick, or an
            # admission idling across several ticks would drift its
            # cursor and scatter garbage ABOVE the resume point, beyond
            # the next chunk's rewrite extent (device-side edit, no
            # transfer).
            kv = self.states.kv
            length = kv.length
            for slot, part in self.admitting.items():
                length = length.at[:, slot].set(part.pos)
            self.states = self.states._replace(
                kv=kv._replace(length=length))
        self._progressed = True
        nxt = np.asarray(nxt)  # sync: required — the tick's one d2h readback (next tokens drive host finish logic)
        if tr is not None:
            tr.end("decode_step")
        for slot in list(self.active):
            req = self.active[slot]
            self._append_token(req, nxt[slot], now)
            self.alloc.slots[slot].length += 1
            done = (len(req.output) >= req.max_new_tokens
                    or (req.eos_id is not None
                        and req.output[-1] == req.eos_id))
            if done:
                finished.append(self._finish(slot))
        if tr is not None:
            tr.end("tick")
        return finished

    def _warmup_decode(self) -> None:
        """Pre-trace the decode step's whole signature set at construction
        (``cfg.warmup="decode"``).  The paged bucket ladder is closed-form
        — the static proof (``serve_static.enumerate_decode_buckets`` /
        ``verify_engine_signatures``) enumerates exactly the clamped
        table-width buckets a live tick can ever present — so warming it
        moves every decode compile off the serving path: steady-state
        ticks never trace.  Warmed traces land in the same jit cache the
        measured-vs-proven cross-check counts, so ``decode_compiles``
        equals the proven ladder up front and a later live retrace still
        trips the budget gate.  Runs outside the tick path (construction
        time), so its transfers are not per-tick sync-contract traffic;
        outputs are discarded and ``self.states`` is untouched (inactive
        rows' scatters land on trash page 0 by design)."""
        sub = self._next_key()
        last = jnp.zeros((self.cfg.max_batch, 1), jnp.int32)
        if not self.paged:
            self._jit_decode(self.params, last, self.states, sub)
            return
        from repro.analysis.serve_static import enumerate_decode_buckets

        kv = self.states.kv
        full_tables = kv.block_tables
        for hw in enumerate_decode_buckets(
                max_len=self.cfg.max_len, page_size=self.cfg.page_size,
                pages_per_slot=self.alloc.pages_per_slot):
            states_in = self.states._replace(
                kv=kv._replace(block_tables=full_tables[:, :, :hw]))
            if hw not in self._decode_table_buckets:
                self._decode_table_buckets.add(hw)
                self._tune_decode_bucket(last, states_in, sub)
            self._jit_decode(self.params, last, states_in, sub)

    def _warmup_prefill(self) -> None:
        """Pre-trace the proven prefill chunk buckets
        (``cfg.warmup="serve"``): same closed-form enumeration the static
        proof checks (``serve_static.enumerate_prefill_buckets``), traced
        against a fresh slot-0 view — ava-identical to every live
        prefill signature, so admission never compiles either.  Outputs
        are discarded; paged writes land on the zeroed (trash-page)
        table of the discarded view copy."""
        from repro.analysis.serve_static import enumerate_prefill_buckets

        view = self._slot_view(0)
        for cb in enumerate_prefill_buckets(
                max_len=self.cfg.max_len,
                prefill_chunk=self.cfg.prefill_chunk,
                bucketed=self._bucketed,
                page_size=self.cfg.page_size if self.paged else None,
                prefix_cache=self.prefix is not None):
            if self._bucketed:
                view = self._set_view_cursor(view, 0)
            self._prefill_buckets.add(cb)
            sub = self._next_key()
            self._jit_prefill_chunk(self.params,
                                    jnp.zeros((1, cb), jnp.int32),
                                    view, jnp.int32(0), sub)

    def _tune_decode_bucket(self, last, states_in, key) -> None:
        """One eager (un-jitted) decode step the first time a table-width
        bucket appears, only where the paged kernel family lowers
        natively: concrete operands let the kernel registry time its
        paged-kernel candidates for this shape *before* the jitted tick
        traces — the trace then bakes the tuned winner instead of the
        default (kernels/ops.py, DESIGN.md §10).  Interpret-mode hosts
        skip this outright — timing interpreted Pallas measures nothing
        real, and the planner routes them to the gather path anyway."""
        from repro.kernels.ops import registry as kernel_registry

        if kernel_registry.interpret_for("paged") or (
                self.decode_plan is not None
                and self.decode_plan.backend != "paged_pallas"):
            return          # gather path / interpret mode: nothing to time
        self._decode_step(self.params, last, states_in, key)

    def _kernel_provenance(self) -> Dict[str, Any]:
        """JSON-safe kernel/plan provenance for trace attribution: the
        planner's chosen backend + reason, the registry's interpret
        decision, and which launch config won each autotuned shape.
        Called only under ``tel is not None`` at bucket-tune rate, never
        on the steady-state tick path."""
        from repro.kernels.ops import registry as kernel_registry

        out: Dict[str, Any] = {
            "backend": (self.decode_plan.backend
                        if self.decode_plan is not None else None),
            "plan_reason": (self.decode_plan.reason
                            if self.decode_plan is not None else None),
            "interpret": kernel_registry.interpret_for("paged"),
        }
        if kernel_registry.decisions:
            out["decisions"] = {
                str(k): {"choice": str(v.get("choice")),
                         "source": v.get("source"),
                         "native": v.get("native")}
                for k, v in kernel_registry.decisions.items()}
        return out

    def _decode_table_width(self) -> int:
        """Bucketed high-water page count across active AND mid-prefill
        slots: the widest block table any row needs for this tick's read
        + one written KV row, rounded up to a power of two (bounds decode
        retraces).  Admitting rows count because their pinned-cursor
        garbage write scatters at ``pos`` — were the clamped table
        narrower than ``pos``'s page, the clamped index would land that
        write on one of the slot's own already-written pages."""
        rows = [self.alloc.slots[s].length for s in self.active]
        # part.pos, not slots[s].length: a credit-mounted partial that has
        # not executed a chunk yet writes its garbage row at pos=credit
        rows += [part.pos for part in self.admitting.values()]
        longest = max(rows) + 1
        return decode_table_width(longest, page_size=self.cfg.page_size,
                                  pages_per_slot=self.alloc.pages_per_slot)

    def run_to_completion(self, max_ticks: int = 10_000,
                          on_tick=None) -> List[Request]:
        """Drive ticks until the engine drains.  ``on_tick(engine,
        finished)`` runs after every tick — the launcher's ``--log-json``
        hook; it must not submit or cancel (reentrancy is untested)."""
        done: List[Request] = []
        for _ in range(max_ticks):
            out = self.step()
            done.extend(out)
            if on_tick is not None:
                on_tick(self, out)
            if (not self.active and not self.admitting
                    and not len(self.scheduler)):
                break
            if (not self.active and not out and not self._progressed
                    and (self._admission_backoff
                         or self._prefill_stalled)):
                # the tick changed nothing: no active slot to free pages,
                # nothing finished, no partial prefill advanced (a
                # partially-prefilled admission advancing IS progress —
                # self._progressed), and an admission failed or a partial
                # stalled on the dry pool — every later tick would be
                # identical, so raise instead of silently burning
                # max_ticks (this state means a leak or an externally
                # held resource; healthy admission always makes progress
                # from an idle engine, since the prefix cache is fully
                # evictable and submit() rejects prompts the pool could
                # never hold).  A scheduler that merely deferred
                # (next() -> None, or a zero prefill quota) keeps
                # ticking: deferral is a policy choice, not a stuck
                # engine.
                head = self.scheduler.next(self)
                head_desc = (f"id={head.request_id}, "
                             f"prompt_len={len(head.prompt)}"
                             if head is not None else "deferred")
                raise RuntimeError(self._dump_on_error(
                    f"engine cannot make progress: {len(self.scheduler)} "
                    f"request(s) queued (head: {head_desc}), "
                    f"{len(self.admitting)} mid-prefill, no active "
                    f"slots, and admission backed off or stalled"
                    + (f" [pages_in_use={self.alloc.pages_in_use}/"
                       f"{self.alloc.num_pages - 1}]" if self.paged else
                       "")))
        self._check_compile_soundness()
        return done

    def _dump_on_error(self, msg: str) -> str:
        """Flight-recorder hook for engine error paths: dump the last K
        events and append the dump path to the error message (telemetry
        off: the message passes through untouched)."""
        if self.tel is None or self.tel.ring is None:
            return msg
        path = dump_flight(self.tel, msg)
        log.error("flight recorder dumped to %s", path)
        return f"{msg} [flight recorder: {path}]"

    def _check_compile_soundness(self) -> None:
        """Measured-vs-proven compile cross-check at drain (the live
        counterpart of ``analysis.serve.cross_check_bench``): a measured
        compile count above the proven retrace budget means the static
        enumeration missed a reachable signature — raise loudly, with
        the flight recorder dumped for forensics."""
        b = self.retrace_budget()
        pm, dm = self.prefill_compiles, self.decode_compiles
        if pm <= b["prefill_proven"] and dm <= b["decode_proven"]:
            return
        raise RuntimeError(self._dump_on_error(
            f"SOUNDNESS BUG: measured compiles exceed the proven retrace "
            f"budget (prefill {pm}/{b['prefill_proven']}, decode "
            f"{dm}/{b['decode_proven']}) — the static enumeration missed "
            f"a reachable trace signature"))


def _reset_slot(states, slot: int):
    """Reset one slot's decode state across all layers.

    Transformer family: zero the (L, b) cursor; KV buffer/pool rows need
    no clearing (validity is cursor-defined; paged tables are rewritten at
    admission).  Hybrid: also zero the slot's mamba ssm/conv carries.
    RWKV: zero the slot's recurrent state rows.
    """
    from repro.models.transformer import LayerState

    if isinstance(states, LayerState):
        kv = states.kv._replace(length=states.kv.length.at[:, slot].set(0))
        ssm = (states.ssm.at[:, slot].set(0)
               if states.ssm is not None else None)
        conv = (states.conv.at[:, slot].set(0)
                if states.conv is not None else None)
        return LayerState(kv=kv, ssm=ssm, conv=conv)
    # recurrent families (rwkv): zero every state leaf's slot row
    return jax.tree.map(lambda x: x.at[:, slot].set(jnp.zeros_like(x[:, slot])),
                        states)
