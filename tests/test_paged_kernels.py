"""Paged decode kernels (kernels/paged.py) + the kernel registry
(kernels/ops.py): interpret-mode parity sweeps against the gather
references, the fused paged backend, trash-page isolation, and the
registry's choice/override plumbing (DESIGN.md §10).

The sweep covers the decode shapes the serve engine actually produces:
GQA groups, sliding windows, ragged per-row cursors, cursors that
straddle a page boundary / land exactly on one / sit at a single token,
and the ``normalize`` flag.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mechanism import (ExecutionPlan, MechanismParams,
                                  PagedLayout, Structural, execute_plan,
                                  get_mechanism)
from repro.kernels import ops as kops, ref as kref
from repro.kernels.paged import (paged_flash_attention_fwd,
                                 paged_flash_inhibitor_fwd)

TOL = dict(rtol=1e-4, atol=1e-5)


def _pool(rng, *, batch, pages_per_slot, page_size, kv_heads, d,
          lengths):
    """A ragged paged pool: per-row non-contiguous physical pages, trash
    page 0 for every unmapped table entry (the engine's layout)."""
    num_pages = batch * pages_per_slot + 1
    kp = rng.normal(size=(num_pages, page_size, kv_heads, d))
    vp = rng.normal(size=(num_pages, page_size, kv_heads, d))
    perm = rng.permutation(np.arange(1, num_pages))
    tables = np.zeros((batch, pages_per_slot), np.int32)
    nxt = 0
    for b, ln in enumerate(lengths):
        used = -(-int(ln) // page_size)
        tables[b, :used] = perm[nxt:nxt + used]
        nxt += used
    return (jnp.asarray(kp.astype(np.float32)),
            jnp.asarray(vp.astype(np.float32)), jnp.asarray(tables),
            jnp.asarray(np.asarray(lengths, np.int32)))


# page_size 8: 13 straddles a boundary, 8 lands exactly on one, 1 is a
# single token, 24 fills three pages
RAGGED_LENGTHS = [13, 8, 1, 24]


@pytest.mark.parametrize("signed", [True, False])
@pytest.mark.parametrize("window", [None, 5])
@pytest.mark.parametrize("normalize", [True, False])
def test_paged_inhibitor_parity_sweep(rng, signed, window, normalize):
    heads, kv_heads, d, ps = 4, 2, 16, 8       # GQA group of 2
    kp, vp, tables, lengths = _pool(
        rng, batch=4, pages_per_slot=4, page_size=ps, kv_heads=kv_heads,
        d=d, lengths=RAGGED_LENGTHS)
    q = jnp.asarray(rng.normal(size=(4, 1, heads, d)).astype(np.float32))
    out = paged_flash_inhibitor_fwd(q, kp, vp, tables, lengths,
                                    signed=signed, normalize=normalize,
                                    window=window, interpret=True)
    ref = kref.paged_flash_inhibitor_ref(q, kp, vp, tables, lengths,
                                         signed=signed, normalize=normalize,
                                         window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


@pytest.mark.parametrize("window", [None, 5])
def test_paged_attention_parity_sweep(rng, window):
    heads, kv_heads, d, ps = 4, 2, 16, 8
    kp, vp, tables, lengths = _pool(
        rng, batch=4, pages_per_slot=4, page_size=ps, kv_heads=kv_heads,
        d=d, lengths=RAGGED_LENGTHS)
    q = jnp.asarray(rng.normal(size=(4, 1, heads, d)).astype(np.float32))
    out = paged_flash_attention_fwd(q, kp, vp, tables, lengths,
                                    window=window, interpret=True)
    ref = kref.paged_flash_attention_ref(q, kp, vp, tables, lengths,
                                         window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


@pytest.mark.parametrize("pps", [1, 2, 3, 4])
def test_pages_per_step_is_semantics_free(rng, pps):
    """Every pages_per_step staging produces the same result — it is a
    launch-configuration knob, not a semantic one."""
    kp, vp, tables, lengths = _pool(
        rng, batch=3, pages_per_slot=4, page_size=8, kv_heads=2, d=16,
        lengths=[13, 8, 32])
    q = jnp.asarray(rng.normal(size=(3, 1, 4, 16)).astype(np.float32))
    base = paged_flash_inhibitor_fwd(q, kp, vp, tables, lengths,
                                     pages_per_step=1, interpret=True)
    out = paged_flash_inhibitor_fwd(q, kp, vp, tables, lengths,
                                    pages_per_step=pps, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("mech", ["inhibitor", "inhibitor_unsigned",
                                  "dotprod"])
def test_paged_pallas_backend_matches_fused_gather(rng, mech):
    """Registry-level parity: the paged_pallas backend ≡ the fused gather
    backend for every registered mechanism, over ragged cursors."""
    kp, vp, tables, lengths = _pool(
        rng, batch=4, pages_per_slot=4, page_size=8, kv_heads=2, d=16,
        lengths=RAGGED_LENGTHS)
    q = jnp.asarray(rng.normal(size=(4, 1, 4, 16)).astype(np.float32))
    m = get_mechanism(mech)
    params = m.make_params(score_scale=None, score_shift=0.5,
                           normalize=True, kv_chunk=64)
    layout = PagedLayout(tables, 8)
    structural = Structural(causal=True, window=None,
                            q_offset=lengths - 1, kv_valid_len=lengths)
    out = execute_plan(ExecutionPlan(mech, "paged_pallas", "test"),
                       q, kp, vp, params=params, structural=structural,
                       paged=layout)
    kj = jnp.arange(tables.shape[1] * 8)[None, :]
    mask = (kj < lengths[:, None])[:, None, None, :]
    ref = execute_plan(ExecutionPlan(mech, "paged", "test"),
                       q, kp, vp, params=params, mask=mask, paged=layout)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_trash_page_garbage_cannot_reach_attendable_positions(rng):
    """Regression (ISSUE 4 satellite): poison the trash page 0 and every
    never-mapped pool page with huge garbage — kernel and gather outputs
    must be unchanged, because those rows sit beyond every cursor."""
    kp, vp, tables, lengths = _pool(
        rng, batch=3, pages_per_slot=4, page_size=8, kv_heads=2, d=16,
        lengths=[13, 8, 1])
    q = jnp.asarray(rng.normal(size=(3, 1, 4, 16)).astype(np.float32))
    mapped = np.unique(np.asarray(tables))
    mapped = mapped[mapped != 0]               # page 0 is never attendable
    poison_rows = np.setdiff1d(np.arange(kp.shape[0]), mapped)
    kp_bad = kp.at[poison_rows].set(1e9)
    vp_bad = vp.at[poison_rows].set(-1e9)
    # also poison the valid pages' tail rows *beyond* each cursor: those
    # slots belong to the row but are past its valid length
    for b, ln in enumerate([13, 8, 1]):
        used = -(-ln // 8)
        last_page = int(np.asarray(tables)[b, used - 1])
        tail = ln - (used - 1) * 8
        if tail < 8:
            kp_bad = kp_bad.at[last_page, tail:].set(1e9)
            vp_bad = vp_bad.at[last_page, tail:].set(-1e9)

    for fwd, kw in ((paged_flash_inhibitor_fwd, dict(signed=True)),
                    (paged_flash_attention_fwd, {})):
        clean = fwd(q, kp, vp, tables, lengths, interpret=True, **kw)
        poisoned = fwd(q, kp_bad, vp_bad, tables, lengths, interpret=True,
                       **kw)
        np.testing.assert_allclose(np.asarray(poisoned), np.asarray(clean),
                                   rtol=1e-6, atol=1e-6)

    # and through the gather backend (mask must exclude every trash row)
    m = get_mechanism("inhibitor")
    params = m.make_params(score_scale=None, score_shift=0.5,
                           normalize=True, kv_chunk=64)
    layout = PagedLayout(tables, 8)
    kj = jnp.arange(tables.shape[1] * 8)[None, :]
    mask = (kj < lengths[:, None])[:, None, None, :]
    plan = ExecutionPlan("inhibitor", "paged", "test")
    clean = execute_plan(plan, q, kp, vp, params=params, mask=mask,
                         paged=layout)
    poisoned = execute_plan(plan, q, kp_bad, vp_bad, params=params,
                            mask=mask, paged=layout)
    np.testing.assert_allclose(np.asarray(poisoned), np.asarray(clean),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# kernel registry (kernels/ops.py)
# ---------------------------------------------------------------------------

def test_registry_single_interpret_decision():
    assert isinstance(kops.registry.interpret, bool)
    # cached: repeated reads return the same object decision
    assert kops.registry.interpret == kops.registry.interpret


def test_registry_caches_choice_per_shape(rng):
    kops.registry.tuned.clear()
    kp, vp, tables, lengths = _pool(
        rng, batch=2, pages_per_slot=2, page_size=8, kv_heads=2, d=16,
        lengths=[5, 9])
    q = jnp.asarray(rng.normal(size=(2, 1, 4, 16)).astype(np.float32))
    kops.paged_flash_inhibitor(q, kp, vp, tables, lengths)
    keys = [k for k in kops.registry.tuned if k[0] == "paged"]
    assert len(keys) == 1
    kops.paged_flash_inhibitor(q, kp, vp, tables, lengths)
    assert len([k for k in kops.registry.tuned if k[0] == "paged"]) == 1


def _spy_choose(monkeypatch):
    """Wrap registry.choose, recording every override it is handed."""
    seen = []
    orig = kops.registry.choose

    def spy(family, shape_key, override=None, timer=None):
        seen.append((family, override))
        return orig(family, shape_key, override, timer)

    monkeypatch.setattr(kops.registry, "choose", spy)
    return seen


def test_kernel_choice_override_wins(rng, monkeypatch):
    """An explicit KernelChoice (e.g. from AttentionConfig.kernel_*) is
    handed to the registry verbatim and produces identical numerics."""
    seen = _spy_choose(monkeypatch)
    kp, vp, tables, lengths = _pool(
        rng, batch=2, pages_per_slot=4, page_size=8, kv_heads=2, d=16,
        lengths=[13, 30])
    q = jnp.asarray(rng.normal(size=(2, 1, 4, 16)).astype(np.float32))
    base = kops.paged_flash_inhibitor(q, kp, vp, tables, lengths)
    for pps in (1, 2):
        out = kops.paged_flash_inhibitor(
            q, kp, vp, tables, lengths,
            choice=kops.KernelChoice(pages_per_step=pps))
        np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                   rtol=1e-6, atol=1e-6)
    overrides = [o for _, o in seen if o is not None]
    assert [o.pages_per_step for o in overrides] == [1, 2]


def test_attention_config_kernel_override_reaches_registry(rng,
                                                           monkeypatch):
    """AttentionConfig.kernel_* fields flow through MechanismParams into
    the kernel registry (block sizes are config, not module constants) —
    asserted on the override the registry actually receives, since block
    sizes are numerics-invariant launch knobs."""
    from repro.core.attention import (AttentionConfig, apply_attention,
                                      init_attention)
    from repro.nn.module import unbox

    seen = _spy_choose(monkeypatch)
    x = jnp.asarray(rng.normal(size=(2, 16, 32)).astype(np.float32))
    outs = []
    for bq in (None, 8):
        cfg = AttentionConfig(mechanism="inhibitor", num_heads=4,
                              num_kv_heads=2, head_dim=8, backend="pallas",
                              kernel_block_q=bq, kernel_block_k=8,
                              kernel_sub_k=4)
        params = unbox(init_attention(jax.random.PRNGKey(0), cfg, 32))
        y, _ = apply_attention(params, cfg, x)
        outs.append(np.asarray(y))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-4, atol=1e-5)
    overrides = [o for fam, o in seen if fam == "inhibitor"]
    assert [ (o.block_q, o.block_k, o.sub_k) for o in overrides ] \
        == [(None, 8, 4), (8, 8, 4)]


def test_kernel_choice_merge_semantics():
    base = kops.KernelChoice(64, 128, 16, 4)
    partial = kops.KernelChoice(block_k=256)
    merged = partial.merge_onto(base)
    assert dataclasses.astuple(merged) == (64, 256, 16, 4)
    assert kops.KernelChoice().empty
    assert not partial.empty


def test_shared_pages_across_rows_read_identically(rng):
    """Prefix-cache invariant (DESIGN.md §11): two rows whose block
    tables reference the *same* physical pages (a mounted shared prefix)
    must read them identically — the paged kernels and the gather
    backend tolerate multiply-referenced table entries, because a table
    entry is just an index into the pool.

    Construction: rows 0 and 1 share their first two physical pages
    (16 tokens of common prefix) and diverge afterwards; row 2 is
    unrelated.  The check is against a dense per-row gather of each
    row's logical view — if any path special-cased "pages are disjoint",
    the shared rows would read garbage.
    """
    heads, kv_heads, d, ps = 4, 2, 16, 8
    batch, pages_per_slot = 3, 4
    num_pages = batch * pages_per_slot + 1
    kp = rng.normal(size=(num_pages, ps, kv_heads, d)).astype(np.float32)
    vp = rng.normal(size=(num_pages, ps, kv_heads, d)).astype(np.float32)
    lengths = np.asarray([21, 18, 13], np.int32)
    tables = np.zeros((batch, pages_per_slot), np.int32)
    tables[0, :3] = [1, 2, 3]       # rows 0/1 share physical pages 1, 2
    tables[1, :3] = [1, 2, 4]       # (the mounted prefix), then diverge
    tables[2, :2] = [5, 6]
    kp, vp = jnp.asarray(kp), jnp.asarray(vp)
    tables_j, lengths_j = jnp.asarray(tables), jnp.asarray(lengths)
    q = jnp.asarray(rng.normal(size=(batch, 1, heads, d)).astype(np.float32))

    # dense oracle: gather each row's logical view and run the reference
    def dense_view(pool):
        arr = np.asarray(pool)
        out = np.stack([arr[tables[b]].reshape(-1, kv_heads, d)
                        for b in range(batch)])
        return jnp.asarray(out)

    kd, vd = dense_view(kp), dense_view(vp)
    kj = jnp.arange(pages_per_slot * ps)[None, :]
    mask = (kj < lengths_j[:, None])[:, None, None, :]

    m = get_mechanism("inhibitor")
    params = m.make_params(score_scale=None, score_shift=0.5,
                           normalize=True, kv_chunk=64)
    oracle = execute_plan(ExecutionPlan("inhibitor", "fused", "test"),
                          q, kd, vd, params=params, mask=mask)

    layout = PagedLayout(tables_j, ps)
    structural = Structural(causal=True, window=None,
                            q_offset=lengths_j - 1, kv_valid_len=lengths_j)
    out_kernel = execute_plan(
        ExecutionPlan("inhibitor", "paged_pallas", "test"),
        q, kp, vp, params=params, structural=structural, paged=layout)
    out_gather = execute_plan(
        ExecutionPlan("inhibitor", "paged", "test"),
        q, kp, vp, params=params, mask=mask, paged=layout)
    np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(oracle),
                               **TOL)
    np.testing.assert_allclose(np.asarray(out_gather), np.asarray(oracle),
                               **TOL)


def test_native_platform_declarations():
    """Every kernel family declares where its Pallas body lowers
    natively; all four are TPU-only today (scalar-prefetch grids have no
    Triton equivalent) — a future GPU body flips one declaration."""
    assert set(kops.NATIVE_PLATFORMS) == {"inhibitor", "flash", "paged",
                                          "wkv6"}
    for plats in kops.NATIVE_PLATFORMS.values():
        assert "tpu" in plats


def test_interpret_for_tracks_family_declaration(monkeypatch):
    """interpret_for is per-family and platform-derived: native on TPU,
    interpret elsewhere; the _interpret test escape hatch overrides every
    family at once."""
    monkeypatch.setattr(kops.registry, "_interpret", None)
    monkeypatch.setattr(kops.registry, "_platform", "tpu")
    assert not kops.registry.interpret_for("paged")
    assert not kops.registry.interpret
    monkeypatch.setattr(kops.registry, "_platform", "cuda")
    assert kops.registry.interpret_for("paged")     # no Triton body yet
    assert kops.registry.interpret
    monkeypatch.setattr(kops.registry, "_interpret", False)
    assert not kops.registry.interpret_for("paged")


def test_choose_records_decision_provenance():
    """registry.decisions records which launch config won and why:
    trace-time resolutions stay unpinned, concrete resolutions record
    timed/default-interpret by platform, overrides always win."""
    r = kops.KernelRegistry()
    r._platform = "cpu"
    key = ("probe", 4, 1, 4, 2, 16)
    full = ("paged",) + key

    got = r.choose("paged", key)
    assert r.decisions[full]["source"] == "default-trace"
    assert full not in r.tuned          # trace-time never pins the cache

    got = r.choose("paged", key, timer=lambda c: 0.0)
    d = r.decisions[full]
    assert d["source"] == "default-interpret"       # cpu: nothing to time
    assert d["platform"] == "cpu" and d["native"] is False
    assert full in r.tuned

    ov = kops.KernelChoice(pages_per_step=2)
    got = r.choose("paged", key, override=ov)
    assert got.pages_per_step == 2
    assert r.decisions[full]["source"] == "override"

    # native platform: the timer actually ranks candidates and records
    # a timed decision with the costmodel priors alongside
    rt = kops.KernelRegistry()
    rt._platform = "tpu"
    rt.choose("paged", key, timer=lambda c: float(c.pages_per_step or 1))
    dt = rt.decisions[("paged",) + key]
    assert dt["source"] == "timed" and dt["native"] is True
    assert ("paged",) + key in rt.priors
