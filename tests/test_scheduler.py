"""Pluggable request schedulers (serve.scheduler): policy ordering at
the protocol level and through the engine, plus the engine satellites
that ride the same subsystem — per-token streaming callbacks, submit
validation, and the run_to_completion no-progress guard.

Shared fixtures (``serve_model``, ``greedy_ref``) live in conftest.py.
"""

import numpy as np
import pytest

from repro.serve.engine import Engine, EngineConfig, Request
from repro.serve.scheduler import (FIFOScheduler, PrefixAffinityScheduler,
                                   PriorityScheduler, Scheduler,
                                   make_scheduler)


def _req(i, prompt=(1, 2, 3), priority=0):
    r = Request(i, np.asarray(prompt, np.int32), priority=priority)
    r.arrival = i
    return r


# ---------------------------------------------------------------------------
# Policy units (no engine)
# ---------------------------------------------------------------------------

def test_fifo_policy_is_arrival_order():
    s = FIFOScheduler()
    reqs = [_req(i) for i in range(3)]
    for r in reqs:
        s.add(r)
    assert len(s) == 3 and s.pending() == reqs
    order = []
    while len(s):
        r = s.next(None)
        assert r is s.next(None)           # next() peeks, no removal
        s.remove(r)
        order.append(r.request_id)
    assert order == [0, 1, 2]
    assert s.next(None) is None


def test_priority_policy_orders_by_priority_then_arrival():
    s = PriorityScheduler()
    for i, prio in enumerate((0, 5, 1, 5)):
        s.add(_req(i, priority=prio))
    order = []
    while len(s):
        r = s.next(None)
        s.remove(r)
        order.append(r.request_id)
    assert order == [1, 3, 2, 0]           # 5 (fifo within), then 1, 0


def test_prefix_affinity_picks_the_resident_prefix_request():
    class _FakeRoot:
        children = {(42,) * 8: object()}   # non-empty: cache is warm

    class _FakeIndex:
        root = _FakeRoot()

        def match(self, prompt, touch=True):
            assert touch is False          # probes must not touch LRU
            n = 8 if prompt[0] == 42 else 0
            return n, []

    class _FakeEngine:
        prefix = _FakeIndex()

    s = PrefixAffinityScheduler()
    cold = _req(0, prompt=[7] * 8)
    warm = _req(1, prompt=[42] * 8)
    s.add(cold)
    s.add(warm)
    assert s.next(_FakeEngine()) is warm   # resident prefix wins
    # without an index the policy degrades to FIFO
    class _NoIndex:
        prefix = None
    assert s.next(_NoIndex()) is cold


def test_make_scheduler_registry():
    assert isinstance(make_scheduler("fifo"), FIFOScheduler)
    assert isinstance(make_scheduler("priority"), PriorityScheduler)
    assert isinstance(make_scheduler("prefix"), PrefixAffinityScheduler)
    assert isinstance(make_scheduler(None), FIFOScheduler)
    inst = PriorityScheduler()
    assert make_scheduler(inst) is inst    # instances pass through
    assert isinstance(inst, Scheduler)     # protocol conformance
    with pytest.raises(ValueError, match="unknown scheduler"):
        make_scheduler("nope")
    with pytest.raises(TypeError):
        make_scheduler(42)


# ---------------------------------------------------------------------------
# Engine-level policy behavior
# ---------------------------------------------------------------------------

def test_engine_priority_scheduler_admits_high_priority_first(
        rng, serve_model, greedy_ref):
    """With one slot, admission order == finish order: priorities jump
    the queue while outputs stay exactly the per-request references."""
    cfg, api, params = serve_model
    eng = Engine(api, params, EngineConfig(max_batch=1, max_len=64,
                                           scheduler="priority",
                                           prefill_chunk=8))
    prompts = {i: rng.integers(0, cfg.vocab_size, (5 + i,)).astype(np.int32)
               for i in range(3)}
    for i, prio in ((0, 0), (1, 9), (2, 4)):
        eng.submit(Request(i, prompts[i], max_new_tokens=3, priority=prio))
    done = eng.run_to_completion()
    assert [r.request_id for r in done] == [1, 2, 0]
    for r in done:
        assert r.output == greedy_ref(prompts[r.request_id], 3)


def test_engine_prefix_affinity_prefers_resident_prefix(rng, serve_model):
    """After caching prompt A's prefix, a queued A-prefixed request is
    admitted ahead of an earlier-arrived cold request."""
    cfg, api, params = serve_model
    eng = Engine(api, params, EngineConfig(max_batch=1, max_len=64,
                                           page_size=8, prefill_chunk=8,
                                           scheduler="prefix"))
    warm_prefix = rng.integers(0, cfg.vocab_size, (16,)).astype(np.int32)
    eng.submit(Request(0, warm_prefix, max_new_tokens=1))
    eng.run_to_completion()                # prefix now resident

    cold = rng.integers(0, cfg.vocab_size, (16,)).astype(np.int32)
    warm = np.concatenate([warm_prefix, rng.integers(
        0, cfg.vocab_size, (4,)).astype(np.int32)])
    eng.submit(Request(1, cold, max_new_tokens=2))     # arrives first
    eng.submit(Request(2, warm, max_new_tokens=2))
    done = eng.run_to_completion()
    assert [r.request_id for r in done] == [2, 1]      # warm jumped
    assert eng.stats()["prefix_hit_tokens"] == 16
    assert eng.stats()["scheduler"] == "prefix"


def test_engine_fifo_unchanged_default(rng, serve_model):
    cfg, api, params = serve_model
    eng = Engine(api, params, EngineConfig(max_batch=1, max_len=64))
    for i in range(3):
        eng.submit(Request(i, rng.integers(0, cfg.vocab_size,
                                           (4,)).astype(np.int32),
                           max_new_tokens=2, priority=9 - i))
    done = eng.run_to_completion()
    assert [r.request_id for r in done] == [0, 1, 2]   # priority ignored


# ---------------------------------------------------------------------------
# Satellites: streaming callbacks, submit validation, no-progress guard
# ---------------------------------------------------------------------------

def test_on_token_streams_every_token_in_order(rng, serve_model):
    cfg, api, params = serve_model
    eng = Engine(api, params, EngineConfig(max_batch=2, max_len=64,
                                           prefill_chunk=8))
    got = []
    prompt = rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)
    eng.submit(Request(0, prompt, max_new_tokens=5,
                       on_token=lambda r, t: got.append((r.request_id, t))))
    done = eng.run_to_completion()
    assert got == [(0, t) for t in done[0].output]
    assert len(got) == 5                   # prefill token included


def test_on_token_exceptions_do_not_kill_the_engine(rng, serve_model):
    cfg, api, params = serve_model
    eng = Engine(api, params, EngineConfig(max_batch=1, max_len=64))
    prompt = rng.integers(0, cfg.vocab_size, (4,)).astype(np.int32)

    def boom(r, t):
        raise RuntimeError("stream consumer died")

    eng.submit(Request(0, prompt, max_new_tokens=3, on_token=boom))
    done = eng.run_to_completion()
    assert len(done) == 1 and len(done[0].output) == 3


def test_submit_rejects_float_and_multidim_prompts(rng, serve_model):
    cfg, api, params = serve_model
    eng = Engine(api, params, EngineConfig(max_batch=1, max_len=64))
    with pytest.raises(ValueError, match="integer"):
        eng.submit(Request(0, np.asarray([1.5, 2.5], np.float32)))
    with pytest.raises(ValueError, match="1-D"):
        eng.submit(Request(1, np.ones((2, 3), np.int32)))
    with pytest.raises(ValueError, match="1-D"):
        eng.submit(Request(2, np.int32(7)))            # 0-D scalar
    # plain python int lists are fine (asarray -> integer dtype)
    eng.submit(Request(3, np.asarray([1, 2, 3])))
    assert len(eng.queue) == 1


def test_submit_copies_prompt_defensively(rng, serve_model, greedy_ref):
    """Caller-side mutation after submit must not corrupt the queued
    prompt (the engine owns its copy)."""
    cfg, api, params = serve_model
    eng = Engine(api, params, EngineConfig(max_batch=1, max_len=64,
                                           prefill_chunk=8))
    prompt = rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)
    ref = greedy_ref(prompt.copy(), 3)
    eng.submit(Request(0, prompt, max_new_tokens=3))
    prompt[:] = 0                           # caller scribbles over it
    done = eng.run_to_completion()
    assert done[0].output == ref


def test_run_to_completion_raises_on_no_progress(rng, serve_model):
    """Satellite: a queued request that can never be admitted (here: all
    slots leaked outside the engine) must raise a descriptive error
    instead of silently burning max_ticks."""
    cfg, api, params = serve_model
    eng = Engine(api, params, EngineConfig(max_batch=2, max_len=64))
    # simulate a leak: something outside the engine holds every slot
    assert eng.alloc.claim(990) is not None
    assert eng.alloc.claim(991) is not None
    eng.submit(Request(0, rng.integers(0, cfg.vocab_size,
                                       (4,)).astype(np.int32)))
    with pytest.raises(RuntimeError, match="cannot make progress"):
        eng.run_to_completion()


def test_deferring_scheduler_keeps_ticking_without_no_progress_error(
        rng, serve_model, greedy_ref):
    """A custom policy may defer admission (next() -> None) while
    requests are queued — that is a scheduling choice, not a stuck
    engine, so run_to_completion must keep ticking instead of raising."""
    cfg, api, params = serve_model

    class Deferring(FIFOScheduler):
        name = "deferring"

        def __init__(self):
            super().__init__()
            self.probes = 0

        def next(self, engine):
            self.probes += 1
            if self.probes < 3:
                return None                # batch up before admitting
            return super().next(engine)

    eng = Engine(api, params, EngineConfig(max_batch=1, max_len=64,
                                           scheduler=Deferring()))
    prompt = rng.integers(0, cfg.vocab_size, (4,)).astype(np.int32)
    eng.submit(Request(0, prompt, max_new_tokens=3))
    done = eng.run_to_completion()
    assert done[0].output == greedy_ref(prompt, 3)
    assert eng.scheduler.probes >= 3
