"""Whole-model fused paged gather (models/transformer.lm_step, DESIGN.md
§14): the decode step hoists ONE all-layer page gather out of the layer
scan and scatters the appended rows back once.  These tests pin

  * output parity with the per-layer ``paged`` escape hatch (a forced
    ``AttentionConfig.backend="paged"`` keeps the old per-layer path) —
    over GQA, sliding windows, ragged cursors, multiply-referenced
    (shared / CoW) pages, and inactive trash-page rows,
  * pool-scatter parity: both paths write identical KV rows back,
  * the ``fused_gather_applies`` planner predicate's gating conditions,
  * the static-cost win the fusion exists for: strictly fewer decode
    HBM bytes than the per-layer gather under the analysis cost model
    (repro.analysis.costmodel), which is what ANALYSIS_serve.json gates.

Model fixture (``serve_model``) lives in conftest.py.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as tfm

TOL = dict(rtol=1e-6, atol=1e-6)


def _with_backend(cfg, backend):
    return dataclasses.replace(
        cfg, attention=dataclasses.replace(cfg.attention, backend=backend))


def _paged_states(cfg, rng, *, max_len, page_size, lengths, tables=None,
                  grow=2):
    """Decode-ready paged states: random pool contents, ragged per-row
    cursors, permuted physical pages (trash page 0 left unmapped) —
    the layout the serve engine hands lm_step mid-stream.  Active rows
    get capacity for ``grow`` appended tokens, mirroring the engine's
    ``ensure`` call before every decode tick (only inactive length-0
    slots ever scatter to the trash page)."""
    batch = len(lengths)
    states = tfm.init_states(cfg, batch, max_len, paged=True,
                             page_size=page_size)
    kv = states.kv
    L = kv.k.shape[0]
    if tables is None:
        pages_per_slot = kv.block_tables.shape[2]
        perm = rng.permutation(np.arange(1, kv.k.shape[1]))
        tables = np.zeros((batch, pages_per_slot), np.int32)
        nxt = 0
        for b, ln in enumerate(lengths):
            used = -(-(int(ln) + grow) // page_size) if ln else 0
            tables[b, :used] = perm[nxt:nxt + used]
            nxt += used
    k = jnp.asarray(rng.normal(size=kv.k.shape).astype(np.float32))
    v = jnp.asarray(rng.normal(size=kv.v.shape).astype(np.float32))
    kv = tfm.PagedKVCache(
        k, v,
        jnp.broadcast_to(jnp.asarray(tables)[None], (L,) + tables.shape),
        jnp.broadcast_to(jnp.asarray(lengths, dtype=jnp.int32)[None],
                         (L, batch)))
    return states._replace(kv=kv), np.asarray(tables)


# page_size 8: 13 straddles a page boundary, 8 lands exactly on one,
# 1 is a single token, 0 is an inactive slot parked on trash page 0
RAGGED_LENGTHS = [13, 8, 1, 0]


@pytest.mark.parametrize("window", [None, 5])
@pytest.mark.parametrize("n_q", [1, 2])
def test_fused_gather_matches_per_layer_paged(rng, serve_model, window,
                                              n_q):
    """The hoisted all-layer gather is numerically interchangeable with
    the per-layer ``paged`` backend: same logits, same pool writeback —
    GQA (4 heads over 2 KV heads), optional sliding window, ragged
    cursors including an inactive trash-page row."""
    cfg, api, params = serve_model
    cfg = dataclasses.replace(cfg, attention=dataclasses.replace(
        cfg.attention, sliding_window=window))
    states, _ = _paged_states(cfg, rng, max_len=32, page_size=8,
                              lengths=RAGGED_LENGTHS)
    tokens = jnp.asarray(rng.integers(
        0, cfg.vocab_size, (len(RAGGED_LENGTHS), n_q)).astype(np.int32))

    assert tfm.fused_gather_applies(cfg, states.kv, n_q)
    logits_f, st_f = tfm.lm_step(params, cfg, tokens, states)
    # escape hatch: a forced backend keeps the per-layer path
    per_layer = _with_backend(cfg, "paged")
    assert not tfm.fused_gather_applies(per_layer, states.kv, n_q)
    logits_p, st_p = tfm.lm_step(params, per_layer, tokens, states)

    # logits parity on *active* rows; the inactive slot's output is a
    # don't-care both paths compute from trash-page garbage, and its
    # trash-page scatter (pool page 0) is order-dependent by design
    active = [b for b, ln in enumerate(RAGGED_LENGTHS) if ln]
    np.testing.assert_allclose(np.asarray(logits_f)[active],
                               np.asarray(logits_p)[active], **TOL)
    np.testing.assert_allclose(np.asarray(st_f.kv.k)[:, 1:],
                               np.asarray(st_p.kv.k)[:, 1:], **TOL)
    np.testing.assert_allclose(np.asarray(st_f.kv.v)[:, 1:],
                               np.asarray(st_p.kv.v)[:, 1:], **TOL)
    np.testing.assert_array_equal(np.asarray(st_f.kv.length),
                                  np.asarray(st_p.kv.length))


def test_fused_gather_shared_cow_pages(rng, serve_model):
    """Prefix-cache layout (DESIGN.md §11): rows 0 and 1 share their
    first two physical pages (a mounted common prefix) and diverge after
    — the fused gather tolerates multiply-referenced table entries
    exactly like the per-layer gather (a table entry is just a pool
    index), and the writeback never touches the shared prefix pages."""
    cfg, api, params = serve_model
    tables = np.zeros((3, 4), np.int32)
    tables[0, :3] = [1, 2, 3]       # rows 0/1 share physical pages 1, 2
    tables[1, :3] = [1, 2, 4]
    tables[2, :2] = [5, 6]
    lengths = [21, 18, 13]
    states, _ = _paged_states(cfg, rng, max_len=32, page_size=8,
                              lengths=lengths, tables=tables)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                      (3, 1)).astype(np.int32))

    logits_f, st_f = tfm.lm_step(params, cfg, tokens, states)
    logits_p, st_p = tfm.lm_step(params, _with_backend(cfg, "paged"),
                                 tokens, states)
    np.testing.assert_allclose(np.asarray(logits_f), np.asarray(logits_p),
                               **TOL)
    np.testing.assert_allclose(np.asarray(st_f.kv.k), np.asarray(st_p.kv.k),
                               **TOL)
    # the appended rows land past each cursor; the shared prefix pages
    # (1, 2) hold only positions < min(cursors) and must be untouched
    np.testing.assert_array_equal(np.asarray(st_f.kv.k[:, 1:3]),
                                  np.asarray(states.kv.k[:, 1:3]))


def test_fused_gather_trash_page_isolation(rng, serve_model):
    """Poisoning trash page 0 and every never-mapped pool page with huge
    garbage leaves the fused-path logits of active rows unchanged: the
    hoisted gather maps unmapped table entries to the trash page, whose
    rows sit beyond every cursor's mask."""
    cfg, api, params = serve_model
    lengths = [13, 8, 1, 0]
    states, tables = _paged_states(cfg, rng, max_len=32, page_size=8,
                                   lengths=lengths)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                      (4, 1)).astype(np.int32))
    logits_clean, _ = tfm.lm_step(params, cfg, tokens, states)

    mapped = np.unique(tables)
    mapped = mapped[mapped != 0]
    poison = np.setdiff1d(np.arange(states.kv.k.shape[1]), mapped)
    kv = states.kv
    poisoned = states._replace(kv=tfm.PagedKVCache(
        kv.k.at[:, poison].set(1e9), kv.v.at[:, poison].set(-1e9),
        kv.block_tables, kv.length))
    logits_bad, _ = tfm.lm_step(params, cfg, tokens, poisoned)
    # rows 0..2 are active and must not see the garbage; row 3 is the
    # inactive slot whose own (don't-care) output is excluded
    np.testing.assert_allclose(np.asarray(logits_bad)[:3],
                               np.asarray(logits_clean)[:3], **TOL)


def test_fused_gather_applies_gating(rng, serve_model):
    """The predicate fires only for the unforced paged decode plan: a
    forced backend, the use_kernel shim, or a contiguous cache all keep
    the per-layer path."""
    cfg, api, params = serve_model
    states, _ = _paged_states(cfg, rng, max_len=32, page_size=8,
                              lengths=[5, 3])
    assert tfm.fused_gather_applies(cfg, states.kv, 1)
    assert not tfm.fused_gather_applies(_with_backend(cfg, "paged"),
                                        states.kv, 1)
    assert not tfm.fused_gather_applies(_with_backend(cfg, "fused"),
                                        states.kv, 1)
    shim = dataclasses.replace(cfg, attention=dataclasses.replace(
        cfg.attention, use_kernel=True))
    assert not tfm.fused_gather_applies(shim, states.kv, 1)
    contiguous = tfm.init_states(cfg, 2, 32, per_slot=True)
    assert not tfm.fused_gather_applies(cfg, contiguous.kv, 1)


def test_fused_gather_drops_static_decode_bytes(rng, serve_model):
    """The reason the fusion exists: under the analysis cost model the
    fused decode step moves strictly fewer HBM bytes than the per-layer
    gather (one table walk instead of num_layers), which is the drop
    ANALYSIS_serve.json's static decode roofline records vs PR 7."""
    from repro.analysis.costmodel import jaxpr_costs

    cfg, api, params = serve_model
    states, _ = _paged_states(cfg, rng, max_len=32, page_size=8,
                              lengths=[13, 8, 1, 0])
    tokens = jnp.zeros((4, 1), jnp.int32)

    def bytes_for(run_cfg):
        jx = jax.make_jaxpr(
            lambda p, t, s: tfm.lm_step(p, run_cfg, t, s))(
                params, tokens, states)
        return jaxpr_costs(jx).hbm_bytes

    fused = bytes_for(cfg)
    per_layer = bytes_for(_with_backend(cfg, "paged"))
    assert fused < per_layer
