import os
import sys

# src-layout import path (so `PYTHONPATH=src pytest tests/` and bare
# `pytest` both work)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def serve_model():
    """Tiny transformer shared by the serving test modules."""
    import jax

    from repro.configs import get_config
    from repro.models.registry import get_model
    from repro.nn.module import unbox

    cfg = get_config("smollm-135m").reduced(num_layers=2, d_model=32,
                                            d_ff=64, vocab_size=128)
    api = get_model(cfg)
    params = unbox(api.init(jax.random.PRNGKey(0)))
    return cfg, api, params


@pytest.fixture
def greedy_ref(serve_model):
    """Sequential greedy decode oracle: ref(prompt, n_new, max_len=64)."""
    import jax.numpy as jnp

    from repro.models import transformer as tfm

    cfg, api, params = serve_model

    def ref(prompt, n_new, max_len=64):
        states = tfm.init_states(cfg, 1, max_len, per_slot=True)
        logits, states = api.step(params, jnp.asarray(prompt)[None],
                                  states, None)
        out = [int(jnp.argmax(logits[0, -1]))]
        while len(out) < n_new:
            logits, states = api.step(
                params, jnp.asarray([[out[-1]]], dtype=jnp.int32), states,
                None)
            out.append(int(jnp.argmax(logits[0, -1])))
        return out

    return ref
