"""Kernel registry: jit'd public wrappers for the Pallas kernels, with one
host-platform decision, per-shape block-size autotuning, and recompute-based
custom VJPs so the training-path kernels are usable under autodiff.

Registry responsibilities (DESIGN.md §10, §14):

  * **One interpret decision per kernel family.**  Each kernel module
    declares the platforms its Pallas body lowers natively on
    (``LOWERS_ON`` → :data:`NATIVE_PLATFORMS`); ``registry.
    interpret_for(family)`` is the per-family decision against the
    cached host platform (non-native hosts run the body as XLA ops in
    ``interpret=True`` mode) — call sites no longer carry their own
    ``not _on_tpu()`` checks, and a family that grows, say, a Triton
    lowering flips to native GPU dispatch by declaration alone.  The
    legacy process-wide ``registry.interpret`` remains as the
    "any-platform-but-TPU" view (today all families declare exactly
    ``("tpu",)``, so the two agree).
  * **Per-shape tuning.**  Every wrapper resolves a :class:`KernelChoice`
    — ``(block_q, block_k, sub_k, pages_per_step)`` — through
    ``registry.choose``: an explicit override (from
    ``AttentionConfig.kernel_*``) wins; otherwise the cached per-shape
    selection is used.  On a *native* platform for the family with
    *concrete* operands (an eager warmup call, e.g.
    ``benchmarks/serve_bench.py``'s un-jitted first tick) the candidate
    set is timed once and the winner cached; a jit trace resolves to
    the default *without* pinning the cache (so a later eager call can
    still tune), and interpret mode caches the default — timing a
    traced or interpreted call would measure nothing real.  Every
    resolution is recorded in ``registry.decisions`` (winner + source +
    platform + native flag) so benches and the planner can report which
    backend won and why.
  * **Kernel families.**  ``flash_inhibitor`` / ``flash_attention``
    (training prefill; custom VJP via the jnp references),
    ``*_cached`` variants carrying per-row ``q_offset`` /
    ``kv_valid_len`` decode cursors (inference-only — no VJP), the
    block-table-native ``paged_*`` decode kernels, and the RWKV6 WKV
    chunk kernel.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flash as kflash
from repro.kernels import inhibitor as kinhibitor
from repro.kernels import paged as kpaged
from repro.kernels import ref as kref
from repro.kernels import rwkv6 as krwkv6
from repro.kernels.flash import flash_attention_fwd
from repro.kernels.inhibitor import flash_inhibitor_fwd
from repro.kernels.paged import (paged_flash_attention_fwd,
                                 paged_flash_inhibitor_fwd)
from repro.kernels.rwkv6 import wkv6_chunked


def _host_platform() -> str:
    try:
        return jax.devices()[0].platform
    except RuntimeError:
        return "cpu"


def _on_tpu() -> bool:
    return _host_platform() == "tpu"


#: Per-family native-lowering platforms, assembled from the kernel
#: modules' own ``LOWERS_ON`` declarations — the single source of truth
#: for "would this Pallas body compile here, or only interpret?".  The
#: registry keys the timed-autotune gate and the wrappers' ``interpret``
#: flag on this, and the planner (core.mechanism.kernel_native) keys
#: kernel eligibility on it, so an interpret-mode kernel can never be
#: ranked above an XLA gather path by accident of platform checks
#: scattered across call sites.
NATIVE_PLATFORMS: Dict[str, Tuple[str, ...]] = {
    "inhibitor": tuple(kinhibitor.LOWERS_ON),
    "flash": tuple(kflash.LOWERS_ON),
    "paged": tuple(kpaged.LOWERS_ON),
    "wkv6": tuple(krwkv6.LOWERS_ON),
}


# ---------------------------------------------------------------------------
# KernelChoice + registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KernelChoice:
    """Block-size selection for one kernel launch.  ``None`` fields fall
    back to the tuned/default value — a partial override (say, just
    ``block_k``) leaves the rest to the registry.  Hashable, so it rides
    through ``jax.custom_vjp`` nondiff argnums."""
    block_q: Optional[int] = None
    block_k: Optional[int] = None
    sub_k: Optional[int] = None
    pages_per_step: Optional[int] = None

    def merge_onto(self, base: "KernelChoice") -> "KernelChoice":
        return KernelChoice(
            self.block_q if self.block_q is not None else base.block_q,
            self.block_k if self.block_k is not None else base.block_k,
            self.sub_k if self.sub_k is not None else base.sub_k,
            (self.pages_per_step if self.pages_per_step is not None
             else base.pages_per_step))

    @property
    def empty(self) -> bool:
        return self == KernelChoice()


#: Candidate grids per kernel family — first entry is the default.
CANDIDATES: Dict[str, Tuple[KernelChoice, ...]] = {
    "inhibitor": (
        KernelChoice(64, 128, 16), KernelChoice(32, 128, 16),
        KernelChoice(128, 128, 16), KernelChoice(64, 256, 32),
        KernelChoice(64, 128, 8),
    ),
    "flash": (
        KernelChoice(64, 128), KernelChoice(32, 128),
        KernelChoice(128, 128), KernelChoice(64, 256),
    ),
    "paged": (
        KernelChoice(pages_per_step=4), KernelChoice(pages_per_step=1),
        KernelChoice(pages_per_step=2), KernelChoice(pages_per_step=8),
    ),
}


class KernelRegistry:
    """Process-wide kernel dispatch state: the cached host platform, the
    per-family interpret decision, and the per-(family, shape) tuned
    :class:`KernelChoice` cache."""

    def __init__(self):
        # test escape hatch: monkeypatching ``_interpret`` to a bool
        # overrides *every* family's decision (pretend-TPU in tests)
        self._interpret: Optional[bool] = None
        self._platform: Optional[str] = None
        self.tuned: Dict[tuple, KernelChoice] = {}
        # static cost-model ranking per tuned shape (costmodel priors):
        # [(KernelChoice, prior_seconds), ...] cheapest-first, recorded
        # whenever a timed tune runs — introspection for benches/tests
        self.priors: Dict[tuple, list] = {}
        # (family,) + shape_key -> {"choice", "source", "platform",
        # "native"}: which launch config won the last resolution and why
        # ("override" | "timed" | "default-interpret" | "default-trace")
        self.decisions: Dict[tuple, dict] = {}

    @property
    def platform(self) -> str:
        """Host platform, resolved once per process (``reset`` re-probes)."""
        if self._platform is None:
            self._platform = _host_platform()
        return self._platform

    @property
    def interpret(self) -> bool:
        """Legacy process-wide view: True anywhere the TPU-era kernels
        would interpret (i.e. any non-TPU host).  Family-aware call
        sites use :meth:`interpret_for` instead."""
        if self._interpret is not None:
            return self._interpret
        return self.platform != "tpu"

    def interpret_for(self, family: str) -> bool:
        """Per-family interpret decision: False exactly when ``family``'s
        Pallas body lowers natively on this host (its module's
        ``LOWERS_ON`` declaration contains :attr:`platform`)."""
        if self._interpret is not None:
            return self._interpret
        return self.platform not in NATIVE_PLATFORMS.get(family, ("tpu",))

    def reset(self) -> None:
        """Drop cached decisions (tests / device topology changes)."""
        self._interpret = None
        self._platform = None
        self.tuned.clear()
        self.priors.clear()
        self.decisions.clear()

    def _record(self, family: str, key: tuple, choice: KernelChoice,
                source: str) -> None:
        self.decisions[key] = {
            "choice": choice, "source": source,
            "platform": self.platform,
            "native": not self.interpret_for(family),
        }

    def choose(self, family: str, shape_key: tuple,
               override: Optional[KernelChoice] = None,
               timer: Optional[Callable[[KernelChoice], float]] = None,
               ) -> KernelChoice:
        """Resolve the launch configuration for ``family`` at ``shape_key``.

        ``override`` (non-empty) short-circuits tuning — explicit config
        wins.  ``timer`` runs one candidate and returns seconds; it is
        only consulted on a platform where ``family`` lowers natively
        (``interpret_for``) with concrete operands, and the winner is
        cached per shape so tuning cost is paid once.
        """
        candidates = CANDIDATES[family]
        default = candidates[0]
        key = (family,) + shape_key
        if override is not None and not override.empty:
            # partial overrides fill their None fields from the tuned
            # per-shape choice when one exists, else the default
            merged = override.merge_onto(self.tuned.get(key, default))
            self._record(family, key, merged, "override")
            return merged
        hit = self.tuned.get(key)
        if hit is not None:
            # the decision for this key was recorded when it was tuned
            return hit
        if timer is None:
            # trace-time resolution: use the default but do NOT pin the
            # cache — a later concrete-operand (eager warmup) call for the
            # same shape must still be able to tune
            if key not in self.decisions:
                self._record(family, key, default, "default-trace")
            return default
        choice = default
        source = "default-interpret"
        if not self.interpret_for(family):
            source = "timed"
            # static roofline priors (repro.analysis.costmodel) rank the
            # candidates before any timing runs: timing walks the list
            # cheapest-prior-first and candidates the model proves
            # infeasible (staged tiles over the VMEM budget) are skipped
            # outright — unless the model rejects everything, in which
            # case the ranking is advisory only and all are timed
            ranked = self._ranked(family, shape_key, candidates)
            skip_inf = any(p != float("inf") for _, p in ranked)
            best_t = float("inf")
            for cand, prior in ranked:
                if skip_inf and prior == float("inf"):
                    continue
                try:
                    t = timer(cand)
                except Exception:  # noqa: BLE001 — an invalid candidate
                    continue       # (VMEM overflow, …) just drops out
                if t < best_t:
                    best_t, choice = t, cand
        self.tuned[key] = choice
        self._record(family, key, choice, source)
        return choice

    def _ranked(self, family: str, shape_key: tuple, candidates):
        """Candidates sorted by static prior (recorded in ``priors``);
        declared order on any cost-model failure."""
        key = (family,) + shape_key
        try:
            from repro.analysis.costmodel import rank_kernel_candidates
            ranked = rank_kernel_candidates(family, shape_key, candidates)
        except Exception:  # noqa: BLE001 — priors must never block tuning
            ranked = [(c, float("inf")) for c in candidates]
        self.priors[key] = ranked
        return ranked


registry = KernelRegistry()


def _concrete(*arrays) -> bool:
    return not any(isinstance(a, jax.core.Tracer) for a in arrays)


def _timer(fn: Callable[[KernelChoice], jax.Array]):
    """best-of-3 wall-clock timer for one candidate (TPU autotune only)."""
    def run(choice: KernelChoice) -> float:
        jax.block_until_ready(fn(choice))       # compile + warm
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(choice))
            best = min(best, time.perf_counter() - t0)
        return best
    return run


# ---------------------------------------------------------------------------
# flash inhibitor (paper's mechanism)
# ---------------------------------------------------------------------------

def _prefill_choice(family, q, k, causal, window, cached,
                    override: Optional[KernelChoice], runner):
    """Shared choice resolution for the prefill-layout kernel families
    ("inhibitor" / "flash"): same shape key, same concrete-operand
    timing gate."""
    shape_key = (q.shape[1], k.shape[1], q.shape[2], k.shape[2], q.shape[3],
                 causal, window, cached)
    timer = None
    if (override is None or override.empty) and _concrete(q, k):
        timer = _timer(runner)
    return registry.choose(family, shape_key, override, timer)


@functools.partial(
    jax.custom_vjp,
    nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def flash_inhibitor(q, k, v, score_scale=None, score_shift=0.5, signed=True,
                    normalize=True, causal=True, window=None, choice=None):
    """Flash-inhibitor attention with recompute-based backward.

    Forward runs the Pallas kernel; backward recomputes via the jnp
    reference (activation-checkpoint style — no score matrix is saved).
    ``choice`` (a :class:`KernelChoice`) overrides the tuned block sizes.
    """
    def run(c: KernelChoice):
        return flash_inhibitor_fwd(
            q, k, v, score_scale=score_scale, score_shift=score_shift,
            signed=signed, normalize=normalize, causal=causal, window=window,
            block_q=c.block_q, block_k=c.block_k, sub_k=c.sub_k,
            interpret=registry.interpret_for("inhibitor"))

    return run(_prefill_choice("inhibitor", q, k, causal, window, False,
                               choice, run))


def _fi_fwd(q, k, v, score_scale, score_shift, signed, normalize, causal,
            window, choice):
    out = flash_inhibitor(q, k, v, score_scale, score_shift, signed,
                          normalize, causal, window, choice)
    return out, (q, k, v)


def _fi_bwd(score_scale, score_shift, signed, normalize, causal, window,
            choice, res, g):
    q, k, v = res

    def f(q_, k_, v_):
        return kref.flash_inhibitor_ref(
            q_, k_, v_, score_scale=score_scale, score_shift=score_shift,
            signed=signed, normalize=normalize, causal=causal, window=window)

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


flash_inhibitor.defvjp(_fi_fwd, _fi_bwd)


def flash_inhibitor_cached(q, k, v, q_offset, kv_valid_len, *,
                           score_scale=None, score_shift=0.5, signed=True,
                           normalize=True, causal=True, window=None,
                           choice=None):
    """Decode-cache flash inhibitor: per-row ``q_offset`` / ``kv_valid_len``
    cursors (traced int32 scalars or (b,) arrays).  Inference-only — no
    custom VJP is registered for the cursor-carrying form."""
    def run(c: KernelChoice):
        return flash_inhibitor_fwd(
            q, k, v, score_scale=score_scale, score_shift=score_shift,
            signed=signed, normalize=normalize, causal=causal, window=window,
            block_q=c.block_q, block_k=c.block_k, sub_k=c.sub_k,
            q_offset=q_offset, kv_valid_len=kv_valid_len,
            interpret=registry.interpret_for("inhibitor"))

    return run(_prefill_choice("inhibitor", q, k, causal, window, True,
                               choice, run))


# ---------------------------------------------------------------------------
# flash attention (baseline mechanism)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, score_scale=None, causal=True, window=None,
                    choice=None):
    def run(c: KernelChoice):
        return flash_attention_fwd(
            q, k, v, score_scale=score_scale, causal=causal, window=window,
            block_q=c.block_q, block_k=c.block_k,
            interpret=registry.interpret_for("flash"))

    return run(_prefill_choice("flash", q, k, causal, window, False,
                               choice, run))


def _fa_fwd(q, k, v, score_scale, causal, window, choice):
    out = flash_attention(q, k, v, score_scale, causal, window, choice)
    return out, (q, k, v)


def _fa_bwd(score_scale, causal, window, choice, res, g):
    q, k, v = res

    def f(q_, k_, v_):
        return kref.flash_attention_ref(
            q_, k_, v_, score_scale=score_scale, causal=causal, window=window)

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)


def flash_attention_cached(q, k, v, q_offset, kv_valid_len, *,
                           score_scale=None, causal=True, window=None,
                           choice=None):
    """Decode-cache flash attention (see :func:`flash_inhibitor_cached`)."""
    def run(c: KernelChoice):
        return flash_attention_fwd(
            q, k, v, score_scale=score_scale, causal=causal, window=window,
            block_q=c.block_q, block_k=c.block_k,
            q_offset=q_offset, kv_valid_len=kv_valid_len,
            interpret=registry.interpret_for("flash"))

    return run(_prefill_choice("flash", q, k, causal, window, True,
                               choice, run))


# ---------------------------------------------------------------------------
# paged decode kernels (block-table-native serving decode)
# ---------------------------------------------------------------------------

def _paged_choice(family_key, q, k_pool, block_tables,
                  override: Optional[KernelChoice], runner):
    shape_key = (family_key, block_tables.shape[1], k_pool.shape[1],
                 q.shape[2], k_pool.shape[2], q.shape[3])
    timer = None
    if (override is None or override.empty) and _concrete(
            q, k_pool, block_tables):
        timer = _timer(runner)
    return registry.choose("paged", shape_key, override, timer)


def paged_flash_inhibitor(q, k_pool, v_pool, block_tables, lengths, *,
                          score_scale=None, score_shift=0.5, signed=True,
                          normalize=True, window=None, choice=None):
    """Block-table-native paged inhibitor decode (inference-only)."""
    def run(c: KernelChoice):
        return paged_flash_inhibitor_fwd(
            q, k_pool, v_pool, block_tables, lengths,
            score_scale=score_scale, score_shift=score_shift, signed=signed,
            normalize=normalize, window=window,
            pages_per_step=c.pages_per_step,
            interpret=registry.interpret_for("paged"))

    return run(_paged_choice("inhibitor", q, k_pool, block_tables, choice,
                             run))


def paged_flash_attention(q, k_pool, v_pool, block_tables, lengths, *,
                          score_scale=None, window=None, choice=None):
    """Block-table-native paged Softmax decode (inference-only)."""
    def run(c: KernelChoice):
        return paged_flash_attention_fwd(
            q, k_pool, v_pool, block_tables, lengths,
            score_scale=score_scale, window=window,
            pages_per_step=c.pages_per_step,
            interpret=registry.interpret_for("paged"))

    return run(_paged_choice("flash", q, k_pool, block_tables, choice, run))


# ---------------------------------------------------------------------------
# RWKV6 WKV
# ---------------------------------------------------------------------------

def wkv6(r, k, v, w, u, state=None, *, chunk: int = 32):
    """Chunked WKV6 (kernel) when starting from zero state; the exact scan
    when a carry state is provided.  The kernel-vs-scan *plan* is made
    (and trace-logged) once at the model level — models.rwkv.apply_block's
    ``choose_plan`` — so this wrapper only enforces the state-carry
    constraint for direct callers."""
    if state is not None:
        return kref.wkv6_ref(r, k, v, w, u, state)
    return wkv6_chunked(r, k, v, w, u, chunk=chunk,
                        interpret=registry.interpret_for("wkv6"))
