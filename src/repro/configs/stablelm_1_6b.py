"""stablelm-1.6b (stablelm-2-1_6b) — dense MHA LM, LayerNorm, partial RoPE.
[hf:stabilityai/stablelm-2-1_6b; unverified]
24L d_model=2048 32H (kv=32) d_ff=5632 vocab=100352, head_dim=64,
rotary_pct=0.25.
"""

from repro.configs.base import ModelConfig
from repro.core.attention import AttentionConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    num_layers=24,
    d_model=2048,
    d_ff=5632,
    vocab_size=100352,
    attention=AttentionConfig(
        mechanism="dotprod", num_heads=32, num_kv_heads=32, head_dim=64,
        qkv_bias=False, use_rope=True, rope_base=10000.0, rope_pct=0.25,
        causal=True),
    norm="layernorm",
    norm_eps=1e-5,
    mlp="gated_silu",
    tie_embeddings=False,
    max_seq_len=4096,
    source="hf:stabilityai/stablelm-2-1_6b",
)
