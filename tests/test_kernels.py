"""Per-kernel correctness: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True executes the kernel body on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref as kref
from repro.kernels.flash import flash_attention_fwd
from repro.kernels.inhibitor import flash_inhibitor_fwd
from repro.kernels.rwkv6 import wkv6_chunked


def _mk(rng, shape, dtype):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32)).astype(
        dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,heads,kv_heads,d", [
    (48, 4, 2, 32), (33, 9, 3, 16), (64, 2, 2, 64),
])
@pytest.mark.parametrize("signed", [True, False])
def test_flash_inhibitor_sweep(rng, dtype, n, heads, kv_heads, d, signed):
    q = _mk(rng, (2, n, heads, d), dtype)
    k = _mk(rng, (2, n, kv_heads, d), dtype)
    v = _mk(rng, (2, n, kv_heads, d), dtype)
    out = flash_inhibitor_fwd(q, k, v, signed=signed, causal=True,
                              block_q=16, block_k=16, sub_k=8,
                              interpret=True)
    refo = kref.flash_inhibitor_ref(q, k, v, signed=signed, causal=True)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(refo, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [None, 16])
def test_flash_inhibitor_window(rng, window):
    q = _mk(rng, (1, 40, 4, 16), jnp.float32)
    k = _mk(rng, (1, 40, 4, 16), jnp.float32)
    v = _mk(rng, (1, 40, 4, 16), jnp.float32)
    out = flash_inhibitor_fwd(q, k, v, window=window, block_q=16,
                              block_k=16, sub_k=8, interpret=True)
    refo = kref.flash_inhibitor_ref(q, k, v, window=window)
    np.testing.assert_allclose(out, refo, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,heads,kv_heads,d", [
    (48, 4, 2, 32), (40, 8, 8, 16),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(rng, n, heads, kv_heads, d, causal):
    q = _mk(rng, (2, n, heads, d), jnp.float32)
    k = _mk(rng, (2, n, kv_heads, d), jnp.float32)
    v = _mk(rng, (2, n, kv_heads, d), jnp.float32)
    out = flash_attention_fwd(q, k, v, causal=causal, block_q=16,
                              block_k=16, interpret=True)
    refo = kref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, refo, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("t,heads,n,chunk", [
    (50, 3, 16, 16), (32, 2, 8, 8), (17, 1, 16, 32),
])
def test_wkv6_chunked_sweep(rng, t, heads, n, chunk):
    b = 2
    r = _mk(rng, (b, t, heads, n), jnp.float32)
    k = _mk(rng, (b, t, heads, n), jnp.float32)
    v = _mk(rng, (b, t, heads, n), jnp.float32)
    w = jnp.asarray(np.exp(-np.exp(
        rng.normal(size=(b, t, heads, n)) * 2)).astype(np.float32))
    u = _mk(rng, (heads, n), jnp.float32)
    o_k, s_k = wkv6_chunked(r, k, v, w, u, chunk=chunk, interpret=True)
    o_r, s_r = kref.wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(o_k, o_r, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(s_k, s_r, rtol=1e-3, atol=1e-3)


def test_wkv6_extreme_decay_stability(rng):
    """Zero-decay (w underflows to 0) must not produce NaN (subnormal
    flush regression)."""
    b, t, h, n = 1, 24, 1, 8
    r = _mk(rng, (b, t, h, n), jnp.float32)
    k = _mk(rng, (b, t, h, n), jnp.float32)
    v = _mk(rng, (b, t, h, n), jnp.float32)
    w = jnp.zeros((b, t, h, n), jnp.float32)  # hardest case
    u = _mk(rng, (h, n), jnp.float32)
    o_k, s_k = wkv6_chunked(r, k, v, w, u, chunk=8, interpret=True)
    assert bool(jnp.isfinite(o_k).all()) and bool(jnp.isfinite(s_k).all())


@pytest.mark.parametrize("window", [None, 6])
def test_flash_inhibitor_cached_ragged_cursors(rng, window):
    """Decode-cache operands: per-row q_offset/kv_valid_len ≡ the masked
    reference over each row's valid prefix."""
    from repro.core.inhibitor import inhibitor_attention

    b, h, hk, d, max_len = 3, 4, 2, 16, 40
    k = _mk(rng, (b, max_len, hk, d), jnp.float32)
    v = _mk(rng, (b, max_len, hk, d), jnp.float32)
    q = _mk(rng, (b, 1, h, d), jnp.float32)
    offs = np.asarray([13, 7, 0], np.int32)
    valids = offs + 1
    out = flash_inhibitor_fwd(q, k, v, q_offset=jnp.asarray(offs),
                              kv_valid_len=jnp.asarray(valids),
                              window=window, block_q=16, block_k=16,
                              sub_k=8, interpret=True)
    qi = offs[:, None, None]
    kj = np.arange(max_len)[None, None, :]
    m = (kj <= qi) & (kj < valids[:, None, None])
    if window is not None:
        m &= kj > qi - window
    ref = inhibitor_attention(q, k, v, mask=jnp.asarray(m[:, None]))
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_flash_attention_cached_scalar_cursor(rng):
    """Shared-cursor prefill-with-cache: scalar q_offset/kv_valid_len."""
    from repro.core.dotprod import dot_product_attention

    b, h, hk, d, max_len = 2, 4, 2, 16, 32
    k = _mk(rng, (b, max_len, hk, d), jnp.float32)
    v = _mk(rng, (b, max_len, hk, d), jnp.float32)
    q = _mk(rng, (b, 5, h, d), jnp.float32)
    out = flash_attention_fwd(q, k, v, q_offset=jnp.int32(3),
                              kv_valid_len=jnp.int32(8), block_q=4,
                              block_k=8, interpret=True)
    qi = 3 + np.arange(5)[None, :, None]
    kj = np.arange(max_len)[None, None, :]
    m = np.broadcast_to((kj <= qi) & (kj < 8), (b, 5, max_len))
    ref = dot_product_attention(q, k, v, mask=jnp.asarray(m[:, None]))
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_cached_cursors_exclude_stale_rows(rng):
    """Rows past kv_valid_len must contribute nothing — poison them."""
    b, h, hk, d, max_len = 2, 2, 2, 8, 24
    k = _mk(rng, (b, max_len, hk, d), jnp.float32)
    v = _mk(rng, (b, max_len, hk, d), jnp.float32)
    q = _mk(rng, (b, 1, h, d), jnp.float32)
    valids = jnp.asarray([9, 3], jnp.int32)
    offs = valids - 1
    clean = flash_inhibitor_fwd(q, k, v, q_offset=offs, kv_valid_len=valids,
                                block_q=8, block_k=8, sub_k=4,
                                interpret=True)
    k_bad = k.at[0, 9:].set(1e9).at[1, 3:].set(1e9)
    v_bad = v.at[0, 9:].set(-1e9).at[1, 3:].set(-1e9)
    poisoned = flash_inhibitor_fwd(q, k_bad, v_bad, q_offset=offs,
                                   kv_valid_len=valids, block_q=8,
                                   block_k=8, sub_k=4, interpret=True)
    np.testing.assert_allclose(poisoned, clean, rtol=1e-6, atol=1e-6)


def test_ops_grads_match_ref(rng):
    q = _mk(rng, (2, 24, 4, 16), jnp.float32)
    k = _mk(rng, (2, 24, 2, 16), jnp.float32)
    v = _mk(rng, (2, 24, 2, 16), jnp.float32)
    g1 = jax.grad(lambda x: ops.flash_inhibitor(x, k, v).sum())(q)
    g2 = jax.grad(lambda x: kref.flash_inhibitor_ref(x, k, v).sum())(q)
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-5)
    g3 = jax.grad(lambda x: ops.flash_attention(x, k, v).sum())(q)
    g4 = jax.grad(lambda x: kref.flash_attention_ref(x, k, v).sum())(q)
    np.testing.assert_allclose(g3, g4, rtol=1e-4, atol=1e-5)
