"""Serving launcher: continuous-batching engine over a trained/initialized
model.

    python -m repro.launch.serve --arch smollm-135m --requests 16

Loads params from --ckpt-dir if given (falls back to random init), then
drives the engine with synthetic ragged prompt traffic and reports
throughput plus the paged-cache accounting (prefill compile count,
page-pool high-water mark) and the shared-prefix cache counters
(hit tokens, CoW forks, evictions).  ``--allocator contiguous`` selects
the dense per-slot baseline; the default is the paged block-table cache
with the radix prefix index on.  ``--shared-prefix N`` makes every
synthetic prompt share an N-token prefix (system-prompt traffic) so the
cache has something to hit; ``--scheduler prefix`` admits
resident-prefix requests first.  ``--tick-budget N`` turns on chunked
prefill-decode interleaving (DESIGN.md §15): each tick spends at most N
padded prefill tokens between decode steps, so long prompts admit over
several ticks instead of stalling every in-flight stream;
``--chunk-tokens`` (alias of ``--prefill-chunk``) sets the chunk width.

Observability (DESIGN.md §16): ``--trace-out trace.json`` records the
full span timeline (request lifecycles, tick phases, kernel/plan
provenance) as Chrome trace-event JSON — load it at ui.perfetto.dev or
validate/summarize with ``python -m repro.serve.telemetry trace.json``.
``--metrics-json`` dumps the engine's counter + histogram registry;
``--log-json`` prints one JSON line of tick stats per engine tick and
arms the flight recorder, whose ring-buffer dump path is logged when
the engine dies (no-progress, soundness cross-check).
"""

from __future__ import annotations

import argparse
import json
import logging
import sys
import time

import jax
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--attention", default=None)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--allocator", choices=("paged", "contiguous"),
                    default="paged")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=None,
                    help="paged pool size (default: full capacity)")
    ap.add_argument("--prefill-chunk", "--chunk-tokens", type=int,
                    default=32, dest="prefill_chunk",
                    help="prefill chunk width in tokens (page-aligned; "
                         "--chunk-tokens is an alias)")
    ap.add_argument("--tick-budget", type=int, default=None,
                    help="max (padded) prefill tokens executed per engine "
                         "tick — enables chunked prefill-decode "
                         "interleaving (DESIGN.md §15); default: whole-"
                         "prompt admission")
    ap.add_argument("--scheduler", choices=("fifo", "priority", "prefix"),
                    default="fifo", help="admission policy")
    ap.add_argument("--no-prefix-cache", dest="prefix_cache",
                    action="store_false", default=True,
                    help="disable the shared-prefix radix KV cache")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="tokens of common prompt prefix across requests")
    ap.add_argument("--sample", action="store_true",
                    help="temperature sampling instead of greedy decode")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace-event JSON timeline here "
                         "(Perfetto-loadable; validate with "
                         "python -m repro.serve.telemetry PATH)")
    ap.add_argument("--metrics-json", default=None,
                    help="write the engine metrics registry (counters + "
                         "bounded histograms) plus stats() here")
    ap.add_argument("--log-json", action="store_true",
                    help="one JSON line of tick stats per engine tick on "
                         "stdout; also arms the crash flight recorder")
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO)
    log = logging.getLogger("repro.launch.serve")

    from repro.configs import get_config
    from repro.models.registry import get_model
    from repro.nn.module import unbox
    from repro.serve.engine import Engine, EngineConfig, Request
    from repro.serve.telemetry import TelemetryConfig, write_trace

    # telemetry is opt-in: full span tracing when a trace sink is given,
    # flight-recorder-only (bounded ring, no event list) under
    # --log-json, and entirely absent otherwise — the engine hooks are
    # `if tel is None` guarded, so off means zero events and zero
    # allocation (proven by the analyzer's telemetry sync audit).
    telemetry = None
    if args.trace_out:
        telemetry = TelemetryConfig(trace=True)
    elif args.log_json:
        telemetry = TelemetryConfig(trace=False)

    name = args.arch if not args.attention else f"{args.arch}@{args.attention}"
    cfg = get_config(name)
    if args.reduced:
        cfg = cfg.reduced()
    api = get_model(cfg)
    params = unbox(api.init(jax.random.PRNGKey(args.seed)))
    if args.ckpt_dir:
        from repro.checkpoint import restore
        (params, _), step = restore(args.ckpt_dir, (params, None))[0], None

    # the engine owns state layout: per-slot cursors always (ragged
    # continuous batching), paged block tables when the family supports it
    eng = Engine(api, params,
                 EngineConfig(max_batch=args.max_batch,
                              max_len=args.max_len,
                              allocator=args.allocator,
                              page_size=args.page_size,
                              num_pages=args.num_pages,
                              prefill_chunk=args.prefill_chunk,
                              tick_budget=args.tick_budget,
                              prefix_cache=args.prefix_cache,
                              scheduler=args.scheduler,
                              greedy=not args.sample,
                              temperature=args.temperature,
                              telemetry=telemetry),
                 seed=args.seed)

    rng = np.random.default_rng(args.seed)
    plen = max(1, min(args.prompt_len, args.max_len - 1))
    shared_len = max(0, min(args.shared_prefix, plen - 1))
    shared = rng.integers(0, cfg.vocab_size, (shared_len,)).astype(np.int32)
    t0 = time.perf_counter()
    for i in range(args.requests):
        tail = rng.integers(0, cfg.vocab_size,
                            (plen - shared_len,)).astype(np.int32)
        prompt = np.concatenate([shared, tail])
        eng.submit(Request(i, prompt, max_new_tokens=args.new_tokens))

    on_tick = None
    if args.log_json:
        def on_tick(e, finished):
            # one line per tick, stable keys — cheap counter reads only,
            # never a full stats() (which walks the allocator)
            print(json.dumps({
                "tick": e._tick, "active": len(e.active),
                "admitting": len(e.admitting),
                "queued": len(e.scheduler), "finished": len(finished),
                "finished_total": e.counters["finished_requests"],
                "generated_tokens": e.counters["generated_tokens"],
                "prefill_tokens": e.counters["prefill_tokens"],
                "table_uploads": e.counters["table_uploads"],
                "paused_prefills": e.counters["paused_prefills"],
            }, sort_keys=True), flush=True)
    try:
        done = eng.run_to_completion(on_tick=on_tick)
    except RuntimeError as err:
        # _dump_on_error already wrote the flight recorder and embedded
        # its path in the message; restate it loudly for log scrapers
        log.error("engine aborted: %s", err)
        if "[flight recorder:" in str(err):
            path = str(err).rsplit("[flight recorder: ", 1)[1].rstrip("]")
            print(f"FLIGHT RECORDER: {path}", file=sys.stderr)
        return 1
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.output) for r in done)
    log.info("served %d requests, %d tokens in %.2fs (%.1f tok/s)",
             len(done), total_tokens, dt, total_tokens / dt)
    log.info("prefill compiles: %d (buckets: %s)", eng.prefill_compiles,
             sorted(eng._prefill_buckets))
    if eng.paged:
        log.info("page pool: high-water %d / %d pages (page_size=%d)",
                 eng.alloc.high_water_pages, eng.alloc.num_pages - 1,
                 eng.alloc.page_size)
    stats = eng.stats()
    log.info("scheduler=%s prefill_tokens=%d prefix_hit_tokens=%d "
             "(%d request hits) forked_pages=%d evictions=%d "
             "cached_pages=%d", stats["scheduler"], stats["prefill_tokens"],
             stats["prefix_hit_tokens"], stats["prefix_hit_requests"],
             stats["forked_pages"], stats["evictions"],
             stats["cached_pages"])
    log.info("latency: ttft p50=%.1fms p99=%.1fms | itl p50=%.2fms "
             "p99=%.2fms | queued_ticks p99=%.0f | paused_prefills=%d",
             stats["ttft_ms_p50"], stats["ttft_ms_p99"],
             stats["itl_ms_p50"], stats["itl_ms_p99"],
             stats["queued_ticks_p99"], stats["paused_prefills"])
    for r in done[:3]:
        log.info("req %d -> %s...", r.request_id, r.output[:8])
    if args.trace_out:
        write_trace(eng.tel, args.trace_out)
        log.info("trace: wrote %s (%d events) — load at ui.perfetto.dev "
                 "or run `python -m repro.serve.telemetry %s`",
                 args.trace_out, len(eng.tel.events), args.trace_out)
    if args.metrics_json:
        with open(args.metrics_json, "w") as f:
            json.dump({"stats": stats, "metrics": eng.metrics.snapshot()},
                      f, indent=2, sort_keys=True)
        log.info("metrics: wrote %s", args.metrics_json)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
