"""Model registry: one uniform API over all architecture families.

``get_model(cfg)`` returns a :class:`ModelApi` whose members close over the
config:

  * ``init(key)``                      -> param tree (boxed)
  * ``forward(params, batch)``         -> (logits, aux)      [train/prefill]
  * ``init_states(batch, max_len)``    -> decode state
  * ``step(params, tokens, states, batch)`` -> (logits, states')  [decode]
  * ``input_specs(shape)``             -> dict of ShapeDtypeStruct stand-ins

``input_specs`` is the single source of truth for the dry-run: it describes
every array the train/serve step consumes (tokens, labels, frontend
embeddings) as ShapeDtypeStructs — weak-type-correct, shardable, no device
allocation.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec as encdec_m
from repro.models import rwkv as rwkv_m
from repro.models import transformer as tfm


class ModelApi(NamedTuple):
    cfg: ModelConfig
    init: Callable
    forward: Callable          # (params, batch) -> (logits, aux)
    init_states: Callable      # (batch_size, max_len) -> states
    step: Callable             # (params, tokens, states, batch) -> (logits, states')
    input_specs: Callable      # (ShapeConfig) -> dict[str, ShapeDtypeStruct]


def _lm_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b = shape.global_batch
    s = shape.seq_len
    if shape.kind == "train":
        return {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
    if shape.kind == "prefill":
        return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    # decode: one new token against a KV cache of seq_len
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}


def _vlm_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    specs = _lm_specs(cfg, shape)
    fe = cfg.frontend
    n_img = fe.tokens_per_item * fe.max_tiles
    if shape.kind != "decode":
        specs["image_embeds"] = jax.ShapeDtypeStruct(
            (shape.global_batch, n_img, fe.embed_dim), jnp.float32)
        # image tokens occupy the front of the sequence; text fills the rest
        text_len = max(shape.seq_len - n_img, 1)
        specs["tokens"] = jax.ShapeDtypeStruct(
            (shape.global_batch, text_len), jnp.int32)
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct(
                (shape.global_batch, text_len + n_img), jnp.int32)
    return specs


def _encdec_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b = shape.global_batch
    fe = cfg.frontend
    src = min(shape.seq_len, cfg.encdec.max_source_len)
    if shape.kind == "train":
        return {
            "frames": jax.ShapeDtypeStruct((b, src, fe.embed_dim),
                                           jnp.float32),
            "tokens": jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32),
        }
    if shape.kind == "prefill":
        return {
            "frames": jax.ShapeDtypeStruct((b, src, fe.embed_dim),
                                           jnp.float32),
            "tokens": jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32),
        }
    return {
        "memory": jax.ShapeDtypeStruct((b, src, cfg.d_model), jnp.float32),
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
    }


def get_model(cfg: ModelConfig) -> ModelApi:
    if cfg.family in ("dense", "moe", "hybrid"):
        def forward(params, batch):
            return tfm.lm_forward(params, cfg, batch["tokens"])

        def step(params, tokens, states, batch=None):
            return tfm.lm_step(params, cfg, tokens, states)

        return ModelApi(
            cfg=cfg,
            init=lambda key: tfm.init_lm(key, cfg),
            forward=forward,
            init_states=lambda b, s, **kw: tfm.init_states(cfg, b, s, **kw),
            step=step,
            input_specs=lambda shape: _lm_specs(cfg, shape),
        )

    if cfg.family == "vlm":
        def forward(params, batch):
            return tfm.lm_forward(params, cfg, batch["tokens"],
                                  extra_embeds=batch.get("image_embeds"))

        def step(params, tokens, states, batch=None):
            return tfm.lm_step(params, cfg, tokens, states)

        return ModelApi(
            cfg=cfg,
            init=lambda key: tfm.init_lm(key, cfg),
            forward=forward,
            init_states=lambda b, s, **kw: tfm.init_states(cfg, b, s, **kw),
            step=step,
            input_specs=lambda shape: _vlm_specs(cfg, shape),
        )

    if cfg.family == "ssm":
        def forward(params, batch):
            # Pallas WKV kernel on TPU; pure-jnp scan on CPU (tests/dry-run
            # compile for the host backend, where the kernel would need
            # interpret mode inside SPMD)
            use_kernel = jax.default_backend() == "tpu"
            return rwkv_m.lm_forward(params, cfg, batch["tokens"],
                                     use_kernel=use_kernel)

        def step(params, tokens, states, batch=None):
            return rwkv_m.lm_step(params, cfg, tokens, states)

        return ModelApi(
            cfg=cfg,
            init=lambda key: rwkv_m.init_lm(key, cfg),
            forward=forward,
            init_states=lambda b, s, **kw: rwkv_m.init_states(cfg, b, s, **kw),
            step=step,
            input_specs=lambda shape: _lm_specs(cfg, shape),
        )

    if cfg.family == "encdec":
        def forward(params, batch):
            logits = encdec_m.forward_train(params, cfg, batch["frames"],
                                            batch["tokens"])
            return logits, jnp.zeros((2,), jnp.float32)

        def step(params, tokens, states, batch=None):
            return encdec_m.decode_step(params, cfg, batch["memory"], tokens,
                                        states)

        return ModelApi(
            cfg=cfg,
            init=lambda key: encdec_m.init_model(key, cfg),
            forward=forward,
            init_states=lambda b, s, **kw: encdec_m.init_states(cfg, b, s, **kw),
            step=step,
            input_specs=lambda shape: _encdec_specs(cfg, shape),
        )

    raise ValueError(f"unknown family {cfg.family!r}")
