"""Training loop: loss falls, checkpoint-resume is bit-exact, watchdog."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointConfig
from repro.configs import get_config
from repro.data.pipeline import PipelineConfig, lm_batch_at
from repro.distributed.fault import StepWatchdog, elastic_remesh_plan
from repro.models.registry import get_model
from repro.optim import AdamWConfig
from repro.train.loop import TrainConfig, train


def _setup(vocab=128):
    cfg = get_config("smollm-135m").reduced(num_layers=2, d_model=48,
                                            d_ff=96, vocab_size=vocab,
                                            num_heads=4, num_kv_heads=2,
                                            head_dim=12)
    api = get_model(cfg)
    pipe = PipelineConfig(global_batch=8, seq_len=32, vocab_size=vocab,
                          seed=11)
    return api, (lambda step: lm_batch_at(pipe, step))


def test_loss_decreases():
    api, batch_fn = _setup()
    out = train(api, AdamWConfig(lr=3e-3), TrainConfig(total_steps=70),
                batch_fn)
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0] - 0.3, losses[:3] + losses[-3:]


def test_checkpoint_resume_exact(tmp_path):
    """Crash at step 12, resume from the step-10 commit -> identical final
    params as an uninterrupted run (restart purity)."""
    api, batch_fn = _setup()
    opt = AdamWConfig(lr=1e-3)

    full = train(api, opt, TrainConfig(total_steps=20), batch_fn)

    ck = CheckpointConfig(str(tmp_path), every_steps=10, async_save=False)
    train(api, opt, TrainConfig(total_steps=12, checkpoint=ck), batch_fn)
    resumed = train(api, opt, TrainConfig(total_steps=20, checkpoint=ck),
                    batch_fn)

    for a, b in zip(jax.tree.leaves(full["params"]),
                    jax.tree.leaves(resumed["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(threshold=2.0, warmup_steps=2)
    for s in range(10):
        assert not wd.observe(s, 1.0)
    assert wd.observe(10, 5.0)
    assert wd.flagged and wd.flagged[0][0] == 10
    # trend not polluted by the straggler
    assert not wd.observe(11, 1.0)


def test_elastic_remesh_plan():
    plan = elastic_remesh_plan(480, model_parallelism=16,
                               old_data_parallelism=16)
    assert plan.model == 16
    assert plan.data * plan.model * plan.pods <= 480
    assert plan.data & (plan.data - 1) == 0   # power of two
