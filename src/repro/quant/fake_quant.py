"""Symmetric quantization utilities (QAT fake-quant + PTQ helpers).

The paper's thesis is that the Inhibitor "allows straightforward
quantization": its score/inhibition path is linear in Q, K, V up to ReLU/|·|
— all scale-covariant ops — so a single shared scale survives the whole
attention computation (no rescale between score and mixing, unlike
Softmax(QKᵀ)·V whose products square the scale).  These helpers provide the
integer projection used by the plaintext-scaling and FHE benchmarks and a
straight-through-estimator fake-quant for QAT.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    bits: int = 8
    symmetric: bool = True
    per_channel: bool = False     # quantize per last-dim channel
    narrow_range: bool = False    # use [-(2^(b-1)-1), 2^(b-1)-1]


def _qrange(cfg: QuantConfig):
    qmax = 2 ** (cfg.bits - 1) - 1
    qmin = -qmax if cfg.narrow_range else -(2 ** (cfg.bits - 1))
    return qmin, qmax


def compute_scale(x: jax.Array, cfg: QuantConfig) -> jax.Array:
    """Max-abs calibration scale (per tensor or per channel)."""
    qmin, qmax = _qrange(cfg)
    if cfg.per_channel:
        amax = jnp.max(jnp.abs(x), axis=tuple(range(x.ndim - 1)),
                       keepdims=True)
    else:
        amax = jnp.max(jnp.abs(x))
    return jnp.maximum(amax, 1e-8) / qmax


def quantize(x: jax.Array, scale: jax.Array, cfg: QuantConfig) -> jax.Array:
    qmin, qmax = _qrange(cfg)
    q = jnp.round(x / scale)
    return jnp.clip(q, qmin, qmax).astype(jnp.int32)


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def fake_quant(x: jax.Array, cfg: QuantConfig,
               scale: Optional[jax.Array] = None) -> jax.Array:
    """Quantize-dequantize with straight-through gradients (QAT)."""
    s = compute_scale(jax.lax.stop_gradient(x), cfg) if scale is None else scale
    qdq = dequantize(quantize(jax.lax.stop_gradient(x), s, cfg), s)
    return x + jax.lax.stop_gradient(qdq - x)


def quantize_params(tree, cfg: QuantConfig):
    """PTQ an unboxed param tree -> (int tree, scale tree)."""

    def one(x):
        if not jnp.issubdtype(x.dtype, jnp.floating):
            return x, jnp.ones((), jnp.float32)
        s = compute_scale(x, cfg)
        return quantize(x, s, cfg), s

    flat, treedef = jax.tree.flatten(tree)
    pairs = [one(x) for x in flat]
    q = jax.tree.unflatten(treedef, [p[0] for p in pairs])
    s = jax.tree.unflatten(treedef, [p[1] for p in pairs])
    return q, s
