"""Mixture-of-Experts FFN with capacity-bounded scatter dispatch.

Design notes (expert parallelism, EP):
  * Expert weights have a leading ``expert`` logical axis, sharded over the
    ``model`` mesh axis by the sharding rules.
  * Tokens are routed with top-k gating, then *scattered* into a dense
    ``(experts, capacity, d)`` buffer (GShard-style, capacity-dropped) so the
    expert compute is a plain batched einsum — XLA SPMD turns the
    token-sharded -> expert-sharded layout change into the all-to-all.
  * Buffer size is ``capacity_factor * top_k * tokens * d`` — the same order
    as one FFN activation, so this scales to the 60-expert qwen2-moe and the
    16-expert llama4-scout configs.
  * ``num_experts`` is padded up to a multiple of the EP degree by the config
    layer when needed (e.g. 60 -> 64); padding experts receive ~0 router
    probability at init and are dropped by top-k thereafter.

Returns Switch-Transformer-style load-balancing and router-z auxiliary
losses so training can regularize the router.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.nn.linear import apply_dense, init_dense
from repro.nn.mlp import _act, apply_gated_mlp, init_gated_mlp
from repro.nn.module import KeyGen


class MoEAux(NamedTuple):
    load_balance_loss: jax.Array
    router_z_loss: jax.Array
    dropped_fraction: jax.Array


def init_moe(
    key,
    embed_dim: int,
    expert_hidden_dim: int,
    num_experts: int,
    *,
    shared_hidden_dim: int = 0,
    shared_gate: bool = False,
    dtype=jnp.float32,
) -> dict:
    kg = KeyGen(key)
    params = {
        "router": init_dense(kg("router"), (embed_dim,), (num_experts,),
                             ("embed",), (None,), dtype=jnp.float32),
        # expert-stacked gated MLP: leading dim is the ("expert",) axis
        "wg": _stack_expert(kg, "wg", num_experts, (embed_dim,),
                            (expert_hidden_dim,), ("embed",), ("mlp",), dtype),
        "wu": _stack_expert(kg, "wu", num_experts, (embed_dim,),
                            (expert_hidden_dim,), ("embed",), ("mlp",), dtype),
        "wd": _stack_expert(kg, "wd", num_experts, (expert_hidden_dim,),
                            (embed_dim,), ("mlp",), ("embed",), dtype),
    }
    if shared_hidden_dim > 0:
        params["shared"] = init_gated_mlp(kg("shared"), embed_dim,
                                          shared_hidden_dim, dtype=dtype)
        if shared_gate:
            params["shared_gate"] = init_dense(
                kg("shared_gate"), (embed_dim,), (1,), ("embed",), (None,),
                dtype=dtype)
    return params


def _stack_expert(kg: KeyGen, name: str, num_experts: int, in_shape, out_shape,
                  in_axes, out_axes, dtype) -> dict:
    """Init ``num_experts`` independent kernels stacked on a leading expert dim."""
    from repro.nn.module import Param
    ks = jax.random.split(kg(name + "_stack"), num_experts)

    def _one(k):
        return init_dense(k, in_shape, out_shape, in_axes, out_axes,
                          dtype=dtype)["kernel"].value

    stacked = jax.vmap(_one)(ks)
    return {"kernel": Param(stacked, ("expert",) + tuple(in_axes) + tuple(out_axes))}


def _router_probs(params, x, *, router_softmax: bool = True):
    logits = apply_dense(params["router"], x.astype(jnp.float32), 1)
    if router_softmax:
        probs = jax.nn.softmax(logits, axis=-1)
    else:
        probs = jax.nn.sigmoid(logits)
    return logits, probs


def apply_moe(
    params: dict,
    x: jax.Array,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    activation: str = "silu",
    normalize_topk: bool = True,
    router_softmax: bool = True,
    compute_dtype=None,
) -> tuple:
    """MoE forward. x: (batch, seq, d) -> (batch, seq, d), MoEAux.

    Dispatch: top-k routing -> position-in-expert via one-hot cumsum ->
    scatter into (E, C, d) -> batched expert einsum -> gather + combine.
    """
    b, s, d = x.shape
    tokens = x.reshape(b * s, d)
    n_tok = b * s
    num_experts = params["wg"]["kernel"].shape[0]

    logits, probs = _router_probs(params, tokens, router_softmax=router_softmax)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)  # (T, k)
    if normalize_topk:
        gate_vals = gate_vals / jnp.maximum(
            jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    capacity = int(max(top_k, capacity_factor * top_k * n_tok / num_experts))
    capacity = min(capacity, n_tok)  # can't exceed all tokens in one expert

    # flatten (T, k) assignments -> (T*k,)
    flat_expert = expert_ids.reshape(-1)           # (T*k,)
    flat_gate = gate_vals.reshape(-1)              # (T*k,)
    flat_token = jnp.repeat(jnp.arange(n_tok), top_k)

    # position of each assignment within its expert: one-hot cumsum
    onehot = jax.nn.one_hot(flat_expert, num_experts, dtype=jnp.int32)
    pos_in_expert = (jnp.cumsum(onehot, axis=0) - 1) * onehot  # (T*k, E)
    flat_pos = jnp.sum(pos_in_expert, axis=-1)     # (T*k,)
    keep = flat_pos < capacity                      # capacity drop mask
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))

    from repro.distributed.sharding import constrain

    cdt = compute_dtype or x.dtype
    # scatter tokens into the expert buffer (E, C, d) — expert-sharded (EP):
    # the token-sharded -> expert-sharded layout change is the all-to-all
    buf = jnp.zeros((num_experts, capacity, d), cdt)
    safe_pos = jnp.where(keep, flat_pos, capacity - 1)
    scatter_val = jnp.where(keep[:, None], tokens[flat_token].astype(cdt), 0)
    buf = buf.at[flat_expert, safe_pos].add(scatter_val, mode="drop")
    buf = constrain(buf, "expert", None, None)

    # expert compute: gated MLP batched over the expert axis
    wg = params["wg"]["kernel"].astype(cdt)
    wu = params["wu"]["kernel"].astype(cdt)
    wd = params["wd"]["kernel"].astype(cdt)
    h = _act(activation)(jnp.einsum("ecd,edf->ecf", buf, wg))
    h = h * jnp.einsum("ecd,edf->ecf", buf, wu)
    h = constrain(h, "expert", None, None)
    out_buf = jnp.einsum("ecf,efd->ecd", h, wd)    # (E, C, d)
    out_buf = constrain(out_buf, "expert", None, None)

    # gather back and combine with gates
    gathered = out_buf[flat_expert, safe_pos]       # (T*k, d)
    gathered = gathered * (flat_gate.astype(cdt) * keep.astype(cdt))[:, None]
    combined = jnp.zeros((n_tok, d), cdt).at[flat_token].add(gathered)

    if "shared" in params:
        shared_out = apply_gated_mlp(params["shared"], tokens,
                                     activation=activation, compute_dtype=cdt)
        if "shared_gate" in params:
            g = jax.nn.sigmoid(
                apply_dense(params["shared_gate"], tokens, 1, cdt))
            shared_out = shared_out * g
        combined = combined + shared_out

    # ---- auxiliary losses (Switch Transformer style) ----
    # fraction of tokens routed to each expert (by top-1 assignment)
    me = jnp.mean(jax.nn.one_hot(expert_ids[:, 0], num_experts,
                                 dtype=jnp.float32), axis=0)
    ce = jnp.mean(probs, axis=0)
    lb_loss = num_experts * jnp.sum(me * ce)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = MoEAux(lb_loss, z_loss, dropped)
    return combined.reshape(b, s, d).astype(x.dtype), aux
