"""Paper Table 2: TFHE compiler parameters + circuit bit widths per T.

Runs both attention circuits through the TFHE simulator at the paper's
scale (single head, d=2, ≤4-bit inputs) for T ∈ {2, 4, 8, 16}, then selects
macro-parameters from the recorded PBS message widths (fhe.params mirrors
the Concrete optimizer's published curves).

Paper claims reproduced: the dot-product arm needs 1–2 more message bits
than the inhibitor arm (its cipher-multiplication PBS inputs are sums a+b
of operands, and its Softmax fixed-point path accumulates), and about twice
the PBS count.
"""

from __future__ import annotations

import numpy as np

from repro.core.mechanism import get_mechanism
from repro.fhe import describe


def run(smoke: bool = False) -> list:
    # the encrypted circuit of each arm comes off the mechanism registry —
    # a new HE-friendly mechanism lands in this table by registering
    inhibitor_circuit = get_mechanism("inhibitor").fhe_circuit
    dotprod_circuit = get_mechanism("dotprod").fhe_circuit
    rows = []
    rng = np.random.default_rng(0)
    for T in (2, 4) if smoke else (2, 4, 8, 16):
        d = 2
        q = rng.integers(-7, 8, (T, d))
        k = rng.integers(-7, 8, (T, d))
        v = rng.integers(-7, 8, (T, d))
        _, s_inh = inhibitor_circuit(q, k, v, gamma_shift=1, alpha_q=1)
        _, s_dot = dotprod_circuit(q, k, v, scale_shift=2)
        di, dd = describe(s_inh), describe(s_dot)
        for name, dsc in (("inhibitor", di), ("dotprod", dd)):
            rows.append((
                f"table2/T{T}/{name}", 0.0,
                f"lwe={dsc['lwe_dim']};poly={dsc['poly_size']};"
                f"bits={dsc['max_bits_at_pbs']};pbs={dsc['pbs']}"))
        rows.append((f"table2/T{T}/bit_gap", 0.0,
                     f"dotprod-inhibitor={dd['max_bits_at_pbs'] - di['max_bits_at_pbs']}"))
        rows.append((f"table2/T{T}/pbs_ratio", 0.0,
                     f"{dd['pbs'] / di['pbs']:.2f}x"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
