"""Mechanism registry + planner: backend parity and plan selection.

The parity tests are registry-driven: every registered mechanism is
checked across every eligible float backend (naive / fused / chunked /
blocked / pallas-in-interpret) against its ``naive`` oracle, through the
full ``apply_attention`` layer (so the planner's forced-backend path,
mask materialization, and structural routing are all exercised) — with
GQA, explicit-mask, and decode-cache cases.  A fourth mechanism that
registers itself is covered with zero edits here.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attention import (AttentionConfig, apply_attention,
                                  init_attention, init_kv_cache)
from repro.core.mechanism import (AttnShapes, ExecutionPlan, Mechanism,
                                  available_mechanisms, backend_eligible,
                                  execute_plan, get_mechanism,
                                  plan_attention, register_mechanism)
from repro.nn.module import unbox

FLOAT_BACKENDS = ("fused", "chunked", "blocked", "pallas")
TOL = dict(rtol=1e-3, atol=1e-4)


def _cfg(mech, backend=None, **kw):
    kw.setdefault("num_heads", 4)
    kw.setdefault("num_kv_heads", 2)        # GQA everywhere
    kw.setdefault("head_dim", 8)
    return AttentionConfig(kind=mech, backend=backend, **kw)


def _layer(mech, embed=32):
    cfg = _cfg(mech)
    return unbox(init_attention(jax.random.PRNGKey(0), cfg, embed))


def _shapes(cfg, n_q, n_k, **kw):
    return AttnShapes(batch=2, n_q=n_q, n_k=n_k, num_heads=cfg.num_heads,
                      num_kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim,
                      **kw)


# ---------------------------------------------------------------------------
# Registry contents
# ---------------------------------------------------------------------------

def test_builtin_mechanisms_registered():
    assert set(available_mechanisms()) >= {"dotprod", "inhibitor",
                                           "inhibitor_unsigned"}
    for name in available_mechanisms():
        mech = get_mechanism(name)
        assert "naive" in mech.backends, "every mechanism needs its oracle"
        assert mech.mask_semantics in ("exclude", "neg_inf")


def test_unknown_mechanism_error_lists_registered():
    with pytest.raises(ValueError, match="inhibitor"):
        get_mechanism("power_softmax")


def test_duplicate_registration_fails_loudly():
    mech = get_mechanism("dotprod")
    with pytest.raises(ValueError, match="already registered"):
        register_mechanism(mech)
    register_mechanism(mech, overwrite=True)    # idempotent restore


# ---------------------------------------------------------------------------
# Backend parity (registry-driven)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mech", available_mechanisms())
@pytest.mark.parametrize("backend", FLOAT_BACKENDS)
def test_backend_parity_full_gqa(rng, mech, backend):
    """Causal self-attention, GQA heads: every backend ≡ the naive oracle."""
    cfg_ref = _cfg(mech, backend="naive")
    cfg = _cfg(mech, backend=backend)
    ok, why = backend_eligible(
        backend, cfg, _shapes(cfg, 32, 32), get_mechanism(mech))
    if not ok:
        pytest.skip(f"{backend}: {why}")
    params = _layer(mech)
    x = jnp.asarray(rng.normal(size=(2, 32, 32)).astype(np.float32))
    y_ref, _ = apply_attention(params, cfg_ref, x)
    y, _ = apply_attention(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), **TOL)


@pytest.mark.parametrize("mech", available_mechanisms())
@pytest.mark.parametrize("backend", FLOAT_BACKENDS)
def test_backend_parity_explicit_mask(rng, mech, backend):
    """Arbitrary boolean masks: mask-capable backends ≡ the oracle."""
    cfg = _cfg(mech, backend=backend, causal=False)
    shapes = _shapes(cfg, 12, 12, has_explicit_mask=True)
    ok, why = backend_eligible(backend, cfg, shapes, get_mechanism(mech))
    if not ok:
        pytest.skip(f"{backend}: {why}")
    params = _layer(mech)
    x = jnp.asarray(rng.normal(size=(2, 12, 32)).astype(np.float32))
    m = np.random.default_rng(7).random((2, 1, 12, 12)) > 0.4
    m |= np.eye(12, dtype=bool)[None, None]       # every query sees itself
    mask = jnp.asarray(m)
    y_ref, _ = apply_attention(params, _cfg(mech, backend="naive",
                                            causal=False), x,
                               attn_mask=mask)
    y, _ = apply_attention(params, cfg, x, attn_mask=mask)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), **TOL)


@pytest.mark.parametrize("mech", available_mechanisms())
@pytest.mark.parametrize("backend", FLOAT_BACKENDS)
def test_backend_parity_decode_cache(rng, mech, backend):
    """Prefill + one-token decode against a KV cache ≡ the oracle."""
    cfg = _cfg(mech, backend=backend)
    shapes = _shapes(cfg, 1, 16, has_cache=True)
    ok, why = backend_eligible(backend, cfg, shapes, get_mechanism(mech))
    if not ok:
        pytest.skip(f"{backend}: {why}")
    params = _layer(mech)
    x = jnp.asarray(rng.normal(size=(2, 6, 32)).astype(np.float32))

    def run(c):
        cache = init_kv_cache(2, 16, c.num_kv_heads, c.head_dim, jnp.float32)
        y_pre, cache = apply_attention(params, c, x[:, :5], cache=cache)
        y_dec, _ = apply_attention(params, c, x[:, 5:6], cache=cache)
        return y_pre, y_dec

    ref_pre, ref_dec = run(_cfg(mech, backend="naive"))
    y_pre, y_dec = run(cfg)
    np.testing.assert_allclose(np.asarray(y_pre), np.asarray(ref_pre), **TOL)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(ref_dec), **TOL)


# ---------------------------------------------------------------------------
# Planner selection
# ---------------------------------------------------------------------------

def test_plan_default_is_fused():
    cfg = _cfg("inhibitor")
    plan = plan_attention(cfg, _shapes(cfg, 64, 64))
    assert (plan.mechanism, plan.backend) == ("inhibitor", "fused")


def test_plan_large_structural_goes_blocked():
    cfg = _cfg("inhibitor")
    plan = plan_attention(cfg, _shapes(cfg, 2048, 2048))
    assert plan.backend == "blocked"
    assert "blocked_threshold" in plan.reason


def test_plan_long_kv_goes_chunked():
    cfg = _cfg("inhibitor")
    # ragged per-slot decode: structural backends ineligible, long kv
    plan = plan_attention(cfg, _shapes(cfg, 1, 8192, has_cache=True,
                                       scalar_cursor=False))
    assert plan.backend == "chunked"


def test_plan_dotprod_has_no_blocked_path():
    cfg = _cfg("dotprod")
    plan = plan_attention(cfg, _shapes(cfg, 2048, 2048,
                                       platform="cpu"))
    assert plan.backend == "fused"


def test_plan_tpu_prefers_pallas_at_scale():
    cfg = _cfg("inhibitor")
    plan = plan_attention(cfg, _shapes(cfg, 2048, 2048, platform="tpu"))
    assert plan.backend == "pallas"


def test_plan_paged_pool_prefers_kernel_on_tpu():
    """Paged decode plans the block-table-native kernel on TPU, the
    clamped gather elsewhere — and prefill chunks always gather."""
    cfg = _cfg("inhibitor")
    decode = _shapes(cfg, 1, 512, has_cache=True, scalar_cursor=False,
                     paged=True)
    plan_tpu = plan_attention(cfg, decode._replace(platform="tpu"))
    assert plan_tpu.backend == "paged_pallas"
    assert "block-table-native" in plan_tpu.reason
    plan_cpu = plan_attention(cfg, decode._replace(platform="cpu"))
    assert plan_cpu.backend == "paged"
    assert "gather" in plan_cpu.reason
    prefill = decode._replace(platform="tpu", n_q=8)
    assert plan_attention(cfg, prefill).backend == "paged"


def test_plan_integer_lanes_go_int():
    cfg = _cfg("inhibitor")
    plan = plan_attention(cfg, _shapes(cfg, 16, 16, dtype=jnp.int32))
    assert plan.backend == "int"


def test_use_kernel_shim_forces_pallas_and_falls_back():
    cfg = _cfg("inhibitor", use_kernel=True)
    with pytest.warns(DeprecationWarning):
        import repro.core.mechanism as M
        M._use_kernel_warned = False        # re-arm the one-shot warning
        plan = plan_attention(cfg, _shapes(cfg, 32, 32, platform="tpu"))
    assert plan.backend == "pallas"
    assert "use_kernel" in plan.reason
    # the kernel cannot honor an explicit mask: shim falls back, reason says so
    plan2 = plan_attention(cfg, _shapes(cfg, 32, 32, platform="tpu",
                                        has_explicit_mask=True))
    assert plan2.backend != "pallas"
    assert "use_kernel requested but pallas ineligible" in plan2.reason
    # on non-TPU hosts the shim never picks interpret-mode pallas
    plan3 = plan_attention(cfg, _shapes(cfg, 32, 32, platform="cpu"))
    assert plan3.backend == "fused"
    assert "interpret mode" in plan3.reason
    # legacy semantics preserved: use_kernel was always a no-op for dotprod
    plan4 = plan_attention(_cfg("dotprod", use_kernel=True),
                           _shapes(cfg, 32, 32, platform="tpu"))
    assert (plan4.backend, plan4.reason) == ("fused", "dense default")


def test_pallas_backend_honors_decode_structure(rng):
    """The flash kernels carry scalar-prefetched q_offset/kv_valid_len
    operands: a Structural with decode-cache cursors must attend over
    exactly the valid prefix (not silently from offset 0 over stale
    rows)."""
    from repro.core.mechanism import Structural

    q = jnp.asarray(rng.normal(size=(1, 1, 2, 8)).astype(np.float32))
    kv = jnp.asarray(rng.normal(size=(1, 8, 2, 8)).astype(np.float32))
    plan = ExecutionPlan("inhibitor", "pallas", "test")
    mech = get_mechanism("inhibitor")
    params = mech.make_params(score_scale=None, score_shift=0.5,
                              normalize=True, kv_chunk=64)
    out = execute_plan(plan, q, kv, kv, params=params,
                       structural=Structural(q_offset=jnp.int32(2),
                                             kv_valid_len=jnp.int32(3)))
    # oracle: naive backend over only the 3 valid rows
    ref = execute_plan(ExecutionPlan("inhibitor", "naive", "test"),
                       q, kv[:, :3], kv[:, :3], params=params)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_paged_pallas_requires_paged_layout(rng):
    """paged_pallas consumes a page pool + PagedLayout; executing it
    without one is a dispatch bug and fails loudly."""
    q = jnp.asarray(rng.normal(size=(1, 1, 2, 8)).astype(np.float32))
    plan = ExecutionPlan("inhibitor", "paged_pallas", "test")
    mech = get_mechanism("inhibitor")
    with pytest.raises(ValueError, match="paged"):
        execute_plan(plan, q, q, q,
                     params=mech.make_params(score_scale=None,
                                             score_shift=0.5,
                                             normalize=True, kv_chunk=64))


def test_forced_ineligible_backend_raises():
    # a paged backend forced at a site with no page pool can never run
    cfg = _cfg("inhibitor", backend="paged")
    with pytest.raises(ValueError, match="ineligible"):
        plan_attention(cfg, _shapes(cfg, 1, 16, has_cache=True))
    # and the paged kernel is decode-only: n_q > 1 is ineligible even
    # with a pool present
    cfg2 = _cfg("inhibitor", backend="paged_pallas")
    with pytest.raises(ValueError, match="ineligible"):
        plan_attention(cfg2, _shapes(cfg2, 8, 64, has_cache=True,
                                     scalar_cursor=False, paged=True))


def test_legacy_kind_still_plans():
    cfg = AttentionConfig(kind="inhibitor_unsigned")
    plan = plan_attention(cfg, AttnShapes(2, 8, 8, 8, 8, 64))
    assert plan.mechanism == "inhibitor_unsigned"


# ---------------------------------------------------------------------------
# Integer / FHE execution domains
# ---------------------------------------------------------------------------

def test_int_backend_matches_raw_reference(rng):
    """The signed mechanism runs the *signed* integer form (the legacy
    adapter silently dropped to unsigned — a masked sign bug)."""
    from repro.quant.int_attention import int_inhibitor_attention

    cfg = _cfg("inhibitor", score_scale=4.0, score_shift=1.0, causal=False)
    q = jnp.asarray(rng.integers(-31, 32, (2, 8, 4, 4)).astype(np.int32))
    k = jnp.asarray(rng.integers(-31, 32, (2, 8, 2, 4)).astype(np.int32))
    v = jnp.asarray(rng.integers(-31, 32, (2, 8, 2, 4)).astype(np.int32))
    shapes = _shapes(cfg, 8, 8, dtype=jnp.int32)
    plan = plan_attention(cfg, shapes)
    assert plan.backend == "int"
    mech = get_mechanism("inhibitor")
    out = execute_plan(plan, q, k, v, params=mech.make_params(
        score_scale=4.0, score_shift=1.0, normalize=False, kv_chunk=256))
    from repro.core.inhibitor import _repeat_kv
    qt = q.transpose(0, 2, 1, 3)
    kt = _repeat_kv(k, 2).transpose(0, 2, 1, 3)
    vt = _repeat_kv(v, 2).transpose(0, 2, 1, 3)
    ref = int_inhibitor_attention(qt, kt, vt, gamma_shift=2, alpha_q=1,
                                  signed=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.transpose(0, 2, 1, 3)))
    un = int_inhibitor_attention(qt, kt, vt, gamma_shift=2, alpha_q=1)
    assert np.any(np.asarray(out) != np.asarray(un.transpose(0, 2, 1, 3)))


def test_fhe_sim_backend_matches_circuit():
    from repro.fhe.circuits import inhibitor_attention_circuit

    rng = np.random.default_rng(3)
    q = rng.integers(-7, 8, (1, 4, 1, 2))
    k = rng.integers(-7, 8, (1, 4, 1, 2))
    v = rng.integers(-7, 8, (1, 4, 1, 2))
    cfg = _cfg("inhibitor", backend="fhe_sim", num_heads=1, num_kv_heads=1,
               head_dim=2, causal=False)
    shapes = AttnShapes(1, 4, 4, 1, 1, 2, dtype=jnp.int32)
    plan = plan_attention(cfg, shapes)
    assert plan.backend == "fhe_sim"
    mech = get_mechanism("inhibitor")
    out = execute_plan(plan, jnp.asarray(q, jnp.int32),
                       jnp.asarray(k, jnp.int32), jnp.asarray(v, jnp.int32),
                       params=mech.make_params(score_scale=None,
                                               score_shift=0.0,
                                               normalize=False,
                                               kv_chunk=256))
    # the signed mechanism's encrypted arm runs the signed circuit
    ref, _ = inhibitor_attention_circuit(q[0, :, 0], k[0, :, 0], v[0, :, 0],
                                         gamma_shift=1, alpha_q=1,
                                         signed=True)
    np.testing.assert_array_equal(np.asarray(out)[0, :, 0], ref)


# ---------------------------------------------------------------------------
# Deprecation shims (PR 1): kind / use_kernel warn once and plan exactly
# like their explicit replacements
# ---------------------------------------------------------------------------

def test_kind_shim_warns_once_and_plans_like_mechanism():
    import warnings as W

    import repro.core.mechanism as M

    M._kind_warned = False                  # re-arm the one-shot warning
    legacy = AttentionConfig(kind="inhibitor_unsigned")
    explicit = AttentionConfig(mechanism="inhibitor_unsigned")
    shapes = _shapes(explicit, 16, 16)
    with pytest.warns(DeprecationWarning, match="kind is deprecated"):
        plan_legacy = plan_attention(legacy, shapes)
    plan_explicit = plan_attention(explicit, shapes)
    assert plan_legacy == plan_explicit     # identical mechanism+backend+reason
    # one-shot: a second legacy resolve stays silent
    with W.catch_warnings():
        W.simplefilter("error")
        assert plan_attention(legacy, shapes) == plan_explicit


def test_kind_default_is_dotprod_without_warning():
    import warnings as W

    with W.catch_warnings():
        W.simplefilter("error")
        cfg = AttentionConfig()             # neither mechanism nor kind
        plan = plan_attention(cfg, _shapes(cfg, 8, 8))
    assert plan.mechanism == "dotprod"


def test_use_kernel_shim_plans_like_explicit_pallas():
    import repro.core.mechanism as M

    M._use_kernel_warned = False
    shimmed_cfg = _cfg("inhibitor", use_kernel=True)
    explicit_cfg = _cfg("inhibitor", backend="pallas")
    shapes = _shapes(shimmed_cfg, 32, 32, platform="tpu")
    with pytest.warns(DeprecationWarning, match="use_kernel"):
        shimmed = plan_attention(shimmed_cfg, shapes)
    explicit = plan_attention(explicit_cfg, shapes)
    assert (shimmed.mechanism, shimmed.backend) \
        == (explicit.mechanism, explicit.backend) == ("inhibitor", "pallas")


# ---------------------------------------------------------------------------
# Leaf-change extensibility: a fourth mechanism registers once and the
# whole layer stack picks it up (the redesign's raison d'être)
# ---------------------------------------------------------------------------

def test_new_mechanism_is_a_leaf_change(rng):
    def mean_pool(q, k, v, *, mask=None, params=None, structural=None):
        from repro.core.inhibitor import _repeat_kv
        vt = _repeat_kv(v, q.shape[2] // v.shape[2]).astype(jnp.float32)
        if mask is not None:
            m = jnp.broadcast_to(mask, (q.shape[0], q.shape[2], q.shape[1],
                                        k.shape[1])).astype(jnp.float32)
            num = jnp.einsum("bhqk,bkhd->bqhd", m, vt)
            den = jnp.maximum(m.sum(-1), 1.0)
            return (num / den.transpose(0, 2, 1)[..., None]).astype(q.dtype)
        return jnp.broadcast_to(vt.mean(axis=1, keepdims=True),
                                q.shape).astype(q.dtype)

    register_mechanism(Mechanism(
        name="_test_meanpool", description="uniform-average stub",
        mask_semantics="exclude", vjp="autodiff",
        backends={"naive": mean_pool, "fused": mean_pool}),
        overwrite=True)
    try:
        cfg = _cfg("_test_meanpool")
        params = _layer("_test_meanpool")
        x = jnp.asarray(rng.normal(size=(2, 6, 32)).astype(np.float32))
        y, _ = apply_attention(params, cfg, x)
        assert y.shape == (2, 6, 32) and bool(jnp.isfinite(y).all())
        plan = plan_attention(cfg, _shapes(cfg, 6, 6))
        assert plan == ExecutionPlan("_test_meanpool", "fused",
                                     "dense default")
    finally:
        import repro.core.mechanism as M
        M._REGISTRY.pop("_test_meanpool", None)
