"""Multi-device semantics on 8 CPU devices (subprocess: the device count
must be set before jax initializes, and other tests need 1 device)."""

import os
import subprocess
import sys
import textwrap

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str):
    code = textwrap.dedent("""
        import sys
        sys.path.insert(0, %r)
        import jax, jax.numpy as jnp, numpy as np
        assert len(jax.devices()) == 8, jax.devices()
    """) % os.path.join(_ROOT, "src") + textwrap.dedent(body)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"


def test_compressed_grad_sync():
    _run("""
        from repro.distributed.collectives import compressed_grad_sync
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        g = {"a": jnp.arange(8.0), "b": jnp.ones((3, 3))}
        out = compressed_grad_sync(g, mesh)
        np.testing.assert_allclose(np.asarray(out["a"]), np.arange(8.0),
                                   rtol=0.02, atol=0.02)
    """)


def test_ring_allgather_matmul():
    _run("""
        from repro.distributed.collectives import allgather_matmul
        rng = np.random.default_rng(0)
        # n and k must divide the 8-way axis (x k-sharded, w n-sharded)
        x = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
        mesh = jax.make_mesh((8,), ("model",))
        y = allgather_matmul(x, w, mesh)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                                   rtol=1e-5, atol=1e-5)
    """)


def test_pipeline_parallel_gpipe():
    _run("""
        from repro.distributed.pipeline import pipeline_apply
        rng = np.random.default_rng(0)
        S, M, mb, dim = 4, 8, 2, 16
        pmesh = jax.make_mesh((4,), ("pipe",))
        Ws = jnp.asarray(rng.normal(size=(S, dim, dim)).astype(np.float32)) * 0.5
        xs = jnp.asarray(rng.normal(size=(M, mb, dim)).astype(np.float32))
        y = pipeline_apply(lambda w, x: jnp.tanh(x @ w), Ws, xs, pmesh,
                           num_microbatches=M)
        ref = xs
        for s in range(S):
            ref = jnp.tanh(ref @ Ws[s])
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
    """)


def test_sharded_train_step_matches_single_device():
    """The pjit'd train step on a 2x2x2 (pod,data,model) mesh produces the
    same loss/params as single-device execution."""
    _run("""
        from repro.configs import get_config
        from repro.distributed.sharding import use_mesh
        from repro.launch import shardings as shlib
        from repro.models.registry import get_model
        from repro.optim import AdamWConfig, init_adamw
        from repro.train.step import init_train_state, make_train_step
        from repro.nn.module import unbox, axes_of

        cfg = get_config("smollm-135m").reduced(
            num_layers=2, d_model=32, d_ff=64, vocab_size=128,
            num_heads=4, num_kv_heads=2, head_dim=8)
        api = get_model(cfg)
        opt_cfg = AdamWConfig(lr=1e-3)
        rngp = np.random.default_rng(0)
        batch = {
            "tokens": jnp.asarray(rngp.integers(0, 128, (8, 16)).astype(np.int32)),
            "labels": jnp.asarray(rngp.integers(0, 128, (8, 16)).astype(np.int32)),
        }

        params, opt_state, _ = init_train_state(api, opt_cfg,
                                                jax.random.PRNGKey(0))
        step = make_train_step(api, opt_cfg)
        p1, o1, m1 = jax.jit(step)(params, opt_state, batch)

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        with use_mesh(mesh):
            boxed = jax.eval_shape(api.init, jax.random.PRNGKey(0))
            _, psh = shlib.params_shardings(boxed, mesh)
            ost = jax.eval_shape(lambda p: init_adamw(p, opt_cfg), params)
            osh = shlib.opt_shardings(ost, psh, mesh)
            bsh = shlib.batch_shardings(
                {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                 for k, v in batch.items()}, mesh)
            jstep = jax.jit(step, in_shardings=(psh, osh, bsh),
                            out_shardings=(psh, osh, None))
            pp = jax.device_put(params, psh)
            oo = jax.device_put(opt_state, osh)
            bb = {k: jax.device_put(v, bsh[k]) for k, v in batch.items()}
            p2, o2, m2 = jstep(pp, oo, bb)

        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3, (
            float(m1["loss"]), float(m2["loss"]))
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=2e-3)
        print("sharded == single-device OK")
    """)


def test_dryrun_single_cell_multipod():
    """A small arch lowers+compiles on the 2x16x16 multi-pod mesh."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "smollm-135m", "--shape", "decode_32k", "--multi-pod",
         "--out-dir", os.path.join(_ROOT, "experiments", "dryrun_test")],
        env={**env, "PYTHONPATH": os.path.join(_ROOT, "src")},
        capture_output=True, text=True, timeout=900, cwd=_ROOT)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "[OK]" in r.stdout
