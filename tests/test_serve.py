"""Serving engine: continuous batching == sequential greedy decode, plus
the admission/overflow bug regressions (EOS on the first prefill token,
max_len hard-stop, submit-time rejection) and the greedy/sampling switch.

Shared fixtures (``serve_model``, ``greedy_ref``) live in conftest.py.
"""

import numpy as np
import pytest

from repro.serve.engine import Engine, EngineConfig, Request


@pytest.mark.parametrize("allocator", ["contiguous", "paged"])
def test_engine_matches_sequential_greedy(rng, serve_model, greedy_ref,
                                          allocator):
    cfg, api, params = serve_model
    eng = Engine(api, params, EngineConfig(max_batch=4, max_len=64,
                                           allocator=allocator,
                                           prefill_chunk=8))
    lens = (5, 3, 7, 5, 4, 6)   # ragged + recycling (6 reqs, 4 slots)
    prompts = [rng.integers(0, cfg.vocab_size, (l,)).astype(np.int32)
               for l in lens]
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new_tokens=6))
    done = eng.run_to_completion()
    assert len(done) == len(prompts)
    for r in done:
        assert r.output == greedy_ref(prompts[r.request_id], 6)


def test_engine_eos_early_stop(rng, serve_model, greedy_ref):
    cfg, api, params = serve_model
    eng = Engine(api, params, EngineConfig(max_batch=2, max_len=64))
    prompt = rng.integers(0, cfg.vocab_size, (4,)).astype(np.int32)
    eos = greedy_ref(prompt, 8)[2]
    eng.submit(Request(0, prompt, max_new_tokens=8, eos_id=eos))
    done = eng.run_to_completion()
    assert done[0].output[-1] == eos and len(done[0].output) <= 8


def test_eos_on_first_prefill_token_finishes_at_admission(rng, serve_model,
                                                          greedy_ref):
    """Regression: a request whose very first (prefill-produced) token is
    eos_id used to sit in its slot until the next decode tick appended a
    second token past EOS."""
    cfg, api, params = serve_model
    prompt = rng.integers(0, cfg.vocab_size, (5,)).astype(np.int32)
    eos = greedy_ref(prompt, 1)[0]
    eng = Engine(api, params, EngineConfig(max_batch=2, max_len=64))
    eng.submit(Request(0, prompt, max_new_tokens=8, eos_id=eos))
    done = eng.step()                       # one tick, admission included
    assert [r.request_id for r in done] == [0]
    assert done[0].output == [eos]          # nothing generated past EOS
    assert not eng.active                   # slot freed same-tick
    assert all(s.done for s in eng.alloc.slots)


def test_max_new_tokens_one_finishes_at_admission(rng, serve_model,
                                                  greedy_ref):
    cfg, api, params = serve_model
    prompt = rng.integers(0, cfg.vocab_size, (4,)).astype(np.int32)
    eng = Engine(api, params, EngineConfig(max_batch=2, max_len=64))
    eng.submit(Request(0, prompt, max_new_tokens=1))
    done = eng.step()
    assert len(done) == 1 and len(done[0].output) == 1
    assert done[0].output == greedy_ref(prompt, 1)


def test_submit_rejects_overlong_prompt(rng, serve_model):
    cfg, api, params = serve_model
    eng = Engine(api, params, EngineConfig(max_batch=2, max_len=16))
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(0, rng.integers(0, cfg.vocab_size,
                                           (16,)).astype(np.int32)))
    with pytest.raises(ValueError, match="empty"):
        eng.submit(Request(1, np.zeros((0,), np.int32)))


@pytest.mark.parametrize("allocator", ["contiguous", "paged"])
def test_decode_hard_stops_at_max_len(rng, serve_model, greedy_ref,
                                      allocator):
    """Regression: generation past max_len used to clamp the KV write
    offset and silently corrupt the newest rows; now the slot hard-stops
    with ``truncated`` set and the prefix stays exact."""
    cfg, api, params = serve_model
    max_len, plen = 24, 8
    eng = Engine(api, params, EngineConfig(max_batch=2, max_len=max_len,
                                           allocator=allocator,
                                           prefill_chunk=8))
    prompt = rng.integers(0, cfg.vocab_size, (plen,)).astype(np.int32)
    eng.submit(Request(0, prompt, max_new_tokens=64))
    done = eng.run_to_completion()
    assert done[0].truncated
    # prefill emits 1 token at length plen; each decode tick consumes one
    # KV row until length == max_len
    assert len(done[0].output) == max_len - plen + 1
    ref = greedy_ref(prompt, len(done[0].output), max_len=64)
    assert done[0].output == ref            # exact prefix, no corruption


def test_slot_recycling_with_interleaved_submits(rng, serve_model,
                                                 greedy_ref):
    """Slots recycled mid-run must not leak stale cursors into the next
    request (late submits land in previously-used slots)."""
    cfg, api, params = serve_model
    eng = Engine(api, params, EngineConfig(max_batch=2, max_len=64,
                                           prefill_chunk=8))
    prompts = [rng.integers(0, cfg.vocab_size, (l,)).astype(np.int32)
               for l in (3, 9, 5, 12, 7)]
    for i in (0, 1):
        eng.submit(Request(i, prompts[i], max_new_tokens=4))
    done = []
    for _ in range(3):
        done.extend(eng.step())
    for i in (2, 3, 4):                     # recycled slots, longer prompts
        eng.submit(Request(i, prompts[i], max_new_tokens=4))
    done.extend(eng.run_to_completion())
    assert sorted(r.request_id for r in done) == [0, 1, 2, 3, 4]
    for r in done:
        assert r.output == greedy_ref(prompts[r.request_id], 4)


def test_greedy_flag_wires_sampling(rng, serve_model, greedy_ref):
    """EngineConfig.greedy=False routes through temperature sampling; a
    near-zero temperature recovers the greedy outputs, a hot one runs."""
    cfg, api, params = serve_model
    prompt = rng.integers(0, cfg.vocab_size, (5,)).astype(np.int32)
    ref = greedy_ref(prompt, 5)

    cold = Engine(api, params, EngineConfig(max_batch=2, max_len=64,
                                            greedy=False, temperature=1e-5))
    cold.submit(Request(0, prompt, max_new_tokens=5))
    assert cold.run_to_completion()[0].output == ref

    hot = Engine(api, params, EngineConfig(max_batch=2, max_len=64,
                                           greedy=False, temperature=5.0))
    hot.submit(Request(0, prompt, max_new_tokens=5))
    out = hot.run_to_completion()[0].output
    assert len(out) == 5
    assert all(0 <= t < cfg.vocab_size for t in out)


def test_paged_default_degrades_for_forced_backend(rng, serve_model,
                                                   greedy_ref):
    """A config that forces a non-paged backend cannot use the paged pool;
    the engine must degrade to contiguous slots, not crash at init."""
    import dataclasses

    cfg, api, params = serve_model
    forced = dataclasses.replace(cfg, attention=dataclasses.replace(
        cfg.attention, backend="fused"))
    api_forced = api._replace(cfg=forced)
    eng = Engine(api_forced, params, EngineConfig(max_batch=2, max_len=64,
                                                  allocator="paged"))
    assert not eng.paged
    prompt = rng.integers(0, cfg.vocab_size, (5,)).astype(np.int32)
    eng.submit(Request(0, prompt, max_new_tokens=4))
    assert eng.run_to_completion()[0].output == greedy_ref(prompt, 4)
