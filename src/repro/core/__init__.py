"""Core: the paper's contribution — inhibitor attention — and its baseline."""

from repro.core.attention import (  # noqa: F401
    AttentionConfig,
    KVCache,
    apply_attention,
    init_attention,
    init_kv_cache,
)
from repro.core.dotprod import dot_product_attention  # noqa: F401
from repro.core.inhibitor import (  # noqa: F401
    inhibit_fused,
    inhibit_naive,
    inhibit_signed_fused,
    inhibit_signed_naive,
    inhibitor_attention,
    inhibitor_attention_chunked,
    manhattan_scores,
)
