"""Blocked inhibitor attention in pure XLA — flash-structured, exact.

The fused eq. 9/10 forms contract (nq, nk, d) difference cubes.  XLA:TPU
fuses those into their reduces, but (a) XLA:CPU materializes them (this is
where the dry-run's memory proof runs), and (b) reverse-mode autodiff keeps
cube-sized residuals on every backend.  This module is the production
XLA-level answer, mirroring the Pallas kernel's structure one level up:

  * forward: two-level ``lax.scan`` over query-chunks × key-chunks; each
    chunk evaluates the masked fused inhibition on a (cq, ck, d) tile.
    Because inhibition is a plain sum over keys (no Softmax normalizer),
    chunk accumulation is exact.
  * backward: an outer ``jax.custom_vjp`` — residuals are just (q, k, v)
    — with two loop nests of the *analytic* chunk gradients
    (indicator-based; see core.inhibitor._make_inhibitor_core):
    dq is accumulated per query-chunk over key-chunks; dk/dv per key-chunk
    over query-chunks.  No cube or score matrix ever outlives a chunk.
  * masking (causal / sliding window / kv-valid-length) is computed from
    chunk indices via iota inside the chunk — no (nq, nk) mask arrays in
    HBM, which also makes the 500k-token decode shape tractable.

Chunk sizes bound the live tile to ~cq·ck·d floats; defaults keep that in
the tens of MB per device at production shapes.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

DEFAULT_CHUNK_Q = 512
DEFAULT_CHUNK_K = 512


CUBE_BUDGET_BYTES = 384 * 1024 * 1024


def _auto_chunks(b: int, h: int, d: int, chunk_q: int, chunk_k: int):
    """Shrink (chunk_q, chunk_k) until the per-device difference cube fits
    CUBE_BUDGET_BYTES, given the active mesh's sharding of batch/heads."""
    from repro.distributed.sharding import current_mesh

    mesh = current_mesh()
    bl, hl = b, h
    if mesh is not None:
        dp = 1
        for ax in ("pod", "data"):
            if ax in mesh.axis_names:
                dp *= mesh.shape[ax]
        if b % dp == 0:
            bl = b // dp
        mp = mesh.shape.get("model", 1)
        if h % mp == 0 and h >= mp:
            hl = h // mp
    while (bl * hl * chunk_q * chunk_k * d * 4 > CUBE_BUDGET_BYTES
           and (chunk_q > 64 or chunk_k > 64)):
        if chunk_k >= chunk_q and chunk_k > 64:
            chunk_k //= 2
        else:
            chunk_q //= 2
    return max(chunk_q, 8), max(chunk_k, 8)


def _pad_to(x, mult, axis):
    pad = -x.shape[axis] % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _chunk_mask(q0, k0, cq, ck, *, causal, window, kv_len, q_offset):
    """(cq, ck) float mask for the chunk at (query q0, key k0); the
    causal/window structure comes from the shared predicate in
    core.attention (one window-implies-causal semantics everywhere)."""
    from repro.core.attention import structural_mask_predicate

    qi = q0 + q_offset + jax.lax.broadcasted_iota(jnp.int32, (cq, ck), 0)
    kj = k0 + jax.lax.broadcasted_iota(jnp.int32, (cq, ck), 1)
    m = kj < kv_len
    structural = structural_mask_predicate(causal, window, qi, kj)
    if structural is not None:
        m = m & structural
    return m.astype(jnp.float32)


def _chunk_fwd(qc, kc, vc, mf, *, gamma, shift, signed):
    """Masked fused inhibition for one (cq, ck) tile.

    qc: (b, h, cq, d); kc, vc: (b, hk, ck, d); mf: (cq, ck).
    Returns (partial H (b, h, cq, d), counts (cq,)).
    """
    z = jnp.sum(jnp.abs(qc[..., :, None, :] - kc[..., None, :, :]),
                axis=-1) * (1.0 / gamma)                 # (b, h, cq, ck)
    if shift:
        z = jax.nn.relu(z - shift)
    col_v = jnp.einsum("qk,bhkd->bhqd", mf, vc)
    mb = mf[None, None, :, :, None]
    if signed:
        vp = jax.nn.relu(vc)
        vn = vc - vp
        t_pos = jnp.sum(jnp.abs(vp[..., None, :, :] - z[..., None]) * mb,
                        axis=-2)
        t_neg = jnp.sum(jnp.abs(-vn[..., None, :, :] - z[..., None]) * mb,
                        axis=-2)
        part = 0.5 * (col_v + t_pos - t_neg)
    else:
        row_z = jnp.sum(z * mf[None, None], axis=-1)
        cross = jnp.sum(jnp.abs(vc[..., None, :, :] - z[..., None]) * mb,
                        axis=-2)
        part = 0.5 * (col_v - row_z[..., None] + cross)
    return part, jnp.sum(mf, axis=-1)


def _chunk_bwd(qc, kc, vc, mf, gc, *, gamma, shift, signed):
    """Analytic chunk gradients. gc: (b, h, cq, d) upstream (already /count).

    Returns (dq_c (b, h, cq, d), dk_c (b, h, ck, d), dv_c (b, h, ck, d)).
    """
    raw = jnp.sum(jnp.abs(qc[..., :, None, :] - kc[..., None, :, :]),
                  axis=-1) * (1.0 / gamma)
    z = jax.nn.relu(raw - shift) if shift else raw
    zc = z[..., None]                                    # (b, h, cq, ck, 1)
    gm = gc[..., :, None, :] * mf[None, None, :, :, None]
    if signed:
        vp = jax.nn.relu(vc)
        vn = vc - vp
        A = vp[..., None, :, :] > zc
        B_ = vn[..., None, :, :] + zc < 0
        ind_v = jnp.where(vc[..., None, :, :] > 0, A, B_)
        dv = jnp.sum(jnp.where(ind_v, gm, 0.0), axis=-3)
        s = jnp.sum(jnp.where(B_, gm, 0.0) - jnp.where(A, gm, 0.0), axis=-1)
    else:
        A = vc[..., None, :, :] > zc
        dv = jnp.sum(jnp.where(A, gm, 0.0), axis=-3)
        s = -jnp.sum(jnp.where(A, gm, 0.0), axis=-1)
    t = s * (1.0 / gamma)
    if shift:
        t = t * (raw > shift)
    sgn = jnp.sign(qc[..., :, None, :] - kc[..., None, :, :])
    dq = jnp.sum(t[..., None] * sgn, axis=-2)
    dk = -jnp.sum(t[..., None] * sgn, axis=-3)
    return dq, dk, dv


@functools.lru_cache(maxsize=None)
def _make_blocked(gamma: float, shift: float, signed: bool, normalize: bool,
                  causal: bool, window: Optional[int], cq: int, ck: int,
                  nq_chunks: int, nk_chunks: int):
    """custom_vjp'd blocked core over padded (b, h, nq, d) / (b, h, nk, d).

    Tensors keep the natural (batch, heads, seq, dim) layout end-to-end so
    SPMD sharding (batch->data, heads->model) propagates without relayout;
    ``q_offset`` / ``kv_len`` are dynamic int32 operands (decode passes the
    traced cache cursor)."""

    def fwd_math(q, k, v, q_offset, kv_len):
        mask_kw = dict(causal=causal, window=window, kv_len=kv_len,
                       q_offset=q_offset)
        b, h, nq, d = q.shape

        def q_iter(qi, _):
            qc = jax.lax.dynamic_slice_in_dim(q, qi * cq, cq, 2)

            def k_iter(carry, kj):
                acc, cnt = carry
                kc = jax.lax.dynamic_slice_in_dim(k, kj * ck, ck, 2)
                vc = jax.lax.dynamic_slice_in_dim(v, kj * ck, ck, 2)
                mf = _chunk_mask(qi * cq, kj * ck, cq, ck, **mask_kw)
                part, c = _chunk_fwd(qc, kc, vc, mf, gamma=gamma,
                                     shift=shift, signed=signed)
                return (acc + part, cnt + c), None

            acc0 = jnp.zeros((b, h, cq, d), jnp.float32)
            cnt0 = jnp.zeros((cq,), jnp.float32)
            (acc, cnt), _ = jax.lax.scan(k_iter, (acc0, cnt0),
                                         jnp.arange(nk_chunks))
            if normalize:
                acc = acc / jnp.maximum(cnt, 1.0)[None, None, :, None]
            return qi + 1, acc

        _, out = jax.lax.scan(q_iter, 0, None, length=nq_chunks)
        # out: (nq_chunks, b, h, cq, d) -> (b, h, nq, d)
        return out.transpose(1, 2, 0, 3, 4).reshape(b, h, nq_chunks * cq, d)

    @jax.custom_vjp
    def core(q, k, v, q_offset, kv_len):
        return fwd_math(q, k, v, q_offset, kv_len)

    def core_fwd(q, k, v, q_offset, kv_len):
        return (fwd_math(q, k, v, q_offset, kv_len),
                (q, k, v, q_offset, kv_len))

    def core_bwd(res, g):
        q, k, v, q_offset, kv_len = res
        mask_kw = dict(causal=causal, window=window, kv_len=kv_len,
                       q_offset=q_offset)
        b, h, nq, d = q.shape
        gf = g.astype(jnp.float32)

        if normalize:
            # recompute per-query counts (cheap: mask only, no scores)
            def cnt_q(qi, _):
                def cnt_k(c, kj):
                    mf = _chunk_mask(qi * cq, kj * ck, cq, ck, **mask_kw)
                    return c + jnp.sum(mf, axis=-1), None
                c, _ = jax.lax.scan(cnt_k, jnp.zeros((cq,), jnp.float32),
                                    jnp.arange(nk_chunks))
                return qi + 1, c
            _, cnts = jax.lax.scan(cnt_q, 0, None, length=nq_chunks)
            cnts = cnts.reshape(nq_chunks * cq)
            gf = gf / jnp.maximum(cnts, 1.0)[None, None, :, None]

        # pass 1: dq per query-chunk (loop over key-chunks)
        def dq_iter(qi, _):
            qc = jax.lax.dynamic_slice_in_dim(q, qi * cq, cq, 2)
            gc = jax.lax.dynamic_slice_in_dim(gf, qi * cq, cq, 2)

            def k_iter(acc, kj):
                kc = jax.lax.dynamic_slice_in_dim(k, kj * ck, ck, 2)
                vc = jax.lax.dynamic_slice_in_dim(v, kj * ck, ck, 2)
                mf = _chunk_mask(qi * cq, kj * ck, cq, ck, **mask_kw)
                dq_c, _, _ = _chunk_bwd(qc, kc, vc, mf, gc, gamma=gamma,
                                        shift=shift, signed=signed)
                return acc + dq_c, None

            acc, _ = jax.lax.scan(k_iter,
                                  jnp.zeros((b, h, cq, d), jnp.float32),
                                  jnp.arange(nk_chunks))
            return qi + 1, acc

        _, dq = jax.lax.scan(dq_iter, 0, None, length=nq_chunks)
        dq = dq.transpose(1, 2, 0, 3, 4).reshape(b, h, nq, d)

        # pass 2: dk/dv per key-chunk (loop over query-chunks)
        def dkv_iter(kj, _):
            kc = jax.lax.dynamic_slice_in_dim(k, kj * ck, ck, 2)
            vc = jax.lax.dynamic_slice_in_dim(v, kj * ck, ck, 2)

            def q_iter2(carry, qi):
                dk_a, dv_a = carry
                qc = jax.lax.dynamic_slice_in_dim(q, qi * cq, cq, 2)
                gc = jax.lax.dynamic_slice_in_dim(gf, qi * cq, cq, 2)
                mf = _chunk_mask(qi * cq, kj * ck, cq, ck, **mask_kw)
                _, dk_c, dv_c = _chunk_bwd(qc, kc, vc, mf, gc, gamma=gamma,
                                           shift=shift, signed=signed)
                return (dk_a + dk_c, dv_a + dv_c), None

            z = jnp.zeros((b, h, ck, d), jnp.float32)
            (dk_a, dv_a), _ = jax.lax.scan(q_iter2, (z, z),
                                           jnp.arange(nq_chunks))
            return kj + 1, (dk_a, dv_a)

        _, (dk, dv) = jax.lax.scan(dkv_iter, 0, None, length=nk_chunks)
        dk = dk.transpose(1, 2, 0, 3, 4).reshape(b, h, nk_chunks * ck, d)
        dv = dv.transpose(1, 2, 0, 3, 4).reshape(b, h, nk_chunks * ck, d)
        f0 = jnp.zeros((), jax.dtypes.float0)
        return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
                f0, f0)

    core.defvjp(core_fwd, core_bwd)
    return core


def blocked_inhibitor_attention(
    q: jax.Array,            # (b, n_q, h, d)
    k: jax.Array,            # (b, n_k, h_kv, d)
    v: jax.Array,
    *,
    score_scale: Optional[float] = None,
    score_shift: float = 0.5,
    signed: bool = True,
    normalize: bool = True,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset=0,
    kv_valid_len=None,
    chunk_q: int = DEFAULT_CHUNK_Q,
    chunk_k: int = DEFAULT_CHUNK_K,
) -> jax.Array:
    """Flash-structured inhibitor attention (exact; structural masks only).

    Equivalent to :func:`repro.core.inhibitor.inhibitor_attention` with a
    causal/sliding-window/valid-length mask; O(chunk²·d) live memory.
    Layout stays (batch, heads, seq, dim) throughout — batch shards over
    ("pod","data") and heads over "model" with zero collectives inside the
    chunk loops.
    """
    from repro.core.inhibitor import _repeat_kv
    from repro.distributed.sharding import constrain

    b, n_q, h, d = q.shape
    n_k, h_kv = k.shape[1], k.shape[2]
    gamma = score_scale if score_scale is not None else float(d) ** 0.5
    kv_len = kv_valid_len if kv_valid_len is not None else n_k

    k = _repeat_kv(k, h // h_kv)
    v = _repeat_kv(v, h // h_kv)
    qt = constrain(q.transpose(0, 2, 1, 3), "batch", "heads")
    kt = constrain(k.transpose(0, 2, 1, 3), "batch", "heads")
    vt = constrain(v.transpose(0, 2, 1, 3), "batch", "heads")

    # adapt chunk sizes to the per-device tile: the live (bl, hl, cq, ck, d)
    # cube should stay within ~CUBE_BUDGET bytes even where the backend
    # materializes it (XLA:CPU; TPU fuses it into the reduces)
    chunk_q, chunk_k = _auto_chunks(b, h, d, chunk_q, chunk_k)
    cq = min(chunk_q, n_q)
    ck = min(chunk_k, n_k)
    qt = _pad_to(qt, cq, 2)
    kt = _pad_to(kt, ck, 2)
    vt = _pad_to(vt, ck, 2)
    nq_chunks = qt.shape[2] // cq
    nk_chunks = kt.shape[2] // ck

    core = _make_blocked(float(gamma), float(score_shift), bool(signed),
                         bool(normalize), bool(causal),
                         None if window is None else int(window),
                         cq, ck, nq_chunks, nk_chunks)
    out = core(qt, kt, vt, jnp.asarray(q_offset, jnp.int32),
               jnp.asarray(kv_len, jnp.int32))[:, :, :n_q]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)
