"""Token embedding with optional logit-tying, sharded over ("vocab","embed")."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn import init as initializers
from repro.nn.module import Param


def init_embedding(key, vocab_size: int, embed_dim: int, *,
                   dtype=jnp.float32, stddev: float = 0.02) -> dict:
    table = initializers.embedding_init(stddev)(key, (vocab_size, embed_dim), dtype)
    return {"table": Param(table, ("vocab", "embed"))}


def apply_embedding(params: dict, token_ids: jax.Array,
                    compute_dtype=None) -> jax.Array:
    """Lookup: (..., ) int32 -> (..., embed)."""
    table = params["table"]
    if compute_dtype is not None:
        table = table.astype(compute_dtype)
    # take() lowers to a gather that shards cleanly over the vocab axis.
    return jnp.take(table, token_ids, axis=0)


def attend_logits(params: dict, x: jax.Array, compute_dtype=None) -> jax.Array:
    """Tied-softmax logits: (..., embed) @ table.T -> (..., vocab)."""
    table = params["table"]
    if compute_dtype is not None:
        table = table.astype(compute_dtype)
        x = x.astype(compute_dtype)
    return jnp.einsum("...d,vd->...v", x, table)


def init_positional(key, max_len: int, embed_dim: int, *,
                    dtype=jnp.float32, stddev: float = 0.02) -> dict:
    tab = initializers.embedding_init(stddev)(key, (max_len, embed_dim), dtype)
    return {"table": Param(tab, (None, "embed"))}


def apply_positional(params: dict, positions: jax.Array,
                     compute_dtype=None) -> jax.Array:
    table = params["table"]
    if compute_dtype is not None:
        table = table.astype(compute_dtype)
    return jnp.take(table, positions, axis=0)
