"""Serving-side KV cache management: slot + paged block-table allocators.

The engine keeps a fixed pool of ``max_batch`` slots it schedules against.
Two allocators back those slots:

``SlotAllocator`` (contiguous)
    Each slot owns a full ``max_len`` stride of the stacked
    (layers, batch, max_len, kv_heads, head_dim) cache buffers — memory for
    the worst case is reserved up front whether or not a request uses it.
    Kept as the baseline arm of ``benchmarks/serve_bench.py``.

``PagedAllocator`` (block tables)
    KV rows live in a shared pool of fixed-size pages
    (layers, num_pages, page_size, kv_heads, head_dim).  Each slot holds a
    block table mapping logical page index -> physical page; pages are
    handed out from a free list on demand as a request's cursor grows and
    reclaimed in O(pages-held) when the slot is released (free-list push,
    no compaction, no copying).  ``high_water_pages`` records the peak
    pool occupancy — the number the serving bench reports against the
    contiguous baseline's always-fully-reserved buffer.

    Physical page 0 is reserved as the *trash page*: inactive batch rows
    still flow through the jitted decode step (static shapes), and their
    garbage KV writes must land somewhere that no live slot owns.  Block
    tables are zeroed on release, so stale rows scatter into page 0, which
    is never allocated and never read (validity is cursor-defined).

    Pages are **reference counted** (DESIGN.md §11): the shared-prefix
    radix index (`serve.prefix.PrefixIndex`) and any number of slots may
    reference the same physical page.  ``map_shared`` points a slot's
    block table at already-populated pages (refcount++), ``release``
    decrements instead of freeing, and a page returns to the free list
    only when its count hits zero.  A slot may write into a mapped page
    only while it is the sole owner (``writable``); ``fork`` implements
    the copy-on-write half — a fresh page replaces the shared one in the
    slot's table and the *caller* copies the device pool rows.  When the
    free list runs dry, an attached reclaimer (the prefix index's LRU
    eviction) is asked to give pages back before allocation fails.

Both allocators expose the same scheduling surface (``claim`` /
``release`` / ``active`` / ``lengths`` / ``slots``); the paged one adds
``ensure(slot, length)`` for on-demand page growth and a ``block_tables``
array the engine mirrors into device state.

The host-side ``block_tables`` here is the single source of truth: the
engine pushes it to the device in batched whole-array uploads (at most
one per decode tick and one per prefill admission — bench-gated), and
the device side broadcasts that one mirror across the layer axis, which
is what makes the whole-model fused page gather in the decode step sound
(DESIGN.md §14).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class SlotState:
    request_id: Optional[int] = None
    length: int = 0
    done: bool = True


class SlotAllocator:
    """Contiguous allocator: slot i owns rows [i] of the cache buffers."""

    def __init__(self, max_batch: int):
        self.slots: List[SlotState] = [SlotState() for _ in range(max_batch)]

    def claim(self, request_id: int) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s.done:
                self.slots[i] = SlotState(request_id, 0, False)
                return i
        return None

    def release(self, slot: int):
        self.slots[slot] = SlotState()

    def active(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if not s.done]

    def lengths(self) -> np.ndarray:
        return np.array([s.length for s in self.slots], np.int32)


class PagedAllocator:
    """Block-table allocator over a shared, ref-counted page pool
    (vLLM-style).

    ``num_pages`` counts *physical* pages including the reserved trash
    page 0; usable capacity is ``num_pages - 1``.  The default sizing
    (``max_batch * pages_per_slot + 1``) can always hold every slot at
    ``max_len`` — undersize it to serve more slots than worst-case memory,
    at the cost of admission backpressure when the free list runs dry.
    """

    def __init__(self, max_batch: int, max_len: int, page_size: int = 16,
                 num_pages: Optional[int] = None):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.page_size = page_size
        self.max_len = max_len
        self.pages_per_slot = -(-max_len // page_size)
        if num_pages is None:
            num_pages = max_batch * self.pages_per_slot + 1
        if num_pages < 2:
            raise ValueError("num_pages must be >= 2 (page 0 is reserved)")
        self.num_pages = num_pages
        self.slots: List[SlotState] = [SlotState() for _ in range(max_batch)]
        self.block_tables = np.zeros((max_batch, self.pages_per_slot),
                                     np.int32)
        self._pages: List[List[int]] = [[] for _ in range(max_batch)]
        # LIFO free list (page 0 reserved as the trash page): pop from the
        # end so recently-released pages are reused while still cache-warm
        self.free: List[int] = list(range(num_pages - 1, 0, -1))
        # per-physical-page reference count: slots and the prefix index
        # each hold one reference per mapping (page 0 never counted)
        self.ref = np.zeros(num_pages, np.int32)
        self.high_water_pages = 0
        self._reclaim: Optional[Callable[[int], int]] = None

    @property
    def pages_in_use(self) -> int:
        return (self.num_pages - 1) - len(self.free)

    def attach_reclaimer(self, fn: Callable[[int], int]):
        """``fn(n)`` is asked to return >= ``n`` pages to the free list
        (by dropping its own references) when allocation runs dry — the
        prefix index's LRU eviction.  Best effort: it returns how many
        pages it actually freed."""
        self._reclaim = fn

    # ---- reference counting ----
    def addref(self, page: int):
        if page == 0:
            raise ValueError("page 0 is the reserved trash page")
        self.ref[page] += 1

    def decref(self, page: int) -> int:
        """Drop one reference; returns 1 if the page went back to the
        free list, 0 if other references keep it alive."""
        if self.ref[page] <= 0:
            raise RuntimeError(
                f"page {page} double-freed (refcount already 0)")
        self.ref[page] -= 1
        if self.ref[page] == 0:
            self.free.append(page)
            return 1
        return 0

    def _alloc_page(self, still_needed: int) -> Optional[int]:
        """Pop a fresh page (refcount 1), asking the reclaimer to evict
        cached pages when the free list is dry.  ``still_needed`` is a
        hint for how many more pages the current operation wants."""
        if not self.free and self._reclaim is not None:
            self._reclaim(max(still_needed, 1))
        if not self.free:
            return None
        page = self.free.pop()
        self.ref[page] = 1
        return page

    # ---- slot lifecycle ----
    def claim(self, request_id: int) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s.done:
                self.slots[i] = SlotState(request_id, 0, False)
                return i
        return None

    def held(self, slot: int) -> List[int]:
        """Physical pages mapped by ``slot`` in logical order."""
        return list(self._pages[slot])

    def map_shared(self, slot: int, pages: List[int]):
        """Point the slot's leading block-table entries at already-
        populated shared pages (prefix-cache hit): refcount++ each, no
        free-list traffic.  Must be called on a freshly claimed slot,
        before any ``ensure`` growth."""
        if self._pages[slot]:
            raise RuntimeError(
                f"map_shared on slot {slot} with {len(self._pages[slot])} "
                f"pages already mapped — shared prefixes mount at logical 0")
        if len(pages) > self.pages_per_slot:
            raise ValueError("shared prefix exceeds the per-slot table")
        for i, page in enumerate(pages):
            self.addref(page)
            self.block_tables[slot, i] = page
            self._pages[slot].append(page)

    def ensure(self, slot: int, length: int) -> Optional[bool]:
        """Grow ``slot``'s block table to cover ``length`` positions.

        Returns True if new pages were mapped, False if already covered,
        None if the free list ran dry — even after asking the reclaimer
        to evict (caller backpressures: requeue the request or hard-stop
        the generation).  Pages grabbed before an exhaustion are kept
        mapped — they are reclaimed with the slot.
        """
        need = -(-length // self.page_size)
        if need > self.pages_per_slot:
            return None
        grew = False
        held = self._pages[slot]
        while len(held) < need:
            page = self._alloc_page(need - len(held))
            if page is None:
                return None
            self.block_tables[slot, len(held)] = page
            held.append(page)
            grew = True
            # inside the loop so a partial growth that then runs dry still
            # counts toward the peak (those pages stay mapped)
            self.high_water_pages = max(self.high_water_pages,
                                        self.pages_in_use)
        return grew

    # ---- copy-on-write ----
    def writable(self, slot: int, logical: int) -> bool:
        """True when the slot is the sole owner of its ``logical``-th
        page — i.e. scattering KV rows into it cannot corrupt another
        slot's view or the prefix index's cached content."""
        return int(self.ref[self._pages[slot][logical]]) == 1

    def fork(self, slot: int, logical: int) -> Optional[Tuple[int, int]]:
        """Copy-on-write fork: replace the shared ``logical``-th page of
        ``slot`` with a fresh page (refcount 1) and drop the slot's
        reference on the shared one.  Returns ``(old, new)`` physical ids
        so the caller can copy the device pool rows (the allocator only
        does the accounting), or None if no page could be allocated."""
        old = self._pages[slot][logical]
        new = self._alloc_page(1)
        if new is None:
            return None
        self.decref(old)            # shared owners keep it alive
        self._pages[slot][logical] = new
        self.block_tables[slot, logical] = new
        self.high_water_pages = max(self.high_water_pages, self.pages_in_use)
        return old, new

    def release(self, slot: int):
        # O(pages-held) reclaim: drop one reference per mapped page (the
        # free-list push happens at refcount 0), zero the table
        for page in self._pages[slot]:
            self.decref(page)
        self._pages[slot] = []
        self.block_tables[slot] = 0
        self.slots[slot] = SlotState()

    def active(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if not s.done]

    def lengths(self) -> np.ndarray:
        return np.array([s.length for s in self.slots], np.int32)
