"""Benchmark trend: delta table between this run's ``BENCH_serve_*.json``
and the previous CI run's artifacts.

CI downloads the last successful run's serve-bench artifacts into a
directory and calls

  python benchmarks/trend.py --current . --previous prev/

which prints one row per tracked metric (tokens/s per allocator arm,
prefill compile counts, decode-tick wall time, prefix-hit rate) with the
old/new values and the percent delta.  Regressions beyond ``--warn-pct``
(default 10%) emit GitHub ``::warning::`` annotations — the step **never
fails**: CI-runner timing noise would make a hard gate flaky, but the
printed trajectory makes a real regression visible in every PR.  Missing
files (first run, renamed artifacts) are reported and skipped.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

# (file, json-path, label, higher_is_better) — json-path is dot-separated
METRICS = [
    ("BENCH_serve_smoke.json", "paged.tok_per_s",
     "serve paged tok/s", True),
    ("BENCH_serve_smoke.json", "contiguous.tok_per_s",
     "serve contiguous tok/s", True),
    ("BENCH_serve_smoke.json", "paged.prefill_compiles",
     "serve paged prefill compiles", False),
    ("BENCH_serve_smoke.json", "paged.decode_compiles",
     "serve paged decode compiles", False),
    ("BENCH_serve_smoke.json", "paged.table_uploads_per_tick",
     "serve table uploads/tick", False),
    ("BENCH_serve_decode.json", "gather.tick_us",
     "decode gather tick us", False),
    ("BENCH_serve_decode.json", "_kernel_tick_us",
     "decode kernel tick us", False),
    ("BENCH_serve_sustained.json", "arms.paged.full.tok_per_s",
     "sustained paged full-batch tok/s", True),
    ("BENCH_serve_sustained.json", "arms.contiguous.full.tok_per_s",
     "sustained contiguous full-batch tok/s", True),
    ("BENCH_serve_sustained.json", "scaling.paged",
     "sustained paged batch scaling", True),
    # tracing overhead (DESIGN.md §16) — warn-only drift tracking; the
    # hard enabled-within-budget gate lives inside serve_bench
    # --sustained ("tracing_enabled_budget")
    ("BENCH_serve_sustained.json", "tracing.overhead_pct",
     "serve tracing overhead %", False),
    ("BENCH_serve_sustained.json", "tracing.on.tok_per_s",
     "serve tracing-on tok/s", True),
    # open-loop latency SLOs (DESIGN.md §15) — warn-only here; the hard
    # interleaved-vs-whole p99-ITL gate lives inside serve_bench --latency
    ("BENCH_serve_latency.json", "arms.interleaved.ttft_ms.p50",
     "latency interleaved TTFT p50 ms", False),
    ("BENCH_serve_latency.json", "arms.interleaved.ttft_ms.p99",
     "latency interleaved TTFT p99 ms", False),
    ("BENCH_serve_latency.json", "arms.interleaved.itl_ms.p99",
     "latency interleaved ITL p99 ms", False),
    ("BENCH_serve_latency.json", "arms.whole.itl_ms.p99",
     "latency whole-admission ITL p99 ms", False),
    ("BENCH_serve_latency.json", "itl_p99_ratio",
     "latency ITL p99 ratio (interleaved/whole)", False),
    ("BENCH_serve_prefix.json", "arms.cache_on.tok_per_s",
     "prefix cache-on tok/s", True),
    ("BENCH_serve_prefix.json", "arms.cache_on.prefill_compiles",
     "prefix cache-on compiles", False),
    ("BENCH_serve_prefix.json", "_hit_rate",
     "prefix hit rate", True),
    # static circuit analysis (repro.analysis): count/width drift across
    # runs is a real circuit change, never timing noise — but the trend
    # step stays warn-only by design; the hard gates live in the analyzer
    ("ANALYSIS_fhe.json", "mechanisms.inhibitor.totals.pbs",
     "static inhibitor PBS/block", False),
    ("ANALYSIS_fhe.json", "mechanisms.inhibitor.totals.max_bits_at_pbs",
     "static inhibitor bits@pbs", False),
    ("ANALYSIS_fhe.json", "mechanisms.inhibitor.totals.cmuls",
     "static inhibitor cmuls", False),
    ("ANALYSIS_fhe.json", "mechanisms.dotprod.totals.cmuls",
     "static dotprod cmuls", False),
    ("ANALYSIS_fhe.json", "mechanisms.dotprod.totals.max_bits_at_pbs",
     "static dotprod bits@pbs", False),
    # serve-path static analysis (repro.analysis.serve): compile-set
    # size, per-tick sync counts, and the static decode byte budget —
    # drift is a hot-path change, never timing noise
    ("ANALYSIS_serve.json", "allocators.paged.retrace.proven_total",
     "serve proven compile set", False),
    ("ANALYSIS_serve.json", "sync_audit.per_tick.h2d",
     "serve per-tick h2d syncs", False),
    ("ANALYSIS_serve.json", "sync_audit.per_tick.d2h",
     "serve per-tick d2h syncs", False),
    ("ANALYSIS_serve.json", "allocators.paged.roofline.decode.max.hbm_bytes",
     "serve static decode bytes/tick", False),
]


def _lookup(doc, path):
    if path == "_hit_rate":            # derived: hit / total prompt tokens
        arm = doc["arms"]["cache_on"]
        total = arm["prefix_hit_tokens"] + arm["prefill_tokens"]
        return arm["prefix_hit_tokens"] / total if total else 0.0
    if path == "_kernel_tick_us":
        # interpret-mode Pallas timings (hosts with no native lowering
        # for the paged family) are not comparable wall times — skip the
        # row rather than annotate a meaningless "regression"
        if doc["kernel"].get("interpret"):
            raise ValueError("interpret-mode timing, not comparable")
        return doc["kernel"]["tick_us"]
    cur = doc
    for key in path.split("."):
        cur = cur[key]
    return cur


def _load(root, fname):
    path = os.path.join(root, fname)
    # artifact downloads sometimes nest one directory deep
    if not os.path.exists(path):
        nested = os.path.join(root, os.path.splitext(fname)[0], fname)
        path = nested if os.path.exists(nested) else path
    with open(path) as f:
        return json.load(f)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", default=".",
                    help="directory with this run's BENCH_serve_*.json")
    ap.add_argument("--previous", default="prev",
                    help="directory with the last run's artifacts")
    ap.add_argument("--warn-pct", type=float, default=10.0,
                    help="regression threshold for ::warning:: lines")
    ap.add_argument("--files", default=None,
                    help="comma-separated artifact filenames to restrict "
                         "the comparison to (e.g. ANALYSIS_fhe.json; "
                         "default: every tracked metric)")
    args = ap.parse_args(argv)

    if not os.path.isdir(args.previous):
        print(f"trend: no previous artifacts at {args.previous!r} — "
              f"nothing to compare (first run?)")
        return 0

    metrics = METRICS
    if args.files:
        wanted = {f.strip() for f in args.files.split(",") if f.strip()}
        unknown = wanted - {m[0] for m in METRICS}
        if unknown:
            print(f"trend: no tracked metrics in {sorted(unknown)} "
                  f"(tracked files: {sorted({m[0] for m in METRICS})})")
        metrics = [m for m in METRICS if m[0] in wanted]

    rows, warned = [], 0
    for fname, path, label, higher_better in metrics:
        try:
            cur = float(_lookup(_load(args.current, fname), path))
        except (OSError, KeyError, TypeError, ValueError) as e:
            print(f"trend: current {label}: unavailable ({e!r})")
            continue
        try:
            prev = float(_lookup(_load(args.previous, fname), path))
        except (OSError, KeyError, TypeError, ValueError):
            rows.append((label, None, cur, None, ""))
            continue
        delta = 100.0 * (cur - prev) / prev if prev else 0.0
        regressed = (delta < -args.warn_pct if higher_better
                     else delta > args.warn_pct)
        flag = "REGRESSED" if regressed else ""
        if regressed:
            warned += 1
            print(f"::warning::{label} regressed "
                  f"{abs(delta):.1f}% ({prev:g} -> {cur:g})")
        rows.append((label, prev, cur, delta, flag))

    width = max((len(r[0]) for r in rows), default=10)
    print(f"{'metric':<{width}}  {'previous':>10}  {'current':>10}  "
          f"{'delta':>8}")
    for label, prev, cur, delta, flag in rows:
        pv = f"{prev:g}" if prev is not None else "-"
        dv = f"{delta:+.1f}%" if delta is not None else "new"
        print(f"{label:<{width}}  {pv:>10}  {cur:>10g}  {dv:>8}  {flag}")
    print(f"trend: {warned} regression(s) beyond {args.warn_pct:.0f}% "
          f"(warn-only, never failing)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
