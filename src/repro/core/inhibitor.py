"""The Inhibitor attention mechanism (Brännvall & Stoian, FHE.org 2024).

This module is the paper's primary contribution, implemented in four
equivalent forms:

  * :func:`manhattan_scores`        — eq. 5 (+ shifted-score variant)
  * :func:`inhibit_naive`           — eq. 6, broadcast form (oracle)
  * :func:`inhibit_signed_naive`    — eq. 7, broadcast form (oracle)
  * :func:`inhibit_fused`           — eq. 9, cdist-decomposed form
  * :func:`inhibit_signed_fused`    — eq. 10, cdist-decomposed form
  * :func:`inhibitor_attention`     — full multi-head GQA entry point with
                                       masking and decode support
  * :func:`inhibitor_attention_chunked` — blockwise-streaming form (the
    structure the Pallas kernel implements; exact, no score matrix in HBM)

Notation follows the paper: ``Z[i,j] = (1/γ)·Σ_k |Q[i,k] − K[j,k]|`` with
γ = √d (``score_scale``), shifted score ``Z' = (Z − α)⁺`` with α ≥ 0
(``score_shift``); inhibition ``H[i,k] = Σ_j (V[j,k] − Z'[i,j])⁺``.

Masking: conventional attention masks scores with −inf before Softmax.
Inhibition suppresses an entry when Z is *large*, so masked (disallowed)
positions are assigned ``Z = +mask_value`` (a large positive constant,
chosen ≥ max|V| so the ReLU terms vanish identically — exact masking, not
approximate). For the signed form both ReLU terms vanish under the same
substitution.

All math is done in float32 regardless of input dtype (the sums of ReLU
terms are unnormalized and can reach seq_len·|V| magnitude, which overflows
fp16/bf16 mantissas long before Softmax attention would).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

# Large-Z mask constant: any Z ≥ max|V| suppresses exactly; we use a value
# far above any shifted score while staying well inside fp32 range so that
# (V − Z)⁺ ≡ 0 and (V⁻ + Z)⁻ ≡ 0 for masked pairs.
MASK_Z: float = 1e9


# ---------------------------------------------------------------------------
# Scores — eq. 5
# ---------------------------------------------------------------------------

def manhattan_scores(q: jax.Array, k: jax.Array, *,
                     score_scale: Optional[float] = None,
                     score_shift: float = 0.0) -> jax.Array:
    """Eq. 5 (+ shift): ``Z[... i, j] = ((1/γ)·Σ_d |q_i − k_j| − α)⁺``.

    q: (..., n_q, d), k: (..., n_k, d) -> (..., n_q, n_k), float32.
    """
    d = q.shape[-1]
    gamma = score_scale if score_scale is not None else float(d) ** 0.5
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    z = jnp.sum(jnp.abs(qf[..., :, None, :] - kf[..., None, :, :]), axis=-1)
    z = z / gamma
    if score_shift:
        z = jax.nn.relu(z - score_shift)
    return z


# ---------------------------------------------------------------------------
# Inhibition — eq. 6 / 7 (naive broadcast oracles)
# ---------------------------------------------------------------------------

def inhibit_naive(v: jax.Array, z: jax.Array) -> jax.Array:
    """Eq. 6: ``H[i,k] = Σ_j (V[j,k] − Z[i,j])⁺``.

    v: (..., n_k, d_v), z: (..., n_q, n_k) -> (..., n_q, d_v), float32.
    """
    vf = v.astype(jnp.float32)
    return jnp.sum(jax.nn.relu(vf[..., None, :, :] - z[..., :, :, None]),
                   axis=-2)


def inhibit_signed_naive(v: jax.Array, z: jax.Array) -> jax.Array:
    """Eq. 7: ``H[i,k] = Σ_j (V⁺−Z)⁺ + Σ_j (V⁻+Z)⁻`` (signed values)."""
    vf = v.astype(jnp.float32)
    vp = jax.nn.relu(vf)
    vn = vf - vp  # V⁻ = min(V, 0)
    t1 = jax.nn.relu(vp[..., None, :, :] - z[..., :, :, None])
    neg = vn[..., None, :, :] + z[..., :, :, None]
    t2 = jnp.minimum(neg, 0.0)  # x⁻ = min(x, 0)
    return jnp.sum(t1 + t2, axis=-2)


# ---------------------------------------------------------------------------
# Fused forms — eq. 9 / 10 (cdist decomposition; no n_q×n_k×d_v temporary)
# ---------------------------------------------------------------------------

def _abs_cross(a: jax.Array, b: jax.Array,
               mask: Optional[jax.Array] = None) -> jax.Array:
    """Σ over the pairing: |a[..., j, k] − b[..., i, j]| summed over j.

    a: (..., n_k, d_v), b: (..., n_q, n_k) -> (..., n_q, d_v).
    This is the pairwise-L1 ("cdist") contraction of eq. 9's last term.
    ``mask`` (..., n_q, n_k) weights each (i, j) pair (True = include) —
    masking is done by *exclusion from the sum*, never by adding large
    constants, which would be catastrophically cancellation-prone in the
    fused decomposition (the three eq. 9 terms individually reach
    n_k·MASK magnitude and only cancel in exact arithmetic).
    """
    cube = jnp.abs(a[..., None, :, :] - b[..., :, :, None])
    if mask is not None:
        cube = cube * mask[..., None].astype(cube.dtype)
    return jnp.sum(cube, axis=-2)


def _masked_col_v(vf: jax.Array, mask: Optional[jax.Array]) -> jax.Array:
    """Σ_j V[j,k] over attendable j: (..., n_q, d_v) (or (..., 1, d_v))."""
    if mask is None:
        return jnp.sum(vf, axis=-2, keepdims=True)
    return jnp.einsum("...ij,...jk->...ik", mask.astype(vf.dtype), vf)


def inhibit_fused(v: jax.Array, z: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Eq. 9: H = ½·Σ_j V − ½·Σ_j Z + ½·Σ_j |V − Z|  (≡ eq. 6).

    ``mask`` (..., n_q, n_k): True = attend. Masked pairs are excluded from
    all three sums (exact; contributes identically zero).
    """
    vf = v.astype(jnp.float32)
    col_v = _masked_col_v(vf, mask)
    zm = z if mask is None else z * mask.astype(z.dtype)
    row_z = jnp.sum(zm, axis=-1, keepdims=True)          # (..., n_q, 1)
    cross = _abs_cross(vf, z, mask)                      # (..., n_q, d_v)
    return 0.5 * (col_v - row_z + cross)


def inhibit_signed_fused(v: jax.Array, z: jax.Array,
                         mask: Optional[jax.Array] = None) -> jax.Array:
    """Eq. 10: H = ½·Σ_j V + ½·Σ_j|V⁺−Z| − ½·Σ_j|V⁻+Z|  (≡ eq. 7)."""
    vf = v.astype(jnp.float32)
    vp = jax.nn.relu(vf)
    vn = vf - vp
    col_v = _masked_col_v(vf, mask)
    t_pos = _abs_cross(vp, z, mask)
    t_neg = _abs_cross(-vn, z, mask)  # |V⁻ + Z| = |(−V⁻) − Z|
    return 0.5 * (col_v + t_pos - t_neg)


# ---------------------------------------------------------------------------
# Masking
# ---------------------------------------------------------------------------

def mask_scores(z: jax.Array, mask: Optional[jax.Array]) -> jax.Array:
    """Apply a boolean mask (True = attend) by setting Z to +MASK_Z."""
    if mask is None:
        return z
    return jnp.where(mask, z, MASK_Z)


def causal_mask(n_q: int, n_k: int, *, q_offset=0) -> jax.Array:
    """(n_q, n_k) boolean causal mask; q_offset shifts query positions
    (decode: query i sits at absolute position q_offset + i)."""
    qi = jnp.arange(n_q)[:, None] + q_offset
    kj = jnp.arange(n_k)[None, :]
    return kj <= qi


def sliding_window_mask(n_q: int, n_k: int, window: int, *, q_offset=0):
    qi = jnp.arange(n_q)[:, None] + q_offset
    kj = jnp.arange(n_k)[None, :]
    return (kj <= qi) & (kj > qi - window)


# ---------------------------------------------------------------------------
# Analytic custom VJP for the fused inhibition core
#
# Autodiff of the broadcast |q − k| / (V − Z)⁺ expressions saves the
# (nq, nk, d) *difference cubes* as residuals — hundreds of GB per chip at
# production shapes (the forward never materializes them thanks to XLA
# reduce-fusion, but reverse-mode keeps the primal of every abs()).  The
# derivatives, however, are themselves plain broadcast-compare-reduce
# contractions over the same operands:
#
#   unsigned  A_ijk = 1[V_jk > Z_ij]
#     dV_jk = Σ_i ĝ_ik m_ij A_ijk           ĝ = g / count (if normalized)
#     s_ij  = −m_ij Σ_k ĝ_ik A_ijk          (= dL/dZ_ij)
#   signed    A_ijk = 1[V⁺_jk > Z_ij],  B_ijk = 1[V⁻_jk + Z_ij < 0]
#     dV_jk = Σ_i ĝ_ik m_ij (V_jk > 0 ? A_ijk : B_ijk)
#     s_ij  = m_ij Σ_k ĝ_ik (B_ijk − A_ijk)
#   both      t_ij  = s_ij · 1[raw_ij > α] / γ       (shift-ReLU gate)
#     dq_id = Σ_j t_ij sign(q_id − k_jd)
#     dk_jd = −Σ_i t_ij sign(q_id − k_jd)
#
# Every contraction is again a fusable broadcast-select-reduce: the bwd
# recomputes Z in one fused pass and materializes only (nq, nk)- and
# operand-sized tensors.  This is what makes the inhibitor *trainable* at
# 4k–32k sequence lengths in pure XLA (measured: 725 GB -> a few GB per
# chip on the llama4-scout train_4k cell; EXPERIMENTS.md §Perf).
# ---------------------------------------------------------------------------


def _raw_scores(q, k, gamma):
    return jnp.sum(jnp.abs(q[..., :, None, :] - k[..., None, :, :]),
                   axis=-1) * (1.0 / gamma)


@functools.lru_cache(maxsize=None)
def _make_inhibitor_core(gamma: float, shift: float, signed: bool,
                         normalize: bool):
    """custom_vjp'd core: (qt, kt, vt, mask01) -> H, all (b, h, ...)."""

    def fwd_math(qt, kt, vt, mask01):
        from repro.distributed.sharding import constrain

        raw = _raw_scores(qt, kt, gamma)
        # scores shard heads over TP when divisible, else the query-seq
        # dim — never replicate the O(s²) tensor (DESIGN.md §6)
        raw = constrain(raw, "batch", "heads", "seq_sp")
        z = jax.nn.relu(raw - shift) if shift else raw
        m = mask01
        if signed:
            out = inhibit_signed_fused(vt, z, m)
        else:
            out = inhibit_fused(vt, z, m)
        if normalize:
            if m is not None:
                cnt = jnp.sum(m.astype(jnp.float32), axis=-1, keepdims=True)
            else:
                cnt = jnp.full(z.shape[:-1] + (1,), float(kt.shape[-2]),
                               jnp.float32)
            out = out / jnp.maximum(cnt, 1.0)
        return out

    @jax.custom_vjp
    def core(qt, kt, vt, mask01):
        return fwd_math(qt, kt, vt, mask01)

    def core_fwd(qt, kt, vt, mask01):
        return fwd_math(qt, kt, vt, mask01), (qt, kt, vt, mask01)

    def core_bwd(res, g):
        from repro.distributed.sharding import constrain

        qt, kt, vt, mask01 = res
        qf = qt.astype(jnp.float32)
        kf = kt.astype(jnp.float32)
        vf = vt.astype(jnp.float32)
        gf = g.astype(jnp.float32)

        raw = _raw_scores(qf, kf, gamma)                 # fused recompute
        raw = constrain(raw, "batch", "heads", "seq_sp")
        z = jax.nn.relu(raw - shift) if shift else raw
        if mask01 is not None:
            m = mask01.astype(jnp.float32)
        else:
            m = None
        if normalize:
            if m is not None:
                cnt = jnp.sum(m, axis=-1, keepdims=True)
            else:
                cnt = jnp.full(z.shape[:-1] + (1,), float(kf.shape[-2]),
                               jnp.float32)
            gf = gf / jnp.maximum(cnt, 1.0)

        # Each (nq, nk, d)-cube expression below must feed exactly ONE
        # reduce: two consumers would defeat XLA's reduce-fusion (CSE merges
        # the producers, the cube materializes — hundreds of GB).  Operands
        # are cloned through optimization_barrier per consumer so every
        # reduce owns a private, fully-fusable producer chain; the cube is
        # recomputed inside each reduce loop instead of stored.
        def _clone(*xs):
            return jax.lax.optimization_barrier(xs)

        def _dv_and_s(vf_, zc_, gm_):
            if signed:
                vp = jax.nn.relu(vf_)
                vn = vf_ - vp
                v1, z1, g1 = _clone(vf_, zc_, gm_)
                ind_v = jnp.where(
                    v1[..., None, :, :] > 0,
                    jax.nn.relu(v1)[..., None, :, :] > z1,
                    (v1 - jax.nn.relu(v1))[..., None, :, :] + z1 < 0)
                dv_ = jnp.sum(jnp.where(ind_v, g1, 0.0), axis=-3)
                v2, z2, g2 = _clone(vf_, zc_, gm_)
                vp2 = jax.nn.relu(v2)
                s_ = jnp.sum(
                    jnp.where((v2 - vp2)[..., None, :, :] + z2 < 0, g2, 0.0)
                    - jnp.where(vp2[..., None, :, :] > z2, g2, 0.0),
                    axis=-1)
            else:
                v1, z1, g1 = _clone(vf_, zc_, gm_)
                dv_ = jnp.sum(jnp.where(v1[..., None, :, :] > z1, g1, 0.0),
                              axis=-3)
                v2, z2, g2 = _clone(vf_, zc_, gm_)
                s_ = -jnp.sum(jnp.where(v2[..., None, :, :] > z2, g2, 0.0),
                              axis=-1)
            return dv_, s_

        zc = z[..., :, :, None]                          # (.., nq, nk, 1)
        gc = gf[..., :, None, :]                         # (.., nq, 1, dv)
        gm = gc if m is None else gc * m[..., None]      # mask inside sums
        dv, s = _dv_and_s(vf, zc, gm)
        s = constrain(s, "batch", "heads", "seq_sp")
        t = s * (1.0 / gamma)
        if shift:
            t = t * (raw > shift)
        q1, k1, t1 = _clone(qf, kf, t)
        dq = jnp.sum(t1[..., None]
                     * jnp.sign(q1[..., :, None, :] - k1[..., None, :, :]),
                     axis=-2)
        q2, k2, t2 = _clone(qf, kf, t)
        dk = -jnp.sum(t2[..., None]
                      * jnp.sign(q2[..., :, None, :] - k2[..., None, :, :]),
                      axis=-3)

        dmask = (jnp.zeros(mask01.shape, jax.dtypes.float0)
                 if mask01 is not None else None)
        return (dq.astype(qt.dtype), dk.astype(kt.dtype),
                dv.astype(vt.dtype), dmask)

    core.defvjp(core_fwd, core_bwd)
    return core


# ---------------------------------------------------------------------------
# Full multi-head attention entry point
# ---------------------------------------------------------------------------

def _repeat_kv(x: jax.Array, n_rep: int) -> jax.Array:
    """(b, s, kv_heads, d) -> (b, s, kv_heads*n_rep, d) for GQA."""
    if n_rep == 1:
        return x
    b, s, h, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, h, n_rep, d)
                            ).reshape(b, s, h * n_rep, d)


def inhibitor_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    mask: Optional[jax.Array] = None,
    score_scale: Optional[float] = None,
    score_shift: float = 0.5,
    signed: bool = True,
    normalize: bool = True,
) -> jax.Array:
    """Multi-head inhibitor attention.

    q: (b, n_q, h, d); k, v: (b, n_k, h_kv, d) with h % h_kv == 0 (GQA).
    mask: broadcastable to (b, h, n_q, n_k), True = attend.
    Returns (b, n_q, h, d) in q.dtype.

    ``normalize``: divide H by n_k (the count of attendable keys when a mask
    is given). The paper's H is an unnormalized sum, which makes the output
    magnitude scale with sequence length; for deep stacked blocks at
    production lengths we renormalize by the key count — a literal
    (constant) multiplication, so it remains FHE-compatible and does not
    change the mechanism (see DESIGN.md §2).
    """
    b, n_q, h, d = q.shape
    n_k = k.shape[1]
    h_kv = k.shape[2]
    k = _repeat_kv(k, h // h_kv)
    v = _repeat_kv(v, h // h_kv)

    # (b, h, n, d) layout for score computation
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    from repro.distributed.sharding import constrain

    gamma = score_scale if score_scale is not None else float(d) ** 0.5
    if mask is not None:
        mask = jnp.broadcast_to(mask, (b, h, n_q, n_k))
        mask = constrain(mask, "batch", "heads", "seq_sp")
    core = _make_inhibitor_core(float(gamma), float(score_shift),
                                bool(signed), bool(normalize))
    out = core(qt, kt, vt, mask)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


# ---------------------------------------------------------------------------
# Blockwise streaming form (exact; the Pallas kernel's structure)
# ---------------------------------------------------------------------------

def inhibitor_attention_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    mask: Optional[jax.Array] = None,
    score_scale: Optional[float] = None,
    score_shift: float = 0.5,
    signed: bool = True,
    normalize: bool = True,
    kv_chunk: int = 512,
) -> jax.Array:
    """Inhibitor attention accumulated over key/value chunks.

    Because inhibition is a *plain sum* of ReLU terms over j (no Softmax
    normalizer), blockwise accumulation is exact and needs no running
    max/denominator — this is the TPU dividend of the paper's formulation
    (DESIGN.md §2). Shapes as :func:`inhibitor_attention`.
    """
    b, n_q, h, d = q.shape
    n_k = k.shape[1]
    h_kv = k.shape[2]
    k = _repeat_kv(k, h // h_kv)
    v = _repeat_kv(v, h // h_kv)
    qt = q.transpose(0, 2, 1, 3).astype(jnp.float32)  # (b, h, n_q, d)
    kt = k.transpose(0, 2, 1, 3).astype(jnp.float32)
    vt = v.transpose(0, 2, 1, 3).astype(jnp.float32)

    n_chunks = -(-n_k // kv_chunk)
    pad = n_chunks * kv_chunk - n_k
    if pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        pad_mask = jnp.arange(n_k + pad) < n_k
        if mask is None:
            mask = jnp.broadcast_to(pad_mask[None, None, None, :],
                                    (b, h, n_q, n_k + pad))
        else:
            mask = jnp.broadcast_to(mask, (b, h, n_q, n_k)) if mask.shape != (
                b, h, n_q, n_k) else mask
            mask = jnp.pad(mask, ((0, 0), (0, 0), (0, 0), (0, pad)))
    elif mask is not None:
        mask = jnp.broadcast_to(mask, (b, h, n_q, n_k))

    kt = kt.reshape(b, h, n_chunks, kv_chunk, d)
    vt = vt.reshape(b, h, n_chunks, kv_chunk, d)
    if mask is not None:
        mask_c = mask.reshape(b, h, n_q, n_chunks, kv_chunk)

    from repro.distributed.sharding import constrain

    def body(carry, idx):
        acc, cnt = carry
        kc = kt[:, :, idx]                                 # (b, h, c, d)
        vc = vt[:, :, idx]
        z = manhattan_scores(qt, kc, score_scale=score_scale,
                             score_shift=score_shift)      # (b, h, n_q, c)
        z = constrain(z, "batch", "heads", "seq_sp")
        if mask is not None:
            m = mask_c[:, :, :, idx]
            cnt = cnt + jnp.sum(m.astype(jnp.float32), axis=-1)
        else:
            m = None
            cnt = cnt + float(kv_chunk)
        if signed:
            part = inhibit_signed_fused(vc, z, m)
        else:
            part = inhibit_fused(vc, z, m)
        return (acc + part, cnt), None

    acc0 = jnp.zeros((b, h, n_q, d), jnp.float32)
    cnt0 = jnp.zeros((b, h, n_q), jnp.float32)
    (acc, cnt), _ = jax.lax.scan(body, (acc0, cnt0), jnp.arange(n_chunks))
    if normalize:
        acc = acc / jnp.maximum(cnt[..., None], 1.0)
    return acc.transpose(0, 2, 1, 3).astype(q.dtype)
