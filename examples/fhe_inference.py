"""Privacy-preserving inference demo: the paper's headline use case.

A tiny Inhibitor attention layer is quantized to the paper's message
space and evaluated under the TFHE circuit simulator — exact integer
semantics with PBS/bit-width accounting — next to the dot-product arm,
reproducing the structure of the paper's Tables 2 and 4 at one glance.

  PYTHONPATH=src python examples/fhe_inference.py
"""

import numpy as np

from repro.fhe import (circuit_seconds, describe, dotprod_attention_circuit,
                       inhibitor_attention_circuit)

rng = np.random.default_rng(7)

print(f"{'T':>4} {'mechanism':>10} {'PBS':>6} {'bits':>5} {'poly':>6} "
      f"{'lweDim':>7} {'est time':>9}   speedup")
for T in (2, 4, 8, 16):
    d = 2
    q = rng.integers(-7, 8, (T, d))
    k = rng.integers(-7, 8, (T, d))
    v = rng.integers(-7, 8, (T, d))
    h_i, s_i = inhibitor_attention_circuit(q, k, v, gamma_shift=1,
                                           alpha_q=1)
    h_d, s_d = dotprod_attention_circuit(q, k, v, scale_shift=2)
    di, dd = describe(s_i), describe(s_d)
    sp = circuit_seconds(s_d) / circuit_seconds(s_i)
    print(f"{T:>4} {'inhibitor':>10} {di['pbs']:>6} "
          f"{di['max_bits_at_pbs']:>5} {di['poly_size']:>6} "
          f"{di['lwe_dim']:>7} {di['est_seconds']:>8.2f}s")
    print(f"{'':>4} {'dotprod':>10} {dd['pbs']:>6} "
          f"{dd['max_bits_at_pbs']:>5} {dd['poly_size']:>6} "
          f"{dd['lwe_dim']:>7} {dd['est_seconds']:>8.2f}s   {sp:.1f}x")

print("\npaper Table 4 speedups for reference: 3.6x / 2.6x / 4.5x / 6.5x")
print("paper Table 2 bit gap: inhibitor needs 1-2 fewer message bits")
