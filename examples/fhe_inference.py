"""Privacy-preserving inference: a whole transformer under TFHE.

The paper's headline use case, end to end: the ``paper_tiny`` Inhibitor
Transformer is post-training-quantized onto the integer lanes and
evaluated — **every layer**: LayerNorm surrogate, QKV/out projections,
attention, ReLU MLP, residuals, logits — under the TFHE circuit
simulator, bit-exact with the plaintext integer lane, next to the
dot-product baseline arm.  The per-layer cost report shows the paper's
structural claim at block scale: the inhibitor arm performs **zero
ciphertext×ciphertext multiplications** (only the Softmax baseline pays
them), and TFHE macro-parameters are selected from the *block-level*
PBS message-width high-water (fhe.params.select_params_for_report).

  PYTHONPATH=src python examples/fhe_inference.py
"""

import jax
import numpy as np

from repro.configs import get_config
from repro.core.lanes import get_lane
from repro.fhe import pbs_seconds, select_params_for_report
from repro.models import transformer as tfm
from repro.models.registry import get_model
from repro.nn.module import unbox
from repro.quant.ptq import ptq_lm

SEQ = 8

cfg = get_config("paper-tiny")
params = unbox(get_model(cfg).init(jax.random.PRNGKey(0)))
rng = np.random.default_rng(7)
tokens = rng.integers(0, cfg.vocab_size, (1, SEQ))

print(f"paper-tiny: {cfg.num_layers} layer(s), d_model={cfg.d_model}, "
      f"T={SEQ} — client embeds+encrypts tokens, server computes on "
      "ciphertexts\n")

for mech in ("inhibitor", "dotprod"):
    qlm = ptq_lm(params, cfg.with_attention_kind(mech))

    # plaintext integer reference (jnp int32 lane)
    int_lane = get_lane("int")
    ref = int_lane.to_numpy(tfm.lm_forward_lane(qlm, int_lane, tokens))

    # the same forward under the TFHE simulator
    fhe = get_lane("fhe_sim")
    enc = fhe.to_numpy(tfm.lm_forward_lane(qlm, fhe, tokens))
    assert np.array_equal(ref, enc), "encrypted forward must be bit-exact"

    report = fhe.ctx.scope_report()
    params_sel = select_params_for_report(report)
    t_pbs = pbs_seconds(params_sel)

    print(f"== {mech} block — encrypted forward bit-exact with int lane ==")
    print(f"{'layer':14s} {'pbs':>8} {'cmuls':>7} {'adds':>9} "
          f"{'bits@pbs':>8}")
    for name, s in report.items():
        print(f"{name:14s} {s['pbs']:>8} {s['cmuls']:>7} {s['adds']:>9} "
              f"{s['max_bits_at_pbs']:>8}")
    tot = fhe.ctx.summary()
    print(f"{'total':14s} {tot['pbs']:>8} {tot['cmuls']:>7} "
          f"{tot['adds']:>9} {tot['max_bits_at_pbs']:>8}")
    print(f"selected params: poly={params_sel.poly_size} "
          f"lwe={params_sel.lwe_dim} level={params_sel.level} "
          f"(block high-water {tot['max_bits_at_pbs']} bits)")
    print(f"estimated encrypted block time: "
          f"{tot['pbs'] * t_pbs:,.0f}s single-thread\n")

print("the inhibitor arm runs the whole block without a single "
      "ciphertext multiplication;\nthe dot-product arm pays 2 PBS per "
      "product in QKᵀ, softmax renorm, and S·V.")
