"""Sharding-spec assembly for the jitted step functions.

Centralizes how (params, opt_state, batch, decode-states) map onto the
mesh, so dryrun/train/serve all compile the same distribution:

  * params      — logical axes via the rules table (FSDP over ``data``,
                  TP/EP over ``model``), with divisibility fallback.
  * opt state   — moments mirror the param shardings; scalars replicated.
  * batch       — leading dim over ("pod", "data").
  * states      — decode caches: batch dim over ("pod", "data"); one
                  additional dim TP-sharded over ``model`` by preference
                  order (sequence dim for KV buffers — the production
                  choice for long-context serving — else the first
                  model-divisible feature dim).
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import param_spec
from repro.nn.module import Param, axes_of, is_param, unbox


def batch_axes_for(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def params_shardings(boxed_struct, mesh: Mesh):
    """Boxed eval_shape tree -> (unboxed struct, shardings tree)."""
    axes = axes_of(boxed_struct)
    struct = unbox(boxed_struct)

    def one(ax, st):
        return NamedSharding(mesh, param_spec(ax, st.shape, mesh))

    is_axes_leaf = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    sh = jax.tree.map(one, axes, struct, is_leaf=is_axes_leaf)
    return struct, sh


def opt_shardings(opt_struct, param_shardings_tree, mesh: Mesh):
    """Moments inherit param shardings; scalars/steps replicated."""
    repl = NamedSharding(mesh, P())

    def build(os, ps_tree):
        # os: AdamWState(step, mu, nu) — mu/nu mirror params
        return type(os)(step=repl, mu=ps_tree, nu=ps_tree)

    return build(opt_struct, param_shardings_tree)


def batch_shardings(specs: dict, mesh: Mesh) -> dict:
    ba = batch_axes_for(mesh)
    out = {}
    for k, s in specs.items():
        bsize = int(np.prod([mesh.shape[a] for a in ba])) if ba else 1
        if s.shape and s.shape[0] % max(bsize, 1) == 0 and ba:
            out[k] = NamedSharding(mesh, P(ba))
        else:
            out[k] = NamedSharding(mesh, P())
    return out


def state_shardings(state_struct, mesh: Mesh):
    """Decode-state shardings by shape heuristics (see module docstring)."""
    ba = batch_axes_for(mesh)
    bsize = int(np.prod([mesh.shape[a] for a in ba])) if ba else 1
    msize = mesh.shape.get("model", 1)

    def spec_for(st):
        if not hasattr(st, "shape") or st.ndim == 0:
            return P()
        parts = [None] * st.ndim
        # dim 0 is the stacked layer axis; dim 1 is batch
        if st.ndim >= 2 and ba and st.shape[1] % bsize == 0:
            parts[1] = ba
        if "model" in mesh.axis_names:
            for i in range(2, st.ndim):
                if st.shape[i] % msize == 0 and st.shape[i] >= msize:
                    parts[i] = "model"
                    break
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)

    return jax.tree.map(lambda st: NamedSharding(mesh, spec_for(st)),
                        state_struct)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
