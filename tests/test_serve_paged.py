"""Paged KV-cache subsystem: block-table allocator accounting, paged vs
contiguous output parity on ragged batches, bucketed single-row prefill
compile bounds, and backpressure when the page pool runs dry.

Shared fixtures (``serve_model``, ``greedy_ref``) live in conftest.py.
"""

import numpy as np
import pytest

from repro.serve.engine import Engine, EngineConfig, Request
from repro.serve.kvcache import PagedAllocator


# ---------------------------------------------------------------------------
# Allocator accounting (host side, no jax)
# ---------------------------------------------------------------------------

def test_paged_allocator_claim_ensure_release():
    al = PagedAllocator(max_batch=2, max_len=32, page_size=8)  # 4 pages/slot
    assert al.num_pages == 2 * 4 + 1        # +1 reserved trash page
    assert al.pages_in_use == 0

    s0 = al.claim(10)
    assert s0 == 0 and al.ensure(s0, 12) is True      # 2 pages for 12 toks
    assert al.pages_in_use == 2
    assert al.ensure(s0, 16) is False                 # already covered
    assert al.ensure(s0, 17) is True                  # crosses page boundary
    assert al.pages_in_use == 3
    mapped = list(al.block_tables[0, :3])
    assert 0 not in mapped                            # trash page never used
    assert len(set(mapped)) == 3

    s1 = al.claim(11)
    assert s1 == 1 and al.ensure(s1, 32) is True
    assert al.pages_in_use == 7 and al.high_water_pages == 7
    assert al.ensure(s1, 33) is None                  # beyond per-slot table

    al.release(s0)                                    # O(pages) reclaim
    assert al.pages_in_use == 4
    assert list(al.block_tables[0]) == [0, 0, 0, 0]   # table zeroed
    s2 = al.claim(12)
    assert s2 == 0 and al.ensure(s2, 32) is True      # freed pages reusable
    assert al.ensure(s2, 32) is False
    al.release(s1)
    al.release(s2)
    assert al.pages_in_use == 0                       # everything reclaimed
    assert al.high_water_pages == 8


def test_paged_allocator_pool_exhaustion_backpressure():
    al = PagedAllocator(max_batch=4, max_len=32, page_size=8, num_pages=5)
    s0 = al.claim(0)
    assert al.ensure(s0, 32) is True                  # takes all 4 pages
    s1 = al.claim(1)
    assert al.ensure(s1, 8) is None                   # free list dry
    al.release(s0)
    assert al.ensure(s1, 8) is True                   # backpressure clears


def test_paged_allocator_partial_growth_counts_toward_high_water():
    al = PagedAllocator(max_batch=2, max_len=32, page_size=8, num_pages=4)
    s0 = al.claim(0)
    assert al.ensure(s0, 32) is None      # needs 4, pool holds 3: fails...
    assert al.pages_in_use == 3           # ...but the grabbed pages stay
    assert al.high_water_pages == 3       # and the peak records them


# ---------------------------------------------------------------------------
# Engine-level parity and compile accounting
# ---------------------------------------------------------------------------

def test_paged_matches_contiguous_mixed_ragged_batch(rng, serve_model,
                                                     greedy_ref):
    """Acceptance: identical greedy outputs for a mixed ragged batch under
    both allocators, and the paged high-water mark stays below the
    contiguous reservation."""
    cfg, api, params = serve_model
    lens = (5, 3, 17, 5, 4, 9, 23, 1)
    prompts = [rng.integers(0, cfg.vocab_size, (l,)).astype(np.int32)
               for l in lens]

    outs = {}
    for allocator in ("contiguous", "paged"):
        # prefix_cache=False: this test is about the raw pool accounting
        # (cache-on retention is covered by tests/test_prefix_cache.py)
        eng = Engine(api, params, EngineConfig(max_batch=3, max_len=64,
                                               allocator=allocator,
                                               page_size=8,
                                               prefill_chunk=8,
                                               prefix_cache=False))
        for i, p in enumerate(prompts):
            eng.submit(Request(i, p, max_new_tokens=6))
        done = eng.run_to_completion()
        assert len(done) == len(prompts)
        outs[allocator] = {r.request_id: r.output for r in done}
        if allocator == "paged":
            # 3 slots x 64 tokens contiguous == 24 pages always reserved;
            # paging only ever held what live requests actually used
            assert eng.alloc.high_water_pages < 3 * (64 // 8)
            assert eng.alloc.pages_in_use == 0        # all reclaimed
    assert outs["paged"] == outs["contiguous"]
    assert outs["paged"][2] == greedy_ref(prompts[2], 6)


@pytest.mark.parametrize("allocator", ["contiguous", "paged"])
def test_prefill_compiles_bounded_by_buckets(rng, serve_model, allocator):
    """Acceptance: prefilling N prompts of distinct lengths triggers at
    most #buckets compiles (power-of-two buckets up to prefill_chunk),
    not one trace per distinct prompt length."""
    cfg, api, params = serve_model
    chunk = 8
    n_buckets = chunk.bit_length()          # {1, 2, 4, 8}
    lens = (1, 2, 3, 5, 6, 7, 9, 11, 13, 15, 19, 21)   # 12 distinct
    eng = Engine(api, params, EngineConfig(max_batch=2, max_len=64,
                                           allocator=allocator,
                                           prefill_chunk=chunk))
    for i, l in enumerate(lens):
        eng.submit(Request(i, rng.integers(0, cfg.vocab_size,
                                           (l,)).astype(np.int32),
                           max_new_tokens=2))
    done = eng.run_to_completion()
    assert len(done) == len(lens)
    assert eng.prefill_compiles <= n_buckets
    assert eng._prefill_buckets <= {1, 2, 4, 8}


def test_paged_engine_survives_undersized_pool(rng, serve_model,
                                               greedy_ref):
    """A pool smaller than the worst case serializes admissions instead of
    corrupting: every request still completes with exact outputs."""
    cfg, api, params = serve_model
    # 5 usable pages of 8 = 40 tokens of pool for 3 slots x 64 max_len
    eng = Engine(api, params, EngineConfig(max_batch=3, max_len=64,
                                           allocator="paged", page_size=8,
                                           num_pages=6, prefill_chunk=8))
    prompts = [rng.integers(0, cfg.vocab_size, (l,)).astype(np.int32)
               for l in (9, 17, 5, 11)]
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new_tokens=4))
    done = eng.run_to_completion()
    assert sorted(r.request_id for r in done) == [0, 1, 2, 3]
    for r in done:
        assert r.output == greedy_ref(prompts[r.request_id], 4)
    assert eng.alloc.high_water_pages <= 5


def test_inflight_request_has_page_priority_over_admission(rng, serve_model,
                                                           greedy_ref):
    """Regression: an admission must not drain the free list out from
    under a decoding request that only needed one more page — in-flight
    slots grow their tables before new requests are admitted."""
    cfg, api, params = serve_model
    # 3 usable pages of 8: request A holds 1 and will need a 2nd page
    # mid-decode; request B (2 pages) arrives while A is decoding — with
    # admission-first ordering B would take the last 2 pages and starve A
    # into a truncated finish
    eng = Engine(api, params, EngineConfig(max_batch=2, max_len=64,
                                           allocator="paged", page_size=8,
                                           num_pages=4, prefill_chunk=8))
    pa = rng.integers(0, cfg.vocab_size, (7,)).astype(np.int32)
    pb = rng.integers(0, cfg.vocab_size, (9,)).astype(np.int32)
    eng.submit(Request(0, pa, max_new_tokens=6))
    done = eng.step()                     # A admitted: 1 page, len 8
    eng.submit(Request(1, pb, max_new_tokens=3))
    done += eng.run_to_completion()
    assert sorted(r.request_id for r in done) == [0, 1]
    for r in done:
        assert not r.truncated
        out = greedy_ref(pa if r.request_id == 0 else pb, len(r.output))
        assert r.output == out


def test_paged_submit_rejects_impossible_prompt(rng, serve_model):
    cfg, api, params = serve_model
    eng = Engine(api, params, EngineConfig(max_batch=2, max_len=64,
                                           allocator="paged", page_size=8,
                                           num_pages=3))   # 2 usable pages
    with pytest.raises(ValueError, match="pages"):
        eng.submit(Request(0, rng.integers(0, cfg.vocab_size,
                                           (30,)).astype(np.int32)))


def test_paged_decode_grows_pages_on_demand(rng, serve_model):
    cfg, api, params = serve_model
    eng = Engine(api, params, EngineConfig(max_batch=2, max_len=64,
                                           allocator="paged", page_size=8,
                                           prefill_chunk=8))
    prompt = rng.integers(0, cfg.vocab_size, (7,)).astype(np.int32)
    eng.submit(Request(0, prompt, max_new_tokens=12))
    eng.step()
    after_admit = eng.alloc.pages_in_use    # covers prompt + 1st decode row
    assert after_admit == 1
    while eng.active:
        eng.step()
    # 7 prompt + 11 decoded KV rows crosses into a 3rd page before finish
    assert eng.alloc.high_water_pages == 3
    # the finished request's page-aligned prefix (18 rows -> 2 full pages)
    # stays resident in the radix index; nothing else is held
    assert eng.prefix is not None
    assert eng.prefix.cached_pages == 2
    assert eng.alloc.pages_in_use == eng.prefix.cached_pages
    assert eng.prefix.clear() == 2
    assert eng.alloc.pages_in_use == 0


def test_decode_clamps_tables_to_high_water_buckets(rng, serve_model,
                                                    greedy_ref):
    """The decode tick narrows block tables to the bucketed batch
    high-water page count (never the full pool-capacity width for short
    requests), restores the full tables afterwards, and stays
    output-exact."""
    cfg, api, params = serve_model
    eng = Engine(api, params, EngineConfig(max_batch=2, max_len=64,
                                           page_size=4, prefill_chunk=8))
    prompts = [np.asarray([3, 5, 7], np.int32),
               np.asarray([2, 4, 6, 8, 1], np.int32)]
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new_tokens=6))
    done = {r.request_id: r.output for r in eng.run_to_completion()}
    full_width = eng.alloc.pages_per_slot               # 16 pages
    assert eng._decode_table_buckets, "decode never narrowed the tables"
    # short requests (≤ 11 tokens) need at most 3 pages -> bucket 4
    assert max(eng._decode_table_buckets) < full_width
    # device tables were restored to full width after each tick
    assert eng.states.kv.block_tables.shape[-1] == full_width
    # and the outputs are exactly the single-request references
    for i, p in enumerate(prompts):
        assert done[i] == greedy_ref(p, 6, 64)


def test_forced_paged_backends_fail_loudly_at_construction(serve_model):
    """backend='paged_pallas' can never run engine-wide (prefill chunks
    are multi-query) and backend='paged' cannot run on contiguous slots
    — both must raise a clear error at Engine construction, not crash
    deep inside the first admission."""
    import dataclasses as dc

    cfg, api, params = serve_model

    def force(backend):
        acfg = dc.replace(cfg.attention, backend=backend)
        return api._replace(cfg=dc.replace(cfg, attention=acfg))

    with pytest.raises(ValueError, match="single-query"):
        Engine(force("paged_pallas"), params,
               EngineConfig(max_batch=2, max_len=64))
    with pytest.raises(ValueError, match="contiguous slots"):
        Engine(force("paged"), params,
               EngineConfig(max_batch=2, max_len=64,
                            allocator="contiguous"))


def test_engine_decode_plan_traces_paged_backend(serve_model):
    cfg, api, params = serve_model
    eng = Engine(api, params, EngineConfig(max_batch=2, max_len=64,
                                           allocator="paged"))
    assert eng.decode_plan.backend == "paged"
    assert "block-table" in eng.decode_plan.reason
    eng2 = Engine(api, params, EngineConfig(max_batch=2, max_len=64,
                                            allocator="contiguous"))
    assert eng2.decode_plan.backend != "paged"


# ---------------------------------------------------------------------------
# Construction-time warmup (EngineConfig.warmup) and prefill upload audit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("warmup", ["decode", "serve"])
def test_warmup_pretraces_proven_ladder(rng, serve_model, warmup):
    """warmup='decode' compiles the decode step's entire proven bucket
    ladder at construction; warmup='serve' additionally compiles every
    proven prefill chunk bucket — serving then triggers ZERO further
    compiles, the measured totals stay exactly at the proven budget, and
    outputs match a cold engine token-for-token."""
    cfg, api, params = serve_model
    prompts = [rng.integers(0, cfg.vocab_size, (l,)).astype(np.int32)
               for l in (5, 3, 17, 9, 1)]

    outs = {}
    for mode in ("none", warmup):
        eng = Engine(api, params, EngineConfig(max_batch=2, max_len=64,
                                               allocator="paged",
                                               page_size=8,
                                               prefill_chunk=8,
                                               warmup=mode))
        budget = eng.stats()["retrace_budget"]
        warm_decode = eng.decode_compiles
        warm_prefill = eng.prefill_compiles
        if mode != "none":
            assert warm_decode == budget["decode_proven"]
        if mode == "serve":
            assert warm_prefill == budget["prefill_proven"]
        for i, p in enumerate(prompts):
            eng.submit(Request(i, p, max_new_tokens=4))
        outs[mode] = {r.request_id: r.output
                      for r in eng.run_to_completion()}
        if mode != "none":
            # the ladder was fully warm: serving recompiled nothing
            assert eng.decode_compiles == warm_decode
        if mode == "serve":
            assert eng.prefill_compiles == warm_prefill
        assert eng.stats()["retrace_budget"]["within_declared"]
    assert outs[warmup] == outs["none"]


def test_warmup_rejects_unknown_policy(serve_model):
    cfg, api, params = serve_model
    with pytest.raises(ValueError, match="warmup"):
        Engine(api, params, EngineConfig(max_batch=2, max_len=64,
                                         warmup="everything"))


def test_prefill_table_uploads_at_most_one_per_prefill(rng, serve_model):
    """Upload audit (S1 gate material): the block-table mirror is pushed
    once per *prefill*, before the chunk loop — multi-chunk prompts must
    not multiply uploads, so uploads/prefill-chunk stays <= 1 and the
    upload count is bounded by the number of admitted prefills."""
    cfg, api, params = serve_model
    lens = (17, 23, 9, 13)                  # 3, 3, 2, 2 chunks of 8
    eng = Engine(api, params, EngineConfig(max_batch=2, max_len=64,
                                           allocator="paged", page_size=8,
                                           prefill_chunk=8))
    for i, l in enumerate(lens):
        eng.submit(Request(i, rng.integers(0, cfg.vocab_size,
                                           (l,)).astype(np.int32),
                           max_new_tokens=2))
    eng.run_to_completion()
    stats = eng.stats()
    assert stats["prefill_chunks"] > len(lens)      # genuinely multi-chunk
    assert stats["table_uploads_prefill"] <= len(lens)
    assert (stats["table_uploads_prefill"]
            <= stats["prefill_chunks"])
