"""Config dataclasses: model architecture, input shapes, run options.

Every assigned architecture is one ``ModelConfig`` in its own module under
:mod:`repro.configs`; the registry resolves ``--arch <id>`` strings.
``AttentionConfig.kind`` switches the paper's mechanism on/off per arch.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core.attention import AttentionConfig


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_hidden_dim: int
    shared_hidden_dim: int = 0
    shared_gate: bool = False
    capacity_factor: float = 1.25
    normalize_topk: bool = True
    # pad the expert axis up to a multiple of the EP degree (e.g. 60 -> 64)
    padded_experts: Optional[int] = None
    lb_loss_weight: float = 0.01
    z_loss_weight: float = 1e-3

    @property
    def effective_experts(self) -> int:
        return self.padded_experts or self.num_experts


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba"            # mamba | rwkv6
    state_dim: int = 16
    inner_dim: Optional[int] = None
    conv_dim: int = 4
    dt_rank: Optional[int] = None
    # rwkv6
    lora_dim: int = 64
    decay_lora_dim: int = 64


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    encoder_layers: int = 24
    decoder_layers: int = 24
    # frontend stub: encoder input is precomputed frame embeddings
    max_source_len: int = 4096


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    kind: str = "vision"           # vision | audio
    embed_dim: int = 1024          # frontend output dim (projected to d_model)
    tokens_per_item: int = 576     # patches per tile / frames per clip
    max_tiles: int = 5             # llava-next anyres


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | encdec | ssm | vlm
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attention: AttentionConfig
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    norm_eps: float = 1e-6
    mlp: str = "gated_silu"        # gated_silu | mlp_gelu | mlp_relu
    mlp_bias: bool = False
    tie_embeddings: bool = False
    rope_pct: float = 1.0          # fraction of head_dim rotated (stablelm)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encdec: Optional[EncDecConfig] = None
    frontend: Optional[FrontendConfig] = None
    max_seq_len: int = 131072
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # remat policy for the layer scan: "none" | "full" | "dots" | "offload"
    remat: str = "full"
    # unroll the layer stack as a python loop instead of lax.scan — used by
    # the dry-run to extract exact per-layer cost deltas (HLO cost analysis
    # counts a While body once, not ×trip_count)
    unroll: bool = False
    # citation / provenance
    source: str = ""

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def with_attention_kind(self, kind: str) -> "ModelConfig":
        # keep both naming fields in sync: ``mechanism`` outranks the
        # legacy ``kind`` in the planner, so overriding only ``kind``
        # would be silently ignored on configs that set ``mechanism``
        return dataclasses.replace(
            self, attention=dataclasses.replace(self.attention, kind=kind,
                                                mechanism=kind))

    def with_layers(self, n: int, *, unroll: bool = False) -> "ModelConfig":
        """Depth-n variant (dry-run per-layer cost extraction)."""
        kw = dict(num_layers=n, unroll=unroll)
        if self.encdec is not None:
            kw["encdec"] = dataclasses.replace(
                self.encdec, encoder_layers=n, decoder_layers=n)
        return dataclasses.replace(self, **kw)

    def reduced(self, *, num_layers=2, d_model=64, d_ff=128, vocab_size=256,
                num_heads=4, num_kv_heads=2, head_dim=16,
                max_seq_len=512) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        attn = dataclasses.replace(
            self.attention, num_heads=num_heads, num_kv_heads=num_kv_heads,
            head_dim=head_dim)
        kw = dict(num_layers=num_layers, d_model=d_model, d_ff=d_ff,
                  vocab_size=vocab_size, attention=attn,
                  max_seq_len=max_seq_len, remat="none",
                  compute_dtype="float32")
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, num_experts=4, top_k=min(2, self.moe.top_k),
                expert_hidden_dim=32,
                shared_hidden_dim=32 if self.moe.shared_hidden_dim else 0,
                padded_experts=None)
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, state_dim=8, inner_dim=d_model * 2
                if self.ssm.inner_dim else None, lora_dim=8,
                decay_lora_dim=8)
        if self.encdec is not None:
            kw["encdec"] = dataclasses.replace(
                self.encdec, encoder_layers=num_layers,
                decoder_layers=num_layers, max_source_len=64)
        if self.frontend is not None:
            kw["frontend"] = dataclasses.replace(
                self.frontend, embed_dim=32, tokens_per_item=8, max_tiles=2)
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


# The four assigned input shapes (LM-family)
SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}
