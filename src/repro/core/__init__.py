"""Core: the paper's contribution — inhibitor attention — and its baseline,
behind the pluggable mechanism registry + backend planner."""

from repro.core.attention import (  # noqa: F401
    AttentionConfig,
    KVCache,
    PagedKVCache,
    apply_attention,
    init_attention,
    init_kv_cache,
    init_paged_kv_cache,
)
from repro.core.mechanism import (  # noqa: F401
    BACKENDS,
    MASK_FREE_BACKENDS,
    AttnShapes,
    ExecutionPlan,
    Mechanism,
    MechanismParams,
    PagedLayout,
    Structural,
    available_mechanisms,
    backend_eligible,
    execute_plan,
    get_mechanism,
    plan_attention,
    register_mechanism,
)
from repro.core.dotprod import dot_product_attention  # noqa: F401
from repro.core.lanes import (  # noqa: F401
    FheSimLane,
    FloatLane,
    IntLane,
    Lane,
    available_lanes,
    get_lane,
)
from repro.core.inhibitor import (  # noqa: F401
    inhibit_fused,
    inhibit_naive,
    inhibit_signed_fused,
    inhibit_signed_naive,
    inhibitor_attention,
    inhibitor_attention_chunked,
    manhattan_scores,
)
