"""TFHE macro-parameter selection from circuit statistics.

Mirrors what the Concrete optimizer (Bergerat et al. 2023) does from the
outside: given the message-space bit-width a circuit's PBS inputs require,
pick (polySize, lweDim, decomposition) meeting the noise/failure budget.
The table below follows the published Concrete parameter curves at
p_fail ≈ 2⁻⁴⁰ and reproduces the paper's Table 2 structure: polySize
doubles when the PBS message width crosses ~6 bits, lweDim creeps with
width, and the dot-product arm lands 1–2 bits (and often one polySize
step) above the inhibitor arm.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class TfheParams:
    lwe_dim: int
    poly_size: int
    base_log: int
    level: int
    msg_bits: int        # message space the PBS table must cover


# (max message bits at PBS) -> parameter point (Concrete-style curve)
_PARAM_CURVE = (
    (4, TfheParams(lwe_dim=750, poly_size=1024, base_log=23, level=1, msg_bits=4)),
    (5, TfheParams(lwe_dim=800, poly_size=2048, base_log=23, level=1, msg_bits=5)),
    (6, TfheParams(lwe_dim=840, poly_size=2048, base_log=23, level=1, msg_bits=6)),
    (7, TfheParams(lwe_dim=870, poly_size=4096, base_log=22, level=1, msg_bits=7)),
    (8, TfheParams(lwe_dim=900, poly_size=4096, base_log=22, level=1, msg_bits=8)),
    (9, TfheParams(lwe_dim=930, poly_size=8192, base_log=15, level=2, msg_bits=9)),
    (10, TfheParams(lwe_dim=950, poly_size=8192, base_log=15, level=2, msg_bits=10)),
    (12, TfheParams(lwe_dim=980, poly_size=16384, base_log=15, level=2, msg_bits=12)),
    (16, TfheParams(lwe_dim=1024, poly_size=32768, base_log=9, level=3, msg_bits=16)),
)


def select_params(max_bits_at_pbs: int) -> TfheParams:
    for bits, params in _PARAM_CURVE:
        if max_bits_at_pbs <= bits:
            return params
    raise ValueError(
        f"message width {max_bits_at_pbs} bits exceeds the 16-bit TFHE "
        "table-lookup ceiling (paper §Computational Efficiency)")


def _worst_pbs_scope(report, kind: str):
    """(scope name, width) of the block-level ``max_bits_at_pbs`` high-
    water, refusing a report without any PBS site: selecting the smallest
    parameter point for a circuit whose widths were simply never observed
    would be silent nonsense, not a cheap circuit."""
    if not report:
        raise ValueError(f"empty {kind} cost report: run a lane forward "
                         "before selecting parameters")
    worst_name, worst = max(report.items(),
                            key=lambda kv: kv[1].get("max_bits_at_pbs", 0))
    worst_bits = worst.get("max_bits_at_pbs", 0)
    if worst_bits <= 0:
        raise ValueError(
            f"no scope in the {kind} cost report observed a PBS "
            f"(max_bits_at_pbs is 0/absent everywhere across "
            f"{sorted(report)}); parameters are selected from PBS message "
            "widths, so a PBS-free trace cannot drive selection")
    return worst_name, worst_bits


def select_params_for_report(report, *, static_report=None) -> TfheParams:
    """Parameter selection from a *full-block* per-layer cost report.

    ``report`` maps layer/scope name → cost summary (the
    :meth:`~repro.fhe.tfhe_sim.FheContext.scope_report` of an end-to-end
    lane forward).  One parameter set must serve every PBS in the
    circuit, so selection keys on the block-level ``max_bits_at_pbs``
    high-water — not just the attention op's — and a width beyond the
    supported table fails loudly *naming the offending layer*, which is
    the actionable signal (lower that layer's fixed-point precision or
    add a rescale before its LUT).

    ``static_report``, when given, is the per-scope report of the static
    interval analysis of the same circuit (``repro.analysis``): every
    measured width is cross-checked against the proven bound, and a
    measured width *exceeding* the static bound fails loudly — observing
    what the analysis proved impossible means the analysis is unsound
    (or the two traces ran different circuits), and parameters derived
    from either are untrustworthy.
    """
    worst_name, worst_bits = _worst_pbs_scope(report, "measured")
    if static_report is not None:
        for name, s in report.items():
            measured = s.get("max_bits_at_pbs", 0)
            bound = static_report.get(name, {}).get("max_bits_at_pbs")
            if bound is None:
                raise ValueError(
                    f"scope {name!r} is missing from the static report "
                    f"(static scopes: {sorted(static_report)}); the "
                    "measured and static traces ran different circuits")
            if measured > bound:
                raise ValueError(
                    f"SOUNDNESS BUG: scope {name!r} measured "
                    f"{measured}-bit PBS messages but the static analysis "
                    f"proved a {bound}-bit worst case; the interval "
                    "analysis (or the circuit pairing) is wrong — do not "
                    "trust either parameter selection")
    try:
        return select_params(worst_bits)
    except ValueError as e:
        raise ValueError(
            f"layer {worst_name!r} needs {worst_bits}-bit PBS messages: "
            f"{e}") from None


def select_params_static(static_report) -> TfheParams:
    """Parameter selection from the *proven* block-level width.

    ``static_report`` is the per-scope report of an
    :class:`~repro.analysis.interval_lane.IntervalLane` forward — the
    same schema as the measured report, but every ``max_bits_at_pbs`` is
    a worst case over all inputs in the declared quantized ranges rather
    than one sample's high-water.  Parameters chosen here are therefore
    sound for *any* input: this is the selection deployments should use
    (the measured selection can under-provision on an unlucky input and
    decrypt to garbage with no error).
    """
    worst_name, worst_bits = _worst_pbs_scope(static_report, "static")
    try:
        return select_params(worst_bits)
    except ValueError as e:
        raise ValueError(
            f"layer {worst_name!r} is statically proven to need "
            f"{worst_bits}-bit PBS messages: {e}") from None
