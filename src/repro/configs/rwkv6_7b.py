"""rwkv6-7b (Finch) — attention-free RNN/SSM LM with data-dependent decay.
[arXiv:2404.05892; hf:RWKV/rwkv-6-world-7b]
32L d_model=4096 d_ff=14336 vocab=65536; 64 wkv heads of dim 64.

The Inhibitor technique is INAPPLICABLE here (no attention to replace) —
implemented faithfully without it; DESIGN.md §Arch-applicability.
``attention`` carries head bookkeeping only (num_heads = wkv heads).
"""

from repro.configs.base import ModelConfig, SSMConfig
from repro.core.attention import AttentionConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=65536,
    attention=AttentionConfig(
        mechanism="dotprod", num_heads=64, num_kv_heads=64, head_dim=64,
        use_rope=False, causal=True),
    norm="layernorm",
    norm_eps=1e-5,
    mlp="mlp_relu",
    ssm=SSMConfig(kind="rwkv6", state_dim=64, lora_dim=64,
                  decay_lora_dim=64),
    tie_embeddings=False,
    max_seq_len=1048576,
    source="arXiv:2404.05892",
)
