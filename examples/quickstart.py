"""Quickstart: the Inhibitor mechanism in five minutes.

  1. swap attention mechanisms on one architecture with a config suffix,
  2. check the eq. 9 fused identity numerically,
  3. run a quantized-integer inhibitor and its ENCRYPTED (TFHE-simulated)
     twin and compare costs with the dot-product arm.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mechanism import resolve_mechanism_name

from repro.configs import get_config
from repro.core import inhibitor as I
from repro.fhe import (describe, dotprod_attention_circuit,
                       inhibitor_attention_circuit)
from repro.models.registry import get_model
from repro.nn.module import param_count, unbox
from repro.quant.int_attention import int_inhibitor_attention, quantize_qkv

rng = np.random.default_rng(0)

# ---- 1. one config, two mechanisms -----------------------------------
print("== mechanism swap ==")
for name in ("smollm-135m", "smollm-135m@inhibitor"):
    cfg = get_config(name).reduced()
    api = get_model(cfg)
    params = unbox(api.init(jax.random.PRNGKey(0)))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)),
                       dtype=jnp.int32)
    logits, _ = api.forward(params, {"tokens": toks})
    print(f"  {name:26s} mechanism={resolve_mechanism_name(cfg.attention):10s} "
          f"params={param_count(params):,} logits={tuple(logits.shape)}")

# ---- 2. the paper's eq. 9 identity ------------------------------------
print("== eq. 9 fused identity ==")
q = jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32))
k = jnp.asarray(rng.normal(size=(6, 8)).astype(np.float32))
v = jnp.asarray(rng.normal(size=(6, 8)).astype(np.float32))
z = I.manhattan_scores(q, k, score_shift=0.5)
err = float(jnp.abs(I.inhibit_fused(v, z) - I.inhibit_naive(v, z)).max())
print(f"  |fused - naive| = {err:.2e}")

# ---- 3. quantized + encrypted ------------------------------------------
print("== encrypted inference (TFHE sim) ==")
qf = jnp.asarray(rng.normal(size=(4, 2)).astype(np.float32))
kf = jnp.asarray(rng.normal(size=(4, 2)).astype(np.float32))
vf = jnp.asarray(rng.normal(size=(4, 2)).astype(np.float32))
qi, ki, vi, scale = quantize_qkv(qf, kf, vf, bits=4)
h_int = int_inhibitor_attention(qi, ki, vi, gamma_shift=1, alpha_q=1)
h_enc, s_inh = inhibitor_attention_circuit(
    np.asarray(qi), np.asarray(ki), np.asarray(vi), gamma_shift=1,
    alpha_q=1)
assert np.array_equal(h_enc, np.asarray(h_int)), "encrypted != integer!"
_, s_dot = dotprod_attention_circuit(np.asarray(qi), np.asarray(ki),
                                     np.asarray(vi), scale_shift=2)
di, dd = describe(s_inh), describe(s_dot)
print(f"  inhibitor: pbs={di['pbs']:4d} bits={di['max_bits_at_pbs']} "
      f"poly={di['poly_size']} est={di['est_seconds']}s")
print(f"  dotprod  : pbs={dd['pbs']:4d} bits={dd['max_bits_at_pbs']} "
      f"poly={dd['poly_size']} est={dd['est_seconds']}s")
print(f"  encrypted speedup: {dd['est_seconds'] / di['est_seconds']:.1f}x "
      "(paper: 3-6x)")
