"""Lane-discipline lint: AST checks for the repo's cross-lane invariants.

Three rules, each guarding a bug class this codebase has actually hit or
is structurally exposed to:

LANE001  no direct ``np.``/``jnp.`` *arithmetic* at the top level of a
         lane-generic function (any function with a parameter literally
         named ``lane``).  Handles must route through the Lane protocol:
         a raw ``jnp.add`` on a handle silently runs float/int32 math on
         the fhe_sim lane's int64 arrays with **no cost accounting and no
         width observation**, breaking int≡fhe parity and making every
         measured/static report a lie.  Nested ``def``/``lambda`` bodies
         are exempt — LUT table functions are legitimately numpy (they
         *define* the table a PBS evaluates; they are not handle math).

LANE002  no ``lane.mul`` / ``lane.dot_scores`` / ``lane.mix_values``
         inside a lane-generic function whose name contains
         ``inhibitor``.  The inhibitor family's zero-cmul property is the
         paper's headline claim; a cipher×cipher op reachable from its
         lane code would forfeit it.  (The static analyzer proves the
         runtime claim; this rule catches the edit at review time, before
         anything runs.)

LANE003  no bare ``hash()`` anywhere: Python's string hashing is salted
         per process (PYTHONHASHSEED), so seed/key derivation through it
         is nondeterministic across runs — the PR 3 bug class.  Derive
         integers with ``zlib.crc32``/``hashlib`` instead.

LANE004  no untagged host-sync primitive (``.item()``, ``int()``/
         ``float()`` coercion, ``np.asarray`` on device values,
         ``jnp.asarray`` uploads) inside the tick-path functions of
         ``serve/engine.py`` or the telemetry emit path of
         ``serve/telemetry.py``.  Every sync these paths keep must
         carry a ``# sync: <required|eliminable|host> — <reason>`` tag
         on its line — the serve-path analyzer
         (``repro.analysis.serve_static``) audits the tagged inventory
         and CI gates on the per-tick counts, so a new sync can't land
         silently.  The tick path is the static call-graph closure of
         ``Engine.step`` / ``run_to_completion``; the telemetry emit
         path is the closure of the Tracer/Histogram hooks the engine
         may call per tick (``TELEMETRY_SYNC_ROOTS``), whose declared
         contract is zero h2d + zero d2h.

Run as ``python -m repro.analysis.lint [paths...]`` (default
``src/repro``); exits non-zero listing every violation.
"""

from __future__ import annotations

import ast
import sys
from pathlib import Path
from typing import Iterator, List, NamedTuple

#: np/jnp attribute calls that are handle arithmetic when applied at the
#: top level of a lane-generic function (structural helpers like asarray/
#: shape/arange/broadcast_to are deliberately absent: cleartext weights,
#: masks and literals are legitimately numpy)
_ARITH_ATTRS = frozenset({
    "add", "subtract", "multiply", "divide", "true_divide", "floor_divide",
    "matmul", "dot", "einsum", "tensordot", "inner", "outer",
    "sum", "prod", "cumsum", "mean", "max", "min", "amax", "amin",
    "maximum", "minimum", "abs", "absolute", "clip", "where", "negative",
    "exp", "exp2", "log", "log2", "sqrt", "square", "sign", "tanh",
    "right_shift", "left_shift", "round", "rint", "power", "reciprocal",
    "softmax", "relu",
})

_CMUL_METHODS = frozenset({"mul", "dot_scores", "mix_values"})

_NUMPY_ALIASES = frozenset({"np", "jnp", "numpy", "jax.numpy"})


class Violation(NamedTuple):
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


def _is_lane_generic(fn: ast.AST) -> bool:
    """A function is lane-generic iff it takes a parameter named ``lane``
    (the repo-wide convention for Lane-protocol code)."""
    if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
        return False
    a = fn.args
    names = [p.arg for p in (a.posonlyargs + a.args + a.kwonlyargs)]
    return "lane" in names


def _top_level_nodes(fn: ast.AST) -> Iterator[ast.AST]:
    """Walk a function body without descending into nested functions or
    lambdas (their bodies are table definitions, not handle math)."""
    stack = list(fn.body)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


def _dotted(node: ast.AST) -> str:
    """Best-effort dotted name of an expression (``jax.numpy`` etc.)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _check_function(fn, path: str, out: List[Violation]) -> None:
    lane_generic = _is_lane_generic(fn)
    inhibitor_scope = lane_generic and "inhibitor" in fn.name
    if not lane_generic:
        return
    for node in _top_level_nodes(fn):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)):
            continue
        base = _dotted(node.func.value)
        attr = node.func.attr
        if base in _NUMPY_ALIASES and attr in _ARITH_ATTRS:
            out.append(Violation(
                path, node.lineno, "LANE001",
                f"direct {base}.{attr}() at the top level of lane-generic "
                f"{fn.name}(); route handle arithmetic through the Lane "
                "protocol (nested table fns are exempt)"))
        if inhibitor_scope and base == "lane" and attr in _CMUL_METHODS:
            out.append(Violation(
                path, node.lineno, "LANE002",
                f"lane.{attr}() inside inhibitor-family {fn.name}() — a "
                "cipher×cipher op would forfeit the proven zero-cmul "
                "property"))


def _check_sync_discipline(tree: ast.Module, src: str, path: str,
                           out: List[Violation]) -> None:
    """LANE004: tick-path host-sync sites in serve/engine.py — and the
    telemetry emit path in serve/telemetry.py — must carry a
    ``# sync:`` tag (classification + tag grammar live in serve_static,
    shared with the analyzer so the lint and the audit can never
    disagree about what counts as a sync)."""
    norm = path.replace("\\", "/")
    from repro.analysis.serve_static import (TELEMETRY_SYNC_ROOTS,
                                             classify_sync_call,
                                             find_sync_tag,
                                             tick_path_functions)

    if norm.endswith("serve/engine.py"):
        funcs = tick_path_functions(tree)
    elif norm.endswith("serve/telemetry.py"):
        funcs = tick_path_functions(tree, roots=TELEMETRY_SYNC_ROOTS)
    else:
        return
    lines = src.splitlines()
    for node in ast.walk(tree):
        if not isinstance(node, ast.FunctionDef) or node.name not in funcs:
            continue
        for call in ast.walk(node):
            hit = classify_sync_call(call)
            if hit is None:
                continue
            api, kind = hit
            line = lines[call.lineno - 1] if call.lineno <= len(lines) else ""
            if find_sync_tag(line) is None:
                out.append(Violation(
                    path, call.lineno, "LANE004",
                    f"untagged host-sync {api} ({kind}) in tick-path "
                    f"{node.name}(); add '# sync: <required|eliminable|"
                    f"host> — <reason>' on this line or move the sync "
                    "off the tick path"))


def lint_source(src: str, path: str = "<string>") -> List[Violation]:
    """Lint one module's source; returns violations (possibly empty)."""
    out: List[Violation] = []
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [Violation(path, e.lineno or 0, "LANE000",
                          f"syntax error: {e.msg}")]
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            _check_function(node, path, out)
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id == "hash"):
            out.append(Violation(
                path, node.lineno, "LANE003",
                "bare hash() — salted per process (PYTHONHASHSEED); use "
                "zlib.crc32/hashlib for seed- or key-derived values"))
    _check_sync_discipline(tree, src, path, out)
    out.sort(key=lambda v: (v.path, v.line, v.rule))
    return out


def lint_paths(paths) -> List[Violation]:
    """Lint every ``*.py`` under the given files/directories."""
    out: List[Violation] = []
    for p in paths:
        p = Path(p)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            out.extend(lint_source(f.read_text(encoding="utf-8"), str(f)))
    return out


def main(argv=None) -> int:
    args = list(sys.argv[1:] if argv is None else argv)
    paths = args or ["src/repro"]
    violations = lint_paths(paths)
    for v in violations:
        print(v)
    n_files = sum(len(sorted(Path(p).rglob("*.py"))) if Path(p).is_dir()
                  else 1 for p in paths)
    if violations:
        print(f"lane-discipline lint: {len(violations)} violation(s) in "
              f"{n_files} file(s)", file=sys.stderr)
        return 1
    print(f"lane-discipline lint: clean ({n_files} file(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
