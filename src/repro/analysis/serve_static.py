"""Static analysis of the serve engine's hot path (DESIGN.md §13).

Three passes over the engine's jit entry points (``_jit_decode``,
``_jit_prefill_chunk``, the donated CoW pool copy) and the tick-path
host code:

1. **Retrace-budget proof** — :func:`retrace_budget` exhaustively
   enumerates every abstract trace signature reachable from an
   ``EngineConfig`` (prefill bucket widths × decode table-width
   buckets), using the *same* pure scheduling functions the engine runs
   (``repro.serve.engine.prefill_schedule`` / ``decode_table_width``),
   and proves the compile set finite and within the declared budget.
   :func:`verify_chunk_resume` extends the proof to continuous batching
   (DESIGN.md §15): resuming a partially-executed schedule at any chunk
   boundary (``prefill_schedule(start=pos)``) reproduces the original
   schedule's suffix exactly, so interleaved chunked prefill adds zero
   trace signatures beyond the whole-prompt enumeration.
   :func:`verify_engine_signatures` then traces each enumerated
   signature abstractly (``jax.eval_shape``) against a live engine,
   proving each is actually traceable; :func:`cross_check_bench`
   compares measured compile counters from a serve_bench artifact
   against the proven bound — measured > proven is a loud SOUNDNESS
   BUG, mirroring PR 6's params cross-check.

2. **Host-sync audit** — :func:`audit_sync_sites` walks the AST of
   ``serve/engine.py``, closes the tick-path call graph from
   ``Engine.step`` / ``run_to_completion``, and inventories every
   host→device upload and device→host sync, classifying each by the
   mandatory ``# sync: <required|eliminable|host> — <reason>`` tag
   (LANE004 in ``repro.analysis.lint`` rejects untagged sites).
   :func:`jaxpr_costs`' ``host_callbacks`` field covers syncs *inside*
   jitted code.  CI gates on the per-tick counts.

3. **Static roofline** — :func:`roofline_engine` walks the jaxpr of
   each enumerated decode/prefill signature with
   ``repro.analysis.costmodel`` and reports FLOPs / HBM-byte /
   transfer-byte budgets per tick.

Entry point: ``python -m repro.analysis.serve`` (see ``serve.py``),
emitting ``ANALYSIS_serve.json``.
"""

from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Any, Dict, List, NamedTuple, Optional, Sequence, Set, Tuple

SCHEMA_VERSION = 2

__all__ = [
    "retrace_budget", "enumerate_prefill_buckets",
    "enumerate_decode_buckets", "verify_chunk_resume",
    "verify_engine_signatures",
    "audit_sync_sites", "sync_summary", "tick_path_functions",
    "classify_sync_call", "find_sync_tag", "audit_telemetry_file",
    "TELEMETRY_SYNC_ROOTS", "roofline_engine",
    "engine_desc", "analyze_serve", "cross_check_bench",
    "format_serve_report",
]


# --------------------------------------------------------------------------
# pass 1: retrace-budget proof
# --------------------------------------------------------------------------

def enumerate_prefill_buckets(*, max_len: int, prefill_chunk: int,
                              bucketed: bool, page_size: Optional[int] = None,
                              prefix_cache: bool = False) -> List[int]:
    """Every prefill chunk width reachable from the config: exhaustive
    over all admissible prompt lengths (1..max_len-1) and — when the
    prefix cache can shift the schedule start — every page-aligned
    credit the cache could grant.  Uses the engine's own pure
    ``prefill_schedule``, so the enumeration IS what the engine traces."""
    from repro.serve.engine import prefill_schedule

    widths: Set[int] = set()
    for plen in range(1, max_len):
        starts: Sequence[int] = (0,)
        if prefix_cache and page_size:
            # admission caps the credit so >=1 prompt token is prefilled
            cap = ((plen - 1) // page_size) * page_size
            starts = range(0, cap + 1, page_size)
        for credit in starts:
            for _start, width in prefill_schedule(
                    plen, chunk=prefill_chunk, max_len=max_len,
                    bucketed=bucketed, start=credit):
                widths.add(width)
    return sorted(widths)


def enumerate_decode_buckets(*, max_len: int, page_size: int,
                             pages_per_slot: int) -> List[int]:
    """Every clamped block-table width a paged decode tick can trace:
    exhaustive over the longest-active-row positions 1..max_len."""
    from repro.serve.engine import decode_table_width

    return sorted({decode_table_width(n, page_size=page_size,
                                      pages_per_slot=pages_per_slot)
                   for n in range(1, max_len + 1)})


def verify_chunk_resume(*, max_len: int, prefill_chunk: int,
                        bucketed: bool, page_size: Optional[int] = None,
                        prefix_cache: bool = False) -> Dict[str, Any]:
    """Prove chunk-granular resume (continuous batching, DESIGN.md §15)
    adds no trace signatures.

    The engine fixes a request's schedule at staging and indexes into it
    across ticks, but a paused-then-restaged admission (and the proof of
    the engine's *right* to do so) rests on the schedule being
    memoryless in the resume position: for every admissible
    ``(prompt_len, credit)`` pair, recomputing the schedule at the first
    chunk boundary (``start = min(credit + chunk, prompt_len)``) must
    reproduce the original schedule's suffix exactly.  Checking the
    k=1 boundary suffices by induction — the recomputed schedule is
    itself an instance of the same recurrence one chunk further along,
    so suffix equality at every boundary follows from equality at the
    first.  ``new_widths`` would list any resumed chunk width outside
    the whole-prompt enumeration (must be empty: resumed execution can
    then never trace a signature the warmup/proof missed)."""
    from repro.serve.engine import prefill_schedule

    base_widths: Set[int] = set()
    resumed_widths: Set[int] = set()
    resume_points = 0
    suffix_exact = True
    for plen in range(1, max_len):
        starts: Sequence[int] = (0,)
        if prefix_cache and page_size:
            cap = ((plen - 1) // page_size) * page_size
            starts = range(0, cap + 1, page_size)
        for credit in starts:
            sched = prefill_schedule(plen, chunk=prefill_chunk,
                                     max_len=max_len, bucketed=bucketed,
                                     start=credit)
            base_widths.update(w for _s, w in sched)
            if len(sched) < 2:
                continue          # single-chunk schedules never resume
            pos1 = min(credit + prefill_chunk, plen)
            resumed = prefill_schedule(plen, chunk=prefill_chunk,
                                       max_len=max_len, bucketed=bucketed,
                                       start=pos1)
            resume_points += 1
            if resumed != sched[1:]:
                suffix_exact = False
            resumed_widths.update(w for _s, w in resumed)
    new = sorted(resumed_widths - base_widths)
    return {
        "resume_points": resume_points,
        "suffix_exact": suffix_exact,
        "new_widths": new,
        "closed": suffix_exact and not new,
    }


def retrace_budget(*, bucketed: bool, paged: bool, max_len: int,
                   prefill_chunk: int, page_size: Optional[int] = None,
                   pages_per_slot: Optional[int] = None,
                   prefix_cache: bool = True,
                   declared: Optional[int] = None) -> Dict[str, Any]:
    """Prove the engine's jit compile set finite and within budget.

    The *declared* budget is the design contract (DESIGN.md §13):
    ``log2(prefill_chunk)+1`` prefill buckets, ``log2(pages_per_slot
    rounded to pow2)+1`` decode table buckets (1 for contiguous), plus
    one donated pool-copy trace under paging.  The *proven* counts come
    from exhaustive enumeration over every reachable input; an
    unbucketed family proves MORE signatures than declared and fails
    ``within_budget`` — the analyzer's rejection case.
    """
    from repro.serve.engine import _next_pow2

    prefill = enumerate_prefill_buckets(
        max_len=max_len, prefill_chunk=prefill_chunk, bucketed=bucketed,
        page_size=page_size if paged else None, prefix_cache=prefix_cache)
    declared_prefill = max(prefill_chunk.bit_length(), 1)
    if paged:
        assert page_size and pages_per_slot, "paged budget needs page geometry"
        decode = enumerate_decode_buckets(
            max_len=max_len, page_size=page_size,
            pages_per_slot=pages_per_slot)
        declared_decode = _next_pow2(pages_per_slot).bit_length()
        pool_copy = 1
    else:
        decode = []                     # one static full-width signature
        declared_decode = 1
        pool_copy = 0
    proven_decode = len(decode) if paged else 1
    proven_total = len(prefill) + proven_decode + pool_copy
    declared_total = (declared if declared is not None
                      else declared_prefill + declared_decode + pool_copy)
    resume = verify_chunk_resume(
        max_len=max_len, prefill_chunk=prefill_chunk, bucketed=bucketed,
        page_size=page_size if paged else None, prefix_cache=prefix_cache)
    return {
        "prefill": {"bucketed": bucketed, "buckets": prefill,
                    "proven": len(prefill), "declared": declared_prefill},
        "decode": {"paged": paged, "buckets": decode,
                   "proven": proven_decode, "declared": declared_decode},
        "pool_copy": {"proven": pool_copy, "declared": pool_copy},
        "chunk_resume": resume,
        "proven_total": proven_total,
        "declared_total": declared_total,
        "within_budget": (len(prefill) <= declared_prefill
                          and proven_decode <= declared_decode
                          and proven_total <= declared_total
                          and resume["closed"]),
    }


def _aval_signature(tree) -> str:
    """Stable digest of a pytree's abstract avals (shape/dtype only)."""
    import hashlib

    import jax

    leaves = [
        (tuple(getattr(x, "shape", ())), str(getattr(x, "dtype", type(x))))
        for x in jax.tree_util.tree_leaves(tree)
    ]
    return hashlib.sha256(repr(leaves).encode()).hexdigest()[:16]


def verify_engine_signatures(engine, budget: Dict[str, Any]
                             ) -> Dict[str, Any]:
    """Abstractly trace every enumerated signature against a live engine
    (``jax.eval_shape`` — no compilation, no device work), proving each
    is reachable and recording its aval digest."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    out: Dict[str, Any] = {"prefill": [], "decode": [], "verified": True}
    try:
        view = engine._slot_view(0)
        for cb in budget["prefill"]["buckets"]:
            args = (engine.params,
                    jax.ShapeDtypeStruct((1, cb), jnp.int32),
                    view, np.int32(0), jax.random.PRNGKey(0))
            jax.eval_shape(engine._prefill_chunk, *args)
            out["prefill"].append(
                {"width": cb, "signature": _aval_signature(args)})
        last = jax.ShapeDtypeStruct((engine.cfg.max_batch, 1), jnp.int32)
        key = jax.random.PRNGKey(0)
        if engine.paged:
            for hw in budget["decode"]["buckets"]:
                kv = engine.states.kv
                states_in = engine.states._replace(
                    kv=kv._replace(block_tables=kv.block_tables[:, :, :hw]))
                args = (engine.params, last, states_in, key)
                jax.eval_shape(engine._decode_step, *args)
                out["decode"].append(
                    {"table_width": hw, "signature": _aval_signature(args)})
        else:
            args = (engine.params, last, engine.states, key)
            jax.eval_shape(engine._decode_step, *args)
            out["decode"].append(
                {"table_width": None, "signature": _aval_signature(args)})
    except Exception as e:  # noqa: BLE001 — an untraceable signature is
        out["verified"] = False       # a finding, not an analyzer crash
        out["error"] = f"{type(e).__name__}: {e}"
    return out


# --------------------------------------------------------------------------
# pass 2: host-sync audit (AST)
# --------------------------------------------------------------------------

#: np/jnp call surfaces that cross the host<->device link.  ``jnp.*``
#: constructors upload (h2d); ``np.asarray``/``.item()``/int()-style
#: coercions on device values block and read back (d2h).
_H2D_ATTRS = frozenset({"asarray", "array", "int32", "int64", "float32",
                        "float64", "bfloat16", "device_put"})
_D2H_ATTRS = frozenset({"asarray", "array", "item", "tolist",
                        "block_until_ready"})
_H2D_BASES = frozenset({"jnp", "jax.numpy", "jax"})
_D2H_BASES = frozenset({"np", "numpy"})
_D2H_BUILTINS = frozenset({"int", "float", "bool"})

_SYNC_TAG_RE = re.compile(
    r"#\s*sync:\s*(required|eliminable|host)\b\s*[—–-]*\s*(.*)")

#: how often each tick-path function runs in steady-state decode — the
#: per-tick gate counts only funcs at "tick" frequency
_TICK_FREQ = {
    "step": "tick", "run_to_completion": "tick", "_flush_tables": "tick",
    "_decode_table_width": "tick", "_select": "tick", "_decode_step": "tick",
    "_prefill_quota": "tick", "_next_key": "tick",
    "_ensure_pages": "growth", "_mark_tables_dirty": "growth",
    "_run_prefills": "admission", "_advance_one": "admission",
    "_plan_chunks": "admission", "_batch_cost": "admission",
    "_reserve_chunks": "admission", "_stage_slot": "admission",
    "_exec_chunks": "admission", "_complete_admission": "admission",
    "_unwind_slot": "admission", "_prefix_credit": "admission",
    "_prefill_schedule": "admission", "_prefill_chunk": "admission",
    "_slot_view": "admission", "_merge_view": "admission",
    "_set_view_cursor": "admission", "_prefill_extent": "admission",
    "prefill_schedule": "admission", "decode_table_width": "tick",
    "_copy_page": "fork", "_jit_pool_page_copy": "fork",
    "_finish": "finish", "_scrub_slot_device": "finish",
    "_append_token": "token", "_reset_slot": "admission",
    "_tune_decode_bucket": "bucket", "retrace_budget": "stats",
    "_kernel_provenance": "bucket", "_reclaim_pages": "growth",
    "_dump_on_error": "error", "_check_compile_soundness": "drain",
}


class SyncSite(NamedTuple):
    path: str
    line: int
    func: str        # enclosing tick-path function
    api: str         # e.g. "jnp.asarray", "np.asarray", "int()"
    kind: str        # "h2d" | "d2h"
    freq: str        # tick | admission | growth | fork | finish | token
    cls: str         # required | eliminable | host | "" (untagged)
    reason: str


def _dotted(node: ast.AST) -> str:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def classify_sync_call(node: ast.AST) -> Optional[Tuple[str, str]]:
    """(api, kind) when the Call crosses the host<->device boundary;
    None otherwise."""
    if not isinstance(node, ast.Call):
        return None
    f = node.func
    if isinstance(f, ast.Attribute):
        base = _dotted(f.value)
        if base in _H2D_BASES and f.attr in _H2D_ATTRS:
            return f"{base}.{f.attr}", "h2d"
        if base in _D2H_BASES and f.attr in _D2H_ATTRS:
            return f"{base}.{f.attr}", "d2h"
        if not base and f.attr in ("item", "tolist", "block_until_ready"):
            return f".{f.attr}", "d2h"
    if (isinstance(f, ast.Name) and f.id in _D2H_BUILTINS and node.args
            and not isinstance(node.args[0], ast.Constant)):
        return f"{f.id}()", "d2h"
    return None


def find_sync_tag(line: str) -> Optional[Tuple[str, str]]:
    """(class, reason) from a ``# sync:`` tag on one source line."""
    m = _SYNC_TAG_RE.search(line)
    return (m.group(1), m.group(2).strip()) if m else None


def tick_path_functions(tree: ast.Module,
                        roots: Sequence[str] = ("step", "run_to_completion"),
                        ) -> Set[str]:
    """Transitive closure of the engine call graph from the tick roots,
    over ``self.X()`` method calls and bare module-function calls."""
    defs: Dict[str, ast.FunctionDef] = {}
    for node in tree.body:
        if isinstance(node, ast.FunctionDef):
            defs[node.name] = node
        elif isinstance(node, ast.ClassDef):
            for item in node.body:
                if isinstance(item, ast.FunctionDef):
                    defs[item.name] = item

    def callees(fn: ast.FunctionDef) -> Set[str]:
        found: Set[str] = set()
        for n in ast.walk(fn):
            if not isinstance(n, ast.Call):
                continue
            f = n.func
            if (isinstance(f, ast.Attribute)
                    and isinstance(f.value, ast.Name)
                    and f.value.id == "self" and f.attr in defs):
                found.add(f.attr)
            elif isinstance(f, ast.Name) and f.id in defs:
                found.add(f.id)
        return found

    reached: Set[str] = set()
    frontier = [r for r in roots if r in defs]
    while frontier:
        name = frontier.pop()
        if name in reached:
            continue
        reached.add(name)
        frontier.extend(callees(defs[name]) - reached)
    return reached


def audit_sync_sites(src: str, path: str = "serve/engine.py",
                     roots: Sequence[str] = ("step", "run_to_completion"),
                     ) -> List[SyncSite]:
    """Inventory every host<->device sync call inside the tick-path
    call-graph closure of one module's source."""
    tree = ast.parse(src, filename=path)
    funcs = tick_path_functions(tree, roots)
    lines = src.splitlines()
    sites: List[SyncSite] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.FunctionDef) or node.name not in funcs:
            continue
        for call in ast.walk(node):
            hit = classify_sync_call(call)
            if hit is None:
                continue
            api, kind = hit
            tag = find_sync_tag(lines[call.lineno - 1]) \
                if call.lineno <= len(lines) else None
            cls, reason = tag if tag else ("", "")
            sites.append(SyncSite(
                path=path, line=call.lineno, func=node.name, api=api,
                kind=kind, freq=_TICK_FREQ.get(node.name, "tick"),
                cls=cls, reason=reason))
    sites.sort(key=lambda s: (s.line, s.api))
    return sites


#: per-decode-tick transfer contract: one batched table flush + one
#: last-token upload (h2d <= 2) and one next-token readback (d2h <= 1)
PER_TICK_DECLARED = {"h2d": 2, "d2h": 1}


def sync_summary(sites: Sequence[SyncSite],
                 declared: Optional[Dict[str, int]] = None
                 ) -> Dict[str, Any]:
    """Aggregate the inventory into the CI gate: untagged sites are
    violations; per-tick counts (freq == "tick", class != host) must
    stay within the declared contract (``declared`` overrides the
    engine's 2 h2d + 1 d2h — the telemetry audit declares 0 + 0)."""
    if declared is None:
        declared = PER_TICK_DECLARED
    untagged = [s for s in sites if not s.cls]
    per_tick = {
        "h2d": sum(1 for s in sites
                   if s.freq == "tick" and s.kind == "h2d"
                   and s.cls != "host"),
        "d2h": sum(1 for s in sites
                   if s.freq == "tick" and s.kind == "d2h"
                   and s.cls != "host"),
    }
    table_flushes = sum(1 for s in sites
                        if s.func == "_flush_tables" and s.kind == "h2d")
    return {
        "sites": [s._asdict() for s in sites],
        "n_sites": len(sites),
        "unallowlisted": [s._asdict() for s in untagged],
        "eliminable": [s._asdict() for s in sites
                       if s.cls == "eliminable"],
        "per_tick": per_tick,
        "declared_per_tick": dict(declared),
        # S1 before/after: the replaced per-slot upload loop cost one
        # h2d transfer per grown slot per tick (<= max_batch); the
        # batched flush is a single full-table upload
        "block_table_uploads_per_tick": {
            "before": "one per grown/scrubbed slot (<= max_batch)",
            "after": table_flushes},
        "ok": (not untagged
               and per_tick["h2d"] <= declared["h2d"]
               and per_tick["d2h"] <= declared["d2h"]
               and table_flushes <= 1),
    }


def audit_engine_file(path: Optional[str] = None) -> Dict[str, Any]:
    """Run the sync audit on the installed ``repro.serve.engine``."""
    if path is None:
        import repro.serve.engine as engine_mod
        path = engine_mod.__file__
    src = Path(path).read_text(encoding="utf-8")
    rel = str(path).replace("\\", "/")
    rel = rel[rel.rfind("repro/"):] if "repro/" in rel else rel
    return sync_summary(audit_sync_sites(src, rel))


#: the telemetry emit path: every Tracer/Histogram/MetricsRegistry
#: method the engine may call per tick / per event while serving.  The
#: audit closes the call graph from these roots over serve/telemetry.py
#: — export/validation/CLI code is deliberately outside (it runs when a
#: trace is written, not while serving).
TELEMETRY_SYNC_ROOTS = (
    "_emit", "now", "begin", "end", "instant", "complete", "counter",
    "set_meta", "set_thread_name", "request_submit", "request_admitted",
    "request_chunks", "request_paused", "request_resumed",
    "request_restaged", "request_decode", "request_finish",
    "request_cancel", "record", "histogram",
)

#: instrumentation must be transfer-free: the telemetry emit path may
#: perform ZERO host<->device syncs — the engine's own 2 h2d + 1 d2h
#: per-tick contract is audited separately and must not grow
TELEMETRY_PER_TICK_DECLARED = {"h2d": 0, "d2h": 0}


def audit_telemetry_file(path: Optional[str] = None) -> Dict[str, Any]:
    """Host-sync audit of ``repro.serve.telemetry``'s emit path: proves
    the instrumentation the engine calls while serving performs no
    host<->device transfers (declared contract 0 h2d + 0 d2h; host-
    tagged sites — python-float coercions on host scalars — are
    inventoried but excluded, same rules as the engine audit)."""
    if path is None:
        import repro.serve.telemetry as tel_mod
        path = tel_mod.__file__
    src = Path(path).read_text(encoding="utf-8")
    rel = str(path).replace("\\", "/")
    rel = rel[rel.rfind("repro/"):] if "repro/" in rel else rel
    sites = audit_sync_sites(src, rel, roots=TELEMETRY_SYNC_ROOTS)
    return sync_summary(sites, declared=TELEMETRY_PER_TICK_DECLARED)


# --------------------------------------------------------------------------
# pass 3: static roofline
# --------------------------------------------------------------------------

def roofline_engine(engine, budget: Dict[str, Any],
                    platform=None) -> Dict[str, Any]:
    """Per-signature FLOPs / HBM-bytes / transfer-bytes via jaxpr
    walking, plus the per-tick host<->device byte budget implied by the
    engine's transfer sites."""
    import jax
    import jax.numpy as jnp

    from repro.analysis import costmodel

    platform = platform or costmodel.DEFAULT_PLATFORM
    b, itemsize = engine.cfg.max_batch, 4
    per_tick_h2d = b * itemsize          # last-token batch
    if engine.paged:
        per_tick_h2d += (b * engine.alloc.pages_per_slot * itemsize)
    per_tick_d2h = b * itemsize          # next-token readback
    out: Dict[str, Any] = {
        "platform": platform.name,
        "transfers_per_tick": {
            "h2d_bytes": per_tick_h2d, "d2h_bytes": per_tick_d2h,
            "h2d_ops": 2 if engine.paged else 1, "d2h_ops": 1},
        "decode": {"per_bucket": {}}, "prefill": {"per_bucket": {}},
    }
    key = jax.random.PRNGKey(0)
    last = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    transfer = float(per_tick_h2d + per_tick_d2h)
    decode_entries = {}
    widths = budget["decode"]["buckets"] if engine.paged else [None]
    for hw in widths:
        states_in = engine.states
        if hw is not None:
            kv = engine.states.kv
            states_in = engine.states._replace(
                kv=kv._replace(block_tables=kv.block_tables[:, :, :hw]))
        jx = jax.make_jaxpr(engine._decode_step)(
            engine.params, last, states_in, key)
        entry = costmodel.roofline(costmodel.jaxpr_costs(jx), platform,
                                   transfer_bytes=transfer)
        decode_entries[str(hw if hw is not None else "full")] = entry
    out["decode"]["per_bucket"] = decode_entries
    if decode_entries:
        out["decode"]["max"] = max(decode_entries.values(),
                                   key=lambda e: e["hbm_bytes"])
    view = engine._slot_view(0)
    prefill_entries = {}
    for cb in budget["prefill"]["buckets"]:
        toks = jax.ShapeDtypeStruct((1, cb), jnp.int32)
        jx = jax.make_jaxpr(engine._prefill_chunk)(
            engine.params, toks, view, jnp.int32(0), key)
        prefill_entries[str(cb)] = costmodel.roofline(
            costmodel.jaxpr_costs(jx), platform,
            transfer_bytes=float(cb * itemsize))
    out["prefill"]["per_bucket"] = prefill_entries
    if prefill_entries:
        out["prefill"]["max"] = max(prefill_entries.values(),
                                    key=lambda e: e["hbm_bytes"])
    out["jit_host_callbacks"] = sum(
        e["host_callbacks"]
        for e in list(decode_entries.values()) + list(prefill_entries.values()))
    return out


# --------------------------------------------------------------------------
# report assembly + measured-vs-proven cross-check
# --------------------------------------------------------------------------

def engine_desc(engine) -> Dict[str, Any]:
    """The effective (post-clamp) engine configuration, recorded into
    bench artifacts so :func:`cross_check_bench` can re-derive the
    proven budget purely from the artifact."""
    return {
        "family": engine.api.cfg.family,
        "allocator": "paged" if engine.paged else "contiguous",
        "bucketed": engine._bucketed,
        "max_batch": engine.cfg.max_batch,
        "max_len": engine.cfg.max_len,
        "page_size": engine.cfg.page_size,
        "prefill_chunk": engine.cfg.prefill_chunk,
        "pages_per_slot": (engine.alloc.pages_per_slot
                           if engine.paged else None),
        "prefix_cache": engine.prefix is not None,
        # continuous batching: the token-budget pace changes *when*
        # chunks run, never their trace signatures (verify_chunk_resume)
        "tick_budget": engine.cfg.tick_budget,
        # warmup="decode" pre-traces the proven ladder at construction,
        # so measured decode_compiles == the proven bound up front (the
        # cross-check budget itself is warmup-independent: warming adds
        # no signatures beyond the enumeration)
        "warmup": engine.cfg.warmup,
    }


def analyze_serve(config_name: str, *,
                  allocators: Sequence[str] = ("paged", "contiguous"),
                  engine_kw: Optional[Dict[str, Any]] = None,
                  reduced: Optional[Dict[str, Any]] = None,
                  declared_budget: Optional[int] = None,
                  seed: int = 0) -> Dict[str, Any]:
    """Run all three passes for one model config; returns the
    ``ANALYSIS_serve.json`` document (pure data, JSON-serializable)."""
    import jax

    from repro.configs import get_config
    from repro.models.registry import get_model
    from repro.nn.module import unbox
    from repro.serve.engine import Engine, EngineConfig

    cfg = get_config(config_name.replace("_", "-"))
    if reduced is not None:
        cfg = cfg.reduced(**reduced)
    api = get_model(cfg)
    params = unbox(api.init(jax.random.PRNGKey(seed)))
    engine_kw = dict(engine_kw or {})

    doc: Dict[str, Any] = {
        "schema": SCHEMA_VERSION,
        "config": cfg.name,
        "family": cfg.family,
        "engine_kw": engine_kw,
        "allocators": {},
    }
    ok = True
    for alloc in allocators:
        eng = Engine(api, params, EngineConfig(allocator=alloc, **engine_kw))
        budget = retrace_budget(
            bucketed=eng._bucketed, paged=eng.paged,
            max_len=eng.cfg.max_len, prefill_chunk=eng.cfg.prefill_chunk,
            page_size=eng.cfg.page_size,
            pages_per_slot=eng.alloc.pages_per_slot if eng.paged else None,
            prefix_cache=eng.prefix is not None, declared=declared_budget)
        sigs = verify_engine_signatures(eng, budget)
        roof = roofline_engine(eng, budget)
        arm_ok = (budget["within_budget"] and sigs["verified"]
                  and roof["jit_host_callbacks"] == 0)
        doc["allocators"][alloc] = {
            "engine": engine_desc(eng),
            "retrace": budget,
            "signatures": sigs,
            "roofline": roof,
            "ok": arm_ok,
        }
        ok = ok and arm_ok
    audit = audit_engine_file()
    doc["sync_audit"] = audit
    # the telemetry emit path is audited under its own (stricter)
    # contract: instrumentation adds ZERO h2d/d2h — the per-tick budget
    # above stays the engine's alone even with tracing compiled in
    audit_tel = audit_telemetry_file()
    doc["sync_audit_telemetry"] = audit_tel
    doc["ok"] = ok and audit["ok"] and audit_tel["ok"]
    return doc


def cross_check_bench(bench_doc: Dict[str, Any]) -> Dict[str, Any]:
    """Measured-vs-proven compile check over a serve_bench artifact.

    Each bench arm records its effective engine config (``engine`` key,
    from :func:`engine_desc`) and its live compile counters; the proven
    budget is re-derived here purely from the recorded config.  A
    measured count above the proven bound means the enumeration missed
    a reachable signature — a SOUNDNESS BUG in the analyzer, reported
    loudly, never papered over.
    """
    arms: Dict[str, Any] = {}
    ok = True
    for name, arm in bench_doc.items():
        if not isinstance(arm, dict) or "engine" not in arm:
            continue
        e = arm["engine"]
        budget = retrace_budget(
            bucketed=e["bucketed"], paged=e["allocator"] == "paged",
            max_len=e["max_len"], prefill_chunk=e["prefill_chunk"],
            page_size=e["page_size"], pages_per_slot=e.get("pages_per_slot"),
            prefix_cache=e.get("prefix_cache", False))
        checks = {
            "prefill": {"measured": arm.get("prefill_compiles", 0),
                        "proven": budget["prefill"]["proven"]},
            "decode": {"measured": arm.get("decode_compiles", 0),
                       "proven": budget["decode"]["proven"]},
        }
        failures = [
            f"SOUNDNESS BUG: {name}.{k} measured {v['measured']} compiles "
            f"> proven bound {v['proven']} — the static enumeration "
            f"missed a reachable trace signature"
            for k, v in checks.items() if v["measured"] > v["proven"]]
        arms[name] = {"checks": checks, "failures": failures,
                      "ok": not failures}
        ok = ok and not failures
    return {"ok": ok, "arms": arms,
            "checked": sorted(arms)}


def format_serve_report(doc: Dict[str, Any]) -> str:
    """Human-readable summary of an analyze_serve document."""
    lines = [f"serve static analysis: config={doc['config']} "
             f"family={doc['family']}"]
    for alloc, arm in doc["allocators"].items():
        r = arm["retrace"]
        lines.append(
            f"  [{alloc}] compile set: prefill {r['prefill']['proven']}"
            f"/{r['prefill']['declared']} buckets "
            f"{r['prefill']['buckets']}, decode {r['decode']['proven']}"
            f"/{r['decode']['declared']} "
            f"({'within' if r['within_budget'] else 'OVER'} budget, "
            f"total {r['proven_total']}/{r['declared_total']})")
        cr = r.get("chunk_resume")
        if cr:
            lines.append(
                f"  [{alloc}] chunk resume: {cr['resume_points']} resume "
                f"points, suffix "
                f"{'exact' if cr['suffix_exact'] else 'MISMATCH'}, "
                f"new widths {cr['new_widths']} -> "
                f"{'closed' if cr['closed'] else 'OPEN'}")
        roof = arm["roofline"]
        dmax = roof["decode"].get("max")
        if dmax:
            lines.append(
                f"  [{alloc}] decode tick (widest bucket): "
                f"{dmax['flops']:.3g} FLOPs, {dmax['hbm_bytes']:.3g} "
                f"HBM bytes, bound={dmax['bound']}, "
                f"est {dmax['est_s'] * 1e6:.1f} us")
        t = roof["transfers_per_tick"]
        lines.append(
            f"  [{alloc}] transfers/tick: {t['h2d_ops']} h2d "
            f"({t['h2d_bytes']} B), {t['d2h_ops']} d2h "
            f"({t['d2h_bytes']} B)")
    audit = doc["sync_audit"]
    lines.append(
        f"  sync audit: {audit['n_sites']} sites, "
        f"{len(audit['unallowlisted'])} untagged, per-tick "
        f"h2d={audit['per_tick']['h2d']}/"
        f"{audit['declared_per_tick']['h2d']} "
        f"d2h={audit['per_tick']['d2h']}/"
        f"{audit['declared_per_tick']['d2h']}, "
        f"table uploads/tick={audit['block_table_uploads_per_tick']['after']}")
    tel = doc.get("sync_audit_telemetry")
    if tel:
        lines.append(
            f"  telemetry audit: {tel['n_sites']} sites, "
            f"{len(tel['unallowlisted'])} untagged, emit-path "
            f"h2d={tel['per_tick']['h2d']}/"
            f"{tel['declared_per_tick']['h2d']} "
            f"d2h={tel['per_tick']['d2h']}/"
            f"{tel['declared_per_tick']['d2h']} "
            f"({'transfer-free' if tel['ok'] else 'VIOLATED'})")
    if "cross_check" in doc:
        cc = doc["cross_check"]
        lines.append(f"  bench cross-check: arms={cc['checked']} "
                     f"{'OK' if cc['ok'] else 'FAILED'}")
        for arm in cc["arms"].values():
            lines.extend(f"    {f}" for f in arm["failures"])
    lines.append(f"  => {'OK' if doc['ok'] else 'FAILED'}")
    return "\n".join(lines)
