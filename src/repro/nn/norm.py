"""Layer normalization variants (LayerNorm, RMSNorm).

Norm statistics are always accumulated in float32 regardless of the
activation dtype (bf16-safe), matching production LM practice.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.module import Param


def init_layernorm(embed_dim: int, *, use_bias: bool = True,
                   dtype=jnp.float32) -> dict:
    p = {"scale": Param(jnp.ones((embed_dim,), dtype), ("embed",))}
    if use_bias:
        p["bias"] = Param(jnp.zeros((embed_dim,), dtype), ("embed",))
    return p


def apply_layernorm(params: dict, x: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mean), axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32)
    if "bias" in params:
        y = y + params["bias"].astype(jnp.float32)
    return y.astype(dtype)


def init_rmsnorm(embed_dim: int, *, dtype=jnp.float32) -> dict:
    return {"scale": Param(jnp.ones((embed_dim,), dtype), ("embed",))}


def apply_rmsnorm(params: dict, x: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + eps)
    y = y * params["scale"].astype(jnp.float32)
    return y.astype(dtype)


def init_groupnorm(num_groups: int, embed_dim: int, *, dtype=jnp.float32) -> dict:
    assert embed_dim % num_groups == 0
    return {
        "scale": Param(jnp.ones((embed_dim,), dtype), ("embed",)),
        "bias": Param(jnp.zeros((embed_dim,), dtype), ("embed",)),
        # static metadata kept out of the pytree; callers pass num_groups.
    }


def apply_groupnorm(params: dict, x: jax.Array, num_groups: int,
                    *, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    *lead, d = xf.shape
    g = xf.reshape(*lead, num_groups, d // num_groups)
    mean = jnp.mean(g, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(g - mean), axis=-1, keepdims=True)
    g = (g - mean) * jax.lax.rsqrt(var + eps)
    y = g.reshape(*lead, d)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dtype)
