"""Integer-arithmetic attention, written once against the lane op set.

Both mechanisms are implemented as *lane-generic* functions
(:func:`lane_inhibitor_attention`, :func:`lane_dot_product_attention`)
over :mod:`repro.core.lanes`: run them on the ``int`` lane and you get
the paper's plaintext integer scaling arm (jit-compiled jnp int32); run
them on the ``fhe_sim`` lane and the *same* code is the TFHE circuit with
PBS/bit-width accounting — bit-exact with the int lane by construction.
The legacy entry points (:func:`int_inhibitor_attention`,
:func:`int_dot_product_attention`) are thin int-lane wrappers.

  * inhibitor: |q − k| sums (add/abs), shift/ReLU, value inhibition
    (sub/ReLU) — *no ciphertext×ciphertext products at all*.
  * dot-product: cipher–cipher MACs for QKᵀ and S·V plus the integer
    Softmax surrogate (max-subtract, exp2 LUT on the clamped difference,
    reciprocal LUT of the row sum, fixed-point renormalize).  The
    reciprocal is *multiplied back* as one more cipher–cipher product —
    the same algorithm on every integer lane, so the encrypted circuit
    and the plaintext int arm agree bit for bit.

Fixed-point range discipline (the old per-element ``(p << frac) // denom``
divide could overflow 32-bit lanes at large ``frac_bits``·``n_k``): with
``p ≤ denom`` and ``recip ≤ 2^{2·frac_bits}``, every product here is
bounded by ``2^{2·frac_bits + 1}`` and the S·V accumulation by
``2^{frac_bits}·max|V|`` (probabilities sum to one), independent of
``n_k``.  ``frac_bits`` is capped at 12 to keep int32 headroom.

Masking is cleartext (attention structure is public): masked pairs are
excluded from the combining sums — which also makes a *fully masked row
yield zero* instead of the uniform average the old ``-2^30`` score
sentinel produced.

Used by benchmarks/table3_plaintext.py for the timing-vs-T scaling law,
by :mod:`repro.fhe.circuits` (Tables 2/4), and by the lane-parameterized
model forward in :mod:`repro.models.transformer`.

Being lane-generic also makes both mechanisms *statically analyzable*:
run on the ``interval`` lane (:mod:`repro.analysis`) they execute over
symbolic bounds, turning the inhibitor's "no cipher×cipher products"
bullet above into a machine-checked proof (``cmul_sites == []`` for any
input in the quantized range) and attributing the dot-product arm's
cmuls to their contractions.  The lane-discipline lint
(``python -m repro.analysis.lint``) guards the conventions this relies
on: handle arithmetic goes through the lane, never raw np/jnp.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant.fake_quant import QuantConfig, compute_scale, quantize

if TYPE_CHECKING:   # imported lazily at runtime: repro.core.mechanism
    from repro.core.lanes import Lane      # imports this module while the
else:                                      # core package is initializing
    Lane = "Lane"


def _int_lane():
    from repro.core.lanes import IntLane

    return IntLane()

_COUNT_FRAC = 8     # fixed-point bits for the key-count normalization


def quantize_qkv(q, k, v, bits: int = 8) -> Tuple:
    """Shared-scale symmetric quantization of q, k, v (paper setup)."""
    cfg = QuantConfig(bits=bits)
    s = jnp.maximum(compute_scale(q, cfg),
                    jnp.maximum(compute_scale(k, cfg),
                                compute_scale(v, cfg)))
    return (quantize(q, s, cfg), quantize(k, s, cfg), quantize(v, s, cfg),
            s)


def _count_literal(mask, n_k: int, frac_base: int):
    """Cleartext attendable-key-count reciprocal as an adaptive fixed-
    point literal (the mask is public, so this is a literal multiply).
    Since the inhibition sum is bounded by ``cnt·max|V|``, the rescaled
    product stays under ``2^frac·max|V|`` independent of the count —
    int32-safe."""
    from repro.core.lanes import reciprocal_literal

    if mask is None:
        return reciprocal_literal(n_k, base_bits=frac_base)
    return reciprocal_literal(n_k, count=mask.sum(-1).clip(1),
                              base_bits=frac_base)


# ---------------------------------------------------------------------------
# Lane-generic mechanisms: q (..., n_q, d); k, v (..., n_k, d);
# mask — cleartext bool, broadcastable to (..., n_q, n_k)
# ---------------------------------------------------------------------------

def lane_inhibitor_attention(
    lane: Lane,
    q, k, v,
    *,
    gamma_shift: int = 0,     # score scale as a right-shift (γ = 2^shift)
    alpha_q: int = 0,         # quantized score shift α (integer units)
    signed: bool = False,     # eq. 7 (signed) vs eq. 6 (unsigned)
    mask=None,
    normalize: bool = False,
):
    """Inhibitor attention on any lane (paper eq. 5 + 6/7, integer form).

    Z = (Σ|q−k|) >> gamma_shift; H = Σ_j (V − Z)⁺ [− (−V − Z)⁺ if signed],
    masked pairs excluded.  Ops: sub, abs, add, shift, ReLU — zero
    ciphertext×ciphertext products, which is the paper's whole point.
    """
    qe = lane.expand_dims(q, -2)                       # (..., n_q, 1, d)
    ke = lane.expand_dims(k, -3)                       # (..., 1, n_k, d)
    z = lane.sum(lane.abs(lane.sub(qe, ke)), axis=-1)  # (..., n_q, n_k)
    if gamma_shift:
        z = lane.shift_right(z, gamma_shift)
    if alpha_q:
        z = lane.relu(lane.sub(z, alpha_q))

    ve = lane.expand_dims(v, -3)                       # (..., 1, n_k, d)
    ze = lane.expand_dims(z, -1)                       # (..., n_q, n_k, 1)
    inh = lane.relu(lane.sub(ve, ze))
    if signed:
        inh = lane.sub(inh, lane.relu(lane.sub(lane.neg(ve), ze)))
    if mask is not None:
        inh = lane.select(mask[..., None], inh, 0)
    h = lane.sum(inh, axis=-2)                         # (..., n_q, d)
    if normalize:
        c, f = _count_literal(mask, lane.shape(k)[-2], _COUNT_FRAC)
        c = c if mask is None else c[..., None]
        # two-step rescale: pre-shifting h keeps the literal product
        # under 2^16·max|V| regardless of n_k (one multiply at
        # f = 8 + log2(n_k) fraction bits could wrap int32 lanes); the
        # truncation it adds is ≤ 2^(f-16)/cnt output units
        pre = max(0, f - 16)
        if pre:
            h = lane.shift_right(h, pre)
        h = lane.shift_right(lane.mul_literal(h, c), f - pre)
    return h


def lane_dot_product_attention(
    lane: Lane,
    q, k, v,
    *,
    scale_shift: int = 0,
    frac_bits: int = 8,
    exp_clip: int = 15,
    mask=None,
    normalize: bool = False,   # softmax already normalizes; kept for symmetry
):
    """Dot-product attention on any lane (the paper's comparison arm).

    QKᵀ cipher MACs → shift scale → integer softmax surrogate (max via the
    relu-tree, exp2 LUT over the clamped difference, reciprocal LUT of the
    row sum multiplied back) → fixed-point S·V.  With a mask, the row max
    runs over the *attendable* subset only (the mask is public, so the
    relu-tree simply skips masked wires): fixed-point softmax is not
    shift-invariant past the exp window, so a dominant masked score would
    otherwise quantize every attendable probability to zero — and a −inf
    sentinel would widen the max/exp PBS message space.
    """
    del normalize
    if frac_bits > 12:
        raise ValueError(
            f"frac_bits={frac_bits} > 12: fixed-point products reach "
            "2^(2*frac_bits+1) and would overflow 32-bit integer lanes")
    fb = frac_bits
    s = lane.dot_scores(q, k)                          # (..., n_q, n_k)
    if scale_shift:
        s = lane.shift_right(s, scale_shift)

    if mask is not None:
        m = lane.masked_max(s, mask, axis=-1, keepdims=True)
    else:
        m = lane.max(s, axis=-1, keepdims=True)
    d = lane.sub(s, m)
    p = lane.lut(
        d,
        lambda x: (np.exp2(x.astype(np.float64)) * (1 << fb)).astype(
            np.int64),
        -exp_clip, 0,
        float_fn=lambda t: jnp.exp2(t) * float(1 << fb))
    if mask is not None:
        p = lane.select(mask, p, 0)                    # excluded, not -inf
    denom = lane.sum(p, axis=-1, keepdims=True)
    n_k = lane.shape(k)[-2]
    recip = lane.lut(
        denom,
        lambda x: (1 << (2 * fb)) // np.maximum(x, 1),
        0, int(n_k) << fb,
        float_fn=lambda x: float(1 << (2 * fb)) / jnp.maximum(x, 1e-6),
        # the table over row sums has n_k·2^fb entries — the int lane
        # evaluates the bit-identical division instead of baking a
        # multi-MB gather constant into the jaxpr at large n_k
        int_fn=lambda x: (1 << (2 * fb)) // jnp.maximum(x, 1))
    pr = lane.shift_right(lane.mul(p, recip), fb)      # probs, fb frac bits
    out = lane.mix_values(pr, v)
    return lane.shift_right(out, fb)


def lane_attention_heads(lane: Lane, lane_fn, q, k, v, *, mask=None, **kw):
    """Adapt the uniform (b, n, h|h_kv, d) layout to the per-head lane
    mechanisms: GQA-repeat kv heads, run at (b, h, n, d), restore layout.
    ``mask`` (cleartext, (b|1, 1, n_q, n_k)) broadcasts over heads."""
    rep = lane.shape(q)[2] // lane.shape(k)[2]
    qt = lane.transpose(q, (0, 2, 1, 3))
    kt = lane.transpose(lane.repeat(k, rep, 2) if rep > 1 else k,
                        (0, 2, 1, 3))
    vt = lane.transpose(lane.repeat(v, rep, 2) if rep > 1 else v,
                        (0, 2, 1, 3))
    out = lane_fn(lane, qt, kt, vt, mask=mask, **kw)
    return lane.transpose(out, (0, 2, 1, 3))


# ---------------------------------------------------------------------------
# Legacy int32 entry points (thin int-lane wrappers)
# ---------------------------------------------------------------------------

def int_inhibitor_attention(
    qi: jax.Array,        # (..., n_q, d) int32
    ki: jax.Array,        # (..., n_k, d) int32
    vi: jax.Array,        # (..., n_k, d) int32
    *,
    gamma_shift: int = 0,
    alpha_q: int = 0,
    signed: bool = False,
    mask: Optional[jax.Array] = None,
) -> jax.Array:
    """Integer inhibitor attention (eq. 5/6/7 on int32 lanes)."""
    return lane_inhibitor_attention(
        _int_lane(), qi, ki, vi, gamma_shift=gamma_shift, alpha_q=alpha_q,
        signed=signed, mask=mask)


def int_dot_product_attention(
    qi: jax.Array,
    ki: jax.Array,
    vi: jax.Array,
    *,
    scale_shift: int = 0,
    mask: Optional[jax.Array] = None,
    frac_bits: int = 8,
) -> jax.Array:
    """Integer dot-product attention baseline (paper's comparison arm)."""
    return lane_dot_product_attention(
        _int_lane(), qi, ki, vi, scale_shift=scale_shift,
        frac_bits=frac_bits, mask=mask)
