"""Jit'd public wrappers for the Pallas kernels, with CPU-fallback dispatch
and a recompute-based custom VJP so the kernels are usable in training.

On a CPU-only host (this container, CI) the wrappers run the kernels in
``interpret=True`` mode — the kernel body executes as XLA ops, which keeps
a single code path for tests and the multi-pod dry-run.  On TPU the same
calls compile to Mosaic.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref
from repro.kernels.flash import flash_attention_fwd
from repro.kernels.inhibitor import flash_inhibitor_fwd
from repro.kernels.rwkv6 import wkv6_chunked


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except RuntimeError:
        return False


# ---------------------------------------------------------------------------
# flash inhibitor (paper's mechanism)
# ---------------------------------------------------------------------------

@functools.partial(
    jax.custom_vjp,
    nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_inhibitor(q, k, v, score_scale=None, score_shift=0.5, signed=True,
                    normalize=True, causal=True, window=None):
    """Flash-inhibitor attention with recompute-based backward.

    Forward runs the Pallas kernel; backward recomputes via the jnp
    reference (activation-checkpoint style — no score matrix is saved).
    """
    return flash_inhibitor_fwd(
        q, k, v, score_scale=score_scale, score_shift=score_shift,
        signed=signed, normalize=normalize, causal=causal, window=window,
        interpret=not _on_tpu())


def _fi_fwd(q, k, v, score_scale, score_shift, signed, normalize, causal,
            window):
    out = flash_inhibitor(q, k, v, score_scale, score_shift, signed,
                          normalize, causal, window)
    return out, (q, k, v)


def _fi_bwd(score_scale, score_shift, signed, normalize, causal, window,
            res, g):
    q, k, v = res

    def f(q_, k_, v_):
        return kref.flash_inhibitor_ref(
            q_, k_, v_, score_scale=score_scale, score_shift=score_shift,
            signed=signed, normalize=normalize, causal=causal, window=window)

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


flash_inhibitor.defvjp(_fi_fwd, _fi_bwd)


# ---------------------------------------------------------------------------
# flash attention (baseline mechanism)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, score_scale=None, causal=True, window=None):
    return flash_attention_fwd(
        q, k, v, score_scale=score_scale, causal=causal, window=window,
        interpret=not _on_tpu())


def _fa_fwd(q, k, v, score_scale, causal, window):
    out = flash_attention(q, k, v, score_scale, causal, window)
    return out, (q, k, v)


def _fa_bwd(score_scale, causal, window, res, g):
    q, k, v = res

    def f(q_, k_, v_):
        return kref.flash_attention_ref(
            q_, k_, v_, score_scale=score_scale, causal=causal, window=window)

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)


# ---------------------------------------------------------------------------
# RWKV6 WKV
# ---------------------------------------------------------------------------

def wkv6(r, k, v, w, u, state=None, *, chunk: int = 32):
    """Chunked WKV6 (kernel) when starting from zero state; the exact scan
    when a carry state is provided.  The kernel-vs-scan *plan* is made
    (and trace-logged) once at the model level — models.rwkv.apply_block's
    ``choose_plan`` — so this wrapper only enforces the state-carry
    constraint for direct callers."""
    if state is not None:
        return kref.wkv6_ref(r, k, v, w, u, state)
    return wkv6_chunked(r, k, v, w, u, chunk=chunk,
                        interpret=not _on_tpu())
