"""Static analysis of the FHE circuit (abstract interpretation + lint).

The measured story (``fhe_sim``) observes one sample forward; this
package *proves* the same quantities for every input in the declared
quantized ranges: per-scope op counts (exactly equal to measured — the
circuit's control flow is input-independent), worst-case PBS message
widths (dominating any measured high-water), zero cipher×cipher products
on the inhibitor arm, and LUT-domain/table-width verification.  See
DESIGN.md §12 for the soundness contract.

    python -m repro.analysis --config paper-tiny      # ANALYSIS_fhe.json
    python -m repro.analysis.lint src/repro           # lane discipline
"""

from repro.analysis.analyzer import (DEFAULT_MECHANISMS,  # noqa: F401
                                     LUT_BITS_CEILING, analyze_config,
                                     analyze_qlm, format_report)
from repro.analysis.interval import (IntervalOverflow,  # noqa: F401
                                     IntervalTensor, as_interval)
from repro.analysis.interval_lane import IntervalLane  # noqa: F401
