"""Rotary position embeddings (RoPE), decode-aware.

Supports plain RoPE (llama/qwen/mistral style, interleaved halves) with a
configurable base, applied over ``(batch, seq, heads, head_dim)`` tensors.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_frequencies(head_dim: int, base: float = 10000.0) -> jax.Array:
    """(head_dim//2,) inverse frequencies, float32."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (base ** exponent)


def apply_rope(x: jax.Array, positions: jax.Array, *,
               base: float = 10000.0) -> jax.Array:
    """Rotate ``x`` of shape (batch, seq, heads, head_dim).

    ``positions``: (batch, seq) int32 absolute positions (decode passes the
    cache offset here, so the same code path serves prefill and decode).
    """
    head_dim = x.shape[-1]
    inv_freq = rope_frequencies(head_dim, base)  # (hd/2,)
    # (batch, seq, hd/2)
    angles = positions[..., None].astype(jnp.float32) * inv_freq
    sin = jnp.sin(angles)[:, :, None, :]  # (b, s, 1, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    # "rotate half" convention (HF llama): (x1, x2) -> (x1*cos - x2*sin,
    #                                                   x2*cos + x1*sin)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
