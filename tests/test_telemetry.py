"""Serve-path telemetry (DESIGN.md §16): span tracer, Chrome trace
export, crash flight recorder, metrics registry.

Covers the observability contract end to end:

* span-tree well-formedness for every terminal state the engine can
  reach — finish, cancel while queued / mid-prefill, truncated
  (cancel-while-decoding), and the pool-dry pause/resume path;
* flight-recorder dump on the no-progress error path (the dump exists,
  carries the reason, and the exception message points at it);
* Chrome trace-event schema validation + the module CLI as a hard gate;
* disabled-mode zero overhead: ``telemetry=None`` constructs NO tracer
  and emits NO events (proven by making every Tracer constructor blow
  up for the duration of the run);
* bounded reservoir histograms replacing the unbounded latency lists,
  with the engine's ``*_p50`` / ``*_p99`` / ``latency_samples`` stats
  surface intact;
* the telemetry emit path itself stays transfer-free and LANE004-clean
  (``audit_telemetry_file`` + the lane lint).
"""

import json

import numpy as np
import pytest

from repro.serve import telemetry
from repro.serve.telemetry import (REQ_TID_BASE, Histogram, MetricsRegistry,
                                   TelemetryConfig, Tracer, make_tracer,
                                   to_chrome_trace, validate_chrome_trace,
                                   write_trace)


def _mk_engine(serve_model, **kw):
    from repro.serve.engine import Engine, EngineConfig

    cfg, api, params = serve_model
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("telemetry", True)
    return Engine(api, params, EngineConfig(**kw))


def _prompts(seed, lens):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, 127, n).astype(np.int32) for n in lens]


def _validated(eng):
    doc = to_chrome_trace(eng.tel)
    v = validate_chrome_trace(doc)
    assert v["ok"], v["errors"]
    return doc, v


def _names(eng, ph=None):
    return [e[2] for e in eng.tel.events if ph is None or e[1] == ph]


# ---------------------------------------------------------------------------
# span trees per terminal state
# ---------------------------------------------------------------------------

def test_span_tree_finish(serve_model):
    from repro.serve.engine import Request

    eng = _mk_engine(serve_model, tick_budget=12)
    for i, p in enumerate(_prompts(30, (3, 17, 40))):
        eng.submit(Request(i, p, max_new_tokens=6))
    done = eng.run_to_completion()
    assert len(done) == 3

    doc, v = _validated(eng)
    s = v["summary"]
    assert s["requests"] == 3
    assert s["admitted"] == 3
    assert s["terminals"] == {"finish": 3}
    assert s["ticks"] > 1
    # tick phase attribution made it onto the engine track
    names = set(_names(eng))
    assert {"tick", "prefill_pass", "scheduler", "decode_step",
            "table_upload"} <= names
    # the 40-token prompt really prefilled in chunk batches (X events
    # with a duration)
    chunk_evs = [e for e in eng.tel.events
                 if e[1] == "X" and e[2] == "prefill_chunks"]
    assert chunk_evs and all("_dur" in e[5] for e in chunk_evs)
    # kernel/plan provenance rode along: engine meta + first-seen-bucket
    # instants carry the registry's interpret decision
    assert doc["otherData"]["meta"]["engine"]["family"]
    buckets = [e for e in eng.tel.events if e[2] == "decode_bucket"]
    assert buckets and all("interpret" in e[5] for e in buckets)


def test_span_tree_cancel_queued_and_mid_prefill(serve_model):
    from repro.serve.engine import Request

    eng = _mk_engine(serve_model, max_batch=1, tick_budget=8,
                     prefix_cache=False)
    long_p, queued_p = _prompts(31, (40, 6))
    eng.submit(Request(0, long_p, max_new_tokens=4))
    eng.submit(Request(1, queued_p, max_new_tokens=4))
    eng.step()
    assert eng.admitting                      # request 0 is mid-prefill
    assert eng.cancel(1)                      # still queued
    assert eng.cancel(0)                      # mid-prefill unwind

    doc, v = _validated(eng)
    assert v["summary"]["terminals"] == {"cancel": 2}
    by_track = {}
    for e in doc["traceEvents"]:
        if e.get("ph") == "i" and e["name"] == "cancel":
            by_track[e["tid"]] = e["args"]["where"]
    assert by_track == {REQ_TID_BASE + 0: "prefill",
                        REQ_TID_BASE + 1: "queued"}


def test_span_tree_truncated_on_decode_cancel(serve_model):
    from repro.serve.engine import Request

    eng = _mk_engine(serve_model)
    [p] = _prompts(32, (6,))
    eng.submit(Request(0, p, max_new_tokens=30))
    for _ in range(3):
        eng.step()
    assert eng.active                         # decoding now
    assert eng.cancel(0)                      # -> _finish(truncated=True)
    _, v = _validated(eng)
    assert v["summary"]["terminals"] == {"truncated": 1}


def test_span_tree_pool_dry_pause_resume(serve_model):
    """The backpressure path (pool-dry pause, later resume) shows up as
    paired paused/resumed instants on the request's own track, and the
    trace still validates — the pause does not tear the span tree."""
    from repro.serve.engine import Request

    eng = _mk_engine(serve_model, max_batch=2, num_pages=10,
                     prefix_cache=False, tick_budget=16)
    blocker_p, late_p = _prompts(33, (32, 40))
    eng.submit(Request(0, blocker_p, max_new_tokens=12))
    eng.step()
    eng.submit(Request(1, late_p, max_new_tokens=3))
    done = eng.run_to_completion()
    assert eng.stats()["paused_prefills"] > 0
    assert sorted(r.request_id for r in done) == [0, 1]

    _, v = _validated(eng)
    # both requests reached a terminal (the blocker may legitimately
    # truncate when the dry pool hard-stops its decode growth)
    assert sum(v["summary"]["terminals"].values()) == 2
    late_tid = REQ_TID_BASE + 1
    late = [(e[1], e[2]) for e in eng.tel.events if e[4] == late_tid]
    assert ("i", "paused") in late
    assert ("i", "resumed") in late
    # pause instants land strictly inside the prefill span
    order = [n for ph, n in late if (ph, n) in
             (("B", "prefill"), ("E", "prefill"), ("i", "paused"),
              ("i", "resumed"))]
    assert order[0] == "prefill" and order[-1] == "prefill"


def test_eviction_and_cow_instants(serve_model):
    """Prefix-cache traffic under page pressure leaves eviction (and the
    CoW forks the cache makes possible) visible in the timeline."""
    from repro.serve.engine import Request

    eng = _mk_engine(serve_model, max_batch=2, num_pages=12,
                     prefix_cache=True)
    shared = _prompts(34, (16,))[0]
    rid = 0
    for tail_len in (8, 10, 12, 14):
        tail = _prompts(35 + tail_len, (tail_len,))[0]
        eng.submit(Request(rid, np.concatenate([shared, tail]),
                           max_new_tokens=4))
        rid += 1
    eng.run_to_completion()
    _, v = _validated(eng)
    names = set(_names(eng, ph="i"))
    s = eng.stats()
    if s["evictions"]:
        assert "eviction" in names
    if s["forked_pages"]:
        assert "cow_fork" in names
    # at minimum the cache-on run re-used the shared prefix
    assert s["prefix_hit_tokens"] > 0


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------

def test_flight_dump_on_no_progress(serve_model, tmp_path):
    from repro.serve.engine import Engine, EngineConfig, Request

    cfg, api, params = serve_model
    flight = tmp_path / "FLIGHT_test.json"
    eng = Engine(api, params, EngineConfig(
        max_batch=2, max_len=64,
        telemetry=TelemetryConfig(trace=False, flight_path=str(flight))))
    assert eng.tel is not None and eng.tel.events is None   # ring only
    # simulate a leak: something outside the engine holds every slot
    assert eng.alloc.claim(990) is not None
    assert eng.alloc.claim(991) is not None
    eng.submit(Request(0, _prompts(36, (4,))[0]))
    with pytest.raises(RuntimeError, match="cannot make progress") as ei:
        eng.run_to_completion()
    assert f"[flight recorder: {flight}]" in str(ei.value)

    doc = json.loads(flight.read_text())
    other = doc["otherData"]
    assert other["flight"] is True
    assert "cannot make progress" in other["reason"]
    assert doc["traceEvents"]                 # the last ticks are there
    # a flight dump legitimately opens mid-span: the validator relaxes
    # balance/terminal checks but still type-checks every event
    v = validate_chrome_trace(doc)
    assert v["ok"], v["errors"]
    assert v["summary"]["flight"] is True


def test_flight_ring_is_bounded():
    tr = Tracer(trace=False, ring=16)
    for i in range(100):
        tr.instant("e", n=i)
    assert len(tr.ring) == 16
    assert tr.dropped == 84
    assert tr.events is None


# ---------------------------------------------------------------------------
# Chrome trace schema + CLI
# ---------------------------------------------------------------------------

def test_trace_export_schema(serve_model, tmp_path):
    from repro.serve.engine import Request

    eng = _mk_engine(serve_model)
    eng.submit(Request(0, _prompts(37, (9,))[0], max_new_tokens=4))
    eng.run_to_completion()
    path = tmp_path / "trace.json"
    write_trace(eng.tel, path)

    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    # track-naming metadata leads the stream
    meta = [e for e in evs if e["ph"] == "M"]
    assert {"process_name", "thread_name",
            "thread_sort_index"} <= {e["name"] for e in meta}
    assert any(e["name"] == "thread_name"
               and e["args"]["name"] == "req 0" for e in meta)
    for e in evs:
        assert isinstance(e["ts"], (int, float))
        assert isinstance(e["tid"], int) and isinstance(e["pid"], int)
        if e["ph"] == "i":
            assert e["s"] == "t"
        if e["ph"] == "X":
            assert isinstance(e["dur"], (int, float))
    assert doc["otherData"]["schema"] == telemetry.SCHEMA
    assert doc["otherData"]["flight"] is False

    # the module CLI is the CI hard gate: 0 on a valid trace
    assert telemetry.main([str(path), "--quiet"]) == 0


def test_cli_rejects_malformed_trace(serve_model, tmp_path, capsys):
    from repro.serve.engine import Request

    eng = _mk_engine(serve_model)
    eng.submit(Request(0, _prompts(38, (9,))[0], max_new_tokens=4))
    eng.run_to_completion()
    doc = to_chrome_trace(eng.tel)
    # drop the request's terminal instant + root close: now a request
    # track never terminates and holds an unclosed span
    tid = REQ_TID_BASE + 0
    doc["traceEvents"] = [
        e for e in doc["traceEvents"]
        if not (e.get("tid") == tid
                and (e["name"] in telemetry.TERMINALS
                     or (e["ph"] == "E" and e["name"] == "request")))]
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(doc))
    assert telemetry.main([str(bad), "--quiet"]) == 1
    err = capsys.readouterr().err
    assert "TRACE INVALID" in err and "terminal" in err


def test_validator_catches_misnesting():
    tr = Tracer()
    tr.begin("a")
    tr.begin("b")
    tr.end("a")                               # misnested: b still open
    tr.end("b")
    v = validate_chrome_trace(to_chrome_trace(tr))
    assert not v["ok"]
    assert any("does not match innermost" in e for e in v["errors"])


def test_validator_catches_backwards_time():
    tr = Tracer()
    tr._emit(100.0, "i", "late", "tick", 0, None)
    tr._emit(50.0, "i", "early", "tick", 0, None)
    v = validate_chrome_trace(to_chrome_trace(tr))
    assert not v["ok"]
    assert any("goes backwards" in e for e in v["errors"])


# ---------------------------------------------------------------------------
# disabled mode: zero events, zero allocation
# ---------------------------------------------------------------------------

def test_disabled_mode_constructs_no_tracer(serve_model, monkeypatch,
                                            greedy_ref):
    """``telemetry=None`` (the default) must never touch the telemetry
    module at runtime: any Tracer construction during the run fails the
    test, and outputs match the oracle."""
    from repro.serve.engine import Request

    def boom(*a, **kw):
        raise AssertionError("Tracer constructed with telemetry disabled")

    monkeypatch.setattr(telemetry.Tracer, "__init__", boom)
    eng = _mk_engine(serve_model, telemetry=None)
    assert eng.tel is None
    [p] = _prompts(39, (9,))
    eng.submit(Request(0, p, max_new_tokens=5))
    done = eng.run_to_completion()
    assert done[0].output == greedy_ref(p, 5)
    # the stats surface is tracer-independent (histograms still fill)
    s = eng.stats()
    assert s["latency_samples"]["ttft_ms"] == 1


def test_make_tracer_specs():
    assert make_tracer(None) is None
    assert make_tracer(False) is None
    assert make_tracer(True).events == []
    assert make_tracer("on").events == []
    fl = make_tracer("flight")
    assert fl.events is None and fl.ring is not None
    t = Tracer()
    assert make_tracer(t) is t
    c = make_tracer(TelemetryConfig(trace=False, ring=7, flight_path="x"))
    assert c.events is None and c.ring.maxlen == 7 and c.flight_path == "x"
    with pytest.raises(ValueError):
        make_tracer("bogus")


# ---------------------------------------------------------------------------
# metrics registry + bounded histograms (satellite: the unbounded-list fix)
# ---------------------------------------------------------------------------

def test_histogram_bounded_reservoir():
    h = Histogram(capacity=64)
    for i in range(10_000):
        h.record(float(i))
    assert h.count == 10_000
    assert len(h._vals) == 64                 # memory stays fixed
    assert h.max == 9999.0 and h.min == 0.0   # extremes are exact
    assert h.mean == pytest.approx(4999.5)
    # the reservoir is a uniform sample: p50 lands well inside the range
    assert 1000.0 < h.percentile(50) < 9000.0
    snap = h.snapshot()
    assert snap["count"] == 10_000 and snap["reservoir"] == 64


def test_histogram_exact_below_capacity():
    h = Histogram(capacity=512)
    for v in (1.0, 2.0, 3.0, 4.0):
        h.record(v)
    assert h.percentile(50) == pytest.approx(2.5)
    assert len(h) == 4
    with pytest.raises(ValueError):
        Histogram(capacity=0)


def test_metrics_registry():
    m = MetricsRegistry()
    m.counter("x")
    m.counter("x", 2)
    assert m.counters["x"] == 3
    assert m.histogram("h") is m.histogram("h")   # get-or-create
    m.histogram("h").record(5.0)
    snap = m.snapshot()
    assert snap["counters"] == {"x": 3}
    assert snap["histograms"]["h"]["count"] == 1


def test_engine_latency_stats_surface_bounded(serve_model):
    """The ``*_p50``/``*_p99``/``latency_samples`` keys survive the
    list->histogram swap, and engine latency memory is now bounded."""
    from repro.serve.engine import Request

    eng = _mk_engine(serve_model, telemetry=None)
    for i, p in enumerate(_prompts(40, (4, 7, 11))):
        eng.submit(Request(i, p, max_new_tokens=5))
    eng.run_to_completion()
    s = eng.stats()
    for k in ("ttft_ms", "itl_ms", "queued_ticks"):
        assert f"{k}_p50" in s and f"{k}_p99" in s
        assert s[f"{k}_p99"] >= s[f"{k}_p50"] >= 0.0
        assert eng._lat[k].capacity == 512    # bounded, not a list
    assert s["latency_samples"]["ttft_ms"] == 3
    assert s["latency_samples"]["itl_ms"] == 3 * 4   # n_new - 1 per req


# ---------------------------------------------------------------------------
# the emit path is audited transfer-free + LANE004-clean
# ---------------------------------------------------------------------------

def test_telemetry_sync_audit_transfer_free():
    from repro.analysis.serve_static import audit_telemetry_file

    audit = audit_telemetry_file()
    assert audit["ok"], audit
    assert audit["unallowlisted"] == []
    assert audit["per_tick"] == {"h2d": 0, "d2h": 0}


def test_telemetry_module_is_lane004_clean():
    from repro.analysis.lint import lint_paths

    assert lint_paths([telemetry.__file__]) == []
