"""Serving: slot-pool continuous batching engine + KV cache management."""

from repro.serve.engine import Engine, EngineConfig, Request  # noqa: F401
from repro.serve.kvcache import SlotAllocator, SlotState  # noqa: F401
