"""Convolution wrappers (used by modality-frontend examples and tests).

Production audio/vision frontends are stubs per the assignment (the
backbone consumes precomputed frame/patch embeddings); these layers back
the IAMW-style handwriting example (paper Table 1) and unit tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.module import KeyGen, Param
from repro.nn import init as initializers


def init_conv2d(key, in_ch: int, out_ch: int, kernel: tuple, *,
                use_bias: bool = True, dtype=jnp.float32) -> dict:
    kg = KeyGen(key)
    kh, kw = kernel
    w = initializers.he_normal(in_axis=2, out_axis=3)(
        kg("w"), (kh, kw, in_ch, out_ch), dtype)
    p = {"kernel": Param(w, (None, None, None, "mlp"))}
    if use_bias:
        p["bias"] = Param(jnp.zeros((out_ch,), dtype), ("mlp",))
    return p


def apply_conv2d(params: dict, x: jax.Array, *, stride: tuple = (1, 1),
                 padding: str = "SAME") -> jax.Array:
    """x: (batch, H, W, C_in) -> (batch, H', W', C_out)."""
    y = jax.lax.conv_general_dilated(
        x, params["kernel"].astype(x.dtype),
        window_strides=stride, padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if "bias" in params:
        y = y + params["bias"].astype(x.dtype)
    return y


def init_conv1d(key, in_ch: int, out_ch: int, kernel: int, *,
                use_bias: bool = True, dtype=jnp.float32) -> dict:
    kg = KeyGen(key)
    w = initializers.he_normal(in_axis=1, out_axis=2)(
        kg("w"), (kernel, in_ch, out_ch), dtype)
    p = {"kernel": Param(w, (None, None, "mlp"))}
    if use_bias:
        p["bias"] = Param(jnp.zeros((out_ch,), dtype), ("mlp",))
    return p


def apply_conv1d(params: dict, x: jax.Array, *, stride: int = 1,
                 padding: str = "SAME") -> jax.Array:
    """x: (batch, T, C_in) -> (batch, T', C_out)."""
    y = jax.lax.conv_general_dilated(
        x, params["kernel"].astype(x.dtype),
        window_strides=(stride,), padding=padding,
        dimension_numbers=("NWC", "WIO", "NWC"))
    if "bias" in params:
        y = y + params["bias"].astype(x.dtype)
    return y
