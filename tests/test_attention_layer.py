"""Unified attention layer: decode==forward, GQA, partial RoPE, ragged."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attention import (AttentionConfig, apply_attention,
                                  init_attention, init_kv_cache)
from repro.nn.module import unbox


@pytest.mark.parametrize("kind", ["dotprod", "inhibitor",
                                  "inhibitor_unsigned"])
def test_decode_matches_forward(rng, kind):
    cfg = AttentionConfig(kind=kind, num_heads=4, num_kv_heads=2, head_dim=8)
    params = unbox(init_attention(jax.random.PRNGKey(0), cfg, 32))
    x = jnp.asarray(rng.normal(size=(2, 6, 32)).astype(np.float32))
    y_full, _ = apply_attention(params, cfg, x)
    cache = init_kv_cache(2, 16, 2, 8, jnp.float32)
    y_pre, cache = apply_attention(params, cfg, x[:, :5], cache=cache)
    y_dec, cache = apply_attention(params, cfg, x[:, 5:6], cache=cache)
    np.testing.assert_allclose(y_full[:, :5], y_pre, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(y_full[:, 5:6], y_dec, rtol=1e-4, atol=1e-4)


def test_partial_rope(rng):
    cfg = AttentionConfig(kind="dotprod", num_heads=2, num_kv_heads=2,
                          head_dim=8, rope_pct=0.25)
    params = unbox(init_attention(jax.random.PRNGKey(0), cfg, 16))
    x = jnp.asarray(rng.normal(size=(1, 5, 16)).astype(np.float32))
    y, _ = apply_attention(params, cfg, x)
    assert y.shape == (1, 5, 16) and bool(jnp.isfinite(y).all())


def test_ragged_per_slot_cache(rng):
    """Per-slot cursors: each row attends over its own valid prefix."""
    cfg = AttentionConfig(kind="dotprod", num_heads=2, num_kv_heads=2,
                          head_dim=8)
    params = unbox(init_attention(jax.random.PRNGKey(0), cfg, 16))
    x = jnp.asarray(rng.normal(size=(2, 4, 16)).astype(np.float32))

    # row 0 prefilled 4 tokens; row 1 prefilled 2 — then both decode 1
    cache = init_kv_cache(2, 16, 2, 8, jnp.float32, per_slot=True)
    y4, cache4 = apply_attention(params, cfg, x, cache=cache)
    tok = jnp.asarray(rng.normal(size=(2, 1, 16)).astype(np.float32))

    # reference: single-row caches
    def single(row, prefill_len):
        c = init_kv_cache(1, 16, 2, 8, jnp.float32, per_slot=True)
        _, c = apply_attention(params, cfg, x[row:row + 1, :prefill_len],
                               cache=c)
        y, _ = apply_attention(params, cfg, tok[row:row + 1], cache=c)
        return y

    # ragged batch: manually set row 1 cursor back to 2 and re-prefill row1
    cache_r = init_kv_cache(2, 16, 2, 8, jnp.float32, per_slot=True)
    _, cache_r = apply_attention(params, cfg, x, cache=cache_r)
    k2 = cache_r.k.at[1, 2:].set(0)
    v2 = cache_r.v.at[1, 2:].set(0)
    cache_r = cache_r._replace(k=k2, v=v2,
                               length=jnp.asarray([4, 2], jnp.int32))
    y_dec, _ = apply_attention(params, cfg, tok, cache=cache_r)
    np.testing.assert_allclose(y_dec[0:1], single(0, 4), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(y_dec[1:2], single(1, 2), rtol=1e-4,
                               atol=1e-4)


def test_sliding_window_layer(rng):
    cfg = AttentionConfig(kind="inhibitor", num_heads=2, num_kv_heads=2,
                          head_dim=8, sliding_window=3)
    params = unbox(init_attention(jax.random.PRNGKey(0), cfg, 16))
    x = jnp.asarray(rng.normal(size=(1, 10, 16)).astype(np.float32))
    y, _ = apply_attention(params, cfg, x)
    assert bool(jnp.isfinite(y).all())
