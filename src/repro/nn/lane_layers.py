"""Lane-generic nn layers: norm surrogate, linear, MLP, embedding, logits.

Every layer here is written once against the :class:`repro.core.lanes.Lane`
op set — add/sub, plaintext-weight matmul, literal mul/shift, ReLU/abs,
univariate LUT — so the same code runs the float reference, the jnp
integer arm, and the TFHE cost simulator (DESIGN.md §9).  LUT sites carry
their real-valued counterpart (``float_fn``), which is the *only* place
the float lane diverges from the integer pipeline; everything else is
shared, so int-vs-float disagreement is pure fixed-point rounding.

The norm surrogate is the one genuinely FHE-shaped deviation: dynamic
normalization ``x · rsqrt(ms(x))`` is a ciphertext×ciphertext product,
which would destroy the inhibitor block's zero-cmul property.  Instead we
*shift-normalize*: a LUT maps the mean square to its dyadic reciprocal-
sqrt exponent ``ex ≈ log2(rms)`` (a few bits), and a packed bivariate LUT
applies the data-dependent shift ``x · 2^(act_frac − ex)`` in one PBS.
All multiplicative work stays literal/PBS — no cipher×cipher anywhere.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.lanes import Lane
from repro.quant.ptq import PtqConfig

_MEAN_FRAC = 8   # significant bits of the 1/d literals in mean reductions


def _mean_literal(d: int):
    """1/d with ~_MEAN_FRAC significant bits for *any* d (a fixed-width
    numerator is 0 past 2^_MEAN_FRAC, silently breaking the means)."""
    from repro.core.lanes import reciprocal_literal

    return reciprocal_literal(d, base_bits=_MEAN_FRAC)


def lane_linear(lane: Lane, x, p: dict, *, ptq: PtqConfig):
    """x @ W_cleartext (+ bias) >> weight_frac — scale-preserving."""
    y = lane.matmul_plain(x, np.asarray(p["kernel"]))
    if "bias" in p:
        y = lane.add(y, np.asarray(p["bias"]))
    return lane.shift_right(y, ptq.weight_frac)


def lane_norm(lane: Lane, x, p: dict, *, ptq: PtqConfig,
              subtract_mean: bool = False):
    """RMSNorm/LayerNorm surrogate: shift-normalized, LUT reciprocal-sqrt.

    1. (LayerNorm only) subtract the mean — levelled literal ops.
    2. squares via LUT ``t → t² >> sq_shift`` (input saturates to the
       activation clamp — this is where the residual stream re-enters the
       quantized range), mean via literal 1/d.
    3. the reciprocal-sqrt LUT maps the mean square to its dyadic
       exponent in *half steps* ``ex = round(2·log2 rms) ∈ [0, 2^ex_bits)``
       (half steps bound the normalizer error by 2^±1/4 ≈ 19%).
    4. packed bivariate LUT applies ``x · 2^(act_frac − ex/2)`` — the
       data-dependent shift, one PBS at ``act_bits + ex_bits`` width.
    5. learned scale (weight-scale literal) and bias (activation scale).
    """
    A, B = ptq.act_frac, ptq.act_clip
    sq_shift, ex_hi = ptq.sq_shift, (1 << ptq.ex_bits) - 1
    d = lane.shape(x)[-1]
    c_d, f_d = _mean_literal(d)

    if subtract_mean:
        mu = lane.shift_right(
            lane.mul_literal(lane.sum(x, axis=-1, keepdims=True), c_d),
            f_d)
        x = lane.sub(x, mu)

    sq = lane.lut(
        x, lambda t: (t * t) >> sq_shift, -B, B,
        float_fn=lambda t: t * t / float(1 << sq_shift))
    ms = lane.shift_right(
        lane.mul_literal(lane.sum(sq, axis=-1, keepdims=True), c_d),
        f_d)

    ms_hi = (B * B) >> sq_shift

    def _ex_int(m):
        rms = np.sqrt(np.maximum(m, 1).astype(np.float64)
                      * (1 << sq_shift))
        return np.clip(np.round(2.0 * np.log2(rms)), 0, ex_hi).astype(
            np.int64)

    ex = lane.lut(
        ms, _ex_int, 0, ms_hi,
        float_fn=lambda m: _fclip(2.0 * _flog2_rms(m, sq_shift), ex_hi,
                                  lo=0))

    def _shift_int(t, e):
        return np.clip(
            np.round(t.astype(np.float64) * 2.0 ** (A - e / 2.0)),
            -B, B).astype(np.int64)

    y = lane.lut2(
        x, ex, _shift_int, x_lo=-B, x_hi=B, y_lo=0, y_hi=ex_hi,
        float_fn=lambda t, e: _fclip(t * 2.0 ** (A - e / 2.0), B))

    y = lane.shift_right(lane.mul_literal(y, np.asarray(p["scale"])),
                         ptq.weight_frac)
    if "bias" in p:
        y = lane.add(y, np.asarray(p["bias"]))
    return y


def _flog2_rms(m, sq_shift):
    import jax.numpy as jnp

    return 0.5 * jnp.log2(jnp.maximum(m, 1e-6) * float(1 << sq_shift))


def _fclip(t, b, lo=None):
    import jax.numpy as jnp

    return jnp.clip(t, -float(b) if lo is None else float(lo), float(b))


def _gelu(x, xp):
    """tanh-approximation GELU over either array module (np table builds
    and the jnp float lane must share one formula — parity by identity)."""
    from math import pi, sqrt

    return 0.5 * x * (1.0 + xp.tanh(sqrt(2.0 / pi)
                                    * (x + 0.044715 * x ** 3)))


def lane_mlp(lane: Lane, x, wi: dict, wo: dict, *, ptq: PtqConfig,
             activation: str = "relu"):
    """Classic two-layer MLP (paper eq. 4): act(x W1 + b1) W2 + b2.
    ReLU is the native 1-PBS op; GELU is a LUT over the activation
    domain.  Gated variants are rejected at PTQ time (cipher×cipher)."""
    h = lane_linear(lane, x, wi, ptq=ptq)
    if activation == "relu":
        h = lane.relu(h)
    elif activation == "gelu":
        import jax.numpy as jnp

        A, B = ptq.act_frac, ptq.act_clip
        h = lane.lut(
            h,
            lambda t: np.round(_gelu(t.astype(np.float64) / (1 << A), np)
                               * (1 << A)).astype(np.int64),
            -4 * B, 4 * B,
            float_fn=lambda t: _gelu(t / float(1 << A), jnp)
            * float(1 << A))
    else:
        raise ValueError(f"unsupported lane activation {activation!r}")
    return lane_linear(lane, h, wo, ptq=ptq)


def lane_embed(lane: Lane, table_q: np.ndarray, tokens) -> "object":
    """Client-side embedding: cleartext table lookup on cleartext tokens,
    then ingestion into the lane (encryption on ``fhe_sim``).  A TFHE
    server cannot index a table with an encrypted id, so in the paper's
    deployment the client embeds locally and encrypts activations.
    Routed through :meth:`Lane.embed` so the static-analysis lane can
    substitute per-channel vocabulary bounds for the concrete gather."""
    return lane.embed(table_q, tokens)


def lane_logits(lane: Lane, x, final_norm: dict, lm_head: dict, *,
                ptq: PtqConfig, subtract_mean: bool = False):
    """Final norm + cleartext lm-head projection → encrypted logits
    (decrypted and argmax'd client-side)."""
    h = lane_norm(lane, x, final_norm, ptq=ptq,
                  subtract_mean=subtract_mean)
    return lane_linear(lane, h, lm_head, ptq=ptq)
