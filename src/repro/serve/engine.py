"""Batched serving engine with continuous batching.

Design (vLLM-style scheduling on a slot pool, TPU-friendly static shapes):

  * A fixed pool of ``max_batch`` slots backs one layer-stacked KV cache
    with **per-slot cursors** (ragged decode is exact — each row attends
    over its own valid prefix only).
  * Incoming requests queue; whenever a slot frees, the next request is
    admitted and its prompt is prefilled *into that slot only* (the other
    slots' rows are untouched because prefill uses per-slot masking).
  * Every engine tick runs one decode step for all active slots together
    (inactive rows compute garbage that is ignored — static shapes, no
    recompilation).
  * A request finishes on EOS or at max_new_tokens; its slot is recycled
    immediately (continuous batching: no global barrier at batch end).

The same engine drives the `serve` launcher and the serving example; on a
mesh the step functions are jit'd with sharded params (TP) and replicated
small decode batches.
"""

from __future__ import annotations

import dataclasses
import logging
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import ModelApi
from repro.serve.kvcache import SlotAllocator

log = logging.getLogger("repro.serve")


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray             # (prompt_len,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine:
    output: Optional[list] = None


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    max_len: int = 512
    greedy: bool = True


class Engine:
    def __init__(self, api: ModelApi, params, cfg: EngineConfig):
        self.api = api
        self.params = params
        self.cfg = cfg
        self.alloc = SlotAllocator(cfg.max_batch)
        self.queue: deque = deque()
        self.active: Dict[int, Request] = {}     # slot -> request
        self.states = api.init_states(cfg.max_batch, cfg.max_len)
        self.decode_plan = self._plan_decode()
        if self.decode_plan is not None:
            log.info("engine decode %s [max_batch=%d max_len=%d]",
                     self.decode_plan.trace_line(), cfg.max_batch,
                     cfg.max_len)
        self._jit_decode = jax.jit(self._decode_step)
        self._jit_prefill_one = jax.jit(self._prefill_slot,
                                        static_argnames=("slot",))

    def _plan_decode(self):
        """Inspectable attention plan for the steady-state decode tick
        (per-slot ragged cursors, full-pool KV buffer).  None for
        attention-free families (rwkv)."""
        from repro.core.mechanism import AttnShapes, plan_attention

        mcfg = self.api.cfg
        if mcfg.family == "ssm":
            return None
        acfg = mcfg.attention
        shapes = AttnShapes(
            batch=self.cfg.max_batch, n_q=1, n_k=self.cfg.max_len,
            num_heads=acfg.num_heads, num_kv_heads=acfg.num_kv_heads,
            head_dim=acfg.head_dim, dtype=mcfg.cdtype, has_cache=True,
            scalar_cursor=False)
        return plan_attention(acfg, shapes)

    # ---- jitted kernels ----
    def _decode_step(self, params, tokens, states):
        logits, new_states = self.api.step(params, tokens, states, None)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt, new_states

    def _prefill_slot(self, params, tokens, states, *, slot: int):
        """Prefill one slot's row: the other rows' caches must not change.

        We run the step over the full (static-shape) batch with the prompt
        broadcast, then splice the updated row into the previous states.
        Per-slot cursors make the attention of other rows irrelevant."""
        b = self.cfg.max_batch
        toks = jnp.broadcast_to(tokens[None], (b,) + tokens.shape)
        logits, new_states = self.api.step(params, toks, states, None)

        # splice the target slot's updated rows into the *argument* states
        # (never a captured self.states — inside jit that would freeze a
        # stale snapshot as a constant and clobber other slots on recycle)
        def splice(new, old):
            if new is None or old is None:
                return old
            # leaf layouts: (L, b, ...) for buffers, (L, b) or (L,) lengths
            if new.ndim >= 2 and new.shape[1] == b:
                return old.at[:, slot].set(new[:, slot])
            return old  # shared scalars (not used with per-slot cursors)

        spliced = jax.tree.map(splice, new_states, states,
                               is_leaf=lambda x: x is None)
        nxt = jnp.argmax(logits[slot, -1], axis=-1).astype(jnp.int32)
        return nxt, spliced

    # ---- public API ----
    def submit(self, req: Request):
        req.output = []
        self.queue.append(req)

    def _admit(self):
        while self.queue:
            slot = self.alloc.claim(self.queue[0].request_id)
            if slot is None:
                return
            req = self.queue.popleft()
            self.active[slot] = req
            # reset this slot's cursor/recurrent state, then prefill
            self.states = _reset_slot(self.states, slot)
            nxt, self.states = self._jit_prefill_one(
                self.params, jnp.asarray(req.prompt), self.states, slot=slot)
            self.alloc.slots[slot].length = len(req.prompt)
            req.output.append(int(nxt))
            log.debug("admitted request %d into slot %d", req.request_id,
                      slot)

    def _finish(self, slot: int):
        req = self.active.pop(slot)
        self.alloc.release(slot)
        return req

    def step(self) -> List[Request]:
        """One engine tick. Returns requests that finished this tick."""
        self._admit()
        if not self.active:
            return []
        last = np.zeros((self.cfg.max_batch, 1), np.int32)
        for slot, req in self.active.items():
            last[slot, 0] = req.output[-1]
        nxt, self.states = self._jit_decode(self.params, jnp.asarray(last),
                                            self.states)
        nxt = np.asarray(nxt)
        finished = []
        for slot in list(self.active):
            req = self.active[slot]
            req.output.append(int(nxt[slot]))
            self.alloc.slots[slot].length += 1
            done = (len(req.output) >= req.max_new_tokens
                    or (req.eos_id is not None
                        and req.output[-1] == req.eos_id))
            if done:
                finished.append(self._finish(slot))
        return finished

    def run_to_completion(self, max_ticks: int = 10_000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_ticks):
            done.extend(self.step())
            if not self.active and not self.queue:
                break
        return done


def _reset_slot(states, slot: int):
    """Reset one slot's decode state across all layers.

    Transformer family: zero the (L, b) cursor; KV buffer rows need no
    clearing (validity is cursor-defined).  Hybrid: also zero the slot's
    mamba ssm/conv carries.  RWKV: zero the slot's recurrent state rows.
    """
    from repro.core.attention import KVCache
    from repro.models.transformer import LayerState

    if isinstance(states, LayerState):
        kv = states.kv._replace(length=states.kv.length.at[:, slot].set(0))
        ssm = (states.ssm.at[:, slot].set(0)
               if states.ssm is not None else None)
        conv = (states.conv.at[:, slot].set(0)
                if states.conv is not None else None)
        return LayerState(kv=kv, ssm=ssm, conv=conv)
    # recurrent families (rwkv): zero every state leaf's slot row
    return jax.tree.map(lambda x: x.at[:, slot].set(jnp.zeros_like(x[:, slot])),
                        states)
