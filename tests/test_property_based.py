"""Hypothesis property tests (paper-level invariants + substrate bounds).

``hypothesis`` is an optional test dependency (the ``test`` extra in
pyproject.toml).  This module holds every property-based test so that,
when the package is absent, the whole file skips at collection via
``pytest.importorskip`` and tier-1 collection never dies — the
deterministic tests stay in their home modules and always run.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import inhibitor as I  # noqa: E402
from repro.optim import (compress_tree, decompress_tree,  # noqa: E402
                         init_compression)
from repro.quant.fake_quant import (QuantConfig, compute_scale,  # noqa: E402
                                    dequantize, quantize)


# ---------------------------------------------------------------------------
# Inhibitor core (paper-level invariants)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(2, 8), st.integers(2, 8), st.integers(2, 6),
       st.floats(0.0, 2.0), st.integers(0, 10**6))
def test_scores_nonnegative_and_shift_monotone(nq, nk, d, shift, seed):
    """Z ≥ 0 always; larger α ⇒ pointwise smaller Z (eq. 5 + shift)."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(nq, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(nk, d)).astype(np.float32))
    z = I.manhattan_scores(q, k, score_shift=shift)
    assert bool((z >= 0).all())
    z2 = I.manhattan_scores(q, k, score_shift=shift + 0.5)
    assert bool((z2 <= z + 1e-6).all())


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 6), st.integers(2, 10), st.integers(2, 6),
       st.integers(0, 10**6))
def test_inhibition_monotone_in_z(nq, nk, d, seed):
    """Unsigned H is pointwise non-increasing in Z (inhibition semantics)."""
    rng = np.random.default_rng(seed)
    v = jnp.asarray(rng.normal(size=(nk, d)).astype(np.float32))
    z = jnp.asarray(np.abs(rng.normal(size=(nq, nk))).astype(np.float32))
    h1 = I.inhibit_fused(v, z)
    h2 = I.inhibit_fused(v, z + 0.3)
    assert bool((h2 <= h1 + 1e-5).all())


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 10), st.integers(2, 6), st.integers(0, 10**6))
def test_normalized_output_bounded_by_values(nk, d, seed):
    """With normalization, |H| ≤ max|V| (inhibition only attenuates)."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(1, 3, nk, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 3, nk, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 3, nk, d)).astype(np.float32))
    qb, kb, vb = (x.transpose(0, 2, 1, 3) for x in (q, k, v))
    out = I.inhibitor_attention(qb, kb, vb, normalize=True, signed=True)
    assert float(jnp.abs(out).max()) <= float(jnp.abs(v).max()) + 1e-4


@settings(max_examples=20, deadline=None)
@given(st.integers(3, 10), st.integers(2, 5), st.integers(0, 10**6))
def test_key_permutation_invariance(nk, d, seed):
    """H is invariant to permuting (K, V) rows together (no positional
    dependence in the mechanism itself — order comes only from masks)."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(1, 4, 2, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, nk, 2, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, nk, 2, d)).astype(np.float32))
    perm = np.random.default_rng(seed + 1).permutation(nk)
    o1 = I.inhibitor_attention(q, k, v)
    o2 = I.inhibitor_attention(q, k[:, perm], v[:, perm])
    np.testing.assert_allclose(o1, o2, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Quantization
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(4, 8), st.integers(1, 64), st.integers(0, 10**6))
def test_quant_roundtrip_error_bound(bits, n, seed):
    """|x − dq(q(x))| ≤ scale/2 (symmetric max-abs quantization)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    cfg = QuantConfig(bits=bits)
    s = compute_scale(x, cfg)
    err = jnp.abs(dequantize(quantize(x, s, cfg), s) - x)
    assert float(err.max()) <= float(s) / 2 + 1e-6


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10**6))
def test_compression_error_feedback(seed):
    """With error feedback, the accumulated compressed sum tracks the true
    sum (residual stays bounded)."""
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(size=(64,)).astype(np.float32))}
    state = init_compression(g)
    total_true = jnp.zeros(64)
    total_comp = jnp.zeros(64)
    for _ in range(10):
        (q, s), state = compress_tree(g, state)
        total_comp = total_comp + decompress_tree(q, s)["w"]
        total_true = total_true + g["w"]
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127
    assert float(jnp.abs(total_comp - total_true).max()) <= scale + 1e-5


# ---------------------------------------------------------------------------
# Lane parity (DESIGN.md §9): one algorithm, three arithmetic domains
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(2, 8), st.integers(2, 8), st.integers(2, 6),
       st.integers(0, 10**6))
def test_inhibitor_int_lane_equals_float_lane_exactly(nq, nk, d, seed):
    """The paper's 'straightforward quantization' as an exact property:
    with no shifts, the inhibitor pipeline is sub/abs/add/relu only — all
    integer-exact in float32 — so int and float lanes agree bit for bit
    at quantized inputs."""
    from repro.core.lanes import get_lane
    from repro.quant.int_attention import lane_inhibitor_attention

    rng = np.random.default_rng(seed)
    q = rng.integers(-31, 32, (1, nq, d))
    k = rng.integers(-31, 32, (1, nk, d))
    v = rng.integers(-31, 32, (1, nk, d))
    li, lf = get_lane("int"), get_lane("float")
    oi = li.to_numpy(lane_inhibitor_attention(
        li, li.array(q), li.array(k), li.array(v), signed=True))
    of = lf.to_numpy(lane_inhibitor_attention(
        lf, lf.array(q), lf.array(k), lf.array(v), signed=True))
    np.testing.assert_array_equal(oi, of.astype(np.int64))


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6), st.integers(2, 8), st.integers(1, 3),
       st.integers(0, 10**6))
def test_inhibitor_int_float_lane_bounded_under_shifts(nq, nk, shift, seed):
    """With a γ right-shift the lanes differ only by the floor rounding of
    Z: |Z_int − Z_float| < 1, and the inhibition sum amplifies that by at
    most n_k per channel."""
    from repro.core.lanes import get_lane
    from repro.quant.int_attention import lane_inhibitor_attention

    rng = np.random.default_rng(seed)
    d = 4
    q = rng.integers(-31, 32, (1, nq, d))
    k = rng.integers(-31, 32, (1, nk, d))
    v = rng.integers(-31, 32, (1, nk, d))
    li, lf = get_lane("int"), get_lane("float")
    kw = dict(gamma_shift=shift, alpha_q=1, signed=True)
    oi = li.to_numpy(lane_inhibitor_attention(
        li, li.array(q), li.array(k), li.array(v), **kw)).astype(float)
    of = lf.to_numpy(lane_inhibitor_attention(
        lf, lf.array(q), lf.array(k), lf.array(v), **kw))
    assert float(np.abs(oi - of).max()) <= 2.0 * nk


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 6), st.integers(2, 10), st.integers(0, 10**6))
def test_lane_layers_int_fhe_bit_exact_property(nq, d, seed):
    """Whatever the shapes/values, the int lane and the TFHE simulator
    execute identical integer arithmetic (norm + mlp + both attention
    mechanisms)."""
    from repro.core.lanes import FheSimLane, get_lane
    from repro.nn.lane_layers import lane_norm
    from repro.quant.int_attention import (lane_dot_product_attention,
                                           lane_inhibitor_attention)
    from repro.quant.ptq import PtqConfig

    rng = np.random.default_rng(seed)
    ptq = PtqConfig()
    x = rng.integers(-ptq.act_clip, ptq.act_clip + 1, (1, nq, d))
    p = {"scale": rng.integers(32, 96, d)}
    li, lh = get_lane("int"), FheSimLane()
    np.testing.assert_array_equal(
        li.to_numpy(lane_norm(li, li.array(x), p, ptq=ptq)),
        lh.to_numpy(lane_norm(lh, lh.array(x), p, ptq=ptq)))
    for fn, kw in ((lane_inhibitor_attention,
                    dict(gamma_shift=1, alpha_q=2, signed=True)),
                   (lane_dot_product_attention,
                    dict(scale_shift=3, frac_bits=6))):
        np.testing.assert_array_equal(
            li.to_numpy(fn(li, li.array(x), li.array(x), li.array(x),
                           **kw)),
            lh.to_numpy(fn(lh, lh.array(x), lh.array(x), lh.array(x),
                           **kw)))
