"""Lightweight parameter/module system for pure-JAX models.

No framework dependency: parameters are nested dicts whose leaves are
:class:`Param` pytree nodes.  Each ``Param`` carries the array *and* a tuple
of **logical axis names** (one per array dim, e.g. ``("vocab", "embed")``).
Logical names are mapped to physical mesh axes by the rules tables in
:mod:`repro.distributed.sharding`, which is how every model in this repo
gets its pjit ``in_shardings`` without per-model sharding code.

Usage pattern::

    params = model.init(key, cfg)          # tree of Param
    arrs   = unbox(params)                 # tree of jax.Array (same structure)
    axes   = axes_of(params)               # tree of tuple[str, ...]
    out    = model.apply(arrs, inputs)     # apply functions take plain arrays

``Param`` is registered as a pytree node whose child is the array and whose
aux data is the axes tuple, so ``jax.tree.map`` over a boxed tree maps over
arrays while preserving the annotation (used by the optimizer to keep
optimizer-state shardings aligned with parameter shardings).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Param:
    """An array annotated with logical sharding axes.

    ``axes`` has one entry per dim; ``None`` entries mean "replicated /
    no constraint on this dim".
    """

    value: jax.Array
    axes: tuple

    def __post_init__(self):
        if hasattr(self.value, "ndim") and len(self.axes) != self.value.ndim:
            raise ValueError(
                f"Param axes {self.axes} rank mismatch with value shape "
                f"{getattr(self.value, 'shape', '?')}"
            )

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, axes, children):
        # Bypass __post_init__ checks: during tree transforms the child can
        # be a tracer/placeholder object without ndim.
        obj = object.__new__(cls)
        obj.value = children[0]
        obj.axes = axes
        return obj

    @property
    def shape(self):
        return self.value.shape

    @property
    def dtype(self):
        return self.value.dtype


def is_param(x) -> bool:
    return isinstance(x, Param)


def unbox(tree: PyTree) -> PyTree:
    """Strip Param boxes, returning a plain-array tree of the same structure."""
    return jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)


def axes_of(tree: PyTree) -> PyTree:
    """Return the logical-axes tree matching ``unbox(tree)``'s structure."""
    return jax.tree.map(lambda p: p.axes, tree, is_leaf=is_param)


def box_like(arrs: PyTree, axes: PyTree) -> PyTree:
    """Re-attach axis annotations to a plain-array tree."""
    return jax.tree.map(Param, arrs, axes)


def param_count(tree: PyTree) -> int:
    arrs = unbox(tree) if any(is_param(l) for l in jax.tree.leaves(
        tree, is_leaf=is_param)) else tree
    return sum(int(x.size) for x in jax.tree.leaves(arrs))


def param_bytes(tree: PyTree) -> int:
    arrs = unbox(tree) if any(is_param(l) for l in jax.tree.leaves(
        tree, is_leaf=is_param)) else tree
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(arrs))


def split_keys(key: jax.Array, n: int) -> list:
    return list(jax.random.split(key, n))


def fold_key(key: jax.Array, name: str) -> jax.Array:
    """Deterministically derive a sub-key from a string name.

    Uses a *stable* hash: python's builtin ``hash()`` is salted per
    process (PYTHONHASHSEED), which silently made every init
    irreproducible across runs — checkpoint-free restart exactness and
    cross-process parity tests depend on this being process-invariant.
    """
    import zlib

    h = zlib.crc32(name.encode("utf-8")) % (2**31 - 1)
    return jax.random.fold_in(key, h)


class KeyGen:
    """Convenience splitter: ``kg = KeyGen(key); k1 = kg('wq')``."""

    def __init__(self, key: jax.Array):
        self._key = key

    def __call__(self, name: str) -> jax.Array:
        return fold_key(self._key, name)


def format_tree(tree: PyTree, max_leaves: int = 200) -> str:
    """Human-readable parameter inventory (shape/dtype/axes per leaf)."""
    lines = []
    flat = jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_param)[0]
    for path, leaf in flat[:max_leaves]:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if is_param(leaf):
            lines.append(
                f"  {name:60s} {str(leaf.value.shape):24s} "
                f"{str(leaf.value.dtype):10s} axes={leaf.axes}"
            )
        else:
            lines.append(f"  {name:60s} {leaf!r}")
    if len(flat) > max_leaves:
        lines.append(f"  ... (+{len(flat) - max_leaves} more)")
    return "\n".join(lines)


def tree_map_params(fn: Callable, tree: PyTree) -> PyTree:
    """Map ``fn`` over Param leaves, preserving annotations."""
    return jax.tree.map(
        lambda p: Param(fn(p.value), p.axes) if is_param(p) else fn(p),
        tree,
        is_leaf=is_param,
    )


def cast_params(tree: PyTree, dtype) -> PyTree:
    """Cast all floating-point params to ``dtype`` (int params untouched)."""

    def _cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return tree_map_params(_cast, tree)
