"""Flash attention (dot-product Softmax) — the paper's baseline mechanism,
as a blockwise Pallas TPU kernel with the standard running-max/denominator
online-Softmax recurrence.

Kept deliberately symmetric with :mod:`repro.kernels.inhibitor` (same grid,
same BlockSpecs, same GQA grouping) so the two mechanisms' HLO and roofline
terms are directly comparable — this is the kernel-level analogue of the
paper's Tables 3/4 comparison.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.inhibitor import launch_prefill_kernel, pack_cursors

DEFAULT_BLOCK_Q = 64
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30

#: Native-lowering platforms (see kernels.paged.LOWERS_ON): the launch
#: path shared with :mod:`repro.kernels.inhibitor` allocates
#: ``pltpu.VMEM`` scratch and uses scalar-prefetch cursors, so GPU
#: execution today is interpret-mode only.
LOWERS_ON = ("tpu",)


def _flash_attention_kernel(
    # refs: [cursors_ref,] q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref
    *refs,
    score_scale: float,
    causal: bool,
    window: Optional[int],
    kv_len: int,
    kv_heads: int,
    block_q: int,
    block_k: int,
    n_kv_blocks: int,
    cached: bool,
):
    if cached:
        cur_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref = refs
    else:
        cur_ref = None
        q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref = refs
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)          # (group, bq, d)
    group, bq, d = q.shape
    ks = k_ref[0].astype(jnp.float32)         # (bk, d)
    vs = v_ref[0].astype(jnp.float32)

    if cur_ref is not None:
        # per-row decode cursors (scalar-prefetched; see inhibitor kernel)
        row = pl.program_id(0) // kv_heads
        q_off = cur_ref[0, row]
        kv_valid = jnp.minimum(kv_len, cur_ref[1, row])
    else:
        q_off = 0
        kv_valid = kv_len
    q_pos = (q_off + iq * block_q
             + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0))
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 1)
    m_blk = k_pos < kv_valid
    if causal:
        m_blk = m_blk & (k_pos <= q_pos)
    if window is not None:
        # a sliding window implies causality (single semantics everywhere)
        m_blk = m_blk & (k_pos > q_pos - window) & (k_pos <= q_pos)

    def do_block():
        s = jnp.einsum("gqd,kd->gqk", q, ks) * (1.0 / score_scale)
        s = jnp.where(m_blk[None], s, NEG_INF)
        m_prev = m_ref[...]                                 # (g, bq)
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        # fully-masked rows: exp(NEG_INF - NEG_INF) = 1 — zero them out
        p = p * jnp.any(m_blk, axis=-1)[None, :, None]
        alpha = jnp.exp(m_prev - m_new)
        alpha = jnp.where(m_prev == NEG_INF, 0.0, alpha)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc_ref[...] * alpha[..., None] + jnp.einsum("gqk,kd->gqd", p, vs)
        return acc, m_new, l_new

    live = True
    if causal or window is not None:
        live = (ik * block_k) <= (q_off + iq * block_q + block_q - 1)
    if cur_ref is not None:
        # skip blocks wholly past the row's valid-length cursor
        live = jnp.logical_and(live, (ik * block_k) < kv_valid)
    if isinstance(live, bool):
        acc, m_new, l_new = do_block()
    else:
        acc, m_new, l_new = jax.lax.cond(
            live, do_block,
            lambda: (acc_ref[...], m_ref[...], l_ref[...]))

    acc_ref[...] = acc
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-20)[..., None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    score_scale: Optional[float] = None,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    q_offset=None,
    kv_valid_len=None,
    interpret: bool = False,
) -> jax.Array:
    """q: (b, n_q, h, d); k, v: (b, n_k, h_kv, d) -> (b, n_q, h, d).

    ``q_offset`` / ``kv_valid_len`` (int, scalar array, or per-row (b,)
    arrays) express decode-cache structure — see
    :func:`repro.kernels.inhibitor.flash_inhibitor_fwd`."""
    batch, n_q, heads, d = q.shape
    n_k, kv_heads = k.shape[1], k.shape[2]
    assert heads % kv_heads == 0
    group = heads // kv_heads
    scale = score_scale if score_scale is not None else math.sqrt(d)

    block_q = min(block_q, max(8, 1 << (n_q - 1).bit_length()))
    block_k = min(block_k, max(8, 1 << (n_k - 1).bit_length()))
    nq_pad = -n_q % block_q
    nk_pad = -n_k % block_k

    qg = q.reshape(batch, n_q, kv_heads, group, d).transpose(0, 2, 3, 1, 4)
    qg = qg.reshape(batch * kv_heads, group, n_q, d)
    kg = k.transpose(0, 2, 1, 3).reshape(batch * kv_heads, n_k, d)
    vg = v.transpose(0, 2, 1, 3).reshape(batch * kv_heads, n_k, d)
    if nq_pad:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, nq_pad), (0, 0)))
    if nk_pad:
        kg = jnp.pad(kg, ((0, 0), (0, nk_pad), (0, 0)))
        vg = jnp.pad(vg, ((0, 0), (0, nk_pad), (0, 0)))

    n_q_blocks = (n_q + nq_pad) // block_q
    n_kv_blocks = (n_k + nk_pad) // block_k
    grid = (batch * kv_heads, n_q_blocks, n_kv_blocks)
    cached = q_offset is not None or kv_valid_len is not None

    kernel = functools.partial(
        _flash_attention_kernel,
        score_scale=scale, causal=causal, window=window, kv_len=n_k,
        kv_heads=kv_heads, block_q=block_q, block_k=block_k,
        n_kv_blocks=n_kv_blocks, cached=cached,
    )

    out = launch_prefill_kernel(
        kernel, qg, kg, vg, grid=grid, group=group, block_q=block_q,
        block_k=block_k, d=d,
        out_shape=jax.ShapeDtypeStruct(
            (batch * kv_heads, group, n_q + nq_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, block_q, d), jnp.float32),
            pltpu.VMEM((group, block_q), jnp.float32),
            pltpu.VMEM((group, block_q), jnp.float32),
        ],
        interpret=interpret,
        cursors=(pack_cursors(batch, q_offset, kv_valid_len, n_k)
                 if cached else None))

    out = out[:, :, :n_q, :]
    out = out.reshape(batch, kv_heads, group, n_q, d).transpose(0, 3, 1, 2, 4)
    return out.reshape(batch, n_q, heads, d)
