"""seamless-m4t-large-v2 — encoder-decoder multimodal (audio) transformer.
[arXiv:2308.11596; hf:facebook/seamless-m4t-v2-large]
24L (enc) + 24L (dec) d_model=1024 16H (kv=16) d_ff=8192 vocab=256206,
head_dim=64.

The speech frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (b, n_frames, 1024); the conformer feature
extractor is out of scope.  Backbone (self/cross attention, FFN ReLU,
LayerNorm) is fully implemented.
"""

from repro.configs.base import EncDecConfig, FrontendConfig, ModelConfig
from repro.core.attention import AttentionConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=24,
    d_model=1024,
    d_ff=8192,
    vocab_size=256206,
    attention=AttentionConfig(
        mechanism="dotprod", num_heads=16, num_kv_heads=16, head_dim=64,
        qkv_bias=True, use_rope=False, causal=True),
    norm="layernorm",
    norm_eps=1e-5,
    mlp="mlp_relu",
    mlp_bias=True,
    encdec=EncDecConfig(encoder_layers=24, decoder_layers=24,
                        max_source_len=4096),
    frontend=FrontendConfig(kind="audio", embed_dim=1024,
                            tokens_per_item=1, max_tiles=1),
    tie_embeddings=False,
    max_seq_len=32768,
    source="arXiv:2308.11596",
)
