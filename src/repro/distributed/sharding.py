"""Logical-axis → mesh-axis sharding rules and helpers.

Parameters and activations carry *logical* axis names ("embed", "heads",
"vocab", "expert", "batch", "seq", ...).  A rules table maps each logical
name to a mesh axis (or None = replicated).  This indirection is what lets
ten architectures share one distribution layer: changing the parallelism
strategy is a rules-table edit, not a model edit.

Axis roles (DESIGN.md §6):
  * ``pod``   — pure data parallelism across pods (cross-pod all-reduce)
  * ``data``  — FSDP: batch sharding + parameter/optimizer-state sharding
  * ``model`` — tensor parallelism (heads / mlp / vocab / experts) and
                sequence parallelism for activations in norm regions

``activation_rules`` differ from ``param_rules``: e.g. "embed" on a
*parameter* is FSDP-sharded over ``data``, while "embed" on an *activation*
is TP-sharded over ``model`` only in projection regions.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Rules tables
# ---------------------------------------------------------------------------

# Parameter logical axes.  FSDP ("data") shards one large dim of each weight;
# TP ("model") shards heads/mlp/vocab/expert dims.
PARAM_RULES = {
    "embed": ("data",),            # d_model dim of weights -> FSDP
    "heads": ("model",),           # query-head dim -> TP
    "kv_heads": ("model",),        # kv-head dim -> TP
    "head_dim": (),                # never sharded
    "mlp": ("model",),             # FFN hidden -> TP
    "heads_mlp": ("model",),       # fused head*dim projections (ssm/rwkv)
    "vocab": ("model",),           # embedding/vocab -> TP
    "expert": ("model",),          # MoE expert axis -> EP (over model)
    "layers": (),                  # stacked-scan layer axis
    None: (),
}

# Activation logical axes.
ACT_RULES = {
    "batch": ("pod", "data"),      # batch -> DP across pod×data
    "batch_heads": ("pod", "data", "model"),  # merged b×h dim (blocked attn)
    "seq": (),                     # sequence replicated by default
    "seq_sp": ("model",),          # sequence-parallel regions
    "embed": (),                   # d_model on activations: replicated
    "heads": ("model",),           # per-head activations -> TP
    "kv_heads": ("model",),
    "head_dim": (),
    "mlp": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    None: (),
}


class _ShardingCtx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.param_rules = dict(PARAM_RULES)
        self.act_rules = dict(ACT_RULES)


_CTX = _ShardingCtx()


@contextlib.contextmanager
def use_mesh(mesh: Mesh, *, param_rules=None, act_rules=None):
    """Activate a mesh + rules for logical-axis constraint helpers."""
    prev = (_CTX.mesh, _CTX.param_rules, _CTX.act_rules)
    _CTX.mesh = mesh
    if param_rules is not None:
        _CTX.param_rules = dict(param_rules)
    if act_rules is not None:
        _CTX.act_rules = dict(act_rules)
    try:
        with mesh:
            yield mesh
    finally:
        _CTX.mesh, _CTX.param_rules, _CTX.act_rules = prev


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def _spec_from_axes(axes, rules, mesh) -> P:
    parts = []
    used = set()
    for name in axes:
        mesh_axes = rules.get(name, ())
        # keep only axes present in this mesh and not already used
        eligible = tuple(a for a in mesh_axes
                         if a in mesh.axis_names and a not in used)
        used.update(eligible)
        if not eligible:
            parts.append(None)
        elif len(eligible) == 1:
            parts.append(eligible[0])
        else:
            parts.append(eligible)
    # PartitionSpec trailing Nones are implicit
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def _divisible(shape, spec, mesh) -> bool:
    for dim, part in zip(shape, spec):
        if part is None:
            continue
        names = part if isinstance(part, tuple) else (part,)
        size = int(np.prod([mesh.shape[n] for n in names]))
        if dim % size:
            return False
    return True


def param_spec(axes, shape=None, mesh: Optional[Mesh] = None) -> P:
    """PartitionSpec for a parameter with logical ``axes``.

    If ``shape`` is given, sharded dims that do not divide evenly fall back
    to replication (keeps tiny reduced-config tests shardable)."""
    mesh = mesh or _CTX.mesh
    if mesh is None:
        return P()
    spec = _spec_from_axes(axes, _CTX.param_rules, mesh)
    if shape is not None and not _divisible(shape, spec, mesh):
        # drop offending axes one dim at a time
        parts = list(spec) + [None] * (len(shape) - len(spec))
        for i, part in enumerate(parts):
            if part is None:
                continue
            names = part if isinstance(part, tuple) else (part,)
            size = int(np.prod([mesh.shape[n] for n in names]))
            if shape[i] % size:
                parts[i] = None
        while parts and parts[-1] is None:
            parts.pop()
        spec = P(*parts)
    return spec


def param_sharding(axes_tree, arr_tree, mesh: Optional[Mesh] = None):
    """Tree of NamedSharding for an unboxed param tree + axes tree."""
    mesh = mesh or _CTX.mesh
    assert mesh is not None

    def one(axes, arr):
        return NamedSharding(mesh, param_spec(axes, arr.shape, mesh))

    return jax.tree.map(one, axes_tree, arr_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def constrain(x: jax.Array, *axes) -> jax.Array:
    """with_sharding_constraint via activation logical axes. No-op when no
    mesh is active (single-device tests). Dims whose size does not divide
    their mesh axes fall back to replication *per dim* — when an early
    logical axis is dropped this way, later axes mapping to the same mesh
    axis get their chance (e.g. ("heads", "seq_sp") both -> "model": a
    40-head tensor on a 16-way axis shards its seq dim instead)."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    rules = _CTX.act_rules
    parts = []
    used = set()
    for i, name in enumerate(axes):
        mesh_axes = rules.get(name, ())
        eligible = tuple(a for a in mesh_axes
                         if a in mesh.axis_names and a not in used)
        if eligible and i < x.ndim:
            size = int(np.prod([mesh.shape[a] for a in eligible]))
            if x.shape[i] % size == 0 and x.shape[i] >= size:
                used.update(eligible)
                parts.append(eligible[0] if len(eligible) == 1
                             else eligible)
                continue
        parts.append(None)
    while parts and parts[-1] is None:
        parts.pop()
    spec = P(*parts)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def batch_spec(mesh: Optional[Mesh] = None) -> P:
    """PartitionSpec for a (batch, ...) input array."""
    mesh = mesh or _CTX.mesh
    if mesh is None:
        return P()
    return _spec_from_axes(("batch",), _CTX.act_rules, mesh)
