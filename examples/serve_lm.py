"""Serving example: continuous batching with ragged prompts.

  PYTHONPATH=src python examples/serve_lm.py --requests 12

Shows the engine admitting more requests than slots, recycling slots as
requests finish at different times, and reports throughput plus the
paged KV-cache accounting (page-pool high-water mark, bucketed prefill
compile count).  Try ``--allocator contiguous`` to compare against the
dense per-slot baseline, or ``--sample --temperature 0.8`` for sampled
decoding.  Pass --ckpt-dir to serve weights trained by
train_inhibitor_lm.py.
"""

from repro.launch import serve as serve_cli

if __name__ == "__main__":
    raise SystemExit(serve_cli.main())
