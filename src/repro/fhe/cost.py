"""TFHE execution-time cost model, calibrated against the paper's Table 4.

PBS dominates TFHE runtime; its cost grows ~linearly in polySize·level and
~linearly in lweDim (blind-rotation external products).  We model

    t_pbs(params) = C · (poly_size / 2048) · level · (lwe_dim / 800)
    t_circuit     = pbs_count · t_pbs + adds · t_add + lit_muls · t_lit

and calibrate C (seconds per reference PBS) against the paper's published
single-thread timings.  With the PBS inventories of
:mod:`repro.fhe.circuits`, C ≈ 25 ms reproduces Table 4 within ~2× across
both arms and all four sequence lengths, preserving the headline 3–6×
inhibitor speedup — the quantity this model exists to verify.
"""

from __future__ import annotations

from repro.fhe.params import TfheParams, select_params

# calibrated constants (single CPU thread, Concrete v1-era)
PBS_REF_SECONDS = 0.025     # one PBS at poly 2048 / level 1 / lwe 800
ADD_SECONDS = 4e-7          # levelled ciphertext add
LIT_MUL_SECONDS = 6e-7      # cleartext-constant multiply


def pbs_seconds(params: TfheParams) -> float:
    return (PBS_REF_SECONDS * (params.poly_size / 2048.0) * params.level
            * (params.lwe_dim / 800.0))


def circuit_seconds(summary: dict, params: TfheParams | None = None) -> float:
    """Estimated wall time for a circuit's cost summary."""
    p = params or select_params(summary["max_bits_at_pbs"])
    return (summary["pbs"] * pbs_seconds(p)
            + summary["adds"] * ADD_SECONDS
            + summary["lit_muls"] * LIT_MUL_SECONDS)


def describe(summary: dict) -> dict:
    p = select_params(summary["max_bits_at_pbs"])
    return {
        **summary,
        "lwe_dim": p.lwe_dim,
        "poly_size": p.poly_size,
        "base_log": p.base_log,
        "level": p.level,
        "est_seconds": round(circuit_seconds(summary, p), 3),
    }
