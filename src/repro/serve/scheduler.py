"""Pluggable request schedulers for the serving engine (DESIGN.md §11).

The engine's admission loop used to be a hardcoded FIFO ``deque``; the
``Scheduler`` protocol makes the admission *order* a policy:

``fifo``
    Arrival order (the old behavior, and the default).
``priority``
    Highest ``Request.priority`` first, FIFO within a priority level.
``prefix``
    Prefix affinity: prefer the queued request whose prompt has the
    longest prefix already resident in the engine's radix index
    (``serve.prefix.PrefixIndex``) — admitting it now costs the fewest
    prefill tokens and keeps hot prefixes hot.  Ties (including the
    all-miss case, and engines without a prefix cache) fall back to
    arrival order.  Affinity probes use ``touch=False`` so peeking at
    the index does not distort its LRU eviction order.

Protocol contract: ``next(engine)`` *peeks* — it returns the request the
policy would admit now without removing it, so the engine can back off
(pool dry, no free slot) and retry the same choice next tick; the engine
calls ``remove(req)`` once the request is actually admitted.  Policies
are registered by name (``register_scheduler``) and resolved by
``make_scheduler``, which also accepts a ready-made instance, so a custom
policy is a leaf change — no engine edits.

Continuous batching (DESIGN.md §15): ``prefill_quota(engine,
decode_slots)`` is the **token-budget-per-tick policy** — each engine
tick asks the scheduler how many prompt tokens chunked prefill may
execute this tick, given that ``decode_slots`` active requests will each
decode one token.  The default (decode-first: ``tick_budget`` minus the
decode slots, unbounded when ``EngineConfig.tick_budget`` is None) is
inherited by every policy here, so admission *order* and tick *budget*
compose independently; a custom policy can return 0 to defer prefill
entirely — the engine treats that as a scheduling choice, not a stuck
engine.

Starvation: ``priority`` and ``prefix`` are deliberately simple (no
aging); a starving workload should submit with adjusted priorities or
pick ``fifo``.
"""

from __future__ import annotations

from collections import deque
from typing import (Callable, Dict, List, Optional, Protocol, Union,
                    runtime_checkable)


@runtime_checkable
class Scheduler(Protocol):
    """Admission-order policy over submitted-but-not-admitted requests."""

    def add(self, req) -> None:
        """Enqueue a newly submitted request."""

    def next(self, engine) -> Optional["object"]:
        """The request the policy would admit now (peek, no removal), or
        None when empty.  ``engine`` grants read access to residency
        state (e.g. ``engine.prefix``)."""

    def remove(self, req) -> None:
        """Drop an admitted (or cancelled) request from the queue."""

    def pending(self) -> List["object"]:
        """Queued requests, in arrival order."""

    def prefill_quota(self, engine, decode_slots: int) -> Optional[int]:
        """Prompt-token budget for this tick's chunked prefill (None =
        unbounded — prefill whole prompts at admission).  ``decode_slots``
        is the number of active requests that will decode one token each
        this tick; the budget charges prefill by *padded* chunk widths
        (the tokens jit actually executes)."""

    def __len__(self) -> int:
        ...


class FIFOScheduler:
    """Arrival order — the engine's original hardcoded policy."""

    name = "fifo"

    def __init__(self):
        self._q: deque = deque()

    def add(self, req) -> None:
        self._q.append(req)

    def next(self, engine) -> Optional[object]:
        return self._q[0] if self._q else None

    def prefill_quota(self, engine, decode_slots: int) -> Optional[int]:
        """Default token-budget policy (inherited by every registered
        scheduler): decode gets first claim on the tick budget — each
        active slot produces exactly one token per tick — and chunked
        prefill spends what is left.  ``tick_budget=None`` keeps the
        legacy whole-prompt admission (unbounded prefill per tick)."""
        budget = engine.cfg.tick_budget
        if budget is None:
            return None
        return max(0, budget - decode_slots)

    def remove(self, req) -> None:
        self._q.remove(req)

    def pending(self) -> List[object]:
        return list(self._q)

    def __len__(self) -> int:
        return len(self._q)


class PriorityScheduler(FIFOScheduler):
    """Highest ``Request.priority`` first; FIFO within a level."""

    name = "priority"

    def next(self, engine) -> Optional[object]:
        if not self._q:
            return None
        # Request.arrival (stamped at submit) breaks priority ties FIFO
        return max(self._q, key=lambda r: (getattr(r, "priority", 0),
                                           -getattr(r, "arrival", 0)))


class PrefixAffinityScheduler(FIFOScheduler):
    """Longest-resident-prefix first (falls back to FIFO on all-miss or
    when the engine has no prefix index)."""

    name = "prefix"

    def next(self, engine) -> Optional[object]:
        if not self._q:
            return None
        index = getattr(engine, "prefix", None)
        if index is None or not index.root.children:
            return self._q[0]              # no index / cold cache: FIFO
        # Request.arrival breaks resident-length ties FIFO.  Probes are
        # O(queue * prompt_len) per peek — fine at engine queue depths;
        # a custom policy can memoize per-request keys if it must scale
        return max(self._q,
                   key=lambda r: (index.match(r.prompt, touch=False)[0],
                                  -getattr(r, "arrival", 0)))


SCHEDULERS: Dict[str, Callable[[], Scheduler]] = {}


def register_scheduler(name: str, factory: Callable[[], Scheduler]):
    SCHEDULERS[name] = factory


register_scheduler("fifo", FIFOScheduler)
register_scheduler("priority", PriorityScheduler)
register_scheduler("prefix", PrefixAffinityScheduler)


def make_scheduler(spec: Union[str, Scheduler, None]) -> Scheduler:
    """Resolve a scheduler: a registered name, a ready-made instance, or
    None (-> fifo)."""
    if spec is None:
        return FIFOScheduler()
    if isinstance(spec, str):
        try:
            return SCHEDULERS[spec]()
        except KeyError:
            raise ValueError(
                f"unknown scheduler {spec!r}; registered: "
                f"{sorted(SCHEDULERS)}") from None
    if isinstance(spec, Scheduler):
        return spec
    raise TypeError(f"scheduler must be a name or Scheduler, got "
                    f"{type(spec).__name__}")
