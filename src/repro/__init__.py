"""repro: Inhibitor-Transformer training/inference framework (JAX).

Reproduction + scale-out of "The Inhibitor: ReLU and Addition-Based
Attention for Efficient Transformers under Fully Homomorphic Encryption on
the Torus" (Brannvall & Stoian). See DESIGN.md for the system map.
"""

__version__ = "1.0.0"
