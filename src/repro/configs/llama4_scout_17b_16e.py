"""llama4-scout-17b-a16e — MoE LM, 16 routed experts top-1 + 1 shared expert.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
48L d_model=5120 40H (GQA kv=8) d_ff=8192 (expert) vocab=202048,
head_dim=128, MoE 16e top-1, every layer MoE (interleave step 1).

Simplifications recorded in DESIGN.md: QK-norm and the NoPE-every-4th-layer
trick of the released model are omitted; attention/RoPE is uniform llama
style so the layer stack stays scan-homogeneous.
"""

from repro.configs.base import ModelConfig, MoEConfig
from repro.core.attention import AttentionConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    d_ff=8192,
    vocab_size=202048,
    attention=AttentionConfig(
        mechanism="dotprod", num_heads=40, num_kv_heads=8, head_dim=128,
        qkv_bias=False, use_rope=True, rope_base=500000.0, causal=True),
    norm="rmsnorm",
    norm_eps=1e-5,
    mlp="gated_silu",
    moe=MoEConfig(
        num_experts=16, top_k=1, expert_hidden_dim=8192,
        shared_hidden_dim=8192, shared_gate=False,
        normalize_topk=False, capacity_factor=1.25),
    tie_embeddings=False,
    max_seq_len=262144,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
