"""CLI: statically analyze a named config and write ANALYSIS_fhe.json.

    PYTHONPATH=src python -m repro.analysis --config paper-tiny \
        --seq-len 8 --out ANALYSIS_fhe.json

Exit status is non-zero when any analyzed mechanism fails its structural
obligations: an inhibitor-family arm with a statically reachable
cipher×cipher multiply, an unverified LUT table width, or an
unselectable parameter point.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.analyzer import (DEFAULT_MECHANISMS, analyze_config,
                                     format_report)

_INHIBITOR_FAMILY = ("inhibitor", "inhibitor_unsigned")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static FHE circuit analysis (interval abstract "
                    "interpretation) of a PTQ'd config")
    ap.add_argument("--config", default="paper-tiny",
                    help="architecture id (default: paper-tiny)")
    ap.add_argument("--mechanisms", default=",".join(DEFAULT_MECHANISMS),
                    help="comma-separated mechanism list")
    ap.add_argument("--seq-len", type=int, default=8)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="ANALYSIS_fhe.json",
                    help="output JSON path ('-' for stdout only)")
    args = ap.parse_args(argv)

    mechs = [m.strip() for m in args.mechanisms.split(",") if m.strip()]
    doc = analyze_config(args.config, seq_len=args.seq_len,
                         batch=args.batch, mechanisms=mechs,
                         seed=args.seed)

    failures = []
    for mech, report in doc["mechanisms"].items():
        print(format_report(report))
        print()
        if mech in _INHIBITOR_FAMILY and not report["zero_cmul_proven"]:
            failures.append(f"{mech}: cipher×cipher multiply statically "
                            f"reachable ({report['cmul_sites']})")
        if not report["lut_verification"]["verified"]:
            failures.append(f"{mech}: LUT table width beyond the ceiling "
                            f"({report['lut_verification']['violations']})")
        if report.get("params") is None:
            failures.append(f"{mech}: {report.get('params_error')}")

    if args.out != "-":
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        print(f"wrote {args.out}")

    for msg in failures:
        print(f"ANALYSIS FAILURE: {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
