"""Encoder-decoder transformer (seamless-m4t family).

Frontend stub per assignment: the encoder consumes precomputed frame
embeddings (b, n_frames, d_frontend) — ``input_specs`` provides them; the
speech frontend itself is out of scope.  Both stacks scan over stacked
layer params; the decoder has self-attention (causal, cached at decode)
plus cross-attention over the encoder memory.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.attention import (AttentionConfig, KVCache, apply_attention,
                                  init_attention, init_kv_cache)
from repro.distributed.sharding import constrain
from repro.nn import embedding as emb
from repro.nn import norm as normnn
from repro.nn.linear import apply_dense, init_dense
from repro.nn.module import KeyGen, Param


class DecLayerState(NamedTuple):
    kv: KVCache


def _enc_attn_cfg(cfg: ModelConfig) -> AttentionConfig:
    return dataclasses.replace(cfg.attention, causal=False)


def _cross_attn_cfg(cfg: ModelConfig) -> AttentionConfig:
    return dataclasses.replace(cfg.attention, causal=False, use_rope=False)


def _init_norm(cfg, dtype):
    if cfg.norm == "rmsnorm":
        return normnn.init_rmsnorm(cfg.d_model, dtype=dtype)
    return normnn.init_layernorm(cfg.d_model, dtype=dtype)


def _apply_norm(cfg, p, x):
    if cfg.norm == "rmsnorm":
        return normnn.apply_rmsnorm(p, x, eps=cfg.norm_eps)
    return normnn.apply_layernorm(p, x, eps=cfg.norm_eps)


def _init_ffn(key, cfg, dtype):
    from repro.nn.mlp import init_mlp
    return init_mlp(key, cfg.d_model, cfg.d_ff, use_bias=True, dtype=dtype)


def _apply_ffn(cfg, p, x, cdt):
    from repro.nn.mlp import apply_mlp
    return apply_mlp(p, x, activation="relu", compute_dtype=cdt)


def init_enc_block(key, cfg: ModelConfig) -> dict:
    kg = KeyGen(key)
    dtype = cfg.pdtype
    return {
        "ln1": _init_norm(cfg, dtype),
        "attn": init_attention(kg("attn"), _enc_attn_cfg(cfg), cfg.d_model,
                               dtype=dtype),
        "ln2": _init_norm(cfg, dtype),
        "ffn": _init_ffn(kg("ffn"), cfg, dtype),
    }


def init_dec_block(key, cfg: ModelConfig) -> dict:
    kg = KeyGen(key)
    dtype = cfg.pdtype
    return {
        "ln1": _init_norm(cfg, dtype),
        "self_attn": init_attention(kg("self"), cfg.attention, cfg.d_model,
                                    dtype=dtype),
        "ln_cross": _init_norm(cfg, dtype),
        "cross_attn": init_attention(kg("cross"), _cross_attn_cfg(cfg),
                                     cfg.d_model, dtype=dtype),
        "ln2": _init_norm(cfg, dtype),
        "ffn": _init_ffn(kg("ffn"), cfg, dtype),
    }


def init_model(key, cfg: ModelConfig) -> dict:
    kg = KeyGen(key)
    dtype = cfg.pdtype
    ed = cfg.encdec

    enc_keys = jax.random.split(kg("enc"), ed.encoder_layers)
    dec_keys = jax.random.split(kg("dec"), ed.decoder_layers)
    enc = jax.vmap(lambda k: init_enc_block(k, cfg))(enc_keys)
    dec = jax.vmap(lambda k: init_dec_block(k, cfg))(dec_keys)
    stack = lambda tree: jax.tree.map(
        lambda p: Param(p.value, ("layers",) + p.axes) if isinstance(p, Param)
        else p, tree, is_leaf=lambda p: isinstance(p, Param))

    return {
        # frontend stub projection: frame embeddings -> d_model
        "frontend_proj": init_dense(kg("fp"), (cfg.frontend.embed_dim,),
                                    (cfg.d_model,), (None,), ("embed",),
                                    use_bias=True, dtype=dtype),
        "embed": emb.init_embedding(kg("embed"), cfg.vocab_size, cfg.d_model,
                                    dtype=dtype),
        "encoder": stack(enc),
        "enc_norm": _init_norm(cfg, dtype),
        "decoder": stack(dec),
        "dec_norm": _init_norm(cfg, dtype),
        "lm_head": init_dense(kg("head"), (cfg.d_model,), (cfg.vocab_size,),
                              ("embed",), ("vocab",), dtype=dtype),
    }


def encode(params, cfg: ModelConfig, frames: jax.Array):
    """frames: (b, n_src, d_frontend) -> encoder memory (b, n_src, d)."""
    cdt = cfg.cdtype
    x = apply_dense(params["frontend_proj"], frames.astype(cdt), 1, cdt)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    acfg = _enc_attn_cfg(cfg)

    def body(h, lp):
        a, _ = apply_attention(lp["attn"], acfg, _apply_norm(cfg, lp["ln1"], h),
                               positions=positions, compute_dtype=cdt)
        h = h + a
        f = _apply_ffn(cfg, lp["ffn"], _apply_norm(cfg, lp["ln2"], h), cdt)
        h = h + f
        return constrain(h, "batch", "seq_sp", "embed"), None

    body_fn = jax.checkpoint(body) if cfg.remat == "full" else body
    if cfg.unroll:
        from repro.models.transformer import unrolled_scan
        x, _ = unrolled_scan(body_fn, x, params["encoder"],
                             cfg.encdec.encoder_layers)
    else:
        x, _ = jax.lax.scan(body_fn, x, params["encoder"])
    return _apply_norm(cfg, params["enc_norm"], x)


def _dec_block(lp, cfg, h, memory, positions, state, cdt):
    a, new_kv = apply_attention(
        lp["self_attn"], cfg.attention, _apply_norm(cfg, lp["ln1"], h),
        positions=positions, cache=state.kv if state is not None else None,
        compute_dtype=cdt)
    h = h + a
    c, _ = apply_attention(
        lp["cross_attn"], _cross_attn_cfg(cfg),
        _apply_norm(cfg, lp["ln_cross"], h), x_kv=memory, compute_dtype=cdt)
    h = h + c
    f = _apply_ffn(cfg, lp["ffn"], _apply_norm(cfg, lp["ln2"], h), cdt)
    h = h + f
    return constrain(h, "batch", "seq_sp", "embed"), new_kv


def decode_train(params, cfg: ModelConfig, memory, tokens):
    """Teacher-forced decoder. tokens (b, t) -> logits (b, t, V)."""
    cdt = cfg.cdtype
    x = emb.apply_embedding(params["embed"], tokens, compute_dtype=cdt)
    b, t, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))

    def body(h, lp):
        h, _ = _dec_block(lp, cfg, h, memory, positions, None, cdt)
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat == "full" else body
    if cfg.unroll:
        from repro.models.transformer import unrolled_scan
        x, _ = unrolled_scan(body_fn, x, params["decoder"],
                             cfg.encdec.decoder_layers)
    else:
        x, _ = jax.lax.scan(body_fn, x, params["decoder"])
    x = _apply_norm(cfg, params["dec_norm"], x)
    logits = apply_dense(params["lm_head"], x, 1, cdt)
    return constrain(logits, "batch", None, "vocab")


def forward_train(params, cfg: ModelConfig, frames, tokens):
    """Full seq2seq training forward: (frames, target tokens) -> logits."""
    memory = encode(params, cfg, frames)
    return decode_train(params, cfg, memory, tokens)


def init_states(cfg: ModelConfig, batch: int, max_len: int, *,
                per_slot: bool = False) -> DecLayerState:
    a = cfg.attention
    kv = init_kv_cache(batch, max_len, a.num_kv_heads, a.head_dim,
                       dtype=cfg.cdtype, per_slot=per_slot)
    L = cfg.encdec.decoder_layers
    kv = KVCache(*(jnp.broadcast_to(t[None], (L,) + t.shape)
                   for t in (kv.k, kv.v)),
                 jnp.broadcast_to(kv.length, (L,)))
    return DecLayerState(kv=kv)


def decode_step(params, cfg: ModelConfig, memory, tokens,
                states: DecLayerState):
    """Incremental decode: tokens (b, t) appended at the cache cursor."""
    cdt = cfg.cdtype
    x = emb.apply_embedding(params["embed"], tokens, compute_dtype=cdt)
    b, t, _ = x.shape
    offset = states.kv.length[0]
    positions = jnp.broadcast_to(jnp.arange(t)[None] + offset, (b, t))

    def body(h, layer_in):
        lp, st = layer_in
        h, new_kv = _dec_block(lp, cfg, h, memory, positions,
                               DecLayerState(st), cdt)
        return h, new_kv

    if cfg.unroll:
        from repro.models.transformer import unrolled_scan
        x, new_kv = unrolled_scan(body, x, (params["decoder"], states.kv),
                                  cfg.encdec.decoder_layers)
    else:
        x, new_kv = jax.lax.scan(body, x, (params["decoder"], states.kv))
    x = _apply_norm(cfg, params["dec_norm"], x)
    logits = apply_dense(params["lm_head"], x, 1, cdt)
    return logits, DecLayerState(kv=new_kv)
